"""Layer-2 JAX models: the co-simulated applications of Table 4, plus
their deterministic synthetic datasets (the WikiText-2 / CIFAR-10
substitutes — see DESIGN.md substitution ledger).

Four trained-at-build-time models:

* ``resmlp_lite``  — MLP-only residual classifier (ResMLP stand-in);
  every layer is a linear layer -> FlexASR.
* ``lstm_wlm_lite`` — word-level LSTM language model (LSTM-WLM stand-in)
  -> FlexASR LSTM + linear decoder.
* ``resnet20_lite`` — 21-conv residual CNN (ResNet-20 stand-in)
  -> HLSCNN convolutions + FlexASR linear head.
* ``mobilenet_lite`` — depthwise-separable CNN (MobileNet-V2 stand-in);
  pointwise convs -> HLSCNN, depthwise (grouped) stay on host.

Architectures intentionally mirror the Rust IR graphs in
``rust/src/apps/cosim_models.rs`` op for op (same layouts: NCHW/OIHW
convs, ``x @ w.T`` dense, i-f-g-o LSTM gates); `aot.py` exports golden
forward outputs so the Rust side can prove the mirror exact.
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

IMG_SHAPE = (3, 8, 8)
NUM_CLASSES = 4
VOCAB = 64
SEQ_LEN = 16
EMBED = 32
HIDDEN = 32

# ----------------------------------------------------------------------
# synthetic datasets (deterministic)
# ----------------------------------------------------------------------

def make_images(n, seed, template_seed=7, noise=3.0):
    """4-class synthetic 3x8x8 images: fixed random class templates (the
    "dataset's structure", shared across splits like `make_text`'s chain)
    plus heavy Gaussian noise and amplitude jitter. Tuned so small models
    reach ~90% — near their capacity, like CIFAR-10 for the paper's
    models — which is what makes application-level accuracy sensitive to
    accelerator numerics (the Table 4 phenomenon)."""
    # low-frequency templates (4x4 upsampled to 8x8): spatially smooth
    # structure that convolutional models can learn as well as MLPs
    trng = np.random.default_rng(template_seed)
    coarse = trng.normal(0, 1, size=(NUM_CLASSES, 3, 4, 4))
    templates = np.repeat(np.repeat(coarse, 2, axis=2), 2, axis=3).astype(np.float32)
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, NUM_CLASSES, size=n)
    xs = np.zeros((n,) + IMG_SHAPE, dtype=np.float32)
    for i in range(n):
        amp = rng.uniform(0.7, 1.3)
        xs[i] = templates[ys[i]] * amp + rng.normal(0, noise, size=IMG_SHAPE)
    return xs, ys.astype(np.int32)


def make_text(n_tokens, seed, chain_seed=42):
    """Synthetic corpus over VOCAB tokens with strong bigram structure
    (each token has 4 likely successors), so a trained LSTM reaches
    perplexity far below uniform (VOCAB). The successor table (the
    "language") is fixed by `chain_seed` so train and test splits come
    from the same process; `seed` only varies the sampling."""
    rng = np.random.default_rng(seed)
    succ = np.random.default_rng(chain_seed).integers(0, VOCAB, size=(VOCAB, 4))
    toks = np.zeros(n_tokens, dtype=np.int32)
    cur = 0
    for i in range(n_tokens):
        toks[i] = cur
        if rng.uniform() < 0.9:
            cur = int(succ[cur, rng.integers(0, 4)])
        else:
            cur = int(rng.integers(0, VOCAB))
    return toks


# ----------------------------------------------------------------------
# param init helpers
# ----------------------------------------------------------------------

def _dense_init(rng, m, k):
    return (rng.normal(0, np.sqrt(2.0 / k), size=(m, k)).astype(np.float32),
            np.zeros(m, dtype=np.float32))


def _conv_init(rng, o, c, kh, kw):
    fan = c * kh * kw
    return rng.normal(0, np.sqrt(2.0 / fan), size=(o, c, kh, kw)).astype(np.float32)


def conv2d(x, w, stride=(1, 1), pad=(1, 1), groups=1):
    """NCHW/OIHW conv — identical semantics to tensor::ops::conv2d."""
    return lax.conv_general_dilated(
        x, w, window_strides=stride,
        padding=[(pad[0], pad[0]), (pad[1], pad[1])],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
    )


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608 * (x + 0.044715 * x ** 3)))


# ----------------------------------------------------------------------
# ResMLP-lite
# ----------------------------------------------------------------------

RESMLP_BLOCKS = 3
RESMLP_DIM = 96

def resmlp_init(seed=10):
    rng = np.random.default_rng(seed)
    p = {}
    p["l0_w"], p["l0_b"] = _dense_init(rng, RESMLP_DIM, 192)
    for i in range(RESMLP_BLOCKS):
        p[f"blk{i}_fc1_w"], p[f"blk{i}_fc1_b"] = _dense_init(rng, RESMLP_DIM, RESMLP_DIM)
        p[f"blk{i}_fc2_w"], p[f"blk{i}_fc2_b"] = _dense_init(rng, RESMLP_DIM, RESMLP_DIM)
    p["head_w"], p["head_b"] = _dense_init(rng, NUM_CLASSES, RESMLP_DIM)
    return p


def resmlp_forward(p, x):
    """x: [N, 3, 8, 8] -> logits [N, 4]."""
    h = x.reshape(x.shape[0], 192)
    h = gelu(h @ p["l0_w"].T + p["l0_b"])
    for i in range(RESMLP_BLOCKS):
        z = gelu(h @ p[f"blk{i}_fc1_w"].T + p[f"blk{i}_fc1_b"])
        z = z @ p[f"blk{i}_fc2_w"].T + p[f"blk{i}_fc2_b"]
        h = h + z
    return h @ p["head_w"].T + p["head_b"]


# ----------------------------------------------------------------------
# LSTM-WLM-lite
# ----------------------------------------------------------------------

def lstm_init(seed=11):
    rng = np.random.default_rng(seed)
    p = {}
    p["embed"] = rng.normal(0, 0.1, size=(VOCAB, EMBED)).astype(np.float32)
    p["w_ih"], _ = _dense_init(rng, 4 * HIDDEN, EMBED)
    p["w_hh"], _ = _dense_init(rng, 4 * HIDDEN, HIDDEN)
    p["b"] = np.zeros(4 * HIDDEN, dtype=np.float32)
    # encourage remembering at init: forget-gate bias 1
    p["b"][HIDDEN : 2 * HIDDEN] = 1.0
    p["head_w"], p["head_b"] = _dense_init(rng, VOCAB, HIDDEN)
    return p


def lstm_forward(p, tokens):
    """tokens: [N, T] int32 -> logits [N, T, VOCAB]. Sequence output only
    (final h/c dropped — the Appendix B simplification)."""
    x = p["embed"][tokens]  # [N, T, E]
    n = x.shape[0]
    h = jnp.zeros((n, HIDDEN))
    c = jnp.zeros((n, HIDDEN))

    def step(carry, xt):
        h, c = carry
        gates = xt @ p["w_ih"].T + h @ p["w_hh"].T + p["b"]
        i = jax.nn.sigmoid(gates[:, 0 * HIDDEN : 1 * HIDDEN])
        f = jax.nn.sigmoid(gates[:, 1 * HIDDEN : 2 * HIDDEN])
        g = jnp.tanh(gates[:, 2 * HIDDEN : 3 * HIDDEN])
        o = jax.nn.sigmoid(gates[:, 3 * HIDDEN : 4 * HIDDEN])
        nc = f * c + i * g
        nh = o * jnp.tanh(nc)
        return (nh, nc), nh

    (_, _), hs = lax.scan(step, (h, c), jnp.transpose(x, (1, 0, 2)))
    hs = jnp.transpose(hs, (1, 0, 2))  # [N, T, H]
    return hs @ p["head_w"].T + p["head_b"]


# ----------------------------------------------------------------------
# ResNet-20-lite
# ----------------------------------------------------------------------

RESNET_STAGES = [(8, 1), (16, 2), (32, 2)]  # (channels, first-stride)
RESNET_BLOCKS = 3

def resnet_init(seed=12):
    rng = np.random.default_rng(seed)
    p = {"conv0_w": _conv_init(rng, 8, 3, 3, 3)}
    cin = 8
    for s, (ch, _) in enumerate(RESNET_STAGES):
        for b in range(RESNET_BLOCKS):
            c1_in = cin if b == 0 else ch
            p[f"s{s}b{b}_c1_w"] = _conv_init(rng, ch, c1_in, 3, 3)
            p[f"s{s}b{b}_c2_w"] = _conv_init(rng, ch, ch, 3, 3)
        if cin != ch:
            p[f"s{s}_down_w"] = _conv_init(rng, ch, cin, 1, 1)
        cin = ch
    p["fc_w"], p["fc_b"] = _dense_init(rng, NUM_CLASSES, 32)
    return p


def resnet_forward(p, x):
    """x: [N, 3, 8, 8] -> logits [N, 4]. 21 convolutions + 1 linear."""
    h = jax.nn.relu(conv2d(x, p["conv0_w"]))
    for s, (ch, stride) in enumerate(RESNET_STAGES):
        for b in range(RESNET_BLOCKS):
            st = (stride, stride) if b == 0 else (1, 1)
            z = jax.nn.relu(conv2d(h, p[f"s{s}b{b}_c1_w"], stride=st))
            z = conv2d(z, p[f"s{s}b{b}_c2_w"])
            if b == 0 and f"s{s}_down_w" in p:
                sc = conv2d(h, p[f"s{s}_down_w"], stride=st, pad=(0, 0))
            else:
                sc = h
            h = jax.nn.relu(z + sc)
    h = jnp.mean(h, axis=(2, 3))  # global average pool -> [N, 32]
    return h @ p["fc_w"].T + p["fc_b"]


# ----------------------------------------------------------------------
# MobileNet-lite
# ----------------------------------------------------------------------

MOBILENET_BLOCKS = [(8, 16), (16, 16), (16, 32), (32, 32)]

def mobilenet_init(seed=13):
    rng = np.random.default_rng(seed)
    p = {"conv0_w": _conv_init(rng, 8, 3, 3, 3)}
    for i, (cin, cout) in enumerate(MOBILENET_BLOCKS):
        p[f"blk{i}_dw_w"] = _conv_init(rng, cin, 1, 3, 3)  # depthwise
        p[f"blk{i}_pw_w"] = _conv_init(rng, cout, cin, 1, 1)  # pointwise
    p["fc_w"], p["fc_b"] = _dense_init(rng, NUM_CLASSES, 32)
    return p


def mobilenet_forward(p, x):
    """x: [N, 3, 8, 8] -> logits [N, 4]. Depthwise convs are grouped (not
    HLSCNN-offloadable); pointwise 1x1 are offloadable."""
    h = jax.nn.relu(conv2d(x, p["conv0_w"]))
    for i, (cin, _) in enumerate(MOBILENET_BLOCKS):
        h = jax.nn.relu(conv2d(h, p[f"blk{i}_dw_w"], groups=cin))
        h = jax.nn.relu(conv2d(h, p[f"blk{i}_pw_w"], pad=(0, 0)))
    h = jnp.mean(h, axis=(2, 3))
    return h @ p["fc_w"].T + p["fc_b"]


# ----------------------------------------------------------------------
# training
# ----------------------------------------------------------------------

def train_classifier(init_fn, fwd, xs, ys, steps=400, batch=32, lr=3e-3, seed=0):
    """Adam training of a classifier; returns (params, final test acc fn)."""
    params = init_fn()
    keys = sorted(params.keys())

    def loss_fn(plist, xb, yb):
        p = dict(zip(keys, plist))
        logits = fwd(p, xb)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(logp[jnp.arange(xb.shape[0]), yb])

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    plist = [jnp.asarray(params[k]) for k in keys]
    m = [jnp.zeros_like(p) for p in plist]
    v = [jnp.zeros_like(p) for p in plist]
    rng = np.random.default_rng(seed)
    for t in range(1, steps + 1):
        idx = rng.integers(0, xs.shape[0], size=batch)
        _, grads = grad_fn(plist, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
        for i in range(len(plist)):
            m[i] = 0.9 * m[i] + 0.1 * grads[i]
            v[i] = 0.999 * v[i] + 0.001 * grads[i] ** 2
            mh = m[i] / (1 - 0.9 ** t)
            vh = v[i] / (1 - 0.999 ** t)
            plist[i] = plist[i] - lr * mh / (jnp.sqrt(vh) + 1e-8)
    return {k: np.asarray(p) for k, p in zip(keys, plist)}


def train_lm(xs_tokens, steps=400, batch=32, lr=3e-3, seed=0):
    """Train the LSTM LM on next-token prediction over the corpus."""
    params = lstm_init()
    keys = sorted(params.keys())
    ntok = xs_tokens.shape[0]

    def loss_fn(plist, toks):
        p = dict(zip(keys, plist))
        logits = lstm_forward(p, toks[:, :-1])
        logp = jax.nn.log_softmax(logits)
        tgt = toks[:, 1:]
        ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    plist = [jnp.asarray(params[k]) for k in keys]
    m = [jnp.zeros_like(p) for p in plist]
    v = [jnp.zeros_like(p) for p in plist]
    rng = np.random.default_rng(seed)
    for t in range(1, steps + 1):
        starts = rng.integers(0, ntok - SEQ_LEN - 1, size=batch)
        toks = np.stack([xs_tokens[s : s + SEQ_LEN + 1] for s in starts])
        _, grads = grad_fn(plist, jnp.asarray(toks))
        for i in range(len(plist)):
            m[i] = 0.9 * m[i] + 0.1 * grads[i]
            v[i] = 0.999 * v[i] + 0.001 * grads[i] ** 2
            mh = m[i] / (1 - 0.9 ** t)
            vh = v[i] / (1 - 0.999 ** t)
            plist[i] = plist[i] - lr * mh / (jnp.sqrt(vh) + 1e-8)
    return {k: np.asarray(p) for k, p in zip(keys, plist)}


def accuracy(fwd, params, xs, ys, batch=200):
    correct = 0
    for i in range(0, xs.shape[0], batch):
        logits = fwd(params, jnp.asarray(xs[i : i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, axis=-1) == ys[i : i + batch]))
    return correct / xs.shape[0]


def perplexity(params, tokens, n_sentences=100):
    """Mean per-token perplexity over consecutive test sentences."""
    total_nll, total_cnt = 0.0, 0
    for s in range(n_sentences):
        seq = tokens[s * (SEQ_LEN + 1) : (s + 1) * (SEQ_LEN + 1)]
        logits = lstm_forward(params, jnp.asarray(seq[None, :-1]))
        logp = jax.nn.log_softmax(logits)[0]
        nll = -float(jnp.mean(logp[jnp.arange(SEQ_LEN), seq[1:]]))
        total_nll += nll * SEQ_LEN
        total_cnt += SEQ_LEN
    return float(np.exp(total_nll / total_cnt))
