"""Layer-1 Pallas kernel: AdaptivFloat-quantized linear layer (the
FlexASR PE-array hot spot).

TPU-minded structure (DESIGN.md §Hardware-Adaptation): the GEMM is tiled
with BlockSpecs sized for the MXU (padded-up multiples of (8, 128) lanes;
full 128x128 tiles for real workloads), the per-tensor exponent biases are
scalar prefetch-style operands computed once outside the grid, and the
quantize/dequantize steps are elementwise VPU work fused into the tile
loop so every tile crosses HBM<->VMEM once.

Runs with `interpret=True` everywhere in this repo: the CPU PJRT client
cannot execute Mosaic custom-calls, so real-TPU lowering is out of scope
(perf is *estimated* from the BlockSpec footprint in EXPERIMENTS.md §Perf,
never measured from interpret-mode wallclock).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _af_quant_block(v, bias, bits=8, exp_bits=3):
    """In-kernel AdaptivFloat snap (same math as ref.af_quantize)."""
    return ref.af_quantize(v, bias, bits, exp_bits)


def _af_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, xb, wb, bbias, ob):
    """One (tile_n, tile_m) output tile: quantize operands on the way into
    the MACs, accumulate in f32, re-quantize on the way out."""
    xq = _af_quant_block(x_ref[...], xb)
    wq = _af_quant_block(w_ref[...], wb)
    bq = _af_quant_block(b_ref[...], bbias)
    acc = jnp.dot(xq, wq.T, preferred_element_type=jnp.float32) + bq
    o_ref[...] = _af_quant_block(acc, ob)


def af_linear(x, w, b, tile_n=8, tile_m=128, biases=None):
    """FlexASR linear layer as a Pallas kernel: `AF8(AF8(x) @ AF8(w)^T +
    AF8(b))` with per-tensor adaptive exponent biases.

    The exponent biases are *static* kernel parameters (the device
    configures them over MMIO before triggering — see the Rust ILA model);
    when `biases` is None they are derived from the concrete operands (the
    device's two-pass range scan). Under `jax.jit` tracing pass `biases`
    explicitly, since tracers have no concrete max. Tile shapes clamp to
    the problem size so small correctness shapes stay unpadded.
    """
    n, k = x.shape
    m = w.shape[0]
    if biases is None:
        xb = ref.af_select_bias(float(jnp.max(jnp.abs(x))))
        wb = ref.af_select_bias(float(jnp.max(jnp.abs(w))))
        bbias = ref.af_select_bias(float(jnp.max(jnp.abs(b))))
        # device two-pass output-range scan (f32 extremum of the result)
        xq = ref.af_quantize(x, xb)
        wq = ref.af_quantize(w, wb)
        bq = ref.af_quantize(b, bbias)
        acc = xq @ wq.T + bq
        ob = ref.af_select_bias(float(jnp.max(jnp.abs(acc))))
    else:
        xb, wb, bbias, ob = biases

    tn = min(tile_n, n)
    tm = min(tile_m, m)
    # grid over output tiles; K stays resident (fits VMEM for FlexASR's
    # layer sizes — checked in vmem_footprint_bytes)
    grid = (pl.cdiv(n, tn), pl.cdiv(m, tm))
    kernel = functools.partial(_af_linear_kernel, xb=xb, wb=wb, bbias=bbias, ob=ob)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, k), lambda i, j: (j, 0)),
            pl.BlockSpec((tm,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tn, tm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x, w, b)


def vmem_footprint_bytes(n, k, m, tile_n=8, tile_m=128):
    """Static VMEM footprint of one grid step (for the §Perf estimate):
    x-tile + w-tile + bias-tile + out-tile, f32."""
    tn, tm = min(tile_n, n), min(tile_m, m)
    return 4 * (tn * k + tm * k + tm + tn * tm)
