"""Layer-1 Pallas kernel: fixed-point GEMM (the HLSCNN conv-as-GEMM hot
spot: convolutions are im2col'd in the Layer-2 graph, then hit this
kernel).

Same TPU-minded tiling story as af_linear (see that module's docstring);
the quantization here is HLSCNN's Q(act_bits, act_frac) activations and
Q(wgt_bits, wgt_frac) weights with a wide accumulator — the weight width
is the Table 4 co-design knob, threaded through as kernel parameters.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _fx_gemm_kernel(x_ref, w_ref, o_ref, *, act_bits, act_frac, wgt_bits, wgt_frac):
    xq = ref.fx_quantize(x_ref[...], act_bits, act_frac)
    wq = ref.fx_quantize(w_ref[...], wgt_bits, wgt_frac)
    acc = jnp.dot(xq, wq.T, preferred_element_type=jnp.float32)
    o_ref[...] = ref.fx_quantize(acc, act_bits, act_frac)


def fx_gemm(
    x,
    w,
    act_bits=16,
    act_frac=8,
    wgt_bits=16,
    wgt_frac=12,
    tile_n=8,
    tile_m=128,
):
    """`FX(FX(x) @ FX(w)^T)` as a Pallas kernel over output tiles."""
    n, k = x.shape
    m = w.shape[0]
    tn = min(tile_n, n)
    tm = min(tile_m, m)
    grid = (pl.cdiv(n, tn), pl.cdiv(m, tm))
    kernel = functools.partial(
        _fx_gemm_kernel,
        act_bits=act_bits,
        act_frac=act_frac,
        wgt_bits=wgt_bits,
        wgt_frac=wgt_frac,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tn, tm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x, w)


def im2col_nchw(x, kh, kw, sh, sw, ph, pw):
    """Unfold NCHW input into [N*OH*OW, C*KH*KW] patches (matches
    tensor::ops::im2col in Rust)."""
    n, c, h, w = x.shape
    xpad = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)))
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (w + 2 * pw - kw) // sw + 1
    patches = []
    for dy in range(kh):
        for dx in range(kw):
            patches.append(
                xpad[:, :, dy : dy + sh * oh : sh, dx : dx + sw * ow : sw]
            )
    # [kh*kw, N, C, OH, OW] -> [N, OH, OW, C, kh*kw] -> rows
    stk = jnp.stack(patches)  # [KHKW, N, C, OH, OW]
    stk = jnp.transpose(stk, (1, 3, 4, 2, 0))  # [N, OH, OW, C, KHKW]
    return stk.reshape(n * oh * ow, c * kh * kw), (n, oh, ow)


def hlscnn_conv2d(x, w, stride=(1, 1), pad=(1, 1), wgt_bits=16, wgt_frac=12):
    """HLSCNN 2-D convolution: im2col (L2 graph) + fixed-point Pallas GEMM
    (L1 kernel), output back in NCHW."""
    o, _, kh, kw = w.shape
    patches, (n, oh, ow) = im2col_nchw(x, kh, kw, stride[0], stride[1], pad[0], pad[1])
    wflat = w.reshape(o, -1)
    y = fx_gemm(patches, wflat, wgt_bits=wgt_bits, wgt_frac=wgt_frac)
    return jnp.transpose(y.reshape(n, oh, ow, o), (0, 3, 1, 2))
