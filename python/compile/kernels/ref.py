"""Pure-jnp reference semantics (the correctness oracle for the Pallas
kernels, and the build-time mirror of the Rust `numerics` module).

The AdaptivFloat model here matches `rust/src/numerics/adaptivfloat.rs`
(format <8,3>: 1 sign | 3 exponent | 4 mantissa, per-tensor adaptive
exponent bias chosen from max-abs). The fixed-point model matches
`rust/src/numerics/fixed_point.rs`. Rounding-tie behaviour differs between
numpy (ties-to-even) and Rust f32::round (ties-away) at exact half-ULP
points; tests use lattice-step tolerances accordingly.
"""

import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------------
# AdaptivFloat <bits, exp_bits>
# ----------------------------------------------------------------------

def af_select_bias(max_abs, exp_bits=3):
    """Adaptive exponent bias for a tensor with the given max-abs."""
    e_max = (1 << exp_bits) - 1
    if max_abs <= 0.0 or not np.isfinite(max_abs):
        return 0
    return int(np.floor(np.log2(max_abs))) - e_max


def af_quantize(x, bias, bits=8, exp_bits=3):
    """Quantize a tensor onto the AdaptivFloat lattice (vectorized).

    Mirrors AdaptivFloatFormat::quantize_value in Rust: normals
    (-1)^s * 2^(E+bias) * (1 + M/2^m), saturation at the top, underflow to
    zero below half the min normal (snap to min normal above).
    """
    m = bits - 1 - exp_bits
    e_max = (1 << exp_bits) - 1
    scale = float(1 << m)

    a = jnp.abs(x)
    sign = jnp.where(x < 0, -1.0, 1.0)
    nz = a > 0
    safe_a = jnp.where(nz, a, 1.0)
    exp = jnp.floor(jnp.log2(safe_a))
    frac = safe_a / jnp.exp2(exp)
    mant = jnp.round((frac - 1.0) * scale)
    overflow = mant >= scale
    exp = jnp.where(overflow, exp + 1, exp)
    mant = jnp.where(overflow, 0.0, mant)
    frac = 1.0 + mant / scale

    e_biased = exp - bias
    max_mag = jnp.exp2(float(e_max + bias)) * (2.0 - 1.0 / scale)
    min_normal = jnp.exp2(float(bias))

    q = sign * jnp.exp2(exp) * frac
    q = jnp.where(e_biased > e_max, sign * max_mag, q)
    q = jnp.where(
        e_biased < 0,
        jnp.where(safe_a < min_normal / 2.0, 0.0, sign * min_normal),
        q,
    )
    return jnp.where(nz, q, 0.0)


def af_quantize_tensor(x, bits=8, exp_bits=3):
    """Per-tensor adaptive quantization (bias from the data)."""
    max_abs = float(jnp.max(jnp.abs(x)))
    bias = af_select_bias(max_abs, exp_bits)
    return af_quantize(x, bias, bits, exp_bits)


# ----------------------------------------------------------------------
# Fixed point Q(bits, frac)
# ----------------------------------------------------------------------

def fx_quantize(x, bits, frac_bits):
    """Symmetric saturating fixed-point quantization (ties-to-even)."""
    step = 2.0 ** (-frac_bits)
    max_int = float((1 << (bits - 1)) - 1)
    min_int = float(-(1 << (bits - 1)))
    scaled = jnp.clip(jnp.round(x / step), min_int, max_int)
    return scaled * step


# ----------------------------------------------------------------------
# Reference ops (the oracles)
# ----------------------------------------------------------------------

def ref_af_linear(x, w, b, bits=8, exp_bits=3):
    """FlexASR linear layer: AF-lattice operands, f32 MAC, AF output.

    Matches FlexAsr::linear in rust/src/accel/flexasr/mod.rs.
    """
    xq = af_quantize_tensor(x, bits, exp_bits)
    wq = af_quantize_tensor(w, bits, exp_bits)
    bq = af_quantize_tensor(b, bits, exp_bits)
    acc = xq @ wq.T + bq
    return af_quantize_tensor(acc, bits, exp_bits)


def ref_fx_gemm(x, w, act_bits=16, act_frac=8, wgt_bits=16, wgt_frac=12):
    """HLSCNN conv-as-GEMM core: fixed-point operands, wide MAC, fixed
    output (matches Hlscnn::conv2d's arithmetic on im2col'd patches)."""
    xq = fx_quantize(x, act_bits, act_frac)
    wq = fx_quantize(w, wgt_bits, wgt_frac)
    acc = xq @ wq.T
    return fx_quantize(acc, act_bits, act_frac)


def ref_lstm_cell(x, h, c, w_ih, w_hh, b):
    """One f32 LSTM cell step, PyTorch gate order (i, f, g, o) — matches
    tensor::ops::lstm_cell in Rust."""
    gates = x @ w_ih.T + h @ w_hh.T + b
    H = h.shape[-1]
    i = jnp.reciprocal(1.0 + jnp.exp(-gates[..., 0 * H : 1 * H]))
    f = jnp.reciprocal(1.0 + jnp.exp(-gates[..., 1 * H : 2 * H]))
    g = jnp.tanh(gates[..., 2 * H : 3 * H])
    o = jnp.reciprocal(1.0 + jnp.exp(-gates[..., 3 * H : 4 * H]))
    nc = f * c + i * g
    nh = o * jnp.tanh(nc)
    return nh, nc
