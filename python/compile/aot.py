"""AOT compile path: run ONCE at build time (`make artifacts`), never on
the request path.

Produces, under `artifacts/`:

* `dataset_images_{train,test}.bin` / `..._labels.bin` — synthetic image
  dataset (f32 NCHW / i32), `dataset_tokens_{train,test}.bin` (i32);
* `weights_<model>.bin` + `manifest_<model>.txt` — trained parameters
  (flat f32 LE; manifest lines: `name dims... offset_floats`);
* `golden_<model>.bin` — f32 forward outputs on the first test inputs,
  so the Rust IR mirror can prove itself equal to the JAX model;
* `<model>.hlo.txt` — the f32 forward pass lowered to HLO **text** (the
  interchange the Rust PJRT runtime loads; see /opt/xla-example/README);
* `af_linear_pallas.hlo.txt` — the Layer-1 Pallas kernel lowered
  (interpret mode) inside a jitted wrapper, for the runtime kernel demo;
* `meta.txt` — reference metrics (accuracy / perplexity) measured at
  train time, echoed by the Table 4 bench as "Reference Result".
"""

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import af_linear as K


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (NOT .serialize(): jax>=0.5
    emits 64-bit-id protos that xla_extension 0.5.1 rejects)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def save_weights(outdir, name, params):
    keys = sorted(params.keys())
    manifest = []
    flat = []
    off = 0
    for k in keys:
        a = np.asarray(params[k], dtype=np.float32)
        manifest.append(f"{k} {','.join(str(d) for d in a.shape)} {off}")
        flat.append(a.reshape(-1))
        off += a.size
    np.concatenate(flat).tofile(os.path.join(outdir, f"weights_{name}.bin"))
    with open(os.path.join(outdir, f"manifest_{name}.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=700)
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    t0 = time.time()
    meta = {}

    # ---- datasets -----------------------------------------------------
    xtr, ytr = M.make_images(3000, seed=1)
    xte, yte = M.make_images(2000, seed=2)
    xtr.tofile(f"{outdir}/dataset_images_train.bin")
    ytr.tofile(f"{outdir}/dataset_labels_train.bin")
    xte.tofile(f"{outdir}/dataset_images_test.bin")
    yte.tofile(f"{outdir}/dataset_labels_test.bin")
    toks_tr = M.make_text(20000, seed=3)
    toks_te = M.make_text(100 * (M.SEQ_LEN + 1), seed=4)
    toks_tr.tofile(f"{outdir}/dataset_tokens_train.bin")
    toks_te.tofile(f"{outdir}/dataset_tokens_test.bin")
    print(f"[aot] datasets written ({time.time()-t0:.1f}s)", flush=True)

    # ---- train the four co-sim models ----------------------------------
    jobs = [
        ("resmlp", M.resmlp_init, M.resmlp_forward),
        ("resnet20", M.resnet_init, M.resnet_forward),
        ("mobilenet", M.mobilenet_init, M.mobilenet_forward),
    ]
    for name, init, fwd in jobs:
        params = M.train_classifier(init, fwd, xtr, ytr, steps=args.steps)
        acc = M.accuracy(fwd, params, xte, yte)
        meta[f"{name}_ref_acc"] = f"{acc:.4f}"
        save_weights(outdir, name, params)
        golden = np.asarray(fwd(params, jnp.asarray(xte[:8])), dtype=np.float32)
        golden.tofile(f"{outdir}/golden_{name}.bin")
        print(f"[aot] {name}: test acc {acc:.3f} ({time.time()-t0:.1f}s)", flush=True)

    params = M.train_lm(toks_tr, steps=args.steps)
    ppl = M.perplexity(params, toks_te)
    meta["lstm_ref_ppl"] = f"{ppl:.2f}"
    save_weights(outdir, "lstm", params)
    g_tokens = toks_te[: M.SEQ_LEN + 1]
    golden = np.asarray(
        M.lstm_forward(params, jnp.asarray(g_tokens[None, :-1])), dtype=np.float32
    )
    golden.tofile(f"{outdir}/golden_lstm.bin")
    print(f"[aot] lstm: test ppl {ppl:.2f} ({time.time()-t0:.1f}s)", flush=True)

    # ---- lower forward passes to HLO text (weights baked as constants) --
    lstm_params = params
    # fetch resmlp params back from disk: one source of truth with rust
    resmlp_trained = load_weights(outdir, "resmlp")
    # weights are passed as PARAMETERS (sorted-key order, matching the
    # manifest): XLA's HLO-text printer elides large constant literals, so
    # baking weights as constants does NOT survive the text interchange.
    # The input is flat [1, 192] so every parameter is 1-/2-D with XLA's
    # default layout.
    rkeys = sorted(resmlp_trained.keys())

    def resmlp_fn(x, *plist):
        return M.resmlp_forward(dict(zip(rkeys, plist)), x)

    specs = [jax.ShapeDtypeStruct((1, 192), jnp.float32)] + [
        jax.ShapeDtypeStruct(resmlp_trained[k].shape, jnp.float32) for k in rkeys
    ]
    text = to_hlo_text(jax.jit(resmlp_fn).lower(*specs))
    with open(f"{outdir}/resmlp.hlo.txt", "w") as f:
        f.write(text)
    print(f"[aot] resmlp.hlo.txt ({len(text)} chars)", flush=True)

    lkeys = sorted(lstm_params.keys())

    def lstm_fn(toks, *plist):
        return M.lstm_forward(dict(zip(lkeys, plist)), toks)

    lspecs = [jax.ShapeDtypeStruct((1, M.SEQ_LEN), jnp.int32)] + [
        jax.ShapeDtypeStruct(np.asarray(lstm_params[k]).shape, jnp.float32)
        for k in lkeys
    ]
    text = to_hlo_text(jax.jit(lstm_fn).lower(*lspecs))
    with open(f"{outdir}/lstm.hlo.txt", "w") as f:
        f.write(text)
    print(f"[aot] lstm.hlo.txt ({len(text)} chars)", flush=True)

    # ---- lower the Layer-1 Pallas kernel itself ------------------------
    rng = np.random.default_rng(7)
    kx = rng.normal(0, 1, (8, 32)).astype(np.float32)
    kw = rng.normal(0, 0.3, (16, 32)).astype(np.float32)
    kb = rng.normal(0, 0.1, (16,)).astype(np.float32)
    # exponent biases are static config (computed here from the concrete
    # demo operands, exactly like the driver writes CFG_EXP_BIAS)
    import jax.numpy as _jnp
    from .kernels import ref as _ref
    xb = _ref.af_select_bias(float(np.max(np.abs(kx))))
    wb = _ref.af_select_bias(float(np.max(np.abs(kw))))
    bb = _ref.af_select_bias(float(np.max(np.abs(kb))))
    acc0 = np.asarray(_ref.af_quantize(_jnp.asarray(kx), xb)) @ np.asarray(
        _ref.af_quantize(_jnp.asarray(kw), wb)
    ).T + np.asarray(_ref.af_quantize(_jnp.asarray(kb), bb))
    ob = _ref.af_select_bias(float(np.max(np.abs(acc0))))
    kernel_fn = lambda x, w, b: K.af_linear(x, w, b, biases=(xb, wb, bb, ob))
    text = to_hlo_text(
        jax.jit(kernel_fn).lower(
            jax.ShapeDtypeStruct(kx.shape, jnp.float32),
            jax.ShapeDtypeStruct(kw.shape, jnp.float32),
            jax.ShapeDtypeStruct(kb.shape, jnp.float32),
        )
    )
    with open(f"{outdir}/af_linear_pallas.hlo.txt", "w") as f:
        f.write(text)
    # golden in/out for the rust runtime test
    kx.tofile(f"{outdir}/kernel_demo_x.bin")
    kw.tofile(f"{outdir}/kernel_demo_w.bin")
    kb.tofile(f"{outdir}/kernel_demo_b.bin")
    np.asarray(K.af_linear(jnp.asarray(kx), jnp.asarray(kw), jnp.asarray(kb), biases=(xb, wb, bb, ob)),
               dtype=np.float32).tofile(f"{outdir}/kernel_demo_out.bin")
    print(f"[aot] af_linear_pallas.hlo.txt ({len(text)} chars)", flush=True)

    with open(f"{outdir}/meta.txt", "w") as f:
        for k, v in sorted(meta.items()):
            f.write(f"{k} {v}\n")
    print(f"[aot] done in {time.time()-t0:.1f}s", flush=True)


def load_weights(outdir, name):
    params = {}
    flat = np.fromfile(f"{outdir}/weights_{name}.bin", dtype=np.float32)
    with open(f"{outdir}/manifest_{name}.txt") as f:
        for line in f:
            parts = line.split()
            if not parts:
                continue
            key, dims, off = parts[0], parts[1], int(parts[2])
            shape = tuple(int(d) for d in dims.split(","))
            n = int(np.prod(shape))
            params[key] = flat[off : off + n].reshape(shape)
    return params


if __name__ == "__main__":
    sys.exit(main())
