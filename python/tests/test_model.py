"""Layer-2 model tests: shapes, trainability, dataset determinism."""

import jax.numpy as jnp
import numpy as np

from compile import model as M


def test_dataset_determinism():
    x1, y1 = M.make_images(16, seed=5)
    x2, y2 = M.make_images(16, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    t1 = M.make_text(100, seed=6)
    t2 = M.make_text(100, seed=6)
    np.testing.assert_array_equal(t1, t2)


def test_forward_shapes():
    x = jnp.zeros((2, 3, 8, 8))
    assert M.resmlp_forward(M.resmlp_init(), x).shape == (2, 4)
    assert M.resnet_forward(M.resnet_init(), x).shape == (2, 4)
    assert M.mobilenet_forward(M.mobilenet_init(), x).shape == (2, 4)
    toks = jnp.zeros((2, M.SEQ_LEN), dtype=jnp.int32)
    assert M.lstm_forward(M.lstm_init(), toks).shape == (2, M.SEQ_LEN, M.VOCAB)


def test_resnet_has_21_convs():
    """The paper's ResNet-20 offloads 21 convolutions (Table 1 row 5)."""
    p = M.resnet_init()
    convs = [k for k in p if k.endswith("_w") and p[k].ndim == 4]
    assert len(convs) == 21, sorted(convs)


def test_classifier_learns_above_chance():
    xs, ys = M.make_images(600, seed=7)
    params = M.train_classifier(M.resmlp_init, M.resmlp_forward, xs, ys, steps=120)
    acc = M.accuracy(M.resmlp_forward, params, xs[:200], ys[:200])
    assert acc > 0.6, f"train acc {acc} barely above chance"


def test_lm_perplexity_below_uniform():
    toks = M.make_text(4000, seed=8)
    params = M.train_lm(toks, steps=120)
    ppl = M.perplexity(params, M.make_text(100 * (M.SEQ_LEN + 1), seed=9),
                       n_sentences=20)
    assert ppl < M.VOCAB * 0.7, f"ppl {ppl} not better than uniform"


def test_mobilenet_depthwise_is_grouped():
    """Depthwise convs must have singleton input-channel dim (groups=C) —
    the reason MobileNet's dw convs are NOT HLSCNN-offloadable."""
    p = M.mobilenet_init()
    for i, (cin, _) in enumerate(M.MOBILENET_BLOCKS):
        assert p[f"blk{i}_dw_w"].shape == (cin, 1, 3, 3)
