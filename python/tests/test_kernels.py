"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes; every property asserts allclose against the
reference implementation — the CORE correctness signal of the compile
path (the kernels lower into the same HLO the Rust runtime executes).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import af_linear as KA
from compile.kernels import fx_gemm as KF
from compile.kernels import ref


dims = st.integers(min_value=1, max_value=24)


@settings(max_examples=25, deadline=None)
@given(n=dims, k=dims, m=dims, seed=st.integers(0, 2**31 - 1))
def test_af_linear_matches_ref(n, k, m, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, k)).astype(np.float32)
    w = rng.normal(0, 0.3, (m, k)).astype(np.float32)
    b = rng.normal(0, 0.1, (m,)).astype(np.float32)
    got = KA.af_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    want = ref.ref_af_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(n=dims, k=dims, m=dims, seed=st.integers(0, 2**31 - 1))
def test_af_linear_tiled_grid_matches_untiled(n, k, m, seed):
    """Tiling must be a pure scheduling choice: different tile shapes,
    identical numerics."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, k)).astype(np.float32)
    w = rng.normal(0, 0.3, (m, k)).astype(np.float32)
    b = rng.normal(0, 0.1, (m,)).astype(np.float32)
    a = KA.af_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), tile_n=4, tile_m=4)
    c = KA.af_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), tile_n=64, tile_m=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=dims, k=dims, m=dims, seed=st.integers(0, 2**31 - 1),
       wbits=st.sampled_from([(8, 4), (16, 12)]))
def test_fx_gemm_matches_ref(n, k, m, seed, wbits):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, k)).astype(np.float32)
    w = rng.normal(0, 0.2, (m, k)).astype(np.float32)
    got = KF.fx_gemm(jnp.asarray(x), jnp.asarray(w), wgt_bits=wbits[0], wgt_frac=wbits[1])
    want = ref.ref_fx_gemm(jnp.asarray(x), jnp.asarray(w),
                           wgt_bits=wbits[0], wgt_frac=wbits[1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(c=st.integers(1, 4), o=st.integers(1, 6), hw=st.integers(4, 8),
       seed=st.integers(0, 2**31 - 1))
def test_hlscnn_conv_kernel_matches_direct_conv_in_16bit(c, o, hw, seed):
    """With wide 16-bit weights the kernel conv tracks the f32 conv to
    within a couple of activation steps."""
    from compile.model import conv2d
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (1, c, hw, hw)).astype(np.float32)
    w = rng.normal(0, 0.2, (o, c, 3, 3)).astype(np.float32)
    got = KF.hlscnn_conv2d(jnp.asarray(x), jnp.asarray(w))
    direct = conv2d(jnp.asarray(x), jnp.asarray(w))
    step = 2.0 ** -8
    assert np.max(np.abs(np.asarray(got) - np.asarray(direct))) < 16 * step


def test_af_quantize_idempotent():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (64,)).astype(np.float32))
    q1 = ref.af_quantize_tensor(x)
    q2 = ref.af_quantize_tensor(q1)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-6)


def test_af_quantize_relative_error_bound():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(0.02, 1.0, (1000,)).astype(np.float32))
    q = ref.af_quantize_tensor(x)
    nz = np.asarray(q) != 0
    rel = np.abs(np.asarray(q)[nz] - np.asarray(x)[nz]) / np.asarray(x)[nz]
    assert rel.max() <= 2.0 ** -5 + 1e-5  # half mantissa ULP at m=4


def test_af_quantize_zero_and_saturation():
    q = ref.af_quantize(jnp.asarray([0.0, 100.0, -100.0, 1e-8]), bias=-7)
    a = np.asarray(q)
    assert a[0] == 0.0
    assert 0 < a[1] < 2.1 and a[2] == -a[1]
    assert a[3] == 0.0


def test_vmem_footprint_under_tpu_budget():
    """The §Perf structural check: one grid step of the production tile
    shape must fit VMEM (16 MiB/core) with double buffering."""
    # FlexASR-sized layer: n=128 tokens, k=1024, m=1024
    fp = KA.vmem_footprint_bytes(128, 1024, 1024, tile_n=128, tile_m=128)
    assert 2 * fp < 16 * 1024 * 1024, f"footprint {fp} too large"


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lstm_cell_ref_gates(seed):
    """ref_lstm_cell sanity: zero weights -> h stays zero; forget-gate
    saturation keeps c."""
    rng = np.random.default_rng(seed)
    H = 8
    x = jnp.asarray(rng.normal(0, 1, (2, 4)).astype(np.float32))
    h = jnp.zeros((2, H))
    c = jnp.asarray(rng.normal(0, 1, (2, H)).astype(np.float32))
    wz = jnp.zeros((4 * H, 4))
    uz = jnp.zeros((4 * H, H))
    b = np.zeros(4 * H, dtype=np.float32)
    b[H : 2 * H] = 100.0  # forget gate wide open
    nh, nc = ref.ref_lstm_cell(x, h, c, wz, uz, jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(nc), np.asarray(c), rtol=1e-5)
    assert np.all(np.abs(np.asarray(nh)) <= 1.0)
