//! **Reproduces: Fig. 3(a) → Fig. 5(c)/(d)** — the whole D2A flow on one
//! small program, through the unified session API.
//!
//! 1. write an IR program (a linear layer, Fig. 3a),
//! 2. build a [`Session`] and compile the program with equality
//!    saturation (flexible matching) into a [`CompiledProgram`] handle,
//! 3. inspect the rewritten program (accelerator instructions present)
//!    and co-simulate it — reference f32 vs accelerator numerics —
//!    straight from the handle,
//! 4. lower the matched operation to a FlexASR ILA fragment (Fig. 5c)
//!    and its MMIO command stream (Fig. 5d),
//! 5. execute the stream on the emulated SoC and check the numerics
//!    against the ILA tensor fast path.
//!
//! Run with: `cargo run --release --example quickstart`

use d2a::accel::{Accelerator, FlexAsr};
use d2a::ir::{parse::to_sexpr, GraphBuilder, Op, Target};
use d2a::session::{Bindings, Session};
use d2a::soc::driver::Driver;
use d2a::tensor::Tensor;
use d2a::util::Rng;
use std::collections::HashMap;

fn main() -> anyhow::Result<()> {
    // 1. the compiler-IR program: bias_add(nn_dense(x, w), b)
    let mut g = GraphBuilder::new();
    let x = g.var("x");
    let w = g.weight("w");
    let b = g.weight("b");
    g.linear(x, w, b);
    let program = g.finish();
    println!("IR program (Fig. 3a):\n  {}\n", to_sexpr(&program));

    // 2. one session = targets + matching mode + accelerator models
    let shapes: HashMap<String, Vec<usize>> = [
        ("x".to_string(), vec![4usize, 16]),
        ("w".to_string(), vec![8, 16]),
        ("b".to_string(), vec![8]),
    ]
    .into_iter()
    .collect();
    let session = Session::builder().targets(&[Target::FlexAsr]).build();
    let compiled = session.compile_expr(&program, &shapes);
    let stats = compiled.stats().expect("freshly compiled");
    println!(
        "compiled ({} e-classes explored, {:?}):\n  {}\n",
        stats.classes,
        stats.stop,
        to_sexpr(compiled.expr())
    );
    assert_eq!(compiled.invocations(Target::FlexAsr), 1);

    // 3. co-simulate straight from the handle: f32 reference vs the
    //    bit-accurate AdaptivFloat fast path, one call
    let dev = FlexAsr::new();
    let mut rng = Rng::new(42);
    let xv = dev.quant(&Tensor::randn(&[4, 16], &mut rng, 1.0));
    let wv = dev.quant(&Tensor::randn(&[8, 16], &mut rng, 0.3));
    let bv = dev.quant(&Tensor::randn(&[8], &mut rng, 0.1));
    let bindings = Bindings::new()
        .with("x", xv.clone())
        .with("w", wv.clone())
        .with("b", bv.clone());
    let rep = compiled.cosim(&bindings)?;
    println!(
        "co-sim: {} accelerator invocation(s), accelerator-vs-f32 error {:.2}% \
         (the AdaptivFloat numerics gap)\n",
        rep.invocations,
        rep.rel_error * 100.0
    );

    // 4. lower the matched fasr_linear to ILA assembly + MMIO commands
    let prog = dev
        .lower_concrete(&Op::FlexLinear, &[&xv, &wv, &bv])
        .expect("linear fits the device");
    let inv = &prog.invocations[0];
    println!("FlexASR ILA fragment (Fig. 5c):\n{}", inv.asm);
    println!("tail of the MMIO stream (Fig. 5d):");
    let cmds: Vec<_> = inv.cmds().collect();
    for cmd in cmds.iter().rev().take(7).rev() {
        println!("  {cmd}");
    }

    // 5. run on the emulated SoC, compare against the ILA fast path and
    //    the session's accelerated result
    let mut driver = Driver::new(d2a::soc::reference_soc());
    let accel_out = driver.invoke_program(&prog)?;
    let host_out = dev
        .exec_op(&Op::FlexLinear, &[&xv, &wv, &bv])
        .unwrap();
    println!(
        "\nMMIO-vs-ILA-fast-path error: {:.2e} (same semantics, two views)",
        accel_out.rel_error(&host_out)
    );
    println!(
        "MMIO-vs-session-run error:   {:.2e} (the handle dispatches to the \
         same models)",
        accel_out.rel_error(&rep.accelerated)
    );
    Ok(())
}
