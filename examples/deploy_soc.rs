//! **Reproduces: §4.3.2** — system deployment, on the emulated SoC instead of the Zynq
//! ZCU102: compile synthetic programs in which LSTM layers and linear
//! layers are offloaded to FlexASR, lower them to MMIO command streams,
//! and drive them through the XSDK-style driver over the bus.
//!
//! Run with: `cargo run --release --example deploy_soc`

use d2a::accel::{Accelerator, FlexAsr, Vta};
use d2a::ir::Op;
use d2a::soc::driver::Driver;
use d2a::soc::reference_soc;
use d2a::tensor::Tensor;
use d2a::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut drv = Driver::new(reference_soc());
    let fa = FlexAsr::new();
    let vta = Vta::new();
    let mut rng = Rng::new(2024);

    println!("=== synthetic program 1: two chained FlexASR linear layers ===");
    let x = fa.quant(&Tensor::randn(&[4, 32], &mut rng, 1.0));
    let w1 = fa.quant(&Tensor::randn(&[16, 32], &mut rng, 0.3));
    let b1 = fa.quant(&Tensor::randn(&[16], &mut rng, 0.1));
    let lin1 = fa.lower_concrete(&Op::FlexLinear, &[&x, &w1, &b1]).expect("fits");
    let h = drv.invoke_program(&lin1)?;
    let w2 = fa.quant(&Tensor::randn(&[8, 16], &mut rng, 0.3));
    let b2 = fa.quant(&Tensor::randn(&[8], &mut rng, 0.1));
    let hq = fa.quant(&h);
    let lin2 = fa.lower_concrete(&Op::FlexLinear, &[&hq, &w2, &b2]).expect("fits");
    let y = drv.invoke_program(&lin2)?;
    let expect = fa.linear(&fa.quant(&fa.linear(&x, &w1, &b1)), &w2, &b2);
    println!(
        "  output {:?}, error vs ILA fast path {:.2e}",
        y.shape,
        y.rel_error(&expect)
    );

    println!("=== synthetic program 2: fused temporal-maxpool chain ===");
    let t = fa.quant(&Tensor::randn(&[64, 64], &mut rng, 1.0));
    let inv = fa.lower_maxpool_chain(&t, 4);
    let pooled = drv.invoke(&inv)?;
    println!(
        "  {:?} -> {:?} with ONE store + ONE load ({} data beats)",
        t.shape,
        pooled.shape,
        inv.data_beats()
    );

    println!("=== synthetic program 3: heterogeneous FlexASR -> VTA pipeline ===");
    let q = vta.quant(&pooled.reshape(&[4, 64]));
    let wq = vta.quant(&Tensor::randn(&[8, 64], &mut rng, 1.0));
    let gemm = vta.lower_concrete(&Op::VtaGemm, &[&q, &wq]).expect("fits");
    let g = drv.invoke_program(&gemm)?;
    assert_eq!(g.rel_error(&vta.gemm(&q, &wq)), 0.0);
    println!("  VTA GEMM exact ({:?})", g.shape);

    println!(
        "\nbus summary: {} MMIO commands total across {} devices",
        drv.bus.total_steps(),
        3
    );
    let _ = fa.supported_ops();
    Ok(())
}
