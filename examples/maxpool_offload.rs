//! **Reproduces: §5.1 / Fig. 7** — offloading 2-D max pooling with
//! window (4,4) and stride (2,2) onto FlexASR's fixed (2,1)/(2,1)
//! temporal max pool, then cancelling the redundant intermediate
//! store/loads — entirely through the Session API
//! (`SessionBuilder::extended_rules` enables the §5.1 data-movement
//! rule set; the compiled handle runs the optimized program under both
//! execution backends).
//!
//! Run with: `cargo run --release --example maxpool_offload`

use d2a::codegen::optimize::{pool_chains, transfer_stats};
use d2a::ir::{parse::to_sexpr, Op, RecExpr, Target};
use d2a::session::{Bindings, ExecBackend, Session};
use d2a::tensor::Tensor;
use d2a::util::Rng;
use std::collections::HashMap;

fn main() {
    // Fig. 7(b): the initial program
    let mut program = RecExpr::new();
    let t = program.add(Op::Var("t".into()), vec![]);
    program.add(Op::MatMaxPool { window: (4, 4), stride: (2, 2) }, vec![t]);
    println!("initial program (Fig. 7b):\n  {}\n", to_sexpr(&program));

    let shapes: HashMap<String, Vec<usize>> =
        [("t".to_string(), vec![128usize, 128])].into_iter().collect();

    // one session carries the whole policy: FlexASR target, flexible
    // matching, plus the extended §5.1 store/load-cancellation rules
    let session = Session::builder()
        .targets(&[Target::FlexAsr])
        .extended_rules(true)
        .build();
    let compiled = session.compile_expr(&program, &shapes);

    // Fig. 7(f): optimized offload
    println!("optimized offload (Fig. 7f):\n  {}\n", to_sexpr(compiled.expr()));
    let stats = transfer_stats(compiled.expr());
    println!(
        "data movement: {} store, {} load, {} fasr_maxpool stages (chains {:?})",
        stats.stores,
        stats.loads,
        stats.compute,
        pool_chains(compiled.expr())
    );
    assert_eq!(stats.stores, 1);
    assert_eq!(stats.loads, 1);
    assert_eq!(stats.compute, 4);

    // rewrite-equivalence check, through handles: the optimized program
    // computes the same f32 function as the original (both run_ref)
    let mut rng = Rng::new(3);
    let bindings = Bindings::new().with("t", Tensor::randn(&[128, 128], &mut rng, 1.0));
    let original = session.attach(program.clone());
    let reference = original.run_ref(&bindings).unwrap();
    let rewritten = compiled.run_ref(&bindings).unwrap();
    println!(
        "\nrewritten-vs-original f32 max|diff|: {:.2e} over {:?} output",
        rewritten.max_abs_diff(&reference),
        rewritten.shape
    );
    assert!(rewritten.max_abs_diff(&reference) < 1e-6);

    // accelerated run: store/load cross the AF8 interface, so the gap to
    // f32 is the (small) AdaptivFloat quantization error, not zero
    let accelerated = compiled.run(&bindings).unwrap();
    let gap = accelerated.rel_error(&reference);
    println!("accelerated (AF8) vs f32 relative error: {:.2}%", gap * 100.0);
    assert!(gap < 0.1, "AdaptivFloat gap out of range: {gap}");

    // the same handle at MMIO fidelity: every pool stage as a real
    // command program on the FlexASR ILA simulator, bit-identical
    let mmio = Session::builder()
        .targets(&[Target::FlexAsr])
        .backend(ExecBackend::IlaMmio)
        .build()
        .attach(compiled.expr().clone());
    let mut engine = mmio.engine();
    let trace = mmio.run_traced_with(&mut engine, &bindings).unwrap();
    assert_eq!(trace.output, accelerated, "MMIO and functional agree bit-exactly");
    println!(
        "MMIO replay: {} invocation(s) as real command programs, \
         {} simulator reset(s), {} B of state restored (dirty-region resets)",
        trace.mmio_invocations,
        engine.resets(),
        engine.bytes_cleared()
    );
}
