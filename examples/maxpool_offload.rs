//! The §5.1 / Fig. 7 walk: offloading 2-D max pooling with window (4,4)
//! and stride (2,2) onto FlexASR's fixed (2,1)/(2,1) temporal max pool,
//! then cancelling the redundant intermediate store/loads.
//!
//! Run with: `cargo run --release --example maxpool_offload`

use d2a::codegen::optimize::{pool_chains, transfer_stats};
use d2a::egraph::{AccelCost, EGraph, Extractor, Runner, RunnerLimits};
use d2a::ir::{interp, parse::to_sexpr, Op, RecExpr, Target};
use d2a::rewrites::{rules_for_extended, Matching};
use d2a::tensor::Tensor;
use d2a::util::Rng;
use std::collections::HashMap;

fn main() {
    // Fig. 7(b): the initial program
    let mut program = RecExpr::new();
    let t = program.add(Op::Var("t".into()), vec![]);
    program.add(Op::MatMaxPool { window: (4, 4), stride: (2, 2) }, vec![t]);
    println!("initial program (Fig. 7b):\n  {}\n", to_sexpr(&program));

    let shapes: HashMap<String, Vec<usize>> =
        [("t".to_string(), vec![128usize, 128])].into_iter().collect();
    let mut eg = EGraph::new(shapes);
    let root = eg.add_expr(&program);
    let rules = rules_for_extended(&[Target::FlexAsr], Matching::Flexible);
    Runner::new(RunnerLimits::default()).run(&mut eg, &rules);
    let best = Extractor::new(&eg, AccelCost::for_target(Target::FlexAsr)).extract(root);

    // Fig. 7(f): optimized offload
    println!("optimized offload (Fig. 7f):\n  {}\n", to_sexpr(&best));
    let stats = transfer_stats(&best);
    println!(
        "data movement: {} store, {} load, {} fasr_maxpool stages (chains {:?})",
        stats.stores,
        stats.loads,
        stats.compute,
        pool_chains(&best)
    );
    assert_eq!(stats.stores, 1);
    assert_eq!(stats.loads, 1);
    assert_eq!(stats.compute, 4);

    // semantics check against the original program
    let mut rng = Rng::new(3);
    let tv = Tensor::randn(&[128, 128], &mut rng, 1.0);
    let env: HashMap<String, Tensor> = [("t".to_string(), tv)].into_iter().collect();
    let a = interp::eval(&program, &env).unwrap();
    let b = interp::eval(&best, &env).unwrap();
    println!(
        "\nrewritten program max|diff| vs original: {:.2e} over {:?} output",
        a.max_abs_diff(&b),
        a.shape
    );
    assert!(a.max_abs_diff(&b) < 1e-6);
}
