//! **Reproduces: the Table 4 pipeline end to end (L1/L2/L3 composition)**
//! — proves all three layers compose on a real (small) workload.
//!
//! * **L1/L2 → artifacts**: `make artifacts` trained the models in JAX
//!   (AdaptivFloat Pallas kernel in the compile path) and lowered the
//!   ResMLP forward pass + the raw Pallas kernel to HLO text.
//! * **runtime**: this binary loads both HLO modules via the PJRT CPU
//!   client (`xla` crate) and executes them from Rust — no Python.
//! * **L3**: the D2A compiler offloads the mirrored IR graph to FlexASR,
//!   and the session's classify_sweep runs the 2000-image test set through
//!   co-simulation, reporting the Table-4-style row.
//!
//! Run with: `cargo run --release --example e2e_cosim` (after
//! `make artifacts`). Set D2A_COSIM_N to change the sweep size.

use d2a::ir::Target;
use d2a::runtime::{pjrt::PjrtInput, ArtifactStore, PjrtRunner};
use d2a::session::{DesignRev, SessionBuilder, SweepSpec};
use d2a::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open(None)?;
    let mut runner = PjrtRunner::new()?;
    println!("PJRT platform: {}", runner.platform());

    // ---- 1. execute the AOT-lowered Pallas kernel from Rust ----------
    runner.load("af_linear", &store.hlo_path("af_linear_pallas"))?;
    let kx = Tensor::new(vec![8, 32], store.read_f32("kernel_demo_x.bin")?);
    let kw = Tensor::new(vec![16, 32], store.read_f32("kernel_demo_w.bin")?);
    let kb = Tensor::new(vec![16], store.read_f32("kernel_demo_b.bin")?);
    let want = Tensor::new(vec![8, 16], store.read_f32("kernel_demo_out.bin")?);
    let got = runner.run(
        "af_linear",
        &[PjrtInput::F32(kx), PjrtInput::F32(kw), PjrtInput::F32(kb)],
        &[8, 16],
    )?;
    println!(
        "L1 Pallas kernel via PJRT: max|diff| vs python golden = {:.2e}",
        got.max_abs_diff(&want)
    );
    assert!(got.max_abs_diff(&want) < 1e-5, "kernel artifact mismatch");

    // ---- 2. execute the lowered ResMLP forward pass, check goldens ----
    runner.load("resmlp", &store.hlo_path("resmlp"))?;
    let (images, labels) = store.test_images()?;
    let golden = store.golden("resmlp", &[8, 4])?;
    let mut maxdiff = 0.0f32;
    for i in 0..8 {
        let out = runner.run("resmlp", &resmlp_inputs(&store, &images[i])?, &[1, 4])?;
        for j in 0..4 {
            maxdiff = maxdiff.max((out.data[j] - golden.data[i * 4 + j]).abs());
        }
    }
    println!("L2 ResMLP fwd via PJRT: max|diff| vs python golden = {maxdiff:.2e}");
    assert!(maxdiff < 1e-3, "model artifact mismatch");

    // ---- 3. D2A-compile the IR mirror and co-simulate the sweep -------
    let app = d2a::apps::cosim_models::resmlp_lite();
    let weights = store.weights("resmlp")?;
    let n: usize = std::env::var("D2A_COSIM_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000)
        .min(images.len());
    // compile once; the extracted program is revision-independent
    let compiled = SessionBuilder::new().targets(&[Target::FlexAsr]).build().compile(&app);
    println!(
        "L3 compiled ResMLP: {} FlexASR invocations per image",
        compiled.invocations(Target::FlexAsr)
    );
    for rev in [DesignRev::Original, DesignRev::Updated] {
        // one session per design revision: the accelerator models are
        // instantiated once and Arc-shared by every sweep worker
        let session =
            SessionBuilder::new().targets(&[Target::FlexAsr]).design_rev(rev).build();
        let program = session.attach(compiled.expr().clone());
        let rep = program.classify_sweep(&SweepSpec {
            input_var: "x",
            weights: &weights,
            inputs: &images[..n],
            labels: &labels[..n],
        });
        println!(
            "co-sim {rev:?}: {} images, reference {:.2}%, accelerated {:.2}% \
             ({:.1?}/image)",
            rep.n,
            rep.ref_accuracy() * 100.0,
            rep.acc_accuracy() * 100.0,
            rep.time_per_point()
        );
    }
    Ok(())
}

/// Build the resmlp PJRT argument list: flat input + weights in
/// sorted-key order (the aot.py parameter convention).
fn resmlp_inputs(
    store: &ArtifactStore,
    img: &d2a::tensor::Tensor,
) -> anyhow::Result<Vec<PjrtInput>> {
    let weights = store.weights("resmlp")?;
    let mut keys: Vec<_> = weights.keys().cloned().collect();
    keys.sort();
    let mut inputs = vec![PjrtInput::F32(img.reshape(&[1, 192]))];
    for k in keys {
        inputs.push(PjrtInput::F32(weights[&k].clone()));
    }
    Ok(inputs)
}
