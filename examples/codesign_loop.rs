//! The §4.4.2 software/hardware co-design loop, replayed:
//!
//! 1. compile ResNet-20 for FlexASR + HLSCNN and co-simulate — accuracy
//!    collapses with the *original* designs (HLSCNN's coarse 8-bit
//!    fixed-point weight store);
//! 2. inspect the per-invocation error statistics the co-sim gathers
//!    (what the paper's authors reported to the accelerator developers);
//! 3. re-run with the *updated* designs (16-bit weight store) — accuracy
//!    recovers, without ever deploying to an FPGA.
//!
//! Requires `make artifacts`. Run with:
//! `cargo run --release --example codesign_loop`

use d2a::compiler::compile_app;
use d2a::coordinator::{accelerators, DesignRev};
use d2a::cosim::AccelHook;
use d2a::egraph::RunnerLimits;
use d2a::ir::interp::eval_with_hook;
use d2a::ir::Target;
use d2a::rewrites::Matching;
use d2a::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open(None)?;
    let app = d2a::apps::cosim_models::resnet20_lite();
    let compiled = compile_app(
        &app,
        &[Target::FlexAsr, Target::Hlscnn],
        Matching::Flexible,
        RunnerLimits::default(),
    );
    println!(
        "ResNet-20 compiled: {} HLSCNN convs + {} FlexASR linears offloaded\n",
        compiled.invocations(Target::Hlscnn),
        compiled.invocations(Target::FlexAsr)
    );

    let weights = store.weights("resnet20")?;
    let (images, labels) = store.test_images()?;
    let n = 120usize;

    for rev in [DesignRev::Original, DesignRev::Updated] {
        let accels = accelerators(rev);
        let mut env = weights.clone();
        let mut correct = 0usize;
        let mut errors: Vec<f32> = Vec::new();
        for (img, &label) in images[..n].iter().zip(&labels[..n]) {
            env.insert("x".to_string(), img.clone());
            let mut hook = AccelHook::new(&accels);
            hook.track_errors = true;
            let out = eval_with_hook(&compiled.expr, &env, &mut hook)?;
            if out.argmax() == label {
                correct += 1;
            }
            errors.extend(hook.inv_errors);
        }
        let stats = d2a::cosim::stats::ErrorStats::from_samples(&errors);
        println!(
            "HLSCNN+FlexASR {rev:?}: accuracy {:.1}% | per-invocation error avg {:.2}% (std {:.2}%)",
            100.0 * correct as f32 / n as f32,
            stats.mean * 100.0,
            stats.std_dev * 100.0,
        );
        if rev == DesignRev::Original {
            println!(
                "  -> reported to the accelerator developers: weight data heavily\n\
                 \u{20}   quantized by the 8-bit fixed-point store (value range clipped)\n"
            );
        } else {
            println!("  -> updated design (16-bit weight store) recovers the reference");
        }
    }
    Ok(())
}
