//! **Reproduces: §4.4.2 + Table 4 (ResNet-20 rows)** — the
//! software/hardware co-design loop, replayed:
//!
//! 1. compile ResNet-20 for FlexASR + HLSCNN and co-simulate — accuracy
//!    collapses with the *original* designs (HLSCNN's coarse 8-bit
//!    fixed-point weight store);
//! 2. inspect the per-invocation error statistics the co-sim gathers
//!    (what the paper's authors reported to the accelerator developers);
//! 3. re-run with the *updated* designs (16-bit weight store) — accuracy
//!    recovers, without ever deploying to an FPGA.
//!
//! The per-revision objective is **accuracy at modeled latency**: the
//! MMIO backend feeds the cost-model timeline as it executes, so each
//! sweep reports modeled device cycles (transfer/compute/overhead)
//! alongside accuracy — the codesign trade-off in device terms, not
//! host proxy counts.
//!
//! Requires `make artifacts`. Run with:
//! `cargo run --release --example codesign_loop`

use d2a::cost::CycleBreakdown;
use d2a::ir::Target;
use d2a::runtime::ArtifactStore;
use d2a::session::{Bindings, DesignRev, ExecBackend, SessionBuilder};

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open(None)?;
    let app = d2a::apps::cosim_models::resnet20_lite();
    let weights = store.weights("resnet20")?;
    let (images, labels) = store.test_images()?;
    let n = 120usize;

    // compile once — the extracted program is revision-independent; only
    // the accelerator numerics change between the two sweeps below
    let compile_session =
        SessionBuilder::new().targets(&[Target::FlexAsr, Target::Hlscnn]).build();
    let compiled = compile_session.compile(&app);
    println!(
        "ResNet-20 compiled: {} HLSCNN convs + {} FlexASR linears offloaded\n",
        compiled.invocations(Target::Hlscnn),
        compiled.invocations(Target::FlexAsr)
    );

    for rev in [DesignRev::Original, DesignRev::Updated] {
        // per-invocation error tracking is an opt-in of the session; the
        // MMIO backend makes the timeline record real device work
        let session = SessionBuilder::new()
            .targets(&[Target::FlexAsr, Target::Hlscnn])
            .design_rev(rev)
            .backend(ExecBackend::IlaMmio)
            .track_errors(true)
            .build();
        let program = session.attach(compiled.expr().clone());
        // one engine for the whole sweep: operand residency carries the
        // (constant) weights across images, as a deployment would
        let mut engine = program.engine();
        let mut bindings = Bindings::from_env(weights.clone());
        let mut correct = 0usize;
        let mut errors: Vec<f32> = Vec::new();
        let mut cycles = CycleBreakdown::default();
        for (img, &label) in images[..n].iter().zip(&labels[..n]) {
            bindings.set("x", img.clone());
            let trace = program.run_traced_with(&mut engine, &bindings)?;
            if trace.output.argmax() == label {
                correct += 1;
            }
            errors.extend(trace.inv_errors);
            cycles += trace.cycles;
        }
        let stats = d2a::cosim::stats::ErrorStats::from_samples(&errors);
        println!(
            "HLSCNN+FlexASR {rev:?}: accuracy {:.1}% | per-invocation error avg {:.2}% (std {:.2}%)",
            100.0 * correct as f32 / n as f32,
            stats.mean * 100.0,
            stats.std_dev * 100.0,
        );
        println!(
            "  modeled latency: {} cycles/image ({} total: {} transfer / \
             {} compute / {} overhead)",
            cycles.total() / n as u64,
            cycles.total(),
            cycles.transfer,
            cycles.compute,
            cycles.overhead,
        );
        if rev == DesignRev::Original {
            println!(
                "  -> reported to the accelerator developers: weight data heavily\n\
                 \u{20}   quantized by the 8-bit fixed-point store (value range clipped)\n"
            );
        } else {
            println!("  -> updated design (16-bit weight store) recovers the reference");
        }
    }
    Ok(())
}
