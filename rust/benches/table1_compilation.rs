//! Regenerates **Table 1** — end-to-end compilation statistics: static
//! accelerator invocations under exact vs flexible matching for the six
//! DL applications and three accelerators.
//!
//! Paper values are printed alongside for comparison. Absolute `#ops`
//! differ from the TVM Relay import (importer expansions); the
//! exact/flexible *invocation counts and their jumps* are the result.

use d2a::apps::table1::all_apps;
use d2a::compiler::compile_app;
use d2a::egraph::RunnerLimits;
use d2a::ir::Target;
use d2a::rewrites::Matching;
use std::time::{Duration, Instant};

const PAPER: &[(&str, usize, [&str; 3])] = &[
    ("EfficientNet", 232, ["0/35", "35/35", "0/35"]),
    ("LSTM-WLM", 578, ["1/1", "0/0", "36/36"]),
    ("MobileNet-V2", 757, ["0/41", "40/40", "1/41"]),
    ("ResMLP", 343, ["0/38", "0/0", "38/38"]),
    ("ResNet-20", 494, ["2/22", "21/21", "2/22"]),
    ("Transformer", 872, ["0/66", "0/0", "66/66"]),
];

fn main() {
    let limits = RunnerLimits {
        max_iters: 8,
        max_nodes: 150_000,
        time_limit: Duration::from_secs(30),
    };
    println!("=== Table 1: end-to-end compilation statistics ===");
    println!(
        "{:<14} {:>6} | {:>13} {:>13} {:>13} | {:>10} | paper (F/H/V, #ops)",
        "application", "#ops", "FlexASR e/f", "HLSCNN e/f", "VTA e/f", "candidates"
    );
    let t0 = Instant::now();
    for (app, paper) in all_apps().iter().zip(PAPER) {
        let mut cells = Vec::new();
        // summed op-index candidate probes across the six compiles — the
        // e-matching work metric the op-head index minimizes
        let mut candidates = 0usize;
        for target in [Target::FlexAsr, Target::Hlscnn, Target::Vta] {
            let e = compile_app(app, &[target], Matching::Exact, limits.clone());
            let f = compile_app(app, &[target], Matching::Flexible, limits.clone());
            candidates += e.candidate_classes() + f.candidate_classes();
            cells.push(format!("{}/{}", e.invocations(target), f.invocations(target)));
        }
        println!(
            "{:<14} {:>6} | {:>13} {:>13} {:>13} | {:>10} | {} {} {} ({})",
            app.name,
            app.num_ops(),
            cells[0],
            cells[1],
            cells[2],
            candidates,
            paper.2[0],
            paper.2[1],
            paper.2[2],
            paper.1,
        );
    }
    println!("total compile time: {:.1}s", t0.elapsed().as_secs_f64());
}
