//! Modeled-latency bench for the timing/cost subsystem: runs **all six
//! Table-1 applications** end-to-end under `ExecBackend::IlaMmio` on
//! **both design revisions** and emits a `BENCH_timing.json` trajectory
//! point with per-op modeled-cycle breakdowns (transfer vs compute vs
//! overhead — the Fig. 7 axes), plus the traffic tallies behind them
//! (staged/dedup/DMA/read bytes). In full mode each (app, rev) pair also
//! runs a residency repeat on the same persistent engine, so the JSON
//! shows how much of the cold-run transfer cost operand residency
//! removes; `--smoke` keeps one cold run per pair for CI.
//!
//! Output path defaults to `BENCH_timing.json`; override with
//! `D2A_BENCH_OUT_TIMING`. Records are serialized by hand (the offline
//! crate set has no serde).
//!
//! **Regression gate**: `-- --check BENCH_timing_baseline.json` compares
//! each (app, rev) pair's cold-run total modeled cycles against a
//! checked-in baseline and exits non-zero when a pair regresses past
//! tolerance (cycles may not grow by more than 25% + 64 — the
//! `bench_matching` band mechanics; cycles are deterministic, so the
//! slack absorbs intentional cost-model recalibration, not noise).
//! Baseline records with a `-1` sentinel are unprimed: the gate passes
//! and prints the priming instruction. `--advisory` (or an
//! `estimated-offline` provenance marker in the baseline) reports
//! regressions as warnings and exits 0; `--prime <path>` writes the
//! cycles just measured into the baseline format under a
//! `"provenance": "primed"` marker, which keeps the gate armed.

use d2a::apps::table1::all_apps;
use d2a::egraph::RunnerLimits;
use d2a::ir::Target;
use d2a::rewrites::Matching;
use d2a::session::{Bindings, DesignRev, ExecBackend, Session};
use d2a::tensor::Tensor;
use d2a::util::Rng;
use std::time::Duration;

fn limits() -> RunnerLimits {
    RunnerLimits {
        max_iters: 8,
        max_nodes: 150_000,
        time_limit: Duration::from_secs(30),
    }
}

fn rev_name(rev: DesignRev) -> &'static str {
    match rev {
        DesignRev::Original => "original",
        DesignRev::Updated => "updated",
    }
}

/// Random bindings covering every leaf an app declares shapes for.
fn random_bindings(app: &d2a::apps::App, rng: &mut Rng) -> Bindings {
    let mut b = Bindings::new();
    for (name, shape) in &app.shapes {
        b.set(name, Tensor::randn(shape, rng, 0.5));
    }
    b
}

/// Minimal field extraction from the flat baseline format (no serde):
/// (app, rev, cycles) per record. Nested objects are skipped because
/// they contain no "app" key.
fn parse_records(text: &str) -> Vec<(String, String, i64)> {
    let mut out = Vec::new();
    for chunk in text.split('{').skip(1) {
        let get_str = |key: &str| -> Option<String> {
            chunk
                .split(&format!("\"{key}\": \""))
                .nth(1)
                .and_then(|rest| rest.split('"').next())
                .map(str::to_string)
        };
        let get_num = |key: &str| -> Option<i64> {
            chunk.split(&format!("\"{key}\": ")).nth(1).and_then(|rest| {
                let end = rest
                    .find(|c: char| !(c.is_ascii_digit() || c == '-'))
                    .unwrap_or(rest.len());
                rest[..end].parse::<i64>().ok()
            })
        };
        if let (Some(app), Some(rev), Some(c)) =
            (get_str("app"), get_str("rev"), get_num("cycles"))
        {
            out.push((app, rev, c));
        }
    }
    out
}

/// Tolerance band: fail when `now` exceeds `base * 1.25 + 64` (modeled
/// cycles are deterministic; the slack absorbs intentional cost-model
/// recalibration without masking a traffic regression).
fn ceiling(base: i64) -> i64 {
    base + base / 4 + 64
}

/// `Ok(())` on pass; `Err((msg, advisory))` on regression, where
/// `advisory` is true when the baseline self-identifies as estimated
/// (provenance marker) and failures must not gate.
fn check_against_baseline(
    current: &[(String, String, i64)],
    baseline_path: &str,
) -> Result<(), (String, bool)> {
    let fail = |msg: String| Err((msg, false));
    let text = match std::fs::read_to_string(baseline_path) {
        Ok(text) => text,
        Err(e) => return fail(format!("cannot read baseline {baseline_path}: {e}")),
    };
    let estimated = text.contains("\"provenance\": \"estimated-offline\"");
    let baseline = parse_records(&text);
    if baseline.is_empty() {
        return fail(format!("baseline {baseline_path} contains no records"));
    }
    if estimated {
        println!(
            "gate: baseline {baseline_path} is estimated-offline — running \
             in advisory mode (regressions warn, never fail)"
        );
    }
    let mut failures = Vec::new();
    let mut unprimed = 0usize;
    for (app, rev, cycles) in current {
        let Some((_, _, bc)) =
            baseline.iter().find(|(a, r, _)| a == app && r == rev)
        else {
            println!("gate: no baseline record for {app}/{rev} (skipped)");
            continue;
        };
        if *bc < 0 {
            unprimed += 1;
            continue;
        }
        if *cycles > ceiling(*bc) {
            failures.push(format!(
                "{app}/{rev}: modeled cycles {cycles} regressed past baseline \
                 {bc} (ceiling {})",
                ceiling(*bc)
            ));
        }
    }
    // coverage: a primed baseline row with no current counterpart means
    // an (app, rev) pair silently dropped out of the bench
    for (app, rev, bc) in &baseline {
        if *bc < 0 {
            continue;
        }
        if !current.iter().any(|(a, r, _)| a == app && r == rev) {
            failures.push(format!(
                "{app}/{rev}: primed baseline record has no current \
                 measurement (app/rev dropped from the bench?)"
            ));
        }
    }
    if unprimed > 0 {
        println!(
            "gate: {unprimed} baseline record(s) unprimed (-1 sentinel); to arm \
             them, run with --prime {baseline_path} and commit"
        );
    }
    if failures.is_empty() {
        println!("gate: modeled cycles within tolerance of {baseline_path}");
        Ok(())
    } else {
        Err((failures.join("\n"), estimated))
    }
}

/// Serialize counters in the flat baseline format (app/rev/cycles only —
/// the stable subset the gate compares), with a leading provenance
/// record so the gate knows the numbers are measured: `"primed"` arms
/// the gate, whereas an `"estimated-offline"` marker keeps it advisory.
/// The provenance record has no `"app"` key, so [`parse_records`] skips
/// it.
fn write_baseline(path: &str, counters: &[(String, String, i64)]) -> std::io::Result<()> {
    let mut rows = vec![
        "  {\"provenance\": \"primed\", \"note\": \"measured by cargo bench \
         --bench table_timing -- --prime; the regression gate is armed\"}"
            .to_string(),
    ];
    rows.extend(counters.iter().map(|(app, rev, c)| {
        format!("  {{\"app\": \"{app}\", \"rev\": \"{rev}\", \"cycles\": {c}}}")
    }));
    std::fs::write(path, format!("[\n{}\n]\n", rows.join(",\n")))?;
    println!("primed {path} with {} record(s)", counters.len());
    Ok(())
}

fn ops_json(ops: &[d2a::cost::OpCycles]) -> String {
    let rows: Vec<String> = ops
        .iter()
        .map(|o| {
            format!(
                "{{\"target\": \"{}\", \"op\": \"{}\", \"executions\": {}, \
                 \"transfer\": {}, \"compute\": {}, \"overhead\": {}, \
                 \"staged_bytes\": {}, \"prefetched_bytes\": {}, \
                 \"dedup_bytes\": {}, \"dma_bytes\": {}, \
                 \"read_bytes\": {}, \"triggers\": {}}}",
                o.target,
                o.op,
                o.executions,
                o.cycles.transfer,
                o.cycles.compute,
                o.cycles.overhead,
                o.staged_bytes,
                o.prefetched_bytes,
                o.dedup_bytes,
                o.dma_bytes,
                o.read_bytes,
                o.triggers,
            )
        })
        .collect();
    format!("[{}]", rows.join(", "))
}

fn main() -> std::io::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let flag_path = |flag: &str| -> Option<String> {
        args.windows(2).find(|w| w[0] == flag).map(|w| w[1].clone())
    };
    let baseline = flag_path("--check");
    if baseline.is_none() && args.iter().any(|a| a == "--check") {
        eprintln!("--check requires a baseline path argument");
        std::process::exit(1);
    }
    let prime = flag_path("--prime");
    if prime.is_none() && args.iter().any(|a| a == "--prime") {
        eprintln!("--prime requires a baseline path argument");
        std::process::exit(1);
    }
    let advisory = args.iter().any(|a| a == "--advisory");
    let smoke = args.iter().any(|a| a == "--smoke");

    let targets = [Target::FlexAsr, Target::Hlscnn, Target::Vta];
    let mut records = Vec::new();
    let mut counters = Vec::new();
    println!("=== table_timing: modeled device cycles, Table-1 apps at MMIO ===");
    println!(
        "{:<14} {:<9} {:<5} {:>12} {:>12} {:>12} {:>14}",
        "application", "rev", "run", "transfer", "compute", "overhead", "total cycles"
    );
    for app in all_apps() {
        // the extracted program is revision-independent; compile once and
        // re-attach under each revision's numerics
        let compile = Session::builder()
            .targets(&targets)
            .matching(Matching::Flexible)
            .limits(limits())
            .build();
        let compiled = compile.compile(&app);
        for rev in [DesignRev::Original, DesignRev::Updated] {
            let session = Session::builder()
                .targets(&targets)
                .design_rev(rev)
                .backend(ExecBackend::IlaMmio)
                .build();
            let program = session.attach(compiled.expr().clone());
            // the same seed per (app, rev): identical operands across
            // revisions, so cycle differences are design differences
            let mut rng = Rng::new(811);
            let bindings = random_bindings(&app, &mut rng);
            let mut engine = program.engine();
            let cold = program
                .run_traced_with(&mut engine, &bindings)
                .unwrap_or_else(|e| panic!("{}/{}: MMIO run failed: {e}", app.name, rev_name(rev)));
            assert!(
                cold.mmio_invocations > 0,
                "{}: nothing lowered — the timing record would be vacuous",
                app.name
            );
            assert!(cold.cycles.total() > 0, "{}: no modeled cycles", app.name);
            assert!(!cold.op_cycles.is_empty(), "{}: no per-op rows", app.name);
            let mut runs = vec![("cold", cold)];
            if !smoke {
                // residency repeat on the same engine: staged operands
                // dedup, so modeled transfer must not grow
                let warm = program
                    .run_traced_with(&mut engine, &bindings)
                    .expect("residency repeat failed");
                assert!(
                    warm.cycles.transfer <= runs[0].1.cycles.transfer,
                    "{}: residency increased modeled transfer ({} vs {})",
                    app.name,
                    warm.cycles.transfer,
                    runs[0].1.cycles.transfer
                );
                runs.push(("warm", warm));
            }
            for (kind, trace) in &runs {
                println!(
                    "{:<14} {:<9} {:<5} {:>12} {:>12} {:>12} {:>14}",
                    app.name,
                    rev_name(rev),
                    kind,
                    trace.cycles.transfer,
                    trace.cycles.compute,
                    trace.cycles.overhead,
                    trace.cycles.total(),
                );
                records.push(format!(
                    "  {{\"app\": \"{}\", \"rev\": \"{}\", \"run\": \"{}\", \
                     \"transfer\": {}, \"compute\": {}, \"overhead\": {}, \
                     \"total\": {}, \"mmio_invocations\": {}, \
                     \"bytes_streamed\": {}, \"bursts_deduped\": {}, \
                     \"ops\": {}}}",
                    app.name,
                    rev_name(rev),
                    kind,
                    trace.cycles.transfer,
                    trace.cycles.compute,
                    trace.cycles.overhead,
                    trace.cycles.total(),
                    trace.mmio_invocations,
                    trace.bytes_streamed,
                    trace.bursts_deduped,
                    ops_json(&trace.op_cycles),
                ));
            }
            counters.push((
                app.name.to_string(),
                rev_name(rev).to_string(),
                runs[0].1.cycles.total() as i64,
            ));
        }
    }
    // paged-DRAM decoder case: the Table 1 [33278 x 650] decoder layer
    // cold (tile set streamed and paged into the weight DRAM) then warm
    // (tile set rides page residency; only input + control replays
    // stream). Runs even under --smoke — the cold/warm pair IS the
    // paging evidence, and it is one layer, not a whole app sweep.
    {
        use d2a::ir::{GraphBuilder, Op};
        let mut g = GraphBuilder::new();
        let (x, w, b) = (g.var("x"), g.weight("w"), g.weight("b"));
        g.expr.add(Op::FlexLinear, vec![x, w, b]);
        let expr = g.finish();
        let mut rng = Rng::new(811);
        let bindings = Bindings::new()
            .with("x", Tensor::randn(&[1, 650], &mut rng, 1.0))
            .with("w", Tensor::randn(&[33_278, 650], &mut rng, 0.3))
            .with("b", Tensor::randn(&[33_278], &mut rng, 0.1));
        for rev in [DesignRev::Original, DesignRev::Updated] {
            let session = Session::builder()
                .targets(&[Target::FlexAsr])
                .design_rev(rev)
                .backend(ExecBackend::IlaMmio)
                .build();
            let program = session.attach(expr.clone());
            let mut engine = program.engine();
            let cold = program
                .run_traced_with(&mut engine, &bindings)
                .expect("decoder cold run failed");
            let warm = program
                .run_traced_with(&mut engine, &bindings)
                .expect("decoder warm run failed");
            assert!(
                warm.bytes_streamed * 10 < cold.bytes_streamed,
                "decoder-paging/{}: warm run must stream <10% of cold \
                 ({} vs {})",
                rev_name(rev),
                warm.bytes_streamed,
                cold.bytes_streamed
            );
            assert!(
                warm.cycles.total() < cold.cycles.total(),
                "decoder-paging/{}: warm modeled cycles must beat cold",
                rev_name(rev)
            );
            for (kind, trace) in [("cold", &cold), ("warm", &warm)] {
                println!(
                    "{:<14} {:<9} {:<5} {:>12} {:>12} {:>12} {:>14}",
                    "decoder-paging",
                    rev_name(rev),
                    kind,
                    trace.cycles.transfer,
                    trace.cycles.compute,
                    trace.cycles.overhead,
                    trace.cycles.total(),
                );
                records.push(format!(
                    "  {{\"app\": \"decoder-paging\", \"rev\": \"{}\", \
                     \"run\": \"{}\", \"transfer\": {}, \"compute\": {}, \
                     \"overhead\": {}, \"total\": {}, \
                     \"mmio_invocations\": {}, \"bytes_streamed\": {}, \
                     \"bursts_deduped\": {}, \"ops\": {}}}",
                    rev_name(rev),
                    kind,
                    trace.cycles.transfer,
                    trace.cycles.compute,
                    trace.cycles.overhead,
                    trace.cycles.total(),
                    trace.mmio_invocations,
                    trace.bytes_streamed,
                    trace.bursts_deduped,
                    ops_json(&trace.op_cycles),
                ));
            }
            counters.push((
                "decoder-paging".to_string(),
                rev_name(rev).to_string(),
                cold.cycles.total() as i64,
            ));
        }
    }

    let out = std::env::var("D2A_BENCH_OUT_TIMING")
        .unwrap_or_else(|_| "BENCH_timing.json".to_string());
    std::fs::write(&out, format!("[\n{}\n]\n", records.join(",\n")))?;
    println!("wrote {out}");

    if let Some(path) = prime {
        write_baseline(&path, &counters)?;
    }
    if let Some(path) = baseline {
        if let Err((msg, estimated)) = check_against_baseline(&counters, &path) {
            if advisory || estimated {
                println!("timing regression gate (advisory): would have failed:\n{msg}");
            } else {
                eprintln!("timing regression gate FAILED:\n{msg}");
                std::process::exit(1);
            }
        }
    }
    Ok(())
}
