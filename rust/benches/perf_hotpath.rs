//! Micro-benchmarks of the L3 hot paths, for the §Perf optimization pass
//! (EXPERIMENTS.md §Perf records before/after for each iteration).
//!
//! Hot paths, in co-sim/table-bench weight order:
//!  1. `tensor::ops::conv2d`   — dominates ResNet/MobileNet co-sim;
//!  2. `tensor::ops::dense`    — dominates ResMLP co-sim + im2col GEMMs;
//!  3. e-graph saturation      — dominates Table 1; measured both ways:
//!     op-indexed + backoff vs the full-scan reference, with the probed
//!     candidate-class counters from `IterStats`;
//!  4. SAT propagation         — dominates Table 3 (BMC);
//!  5. FlexASR ILA fast path   — the per-invocation co-sim cost;
//!  6. accelerator dispatch    — registry O(1) lookup vs the seed-era
//!     linear scan, and the plan-driven session run vs the hook path.

use d2a::egraph::{EGraph, Runner, RunnerLimits};
use d2a::rewrites::{rules_for, Matching};
use d2a::session::{AcceleratorRegistry, Bindings, DesignRev, Session};
use d2a::tensor::{ops, Tensor};
use d2a::util::Rng;
use std::hint::black_box;
use std::time::Instant;

fn time<F: FnMut()>(name: &str, reps: u32, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / reps as f64;
    println!("{name:<44} {:>10.3} ms/iter", per * 1e3);
    per
}

fn main() {
    println!("=== perf_hotpath: L3 hot-path micro-benchmarks ===");
    let mut rng = Rng::new(7);

    let x = Tensor::randn(&[1, 16, 8, 8], &mut rng, 1.0);
    let w = Tensor::randn(&[16, 16, 3, 3], &mut rng, 0.2);
    time("conv2d 1x16x8x8 * 16x16x3x3", 500, || {
        let _ = ops::conv2d(&x, &w, (1, 1), (1, 1));
    });

    let a = Tensor::randn(&[1, 96], &mut rng, 1.0);
    let b = Tensor::randn(&[96, 96], &mut rng, 0.2);
    time("dense [1,96]x[96,96]", 5000, || {
        let _ = ops::dense(&a, &b);
    });
    let a2 = Tensor::randn(&[64, 384], &mut rng, 1.0);
    let b2 = Tensor::randn(&[384, 384], &mut rng, 0.2);
    time("dense [64,384]x[384,384]", 50, || {
        let _ = ops::dense(&a2, &b2);
    });

    let app = d2a::apps::table1::lstm_wlm();
    time("compile LSTM-WLM (flexible, FlexASR)", 5, || {
        let _ = d2a::compiler::compile_app(
            &app,
            &[d2a::ir::Target::FlexAsr],
            d2a::rewrites::Matching::Flexible,
            d2a::egraph::RunnerLimits::default(),
        );
    });

    matching_benches();

    time("BMC miter 4x16 (CDCL)", 3, || {
        let _ = d2a::verify::verify_bmc(4, 16, std::time::Duration::from_secs(120));
    });

    let fa = d2a::accel::FlexAsr::new();
    let lx = fa.quant(&Tensor::randn(&[16, 96], &mut rng, 1.0));
    let lw = fa.quant(&Tensor::randn(&[96, 96], &mut rng, 0.2));
    let lb = fa.quant(&Tensor::randn(&[96], &mut rng, 0.1));
    time("FlexASR linear ILA fast path 16x96x96", 1000, || {
        let _ = fa.linear(&lx, &lw, &lb);
    });

    dispatch_benches(&mut rng);
    engine_reuse_benches(&mut rng);
    operand_residency_benches(&mut rng);
    pool_scheduling_benches(&mut rng);
}

/// E-matching: op-indexed search + backoff scheduling vs the full-scan
/// reference, on the largest Table 1 app (Transformer). The indexed path
/// must probe strictly fewer root-candidate classes for the same final
/// e-graph (extraction parity is asserted by `tests/prop_invariants.rs`).
fn matching_benches() {
    use d2a::ir::Target;
    let limits = RunnerLimits {
        max_iters: 6,
        max_nodes: 150_000,
        time_limit: std::time::Duration::from_secs(30),
    };
    let targets = [Target::FlexAsr, Target::Hlscnn, Target::Vta];
    let rules = rules_for(&targets, Matching::Flexible);
    let app = d2a::apps::table1::transformer();
    let saturate = |mut runner: Runner| -> Runner {
        let mut eg = EGraph::new(app.shapes.clone());
        eg.add_expr(&app.expr);
        runner.run(&mut eg, &rules);
        runner
    };
    let mut probed = [0usize; 2];
    let t0 = Instant::now();
    let indexed = saturate(Runner::new(limits.clone()));
    let t_indexed = t0.elapsed();
    let t1 = Instant::now();
    let full = saturate(Runner::reference(limits));
    let t_full = t1.elapsed();
    probed[0] = indexed.total_candidates();
    probed[1] = full.total_candidates();
    println!(
        "saturate Transformer, op-indexed + backoff        {:>10.3} ms  \
         ({} candidates)",
        t_indexed.as_secs_f64() * 1e3,
        probed[0]
    );
    println!(
        "saturate Transformer, full-scan reference         {:>10.3} ms  \
         ({} candidates)",
        t_full.as_secs_f64() * 1e3,
        probed[1]
    );
    assert!(
        probed[0] < probed[1],
        "indexed matching must do strictly less work: {} vs {}",
        probed[0],
        probed[1]
    );
}

/// Engine reuse + dirty-region resets: single-point MMIO evaluations
/// through a caller-held `ExecEngine` vs a throwaway engine per call
/// (the seed behaviour of `run`), and the per-invocation sim setup work
/// each pays. The persistent engine must build its simulator once and
/// its dirty-region resets must restore strictly fewer bytes than the
/// full-clone-per-invocation baseline — the counters are reported so the
/// reduction is visible in CI logs, not just asserted.
fn engine_reuse_benches(rng: &mut Rng) {
    use d2a::ir::{GraphBuilder, Op, Target};
    use d2a::session::ExecBackend;

    let mut g = GraphBuilder::new();
    let (x, w, b) = (g.var("x"), g.weight("w"), g.weight("b"));
    // attach() skips saturation: add the already-mapped accelerator op
    // (the host-level `g.linear` pattern would never lower)
    g.expr.add(Op::FlexLinear, vec![x, w, b]);
    let session = Session::builder()
        .targets(&[Target::FlexAsr])
        .backend(ExecBackend::IlaMmio)
        .build();
    let program = session.attach(g.finish());
    let bindings = Bindings::new()
        .with("x", Tensor::randn(&[16, 96], rng, 1.0))
        .with("w", Tensor::randn(&[96, 96], rng, 0.2))
        .with("b", Tensor::randn(&[96], rng, 0.1));

    let reps = 200u32;
    time("mmio run: fresh engine per call (seed)", reps, || {
        let _ = program.run(&bindings).unwrap();
    });
    let mut engine = program.engine();
    time("mmio run: caller-held persistent engine", reps, || {
        let _ = program.run_with(&mut engine, &bindings).unwrap();
    });

    let per_invocation_cleared = engine.bytes_cleared() / engine.resets().max(1);
    let full_clone = engine.state_bytes();
    println!(
        "engine-reuse: {} sim build(s) for {} invocations; dirty resets \
         restored {} B/invocation vs {} B/invocation full-clone baseline \
         ({:.1}x less reset traffic)",
        engine.sims_built(),
        engine.lowered_invocations(),
        per_invocation_cleared,
        full_clone,
        full_clone as f64 / per_invocation_cleared.max(1) as f64
    );
    println!(
        "engine-reuse: {} B streamed over {} invocations; {} resident \
         burst(s) deduped, {} mirror recomputation(s) avoided",
        engine.bytes_streamed(),
        engine.lowered_invocations(),
        engine.bursts_deduped(),
        engine.mirror_hits()
    );
    assert_eq!(engine.sims_built(), 1, "persistent engine must build once");
    assert!(
        engine.bytes_cleared() < engine.resets() * full_clone,
        "dirty resets must restore strictly fewer bytes than full clones"
    );
    // operand residency must engage on the repeated layer: the weight
    // and bias bursts stay device-resident from the second call on
    assert!(
        engine.bursts_deduped() > 0,
        "resident weight bursts must dedup across repeated calls"
    );
    // and it must strictly reduce streamed traffic: one more call on the
    // persistent engine moves fewer bytes than a fresh engine's call
    let before = engine.bytes_streamed();
    let _ = program.run_with(&mut engine, &bindings).unwrap();
    let resident_call = engine.bytes_streamed() - before;
    let mut fresh = program.engine();
    let _ = program.run_with(&mut fresh, &bindings).unwrap();
    println!(
        "engine-reuse: resident call streams {} B vs {} B fresh",
        resident_call,
        fresh.bytes_streamed()
    );
    assert!(
        resident_call < fresh.bytes_streamed(),
        "residency must strictly reduce streamed traffic: {} vs {}",
        resident_call,
        fresh.bytes_streamed()
    );
}

/// Operand residency + lowering cache on the Table 1 LSTM-WLM gate
/// matrix ([2600 x 1300], 35 timesteps) at MMIO fidelity. The tiled
/// lowering stages each weight tile in the device weight DRAM **once
/// per program** (not once per timestep — the PR-4 behaviour paid ~35x
/// that), and under a persistent engine the staged tiles survive the
/// between-call dirty reset, so a repeat call re-streams only the input
/// sequence. The acceptance bar: repeat-call `bytes_streamed` is >10x
/// below the fresh-engine baseline.
fn operand_residency_benches(rng: &mut Rng) {
    use d2a::ir::{GraphBuilder, Op, Target};
    use d2a::session::ExecBackend;

    let (t, e, h) = (35usize, 650usize, 650usize);
    let mut g = GraphBuilder::new();
    let (x, wi, wh, b) =
        (g.var("x"), g.weight("wi"), g.weight("wh"), g.weight("b"));
    g.expr.add(Op::FlexLstm { steps: t }, vec![x, wi, wh, b]);
    let session = Session::builder()
        .targets(&[Target::FlexAsr])
        .backend(ExecBackend::IlaMmio)
        .build();
    let program = session.attach(g.finish());
    let bindings = Bindings::new()
        .with("x", Tensor::randn(&[t, 1, e], rng, 1.0))
        .with("wi", Tensor::randn(&[4 * h, e], rng, 0.3))
        .with("wh", Tensor::randn(&[4 * h, h], rng, 0.3))
        .with("b", Tensor::randn(&[4 * h], rng, 0.1));

    // fresh-engine baseline: every call stages the whole tile set
    let t0 = Instant::now();
    let mut fresh_engine = program.engine();
    let fresh =
        program.run_traced_with(&mut fresh_engine, &bindings).unwrap();
    let t_fresh = t0.elapsed();

    // persistent engine: first call stages, repeat call rides residency
    let mut engine = program.engine();
    let first = program.run_traced_with(&mut engine, &bindings).unwrap();
    let t1 = Instant::now();
    let repeat = program.run_traced_with(&mut engine, &bindings).unwrap();
    let t_repeat = t1.elapsed();
    assert_eq!(repeat.output, fresh.output, "residency changed the result");

    println!(
        "lstm-wlm mmio: fresh engine          {:>10.1} ms  {:>12} B streamed",
        t_fresh.as_secs_f64() * 1e3,
        fresh.bytes_streamed
    );
    println!(
        "lstm-wlm mmio: persistent, repeat    {:>10.1} ms  {:>12} B streamed \
         ({} bursts deduped, {} mirror hit(s))",
        t_repeat.as_secs_f64() * 1e3,
        repeat.bytes_streamed,
        repeat.bursts_deduped,
        repeat.mirror_hits
    );
    println!(
        "lstm-wlm mmio: residency cuts streamed traffic {:.1}x \
         (first call already stages each weight tile once, not once per \
         timestep)",
        fresh.bytes_streamed as f64 / repeat.bytes_streamed.max(1) as f64
    );
    assert_eq!(first.bytes_streamed, fresh.bytes_streamed);
    assert!(repeat.bursts_deduped > 0, "weight tiles must stay resident");
    assert!(repeat.mirror_hits > 0, "the bias-schedule mirror must cache");
    assert!(
        fresh.bytes_streamed > 10 * repeat.bytes_streamed,
        "residency must cut streamed bytes >10x: fresh {} vs repeat {}",
        fresh.bytes_streamed,
        repeat.bytes_streamed
    );
}

/// Affinity-aware device-pool scheduling on a repeated-weights serving
/// workload: the A,B,B,A,A,B,B,A tenant pattern on a 2-device pool.
/// Affinity routing parks each weight set on its own device and serves
/// repeats from residency; FIFO re-streams the weights on every tenant
/// switch. The full open-loop Poisson load generator (throughput,
/// p50/p99, occupancy) lives in `benches/bench_serving.rs` — this
/// section keeps the strict streamed-bytes comparison in the hot-path
/// log.
fn pool_scheduling_benches(rng: &mut Rng) {
    use d2a::ir::{GraphBuilder, Op, Target};
    use d2a::session::{ExecBackend, SchedPolicy};

    let (t, e, h) = (2usize, 64usize, 64usize);
    let pattern = [0usize, 1, 1, 0, 0, 1, 1, 0];
    let mut bytes = [0u64; 2];
    let mut times = [0f64; 2];
    for (slot, policy) in [SchedPolicy::Affinity, SchedPolicy::Fifo].into_iter().enumerate() {
        let mut g = GraphBuilder::new();
        let (x, wi, wh, b) = (g.var("x"), g.weight("wi"), g.weight("wh"), g.weight("b"));
        g.expr.add(Op::FlexLstm { steps: t }, vec![x, wi, wh, b]);
        let session = Session::builder()
            .targets(&[Target::FlexAsr])
            .backend(ExecBackend::IlaMmio)
            .device_pool(2)
            .sched_policy(policy)
            .build();
        let program = session.attach(g.finish());
        let mut set_rng = Rng::new(17);
        let sets: Vec<_> = (0..2)
            .map(|_| {
                (
                    Tensor::randn(&[4 * h, e], &mut set_rng, 0.3),
                    Tensor::randn(&[4 * h, h], &mut set_rng, 0.3),
                    Tensor::randn(&[4 * h], &mut set_rng, 0.1),
                )
            })
            .collect();
        let mut engine = program.engine();
        let t0 = Instant::now();
        for &set in pattern.iter() {
            let (wi, wh, b) = &sets[set];
            let bindings = Bindings::new()
                .with("x", Tensor::randn(&[t, 1, e], rng, 1.0))
                .with("wi", wi.clone())
                .with("wh", wh.clone())
                .with("b", b.clone());
            let _ = program.run_with(&mut engine, &bindings).unwrap();
        }
        times[slot] = t0.elapsed().as_secs_f64() * 1e3;
        bytes[slot] = engine.bytes_streamed();
        println!(
            "pool {:<9} A,B,B,A,A,B,B,A x lstm({t},{e},{h})  {:>8.1} ms  \
             {:>10} B streamed",
            policy.to_string(),
            times[slot],
            bytes[slot]
        );
    }
    assert!(
        bytes[0] < bytes[1],
        "affinity scheduling must stream strictly fewer bytes than FIFO: \
         {} vs {}",
        bytes[0],
        bytes[1]
    );
}

/// Per-node accelerator dispatch: the co-sim hot loop resolves an
/// accelerator for every accelerator node of every input. The registry's
/// target-indexed lookup must be no slower than the seed-era linear scan
/// (reproduced locally; the deprecated `accel_for` shim is deleted), and
/// the plan-driven `CompiledProgram::run` must be no slower than the
/// hook-interception path it replaces.
fn dispatch_benches(rng: &mut Rng) {
    use d2a::accel::Accelerator;
    use d2a::ir::{GraphBuilder, Op, Target};

    /// The seed-era O(n) scan, kept here as the bench baseline.
    fn accel_for_scan<'a>(
        accels: &'a [Box<dyn Accelerator>],
        op: &Op,
    ) -> Option<&'a dyn Accelerator> {
        let t = op.target();
        accels.iter().map(|a| a.as_ref()).find(|a| a.target() == t)
    }

    let registry = AcceleratorRegistry::for_rev(DesignRev::Updated);
    let accels = d2a::session::registry::models(DesignRev::Updated);
    let probe = [
        Op::FlexLinear,
        Op::VtaGemm,
        Op::HlscnnConv2d { stride: (1, 1), pad: (1, 1) },
        Op::Relu,
    ];
    time("dispatch: registry for_op, 4 ops x 10k", 200, || {
        for _ in 0..10_000 {
            for op in &probe {
                black_box(registry.for_op(black_box(op)).map(|a| a.name()));
            }
        }
    });
    time("dispatch: linear-scan baseline, 4 ops x 10k", 200, || {
        for _ in 0..10_000 {
            for op in &probe {
                black_box(accel_for_scan(&accels, black_box(op)).map(|a| a.name()));
            }
        }
    });

    let mut g = GraphBuilder::new();
    let x = g.var("x");
    let w = g.weight("w");
    let b = g.weight("b");
    g.linear(x, w, b);
    let expr = g.finish();
    let shapes: std::collections::HashMap<String, Vec<usize>> = [
        ("x".to_string(), vec![16usize, 96]),
        ("w".to_string(), vec![96, 96]),
        ("b".to_string(), vec![96]),
    ]
    .into_iter()
    .collect();
    let session = Session::builder().targets(&[Target::FlexAsr]).build();
    let program = session.compile_expr(&expr, &shapes);
    assert_eq!(program.invocations(Target::FlexAsr), 1);
    let bindings = Bindings::new()
        .with("x", Tensor::randn(&[16, 96], rng, 1.0))
        .with("w", Tensor::randn(&[96, 96], rng, 0.2))
        .with("b", Tensor::randn(&[96], rng, 0.1));
    time("cosim step: plan-driven CompiledProgram::run", 1000, || {
        let _ = program.run(&bindings).unwrap();
    });
    time("cosim step: AccelHook eval_with_hook", 1000, || {
        let _ =
            d2a::cosim::run_accelerated(program.expr(), bindings.env(), &registry)
                .unwrap();
    });
}
