//! Regenerates **Table 4** — application-level co-simulation: reference
//! result (host f32), "Original" accelerator designs (HLSCNN 8-bit
//! fixed-point weight store), and "Updated" designs (16-bit weights, the
//! developer fix from the co-design case study), plus average simulation
//! time per data point.
//!
//! Requires `make artifacts`. D2A_COSIM_N bounds the image count
//! (default 400; the paper evaluates 2000 images / 100 sentences).

use d2a::egraph::RunnerLimits;
use d2a::ir::Target;
use d2a::runtime::ArtifactStore;
use d2a::session::{DesignRev, SessionBuilder, SweepSpec};
use std::time::Duration;

const PAPER: &[(&str, &str, &str, &str, &str)] = &[
    ("LSTM-WLM", "FlexASR", "122.15 ppl", "257.39 ppl", "(reported)"),
    ("ResMLP", "FlexASR", "69.65%", "10.65%", "(reported)"),
    ("ResNet-20", "FlexASR & HLSCNN", "91.55%", "29.15%", "91.85%"),
    ("MobileNet-V2", "FlexASR & HLSCNN", "92.40%", "10.35%", "91.20%"),
];

fn limits() -> RunnerLimits {
    RunnerLimits { max_iters: 8, max_nodes: 150_000, time_limit: Duration::from_secs(30) }
}

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open(None)?;
    let meta = store.meta()?;
    let n_img: usize = std::env::var("D2A_COSIM_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    println!("=== Table 4: application-level co-simulation ({n_img} images / 100 sentences) ===");
    println!(
        "{:<13} {:<18} {:>10} {:>10} {:>10} {:>10} | paper ref/orig/upd",
        "application", "platform", "reference", "original", "updated", "per-point"
    );

    // ---- LSTM-WLM on FlexASR ------------------------------------------
    {
        let app = d2a::apps::cosim_models::lstm_wlm_lite();
        let session = SessionBuilder::new()
            .targets(&[Target::FlexAsr])
            .limits(limits())
            .design_rev(DesignRev::Original)
            .build();
        let program = session.compile(&app);
        let mut weights = store.weights("lstm")?;
        let embed = weights.remove("embed").unwrap();
        let tokens = store.test_tokens()?;
        let t0 = std::time::Instant::now();
        let rep = program.lm_sweep(&weights, &embed, &tokens, 100)?;
        let per = t0.elapsed() / 100;
        println!(
            "{:<13} {:<18} {:>10} {:>10} {:>10} {:>10} | {} / {} / {}",
            "LSTM-WLM",
            "FlexASR",
            format!("{:.2}ppl", rep.ref_perplexity),
            format!("{:.2}ppl", rep.acc_perplexity),
            "(reported)",
            format!("{per:.1?}"),
            PAPER[0].2,
            PAPER[0].3,
            PAPER[0].4
        );
        let _ = meta.get("lstm_ref_ppl");
    }

    // ---- classifiers ---------------------------------------------------
    let (images, labels) = store.test_images()?;
    let n = n_img.min(images.len());
    let jobs: [(&str, &str, &[Target], usize); 3] = [
        ("ResMLP", "resmlp", &[Target::FlexAsr], 1),
        ("ResNet-20", "resnet20", &[Target::FlexAsr, Target::Hlscnn], 2),
        ("MobileNet-V2", "mobilenet", &[Target::FlexAsr, Target::Hlscnn], 3),
    ];
    for (name, model, targets, paper_idx) in jobs {
        let app = match model {
            "resmlp" => d2a::apps::cosim_models::resmlp_lite(),
            "resnet20" => d2a::apps::cosim_models::resnet20_lite(),
            _ => d2a::apps::cosim_models::mobilenet_lite(),
        };
        let weights = store.weights(model)?;
        // compile once; the extracted program is revision-independent
        let compiled = SessionBuilder::new()
            .targets(targets)
            .limits(limits())
            .build()
            .compile(&app);
        let run = |rev: DesignRev| {
            let session = SessionBuilder::new().targets(targets).design_rev(rev).build();
            session.attach(compiled.expr().clone()).classify_sweep(&SweepSpec {
                input_var: "x",
                weights: &weights,
                inputs: &images[..n],
                labels: &labels[..n],
            })
        };
        let orig = run(DesignRev::Original);
        let upd = run(DesignRev::Updated);
        let platform = if targets.len() == 1 { "FlexASR" } else { "FlexASR & HLSCNN" };
        println!(
            "{:<13} {:<18} {:>10} {:>10} {:>10} {:>10} | {} / {} / {}",
            name,
            platform,
            format!("{:.2}%", orig.ref_accuracy() * 100.0),
            format!("{:.2}%", orig.acc_accuracy() * 100.0),
            format!("{:.2}%", upd.acc_accuracy() * 100.0),
            // per-point *sim* time (aggregate worker busy time / n), not
            // wall/n which shrinks with the worker count
            format!("{:.1?}", upd.sim_time_per_point()),
            PAPER[paper_idx].2,
            PAPER[paper_idx].3,
            PAPER[paper_idx].4
        );
    }
    Ok(())
}
