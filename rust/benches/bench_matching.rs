//! Bench smoke binary for the e-matching hot path: saturates every seed
//! (Table 1) application under both matching modes and emits a
//! `BENCH_matching.json` trajectory point — saturation iterations,
//! e-graph size, probed candidate classes, matches, and wall time — so
//! the perf trend is tracked from PR 2 onward.
//!
//! Output path defaults to `BENCH_matching.json` in the working
//! directory; override with `D2A_BENCH_OUT`. The JSON is a flat array of
//! per-(app, mode) records, serialized by hand (the offline crate set
//! has no serde).

use d2a::apps::table1::all_apps;
use d2a::compiler::compile_app;
use d2a::egraph::RunnerLimits;
use d2a::ir::Target;
use d2a::rewrites::Matching;
use std::time::Duration;

fn limits() -> RunnerLimits {
    RunnerLimits {
        max_iters: 8,
        max_nodes: 150_000,
        time_limit: Duration::from_secs(30),
    }
}

fn main() -> std::io::Result<()> {
    let targets = [Target::FlexAsr, Target::Hlscnn, Target::Vta];
    let mut records = Vec::new();
    println!("=== bench_matching: saturation smoke (indexed matcher) ===");
    println!(
        "{:<14} {:<8} {:>6} {:>8} {:>8} {:>11} {:>9} {:>9}",
        "application", "mode", "iters", "classes", "nodes", "candidates", "matches", "ms"
    );
    for app in all_apps() {
        for mode in [Matching::Exact, Matching::Flexible] {
            let res = compile_app(&app, &targets, mode, limits());
            let ms = res.elapsed.as_secs_f64() * 1e3;
            println!(
                "{:<14} {:<8} {:>6} {:>8} {:>8} {:>11} {:>9} {:>9.1}",
                app.name,
                mode.to_string(),
                res.iterations.len(),
                res.classes,
                res.nodes,
                res.candidate_classes(),
                res.total_matches(),
                ms
            );
            records.push(format!(
                "  {{\"app\": \"{}\", \"mode\": \"{}\", \"stop\": \"{:?}\", \
                 \"iters\": {}, \"classes\": {}, \"nodes\": {}, \
                 \"candidates\": {}, \"matches\": {}, \"wall_ms\": {:.3}, \
                 \"invocations\": {{\"flexasr\": {}, \"hlscnn\": {}, \"vta\": {}}}}}",
                app.name,
                mode,
                res.stop,
                res.iterations.len(),
                res.classes,
                res.nodes,
                res.candidate_classes(),
                res.total_matches(),
                ms,
                res.invocations(Target::FlexAsr),
                res.invocations(Target::Hlscnn),
                res.invocations(Target::Vta),
            ));
        }
    }
    let out = std::env::var("D2A_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_matching.json".to_string());
    let json = format!("[\n{}\n]\n", records.join(",\n"));
    std::fs::write(&out, json)?;
    println!("wrote {out}");
    Ok(())
}
