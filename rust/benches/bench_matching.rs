//! Bench smoke binary for the e-matching hot path: saturates every seed
//! (Table 1) application under both matching modes and emits a
//! `BENCH_matching.json` trajectory point — saturation iterations,
//! e-graph size, probed candidate classes, matches, and wall time — so
//! the perf trend is tracked from PR 2 onward.
//!
//! Output path defaults to `BENCH_matching.json` in the working
//! directory; override with `D2A_BENCH_OUT`. The JSON is a flat array of
//! per-(app, mode) records, serialized by hand (the offline crate set
//! has no serde).
//!
//! **Regression gate**: `-- --check BENCH_matching_baseline.json`
//! compares the deterministic work counters (probed candidate classes,
//! e-matches) against a checked-in baseline and exits non-zero when a
//! record regresses beyond tolerance (candidates may not grow, nor
//! matches drift, by more than 25% + 64). Baseline records with a `-1`
//! sentinel are unprimed: the gate passes and prints the priming
//! instruction.
//!
//! **Advisory mode**: `-- --check <baseline> --advisory` (or a baseline
//! whose provenance marker says `estimated-offline`) reports regressions
//! as warnings and exits 0. This is how an estimated baseline lands
//! without risking a false-positive CI failure: the comparison machinery
//! runs for real, but only CI-measured numbers are allowed to gate.
//!
//! **Priming**: `-- --prime BENCH_matching_baseline.json` writes the
//! counters just measured into the baseline file in the flat baseline
//! format (replacing `-1` sentinels or stale numbers) — one command
//! instead of the manual copy-and-trim. CI uses it to emit a
//! ready-to-commit `BENCH_matching_baseline.primed.json` artifact
//! whenever the checked-in baseline is still sentinel-valued, so the
//! gate stops being vacuous as soon as that artifact lands in the repo.

use d2a::apps::table1::all_apps;
use d2a::compiler::compile_app;
use d2a::egraph::RunnerLimits;
use d2a::ir::Target;
use d2a::rewrites::Matching;
use std::time::Duration;

fn limits() -> RunnerLimits {
    RunnerLimits {
        max_iters: 8,
        max_nodes: 150_000,
        time_limit: Duration::from_secs(30),
    }
}

/// Minimal field extraction from our own flat record format (the offline
/// crate set has no serde): returns (app, mode, candidates, matches) per
/// record. Nested objects are skipped because they contain no "app" key.
fn parse_records(text: &str) -> Vec<(String, String, i64, i64)> {
    let mut out = Vec::new();
    for chunk in text.split('{').skip(1) {
        let get_str = |key: &str| -> Option<String> {
            chunk
                .split(&format!("\"{key}\": \""))
                .nth(1)
                .and_then(|rest| rest.split('"').next())
                .map(str::to_string)
        };
        let get_num = |key: &str| -> Option<i64> {
            chunk.split(&format!("\"{key}\": ")).nth(1).and_then(|rest| {
                let end = rest
                    .find(|c: char| !(c.is_ascii_digit() || c == '-'))
                    .unwrap_or(rest.len());
                rest[..end].parse::<i64>().ok()
            })
        };
        if let (Some(app), Some(mode), Some(c), Some(m)) =
            (get_str("app"), get_str("mode"), get_num("candidates"), get_num("matches"))
        {
            out.push((app, mode, c, m));
        }
    }
    out
}

/// Tolerance band: fail when `now` exceeds `base * 1.25 + 64` (work
/// counters are deterministic; the slack absorbs intentional rule-set
/// growth without masking a complexity regression).
fn ceiling(base: i64) -> i64 {
    base + base / 4 + 64
}

/// `Ok(())` on pass; `Err((msg, advisory))` on regression, where
/// `advisory` is true when the baseline self-identifies as estimated
/// (provenance marker) and failures must not gate.
fn check_against_baseline(
    current: &[(String, String, i64, i64)],
    baseline_path: &str,
) -> Result<(), (String, bool)> {
    let fail = |msg: String| Err((msg, false));
    let text = match std::fs::read_to_string(baseline_path) {
        Ok(text) => text,
        Err(e) => return fail(format!("cannot read baseline {baseline_path}: {e}")),
    };
    let estimated = text.contains("\"provenance\": \"estimated-offline\"");
    let baseline = parse_records(&text);
    if baseline.is_empty() {
        return fail(format!("baseline {baseline_path} contains no records"));
    }
    if estimated {
        println!(
            "gate: baseline {baseline_path} is estimated-offline — running \
             in advisory mode (regressions warn, never fail)"
        );
    }
    let mut failures = Vec::new();
    let mut unprimed = 0usize;
    for (app, mode, cand, mat) in current {
        let Some((_, _, bc, bm)) =
            baseline.iter().find(|(a, m, _, _)| a == app && m == mode)
        else {
            println!("gate: no baseline record for {app}/{mode} (skipped)");
            continue;
        };
        if *bc < 0 || *bm < 0 {
            unprimed += 1;
            continue;
        }
        if *cand > ceiling(*bc) {
            failures.push(format!(
                "{app}/{mode}: candidates {cand} regressed past baseline {bc} \
                 (ceiling {})",
                ceiling(*bc)
            ));
        }
        if *mat > ceiling(*bm) || *mat < *bm - *bm / 4 - 64 {
            failures.push(format!(
                "{app}/{mode}: matches {mat} drifted from baseline {bm} \
                 (band [{}, {}])",
                *bm - *bm / 4 - 64,
                ceiling(*bm)
            ));
        }
    }
    // coverage: a primed baseline row with no current counterpart means
    // an app/mode silently dropped out of the bench — that is itself a
    // regression, not a pass
    for (app, mode, bc, bm) in &baseline {
        if *bc < 0 || *bm < 0 {
            continue;
        }
        if !current.iter().any(|(a, m, _, _)| a == app && m == mode) {
            failures.push(format!(
                "{app}/{mode}: primed baseline record has no current \
                 measurement (app/mode dropped from the bench?)"
            ));
        }
    }
    if unprimed > 0 {
        println!(
            "gate: {unprimed} baseline record(s) unprimed (-1 sentinel); to arm \
             them, copy the emitted BENCH_matching.json over {baseline_path} \
             and commit"
        );
    }
    if failures.is_empty() {
        println!("gate: candidates/matches within tolerance of {baseline_path}");
        Ok(())
    } else {
        Err((failures.join("\n"), estimated))
    }
}

/// Serialize counters in the flat baseline format (app/mode/candidates/
/// matches only — the stable subset the gate compares).
fn write_baseline(
    path: &str,
    counters: &[(String, String, i64, i64)],
) -> std::io::Result<()> {
    let rows: Vec<String> = counters
        .iter()
        .map(|(app, mode, c, m)| {
            format!(
                "  {{\"app\": \"{app}\", \"mode\": \"{mode}\", \
                 \"candidates\": {c}, \"matches\": {m}}}"
            )
        })
        .collect();
    std::fs::write(path, format!("[\n{}\n]\n", rows.join(",\n")))?;
    println!("primed {path} with {} record(s)", counters.len());
    Ok(())
}

fn main() -> std::io::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let flag_path = |flag: &str| -> Option<String> {
        args.windows(2).find(|w| w[0] == flag).map(|w| w[1].clone())
    };
    let baseline = flag_path("--check");
    // a dangling `--check`/`--prime` with no path would silently skip
    if baseline.is_none() && args.iter().any(|a| a == "--check") {
        eprintln!("--check requires a baseline path argument");
        std::process::exit(1);
    }
    let prime = flag_path("--prime");
    if prime.is_none() && args.iter().any(|a| a == "--prime") {
        eprintln!("--prime requires a baseline path argument");
        std::process::exit(1);
    }
    let advisory = args.iter().any(|a| a == "--advisory");

    let targets = [Target::FlexAsr, Target::Hlscnn, Target::Vta];
    let mut records = Vec::new();
    let mut counters = Vec::new();
    println!("=== bench_matching: saturation smoke (indexed matcher) ===");
    println!(
        "{:<14} {:<8} {:>6} {:>8} {:>8} {:>11} {:>9} {:>9}",
        "application", "mode", "iters", "classes", "nodes", "candidates", "matches", "ms"
    );
    for app in all_apps() {
        for mode in [Matching::Exact, Matching::Flexible] {
            let res = compile_app(&app, &targets, mode, limits());
            let ms = res.elapsed.as_secs_f64() * 1e3;
            println!(
                "{:<14} {:<8} {:>6} {:>8} {:>8} {:>11} {:>9} {:>9.1}",
                app.name,
                mode.to_string(),
                res.iterations.len(),
                res.classes,
                res.nodes,
                res.candidate_classes(),
                res.total_matches(),
                ms
            );
            counters.push((
                app.name.to_string(),
                mode.to_string(),
                res.candidate_classes() as i64,
                res.total_matches() as i64,
            ));
            records.push(format!(
                "  {{\"app\": \"{}\", \"mode\": \"{}\", \"stop\": \"{:?}\", \
                 \"iters\": {}, \"classes\": {}, \"nodes\": {}, \
                 \"candidates\": {}, \"matches\": {}, \"wall_ms\": {:.3}, \
                 \"invocations\": {{\"flexasr\": {}, \"hlscnn\": {}, \"vta\": {}}}}}",
                app.name,
                mode,
                res.stop,
                res.iterations.len(),
                res.classes,
                res.nodes,
                res.candidate_classes(),
                res.total_matches(),
                ms,
                res.invocations(Target::FlexAsr),
                res.invocations(Target::Hlscnn),
                res.invocations(Target::Vta),
            ));
        }
    }
    let out = std::env::var("D2A_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_matching.json".to_string());
    let json = format!("[\n{}\n]\n", records.join(",\n"));
    std::fs::write(&out, json)?;
    println!("wrote {out}");

    if let Some(path) = prime {
        write_baseline(&path, &counters)?;
    }
    if let Some(path) = baseline {
        if let Err((msg, estimated)) = check_against_baseline(&counters, &path) {
            if advisory || estimated {
                println!(
                    "matching regression gate (advisory): would have \
                     failed:\n{msg}"
                );
            } else {
                eprintln!("matching regression gate FAILED:\n{msg}");
                std::process::exit(1);
            }
        }
    }
    Ok(())
}
