//! Regenerates the **§5.1 / Fig. 7** data-transfer optimization study:
//! offloading a (4,4)/(2,2) 2-D max pool of a 128x128 matrix onto
//! FlexASR's fixed (2,1)/(2,1) temporal max pool.
//!
//! Reports (a) the rewritten program shapes with and without the
//! store/load-cancellation rule and (b) the MMIO data beats of the naive
//! vs fused lowering.

use d2a::accel::FlexAsr;
use d2a::codegen::optimize::{pool_chains, transfer_stats};
use d2a::egraph::{AccelCost, EGraph, Extractor, Runner, RunnerLimits};
use d2a::ir::{parse::to_sexpr, Op, RecExpr, Target};
use d2a::rewrites::{compiler_ir, rules_for_extended, Matching};
use d2a::tensor::Tensor;
use d2a::util::Rng;
use std::collections::HashMap;

fn compile_maxpool(with_cancellation: bool) -> RecExpr {
    let mut e = RecExpr::new();
    let t = e.add(Op::Var("t".into()), vec![]);
    e.add(Op::MatMaxPool { window: (4, 4), stride: (2, 2) }, vec![t]);
    let env: HashMap<String, Vec<usize>> =
        [("t".to_string(), vec![128usize, 128])].into_iter().collect();
    let mut eg = EGraph::new(env);
    let root = eg.add_expr(&e);
    let mut rules = rules_for_extended(&[Target::FlexAsr], Matching::Flexible);
    if !with_cancellation {
        rules.retain(|r| r.name != "fasr-store-load-cancel");
        let _ = compiler_ir::data_movement_rules();
    }
    Runner::new(RunnerLimits::default()).run(&mut eg, &rules);
    Extractor::new(&eg, AccelCost::for_target(Target::FlexAsr)).extract(root)
}

fn main() {
    println!("=== Fig. 7 / §5.1: data-transfer optimization ===");
    let naive = compile_maxpool(false);
    let fused = compile_maxpool(true);
    let sn = transfer_stats(&naive);
    let sf = transfer_stats(&fused);
    println!("without store/load cancellation: {sn:?}, chains {:?}", pool_chains(&naive));
    println!("   with store/load cancellation: {sf:?}, chains {:?}", pool_chains(&fused));
    println!("naive program:     {}", to_sexpr(&naive));
    println!("optimized program: {}", to_sexpr(&fused));
    assert_eq!(sf.stores, 1, "optimized program stores once");
    assert_eq!(sf.loads, 1, "optimized program loads once");
    assert_eq!(sf.compute, 4);

    // MMIO-level beats (the physical cost the rewrite saves)
    let dev = FlexAsr::new();
    let mut rng = Rng::new(7);
    let t = dev.quant(&Tensor::randn(&[128, 128], &mut rng, 1.0));
    let fused_inv = dev.lower_maxpool_chain(&t, 4);
    let naive_invs = dev.lower_maxpool_chain_naive(&t, 4);
    let naive_beats: usize = naive_invs.iter().map(|i| i.data_beats()).sum();
    println!(
        "MMIO data beats: naive {} vs fused {} ({:.2}x reduction in stores alone;\n\
         naive additionally reads every intermediate back to the host)",
        naive_beats,
        fused_inv.data_beats(),
        naive_beats as f64 / fused_inv.data_beats() as f64
    );
}
