//! Regenerates the **§5.1 / Fig. 7** data-transfer optimization study:
//! offloading a (4,4)/(2,2) 2-D max pool of a 128x128 matrix onto
//! FlexASR's fixed (2,1)/(2,1) temporal max pool.
//!
//! The fused program compiles through the Session API
//! (`SessionBuilder::extended_rules` carries the §5.1 store/load
//! cancellation); the naive baseline needs a rule-set surgery the
//! session deliberately does not expose — dropping only
//! `fasr-store-load-cancel` — so it keeps the manual e-graph drive.
//!
//! Reports (a) the rewritten program shapes with and without the
//! cancellation rule, (b) the MMIO data beats/bytes of the naive vs
//! fused lowering, and (c) **modeled device cycles** under the FlexASR
//! cost model — the quantified Fig-7 claim: the fused lowering must be
//! strictly cheaper. Emits `BENCH_fig7.json` (override the path with
//! `D2A_BENCH_OUT_FIG7`).

use d2a::accel::FlexAsr;
use d2a::codegen::optimize::{pool_chains, transfer_stats};
use d2a::cost::{self, CostModel, CycleBreakdown, OpFamily};
use d2a::egraph::{AccelCost, EGraph, Extractor, Runner, RunnerLimits};
use d2a::ir::{parse::to_sexpr, Op, RecExpr, Target};
use d2a::rewrites::{rules_for_extended, Matching};
use d2a::session::Session;
use d2a::tensor::Tensor;
use d2a::util::Rng;
use std::collections::HashMap;

fn maxpool_expr() -> (RecExpr, HashMap<String, Vec<usize>>) {
    let mut e = RecExpr::new();
    let t = e.add(Op::Var("t".into()), vec![]);
    e.add(Op::MatMaxPool { window: (4, 4), stride: (2, 2) }, vec![t]);
    let shapes: HashMap<String, Vec<usize>> =
        [("t".to_string(), vec![128usize, 128])].into_iter().collect();
    (e, shapes)
}

/// The naive baseline: saturate with the extended rule set **minus** the
/// store/load-cancellation rule, so every pool stage round-trips through
/// host memory.
fn compile_naive() -> RecExpr {
    let (e, shapes) = maxpool_expr();
    let mut eg = EGraph::new(shapes);
    let root = eg.add_expr(&e);
    let mut rules = rules_for_extended(&[Target::FlexAsr], Matching::Flexible);
    rules.retain(|r| r.name != "fasr-store-load-cancel");
    Runner::new(RunnerLimits::default()).run(&mut eg, &rules);
    Extractor::new(&eg, AccelCost::for_target(Target::FlexAsr)).extract(root)
}

fn main() -> std::io::Result<()> {
    println!("=== Fig. 7 / §5.1: data-transfer optimization ===");
    let naive = compile_naive();
    let session = Session::builder()
        .targets(&[Target::FlexAsr])
        .extended_rules(true)
        .build();
    let (expr, shapes) = maxpool_expr();
    let fused = session.compile_expr(&expr, &shapes);
    let sn = transfer_stats(&naive);
    let sf = transfer_stats(fused.expr());
    println!("without store/load cancellation: {sn:?}, chains {:?}", pool_chains(&naive));
    println!(
        "   with store/load cancellation: {sf:?}, chains {:?}",
        pool_chains(fused.expr())
    );
    println!("naive program:     {}", to_sexpr(&naive));
    println!("optimized program: {}", to_sexpr(fused.expr()));
    assert_eq!(sf.stores, 1, "optimized program stores once");
    assert_eq!(sf.loads, 1, "optimized program loads once");
    assert_eq!(sf.compute, 4);

    // MMIO-level beats and modeled cycles (the physical cost the rewrite
    // saves); the chain lowers the same way the engine executes it, so
    // the static estimate is the cold-path engine cost
    let dev = FlexAsr::new();
    let model = CostModel::for_target(Target::FlexAsr);
    let mut rng = Rng::new(7);
    let t = dev.quant(&Tensor::randn(&[128, 128], &mut rng, 1.0));
    let fused_inv = dev.lower_maxpool_chain(&t, 4);
    let naive_invs = dev.lower_maxpool_chain_naive(&t, 4);
    let naive_beats: usize = naive_invs.iter().map(|i| i.data_beats()).sum();
    let naive_bytes: u64 = naive_invs.iter().map(|i| i.data_bytes()).sum();
    let naive_cycles: CycleBreakdown = naive_invs
        .iter()
        .map(|i| cost::invocation_cycles(&model, OpFamily::Pool, i))
        .fold(CycleBreakdown::default(), |acc, c| acc + c);
    let fused_cycles = cost::invocation_cycles(&model, OpFamily::Pool, &fused_inv);
    println!(
        "MMIO data beats: naive {} vs fused {} ({:.2}x reduction in stores alone;\n\
         naive additionally reads every intermediate back to the host)",
        naive_beats,
        fused_inv.data_beats(),
        naive_beats as f64 / fused_inv.data_beats() as f64
    );
    println!("modeled cycles: naive {naive_cycles} vs fused {fused_cycles}");
    assert!(
        fused_cycles.total() < naive_cycles.total(),
        "Fig-7 ordering: fused must be strictly cheaper in modeled cycles \
         ({} vs {})",
        fused_cycles.total(),
        naive_cycles.total()
    );

    let records = [
        format!(
            "  {{\"variant\": \"naive\", \"stores\": {}, \"loads\": {}, \
             \"pool_stages\": {}, \"data_beats\": {}, \"data_bytes\": {}, \
             \"transfer\": {}, \"compute\": {}, \"overhead\": {}, \"total\": {}}}",
            sn.stores,
            sn.loads,
            sn.compute,
            naive_beats,
            naive_bytes,
            naive_cycles.transfer,
            naive_cycles.compute,
            naive_cycles.overhead,
            naive_cycles.total(),
        ),
        format!(
            "  {{\"variant\": \"fused\", \"stores\": {}, \"loads\": {}, \
             \"pool_stages\": {}, \"data_beats\": {}, \"data_bytes\": {}, \
             \"transfer\": {}, \"compute\": {}, \"overhead\": {}, \"total\": {}}}",
            sf.stores,
            sf.loads,
            sf.compute,
            fused_inv.data_beats(),
            fused_inv.data_bytes(),
            fused_cycles.transfer,
            fused_cycles.compute,
            fused_cycles.overhead,
            fused_cycles.total(),
        ),
    ];
    let out = std::env::var("D2A_BENCH_OUT_FIG7")
        .unwrap_or_else(|_| "BENCH_fig7.json".to_string());
    std::fs::write(&out, format!("[\n{}\n]\n", records.join(",\n")))?;
    println!("wrote {out}");
    Ok(())
}
