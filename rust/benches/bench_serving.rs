//! Multi-tenant serving bench for the arbitrated device pool: an
//! open-loop Poisson load generator drives W worker threads against a
//! shared pool of K < W ILA devices, serving the LSTM-WLM layer with M
//! rotating weight sets (M tenants). Reports throughput, p50/p99
//! latency, pool occupancy, the residency hit rate, and the
//! weight-keyed template-cache hit rate (per-request inputs differ, so
//! every template hit is a lowering avoided) for both scheduling
//! policies, and emits a `BENCH_serving.json` trajectory point
//! (hand-serialized; the offline crate set has no serde).
//!
//! Open loop means arrivals are precomputed from an exponential
//! inter-arrival distribution and do **not** wait for completions — a
//! slow service backs requests up in the pool queue and shows up as p99
//! latency, exactly like production serving.
//!
//! The timing section is load-dependent, so the strict acceptance check
//! lives in a deterministic coda: a sequential repeated-weights pattern
//! (A,B,B,A,A,B,B,A) on a 2-device pool, where affinity routing must
//! stream strictly fewer bytes than FIFO. `tests/device_pool.rs` asserts
//! the same property under CrossCheck; here it also lands in the JSON.
//!
//! `--smoke` shrinks shapes and request count for CI. Output path
//! defaults to `BENCH_serving.json`; override with
//! `D2A_BENCH_OUT_SERVING`.

use d2a::cost::CycleBreakdown;
use d2a::ir::{GraphBuilder, Op, Target};
use d2a::session::{Bindings, DesignRev, ExecBackend, SchedPolicy, Session};
use d2a::tensor::Tensor;
use d2a::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Shared pool size (devices per target) — deliberately smaller than
/// [`WORKERS`] so requests contend for devices.
const POOL: usize = 2;
/// Serving worker threads.
const WORKERS: usize = 6;
/// Tenants: distinct weight sets rotating through the request stream.
const TENANTS: usize = 4;

struct Load {
    /// LSTM timesteps / embedding width / hidden width.
    t: usize,
    e: usize,
    h: usize,
    /// Requests in the open-loop run.
    requests: usize,
}

fn lstm_session(policy: SchedPolicy) -> Session {
    Session::builder()
        .targets(&[Target::FlexAsr])
        .design_rev(DesignRev::Updated)
        .backend(ExecBackend::IlaMmio)
        .device_pool(POOL)
        .sched_policy(policy)
        .build()
}

fn lstm_expr(steps: usize) -> d2a::ir::RecExpr {
    let mut g = GraphBuilder::new();
    let (x, wi, wh, b) = (g.var("x"), g.weight("wi"), g.weight("wh"), g.weight("b"));
    g.expr.add(Op::FlexLstm { steps }, vec![x, wi, wh, b]);
    g.finish()
}

/// One tenant's weight set plus a fresh per-request input, bound for the
/// LSTM program.
fn bindings_for(load: &Load, set: &(Tensor, Tensor, Tensor), rng: &mut Rng) -> Bindings {
    Bindings::new()
        .with("x", Tensor::randn(&[load.t, 1, load.e], rng, 1.0))
        .with("wi", set.0.clone())
        .with("wh", set.1.clone())
        .with("b", set.2.clone())
}

fn weight_sets(load: &Load, rng: &mut Rng) -> Vec<(Tensor, Tensor, Tensor)> {
    (0..TENANTS)
        .map(|_| {
            (
                Tensor::randn(&[4 * load.h, load.e], rng, 0.3),
                Tensor::randn(&[4 * load.h, load.h], rng, 0.3),
                Tensor::randn(&[4 * load.h], rng, 0.1),
            )
        })
        .collect()
}

struct ServingReport {
    policy: SchedPolicy,
    wall: Duration,
    throughput: f64,
    p50: Duration,
    p99: Duration,
    occupancy: f64,
    hit_rate: f64,
    /// Weight-keyed template-cache hit rate across the worker engines:
    /// per-request inputs differ, so every hit is a lowering (weight
    /// encode + calibration mirrors) avoided — only the cheap operand
    /// bind ran.
    template_hit_rate: f64,
    bytes_streamed: u64,
    mean_interarrival: Duration,
    /// Modeled device cycles summed over the worker engines — the
    /// host-speed-independent cost of serving the whole request stream.
    cycles: CycleBreakdown,
    stats: d2a::session::PoolStats,
}

fn percentile(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Open-loop run: W workers pull request indices off a shared counter,
/// sleep until each request's precomputed Poisson arrival, execute it on
/// a pool-backed engine, and record completion − arrival as its latency.
fn open_loop(load: &Load, policy: SchedPolicy) -> ServingReport {
    let session = lstm_session(policy);
    let program = session.attach(lstm_expr(load.t));
    let mut rng = Rng::new(61);
    let sets = weight_sets(load, &mut rng);

    // warm one device and measure the per-request service time s, then
    // offer load just under pool capacity: mean inter-arrival 1.2·s/K
    let mut warm = program.engine();
    let _ = program.run_with(&mut warm, &bindings_for(load, &sets[0], &mut rng)).unwrap();
    let t0 = Instant::now();
    let _ = program.run_with(&mut warm, &bindings_for(load, &sets[0], &mut rng)).unwrap();
    let service = t0.elapsed();
    drop(warm);
    let mean = service.mul_f64(1.2 / POOL as f64);

    // precompute the whole request stream before the clock starts:
    // tenant rotation, fresh inputs, and exponential inter-arrivals
    let requests: Vec<Bindings> = (0..load.requests)
        .map(|i| bindings_for(load, &sets[i % TENANTS], &mut rng))
        .collect();
    let mut arrivals = Vec::with_capacity(load.requests);
    let mut at = Duration::ZERO;
    for _ in 0..load.requests {
        let u = rng.uniform() as f64;
        at += mean.mul_f64(-(1.0 - u).ln());
        arrivals.push(at);
    }

    let next = AtomicUsize::new(0);
    let clock = Instant::now();
    let (mut latencies, dedup, streamed, bytes, cycles, tmpl) = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..WORKERS)
            .map(|_| {
                scope.spawn(|| {
                    let mut engine = program.engine();
                    let mut mine = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= requests.len() {
                            break;
                        }
                        let due = arrivals[i];
                        let now = clock.elapsed();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        let _ = program.run_with(&mut engine, &requests[i]).unwrap();
                        mine.push(clock.elapsed() - due);
                    }
                    let dedup = engine.bursts_deduped();
                    let streamed = engine.staged_streamed();
                    let bytes = engine.bytes_streamed();
                    let cycles = engine.modeled_cycles();
                    let tmpl = (engine.lower_cache_hits(), engine.lower_cache_misses());
                    (mine, dedup, streamed, bytes, cycles, tmpl)
                })
            })
            .collect();
        let mut lat = Vec::with_capacity(load.requests);
        let (mut dedup, mut streamed, mut bytes) = (0u64, 0u64, 0u64);
        let mut cycles = CycleBreakdown::default();
        let (mut tmpl_hits, mut tmpl_misses) = (0u64, 0u64);
        for h in handles {
            let (mine, d, s, b, c, (th, tm)) = h.join().expect("serving worker panicked");
            lat.extend(mine);
            dedup += d;
            streamed += s;
            bytes += b;
            cycles += c;
            tmpl_hits += th;
            tmpl_misses += tm;
        }
        (lat, dedup, streamed, bytes, cycles, (tmpl_hits, tmpl_misses))
    });
    let wall = clock.elapsed();
    let (tmpl_hits, tmpl_misses) = tmpl;
    latencies.sort();

    let stats = session.device_pool().unwrap().stats();
    ServingReport {
        policy,
        wall,
        throughput: load.requests as f64 / wall.as_secs_f64(),
        p50: percentile(&latencies, 0.50),
        p99: percentile(&latencies, 0.99),
        occupancy: stats.busy.as_secs_f64() / (POOL as f64 * wall.as_secs_f64()),
        hit_rate: dedup as f64 / (dedup + streamed).max(1) as f64,
        template_hit_rate: tmpl_hits as f64 / (tmpl_hits + tmpl_misses).max(1) as f64,
        bytes_streamed: bytes,
        mean_interarrival: mean,
        cycles,
        stats,
    }
}

/// Deterministic coda: sequential repeated-weights pattern on a
/// 2-device pool. Returns total `bytes_streamed` and modeled device
/// cycles under the policy — affinity must win on both axes.
fn repeated_weights(load: &Load, policy: SchedPolicy) -> (u64, CycleBreakdown) {
    let pattern = [0usize, 1, 1, 0, 0, 1, 1, 0];
    let session = lstm_session(policy);
    let program = session.attach(lstm_expr(load.t));
    let mut rng = Rng::new(62);
    let sets = weight_sets(load, &mut rng);
    let mut engine = program.engine();
    for &set in pattern.iter() {
        let b = bindings_for(load, &sets[set], &mut rng);
        let _ = program.run_with(&mut engine, &b).unwrap();
    }
    (engine.bytes_streamed(), engine.modeled_cycles())
}

fn report_json(r: &ServingReport, load: &Load) -> String {
    format!(
        "  {{\"section\": \"open-loop\", \"policy\": \"{}\", \
         \"lstm\": [{}, {}, {}], \"requests\": {}, \"workers\": {}, \
         \"pool\": {}, \"tenants\": {}, \
         \"mean_interarrival_ms\": {:.3}, \"wall_ms\": {:.1}, \
         \"throughput_rps\": {:.2}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \
         \"occupancy\": {:.3}, \"residency_hit_rate\": {:.3}, \
         \"template_hit_rate\": {:.3}, \
         \"bytes_streamed\": {}, \"transfer_cycles\": {}, \
         \"compute_cycles\": {}, \"overhead_cycles\": {}, \
         \"total_cycles\": {}, \"pool_busy_cycles\": {}, \
         \"pool_wait_cycles\": {}, \"devices_built\": {}, \"queued\": {}, \
         \"affinity_grants\": {}, \"fifo_grants\": {}, \
         \"build_grants\": {}, \"starvation_promotions\": {}}}",
        r.policy,
        load.t,
        load.e,
        load.h,
        load.requests,
        WORKERS,
        POOL,
        TENANTS,
        r.mean_interarrival.as_secs_f64() * 1e3,
        r.wall.as_secs_f64() * 1e3,
        r.throughput,
        r.p50.as_secs_f64() * 1e3,
        r.p99.as_secs_f64() * 1e3,
        r.occupancy,
        r.hit_rate,
        r.template_hit_rate,
        r.bytes_streamed,
        r.cycles.transfer,
        r.cycles.compute,
        r.cycles.overhead,
        r.cycles.total(),
        r.stats.busy_cycles,
        r.stats.wait_cycles,
        r.stats.devices_built,
        r.stats.queued,
        r.stats.affinity_grants,
        r.stats.fifo_grants,
        r.stats.build_grants,
        r.stats.starvation_promotions,
    )
}

fn main() -> std::io::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let load = if smoke {
        Load { t: 2, e: 64, h: 64, requests: 24 }
    } else {
        Load { t: 8, e: 256, h: 256, requests: 48 }
    };
    println!(
        "=== bench_serving: {} workers, pool {}, {} tenants, {} requests, \
         LSTM ({}, {}, {}) ===",
        WORKERS, POOL, TENANTS, load.requests, load.t, load.e, load.h
    );

    let mut records = Vec::new();
    for policy in [SchedPolicy::Affinity, SchedPolicy::Fifo] {
        let r = open_loop(&load, policy);
        println!(
            "{:<9} {:>7.1} req/s  p50 {:>8.2} ms  p99 {:>8.2} ms  \
             occupancy {:>5.1}%  residency hits {:>5.1}%  template hits \
             {:>5.1}%  {:>12} B streamed",
            r.policy.to_string(),
            r.throughput,
            r.p50.as_secs_f64() * 1e3,
            r.p99.as_secs_f64() * 1e3,
            r.occupancy * 1e2,
            r.hit_rate * 1e2,
            r.template_hit_rate * 1e2,
            r.bytes_streamed,
        );
        println!(
            "          modeled {} device cycles ({} transfer / {} compute \
             / {} overhead); pool busy {} cy, queue exposure {} cy",
            r.cycles.total(),
            r.cycles.transfer,
            r.cycles.compute,
            r.cycles.overhead,
            r.stats.busy_cycles,
            r.stats.wait_cycles,
        );
        assert!(r.throughput > 0.0);
        assert!(r.p50 <= r.p99);
        assert!((0.0..=1.0).contains(&r.hit_rate));
        assert!((0.0..=1.0).contains(&r.template_hit_rate));
        assert!(
            r.stats.devices_built as usize <= POOL,
            "pool must cap device construction"
        );
        records.push(report_json(&r, &load));
    }

    // the strict, load-independent acceptance check: affinity routing
    // must beat FIFO in streamed bytes AND in modeled device cycles
    let (aff, aff_cycles) = repeated_weights(&load, SchedPolicy::Affinity);
    let (fifo, fifo_cycles) = repeated_weights(&load, SchedPolicy::Fifo);
    println!(
        "repeated-weights (A,B,B,A,A,B,B,A): affinity streams {aff} B, \
         fifo {fifo} B ({:.2}x less)",
        fifo as f64 / aff.max(1) as f64
    );
    println!(
        "modeled device cycles: affinity {} vs fifo {} \
         ({} cycles saved, all in transfer: {} vs {})",
        aff_cycles.total(),
        fifo_cycles.total(),
        fifo_cycles.total().saturating_sub(aff_cycles.total()),
        aff_cycles.transfer,
        fifo_cycles.transfer,
    );
    assert!(
        aff < fifo,
        "affinity must stream strictly fewer bytes than FIFO: {aff} vs {fifo}"
    );
    assert!(
        aff_cycles.total() < fifo_cycles.total(),
        "affinity must cost strictly fewer modeled cycles than FIFO: {} vs {}",
        aff_cycles.total(),
        fifo_cycles.total()
    );
    records.push(format!(
        "  {{\"section\": \"repeated-weights\", \"pattern\": \"ABBAABBA\", \
         \"affinity_bytes\": {aff}, \"fifo_bytes\": {fifo}, \
         \"affinity_cycles\": {}, \"fifo_cycles\": {}}}",
        aff_cycles.total(),
        fifo_cycles.total()
    ));

    let out = std::env::var("D2A_BENCH_OUT_SERVING")
        .unwrap_or_else(|_| "BENCH_serving.json".to_string());
    std::fs::write(&out, format!("[\n{}\n]\n", records.join(",\n")))?;
    println!("wrote {out}");
    Ok(())
}
