//! Regenerates the **§4.4.2 speedup claim**: "for FlexASR, we see a ~30x
//! speedup on average with the ILA simulator compared to RTL simulation".
//!
//! Workload: FlexASR linear layers at several sizes. The ILA simulator
//! executes one whole-operation state update per instruction; the
//! RTL-proxy clocks the 16-lane PE pipeline cycle by cycle with bit-level
//! decode in every lane.

use d2a::accel::FlexAsr;
use d2a::rtl::RtlFlexAsr;
use d2a::tensor::Tensor;
use d2a::util::Rng;
use std::time::Instant;

fn main() {
    println!("=== ILA simulation vs RTL-level simulation (FlexASR linear) ===");
    println!(
        "{:<16} {:>12} {:>12} {:>9} {:>12}",
        "layer", "ILA sim", "RTL sim", "speedup", "RTL cycles"
    );
    let dev = FlexAsr::new();
    let mut rng = Rng::new(11);
    let mut speedups = Vec::new();
    for (n, k, m) in [(16, 64, 64), (32, 128, 128), (64, 256, 256), (64, 512, 512)] {
        let x = dev.quant(&Tensor::randn(&[n, k], &mut rng, 1.0));
        let w = dev.quant(&Tensor::randn(&[m, k], &mut rng, 0.3));
        let b = dev.quant(&Tensor::randn(&[m], &mut rng, 0.1));

        // warm + time ILA (tensor-level instruction semantics)
        let reps = 5;
        let t0 = Instant::now();
        for _ in 0..reps {
            let _ = dev.linear(&x, &w, &b);
        }
        let ila = t0.elapsed() / reps;

        let mut rtl = RtlFlexAsr::new();
        let t0 = Instant::now();
        let _ = rtl.linear(&x, &w, &b);
        let rtl_t = t0.elapsed();

        let speedup = rtl_t.as_secs_f64() / ila.as_secs_f64();
        speedups.push(speedup);
        println!(
            "{:<16} {:>12} {:>12} {:>8.1}x {:>12}",
            format!("{n}x{k}->{m}"),
            format!("{ila:.1?}"),
            format!("{rtl_t:.1?}"),
            speedup,
            rtl.cycles
        );
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    println!("average speedup: {avg:.1}x (paper: ~30x vs a commercial Verilog simulator)");
}
