//! Regenerates the **§4.4.2 speedup claim**: "for FlexASR, we see a ~30x
//! speedup on average with the ILA simulator compared to RTL simulation",
//! plus a functional-vs-MMIO **fidelity section**: the same compiled
//! program run under `ExecBackend::Functional` and `ExecBackend::IlaMmio`
//! must produce bit-identical outputs (and `CrossCheck` must report a
//! clean fidelity table), while the MMIO backend pays the byte-level
//! interface cost this bench quantifies.
//!
//! Workload: FlexASR linear layers at several sizes. The ILA simulator
//! executes one whole-operation state update per instruction; the
//! RTL-proxy clocks the 16-lane PE pipeline cycle by cycle with bit-level
//! decode in every lane.

use d2a::accel::FlexAsr;
use d2a::ir::{GraphBuilder, Target};
use d2a::rtl::RtlFlexAsr;
use d2a::session::{Bindings, ExecBackend, Session};
use d2a::tensor::Tensor;
use d2a::util::Rng;
use std::time::Instant;

/// Functional vs MMIO vs CrossCheck over one compiled linear program.
fn fidelity_section() {
    println!();
    println!("=== backend fidelity: functional vs ILA-MMIO (one FlexASR linear) ===");
    let mut g = GraphBuilder::new();
    let (x, w, b) = (g.var("x"), g.weight("w"), g.weight("b"));
    g.linear(x, w, b);
    let expr = g.finish();
    let shapes = [
        ("x".to_string(), vec![32usize, 128]),
        ("w".to_string(), vec![128, 128]),
        ("b".to_string(), vec![128]),
    ]
    .into_iter()
    .collect();
    let mut rng = Rng::new(12);
    let bindings = Bindings::new()
        .with("x", Tensor::randn(&[32, 128], &mut rng, 1.0))
        .with("w", Tensor::randn(&[128, 128], &mut rng, 0.3))
        .with("b", Tensor::randn(&[128], &mut rng, 0.1));

    let functional = Session::builder().targets(&[Target::FlexAsr]).build();
    let program = functional.compile_expr(&expr, &shapes);
    let reps = 20u32;
    let t0 = Instant::now();
    let mut f_out = program.run(&bindings).unwrap();
    for _ in 1..reps {
        f_out = program.run(&bindings).unwrap();
    }
    let t_func = t0.elapsed() / reps;

    let mmio = Session::builder()
        .targets(&[Target::FlexAsr])
        .backend(ExecBackend::IlaMmio)
        .build()
        .attach(program.expr().clone());
    // a caller-held engine amortizes simulator construction across calls
    // (the per-call alternative, `run()`, rebuilds the FlexASR IlaSim —
    // a ~0.3 MB initial-state clone — on every evaluation; `perf_hotpath`
    // times the two head to head and reports the reset-traffic counters)
    let mut engine = mmio.engine();
    let t0 = Instant::now();
    let mut m_out = mmio.run_with(&mut engine, &bindings).unwrap();
    for _ in 1..reps {
        m_out = mmio.run_with(&mut engine, &bindings).unwrap();
    }
    let t_mmio = t0.elapsed() / reps;

    assert_eq!(f_out, m_out, "backends must be bit-identical");
    println!(
        "functional {t_func:.1?}/eval vs ila-mmio {t_mmio:.1?}/eval \
         ({:.1}x interface cost), outputs bit-identical",
        t_mmio.as_secs_f64() / t_func.as_secs_f64().max(1e-12)
    );

    let crosscheck = Session::builder()
        .targets(&[Target::FlexAsr])
        .backend(ExecBackend::CrossCheck)
        .build()
        .attach(program.expr().clone());
    let trace = crosscheck.run_traced(&bindings).unwrap();
    assert!(trace.fidelity.is_clean(), "{}", trace.fidelity);
    print!("{}", trace.fidelity);
}

fn main() {
    println!("=== ILA simulation vs RTL-level simulation (FlexASR linear) ===");
    println!(
        "{:<16} {:>12} {:>12} {:>9} {:>12}",
        "layer", "ILA sim", "RTL sim", "speedup", "RTL cycles"
    );
    let dev = FlexAsr::new();
    let mut rng = Rng::new(11);
    let mut speedups = Vec::new();
    for (n, k, m) in [(16, 64, 64), (32, 128, 128), (64, 256, 256), (64, 512, 512)] {
        let x = dev.quant(&Tensor::randn(&[n, k], &mut rng, 1.0));
        let w = dev.quant(&Tensor::randn(&[m, k], &mut rng, 0.3));
        let b = dev.quant(&Tensor::randn(&[m], &mut rng, 0.1));

        // warm + time ILA (tensor-level instruction semantics)
        let reps = 5;
        let t0 = Instant::now();
        for _ in 0..reps {
            let _ = dev.linear(&x, &w, &b);
        }
        let ila = t0.elapsed() / reps;

        let mut rtl = RtlFlexAsr::new();
        let t0 = Instant::now();
        let _ = rtl.linear(&x, &w, &b);
        let rtl_t = t0.elapsed();

        let speedup = rtl_t.as_secs_f64() / ila.as_secs_f64();
        speedups.push(speedup);
        println!(
            "{:<16} {:>12} {:>12} {:>8.1}x {:>12}",
            format!("{n}x{k}->{m}"),
            format!("{ila:.1?}"),
            format!("{rtl_t:.1?}"),
            speedup,
            rtl.cycles
        );
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    println!("average speedup: {avg:.1}x (paper: ~30x vs a commercial Verilog simulator)");

    fidelity_section();
}
