//! Regenerates **Table 3** — formal verification of the FlexASR MaxPool
//! IR-accelerator mapping: BMC (full unroll, one monolithic miter) vs
//! CHC-style (relational per-tile invariants) runtimes across matrix
//! sizes, on our from-scratch CDCL/bit-blasting stack (the paper used Z3
//! on an i7-5500U with a 3-hour timeout; set D2A_VERIFY_TIMEOUT to taste).
//!
//! The second section runs the **lowering translation-validation
//! obligation suite** (both design revisions) and emits a
//! `BENCH_verification.json` trajectory point — per obligation: verdict,
//! SAT queries, conflicts, CNF variables, and wall time — so solver
//! effort on the repo's own codegen is tracked over time. Output path
//! defaults to `BENCH_verification.json` in the working directory;
//! override with `D2A_BENCH_OUT` (serialized by hand — the offline
//! crate set has no serde). The bench asserts the obligation lattice:
//! every verdict must match its expectation (Updated all-equivalent,
//! Original HLSCNN conv refuted with a concrete counterexample).

use d2a::smt::EquivResult;
use d2a::verify::{
    all_obligations_both_revs, check, verify_bmc, verify_chc, ObligationStatus,
};
use std::time::Duration;

const PAPER: &[((usize, usize), &str, &str)] = &[
    ((2, 16), "443", "38"),
    ((4, 16), "1976", "37"),
    ((4, 32), "7954", "146"),
    ((8, 64), "Timeout (>3 hrs)", "1831"),
    ((16, 64), "Timeout (>3 hrs)", "5177"),
];

fn fmt(r: &EquivResult, secs: f64, timeout: Duration) -> String {
    match r {
        EquivResult::Equivalent => format!("{secs:.1}s"),
        EquivResult::Timeout => format!("Timeout (>{}s)", timeout.as_secs()),
        EquivResult::Counterexample(_) => "REFUTED(!)".to_string(),
    }
}

fn main() {
    let timeout = Duration::from_secs(
        std::env::var("D2A_VERIFY_TIMEOUT")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(120u64),
    );
    println!(
        "=== Table 3: formal verification of the FlexASR MaxPool mapping ===\n\
         (our solver, timeout {}s; paper: Z3, 3h timeout)",
        timeout.as_secs()
    );
    println!("{:<10} {:>18} {:>18} | paper BMC / CHC (s)", "matrix", "BMC", "CHC");
    for ((r, c), pb, pc) in PAPER {
        let bmc = verify_bmc(*r, *c, timeout);
        let chc = verify_chc(*r, *c, timeout);
        println!(
            "{:<10} {:>18} {:>18} | {} / {}",
            format!("{r} x {c}"),
            fmt(&bmc.result, bmc.elapsed.as_secs_f64(), timeout),
            fmt(&chc.result, chc.elapsed.as_secs_f64(), timeout),
            pb,
            pc
        );
        assert!(
            !matches!(bmc.result, EquivResult::Counterexample(_)),
            "mapping must never be refuted"
        );
        assert!(!matches!(chc.result, EquivResult::Counterexample(_)));
    }

    println!();
    println!("=== Lowering translation validation (both design revisions) ===");
    println!(
        "{:<36} {:>13} {:>7} {:>10} {:>8}",
        "obligation", "status", "vars", "conflicts", "time"
    );
    let mut records = Vec::new();
    let mut unexpected = 0usize;
    for ob in all_obligations_both_revs() {
        let rep = check(&ob, timeout);
        let (queries, conflicts, vars, wall_ms) = rep
            .stats
            .as_ref()
            .map(|s| (s.queries, s.conflicts, s.vars, s.elapsed.as_secs_f64() * 1e3))
            .unwrap_or((0, 0, 0, 0.0));
        println!(
            "{:<36} {:>13} {:>7} {:>10} {:>7.0}ms",
            ob.id,
            rep.status.label(),
            vars,
            conflicts,
            wall_ms
        );
        if let ObligationStatus::Inequivalent(cex) = &rep.status {
            println!(
                "      counterexample at index {}: device {} vs reference {} — {}",
                cex.index, cex.hw_code, cex.ref_code, cex.note
            );
        }
        if !rep.as_expected() {
            unexpected += 1;
        }
        records.push(format!(
            "  {{\"obligation\": \"{}\", \"status\": \"{}\", \"queries\": {}, \
             \"conflicts\": {}, \"vars\": {}, \"wall_ms\": {:.1}}}",
            ob.id,
            rep.status.label(),
            queries,
            conflicts,
            vars,
            wall_ms
        ));
    }
    let out = std::env::var("D2A_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_verification.json".to_string());
    std::fs::write(&out, format!("[\n{}\n]\n", records.join(",\n")))
        .expect("write BENCH_verification.json");
    println!("wrote {out}");
    assert_eq!(
        unexpected, 0,
        "every obligation must match its expected verdict (see table above)"
    );
}
