//! Regenerates **Table 3** — formal verification of the FlexASR MaxPool
//! IR-accelerator mapping: BMC (full unroll, one monolithic miter) vs
//! CHC-style (relational per-tile invariants) runtimes across matrix
//! sizes, on our from-scratch CDCL/bit-blasting stack (the paper used Z3
//! on an i7-5500U with a 3-hour timeout; set D2A_VERIFY_TIMEOUT to taste).

use d2a::smt::EquivResult;
use d2a::verify::{verify_bmc, verify_chc};
use std::time::Duration;

const PAPER: &[((usize, usize), &str, &str)] = &[
    ((2, 16), "443", "38"),
    ((4, 16), "1976", "37"),
    ((4, 32), "7954", "146"),
    ((8, 64), "Timeout (>3 hrs)", "1831"),
    ((16, 64), "Timeout (>3 hrs)", "5177"),
];

fn fmt(r: &EquivResult, secs: f64, timeout: Duration) -> String {
    match r {
        EquivResult::Equivalent => format!("{secs:.1}s"),
        EquivResult::Timeout => format!("Timeout (>{}s)", timeout.as_secs()),
        EquivResult::Counterexample(_) => "REFUTED(!)".to_string(),
    }
}

fn main() {
    let timeout = Duration::from_secs(
        std::env::var("D2A_VERIFY_TIMEOUT")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(120u64),
    );
    println!(
        "=== Table 3: formal verification of the FlexASR MaxPool mapping ===\n\
         (our solver, timeout {}s; paper: Z3, 3h timeout)",
        timeout.as_secs()
    );
    println!("{:<10} {:>18} {:>18} | paper BMC / CHC (s)", "matrix", "BMC", "CHC");
    for ((r, c), pb, pc) in PAPER {
        let bmc = verify_bmc(*r, *c, timeout);
        let chc = verify_chc(*r, *c, timeout);
        println!(
            "{:<10} {:>18} {:>18} | {} / {}",
            format!("{r} x {c}"),
            fmt(&bmc.result, bmc.elapsed.as_secs_f64(), timeout),
            fmt(&chc.result, chc.elapsed.as_secs_f64(), timeout),
            pb,
            pc
        );
        assert!(
            !matches!(bmc.result, EquivResult::Counterexample(_)),
            "mapping must never be refuted"
        );
        assert!(!matches!(chc.result, EquivResult::Counterexample(_)));
    }
}
