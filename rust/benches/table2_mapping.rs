//! Regenerates **Table 2** — simulation-based validation of the
//! IR-accelerator mappings: average relative Frobenius error and standard
//! deviation over 100 random (on-lattice) test inputs per mapping.

use std::time::Instant;

const PAPER: &[(&str, &str, &str, &str)] = &[
    ("VTA", "GEMM", "0.00%", "0.00%"),
    ("HLSCNN", "Conv2D", "1.78%", "0.16%"),
    ("FlexASR", "LinearLayer", "0.84%", "0.29%"),
    ("FlexASR", "LSTM", "1.21%", "0.19%"),
    ("FlexASR", "LayerNorm", "0.27%", "0.20%"),
    ("FlexASR", "MaxPool", "0.00%", "0.00%"),
    ("FlexASR", "MeanPool", "1.79%", "0.28%"),
    ("FlexASR", "Attention", "4.22%", "0.09%"),
];

fn main() {
    let n = std::env::var("D2A_TABLE2_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100usize);
    println!("=== Table 2: simulation-based mapping validation ({n} inputs) ===");
    println!(
        "{:<9} {:<12} {:>9} {:>9} | paper avg/std",
        "accel", "operation", "avg err", "std dev"
    );
    let t0 = Instant::now();
    let rows = d2a::cosim::table2::validate_all(n, 2022);
    for (row, paper) in rows.iter().zip(PAPER) {
        let (m, s) = row.stats.pct();
        println!(
            "{:<9} {:<12} {:>9} {:>9} | {} / {}",
            row.accelerator, row.operation, m, s, paper.2, paper.3
        );
    }
    println!("validation time: {:.2}s", t0.elapsed().as_secs_f64());
}
