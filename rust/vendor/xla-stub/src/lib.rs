//! Offline stub of the `xla-rs` PJRT API surface that `d2a::runtime::pjrt`
//! consumes. The real backend needs the XLA C library, which is not part
//! of the offline build environment; this stub keeps the `pjrt` feature
//! compiling everywhere and fails cleanly at runtime. To run against real
//! PJRT, patch the `xla` dependency to `github.com/LaurentMazare/xla-rs`.

use std::path::Path;

/// Stub error: carries a human-readable reason.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla stub: PJRT backend not available in this build; patch the `xla` \
         crate to xla-rs to execute HLO artifacts"
            .to_string(),
    ))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        unavailable()
    }
}

/// XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}
