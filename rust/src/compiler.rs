//! The end-to-end D2A compilation driver (Fig. 2): IR program → equality
//! saturation (exact or flexible matching) → lowest-cost extraction.
//!
//! This is the low-level core; most callers should go through
//! [`crate::session::Session::compile`], which wraps the result in a
//! [`crate::session::CompiledProgram`] handle with a precomputed
//! accelerator dispatch plan.

use crate::egraph::{
    AccelCost, EGraph, Extractor, IterStats, Runner, RunnerLimits, StopReason,
};
use crate::ir::shape::Shape;
use crate::ir::{RecExpr, Target};
use crate::rewrites::{rules_for, Matching};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Result of one compilation run.
#[derive(Debug, Clone)]
pub struct CompileResult {
    /// The extracted (rewritten) program.
    pub expr: RecExpr,
    /// Why saturation stopped.
    pub stop: StopReason,
    /// e-graph size at extraction time.
    pub classes: usize,
    /// e-graph nodes at extraction time.
    pub nodes: usize,
    /// wall-clock of saturation + extraction.
    pub elapsed: Duration,
    /// Per-iteration saturation statistics (candidate-class counts,
    /// matches, unions) — the op-index effectiveness trail.
    pub iterations: Vec<IterStats>,
}

impl CompileResult {
    /// Static accelerator invocations per target — the Table 1 metric.
    pub fn invocations(&self, t: Target) -> usize {
        self.expr.invocations(t)
    }

    /// Total root-candidate classes probed during saturation.
    pub fn candidate_classes(&self) -> usize {
        self.iterations.iter().map(|i| i.candidates).sum()
    }

    /// Total e-matches found during saturation.
    pub fn total_matches(&self) -> usize {
        self.iterations.iter().map(|i| i.matches).sum()
    }
}

/// Compile an [`crate::apps::App`], automatically including app-specific
/// rules (the unrolled-LSTM mapping for LSTM-WLM, whose pattern is built
/// for the app's exact step count — Appendix A).
pub fn compile_app(
    app: &crate::apps::App,
    targets: &[Target],
    mode: Matching,
    limits: RunnerLimits,
) -> CompileResult {
    let mut extra = Vec::new();
    if app.name == "LSTM-WLM" && targets.contains(&Target::FlexAsr) {
        extra.push(crate::rewrites::accel::flexasr_unrolled_lstm(35, 650));
    }
    compile_with_extra(&app.expr, &app.shapes, targets, mode, limits, extra)
}

/// Compile `expr` for the given targets under the given matching mode.
pub fn compile(
    expr: &RecExpr,
    shape_env: &HashMap<String, Shape>,
    targets: &[Target],
    mode: Matching,
    limits: RunnerLimits,
) -> CompileResult {
    compile_with_extra(expr, shape_env, targets, mode, limits, Vec::new())
}

/// Compile with additional app-specific rewrite rules.
pub fn compile_with_extra(
    expr: &RecExpr,
    shape_env: &HashMap<String, Shape>,
    targets: &[Target],
    mode: Matching,
    limits: RunnerLimits,
    extra: Vec<crate::egraph::Rewrite>,
) -> CompileResult {
    let start = Instant::now();
    let mut eg = EGraph::new(shape_env.clone());
    let root = eg.add_expr(expr);
    let mut rules = rules_for(targets, mode);
    rules.extend(extra);
    let mut runner = Runner::new(limits);
    let stop = runner.run(&mut eg, &rules);
    let extractor = Extractor::new(&eg, AccelCost::for_targets(targets));
    let best = extractor.extract(root);
    CompileResult {
        expr: best,
        stop,
        classes: eg.num_classes(),
        nodes: eg.num_nodes(),
        elapsed: start.elapsed(),
        iterations: runner.iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    #[test]
    fn exact_vs_flexible_on_bare_dense() {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        g.dense(x, w);
        let expr = g.finish();
        let env: HashMap<String, Shape> =
            [("x".to_string(), vec![1usize, 8]), ("w".to_string(), vec![4, 8])]
                .into_iter()
                .collect();
        let exact = compile(
            &expr,
            &env,
            &[Target::FlexAsr],
            Matching::Exact,
            RunnerLimits::default(),
        );
        let flex = compile(
            &expr,
            &env,
            &[Target::FlexAsr],
            Matching::Flexible,
            RunnerLimits::default(),
        );
        assert_eq!(exact.invocations(Target::FlexAsr), 0);
        assert_eq!(flex.invocations(Target::FlexAsr), 1);
    }
}
