//! Bit-vector layer: terms, Tseitin bit-blasting, and miter-based
//! equivalence checking over the SAT core.
//!
//! The term language is exactly what the FlexASR MaxPool verification
//! (§4.4.1 / Table 3) needs: symbolic fixed-width variables, constants,
//! `max` (unsigned compare + mux), and `select` over symbolically-indexed
//! buffers (the store/select chains that make BMC's fully-unrolled
//! encodings big).

use super::sat::{Lit, SatResult, Solver, Var};
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Duration;

/// A bit-vector term (all terms in one query share a width).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum BvTerm {
    /// Named symbolic input.
    Var(String),
    /// Constant value.
    Const(u64),
    /// `max(a, b)` — unsigned.
    Max(Rc<BvTerm>, Rc<BvTerm>),
    /// `min(a, b)` — unsigned (used by meanpool-style fragments).
    Min(Rc<BvTerm>, Rc<BvTerm>),
}

impl BvTerm {
    /// A named input variable.
    pub fn var(name: impl Into<String>) -> Rc<BvTerm> {
        Rc::new(BvTerm::Var(name.into()))
    }

    /// Unsigned maximum of two terms.
    pub fn max(a: Rc<BvTerm>, b: Rc<BvTerm>) -> Rc<BvTerm> {
        Rc::new(BvTerm::Max(a, b))
    }

    /// Unsigned minimum of two terms.
    pub fn min(a: Rc<BvTerm>, b: Rc<BvTerm>) -> Rc<BvTerm> {
        Rc::new(BvTerm::Min(a, b))
    }

    /// Evaluate under a concrete environment (differential testing).
    pub fn eval(&self, env: &HashMap<String, u64>) -> u64 {
        match self {
            BvTerm::Var(n) => *env.get(n).unwrap_or(&0),
            BvTerm::Const(c) => *c,
            BvTerm::Max(a, b) => a.eval(env).max(b.eval(env)),
            BvTerm::Min(a, b) => a.eval(env).min(b.eval(env)),
        }
    }
}

/// Bit-blasting context: CNF builder over a [`Solver`].
pub struct BitBlaster {
    /// The underlying CDCL solver.
    pub solver: Solver,
    /// Bit-vector width in bits.
    pub width: u32,
    /// input variable name -> bit literals (LSB first)
    inputs: HashMap<String, Vec<Lit>>,
    /// structural cache: term pointer identity is not stable, so cache by
    /// value
    cache: HashMap<BvTerm, Vec<Lit>>,
    lit_true: Lit,
}

impl BitBlaster {
    /// Fresh context for `width`-bit terms.
    pub fn new(width: u32) -> Self {
        let mut solver = Solver::new();
        let t = solver.new_var();
        solver.add_clause(&[Lit::pos(t)]);
        BitBlaster {
            solver,
            width,
            inputs: HashMap::new(),
            cache: HashMap::new(),
            lit_true: Lit::pos(t),
        }
    }

    fn fresh(&mut self) -> Lit {
        Lit::pos(self.solver.new_var())
    }

    fn const_lit(&self, b: bool) -> Lit {
        if b {
            self.lit_true
        } else {
            self.lit_true.negate()
        }
    }

    /// y <-> a AND b
    fn and_gate(&mut self, a: Lit, b: Lit) -> Lit {
        let y = self.fresh();
        self.solver.add_clause(&[y.negate(), a]);
        self.solver.add_clause(&[y.negate(), b]);
        self.solver.add_clause(&[y, a.negate(), b.negate()]);
        y
    }

    /// y <-> a OR b
    fn or_gate(&mut self, a: Lit, b: Lit) -> Lit {
        self.and_gate(a.negate(), b.negate()).negate()
    }

    /// y <-> a XOR b
    fn xor_gate(&mut self, a: Lit, b: Lit) -> Lit {
        let y = self.fresh();
        self.solver.add_clause(&[y.negate(), a, b]);
        self.solver.add_clause(&[y.negate(), a.negate(), b.negate()]);
        self.solver.add_clause(&[y, a, b.negate()]);
        self.solver.add_clause(&[y, a.negate(), b]);
        y
    }

    /// y <-> (sel ? a : b)
    fn mux_gate(&mut self, sel: Lit, a: Lit, b: Lit) -> Lit {
        let y = self.fresh();
        self.solver.add_clause(&[sel.negate(), y.negate(), a]);
        self.solver.add_clause(&[sel.negate(), y, a.negate()]);
        self.solver.add_clause(&[sel, y.negate(), b]);
        self.solver.add_clause(&[sel, y, b.negate()]);
        y
    }

    /// Unsigned `a >= b` comparator (ripple from MSB).
    fn geq(&mut self, a: &[Lit], b: &[Lit]) -> Lit {
        // geq_i over bits [i..]: geq = (a_i > b_i) OR (a_i == b_i AND geq_{i+1})
        let mut geq = self.const_lit(true); // empty suffix: equal
        for i in 0..a.len() {
            let gt = self.and_gate(a[i], b[i].negate());
            let eq = self.xor_gate(a[i], b[i]).negate();
            let eq_and_rest = self.and_gate(eq, geq);
            geq = self.or_gate(gt, eq_and_rest);
        }
        geq
    }

    /// Bit-blast a term to literals (LSB first).
    pub fn blast(&mut self, t: &BvTerm) -> Vec<Lit> {
        if let Some(bits) = self.cache.get(t) {
            return bits.clone();
        }
        let bits = match t {
            BvTerm::Var(name) => {
                if let Some(b) = self.inputs.get(name) {
                    b.clone()
                } else {
                    let b: Vec<Lit> = (0..self.width).map(|_| self.fresh()).collect();
                    self.inputs.insert(name.clone(), b.clone());
                    b
                }
            }
            BvTerm::Const(c) => (0..self.width)
                .map(|i| self.const_lit((c >> i) & 1 == 1))
                .collect(),
            BvTerm::Max(a, b) | BvTerm::Min(a, b) => {
                let ab = self.blast(a);
                let bb = self.blast(b);
                let mut sel = self.geq(&ab, &bb); // a >= b
                if matches!(t, BvTerm::Min(..)) {
                    sel = sel.negate();
                }
                (0..self.width as usize)
                    .map(|i| self.mux_gate(sel, ab[i], bb[i]))
                    .collect()
            }
        };
        self.cache.insert(t.clone(), bits.clone());
        bits
    }

    /// Assert that at least one pair differs (the miter), then solve:
    /// UNSAT ⇒ all pairs are equivalent for all inputs.
    pub fn prove_all_equal(
        &mut self,
        pairs: &[(Rc<BvTerm>, Rc<BvTerm>)],
        timeout: Duration,
    ) -> EquivResult {
        let mut any_diff: Vec<Lit> = Vec::new();
        for (a, b) in pairs {
            let ab = self.blast(a);
            let bb = self.blast(b);
            // diff bit for this pair: OR of per-bit XORs
            let mut diff = self.const_lit(false);
            for i in 0..self.width as usize {
                let x = self.xor_gate(ab[i], bb[i]);
                diff = self.or_gate(diff, x);
            }
            any_diff.push(diff);
        }
        self.solver.add_clause(&any_diff);
        match self.solver.solve(timeout) {
            SatResult::Unsat => EquivResult::Equivalent,
            SatResult::Timeout => EquivResult::Timeout,
            SatResult::Sat => {
                let model: HashMap<String, u64> = self
                    .inputs
                    .iter()
                    .map(|(name, bits)| {
                        let mut v = 0u64;
                        for (i, l) in bits.iter().enumerate() {
                            let val = self.solver.model_value(l.var());
                            let bit = if l.sign() { !val } else { val };
                            if bit {
                                v |= 1 << i;
                            }
                        }
                        (name.clone(), v)
                    })
                    .collect();
                EquivResult::Counterexample(model)
            }
        }
    }

    /// Expose a named input's SAT variables (for assumptions in tests).
    pub fn input_bits(&self, name: &str) -> Option<&Vec<Lit>> {
        self.inputs.get(name)
    }

    #[allow(dead_code)]
    fn _unused(&self) -> Var {
        0
    }
}

/// Equivalence verdict.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EquivResult {
    Equivalent,
    Counterexample(HashMap<String, u64>),
    Timeout,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const T: Duration = Duration::from_secs(20);

    #[test]
    fn max_is_commutative() {
        let mut bb = BitBlaster::new(8);
        let a = BvTerm::var("a");
        let b = BvTerm::var("b");
        let lhs = BvTerm::max(a.clone(), b.clone());
        let rhs = BvTerm::max(b, a);
        assert_eq!(bb.prove_all_equal(&[(lhs, rhs)], T), EquivResult::Equivalent);
    }

    #[test]
    fn max_is_associative() {
        let mut bb = BitBlaster::new(8);
        let (a, b, c) = (BvTerm::var("a"), BvTerm::var("b"), BvTerm::var("c"));
        let lhs = BvTerm::max(BvTerm::max(a.clone(), b.clone()), c.clone());
        let rhs = BvTerm::max(a, BvTerm::max(b, c));
        assert_eq!(bb.prove_all_equal(&[(lhs, rhs)], T), EquivResult::Equivalent);
    }

    #[test]
    fn max_vs_min_refuted_with_model() {
        let mut bb = BitBlaster::new(8);
        let (a, b) = (BvTerm::var("a"), BvTerm::var("b"));
        let lhs = BvTerm::max(a.clone(), b.clone());
        let rhs = BvTerm::min(a.clone(), b.clone());
        match bb.prove_all_equal(&[(lhs, rhs)], T) {
            EquivResult::Counterexample(m) => {
                // the model must actually distinguish max from min
                let av = m["a"];
                let bv = m["b"];
                assert_ne!(av.max(bv), av.min(bv), "model {m:?} not a witness");
            }
            other => panic!("expected counterexample, got {other:?}"),
        }
    }

    #[test]
    fn const_folding_equivalence() {
        let mut bb = BitBlaster::new(8);
        let lhs = BvTerm::max(Rc::new(BvTerm::Const(7)), Rc::new(BvTerm::Const(3)));
        let rhs = Rc::new(BvTerm::Const(7));
        assert_eq!(bb.prove_all_equal(&[(lhs, rhs)], T), EquivResult::Equivalent);
    }

    /// Differential fuzz: term evaluation vs blasted semantics through
    /// equivalence of a term with itself under random rebalancing.
    #[test]
    fn random_max_trees_equivalent_under_rebalancing() {
        let mut rng = Rng::new(7);
        for _ in 0..5 {
            let leaves: Vec<Rc<BvTerm>> =
                (0..6).map(|i| BvTerm::var(format!("x{i}"))).collect();
            // left fold vs right fold of max over the same leaves
            let lhs = leaves[1..]
                .iter()
                .fold(leaves[0].clone(), |acc, l| BvTerm::max(acc, l.clone()));
            let rhs = leaves[..leaves.len() - 1]
                .iter()
                .rev()
                .fold(leaves.last().unwrap().clone(), |acc, l| {
                    BvTerm::max(l.clone(), acc)
                });
            // sanity: same concrete semantics
            let mut env = HashMap::new();
            for i in 0..6 {
                env.insert(format!("x{i}"), rng.below(256) as u64);
            }
            assert_eq!(lhs.eval(&env), rhs.eval(&env));
            let mut bb = BitBlaster::new(8);
            assert_eq!(bb.prove_all_equal(&[(lhs, rhs)], T), EquivResult::Equivalent);
        }
    }
}
