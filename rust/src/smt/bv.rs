//! Bit-vector layer: terms, Tseitin bit-blasting, and miter-based
//! equivalence checking over the SAT core.
//!
//! The term language started as exactly what the FlexASR MaxPool
//! verification (§4.4.1 / Table 3) needs — symbolic fixed-width
//! variables, constants, unsigned `max`/`min` — and now additionally
//! carries the integer arithmetic the tiled-lowering translation
//! validation (`verify::lowering`) encodes: two's-complement add /
//! multiply / negate, logic and arithmetic shifts, round-ties-even
//! arithmetic shift (the fixed-point requantization step), signed
//! max/min (saturation clamps), and width-bounded signed inputs
//! ([`BvTerm::SVar`]) that keep obligation inputs inside the ranges the
//! storage codecs can replay.
//!
//! Gate constructors constant-fold (`and(a, true) = a`,
//! `xor(a, a) = false`, …), so a miter whose two sides blast to the same
//! literals collapses to an empty clause at `add_clause` time: a
//! structurally-correct lowering discharges with **zero** solver search.

use super::sat::{Lit, SatResult, Solver};
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Duration;

/// Low `width` bits set.
fn mask(width: u32) -> u64 {
    if width >= 64 {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

/// Sign-extend the low `width` bits of `v` to a full i64.
fn sext64(v: u64, width: u32) -> i64 {
    if width >= 64 {
        return v as i64;
    }
    let v = v & mask(width);
    if (v >> (width - 1)) & 1 == 1 {
        (v | (!0u64 << width)) as i64
    } else {
        v as i64
    }
}

/// A bit-vector term (all terms in one query share a width).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum BvTerm {
    /// Named symbolic input spanning the full query width.
    Var(String),
    /// Named signed symbolic input of `n` significant bits, sign-extended
    /// to the query width. Bounding inputs this way keeps obligation
    /// witnesses inside the value ranges the storage codecs round-trip.
    SVar(String, u32),
    /// Constant value (truncated to the query width when blasted).
    Const(u64),
    /// `max(a, b)` — unsigned.
    Max(Rc<BvTerm>, Rc<BvTerm>),
    /// `min(a, b)` — unsigned (used by meanpool-style fragments).
    Min(Rc<BvTerm>, Rc<BvTerm>),
    /// Two's-complement addition (wrapping).
    Add(Rc<BvTerm>, Rc<BvTerm>),
    /// Two's-complement multiplication (wrapping).
    Mul(Rc<BvTerm>, Rc<BvTerm>),
    /// Two's-complement negation.
    Neg(Rc<BvTerm>),
    /// Logical left shift by a constant.
    Shl(Rc<BvTerm>, u32),
    /// Arithmetic (sign-preserving) right shift by a constant — the
    /// truncating fixed-point rescale.
    Ashr(Rc<BvTerm>, u32),
    /// Round-ties-even arithmetic right shift by a constant — the
    /// rounding fixed-point rescale (`FixedPointFormat` encode
    /// semantics).
    Rte(Rc<BvTerm>, u32),
    /// `max(a, b)` — signed (saturation clamps).
    SMax(Rc<BvTerm>, Rc<BvTerm>),
    /// `min(a, b)` — signed (saturation clamps).
    SMin(Rc<BvTerm>, Rc<BvTerm>),
}

impl BvTerm {
    /// A named input variable.
    pub fn var(name: impl Into<String>) -> Rc<BvTerm> {
        Rc::new(BvTerm::Var(name.into()))
    }

    /// A named signed input of `bits` significant bits (sign-extended).
    pub fn svar(name: impl Into<String>, bits: u32) -> Rc<BvTerm> {
        Rc::new(BvTerm::SVar(name.into(), bits))
    }

    /// A constant.
    pub fn cnst(c: u64) -> Rc<BvTerm> {
        Rc::new(BvTerm::Const(c))
    }

    /// A constant from a signed value (two's complement at blast width).
    pub fn cnst_i(c: i64) -> Rc<BvTerm> {
        Rc::new(BvTerm::Const(c as u64))
    }

    /// Unsigned maximum of two terms.
    pub fn max(a: Rc<BvTerm>, b: Rc<BvTerm>) -> Rc<BvTerm> {
        Rc::new(BvTerm::Max(a, b))
    }

    /// Unsigned minimum of two terms.
    pub fn min(a: Rc<BvTerm>, b: Rc<BvTerm>) -> Rc<BvTerm> {
        Rc::new(BvTerm::Min(a, b))
    }

    /// Wrapping addition.
    pub fn add(a: Rc<BvTerm>, b: Rc<BvTerm>) -> Rc<BvTerm> {
        Rc::new(BvTerm::Add(a, b))
    }

    /// Wrapping multiplication.
    pub fn mul(a: Rc<BvTerm>, b: Rc<BvTerm>) -> Rc<BvTerm> {
        Rc::new(BvTerm::Mul(a, b))
    }

    /// Two's-complement negation.
    pub fn neg(a: Rc<BvTerm>) -> Rc<BvTerm> {
        Rc::new(BvTerm::Neg(a))
    }

    /// Left shift by a constant (`shl(t, 0)` folds to `t`).
    pub fn shl(a: Rc<BvTerm>, s: u32) -> Rc<BvTerm> {
        if s == 0 {
            a
        } else {
            Rc::new(BvTerm::Shl(a, s))
        }
    }

    /// Arithmetic right shift by a constant (`ashr(t, 0)` folds to `t`).
    pub fn ashr(a: Rc<BvTerm>, s: u32) -> Rc<BvTerm> {
        if s == 0 {
            a
        } else {
            Rc::new(BvTerm::Ashr(a, s))
        }
    }

    /// Round-ties-even right shift by a constant (`rte(t, 0)` = `t`).
    pub fn rte(a: Rc<BvTerm>, s: u32) -> Rc<BvTerm> {
        if s == 0 {
            a
        } else {
            Rc::new(BvTerm::Rte(a, s))
        }
    }

    /// Signed maximum.
    pub fn smax(a: Rc<BvTerm>, b: Rc<BvTerm>) -> Rc<BvTerm> {
        Rc::new(BvTerm::SMax(a, b))
    }

    /// Signed minimum.
    pub fn smin(a: Rc<BvTerm>, b: Rc<BvTerm>) -> Rc<BvTerm> {
        Rc::new(BvTerm::SMin(a, b))
    }

    /// Clamp `a` into the signed range `[lo, hi]` (saturation).
    pub fn sclamp(a: Rc<BvTerm>, lo: i64, hi: i64) -> Rc<BvTerm> {
        BvTerm::smin(BvTerm::smax(a, BvTerm::cnst_i(lo)), BvTerm::cnst_i(hi))
    }

    /// Evaluate under a concrete environment at `width` bits, mirroring
    /// the blasted two's-complement semantics (differential testing and
    /// counterexample replay).
    pub fn eval(&self, env: &HashMap<String, u64>, width: u32) -> u64 {
        let m = mask(width);
        match self {
            BvTerm::Var(n) | BvTerm::SVar(n, _) => *env.get(n).unwrap_or(&0) & m,
            BvTerm::Const(c) => *c & m,
            BvTerm::Max(a, b) => a.eval(env, width).max(b.eval(env, width)),
            BvTerm::Min(a, b) => a.eval(env, width).min(b.eval(env, width)),
            BvTerm::Add(a, b) => {
                a.eval(env, width).wrapping_add(b.eval(env, width)) & m
            }
            BvTerm::Mul(a, b) => {
                a.eval(env, width).wrapping_mul(b.eval(env, width)) & m
            }
            BvTerm::Neg(a) => a.eval(env, width).wrapping_neg() & m,
            BvTerm::Shl(a, s) => {
                let v = a.eval(env, width);
                if *s >= 64 {
                    0
                } else {
                    (v << s) & m
                }
            }
            BvTerm::Ashr(a, s) => {
                let v = sext64(a.eval(env, width), width);
                (v >> s.min(&63)) as u64 & m
            }
            BvTerm::Rte(a, s) => {
                let v = a.eval(env, width);
                let q = (sext64(v, width) >> s.min(&63)) as u64;
                let r = v & mask(*s);
                let half = 1u64 << (s - 1);
                let inc = r > half || (r == half && q & 1 == 1);
                q.wrapping_add(inc as u64) & m
            }
            BvTerm::SMax(a, b) => {
                let (x, y) = (a.eval(env, width), b.eval(env, width));
                if sext64(x, width) >= sext64(y, width) {
                    x
                } else {
                    y
                }
            }
            BvTerm::SMin(a, b) => {
                let (x, y) = (a.eval(env, width), b.eval(env, width));
                if sext64(x, width) <= sext64(y, width) {
                    x
                } else {
                    y
                }
            }
        }
    }
}

/// Bit-blasting context: CNF builder over a [`Solver`].
pub struct BitBlaster {
    /// The underlying CDCL solver.
    pub solver: Solver,
    /// Bit-vector width in bits.
    pub width: u32,
    /// input variable name -> bit literals (LSB first)
    inputs: HashMap<String, Vec<Lit>>,
    /// significant-bit count of each [`BvTerm::SVar`] input (for
    /// sign-extended model extraction)
    svar_bits: HashMap<String, u32>,
    /// structural cache: term pointer identity is not stable, so cache by
    /// value
    cache: HashMap<BvTerm, Vec<Lit>>,
    lit_true: Lit,
}

impl BitBlaster {
    /// Fresh context for `width`-bit terms.
    pub fn new(width: u32) -> Self {
        let mut solver = Solver::new();
        let t = solver.new_var();
        solver.add_clause(&[Lit::pos(t)]);
        BitBlaster {
            solver,
            width,
            inputs: HashMap::new(),
            svar_bits: HashMap::new(),
            cache: HashMap::new(),
            lit_true: Lit::pos(t),
        }
    }

    fn fresh(&mut self) -> Lit {
        Lit::pos(self.solver.new_var())
    }

    fn const_lit(&self, b: bool) -> Lit {
        if b {
            self.lit_true
        } else {
            self.lit_true.negate()
        }
    }

    /// y <-> a AND b (constant-folded: known operands never allocate a
    /// gate, so structurally-equal miter sides stay literal-identical).
    fn and_gate(&mut self, a: Lit, b: Lit) -> Lit {
        let t = self.lit_true;
        let f = t.negate();
        if a == f || b == f || a == b.negate() {
            return f;
        }
        if a == t || a == b {
            return b;
        }
        if b == t {
            return a;
        }
        let y = self.fresh();
        self.solver.add_clause(&[y.negate(), a]);
        self.solver.add_clause(&[y.negate(), b]);
        self.solver.add_clause(&[y, a.negate(), b.negate()]);
        y
    }

    /// y <-> a OR b
    fn or_gate(&mut self, a: Lit, b: Lit) -> Lit {
        self.and_gate(a.negate(), b.negate()).negate()
    }

    /// y <-> a XOR b (constant-folded)
    fn xor_gate(&mut self, a: Lit, b: Lit) -> Lit {
        let t = self.lit_true;
        let f = t.negate();
        if a == f {
            return b;
        }
        if b == f {
            return a;
        }
        if a == t {
            return b.negate();
        }
        if b == t {
            return a.negate();
        }
        if a == b {
            return f;
        }
        if a == b.negate() {
            return t;
        }
        let y = self.fresh();
        self.solver.add_clause(&[y.negate(), a, b]);
        self.solver.add_clause(&[y.negate(), a.negate(), b.negate()]);
        self.solver.add_clause(&[y, a, b.negate()]);
        self.solver.add_clause(&[y, a.negate(), b]);
        y
    }

    /// y <-> (sel ? a : b) (constant-folded)
    fn mux_gate(&mut self, sel: Lit, a: Lit, b: Lit) -> Lit {
        let t = self.lit_true;
        let f = t.negate();
        if sel == t || a == b {
            return a;
        }
        if sel == f {
            return b;
        }
        let y = self.fresh();
        self.solver.add_clause(&[sel.negate(), y.negate(), a]);
        self.solver.add_clause(&[sel.negate(), y, a.negate()]);
        self.solver.add_clause(&[sel, y.negate(), b]);
        self.solver.add_clause(&[sel, y, b.negate()]);
        y
    }

    /// Unsigned `a >= b` comparator (ripple from LSB up).
    fn geq(&mut self, a: &[Lit], b: &[Lit]) -> Lit {
        // geq_i over bits [i..]: geq = (a_i > b_i) OR (a_i == b_i AND geq_{i+1})
        let mut geq = self.const_lit(true); // empty suffix: equal
        for i in 0..a.len() {
            let gt = self.and_gate(a[i], b[i].negate());
            let eq = self.xor_gate(a[i], b[i]).negate();
            let eq_and_rest = self.and_gate(eq, geq);
            geq = self.or_gate(gt, eq_and_rest);
        }
        geq
    }

    /// Signed `a >= b`: flip both MSBs (bias by 2^(w-1)) and compare
    /// unsigned.
    fn sgeq(&mut self, a: &[Lit], b: &[Lit]) -> Lit {
        let mut af = a.to_vec();
        let mut bf = b.to_vec();
        if let (Some(am), Some(bm)) = (af.last_mut(), bf.last_mut()) {
            *am = am.negate();
            *bm = bm.negate();
        }
        self.geq(&af, &bf)
    }

    /// Ripple-carry adder: `a + b + carry_in`, discarding the carry out
    /// (wrapping semantics).
    fn add_lits(&mut self, a: &[Lit], b: &[Lit], mut carry: Lit) -> Vec<Lit> {
        let mut out = Vec::with_capacity(a.len());
        for i in 0..a.len() {
            let axb = self.xor_gate(a[i], b[i]);
            out.push(self.xor_gate(axb, carry));
            let c1 = self.and_gate(a[i], b[i]);
            let c2 = self.and_gate(axb, carry);
            carry = self.or_gate(c1, c2);
        }
        out
    }

    /// Arithmetic right shift of a literal vector (sign bit replicated).
    fn ashr_lits(&self, a: &[Lit], s: u32) -> Vec<Lit> {
        let w = a.len();
        let sign = a[w - 1];
        (0..w)
            .map(|i| if i + (s as usize) < w { a[i + s as usize] } else { sign })
            .collect()
    }

    /// Bit-blast a term to literals (LSB first).
    pub fn blast(&mut self, t: &BvTerm) -> Vec<Lit> {
        if let Some(bits) = self.cache.get(t) {
            return bits.clone();
        }
        let w = self.width as usize;
        let bits = match t {
            BvTerm::Var(name) => {
                if let Some(b) = self.inputs.get(name) {
                    b.clone()
                } else {
                    let b: Vec<Lit> = (0..self.width).map(|_| self.fresh()).collect();
                    self.inputs.insert(name.clone(), b.clone());
                    b
                }
            }
            BvTerm::SVar(name, nbits) => {
                let nb = (*nbits).clamp(1, self.width) as usize;
                let base = if let Some(b) = self.inputs.get(name) {
                    b.clone()
                } else {
                    let b: Vec<Lit> = (0..nb).map(|_| self.fresh()).collect();
                    self.inputs.insert(name.clone(), b.clone());
                    self.svar_bits.insert(name.clone(), nb as u32);
                    b
                };
                let sign = base[base.len() - 1];
                (0..w)
                    .map(|i| if i < base.len() { base[i] } else { sign })
                    .collect()
            }
            BvTerm::Const(c) => (0..self.width)
                .map(|i| self.const_lit((c >> i) & 1 == 1))
                .collect(),
            BvTerm::Max(a, b) | BvTerm::Min(a, b) => {
                let ab = self.blast(a);
                let bb = self.blast(b);
                let mut sel = self.geq(&ab, &bb); // a >= b
                if matches!(t, BvTerm::Min(..)) {
                    sel = sel.negate();
                }
                (0..w).map(|i| self.mux_gate(sel, ab[i], bb[i])).collect()
            }
            BvTerm::SMax(a, b) | BvTerm::SMin(a, b) => {
                let ab = self.blast(a);
                let bb = self.blast(b);
                let mut sel = self.sgeq(&ab, &bb); // a >=s b
                if matches!(t, BvTerm::SMin(..)) {
                    sel = sel.negate();
                }
                (0..w).map(|i| self.mux_gate(sel, ab[i], bb[i])).collect()
            }
            BvTerm::Add(a, b) => {
                let ab = self.blast(a);
                let bb = self.blast(b);
                let cin = self.const_lit(false);
                self.add_lits(&ab, &bb, cin)
            }
            BvTerm::Mul(a, b) => {
                let ab = self.blast(a);
                let bb = self.blast(b);
                let f = self.const_lit(false);
                let mut acc = vec![f; w];
                for i in 0..w {
                    let mut pp = vec![f; w];
                    for j in i..w {
                        pp[j] = self.and_gate(bb[j - i], ab[i]);
                    }
                    acc = self.add_lits(&acc, &pp, f);
                }
                acc
            }
            BvTerm::Neg(a) => {
                let ab = self.blast(a);
                let inv: Vec<Lit> = ab.iter().map(|l| l.negate()).collect();
                let zeros = vec![self.const_lit(false); w];
                let one = self.const_lit(true);
                self.add_lits(&inv, &zeros, one)
            }
            BvTerm::Shl(a, s) => {
                let ab = self.blast(a);
                let s = (*s as usize).min(w);
                let f = self.const_lit(false);
                (0..w).map(|i| if i < s { f } else { ab[i - s] }).collect()
            }
            BvTerm::Ashr(a, s) => {
                let ab = self.blast(a);
                self.ashr_lits(&ab, (*s).min(self.width - 1))
            }
            BvTerm::Rte(a, s) => {
                // q = a >>s (arith); r = low s bits; round up when
                // r > half, or r == half and q is odd (ties to even)
                let ab = self.blast(a);
                let s = (*s).min(self.width - 1).max(1) as usize;
                let q = self.ashr_lits(&ab, s as u32);
                let mut low_or = self.const_lit(false);
                for &l in &ab[..s - 1] {
                    low_or = self.or_gate(low_or, l);
                }
                let rtop = ab[s - 1];
                let gt = self.and_gate(rtop, low_or);
                let eq = self.and_gate(rtop, low_or.negate());
                let tie_up = self.and_gate(eq, q[0]);
                let round_up = self.or_gate(gt, tie_up);
                let zeros = vec![self.const_lit(false); w];
                self.add_lits(&q, &zeros, round_up)
            }
        };
        self.cache.insert(t.clone(), bits.clone());
        bits
    }

    /// Assert that at least one pair differs (the miter), then solve:
    /// UNSAT ⇒ all pairs are equivalent for all inputs.
    pub fn prove_all_equal(
        &mut self,
        pairs: &[(Rc<BvTerm>, Rc<BvTerm>)],
        timeout: Duration,
    ) -> EquivResult {
        let mut any_diff: Vec<Lit> = Vec::new();
        for (a, b) in pairs {
            let ab = self.blast(a);
            let bb = self.blast(b);
            // diff bit for this pair: OR of per-bit XORs
            let mut diff = self.const_lit(false);
            for i in 0..self.width as usize {
                let x = self.xor_gate(ab[i], bb[i]);
                diff = self.or_gate(diff, x);
            }
            any_diff.push(diff);
        }
        self.solver.add_clause(&any_diff);
        match self.solver.solve(timeout) {
            SatResult::Unsat => EquivResult::Equivalent,
            SatResult::Timeout => EquivResult::Timeout,
            SatResult::Sat => {
                let model: HashMap<String, u64> = self
                    .inputs
                    .iter()
                    .map(|(name, bits)| {
                        let mut v = 0u64;
                        for (i, l) in bits.iter().enumerate() {
                            let val = self.solver.model_value(l.var());
                            let bit = if l.sign() { !val } else { val };
                            if bit {
                                v |= 1 << i;
                            }
                        }
                        // sign-extend bounded signed inputs so the
                        // witness reads as a plain i64
                        if let Some(&nb) = self.svar_bits.get(name) {
                            if nb < 64 && (v >> (nb - 1)) & 1 == 1 {
                                v |= !0u64 << nb;
                            }
                        }
                        (name.clone(), v)
                    })
                    .collect();
                EquivResult::Counterexample(model)
            }
        }
    }

    /// Expose a named input's SAT variables (for assumptions in tests).
    pub fn input_bits(&self, name: &str) -> Option<&Vec<Lit>> {
        self.inputs.get(name)
    }
}

/// Equivalence verdict.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EquivResult {
    /// UNSAT miter: the two sides agree on every input.
    Equivalent,
    /// SAT miter: a concrete input assignment distinguishing the sides.
    Counterexample(HashMap<String, u64>),
    /// Solver hit the caller's wall-clock budget.
    Timeout,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const T: Duration = Duration::from_secs(20);

    #[test]
    fn max_is_commutative() {
        let mut bb = BitBlaster::new(8);
        let a = BvTerm::var("a");
        let b = BvTerm::var("b");
        let lhs = BvTerm::max(a.clone(), b.clone());
        let rhs = BvTerm::max(b, a);
        assert_eq!(bb.prove_all_equal(&[(lhs, rhs)], T), EquivResult::Equivalent);
    }

    #[test]
    fn max_is_associative() {
        let mut bb = BitBlaster::new(8);
        let (a, b, c) = (BvTerm::var("a"), BvTerm::var("b"), BvTerm::var("c"));
        let lhs = BvTerm::max(BvTerm::max(a.clone(), b.clone()), c.clone());
        let rhs = BvTerm::max(a, BvTerm::max(b, c));
        assert_eq!(bb.prove_all_equal(&[(lhs, rhs)], T), EquivResult::Equivalent);
    }

    #[test]
    fn max_vs_min_refuted_with_model() {
        let mut bb = BitBlaster::new(8);
        let (a, b) = (BvTerm::var("a"), BvTerm::var("b"));
        let lhs = BvTerm::max(a.clone(), b.clone());
        let rhs = BvTerm::min(a.clone(), b.clone());
        match bb.prove_all_equal(&[(lhs, rhs)], T) {
            EquivResult::Counterexample(m) => {
                // the model must actually distinguish max from min
                let av = m["a"];
                let bv = m["b"];
                assert_ne!(av.max(bv), av.min(bv), "model {m:?} not a witness");
            }
            other => panic!("expected counterexample, got {other:?}"),
        }
    }

    #[test]
    fn const_folding_equivalence() {
        let mut bb = BitBlaster::new(8);
        let lhs = BvTerm::max(Rc::new(BvTerm::Const(7)), Rc::new(BvTerm::Const(3)));
        let rhs = Rc::new(BvTerm::Const(7));
        assert_eq!(bb.prove_all_equal(&[(lhs, rhs)], T), EquivResult::Equivalent);
    }

    /// Differential fuzz: term evaluation vs blasted semantics through
    /// equivalence of a term with itself under random rebalancing.
    #[test]
    fn random_max_trees_equivalent_under_rebalancing() {
        let mut rng = Rng::new(7);
        for _ in 0..5 {
            let leaves: Vec<Rc<BvTerm>> =
                (0..6).map(|i| BvTerm::var(format!("x{i}"))).collect();
            // left fold vs right fold of max over the same leaves
            let lhs = leaves[1..]
                .iter()
                .fold(leaves[0].clone(), |acc, l| BvTerm::max(acc, l.clone()));
            let rhs = leaves[..leaves.len() - 1]
                .iter()
                .rev()
                .fold(leaves.last().unwrap().clone(), |acc, l| {
                    BvTerm::max(l.clone(), acc)
                });
            // sanity: same concrete semantics
            let mut env = HashMap::new();
            for i in 0..6 {
                env.insert(format!("x{i}"), rng.below(256) as u64);
            }
            assert_eq!(lhs.eval(&env, 8), rhs.eval(&env, 8));
            let mut bb = BitBlaster::new(8);
            assert_eq!(bb.prove_all_equal(&[(lhs, rhs)], T), EquivResult::Equivalent);
        }
    }

    /// Differential fuzz of the arithmetic nodes: `eval` must agree with
    /// the blasted circuit on random signed inputs (proved by asking the
    /// solver whether a term differs from the constant `eval` computed).
    #[test]
    fn arithmetic_eval_matches_blasted_semantics() {
        let mut rng = Rng::new(11);
        for round in 0..8 {
            let a = BvTerm::svar("a", 9);
            let b = BvTerm::svar("b", 9);
            let t = match round % 4 {
                0 => BvTerm::add(BvTerm::mul(a.clone(), b.clone()), a.clone()),
                1 => BvTerm::rte(BvTerm::mul(a.clone(), b.clone()), 3),
                2 => BvTerm::sclamp(BvTerm::add(a.clone(), b.clone()), -100, 100),
                _ => BvTerm::ashr(BvTerm::neg(a.clone()), 2),
            };
            let av = rng.below(512) as i64 - 256;
            let bv = rng.below(512) as i64 - 256;
            let mut env = HashMap::new();
            env.insert("a".to_string(), av as u64);
            env.insert("b".to_string(), bv as u64);
            let want = t.eval(&env, 24);
            // pin the inputs with unit clauses, then prove t == want
            let mut bb = BitBlaster::new(24);
            let bits_t = bb.blast(&t);
            for (name, v) in [("a", av), ("b", bv)] {
                let lits = bb.input_bits(name).unwrap().clone();
                for (i, l) in lits.iter().enumerate() {
                    let on = (v as u64 >> i) & 1 == 1;
                    let unit = if on { *l } else { l.negate() };
                    assert!(bb.solver.add_clause(&[unit]));
                }
            }
            let want_bits = bb.blast(&BvTerm::Const(want));
            let pairs: Vec<_> =
                bits_t.into_iter().zip(want_bits).collect();
            // any diff bit must be unsatisfiable
            let mut diff = bb.const_lit(false);
            for (x, y) in pairs {
                let d = bb.xor_gate(x, y);
                diff = bb.or_gate(diff, d);
            }
            bb.solver.add_clause(&[diff]);
            assert_eq!(
                bb.solver.solve(T),
                SatResult::Unsat,
                "round {round}: blasted value disagrees with eval ({av}, {bv})"
            );
        }
    }

    /// The requantization flaw in miniature: round-ties-even shift vs
    /// truncating shift differ, and the witness pinpoints it.
    #[test]
    fn rte_vs_ashr_refuted_with_sound_witness() {
        let mut bb = BitBlaster::new(16);
        let a = BvTerm::svar("a", 12);
        let lhs = BvTerm::rte(a.clone(), 4);
        let rhs = BvTerm::ashr(a.clone(), 4);
        match bb.prove_all_equal(&[(lhs.clone(), rhs.clone())], T) {
            EquivResult::Counterexample(m) => {
                assert_ne!(lhs.eval(&m, 16), rhs.eval(&m, 16), "witness {m:?}");
            }
            other => panic!("expected counterexample, got {other:?}"),
        }
    }

    /// Structurally identical miter sides must discharge without any
    /// solver search: constant folding collapses the miter to an empty
    /// clause at add time.
    #[test]
    fn structural_equality_discharges_without_search() {
        let mut bb = BitBlaster::new(32);
        let a = BvTerm::svar("a", 8);
        let b = BvTerm::svar("b", 8);
        let t = BvTerm::rte(BvTerm::add(BvTerm::mul(a, b.clone()), b), 2);
        assert_eq!(
            bb.prove_all_equal(&[(t.clone(), t)], T),
            EquivResult::Equivalent
        );
        assert_eq!(bb.solver.stats_decisions, 0, "no search expected");
        assert_eq!(bb.solver.stats_conflicts, 0, "no conflicts expected");
    }
}
