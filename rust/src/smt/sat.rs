//! A CDCL SAT solver (watched literals, 1UIP learning, VSIDS-style
//! activity, geometric restarts) — the decision engine under the
//! bit-vector equivalence checking of §4.4.1. Z3 fills this role in the
//! paper; the offline environment has no SMT solver, so we built the
//! stack from the ground up (see DESIGN.md substitution ledger).

use std::time::{Duration, Instant};

/// Variable index (0-based).
pub type Var = u32;

/// Literal: `var << 1 | sign` (sign 1 = negated).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Lit(pub u32);

impl Lit {
    /// Positive literal of a variable.
    pub fn pos(v: Var) -> Lit {
        Lit(v << 1)
    }

    /// Negated literal of a variable.
    pub fn neg(v: Var) -> Lit {
        Lit((v << 1) | 1)
    }

    /// The underlying variable.
    pub fn var(self) -> Var {
        self.0 >> 1
    }

    /// True when negated.
    pub fn sign(self) -> bool {
        self.0 & 1 == 1
    }

    /// The opposite literal.
    pub fn negate(self) -> Lit {
        Lit(self.0 ^ 1)
    }

    fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Solver outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SatResult {
    Sat,
    Unsat,
    Timeout,
}

const UNASSIGNED: i8 = 2;

#[derive(Debug, Clone)]
struct Clause {
    lits: Vec<Lit>,
    learnt: bool,
}

/// The solver.
pub struct Solver {
    clauses: Vec<Clause>,
    /// watches[lit] = clause indices watching `lit`
    watches: Vec<Vec<usize>>,
    /// assignment per var: 0 false, 1 true, 2 unassigned
    assign: Vec<i8>,
    /// decision level per var
    level: Vec<u32>,
    /// reason clause per var (usize::MAX = decision/none)
    reason: Vec<usize>,
    trail: Vec<Lit>,
    trail_lim: Vec<usize>,
    prop_head: usize,
    activity: Vec<f64>,
    act_inc: f64,
    /// polarity memory for phase saving
    polarity: Vec<bool>,
    /// set when an empty clause is added
    unsat_on_add: bool,
    /// Conflicts encountered (proof effort metric).
    pub stats_conflicts: u64,
    /// Unit propagations performed.
    pub stats_propagations: u64,
    /// Branching decisions taken.
    pub stats_decisions: u64,
}

impl Default for Solver {
    fn default() -> Self {
        Self::new()
    }
}

impl Solver {
    /// An empty solver.
    pub fn new() -> Self {
        Solver {
            clauses: Vec::new(),
            watches: Vec::new(),
            assign: Vec::new(),
            level: Vec::new(),
            reason: Vec::new(),
            trail: Vec::new(),
            trail_lim: Vec::new(),
            prop_head: 0,
            activity: Vec::new(),
            act_inc: 1.0,
            polarity: Vec::new(),
            unsat_on_add: false,
            stats_conflicts: 0,
            stats_propagations: 0,
            stats_decisions: 0,
        }
    }

    /// Allocate a fresh variable.
    pub fn new_var(&mut self) -> Var {
        let v = self.assign.len() as Var;
        self.assign.push(UNASSIGNED);
        self.level.push(0);
        self.reason.push(usize::MAX);
        self.activity.push(0.0);
        self.polarity.push(false);
        self.watches.push(Vec::new());
        self.watches.push(Vec::new());
        v
    }

    /// Number of allocated variables.
    pub fn num_vars(&self) -> usize {
        self.assign.len()
    }

    /// Number of stored clauses.
    pub fn num_clauses(&self) -> usize {
        self.clauses.len()
    }

    fn value(&self, l: Lit) -> i8 {
        let a = self.assign[l.var() as usize];
        if a == UNASSIGNED {
            UNASSIGNED
        } else if l.sign() {
            1 - a
        } else {
            a
        }
    }

    /// Add a clause (at decision level 0 only). Returns false when the
    /// formula became trivially unsatisfiable.
    pub fn add_clause(&mut self, lits: &[Lit]) -> bool {
        debug_assert!(self.trail_lim.is_empty(), "add_clause at level 0 only");
        // simplify: drop false lits, detect true/duplicate
        let mut c: Vec<Lit> = Vec::with_capacity(lits.len());
        for &l in lits {
            match self.value(l) {
                1 => return true, // already satisfied
                0 => continue,
                _ => {
                    if c.contains(&l.negate()) {
                        return true; // tautology
                    }
                    if !c.contains(&l) {
                        c.push(l);
                    }
                }
            }
        }
        match c.len() {
            0 => {
                self.unsat_on_add = true;
                false
            }
            1 => {
                if !self.enqueue(c[0], usize::MAX) {
                    self.unsat_on_add = true;
                    return false;
                }
                // propagate eagerly so later adds see the implications
                if self.propagate().is_some() {
                    self.unsat_on_add = true;
                    return false;
                }
                true
            }
            _ => {
                let ci = self.clauses.len();
                self.watches[c[0].idx()].push(ci);
                self.watches[c[1].idx()].push(ci);
                self.clauses.push(Clause { lits: c, learnt: false });
                true
            }
        }
    }

    fn enqueue(&mut self, l: Lit, reason: usize) -> bool {
        match self.value(l) {
            1 => true,
            0 => false,
            _ => {
                let v = l.var() as usize;
                self.assign[v] = if l.sign() { 0 } else { 1 };
                self.level[v] = self.trail_lim.len() as u32;
                self.reason[v] = reason;
                self.polarity[v] = !l.sign();
                self.trail.push(l);
                true
            }
        }
    }

    /// Unit propagation; returns the conflicting clause index on conflict.
    fn propagate(&mut self) -> Option<usize> {
        while self.prop_head < self.trail.len() {
            let p = self.trail[self.prop_head];
            self.prop_head += 1;
            self.stats_propagations += 1;
            let false_lit = p.negate();
            let mut ws = std::mem::take(&mut self.watches[false_lit.idx()]);
            let mut i = 0;
            while i < ws.len() {
                let ci = ws[i];
                // make sure false_lit is at position 1
                let (l0, l1) = {
                    let c = &mut self.clauses[ci];
                    if c.lits[0] == false_lit {
                        c.lits.swap(0, 1);
                    }
                    (c.lits[0], c.lits[1])
                };
                debug_assert_eq!(l1, false_lit);
                if self.value(l0) == 1 {
                    i += 1;
                    continue;
                }
                // find a new watch
                let mut found = false;
                let n = self.clauses[ci].lits.len();
                for k in 2..n {
                    let lk = self.clauses[ci].lits[k];
                    if self.value(lk) != 0 {
                        self.clauses[ci].lits.swap(1, k);
                        self.watches[lk.idx()].push(ci);
                        ws.swap_remove(i);
                        found = true;
                        break;
                    }
                }
                if found {
                    continue;
                }
                // clause is unit or conflicting
                if !self.enqueue(l0, ci) {
                    self.watches[false_lit.idx()] = ws;
                    return Some(ci);
                }
                i += 1;
            }
            self.watches[false_lit.idx()] = ws;
        }
        None
    }

    fn bump(&mut self, v: Var) {
        self.activity[v as usize] += self.act_inc;
        if self.activity[v as usize] > 1e100 {
            for a in self.activity.iter_mut() {
                *a *= 1e-100;
            }
            self.act_inc *= 1e-100;
        }
    }

    /// First-UIP conflict analysis; returns (learnt clause, backjump level).
    fn analyze(&mut self, confl: usize) -> (Vec<Lit>, u32) {
        let mut learnt: Vec<Lit> = vec![Lit(0)]; // placeholder for UIP
        let mut seen = vec![false; self.num_vars()];
        let mut counter = 0usize;
        let mut p: Option<Lit> = None;
        let mut ci = confl;
        let cur_level = self.trail_lim.len() as u32;
        let mut trail_i = self.trail.len();

        loop {
            let start = if p.is_none() { 0 } else { 1 };
            let lits = self.clauses[ci].lits.clone();
            for &q in &lits[start..] {
                let v = q.var() as usize;
                if !seen[v] && self.level[v] > 0 {
                    seen[v] = true;
                    self.bump(q.var());
                    if self.level[v] == cur_level {
                        counter += 1;
                    } else {
                        learnt.push(q);
                    }
                }
            }
            // pick next literal from trail at current level
            loop {
                trail_i -= 1;
                if seen[self.trail[trail_i].var() as usize] {
                    break;
                }
            }
            let pl = self.trail[trail_i];
            seen[pl.var() as usize] = false;
            counter -= 1;
            if counter == 0 {
                learnt[0] = pl.negate();
                break;
            }
            ci = self.reason[pl.var() as usize];
            p = Some(pl);
        }
        let bj = learnt[1..]
            .iter()
            .map(|l| self.level[l.var() as usize])
            .max()
            .unwrap_or(0);
        (learnt, bj)
    }

    fn cancel_until(&mut self, lvl: u32) {
        while self.trail_lim.len() as u32 > lvl {
            let lim = self.trail_lim.pop().unwrap();
            while self.trail.len() > lim {
                let l = self.trail.pop().unwrap();
                self.assign[l.var() as usize] = UNASSIGNED;
                self.reason[l.var() as usize] = usize::MAX;
            }
        }
        self.prop_head = self.prop_head.min(self.trail.len());
    }

    fn decide(&mut self) -> Option<Lit> {
        let mut best: Option<(Var, f64)> = None;
        for v in 0..self.num_vars() as Var {
            if self.assign[v as usize] == UNASSIGNED {
                let a = self.activity[v as usize];
                if best.map(|(_, ba)| a > ba).unwrap_or(true) {
                    best = Some((v, a));
                }
            }
        }
        best.map(|(v, _)| {
            if self.polarity[v as usize] {
                Lit::pos(v)
            } else {
                Lit::neg(v)
            }
        })
    }

    /// Solve with a wall-clock timeout.
    pub fn solve(&mut self, timeout: Duration) -> SatResult {
        if self.unsat_on_add {
            return SatResult::Unsat;
        }
        let start = Instant::now();
        if self.propagate().is_some() {
            return SatResult::Unsat;
        }
        let mut restart_limit = 100u64;
        let mut conflicts_since_restart = 0u64;
        loop {
            if start.elapsed() > timeout {
                return SatResult::Timeout;
            }
            match self.propagate() {
                Some(confl) => {
                    self.stats_conflicts += 1;
                    conflicts_since_restart += 1;
                    if self.trail_lim.is_empty() {
                        return SatResult::Unsat;
                    }
                    let (learnt, bj) = self.analyze(confl);
                    self.cancel_until(bj);
                    self.act_inc /= 0.95;
                    if learnt.len() == 1 {
                        let ok = self.enqueue(learnt[0], usize::MAX);
                        if !ok {
                            return SatResult::Unsat;
                        }
                    } else {
                        let ci = self.clauses.len();
                        self.watches[learnt[0].idx()].push(ci);
                        self.watches[learnt[1].idx()].push(ci);
                        let l0 = learnt[0];
                        self.clauses.push(Clause { lits: learnt, learnt: true });
                        let ok = self.enqueue(l0, ci);
                        debug_assert!(ok);
                    }
                    if conflicts_since_restart > restart_limit {
                        conflicts_since_restart = 0;
                        restart_limit = (restart_limit as f64 * 1.5) as u64;
                        self.cancel_until(0);
                    }
                }
                None => match self.decide() {
                    None => return SatResult::Sat,
                    Some(l) => {
                        self.stats_decisions += 1;
                        self.trail_lim.push(self.trail.len());
                        let ok = self.enqueue(l, usize::MAX);
                        debug_assert!(ok);
                    }
                },
            }
        }
    }

    /// Model value of a variable after SAT (garbage before).
    pub fn model_value(&self, v: Var) -> bool {
        self.assign[v as usize] == 1
    }

    /// Dump the problem clauses (original, not learnt) in DIMACS CNF,
    /// including level-0 unit facts sitting on the trail — so a failing
    /// obligation can be replayed through an external solver
    /// (`minisat out.cnf`). DIMACS variables are 1-based.
    pub fn dimacs(&self) -> String {
        let level0 = if self.trail_lim.is_empty() {
            &self.trail[..]
        } else {
            &self.trail[..self.trail_lim[0]]
        };
        let units: Vec<&Lit> = level0.iter().collect();
        let originals: Vec<&Clause> =
            self.clauses.iter().filter(|c| !c.learnt).collect();
        let mut out = format!(
            "p cnf {} {}\n",
            self.num_vars(),
            originals.len() + units.len()
        );
        let fmt_lit = |l: &Lit| {
            let v = l.var() as i64 + 1;
            if l.sign() {
                -v
            } else {
                v
            }
        };
        for l in units {
            out.push_str(&format!("{} 0\n", fmt_lit(l)));
        }
        for c in originals {
            for l in &c.lits {
                out.push_str(&format!("{} ", fmt_lit(l)));
            }
            out.push_str("0\n");
        }
        out
    }

    /// Drop learnt clauses and reset the trail — reuse the solver shell
    /// for a fresh problem is NOT supported; this is for tests only.
    #[cfg(test)]
    fn is_learnt_count(&self) -> usize {
        self.clauses.iter().filter(|c| c.learnt).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::time::Duration;

    const T: Duration = Duration::from_secs(10);

    #[test]
    fn trivial_sat() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        s.add_clause(&[Lit::pos(a), Lit::pos(b)]);
        s.add_clause(&[Lit::neg(a)]);
        assert_eq!(s.solve(T), SatResult::Sat);
        assert!(!s.model_value(a));
        assert!(s.model_value(b));
    }

    #[test]
    fn trivial_unsat() {
        let mut s = Solver::new();
        let a = s.new_var();
        s.add_clause(&[Lit::pos(a)]);
        s.add_clause(&[Lit::neg(a)]);
        assert_eq!(s.solve(T), SatResult::Unsat);
    }

    #[test]
    fn xor_chain_unsat() {
        // a xor b, b xor c, c xor a with odd parity forced -> unsat
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        let c = s.new_var();
        // a != b
        s.add_clause(&[Lit::pos(a), Lit::pos(b)]);
        s.add_clause(&[Lit::neg(a), Lit::neg(b)]);
        // b != c
        s.add_clause(&[Lit::pos(b), Lit::pos(c)]);
        s.add_clause(&[Lit::neg(b), Lit::neg(c)]);
        // c != a
        s.add_clause(&[Lit::pos(c), Lit::pos(a)]);
        s.add_clause(&[Lit::neg(c), Lit::neg(a)]);
        assert_eq!(s.solve(T), SatResult::Unsat);
    }

    #[test]
    fn pigeonhole_4_into_3_unsat() {
        // PHP(4,3): classic small-hard UNSAT instance
        let (p, h) = (4usize, 3usize);
        let mut s = Solver::new();
        let vars: Vec<Vec<Var>> =
            (0..p).map(|_| (0..h).map(|_| s.new_var()).collect()).collect();
        for i in 0..p {
            let c: Vec<Lit> = (0..h).map(|j| Lit::pos(vars[i][j])).collect();
            s.add_clause(&c);
        }
        for j in 0..h {
            for i1 in 0..p {
                for i2 in i1 + 1..p {
                    s.add_clause(&[Lit::neg(vars[i1][j]), Lit::neg(vars[i2][j])]);
                }
            }
        }
        assert_eq!(s.solve(T), SatResult::Unsat);
        assert!(s.stats_conflicts > 0);
        assert!(s.is_learnt_count() > 0);
    }

    #[test]
    fn unit_propagation_chains_without_decisions() {
        // a; a->b; b->c; c->d : everything follows by propagation alone
        let mut s = Solver::new();
        let vs: Vec<Var> = (0..4).map(|_| s.new_var()).collect();
        s.add_clause(&[Lit::pos(vs[0])]);
        for w in vs.windows(2) {
            s.add_clause(&[Lit::neg(w[0]), Lit::pos(w[1])]);
        }
        assert_eq!(s.solve(T), SatResult::Sat);
        assert_eq!(s.stats_decisions, 0, "implication chain needs no search");
        assert!(s.stats_propagations >= 4, "each fact must be propagated");
        for v in vs {
            assert!(s.model_value(v));
        }
    }

    #[test]
    fn conflict_analysis_learns_clauses() {
        // PHP(4,3) cannot be solved without conflicts; every conflict
        // must yield a learnt clause (or a level-0 unit fact)
        let mut s = php_instance(4, 3);
        assert_eq!(s.solve(T), SatResult::Unsat);
        assert!(s.stats_conflicts > 0);
        assert!(
            s.is_learnt_count() > 0,
            "CDCL without learning would be plain DPLL"
        );
    }

    #[test]
    fn restarts_are_deterministic() {
        // two identical fresh solves must take the exact same path:
        // restart policy, activity bumps, and phase saving hold no
        // hidden global state
        let run = || {
            let mut s = php_instance(5, 4);
            let r = s.solve(Duration::from_secs(60));
            (r, s.stats_conflicts, s.stats_decisions, s.stats_propagations)
        };
        let a = run();
        let b = run();
        assert_eq!(a.0, SatResult::Unsat);
        assert_eq!(a, b, "solver must be a deterministic function of input");
        assert!(a.1 > 100, "PHP(5,4) should be enough work to restart");
    }

    fn php_instance(p: usize, h: usize) -> Solver {
        let mut s = Solver::new();
        let vars: Vec<Vec<Var>> =
            (0..p).map(|_| (0..h).map(|_| s.new_var()).collect()).collect();
        for pi in vars.iter() {
            let c: Vec<Lit> = pi.iter().map(|&v| Lit::pos(v)).collect();
            s.add_clause(&c);
        }
        for j in 0..h {
            for i1 in 0..p {
                for i2 in i1 + 1..p {
                    s.add_clause(&[Lit::neg(vars[i1][j]), Lit::neg(vars[i2][j])]);
                }
            }
        }
        s
    }

    #[test]
    fn dimacs_dump_roundtrips_the_problem() {
        let mut s = Solver::new();
        let a = s.new_var();
        let b = s.new_var();
        let c = s.new_var();
        s.add_clause(&[Lit::pos(a)]); // becomes a level-0 unit fact
        // add_clause simplifies against the trail: ¬a is already false
        // and drops out, so the stored clause is (b ∨ ¬c)
        s.add_clause(&[Lit::neg(a), Lit::pos(b), Lit::neg(c)]);
        let text = s.dimacs();
        let mut lines = text.lines();
        assert_eq!(lines.next(), Some("p cnf 3 2"));
        assert_eq!(lines.next(), Some("1 0"));
        assert_eq!(lines.next(), Some("2 -3 0"));
        assert_eq!(lines.next(), None);
    }

    /// Differential test against brute force on random small 3-SAT.
    #[test]
    fn random_3sat_matches_brute_force() {
        let mut rng = Rng::new(2024);
        for round in 0..40 {
            let nvars = 6 + rng.below(5); // 6..10
            let nclauses = 10 + rng.below(30);
            let mut clauses: Vec<Vec<(usize, bool)>> = Vec::new();
            for _ in 0..nclauses {
                let mut c = Vec::new();
                for _ in 0..3 {
                    c.push((rng.below(nvars), rng.below(2) == 1));
                }
                clauses.push(c);
            }
            // brute force
            let mut bf_sat = false;
            'outer: for m in 0..(1u32 << nvars) {
                for c in &clauses {
                    let mut ok = false;
                    for &(v, neg) in c {
                        let val = (m >> v) & 1 == 1;
                        if val != neg {
                            ok = true;
                            break;
                        }
                    }
                    if !ok {
                        continue 'outer;
                    }
                }
                bf_sat = true;
                break;
            }
            // solver
            let mut s = Solver::new();
            let vars: Vec<Var> = (0..nvars).map(|_| s.new_var()).collect();
            let mut consistent = true;
            for c in &clauses {
                let lits: Vec<Lit> = c
                    .iter()
                    .map(|&(v, neg)| if neg { Lit::neg(vars[v]) } else { Lit::pos(vars[v]) })
                    .collect();
                consistent &= s.add_clause(&lits);
            }
            let got = if !consistent { SatResult::Unsat } else { s.solve(T) };
            let want = if bf_sat { SatResult::Sat } else { SatResult::Unsat };
            assert_eq!(got, want, "round {round} disagrees with brute force");
        }
    }
}
