//! SAT/bit-vector substrate for the proof-based verification of
//! IR-accelerator mappings (§4.4.1 / Table 3).
//!
//! The paper discharges its verification conditions with Z3; this module
//! is the from-scratch replacement: a CDCL SAT core ([`sat`]) and a
//! bit-vector term layer with Tseitin bit-blasting and miter-based
//! equivalence checking ([`bv`]).

pub mod bv;
pub mod sat;

pub use bv::{BitBlaster, BvTerm, EquivResult};
pub use sat::{Lit, SatResult, Solver};
