//! Op-for-op IR mirrors of the trained build-time models
//! (`python/compile/model.py`) for application-level co-simulation
//! (Table 4). The integration test `integration_runtime` proves each
//! mirror equal to the JAX original via the exported goldens.

use super::App;
use crate::ir::shape::Shape;
use crate::ir::{GraphBuilder, Id};
use std::collections::HashMap;

const DIM: usize = 96;
const BLOCKS: usize = 3;

fn sh(env: &mut HashMap<String, Shape>, name: &str, s: &[usize]) {
    env.insert(name.to_string(), s.to_vec());
}

/// ResMLP-lite: 8 linear layers, all FlexASR-offloadable.
pub fn resmlp_lite() -> App {
    let mut g = GraphBuilder::new();
    let mut env = HashMap::new();
    let x = g.var("x");
    sh(&mut env, "x", &[1, 3, 8, 8]);
    let flat = g.reshape(x, &[1, 192]);
    let w0 = g.weight("l0_w");
    sh(&mut env, "l0_w", &[DIM, 192]);
    let b0 = g.weight("l0_b");
    sh(&mut env, "l0_b", &[DIM]);
    let mut h = g.linear(flat, w0, b0);
    h = g.gelu(h);
    for i in 0..BLOCKS {
        let w1 = g.weight(&format!("blk{i}_fc1_w"));
        sh(&mut env, &format!("blk{i}_fc1_w"), &[DIM, DIM]);
        let b1 = g.weight(&format!("blk{i}_fc1_b"));
        sh(&mut env, &format!("blk{i}_fc1_b"), &[DIM]);
        let mut z = g.linear(h, w1, b1);
        z = g.gelu(z);
        let w2 = g.weight(&format!("blk{i}_fc2_w"));
        sh(&mut env, &format!("blk{i}_fc2_w"), &[DIM, DIM]);
        let b2 = g.weight(&format!("blk{i}_fc2_b"));
        sh(&mut env, &format!("blk{i}_fc2_b"), &[DIM]);
        z = g.linear(z, w2, b2);
        h = g.add(h, z);
    }
    let wh = g.weight("head_w");
    sh(&mut env, "head_w", &[4, DIM]);
    let bh = g.weight("head_b");
    sh(&mut env, "head_b", &[4]);
    g.linear(h, wh, bh);
    App { name: "ResMLP", source_dsl: "JAX", expr: g.finish(), shapes: env }
}

/// LSTM-WLM-lite: pre-embedded input sequence -> fused LSTM op -> decoder
/// linear. (Embedding lookup happens in the co-sim driver.)
pub fn lstm_wlm_lite() -> App {
    let (t, e, h, v) = (16usize, 32usize, 32usize, 64usize);
    let mut g = GraphBuilder::new();
    let mut env = HashMap::new();
    let x = g.var("x_seq");
    sh(&mut env, "x_seq", &[t, 1, e]);
    let wi = g.weight("w_ih");
    sh(&mut env, "w_ih", &[4 * h, e]);
    let wh = g.weight("w_hh");
    sh(&mut env, "w_hh", &[4 * h, h]);
    let b = g.weight("b");
    sh(&mut env, "b", &[4 * h]);
    let seq = g.lstm(x, wi, wh, b, t); // [T, 1, H]
    let flat = g.reshape(seq, &[t, h]);
    let wd = g.weight("head_w");
    sh(&mut env, "head_w", &[v, h]);
    let bd = g.weight("head_b");
    sh(&mut env, "head_b", &[v]);
    g.linear(flat, wd, bd);
    App { name: "LSTM-WLM", source_dsl: "JAX", expr: g.finish(), shapes: env }
}

/// ResNet20-lite: 21 convolutions + linear head (HLSCNN + FlexASR).
pub fn resnet20_lite() -> App {
    let mut g = GraphBuilder::new();
    let mut env = HashMap::new();
    let x = g.var("x");
    sh(&mut env, "x", &[1, 3, 8, 8]);
    let w = g.weight("conv0_w");
    sh(&mut env, "conv0_w", &[8, 3, 3, 3]);
    let mut h = g.conv2d(x, w, (1, 1), (1, 1), 1);
    h = g.relu(h);
    let stages: [(usize, usize); 3] = [(8, 1), (16, 2), (32, 2)];
    let mut cin = 8usize;
    for (s, (ch, stride)) in stages.into_iter().enumerate() {
        for b in 0..3 {
            let st = if b == 0 { (stride, stride) } else { (1, 1) };
            let c1_in = if b == 0 { cin } else { ch };
            let w1 = g.weight(&format!("s{s}b{b}_c1_w"));
            sh(&mut env, &format!("s{s}b{b}_c1_w"), &[ch, c1_in, 3, 3]);
            let mut z = g.conv2d(h, w1, st, (1, 1), 1);
            z = g.relu(z);
            let w2 = g.weight(&format!("s{s}b{b}_c2_w"));
            sh(&mut env, &format!("s{s}b{b}_c2_w"), &[ch, ch, 3, 3]);
            z = g.conv2d(z, w2, (1, 1), (1, 1), 1);
            let sc: Id = if b == 0 && cin != ch {
                let wd = g.weight(&format!("s{s}_down_w"));
                sh(&mut env, &format!("s{s}_down_w"), &[ch, cin, 1, 1]);
                g.conv2d(h, wd, st, (0, 0), 1)
            } else {
                h
            };
            let sum = g.add(z, sc);
            h = g.relu(sum);
        }
        cin = ch;
    }
    let gap = g.global_avg_pool(h); // [1, 32]
    let wf = g.weight("fc_w");
    sh(&mut env, "fc_w", &[4, 32]);
    let bf = g.weight("fc_b");
    sh(&mut env, "fc_b", &[4]);
    g.linear(gap, wf, bf);
    App { name: "ResNet-20", source_dsl: "JAX", expr: g.finish(), shapes: env }
}

/// MobileNet-lite: depthwise (grouped, host) + pointwise (HLSCNN) convs
/// + linear head (FlexASR).
pub fn mobilenet_lite() -> App {
    let blocks: [(usize, usize); 4] = [(8, 16), (16, 16), (16, 32), (32, 32)];
    let mut g = GraphBuilder::new();
    let mut env = HashMap::new();
    let x = g.var("x");
    sh(&mut env, "x", &[1, 3, 8, 8]);
    let w = g.weight("conv0_w");
    sh(&mut env, "conv0_w", &[8, 3, 3, 3]);
    let mut h = g.conv2d(x, w, (1, 1), (1, 1), 1);
    h = g.relu(h);
    for (i, (cin, cout)) in blocks.into_iter().enumerate() {
        let wd = g.weight(&format!("blk{i}_dw_w"));
        sh(&mut env, &format!("blk{i}_dw_w"), &[cin, 1, 3, 3]);
        h = g.conv2d(h, wd, (1, 1), (1, 1), cin);
        h = g.relu(h);
        let wp = g.weight(&format!("blk{i}_pw_w"));
        sh(&mut env, &format!("blk{i}_pw_w"), &[cout, cin, 1, 1]);
        h = g.conv2d(h, wp, (1, 1), (0, 0), 1);
        h = g.relu(h);
    }
    let gap = g.global_avg_pool(h); // [1, 32]
    let wf = g.weight("fc_w");
    sh(&mut env, "fc_w", &[4, 32]);
    let bf = g.weight("fc_b");
    sh(&mut env, "fc_b", &[4]);
    g.linear(gap, wf, bf);
    App { name: "MobileNet-V2", source_dsl: "JAX", expr: g.finish(), shapes: env }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::shape::infer;

    #[test]
    fn cosim_mirrors_shape_check() {
        for app in [resmlp_lite(), lstm_wlm_lite(), resnet20_lite(), mobilenet_lite()]
        {
            let shapes = infer(&app.expr, &app.shapes)
                .unwrap_or_else(|e| panic!("{}: {e}", app.name));
            let out = shapes.last().unwrap();
            assert!(
                out == &vec![1, 4] || out == &vec![16, 64],
                "{}: unexpected output shape {out:?}",
                app.name
            );
        }
    }

    #[test]
    fn resnet_mirror_has_21_convs() {
        use crate::ir::Op;
        let app = resnet20_lite();
        assert_eq!(
            app.expr.count(|o| matches!(o, Op::Conv2d { .. })),
            21
        );
    }
}
