//! Application graphs.
//!
//! Two families:
//!
//! * [`table1`] — structural replicas of the paper's six DL applications
//!   (EfficientNet, LSTM-WLM, MobileNet-V2, ResMLP, ResNet-20,
//!   Transformer) with layer counts matching the real architectures.
//!   These drive the compilation-statistics experiment (Table 1); they
//!   carry shapes but no trained weights.
//! * [`cosim_models`] — op-for-op IR mirrors of the four *trained*
//!   build-time models from `python/compile/model.py` (ResMLP-lite,
//!   LSTM-WLM-lite, ResNet20-lite, MobileNet-lite). These drive the
//!   application-level co-simulation (Table 4); golden outputs exported
//!   by aot.py prove the mirrors exact.

pub mod cosim_models;
pub mod table1;

use crate::ir::shape::Shape;
use crate::ir::RecExpr;
use std::collections::HashMap;

/// A compilable application: graph + leaf shapes.
pub struct App {
    /// Application name (the Table 1 row label).
    pub name: &'static str,
    /// Front-end the paper imported the model from (PyTorch, MxNet, ...).
    pub source_dsl: &'static str,
    /// The IR program.
    pub expr: RecExpr,
    /// Declared shapes of every input/weight leaf.
    pub shapes: HashMap<String, Shape>,
}

impl App {
    /// Number of IR nodes (the "#Relay ops" proxy, Table 1 row 3).
    pub fn num_ops(&self) -> usize {
        self.expr.len()
    }
}
