//! Structural replicas of the paper's six DL applications (§4.2) for the
//! Table 1 compilation-statistics experiment.
//!
//! Each builder reproduces the *offloadable structure* of the real
//! network — the number of non-grouped convolutions, bare vs biased
//! dense layers, the unrolled LSTM recurrence, attention blocks — with
//! the layer counts of the real architectures, so the exact/flexible
//! invocation counts track the paper's. Exact node totals differ from
//! TVM's Relay import (different importer expansions); EXPERIMENTS.md
//! reports both.

use super::App;
use crate::ir::shape::Shape;
use crate::ir::{GraphBuilder, Id, Op};
use std::collections::HashMap;

fn sh(env: &mut HashMap<String, Shape>, name: &str, s: &[usize]) {
    env.insert(name.to_string(), s.to_vec());
}

/// EfficientNet (MxNet): 35 non-grouped convolutions (stem + 16 MBConv
/// expand/project pairs + head + conv-classifier), 16 depthwise convs,
/// swish activations. No dense layers at all.
pub fn efficientnet() -> App {
    let mut g = GraphBuilder::new();
    let mut env = HashMap::new();
    let x = g.var("data");
    sh(&mut env, "data", &[1, 3, 64, 64]);

    let swish = |g: &mut GraphBuilder, h: Id| {
        let s = g.expr.add(Op::Sigmoid, vec![h]);
        g.mul(h, s)
    };

    // stem: conv s2 3->24
    let w = g.weight("stem_w");
    sh(&mut env, "stem_w", &[24, 3, 3, 3]);
    let mut h = g.conv2d(x, w, (2, 2), (1, 1), 1);
    h = swish(&mut g, h);

    for b in 0..16 {
        // expand 24 -> 96 (1x1)
        let we = g.weight(&format!("b{b}_exp_w"));
        sh(&mut env, &format!("b{b}_exp_w"), &[96, 24, 1, 1]);
        let mut z = g.conv2d(h, we, (1, 1), (0, 0), 1);
        z = swish(&mut g, z);
        // depthwise 96 (groups=96) — not HLSCNN-offloadable
        let wd = g.weight(&format!("b{b}_dw_w"));
        sh(&mut env, &format!("b{b}_dw_w"), &[96, 1, 3, 3]);
        z = g.conv2d(z, wd, (1, 1), (1, 1), 96);
        z = swish(&mut g, z);
        // project 96 -> 24 (1x1)
        let wp = g.weight(&format!("b{b}_prj_w"));
        sh(&mut env, &format!("b{b}_prj_w"), &[24, 96, 1, 1]);
        z = g.conv2d(z, wp, (1, 1), (0, 0), 1);
        h = g.add(h, z); // residual
    }

    // head conv 24 -> 64, then classifier AS a 1x1 conv (hence zero
    // dense ops and zero exact VTA/FlexASR matches, as in Table 1)
    let wh = g.weight("head_w");
    sh(&mut env, "head_w", &[64, 24, 1, 1]);
    h = g.conv2d(h, wh, (1, 1), (0, 0), 1);
    h = swish(&mut g, h);
    let wc = g.weight("cls_w");
    sh(&mut env, "cls_w", &[1000, 64, 1, 1]);
    h = g.conv2d(h, wc, (1, 1), (0, 0), 1);
    g.global_avg_pool(h);

    App { name: "EfficientNet", source_dsl: "MxNet", expr: g.finish(), shapes: env }
}

/// LSTM-WLM (PyTorch): the word-language-model — an LSTM unrolled to 35
/// timesteps exactly as the importer emits it (SliceStep/Concat/Dense/
/// gate-slice recurrence; ~16 ops per step), plus one vocabulary-sized
/// decoder linear that exceeds FlexASR's buffer capacity.
pub fn lstm_wlm() -> App {
    let steps = 35usize;
    let embed = 650usize;
    let hidden = 650usize;
    let vocab = 33278usize;
    let mut g = GraphBuilder::new();
    let mut env = HashMap::new();
    let x = g.var("seq");
    sh(&mut env, "seq", &[steps, 1, embed]);
    let w = g.weight("lstm_w");
    sh(&mut env, "lstm_w", &[4 * hidden, embed + hidden]);
    let b = g.weight("lstm_b");
    sh(&mut env, "lstm_b", &[4 * hidden]);

    let h0 = g.expr.add(Op::ZeroTensor(vec![1, hidden]), vec![]);
    let c0 = g.expr.add(Op::ZeroTensor(vec![1, hidden]), vec![]);
    let (mut h, mut c) = (h0, c0);
    let mut chain: Option<Id> = None;
    for t in 0..steps {
        let xt = g.expr.add(Op::SliceStep { t }, vec![x]);
        let cat = g.concat(xt, h);
        let d = g.dense(cat, w);
        let gates = g.add(d, b);
        let gi = g.expr.add(Op::SliceCols { lo: 0, hi: hidden }, vec![gates]);
        let gi = g.expr.add(Op::Sigmoid, vec![gi]);
        let gf = g.expr.add(Op::SliceCols { lo: hidden, hi: 2 * hidden }, vec![gates]);
        let gf = g.expr.add(Op::Sigmoid, vec![gf]);
        let gg =
            g.expr.add(Op::SliceCols { lo: 2 * hidden, hi: 3 * hidden }, vec![gates]);
        let gg = g.expr.add(Op::Tanh, vec![gg]);
        let go =
            g.expr.add(Op::SliceCols { lo: 3 * hidden, hi: 4 * hidden }, vec![gates]);
        let go = g.expr.add(Op::Sigmoid, vec![go]);
        let fc = g.mul(gf, c);
        let ig = g.mul(gi, gg);
        c = g.add(fc, ig);
        let tc = g.expr.add(Op::Tanh, vec![c]);
        h = g.mul(go, tc);
        chain = Some(match chain {
            None => h,
            Some(acc) => g.expr.add(Op::ConcatRows, vec![acc, h]),
        });
    }
    // decoder: hidden -> vocab (bare dense + broadcast add; vocab size
    // 33278 exceeds FlexASR's 4096-dim capacity)
    let wd = g.weight("dec_w");
    sh(&mut env, "dec_w", &[vocab, hidden]);
    let bd = g.weight("dec_b");
    sh(&mut env, "dec_b", &[vocab]);
    let dec = g.dense(chain.unwrap(), wd);
    g.add(dec, bd);

    App { name: "LSTM-WLM", source_dsl: "PyTorch", expr: g.finish(), shapes: env }
}

/// MobileNet-V2 (PyTorch): 40 non-grouped convolutions (stem + 19
/// expand/project pairs + head is folded into the pairs) + 19 depthwise
/// convs + a classifier written as `add(reshape(nn_dense ...), bias)` —
/// the §2.2.2 pattern that defeats exact matching but not flexible.
pub fn mobilenet_v2() -> App {
    let mut g = GraphBuilder::new();
    let mut env = HashMap::new();
    let x = g.var("data");
    sh(&mut env, "data", &[1, 3, 32, 32]);

    // stem 3 -> 16
    let w = g.weight("stem_w");
    sh(&mut env, "stem_w", &[16, 3, 3, 3]);
    let mut h = g.conv2d(x, w, (1, 1), (1, 1), 1);
    h = g.relu(h);

    for b in 0..19 {
        let we = g.weight(&format!("b{b}_exp_w"));
        sh(&mut env, &format!("b{b}_exp_w"), &[32, 16, 1, 1]);
        let mut z = g.conv2d(h, we, (1, 1), (0, 0), 1);
        z = g.relu(z);
        let wd = g.weight(&format!("b{b}_dw_w"));
        sh(&mut env, &format!("b{b}_dw_w"), &[32, 1, 3, 3]);
        z = g.conv2d(z, wd, (1, 1), (1, 1), 32);
        z = g.relu(z);
        let wp = g.weight(&format!("b{b}_prj_w"));
        sh(&mut env, &format!("b{b}_prj_w"), &[16, 32, 1, 1]);
        z = g.conv2d(z, wp, (1, 1), (0, 0), 1);
        h = g.add(h, z);
    }
    // head conv 16 -> 32 (the 40th non-grouped convolution)
    let whd = g.weight("head_w");
    sh(&mut env, "head_w", &[32, 16, 1, 1]);
    h = g.conv2d(h, whd, (1, 1), (0, 0), 1);
    h = g.relu(h);
    let gap = g.global_avg_pool(h); // [1, 32]
    let wc = g.weight("cls_w");
    sh(&mut env, "cls_w", &[1000, 32]);
    let bc = g.weight("cls_b");
    sh(&mut env, "cls_b", &[1000]);
    let d = g.dense(gap, wc);
    let r = g.reshape(d, &[1, 1000]);
    g.add(r, bc);

    App { name: "MobileNet-V2", source_dsl: "PyTorch", expr: g.finish(), shapes: env }
}

/// ResMLP (PyTorch): 38 dense layers (embed + 12 x {cross-patch +
/// fc1 + fc2} + head), affine transforms instead of bias_add — so zero
/// exact FlexASR matches, all 38 exposed by flexible matching.
pub fn resmlp() -> App {
    let dim = 384usize;
    let mut g = GraphBuilder::new();
    let mut env = HashMap::new();
    let x = g.var("data");
    sh(&mut env, "data", &[16, dim]); // 16 patches x 384 features

    let affine = |g: &mut GraphBuilder,
                  env: &mut HashMap<String, Shape>,
                  name: String,
                  h: Id| {
        let sc = g.weight(&format!("{name}_scale"));
        sh(env, &format!("{name}_scale"), &[dim]);
        let sb = g.weight(&format!("{name}_shift"));
        sh(env, &format!("{name}_shift"), &[dim]);
        let m = g.mul(h, sc);
        g.add(m, sb)
    };

    let we = g.weight("embed_w");
    sh(&mut env, "embed_w", &[dim, dim]);
    let mut h = g.dense(x, we);
    for l in 0..12 {
        // cross-patch: transpose, dense over patches, transpose back
        let a = affine(&mut g, &mut env, format!("l{l}_a1"), h);
        let t = g.transpose(a);
        let wx = g.weight(&format!("l{l}_xpatch_w"));
        sh(&mut env, &format!("l{l}_xpatch_w"), &[16, 16]);
        let t = g.dense(t, wx);
        let t = g.transpose(t);
        h = g.add(h, t);
        // cross-channel MLP
        let a = affine(&mut g, &mut env, format!("l{l}_a2"), h);
        let w1 = g.weight(&format!("l{l}_fc1_w"));
        sh(&mut env, &format!("l{l}_fc1_w"), &[dim, dim]);
        let z = g.dense(a, w1);
        let z = g.gelu(z);
        let w2 = g.weight(&format!("l{l}_fc2_w"));
        sh(&mut env, &format!("l{l}_fc2_w"), &[dim, dim]);
        let z = g.dense(z, w2);
        h = g.add(h, z);
    }
    let wh = g.weight("head_w");
    sh(&mut env, "head_w", &[10, dim]);
    g.dense(h, wh);

    App { name: "ResMLP", source_dsl: "PyTorch", expr: g.finish(), shapes: env }
}

/// ResNet-20 (MxNet): 21 non-grouped convolutions (stem + 9 blocks x 2 +
/// 2 downsample shortcuts) and two biased linear layers — the only two
/// exact FlexASR/VTA matches in the row.
pub fn resnet20() -> App {
    let mut g = GraphBuilder::new();
    let mut env = HashMap::new();
    let x = g.var("data");
    sh(&mut env, "data", &[1, 3, 32, 32]);

    let w = g.weight("conv0_w");
    sh(&mut env, "conv0_w", &[16, 3, 3, 3]);
    let mut h = g.conv2d(x, w, (1, 1), (1, 1), 1);
    h = g.relu(h);

    let stages: [(usize, usize); 3] = [(16, 1), (32, 2), (64, 2)];
    let mut cin = 16;
    for (s, (ch, stride)) in stages.into_iter().enumerate() {
        for b in 0..3 {
            let st = if b == 0 { (stride, stride) } else { (1, 1) };
            let w1 = g.weight(&format!("s{s}b{b}_c1_w"));
            sh(&mut env, &format!("s{s}b{b}_c1_w"), &[ch, if b == 0 { cin } else { ch }, 3, 3]);
            let mut z = g.conv2d(h, w1, st, (1, 1), 1);
            z = g.relu(z);
            let w2 = g.weight(&format!("s{s}b{b}_c2_w"));
            sh(&mut env, &format!("s{s}b{b}_c2_w"), &[ch, ch, 3, 3]);
            z = g.conv2d(z, w2, (1, 1), (1, 1), 1);
            let sc = if b == 0 && cin != ch {
                let wd = g.weight(&format!("s{s}_down_w"));
                sh(&mut env, &format!("s{s}_down_w"), &[ch, cin, 1, 1]);
                g.conv2d(h, wd, st, (0, 0), 1)
            } else {
                h
            };
            let sum = g.add(z, sc);
            h = g.relu(sum);
        }
        cin = ch;
    }
    let gap = g.global_avg_pool(h); // [1, 64]
    let w1 = g.weight("fc1_w");
    sh(&mut env, "fc1_w", &[64, 64]);
    let b1 = g.weight("fc1_b");
    sh(&mut env, "fc1_b", &[64]);
    let h2 = g.linear(gap, w1, b1);
    let h2 = g.relu(h2);
    let w2 = g.weight("fc2_w");
    sh(&mut env, "fc2_w", &[10, 64]);
    let b2 = g.weight("fc2_b");
    sh(&mut env, "fc2_b", &[10]);
    g.linear(h2, w2, b2);

    App { name: "ResNet-20", source_dsl: "MxNet", expr: g.finish(), shapes: env }
}

/// Transformer (PyTorch nn.Transformer, 6+6 layers, 256 features): 66
/// bare dense layers (enc: 4/layer; dec: 7/layer), attention internals as
/// `attention` ops (not nn.dense, so VTA never sees them — as in the
/// paper), layer norms throughout.
pub fn transformer() -> App {
    let t = 35usize;
    let d = 256usize;
    let mut g = GraphBuilder::new();
    let mut env = HashMap::new();
    let x = g.var("src");
    sh(&mut env, "src", &[t, d]);

    let mut dense_ct = 0usize;
    let mut mk_dense = |g: &mut GraphBuilder,
                        env: &mut HashMap<String, Shape>,
                        h: Id,
                        m: usize,
                        k: usize| {
        let name = format!("w{dense_ct}");
        dense_ct += 1;
        let w = g.weight(&name);
        sh(env, &name, &[m, k]);
        g.dense(h, w)
    };

    let self_attn = |g: &mut GraphBuilder,
                     env: &mut HashMap<String, Shape>,
                     mk: &mut dyn FnMut(
        &mut GraphBuilder,
        &mut HashMap<String, Shape>,
        Id,
        usize,
        usize,
    ) -> Id,
                     h: Id| {
        let qkv = mk(g, env, h, 3 * d, d); // in-proj (one dense)
        let q = g.expr.add(Op::SliceCols { lo: 0, hi: d }, vec![qkv]);
        let k = g.expr.add(Op::SliceCols { lo: d, hi: 2 * d }, vec![qkv]);
        let v = g.expr.add(Op::SliceCols { lo: 2 * d, hi: 3 * d }, vec![qkv]);
        let a = g.attention(q, k, v);
        mk(g, env, a, d, d) // out-proj
    };

    // encoder: 6 layers x (inproj + outproj + 2 ffn) = 24 dense
    let mut h = x;
    for _ in 0..6 {
        let a = self_attn(&mut g, &mut env, &mut mk_dense, h);
        let r = g.add(h, a);
        h = g.layer_norm(r);
        let f = mk_dense(&mut g, &mut env, h, 2 * d, d);
        let f = g.gelu(f);
        let f = mk_dense(&mut g, &mut env, f, d, 2 * d);
        let r = g.add(h, f);
        h = g.layer_norm(r);
    }
    let memory = h;

    // decoder: 6 layers x (self 2 + cross 3 + ffn 2) = 42 dense
    let tgt = g.var("tgt");
    sh(&mut env, "tgt", &[t, d]);
    let mut hd = tgt;
    for _ in 0..6 {
        let a = self_attn(&mut g, &mut env, &mut mk_dense, hd);
        let r = g.add(hd, a);
        hd = g.layer_norm(r);
        // cross attention: q from decoder, kv from encoder memory
        let q = mk_dense(&mut g, &mut env, hd, d, d);
        let kv = mk_dense(&mut g, &mut env, memory, 2 * d, d);
        let k = g.expr.add(Op::SliceCols { lo: 0, hi: d }, vec![kv]);
        let v = g.expr.add(Op::SliceCols { lo: d, hi: 2 * d }, vec![kv]);
        let a = g.attention(q, k, v);
        let a = mk_dense(&mut g, &mut env, a, d, d);
        let r = g.add(hd, a);
        hd = g.layer_norm(r);
        let f = mk_dense(&mut g, &mut env, hd, 2 * d, d);
        let f = g.gelu(f);
        let f = mk_dense(&mut g, &mut env, f, d, 2 * d);
        let r = g.add(hd, f);
        hd = g.layer_norm(r);
    }

    App { name: "Transformer", source_dsl: "PyTorch", expr: g.finish(), shapes: env }
}

/// All six applications, in the paper's column order.
pub fn all_apps() -> Vec<App> {
    vec![
        efficientnet(),
        lstm_wlm(),
        mobilenet_v2(),
        resmlp(),
        resnet20(),
        transformer(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::shape::infer;

    #[test]
    fn all_apps_shape_check() {
        for app in all_apps() {
            let shapes = infer(&app.expr, &app.shapes)
                .unwrap_or_else(|e| panic!("{}: {e}", app.name));
            assert!(!shapes.is_empty());
        }
    }

    #[test]
    fn conv_counts_match_paper() {
        let count_convs = |app: &App| {
            app.expr.count(|o| matches!(o, Op::Conv2d { groups: 1, .. }))
        };
        assert_eq!(count_convs(&efficientnet()), 35);
        assert_eq!(count_convs(&mobilenet_v2()), 40);
        assert_eq!(count_convs(&resnet20()), 21);
    }

    #[test]
    fn dense_counts_match_paper() {
        let count = |app: &App| app.expr.count(|o| matches!(o, Op::Dense));
        assert_eq!(count(&resmlp()), 38);
        assert_eq!(count(&transformer()), 66);
        assert_eq!(count(&lstm_wlm()), 36); // 35 gate denses + decoder
    }

    #[test]
    fn op_totals_in_relay_ballpark() {
        // the importer expands ops (batch norm, padding, etc.) that our
        // builders fold away, so totals differ; require same order of
        // magnitude
        for (app, paper) in all_apps().iter().zip([232, 578, 757, 343, 494, 872]) {
            let n = app.num_ops();
            assert!(
                n > paper / 8 && n < paper * 8,
                "{}: {n} ops vs paper {paper}",
                app.name
            );
        }
    }
}
