//! Emulated SoC: an MMIO bus with accelerators mapped into the address
//! space, plus an XSDK-style driver shim — our substitute for the paper's
//! Zynq ZCU102 FPGA deployment (§4.3.2).
//!
//! The D2A-generated command streams (from `codegen`) are played against
//! the bus exactly as the Xilinx SDK would issue them to the physical
//! accelerator interface; behind the bus sit the ILA models, so the
//! deployment path exercises the same formal semantics the compiler was
//! validated against.

pub mod driver;

use crate::ila::sim::IlaSim;
use crate::ila::{Cmd, IlaError};
use std::ops::Range;

/// One device on the bus: an ILA simulator claiming address ranges.
pub struct BusDevice {
    /// Device name (used by driver-side result read-out).
    pub name: String,
    /// Claimed MMIO address ranges.
    pub ranges: Vec<Range<u64>>,
    /// The device's ILA simulator.
    pub sim: IlaSim,
}

/// Bus-level errors.
#[derive(Debug, thiserror::Error)]
pub enum BusError {
    #[error("bus abort: no device claims address 0x{0:08X}")]
    NoDevice(u64),
    #[error("device `{dev}` fault: {err}")]
    Device { dev: String, err: IlaError },
    /// A read decoded to an instruction that produced no read-back data
    /// (e.g. reading a write-only register). The seed driver masked this
    /// by returning zeros, silently corrupting results downstream.
    #[error("read at 0x{0:08X} returned no data")]
    NoData(u64),
}

/// The MMIO interconnect.
#[derive(Default)]
pub struct Bus {
    devices: Vec<BusDevice>,
}

impl Bus {
    /// An empty bus with no devices attached.
    pub fn new() -> Self {
        Bus { devices: Vec::new() }
    }

    /// Map a device at the given address ranges.
    pub fn attach(&mut self, name: &str, ranges: Vec<Range<u64>>, sim: IlaSim) {
        self.devices.push(BusDevice { name: name.to_string(), ranges, sim });
    }

    /// Route one command to the claiming device.
    pub fn issue(&mut self, cmd: &Cmd) -> Result<Option<[u8; 16]>, BusError> {
        for dev in &mut self.devices {
            if dev.ranges.iter().any(|r| r.contains(&cmd.addr)) {
                return dev
                    .sim
                    .step(cmd)
                    .map_err(|err| BusError::Device { dev: dev.name.clone(), err });
            }
        }
        Err(BusError::NoDevice(cmd.addr))
    }

    /// Play a whole command stream; collect read-back data.
    pub fn run(&mut self, prog: &[Cmd]) -> Result<Vec<[u8; 16]>, BusError> {
        let mut out = Vec::new();
        for cmd in prog {
            if let Some(d) = self.issue(cmd)? {
                out.push(d);
            }
        }
        Ok(out)
    }

    /// Borrow a device's simulator by name (for result read-out).
    pub fn device_mut(&mut self, name: &str) -> Option<&mut IlaSim> {
        self.devices.iter_mut().find(|d| d.name == name).map(|d| &mut d.sim)
    }

    /// Total MMIO commands issued across all devices.
    pub fn total_steps(&self) -> u64 {
        self.devices.iter().map(|d| d.sim.steps).sum()
    }
}

/// Build the reference SoC: all three accelerators on one bus at their
/// documented address maps.
pub fn reference_soc() -> Bus {
    use crate::accel::{flexasr::model as fx, hlscnn::model as hx, vta::model as vx};
    use crate::accel::{Accelerator, FlexAsr, Hlscnn, Vta};
    let mut bus = Bus::new();
    bus.attach(
        "FlexASR",
        vec![
            fx::GB_BASE..fx::GB_BASE + fx::GB_SIZE as u64,
            fx::PE_WGT_BASE..fx::PE_WGT_BASE + fx::PE_WGT_SIZE as u64,
            fx::WGT_DRAM_BASE..fx::WGT_DRAM_BASE + fx::WGT_DRAM_SIZE as u64,
            0xA000_0000..0xA100_0000, // config/trigger/status/DMA block
        ],
        IlaSim::new(FlexAsr::new().build_ila()),
    );
    bus.attach(
        "HLSCNN",
        vec![hx::ACT_BASE..0xB040_0000, 0xB000_0000..0xB001_0000],
        IlaSim::new(Hlscnn::default().build_ila()),
    );
    bus.attach(
        "VTA",
        vec![vx::INP_BASE..0xC040_0000, 0xC000_0000..0xC001_0000],
        IlaSim::new(Vta::new().build_ila()),
    );
    bus
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::flexasr::model as fx;

    #[test]
    fn bus_routes_by_address() {
        let mut soc = reference_soc();
        // FlexASR config write lands on FlexASR
        soc.issue(&Cmd::write_u64(fx::CFG_ACT, 1)).unwrap();
        assert_eq!(soc.device_mut("FlexASR").unwrap().steps, 1);
        assert_eq!(soc.device_mut("VTA").unwrap().steps, 0);
    }

    #[test]
    fn unmapped_address_aborts() {
        let mut soc = reference_soc();
        assert!(matches!(
            soc.issue(&Cmd::write_u64(0xDEAD_0000, 0)),
            Err(BusError::NoDevice(_))
        ));
    }
}
