//! Shape inference over the IR.
//!
//! Used three ways: validating hand-built application graphs, powering the
//! e-graph's per-eclass shape analysis (which the shape-dependent rewrites
//! — dense+zero-add, im2col — consult), and sizing buffers in codegen.

use super::{Op, RecExpr};
use std::collections::HashMap;

/// Tensor shape.
pub type Shape = Vec<usize>;

/// Shape-inference failure.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum ShapeError {
    #[error("unknown input `{0}` (no shape provided)")]
    UnknownInput(String),
    #[error("rank mismatch at {op}: expected {expected}, got {got:?}")]
    Rank { op: String, expected: usize, got: Shape },
    #[error("dimension mismatch at {op}: {detail}")]
    Dim { op: String, detail: String },
}

fn rank_err(op: &Op, expected: usize, got: &[usize]) -> ShapeError {
    ShapeError::Rank { op: op.head(), expected, got: got.to_vec() }
}

fn dim_err(op: &Op, detail: impl Into<String>) -> ShapeError {
    ShapeError::Dim { op: op.head(), detail: detail.into() }
}

fn pool_out(op: &Op, dim: usize, w: usize, s: usize) -> Result<usize, ShapeError> {
    if dim < w {
        return Err(dim_err(op, format!("window {w} larger than dim {dim}")));
    }
    Ok((dim - w) / s + 1)
}

/// Infer the output shape of one operator from its children's shapes.
/// Leaves (`Var`/`Weight`) must be resolved by the caller via `env`.
pub fn infer_op(
    op: &Op,
    ch: &[&Shape],
    env: &HashMap<String, Shape>,
) -> Result<Shape, ShapeError> {
    use Op::*;
    let s = |i: usize| -> &Shape { ch[i] };
    match op {
        Var(name) | Weight(name) => env
            .get(name)
            .cloned()
            .ok_or_else(|| ShapeError::UnknownInput(name.clone())),
        ConstScalar(_) => Ok(vec![]),
        ZeroTensor(shape) => Ok(shape.clone()),

        Dense | VtaGemm => {
            let (x, w) = (s(0), s(1));
            if x.len() != 2 {
                return Err(rank_err(op, 2, x));
            }
            if w.len() != 2 {
                return Err(rank_err(op, 2, w));
            }
            if x[1] != w[1] {
                return Err(dim_err(op, format!("inner dims {} vs {}", x[1], w[1])));
            }
            Ok(vec![x[0], w[0]])
        }
        BiasAdd | Add | Mul | VtaAdd => {
            let (x, y) = (s(0), s(1));
            let ok = x == y
                || y.is_empty()
                || (y.len() == 1 && !x.is_empty() && *x.last().unwrap() == y[0]);
            if !ok {
                return Err(dim_err(op, format!("broadcast {x:?} vs {y:?}")));
            }
            Ok(x.clone())
        }
        Relu | Sigmoid | Tanh | Gelu | Softmax | LayerNorm | FlexLayerNorm
        | FlexMaxpStore | FlexMaxpLoad => Ok(s(0).clone()),

        Reshape(shape) => {
            let n: usize = s(0).iter().product();
            let m: usize = shape.iter().product();
            if n != m {
                return Err(dim_err(op, format!("{:?} -> {shape:?}", s(0))));
            }
            Ok(shape.clone())
        }
        Transpose => {
            let x = s(0);
            if x.len() != 2 {
                return Err(rank_err(op, 2, x));
            }
            Ok(vec![x[1], x[0]])
        }
        Concat => {
            let (x, y) = (s(0), s(1));
            if x.len() != 2 || y.len() != 2 || x[0] != y[0] {
                return Err(dim_err(op, format!("{x:?} ++ {y:?}")));
            }
            Ok(vec![x[0], x[1] + y[1]])
        }
        Conv2d { stride, pad, groups } => {
            let (x, w) = (s(0), s(1));
            if x.len() != 4 {
                return Err(rank_err(op, 4, x));
            }
            if w.len() != 4 {
                return Err(rank_err(op, 4, w));
            }
            if x[1] != w[1] * groups {
                return Err(dim_err(
                    op,
                    format!("channels {} vs {}*{groups}", x[1], w[1]),
                ));
            }
            let oh = (x[2] + 2 * pad.0).checked_sub(w[2]).map(|d| d / stride.0 + 1);
            let ow = (x[3] + 2 * pad.1).checked_sub(w[3]).map(|d| d / stride.1 + 1);
            match (oh, ow) {
                (Some(oh), Some(ow)) => Ok(vec![x[0], w[0], oh, ow]),
                _ => Err(dim_err(op, "kernel larger than padded input")),
            }
        }
        HlscnnConv2d { stride, pad } => infer_op(
            &Conv2d { stride: *stride, pad: *pad, groups: 1 },
            ch,
            env,
        ),
        MaxPool2d { window, stride } | AvgPool2d { window, stride } => {
            let x = s(0);
            if x.len() != 4 {
                return Err(rank_err(op, 4, x));
            }
            Ok(vec![
                x[0],
                x[1],
                pool_out(op, x[2], window.0, stride.0)?,
                pool_out(op, x[3], window.1, stride.1)?,
            ])
        }
        GlobalAvgPool => {
            let x = s(0);
            if x.len() != 4 {
                return Err(rank_err(op, 4, x));
            }
            Ok(vec![x[0], x[1]])
        }
        MatMaxPool { window, stride } | MatMeanPool { window, stride } => {
            let x = s(0);
            if x.len() != 2 {
                return Err(rank_err(op, 2, x));
            }
            Ok(vec![
                pool_out(op, x[0], window.0, stride.0)?,
                pool_out(op, x[1], window.1, stride.1)?,
            ])
        }
        WindowsFlatten { window, stride } => {
            let x = s(0);
            if x.len() != 2 {
                return Err(rank_err(op, 2, x));
            }
            let or = pool_out(op, x[0], window.0, stride.0)?;
            let oc = pool_out(op, x[1], window.1, stride.1)?;
            Ok(vec![window.0 * window.1, or * oc])
        }
        TempMaxPool | TempMeanPool | FlexMaxpool | FlexMeanpool => {
            let x = s(0);
            if x.len() != 2 {
                return Err(rank_err(op, 2, x));
            }
            if x[0] % 2 != 0 {
                return Err(dim_err(op, format!("odd row count {}", x[0])));
            }
            Ok(vec![x[0] / 2, x[1]])
        }
        Im2col { kernel, stride, pad } => {
            let x = s(0);
            if x.len() != 4 {
                return Err(rank_err(op, 4, x));
            }
            let oh = pool_out(op, x[2] + 2 * pad.0, kernel.0, stride.0)?;
            let ow = pool_out(op, x[3] + 2 * pad.1, kernel.1, stride.1)?;
            Ok(vec![x[0] * oh * ow, x[1] * kernel.0 * kernel.1])
        }
        FromIm2col { n, oh, ow } => {
            let x = s(0);
            if x.len() != 2 {
                return Err(rank_err(op, 2, x));
            }
            if x[0] != n * oh * ow {
                return Err(dim_err(op, format!("rows {} != {n}*{oh}*{ow}", x[0])));
            }
            Ok(vec![*n, x[1], *oh, *ow])
        }
        SliceStep { t } => {
            let x = s(0);
            if x.len() != 3 {
                return Err(rank_err(op, 3, x));
            }
            if *t >= x[0] {
                return Err(dim_err(op, format!("step {t} out of {} steps", x[0])));
            }
            Ok(vec![x[1], x[2]])
        }
        SliceCols { lo, hi } => {
            let x = s(0);
            if x.len() != 2 {
                return Err(rank_err(op, 2, x));
            }
            if *lo >= *hi || *hi > x[1] {
                return Err(dim_err(op, format!("cols {lo}..{hi} of {}", x[1])));
            }
            Ok(vec![x[0], hi - lo])
        }
        ConcatRows => {
            let (x, y) = (s(0), s(1));
            if x.len() != 2 || y.len() != 2 || x[1] != y[1] {
                return Err(dim_err(op, format!("{x:?} vcat {y:?}")));
            }
            Ok(vec![x[0] + y[0], x[1]])
        }
        FlexLstmFused { steps } => {
            let (x, w, b) = (s(0), s(1), s(2));
            if x.len() != 3 || w.len() != 2 || b.len() != 1 {
                return Err(dim_err(op, "fused-lstm operand ranks"));
            }
            if x[0] != *steps {
                return Err(dim_err(op, "T != steps"));
            }
            let four_h = w[0];
            if four_h % 4 != 0 || b[0] != four_h {
                return Err(dim_err(op, "gate dims"));
            }
            let h = four_h / 4;
            if w[1] != x[2] + h {
                return Err(dim_err(op, "fused K must be E + H"));
            }
            Ok(vec![x[0], x[1], h])
        }
        Lstm { steps } | FlexLstm { steps } => {
            let (x, w_ih, w_hh, b) = (s(0), s(1), s(2), s(3));
            if x.len() != 3 {
                return Err(rank_err(op, 3, x));
            }
            if x[0] != *steps {
                return Err(dim_err(op, format!("T {} != steps {steps}", x[0])));
            }
            let h = w_hh[1];
            if w_ih.len() != 2 || w_hh.len() != 2 || b.len() != 1 {
                return Err(dim_err(op, "weight ranks"));
            }
            if w_ih[0] != 4 * h || w_hh[0] != 4 * h || b[0] != 4 * h {
                return Err(dim_err(op, "gate dims must be 4*hidden"));
            }
            if w_ih[1] != x[2] {
                return Err(dim_err(op, "input dim mismatch"));
            }
            Ok(vec![x[0], x[1], h])
        }
        Attention | FlexAttention => {
            let (q, k, v) = (s(0), s(1), s(2));
            if q.len() != 2 || k.len() != 2 || v.len() != 2 {
                return Err(dim_err(op, "attention operands must be 2-D"));
            }
            if q[1] != k[1] || k[0] != v[0] {
                return Err(dim_err(op, format!("q{q:?} k{k:?} v{v:?}")));
            }
            Ok(vec![q[0], v[1]])
        }
        FlexLinear => {
            let (x, w, b) = (s(0), s(1), s(2));
            if x.len() != 2 || w.len() != 2 || b.len() != 1 {
                return Err(dim_err(op, "linear operand ranks"));
            }
            if x[1] != w[1] || b[0] != w[0] {
                return Err(dim_err(op, format!("x{x:?} w{w:?} b{b:?}")));
            }
            Ok(vec![x[0], w[0]])
        }
    }
}

/// Infer shapes for every node of a program. `env` maps `Var`/`Weight`
/// names to their shapes.
pub fn infer(
    expr: &RecExpr,
    env: &HashMap<String, Shape>,
) -> Result<Vec<Shape>, ShapeError> {
    let mut out: Vec<Shape> = Vec::with_capacity(expr.len());
    for node in &expr.nodes {
        let ch: Vec<&Shape> = node.children.iter().map(|&c| &out[c]).collect();
        out.push(infer_op(&node.op, &ch, env)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    fn env(pairs: &[(&str, &[usize])]) -> HashMap<String, Shape> {
        pairs.iter().map(|(n, s)| (n.to_string(), s.to_vec())).collect()
    }

    #[test]
    fn linear_shapes() {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        let b = g.weight("b");
        g.linear(x, w, b);
        let shapes = infer(
            &g.finish(),
            &env(&[("x", &[4, 16]), ("w", &[8, 16]), ("b", &[8])]),
        )
        .unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![4, 8]);
    }

    #[test]
    fn conv_shapes_with_padding() {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        g.conv2d(x, w, (2, 2), (1, 1), 1);
        let shapes = infer(
            &g.finish(),
            &env(&[("x", &[1, 3, 32, 32]), ("w", &[16, 3, 3, 3])]),
        )
        .unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![1, 16, 16, 16]);
    }

    #[test]
    fn windows_flatten_then_tempmax_reduces() {
        use crate::ir::{Op, RecExpr};
        let mut e = RecExpr::new();
        let x = e.add(Op::Var("t".into()), vec![]);
        let wf = e.add(
            Op::WindowsFlatten { window: (4, 4), stride: (2, 2) },
            vec![x],
        );
        let m1 = e.add(Op::TempMaxPool, vec![wf]);
        let m2 = e.add(Op::TempMaxPool, vec![m1]);
        let m3 = e.add(Op::TempMaxPool, vec![m2]);
        let m4 = e.add(Op::TempMaxPool, vec![m3]);
        let _r = e.add(Op::Reshape(vec![63, 63]), vec![m4]);
        let shapes = infer(&e, &env(&[("t", &[128, 128])])).unwrap();
        assert_eq!(shapes[wf], vec![16, 63 * 63]);
        assert_eq!(shapes[m4], vec![1, 63 * 63]);
        assert_eq!(shapes.last().unwrap(), &vec![63, 63]);
    }

    #[test]
    fn mismatch_reported() {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        g.dense(x, w);
        let err =
            infer(&g.finish(), &env(&[("x", &[4, 16]), ("w", &[8, 17])])).unwrap_err();
        assert!(matches!(err, ShapeError::Dim { .. }));
    }

    #[test]
    fn lstm_shape() {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let wi = g.weight("wi");
        let wh = g.weight("wh");
        let b = g.weight("b");
        g.lstm(x, wi, wh, b, 35);
        let shapes = infer(
            &g.finish(),
            &env(&[
                ("x", &[35, 1, 64]),
                ("wi", &[256, 64]),
                ("wh", &[256, 64]),
                ("b", &[256]),
            ]),
        )
        .unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![35, 1, 64]);
    }
}
