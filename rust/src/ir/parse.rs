//! S-expression printing and parsing for IR programs.
//!
//! The textual form mirrors the paper's listings, e.g.
//! `(bias_add (nn_dense %a $w) $b)`. Printing expands the term DAG into a
//! tree (fine for the fragment-sized terms in docs, tests, and the
//! examples); parsing rebuilds a RecExpr with hash-consing so shared
//! subterms collapse back into one node.

use super::{Id, Op, RecExpr};
use std::collections::HashMap;
use std::fmt::Write as _;

/// Render a program as an s-expression (tree-expanded).
pub fn to_sexpr(expr: &RecExpr) -> String {
    fn go(expr: &RecExpr, id: Id, out: &mut String) {
        let node = &expr.nodes[id];
        if node.children.is_empty() {
            let _ = write!(out, "{}", node.op.head());
            return;
        }
        let _ = write!(out, "({}", node.op.head());
        for &c in &node.children {
            out.push(' ');
            go(expr, c, out);
        }
        out.push(')');
    }
    let mut s = String::new();
    go(expr, expr.root(), &mut s);
    s
}

/// Parse failure.
#[derive(Debug, thiserror::Error)]
pub enum ParseError {
    #[error("unexpected end of input")]
    Eof,
    #[error("unexpected token `{0}`")]
    Unexpected(String),
    #[error("unknown operator `{0}`")]
    UnknownOp(String),
    #[error("operator `{0}` expects {1} children, got {2}")]
    Arity(String, usize, usize),
}

/// Parse an s-expression back into a RecExpr (hash-consed).
pub fn parse_sexpr(src: &str) -> Result<RecExpr, ParseError> {
    let tokens = tokenize(src);
    let mut pos = 0usize;
    let mut expr = RecExpr::new();
    let mut memo: HashMap<(Op, Vec<Id>), Id> = HashMap::new();
    let root = parse_term(&tokens, &mut pos, &mut expr, &mut memo)?;
    if pos != tokens.len() {
        return Err(ParseError::Unexpected(tokens[pos].clone()));
    }
    // ensure root is last
    if root != expr.root() {
        // re-add a copy of the root node at the end
        let node = expr.nodes[root].clone();
        expr.nodes.push(node);
    }
    Ok(expr)
}

fn tokenize(src: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    // inside <...> or [...] head parameters, whitespace and parens are
    // part of the token
    let mut depth_angle = 0i32;
    for ch in src.chars() {
        match ch {
            '<' | '[' => {
                depth_angle += 1;
                cur.push(ch);
            }
            '>' | ']' => {
                depth_angle -= 1;
                cur.push(ch);
            }
            '(' | ')' if depth_angle == 0 => {
                if !cur.is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
                out.push(ch.to_string());
            }
            c if c.is_whitespace() && depth_angle == 0 => {
                if !cur.is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
            }
            c => cur.push(c),
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

fn parse_term(
    tokens: &[String],
    pos: &mut usize,
    expr: &mut RecExpr,
    memo: &mut HashMap<(Op, Vec<Id>), Id>,
) -> Result<Id, ParseError> {
    let tok = tokens.get(*pos).ok_or(ParseError::Eof)?.clone();
    *pos += 1;
    if tok == "(" {
        let head = tokens.get(*pos).ok_or(ParseError::Eof)?.clone();
        *pos += 1;
        let op = op_from_head(&head)?;
        let mut children = Vec::new();
        while tokens.get(*pos).map(|t| t.as_str()) != Some(")") {
            children.push(parse_term(tokens, pos, expr, memo)?);
        }
        *pos += 1; // consume ')'
        if children.len() != op.arity() {
            return Err(ParseError::Arity(head, op.arity(), children.len()));
        }
        Ok(intern(expr, memo, op, children))
    } else if tok == ")" {
        Err(ParseError::Unexpected(tok))
    } else {
        let op = op_from_head(&tok)?;
        if op.arity() != 0 {
            return Err(ParseError::Arity(tok, op.arity(), 0));
        }
        Ok(intern(expr, memo, op, vec![]))
    }
}

fn intern(
    expr: &mut RecExpr,
    memo: &mut HashMap<(Op, Vec<Id>), Id>,
    op: Op,
    children: Vec<Id>,
) -> Id {
    if let Some(&id) = memo.get(&(op.clone(), children.clone())) {
        return id;
    }
    let id = expr.add(op.clone(), children.clone());
    memo.insert((op, children), id);
    id
}

/// Parse a `(a, b)` pair of usizes from a head-parameter substring.
fn parse_pair(s: &str) -> Option<(usize, usize)> {
    let s = s.trim().trim_start_matches('(').trim_end_matches(')');
    let mut it = s.split(',').map(|p| p.trim().parse::<usize>().ok());
    Some((it.next()??, it.next()??))
}

/// Split `head<params>` into (name, params).
fn split_params(head: &str) -> (&str, Option<&str>) {
    match head.find('<') {
        Some(i) if head.ends_with('>') => (&head[..i], Some(&head[i + 1..head.len() - 1])),
        _ => (head, None),
    }
}

/// Split a params string on top-level commas (commas inside parens stay).
fn top_level_split(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '(' => {
                depth += 1;
                cur.push(c);
            }
            ')' => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 => out.push(std::mem::take(&mut cur)),
            c => cur.push(c),
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

fn op_from_head(head: &str) -> Result<Op, ParseError> {
    if let Some(name) = head.strip_prefix('%') {
        return Ok(Op::Var(name.to_string()));
    }
    if let Some(name) = head.strip_prefix('$') {
        return Ok(Op::Weight(name.to_string()));
    }
    if let Ok(v) = head.parse::<f32>() {
        return Ok(Op::ConstScalar(v.to_bits()));
    }
    let (name, params) = split_params(head);
    let two_pairs = || -> Option<((usize, usize), (usize, usize))> {
        let parts = top_level_split(params?);
        Some((parse_pair(parts.first()?)?, parse_pair(parts.get(1)?)?))
    };
    let op = match name {
        "nn_dense" => Op::Dense,
        "bias_add" => Op::BiasAdd,
        "add" => Op::Add,
        "mul" => Op::Mul,
        "relu" => Op::Relu,
        "sigmoid" => Op::Sigmoid,
        "tanh" => Op::Tanh,
        "gelu" => Op::Gelu,
        "softmax" => Op::Softmax,
        "layer_norm" => Op::LayerNorm,
        "transpose" => Op::Transpose,
        "concat" => Op::Concat,
        "global_avg_pool" => Op::GlobalAvgPool,
        "temp_maxpool" => Op::TempMaxPool,
        "temp_meanpool" => Op::TempMeanPool,
        "attention" => Op::Attention,
        "fasr_linear" => Op::FlexLinear,
        "fasr_layernorm" => Op::FlexLayerNorm,
        "fasr_maxpool" => Op::FlexMaxpool,
        "fasr_meanpool" => Op::FlexMeanpool,
        "fasr_attention" => Op::FlexAttention,
        "fasr_maxp_store" => Op::FlexMaxpStore,
        "fasr_maxp_load" => Op::FlexMaxpLoad,
        "vta_gemm" => Op::VtaGemm,
        "vta_add" => Op::VtaAdd,
        "mat_maxpool" => {
            let (w, s) = two_pairs().ok_or_else(|| ParseError::UnknownOp(head.into()))?;
            Op::MatMaxPool { window: w, stride: s }
        }
        "mat_meanpool" => {
            let (w, s) = two_pairs().ok_or_else(|| ParseError::UnknownOp(head.into()))?;
            Op::MatMeanPool { window: w, stride: s }
        }
        "windows_flatten" => {
            let (w, s) = two_pairs().ok_or_else(|| ParseError::UnknownOp(head.into()))?;
            Op::WindowsFlatten { window: w, stride: s }
        }
        "max_pool2d" => {
            let (w, s) = two_pairs().ok_or_else(|| ParseError::UnknownOp(head.into()))?;
            Op::MaxPool2d { window: w, stride: s }
        }
        "avg_pool2d" => {
            let (w, s) = two_pairs().ok_or_else(|| ParseError::UnknownOp(head.into()))?;
            Op::AvgPool2d { window: w, stride: s }
        }
        "nn_lstm" => {
            let steps = params
                .and_then(|p| p.trim().parse::<usize>().ok())
                .ok_or_else(|| ParseError::UnknownOp(head.into()))?;
            Op::Lstm { steps }
        }
        "fasr_lstm" => {
            let steps = params
                .and_then(|p| p.trim().parse::<usize>().ok())
                .ok_or_else(|| ParseError::UnknownOp(head.into()))?;
            Op::FlexLstm { steps }
        }
        _ => {
            // reshape[2, 3] / zeros[2, 3]
            if let Some(rest) = head.strip_prefix("reshape[") {
                let dims = parse_dims(rest)?;
                return Ok(Op::Reshape(dims));
            }
            if let Some(rest) = head.strip_prefix("zeros[") {
                let dims = parse_dims(rest)?;
                return Ok(Op::ZeroTensor(dims));
            }
            return Err(ParseError::UnknownOp(head.to_string()));
        }
    };
    Ok(op)
}

fn parse_dims(rest: &str) -> Result<Vec<usize>, ParseError> {
    let inner = rest.trim_end_matches(']');
    if inner.trim().is_empty() {
        return Ok(vec![]);
    }
    inner
        .split(',')
        .map(|d| {
            d.trim()
                .parse::<usize>()
                .map_err(|_| ParseError::UnknownOp(format!("[{inner}]")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    #[test]
    fn roundtrip_linear() {
        let mut g = GraphBuilder::new();
        let x = g.var("a");
        let w = g.weight("w0");
        let b = g.weight("b0");
        g.linear(x, w, b);
        let e = g.finish();
        let s = to_sexpr(&e);
        assert_eq!(s, "(bias_add (nn_dense %a $w0) $b0)");
        let back = parse_sexpr(&s).unwrap();
        assert_eq!(to_sexpr(&back), s);
    }

    #[test]
    fn roundtrip_parameterized_heads() {
        let cases = [
            "(mat_maxpool<(4, 4),(2, 2)> %t)",
            "(windows_flatten<(2, 1),(2, 1)> %t)",
            "(fasr_maxp_load (fasr_maxpool (fasr_maxp_store %t)))",
            "(reshape[63, 63] (temp_maxpool %t))",
            "(fasr_lstm<35> %x $wi $wh $b)",
        ];
        for c in cases {
            let e = parse_sexpr(c).unwrap();
            assert_eq!(to_sexpr(&e), c, "roundtrip failed for {c}");
        }
    }

    #[test]
    fn sharing_is_hash_consed() {
        // (add (nn_dense %a $w) (nn_dense %a $w)) — dense appears once
        let e = parse_sexpr("(add (nn_dense %a $w) (nn_dense %a $w))").unwrap();
        let denses = e.count(|op| matches!(op, Op::Dense));
        assert_eq!(denses, 1, "shared subterm must be interned once");
    }

    #[test]
    fn arity_errors() {
        assert!(matches!(
            parse_sexpr("(nn_dense %a)"),
            Err(ParseError::Arity(_, 2, 1))
        ));
    }

    #[test]
    fn unknown_op_errors() {
        assert!(matches!(
            parse_sexpr("(frobnicate %a)"),
            Err(ParseError::UnknownOp(_))
        ));
    }
}
