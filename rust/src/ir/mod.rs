//! The compiler IR — a pure (side-effect-free) tensor program
//! representation in the spirit of Relay/Glenside.
//!
//! Programs are *RecExprs*: arrays of operator nodes whose children are
//! indices into the same array (a DAG in term form). The same [`Op`]
//! vocabulary is shared by the e-graph (`crate::egraph`), the f32
//! interpreter ([`interp`], the "IR interpreter" reference of §4.4), and
//! code generation. Accelerator operators (`Flex*`, `Hlscnn*`, `Vta*`) are
//! first-class IR nodes — the product of IR-accelerator rewrites — whose
//! *f32 semantics* equal their IR counterparts; their *numeric* semantics
//! (AdaptivFloat / fixed-point / int8) live in the ILA models and take over
//! during co-simulation.

pub mod interp;
pub mod parse;
pub mod shape;

use std::fmt;

/// Index of a node within a [`RecExpr`] (or an e-class id inside the
/// e-graph — the two spaces are kept deliberately interchangeable).
pub type Id = usize;

/// Which accelerator an operator belongs to (for invocation counting and
/// codegen dispatch).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Target {
    Host,
    FlexAsr,
    Hlscnn,
    Vta,
}

impl Target {
    /// Number of `Target` variants — the size of dense target-indexed
    /// tables (see `session::AcceleratorRegistry`).
    pub const COUNT: usize = 4;

    /// Dense index of this target, for O(1) dispatch tables.
    pub fn index(self) -> usize {
        match self {
            Target::Host => 0,
            Target::FlexAsr => 1,
            Target::Hlscnn => 2,
            Target::Vta => 3,
        }
    }
}

impl fmt::Display for Target {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Target::Host => write!(f, "host"),
            Target::FlexAsr => write!(f, "FlexASR"),
            Target::Hlscnn => write!(f, "HLSCNN"),
            Target::Vta => write!(f, "VTA"),
        }
    }
}

/// Operator vocabulary. Parameters (shapes, windows, strides) are part of
/// the operator label, never of the child list, so the e-graph can hash
/// and unify nodes structurally.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Op {
    // ----- leaves ---------------------------------------------------
    /// Named input tensor (activations).
    Var(String),
    /// Named parameter tensor (weights); distinguished from `Var` so cost
    /// functions and codegen can treat constants specially.
    Weight(String),
    /// Scalar constant (f32 bits, for Eq/Hash).
    ConstScalar(u32),
    /// All-zero tensor of a known shape (introduced by the `dense ->
    /// dense + 0` flexible-matching rewrite).
    ZeroTensor(Vec<usize>),

    // ----- generic tensor ops ---------------------------------------
    /// `dense(x, w) = x @ w^T` — Relay `nn.dense`.
    Dense,
    /// `bias_add(x, b)` — broadcast add along the trailing axis.
    BiasAdd,
    /// Elementwise/broadcast addition.
    Add,
    /// Elementwise/broadcast multiplication.
    Mul,
    Relu,
    Sigmoid,
    Tanh,
    Gelu,
    /// Softmax over the trailing axis.
    Softmax,
    /// LayerNorm over the trailing axis (eps folded into semantics).
    LayerNorm,
    /// Reshape to an explicit shape.
    Reshape(Vec<usize>),
    /// 2-D matrix transpose.
    Transpose,
    /// Concatenate two matrices along axis 1.
    Concat,
    /// NCHW convolution, OIHW weights.
    Conv2d { stride: (usize, usize), pad: (usize, usize), groups: usize },
    /// NCHW max pooling.
    MaxPool2d { window: (usize, usize), stride: (usize, usize) },
    /// NCHW average pooling.
    AvgPool2d { window: (usize, usize), stride: (usize, usize) },
    /// Global average pooling over H, W: [N, C, H, W] -> [N, C].
    GlobalAvgPool,
    /// Matrix (2-D) max pooling — the Glenside
    /// `map reduceMax (windows ...)` form of §5.1.
    MatMaxPool { window: (usize, usize), stride: (usize, usize) },
    /// Matrix (2-D) mean pooling.
    MatMeanPool { window: (usize, usize), stride: (usize, usize) },
    /// Unfold a matrix into flattened windows: `[R, C] ->
    /// [wh*ww, n_windows]`; each *column* is one window, rows are the
    /// within-window positions (so pairwise-row-max reduces windows).
    WindowsFlatten { window: (usize, usize), stride: (usize, usize) },
    /// Temporal max pool: pairwise max of adjacent rows,
    /// `[2k, C] -> [k, C]` — exactly FlexASR's supported maxpool.
    TempMaxPool,
    /// Temporal mean pool: pairwise mean of adjacent rows.
    TempMeanPool,
    /// im2col patch extraction (kernel/stride/pad recorded).
    Im2col { kernel: (usize, usize), stride: (usize, usize), pad: (usize, usize) },
    /// Rearrange a GEMM result `[N*OH*OW, O]` back to NCHW.
    FromIm2col { n: usize, oh: usize, ow: usize },
    /// Unrolled LSTM over `[T, N, I]` (sequence output only; Appendix B).
    Lstm { steps: usize },
    /// Single-head scaled dot-product attention (q, k, v).
    Attention,
    /// Take timestep `t` of a `[T, N, E]` sequence -> `[N, E]` (the
    /// importer's per-step `take` in the unrolled LSTM).
    SliceStep { t: usize },
    /// Column slice `[.., lo..hi)` of a matrix (gate extraction in the
    /// unrolled LSTM).
    SliceCols { lo: usize, hi: usize },
    /// Concatenate two matrices along axis 0 (rows).
    ConcatRows,

    // ----- FlexASR accelerator ops ----------------------------------
    /// Linear layer `x @ w^T + b` on the FlexASR PE array (AdaptivFloat).
    FlexLinear,
    /// Full LSTM layer — one ILA instruction regardless of step count
    /// (the dramatic granularity mismatch of Table 1).
    FlexLstm { steps: usize },
    /// LSTM layer with the fused gate matrix `w = [w_ih | w_hh]` (the
    /// concat formulation the unrolled-LSTM rewrite produces):
    /// children (x, w, b).
    FlexLstmFused { steps: usize },
    FlexLayerNorm,
    /// Temporal max pooling on FlexASR.
    FlexMaxpool,
    FlexMeanpool,
    FlexAttention,
    /// Explicit data movement into FlexASR's global buffer (§5.1).
    FlexMaxpStore,
    /// Explicit data movement out of FlexASR's global buffer (§5.1).
    FlexMaxpLoad,

    // ----- HLSCNN accelerator ops -----------------------------------
    /// Non-grouped 2-D convolution on HLSCNN (8/16-bit fixed point).
    HlscnnConv2d { stride: (usize, usize), pad: (usize, usize) },

    // ----- VTA accelerator ops --------------------------------------
    /// GEMM on VTA's int8 matrix core (dense semantics: x @ w^T).
    VtaGemm,
    /// Elementwise add on VTA's ALU.
    VtaAdd,
}

impl Op {
    /// Number of children each operator expects.
    pub fn arity(&self) -> usize {
        use Op::*;
        match self {
            Var(_) | Weight(_) | ConstScalar(_) | ZeroTensor(_) => 0,
            Relu | Sigmoid | Tanh | Gelu | Softmax | LayerNorm | Reshape(_)
            | Transpose | MaxPool2d { .. } | AvgPool2d { .. } | GlobalAvgPool
            | MatMaxPool { .. } | MatMeanPool { .. } | WindowsFlatten { .. }
            | TempMaxPool | TempMeanPool | Im2col { .. } | FromIm2col { .. }
            | SliceStep { .. } | SliceCols { .. }
            | FlexLayerNorm | FlexMaxpool | FlexMeanpool | FlexMaxpStore
            | FlexMaxpLoad => 1,
            Dense | BiasAdd | Add | Mul | Concat | ConcatRows | Conv2d { .. }
            | HlscnnConv2d { .. } | VtaGemm | VtaAdd => 2,
            FlexLinear | Attention | FlexAttention | FlexLstmFused { .. } => 3,
            Lstm { .. } | FlexLstm { .. } => 4,
        }
    }

    /// Which platform executes this operator.
    pub fn target(&self) -> Target {
        use Op::*;
        match self {
            FlexLinear | FlexLstm { .. } | FlexLstmFused { .. } | FlexLayerNorm | FlexMaxpool
            | FlexMeanpool | FlexAttention | FlexMaxpStore | FlexMaxpLoad => {
                Target::FlexAsr
            }
            HlscnnConv2d { .. } => Target::Hlscnn,
            VtaGemm | VtaAdd => Target::Vta,
            _ => Target::Host,
        }
    }

    /// True for accelerator *compute* invocations (data movement ops are
    /// not counted as invocations in Table 1).
    pub fn is_accel_invocation(&self) -> bool {
        self.target() != Target::Host
            && !matches!(self, Op::FlexMaxpStore | Op::FlexMaxpLoad)
    }

    /// S-expression head symbol.
    pub fn head(&self) -> String {
        use Op::*;
        match self {
            Var(s) => format!("%{s}"),
            Weight(s) => format!("${s}"),
            ConstScalar(b) => format!("{}", f32::from_bits(*b)),
            ZeroTensor(s) => format!("zeros{s:?}"),
            Dense => "nn_dense".into(),
            BiasAdd => "bias_add".into(),
            Add => "add".into(),
            Mul => "mul".into(),
            Relu => "relu".into(),
            Sigmoid => "sigmoid".into(),
            Tanh => "tanh".into(),
            Gelu => "gelu".into(),
            Softmax => "softmax".into(),
            LayerNorm => "layer_norm".into(),
            Reshape(s) => format!("reshape{s:?}"),
            Transpose => "transpose".into(),
            Concat => "concat".into(),
            Conv2d { stride, pad, groups } => {
                format!("nn_conv2d<s{stride:?},p{pad:?},g{groups}>")
            }
            MaxPool2d { window, stride } => format!("max_pool2d<{window:?},{stride:?}>"),
            AvgPool2d { window, stride } => format!("avg_pool2d<{window:?},{stride:?}>"),
            GlobalAvgPool => "global_avg_pool".into(),
            MatMaxPool { window, stride } => format!("mat_maxpool<{window:?},{stride:?}>"),
            MatMeanPool { window, stride } => {
                format!("mat_meanpool<{window:?},{stride:?}>")
            }
            WindowsFlatten { window, stride } => {
                format!("windows_flatten<{window:?},{stride:?}>")
            }
            TempMaxPool => "temp_maxpool".into(),
            TempMeanPool => "temp_meanpool".into(),
            Im2col { kernel, stride, pad } => {
                format!("im2col<{kernel:?},{stride:?},{pad:?}>")
            }
            FromIm2col { n, oh, ow } => format!("from_im2col<{n},{oh},{ow}>"),
            Lstm { steps } => format!("nn_lstm<{steps}>"),
            Attention => "attention".into(),
            SliceStep { t } => format!("slice_step<{t}>"),
            SliceCols { lo, hi } => format!("slice_cols<{lo},{hi}>"),
            ConcatRows => "concat_rows".into(),
            FlexLinear => "fasr_linear".into(),
            FlexLstm { steps } => format!("fasr_lstm<{steps}>"),
            FlexLstmFused { steps } => format!("fasr_lstm_fused<{steps}>"),
            FlexLayerNorm => "fasr_layernorm".into(),
            FlexMaxpool => "fasr_maxpool".into(),
            FlexMeanpool => "fasr_meanpool".into(),
            FlexAttention => "fasr_attention".into(),
            FlexMaxpStore => "fasr_maxp_store".into(),
            FlexMaxpLoad => "fasr_maxp_load".into(),
            HlscnnConv2d { stride, pad } => format!("hlscnn_conv2d<s{stride:?},p{pad:?}>"),
            VtaGemm => "vta_gemm".into(),
            VtaAdd => "vta_add".into(),
        }
    }
}

/// One node: operator + children.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Node {
    /// The operator.
    pub op: Op,
    /// Child node indices.
    pub children: Vec<Id>,
}

impl Node {
    /// Construct a node, checking arity.
    pub fn new(op: Op, children: Vec<Id>) -> Self {
        debug_assert_eq!(
            op.arity(),
            children.len(),
            "arity mismatch for {:?}",
            op
        );
        Node { op, children }
    }
}

/// A term-DAG program: nodes in topological order (children precede
/// parents); the last node is the root.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RecExpr {
    /// Nodes in topological order; the last is the root.
    pub nodes: Vec<Node>,
}

impl RecExpr {
    /// Empty program.
    pub fn new() -> Self {
        RecExpr { nodes: Vec::new() }
    }

    /// Append a node; children must already be present.
    pub fn add(&mut self, op: Op, children: Vec<Id>) -> Id {
        for &c in &children {
            assert!(c < self.nodes.len(), "child {c} out of range");
        }
        self.nodes.push(Node::new(op, children));
        self.nodes.len() - 1
    }

    /// Root node id (the last node).
    pub fn root(&self) -> Id {
        assert!(!self.nodes.is_empty(), "empty RecExpr has no root");
        self.nodes.len() - 1
    }

    /// Total number of nodes (the "#Relay ops" proxy of Table 1 Row 3).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when the program has no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Count operator nodes matching a predicate.
    pub fn count(&self, pred: impl Fn(&Op) -> bool) -> usize {
        self.nodes.iter().filter(|n| pred(&n.op)).count()
    }

    /// Count accelerator invocations per target — the Table 1 metric.
    pub fn invocations(&self, target: Target) -> usize {
        self.count(|op| op.target() == target && op.is_accel_invocation())
    }

    /// Names of all `Var` leaves.
    pub fn var_names(&self) -> Vec<String> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                Op::Var(s) => Some(s.clone()),
                _ => None,
            })
            .collect()
    }
}

/// Convenience builder for writing application graphs by hand.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    /// The expression under construction.
    pub expr: RecExpr,
}

impl GraphBuilder {
    /// An empty graph.
    pub fn new() -> Self {
        GraphBuilder { expr: RecExpr::new() }
    }

    /// A named input leaf.
    pub fn var(&mut self, name: &str) -> Id {
        self.expr.add(Op::Var(name.to_string()), vec![])
    }

    /// A named weight leaf.
    pub fn weight(&mut self, name: &str) -> Id {
        self.expr.add(Op::Weight(name.to_string()), vec![])
    }

    /// `nn.dense` (x @ w^T).
    pub fn dense(&mut self, x: Id, w: Id) -> Id {
        self.expr.add(Op::Dense, vec![x, w])
    }

    /// Broadcasting bias add.
    pub fn bias_add(&mut self, x: Id, b: Id) -> Id {
        self.expr.add(Op::BiasAdd, vec![x, b])
    }

    /// `linear = bias_add(dense(x, w), b)` — the Fig. 3 compiler-IR
    /// pattern.
    pub fn linear(&mut self, x: Id, w: Id, b: Id) -> Id {
        let d = self.dense(x, w);
        self.bias_add(d, b)
    }

    /// Elementwise add.
    pub fn add(&mut self, a: Id, b: Id) -> Id {
        self.expr.add(Op::Add, vec![a, b])
    }

    /// Elementwise multiply.
    pub fn mul(&mut self, a: Id, b: Id) -> Id {
        self.expr.add(Op::Mul, vec![a, b])
    }

    /// ReLU activation.
    pub fn relu(&mut self, x: Id) -> Id {
        self.expr.add(Op::Relu, vec![x])
    }

    /// GELU activation.
    pub fn gelu(&mut self, x: Id) -> Id {
        self.expr.add(Op::Gelu, vec![x])
    }

    /// Row-wise softmax.
    pub fn softmax(&mut self, x: Id) -> Id {
        self.expr.add(Op::Softmax, vec![x])
    }

    /// Row-wise layer normalization.
    pub fn layer_norm(&mut self, x: Id) -> Id {
        self.expr.add(Op::LayerNorm, vec![x])
    }

    /// Reshape to an explicit shape.
    pub fn reshape(&mut self, x: Id, shape: &[usize]) -> Id {
        self.expr.add(Op::Reshape(shape.to_vec()), vec![x])
    }

    /// 2-D transpose.
    pub fn transpose(&mut self, x: Id) -> Id {
        self.expr.add(Op::Transpose, vec![x])
    }

    /// Column-wise concatenation.
    pub fn concat(&mut self, a: Id, b: Id) -> Id {
        self.expr.add(Op::Concat, vec![a, b])
    }

    /// 2-D convolution (NCHW x OIHW).
    pub fn conv2d(
        &mut self,
        x: Id,
        w: Id,
        stride: (usize, usize),
        pad: (usize, usize),
        groups: usize,
    ) -> Id {
        self.expr.add(Op::Conv2d { stride, pad, groups }, vec![x, w])
    }

    /// 2-D max pooling.
    pub fn max_pool2d(&mut self, x: Id, window: (usize, usize), stride: (usize, usize)) -> Id {
        self.expr.add(Op::MaxPool2d { window, stride }, vec![x])
    }

    /// Global average pool over spatial dims.
    pub fn global_avg_pool(&mut self, x: Id) -> Id {
        self.expr.add(Op::GlobalAvgPool, vec![x])
    }

    /// Whole-sequence LSTM layer.
    pub fn lstm(&mut self, x: Id, w_ih: Id, w_hh: Id, b: Id, steps: usize) -> Id {
        self.expr.add(Op::Lstm { steps }, vec![x, w_ih, w_hh, b])
    }

    /// Single-head attention.
    pub fn attention(&mut self, q: Id, k: Id, v: Id) -> Id {
        self.expr.add(Op::Attention, vec![q, k, v])
    }

    /// Finalize and return the expression.
    pub fn finish(self) -> RecExpr {
        self.expr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_constructs_linear_pattern() {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        let b = g.weight("b");
        let _y = g.linear(x, w, b);
        let e = g.finish();
        assert_eq!(e.len(), 5);
        assert_eq!(e.nodes[e.root()].op, Op::BiasAdd);
    }

    #[test]
    fn invocation_counting() {
        let mut e = RecExpr::new();
        let x = e.add(Op::Var("x".into()), vec![]);
        let w = e.add(Op::Weight("w".into()), vec![]);
        let b = e.add(Op::Weight("b".into()), vec![]);
        let lin = e.add(Op::FlexLinear, vec![x, w, b]);
        let _ = e.add(Op::FlexMaxpStore, vec![lin]);
        assert_eq!(e.invocations(Target::FlexAsr), 1, "store is not an invocation");
        assert_eq!(e.invocations(Target::Vta), 0);
    }

    #[test]
    #[should_panic]
    fn add_rejects_forward_reference() {
        let mut e = RecExpr::new();
        e.add(Op::Relu, vec![3]);
    }

    #[test]
    fn arity_table_consistent() {
        assert_eq!(Op::Dense.arity(), 2);
        assert_eq!(Op::FlexLinear.arity(), 3);
        assert_eq!(Op::Lstm { steps: 3 }.arity(), 4);
        assert_eq!(Op::Var("a".into()).arity(), 0);
    }
}
