//! The f32 IR interpreter — the *reference semantics* of the compiler IR.
//!
//! §4.4: "we use an IR interpreter as the reference when running
//! simulation". Every op, including the accelerator ops, is given its
//! exact f32 meaning here; the custom-numerics behaviour of accelerator
//! ops is layered on by the co-simulation driver, which intercepts
//! accelerator nodes and routes them to the ILA simulators instead.

use super::{Node, Op, RecExpr};
use crate::tensor::{ops, Tensor};
use std::collections::HashMap;

/// Interpretation failure.
#[derive(Debug, thiserror::Error)]
pub enum EvalError {
    #[error("unbound input `{0}`")]
    Unbound(String),
    #[error("evaluation of {0} failed: {1}")]
    Op(String, String),
    /// Malformed driver input (e.g. a token stream too short for the
    /// requested sweep) — reported instead of slice-panicking.
    #[error("invalid input: {0}")]
    Input(String),
}

/// Name → tensor lookup consulted for `Var`/`Weight` leaves.
///
/// Abstracting over the environment lets sweep workers layer a one-slot
/// per-datapoint input override on top of a shared weight map
/// ([`crate::session::LayeredEnv`]) instead of cloning the whole map per
/// worker, while plain `HashMap` environments keep working unchanged.
pub trait EnvLookup {
    /// Resolve a leaf name to its bound tensor.
    fn lookup(&self, name: &str) -> Option<&Tensor>;
}

impl EnvLookup for HashMap<String, Tensor> {
    fn lookup(&self, name: &str) -> Option<&Tensor> {
        self.get(name)
    }
}

/// Hook consulted for every node *before* default evaluation; returning
/// `Ok(Some(tensor))` overrides the f32 semantics. The co-sim driver uses
/// this to swap in ILA-simulated accelerator execution; MMIO-backend
/// failures surface as `Err` instead of being silently dropped.
pub trait EvalHook {
    /// Override evaluation of `node` given already-evaluated children.
    fn intercept(
        &mut self,
        node: &Node,
        children: &[&Tensor],
    ) -> Result<Option<Tensor>, EvalError>;
}

/// No-op hook: pure f32 reference execution.
pub struct NoHook;

impl EvalHook for NoHook {
    fn intercept(&mut self, _: &Node, _: &[&Tensor]) -> Result<Option<Tensor>, EvalError> {
        Ok(None)
    }
}

/// Evaluate one operator with f32 semantics.
pub fn eval_op(op: &Op, ch: &[&Tensor]) -> Result<Tensor, EvalError> {
    use Op::*;
    let t = |i: usize| -> &Tensor { ch[i] };
    let out = match op {
        Var(n) | Weight(n) => return Err(EvalError::Unbound(n.clone())),
        ConstScalar(bits) => Tensor::scalar(f32::from_bits(*bits)),
        ZeroTensor(shape) => Tensor::zeros(shape),
        Dense | VtaGemm => ops::dense(t(0), t(1)),
        BiasAdd => ops::bias_add(t(0), t(1)),
        Add | VtaAdd => ops::add(t(0), t(1)),
        Mul => ops::mul(t(0), t(1)),
        Relu => ops::relu(t(0)),
        Sigmoid => ops::sigmoid(t(0)),
        Tanh => ops::tanh(t(0)),
        Gelu => ops::gelu(t(0)),
        Softmax => ops::softmax(t(0)),
        LayerNorm | FlexLayerNorm => ops::layer_norm(t(0), 1e-5),
        Reshape(shape) => t(0).reshape(shape),
        Transpose => ops::transpose2(t(0)),
        Concat => ops::concat_cols(&[t(0), t(1)]),
        Conv2d { stride, pad, groups } => {
            if *groups == 1 {
                ops::conv2d(t(0), t(1), *stride, *pad)
            } else {
                grouped_conv2d(t(0), t(1), *stride, *pad, *groups)
            }
        }
        HlscnnConv2d { stride, pad } => ops::conv2d(t(0), t(1), *stride, *pad),
        MaxPool2d { window, stride } => ops::max_pool2d(t(0), *window, *stride),
        AvgPool2d { window, stride } => ops::avg_pool2d(t(0), *window, *stride),
        GlobalAvgPool => global_avg_pool(t(0)),
        MatMaxPool { window, stride } => ops::matrix_max_pool(t(0), *window, *stride),
        MatMeanPool { window, stride } => matrix_mean_pool(t(0), *window, *stride),
        WindowsFlatten { window, stride } => windows_flatten(t(0), *window, *stride),
        TempMaxPool | FlexMaxpool => temp_pool(t(0), |a, b| a.max(b)),
        TempMeanPool | FlexMeanpool => temp_pool(t(0), |a, b| (a + b) / 2.0),
        Im2col { kernel, stride, pad } => ops::im2col(t(0), *kernel, *stride, *pad),
        FromIm2col { n, oh, ow } => from_im2col(t(0), *n, *oh, *ow),
        Lstm { .. } | FlexLstm { .. } => ops::lstm_sequence(t(0), t(1), t(2), t(3)),
        SliceStep { t: step } => {
            let x = t(0);
            let (n, e) = (x.shape[1], x.shape[2]);
            Tensor::new(vec![n, e], x.data[step * n * e..(step + 1) * n * e].to_vec())
        }
        SliceCols { lo, hi } => {
            let x = t(0);
            let (r, c) = (x.shape[0], x.shape[1]);
            let mut out = Vec::with_capacity(r * (hi - lo));
            for i in 0..r {
                out.extend_from_slice(&x.data[i * c + lo..i * c + hi]);
            }
            Tensor::new(vec![r, hi - lo], out)
        }
        ConcatRows => {
            let (a, b) = (t(0), t(1));
            let mut data = a.data.clone();
            data.extend_from_slice(&b.data);
            Tensor::new(vec![a.shape[0] + b.shape[0], a.shape[1]], data)
        }
        FlexLstmFused { .. } => {
            // split the fused gate matrix w = [w_ih | w_hh]
            let (x, w, b) = (t(0), t(1), t(2));
            let e = x.shape[2];
            let four_h = w.shape[0];
            let h = four_h / 4;
            let mut wih = Vec::with_capacity(four_h * e);
            let mut whh = Vec::with_capacity(four_h * h);
            for r in 0..four_h {
                wih.extend_from_slice(&w.data[r * (e + h)..r * (e + h) + e]);
                whh.extend_from_slice(&w.data[r * (e + h) + e..(r + 1) * (e + h)]);
            }
            ops::lstm_sequence(
                x,
                &Tensor::new(vec![four_h, e], wih),
                &Tensor::new(vec![four_h, h], whh),
                b,
            )
        }
        Attention | FlexAttention => ops::attention(t(0), t(1), t(2)),
        FlexLinear => ops::bias_add(&ops::dense(t(0), t(1)), t(2)),
        FlexMaxpStore | FlexMaxpLoad => t(0).clone(),
    };
    Ok(out)
}

/// Evaluate a whole program under `env`, with an interception hook.
pub fn eval_with_hook<E: EnvLookup + ?Sized>(
    expr: &RecExpr,
    env: &E,
    hook: &mut dyn EvalHook,
) -> Result<Tensor, EvalError> {
    let mut values: Vec<Tensor> = Vec::with_capacity(expr.len());
    for node in &expr.nodes {
        let ch: Vec<&Tensor> = node.children.iter().map(|&c| &values[c]).collect();
        let v = match &node.op {
            Op::Var(n) | Op::Weight(n) => {
                env.lookup(n).cloned().ok_or_else(|| EvalError::Unbound(n.clone()))?
            }
            op => match hook.intercept(node, &ch)? {
                Some(t) => t,
                None => eval_op(op, &ch)?,
            },
        };
        values.push(v);
    }
    Ok(values.pop().expect("empty program"))
}

/// Pure f32 reference evaluation.
pub fn eval<E: EnvLookup + ?Sized>(expr: &RecExpr, env: &E) -> Result<Tensor, EvalError> {
    eval_with_hook(expr, env, &mut NoHook)
}

fn global_avg_pool(x: &Tensor) -> Tensor {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = vec![0.0f32; n * c];
    for b in 0..n {
        for ch in 0..c {
            let base = (b * c + ch) * h * w;
            out[b * c + ch] =
                x.data[base..base + h * w].iter().sum::<f32>() / (h * w) as f32;
        }
    }
    Tensor::new(vec![n, c], out)
}

fn matrix_mean_pool(x: &Tensor, window: (usize, usize), stride: (usize, usize)) -> Tensor {
    let (r, c) = (x.shape[0], x.shape[1]);
    let (wh, ww) = window;
    let (sh, sw) = stride;
    let or = (r - wh) / sh + 1;
    let oc = (c - ww) / sw + 1;
    let mut out = vec![0.0f32; or * oc];
    for i in 0..or {
        for j in 0..oc {
            let mut acc = 0.0f32;
            for di in 0..wh {
                for dj in 0..ww {
                    acc += x.data[(i * sh + di) * c + j * sw + dj];
                }
            }
            out[i * oc + j] = acc / (wh * ww) as f32;
        }
    }
    Tensor::new(vec![or, oc], out)
}

/// `[R, C] -> [wh*ww, OR*OC]`: column `w` is window `w` (row-major over
/// the output grid); row `p` is within-window position `p = dy*ww + dx`.
fn windows_flatten(x: &Tensor, window: (usize, usize), stride: (usize, usize)) -> Tensor {
    let (r, c) = (x.shape[0], x.shape[1]);
    let (wh, ww) = window;
    let (sh, sw) = stride;
    let or = (r - wh) / sh + 1;
    let oc = (c - ww) / sw + 1;
    let nwin = or * oc;
    let mut out = vec![0.0f32; wh * ww * nwin];
    for i in 0..or {
        for j in 0..oc {
            let wi = i * oc + j;
            for dy in 0..wh {
                for dx in 0..ww {
                    out[(dy * ww + dx) * nwin + wi] =
                        x.data[(i * sh + dy) * c + j * sw + dx];
                }
            }
        }
    }
    Tensor::new(vec![wh * ww, nwin], out)
}

/// Pairwise reduction of adjacent rows: `[2k, C] -> [k, C]`.
fn temp_pool(x: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    let (r, c) = (x.shape[0], x.shape[1]);
    assert!(r % 2 == 0, "temp pool needs even rows, got {r}");
    let mut out = vec![0.0f32; r / 2 * c];
    for i in 0..r / 2 {
        for j in 0..c {
            out[i * c + j] = f(x.data[2 * i * c + j], x.data[(2 * i + 1) * c + j]);
        }
    }
    Tensor::new(vec![r / 2, c], out)
}

fn from_im2col(x: &Tensor, n: usize, oh: usize, ow: usize) -> Tensor {
    let o = x.shape[1];
    let mut out = vec![0.0f32; n * o * oh * ow];
    for b in 0..n {
        for y in 0..oh {
            for xw in 0..ow {
                for oc in 0..o {
                    out[((b * o + oc) * oh + y) * ow + xw] =
                        x.data[((b * oh + y) * ow + xw) * o + oc];
                }
            }
        }
    }
    Tensor::new(vec![n, o, oh, ow], out)
}

fn grouped_conv2d(
    x: &Tensor,
    w: &Tensor,
    stride: (usize, usize),
    pad: (usize, usize),
    groups: usize,
) -> Tensor {
    let (n, c, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let o = w.shape[0];
    let cg = c / groups;
    let og = o / groups;
    let mut parts: Vec<Tensor> = Vec::with_capacity(groups);
    for g in 0..groups {
        // slice channels [g*cg, (g+1)*cg) of x and filters [g*og, (g+1)*og)
        let mut xg = Tensor::zeros(&[n, cg, h, wd]);
        for b in 0..n {
            for ic in 0..cg {
                let src = ((b * c + g * cg + ic) * h) * wd;
                let dst = ((b * cg + ic) * h) * wd;
                xg.data[dst..dst + h * wd].copy_from_slice(&x.data[src..src + h * wd]);
            }
        }
        let ksz = w.shape[2] * w.shape[3] * cg;
        let wg = Tensor::new(
            vec![og, cg, w.shape[2], w.shape[3]],
            w.data[g * og * ksz..(g + 1) * og * ksz].to_vec(),
        );
        parts.push(ops::conv2d(&xg, &wg, stride, pad));
    }
    // concat along channel axis
    let oh = parts[0].shape[2];
    let ow = parts[0].shape[3];
    let mut out = Tensor::zeros(&[n, o, oh, ow]);
    for (g, p) in parts.iter().enumerate() {
        for b in 0..n {
            for oc in 0..og {
                let src = ((b * og + oc) * oh) * ow;
                let dst = ((b * o + g * og + oc) * oh) * ow;
                out.data[dst..dst + oh * ow].copy_from_slice(&p.data[src..src + oh * ow]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{GraphBuilder, Op, RecExpr};
    use crate::util::Rng;

    fn tenv(pairs: Vec<(&str, Tensor)>) -> HashMap<String, Tensor> {
        pairs.into_iter().map(|(n, t)| (n.to_string(), t)).collect()
    }

    #[test]
    fn linear_program_evaluates() {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        let b = g.weight("b");
        g.linear(x, w, b);
        let env = tenv(vec![
            ("x", Tensor::new(vec![1, 2], vec![1.0, 2.0])),
            ("w", Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0])),
            ("b", Tensor::new(vec![2], vec![10.0, 20.0])),
        ]);
        let y = eval(&g.finish(), &env).unwrap();
        assert_eq!(y.data, vec![11.0, 22.0]);
    }

    #[test]
    fn accel_ops_match_ir_ops_in_f32() {
        // FlexLinear's f32 semantics == bias_add(dense(x, w), b)
        let mut rng = Rng::new(42);
        let x = Tensor::randn(&[3, 8], &mut rng, 1.0);
        let w = Tensor::randn(&[4, 8], &mut rng, 1.0);
        let b = Tensor::randn(&[4], &mut rng, 1.0);
        let flex = eval_op(&Op::FlexLinear, &[&x, &w, &b]).unwrap();
        let d = eval_op(&Op::Dense, &[&x, &w]).unwrap();
        let reference = eval_op(&Op::BiasAdd, &[&d, &b]).unwrap();
        assert!(flex.max_abs_diff(&reference) < 1e-6);
    }

    #[test]
    fn maxpool_decomposition_is_semantics_preserving() {
        // the Fig. 7 rewrite: mat_maxpool (4,4)(2,2) ==
        // reshape . tempmax^4 . windows_flatten (4,4)(2,2)
        let mut rng = Rng::new(7);
        let t = Tensor::randn(&[16, 16], &mut rng, 1.0);
        let direct = eval_op(
            &Op::MatMaxPool { window: (4, 4), stride: (2, 2) },
            &[&t],
        )
        .unwrap();

        let mut e = RecExpr::new();
        let x = e.add(Op::Var("t".into()), vec![]);
        let wf = e.add(Op::WindowsFlatten { window: (4, 4), stride: (2, 2) }, vec![x]);
        let m1 = e.add(Op::TempMaxPool, vec![wf]);
        let m2 = e.add(Op::TempMaxPool, vec![m1]);
        let m3 = e.add(Op::TempMaxPool, vec![m2]);
        let m4 = e.add(Op::TempMaxPool, vec![m3]);
        e.add(Op::Reshape(vec![7, 7]), vec![m4]);
        let staged = eval(&e, &tenv(vec![("t", t)])).unwrap();
        assert_eq!(staged.shape, direct.shape);
        assert!(staged.max_abs_diff(&direct) < 1e-6);
    }

    #[test]
    fn im2col_pipeline_equals_conv() {
        let mut rng = Rng::new(9);
        let x = Tensor::randn(&[1, 3, 8, 8], &mut rng, 1.0);
        let w = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.5);
        let direct = eval_op(
            &Op::Conv2d { stride: (1, 1), pad: (1, 1), groups: 1 },
            &[&x, &w],
        )
        .unwrap();

        let mut e = RecExpr::new();
        let xv = e.add(Op::Var("x".into()), vec![]);
        let wv = e.add(Op::Weight("w".into()), vec![]);
        let patches = e.add(
            Op::Im2col { kernel: (3, 3), stride: (1, 1), pad: (1, 1) },
            vec![xv],
        );
        let wflat = e.add(Op::Reshape(vec![4, 27]), vec![wv]);
        let gemm = e.add(Op::Dense, vec![patches, wflat]);
        e.add(Op::FromIm2col { n: 1, oh: 8, ow: 8 }, vec![gemm]);
        let staged = eval(&e, &tenv(vec![("x", x), ("w", w)])).unwrap();
        assert!(staged.max_abs_diff(&direct) < 1e-4);
    }

    #[test]
    fn grouped_conv_matches_manual_split() {
        let mut rng = Rng::new(13);
        let x = Tensor::randn(&[1, 4, 6, 6], &mut rng, 1.0);
        let w = Tensor::randn(&[8, 2, 3, 3], &mut rng, 0.5); // groups=2
        let y = eval_op(
            &Op::Conv2d { stride: (1, 1), pad: (1, 1), groups: 2 },
            &[&x, &w],
        )
        .unwrap();
        assert_eq!(y.shape, vec![1, 8, 6, 6]);
        // group 0 output channel 0 must equal plain conv over channels 0..2
        let mut x0 = Tensor::zeros(&[1, 2, 6, 6]);
        x0.data.copy_from_slice(&x.data[0..72]);
        let w0 = Tensor::new(vec![4, 2, 3, 3], w.data[0..72].to_vec());
        let y0 = crate::tensor::ops::conv2d(&x0, &w0, (1, 1), (1, 1));
        assert!((y.data[0] - y0.data[0]).abs() < 1e-5);
    }

    #[test]
    fn hook_intercepts_accelerator_nodes() {
        struct CountHook(usize);
        impl EvalHook for CountHook {
            fn intercept(
                &mut self,
                node: &Node,
                ch: &[&Tensor],
            ) -> Result<Option<Tensor>, EvalError> {
                if matches!(node.op, Op::FlexLinear) {
                    self.0 += 1;
                    // deliberately perturb so we can observe the override
                    let t = eval_op(&node.op, ch)?;
                    return Ok(Some(t.map(|v| v + 1000.0)));
                }
                Ok(None)
            }
        }
        let mut e = RecExpr::new();
        let x = e.add(Op::Var("x".into()), vec![]);
        let w = e.add(Op::Weight("w".into()), vec![]);
        let b = e.add(Op::Weight("b".into()), vec![]);
        e.add(Op::FlexLinear, vec![x, w, b]);
        let env = tenv(vec![
            ("x", Tensor::ones(&[1, 2])),
            ("w", Tensor::ones(&[1, 2])),
            ("b", Tensor::zeros(&[1])),
        ]);
        let mut hook = CountHook(0);
        let y = eval_with_hook(&e, &env, &mut hook).unwrap();
        assert_eq!(hook.0, 1);
        assert!((y.data[0] - 1002.0).abs() < 1e-5);
    }
}
