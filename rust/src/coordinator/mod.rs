//! The validation coordinator: a worker-pool job scheduler that fans
//! application-level co-simulation sweeps (2000 images / 100 sentences,
//! Table 4) across threads, each worker owning its own accelerator model
//! instances, and merges the partial reports.
//!
//! std::thread + channels (tokio is not in the offline vendored set — see
//! DESIGN.md); the structure is the same leader/worker shape a
//! distributed deployment would use.

use crate::accel::{Accelerator, FlexAsr, Hlscnn, HlscnnConfig, Vta};
use crate::ir::RecExpr;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Which accelerator configuration a sweep runs under (the Table 4
/// "Original" vs "Updated" columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DesignRev {
    /// As-published designs: HLSCNN 8-bit fixed-point weight store.
    Original,
    /// Post-co-design fix: HLSCNN 16-bit weights.
    Updated,
}

/// Build the accelerator set for a design revision.
pub fn accelerators(rev: DesignRev) -> Vec<Box<dyn Accelerator>> {
    let (fa, hl) = match rev {
        DesignRev::Original => {
            (FlexAsr::original(), Hlscnn::new(HlscnnConfig::original()))
        }
        DesignRev::Updated => {
            (FlexAsr::updated(), Hlscnn::new(HlscnnConfig::updated()))
        }
    };
    vec![Box::new(fa), Box::new(hl), Box::new(Vta::new())]
}

/// Merged result of a distributed classification sweep.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub n: usize,
    pub ref_correct: usize,
    pub acc_correct: usize,
    pub elapsed: Duration,
    pub workers: usize,
}

impl SweepReport {
    pub fn ref_accuracy(&self) -> f32 {
        self.ref_correct as f32 / self.n as f32
    }

    pub fn acc_accuracy(&self) -> f32 {
        self.acc_correct as f32 / self.n as f32
    }

    /// Average simulation time per data point (the Table 4 column).
    pub fn time_per_point(&self) -> Duration {
        self.elapsed / self.n.max(1) as u32
    }
}

/// Run a classification co-simulation sweep over `images` with `workers`
/// threads. Each worker instantiates its own accelerator models (they
/// are stateless between invocations) and processes a strided shard.
pub fn classify_sweep(
    expr: &RecExpr,
    weights: &HashMap<String, Tensor>,
    images: &[Tensor],
    labels: &[usize],
    rev: DesignRev,
    workers: usize,
) -> SweepReport {
    let start = Instant::now();
    let expr = Arc::new(expr.clone());
    let weights = Arc::new(weights.clone());
    let images = Arc::new(images.to_vec());
    let labels = Arc::new(labels.to_vec());
    let (tx, rx) = mpsc::channel::<(usize, usize, usize)>();

    let workers = workers.max(1);
    let mut handles = Vec::new();
    for wid in 0..workers {
        let tx = tx.clone();
        let expr = Arc::clone(&expr);
        let weights = Arc::clone(&weights);
        let images = Arc::clone(&images);
        let labels = Arc::clone(&labels);
        handles.push(thread::spawn(move || {
            let accels = accelerators(rev);
            let mut env = (*weights).clone();
            let mut ref_c = 0usize;
            let mut acc_c = 0usize;
            let mut n = 0usize;
            let mut idx = wid;
            while idx < images.len() {
                env.insert("x".to_string(), images[idx].clone());
                if let Ok(r) = crate::ir::interp::eval(&expr, &env) {
                    if r.argmax() == labels[idx] {
                        ref_c += 1;
                    }
                }
                if let Ok((a, _)) = crate::cosim::run_accelerated(&expr, &env, &accels)
                {
                    if a.argmax() == labels[idx] {
                        acc_c += 1;
                    }
                }
                n += 1;
                idx += workers;
            }
            let _ = tx.send((ref_c, acc_c, n));
        }));
    }
    drop(tx);

    let mut report = SweepReport {
        n: 0,
        ref_correct: 0,
        acc_correct: 0,
        elapsed: Duration::ZERO,
        workers,
    };
    for (r, a, n) in rx {
        report.ref_correct += r;
        report.acc_correct += a;
        report.n += n;
    }
    for h in handles {
        let _ = h.join();
    }
    report.elapsed = start.elapsed();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::util::Rng;

    /// Sweep over a toy linear classifier: worker sharding must cover
    /// every input exactly once and agree with the sequential path.
    #[test]
    fn sweep_matches_sequential() {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        let b = g.weight("b");
        g.linear(x, w, b);
        let expr = g.finish();
        let mut rng = Rng::new(5);
        let weights: HashMap<String, Tensor> = [
            ("w".to_string(), Tensor::randn(&[4, 8], &mut rng, 0.5)),
            ("b".to_string(), Tensor::randn(&[4], &mut rng, 0.1)),
        ]
        .into_iter()
        .collect();
        let images: Vec<Tensor> =
            (0..23).map(|_| Tensor::randn(&[1, 8], &mut rng, 1.0)).collect();
        let labels: Vec<usize> = (0..23).map(|_| rng.below(4)).collect();

        let seq = classify_sweep(&expr, &weights, &images, &labels, DesignRev::Updated, 1);
        let par = classify_sweep(&expr, &weights, &images, &labels, DesignRev::Updated, 4);
        assert_eq!(seq.n, 23);
        assert_eq!(par.n, 23);
        assert_eq!(seq.ref_correct, par.ref_correct);
        assert_eq!(seq.acc_correct, par.acc_correct);
    }

    #[test]
    fn design_revisions_differ() {
        let orig = accelerators(DesignRev::Original);
        let upd = accelerators(DesignRev::Updated);
        assert_eq!(orig.len(), 3);
        assert_eq!(upd.len(), 3);
    }
}
