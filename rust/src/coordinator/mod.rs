//! Deprecated coordinator shims.
//!
//! The worker-pool sweep scheduler that lived here moved into the
//! session layer: [`crate::session::CompiledProgram::classify_sweep`]
//! shards a labelled dataset over the session's worker threads against
//! one `Arc`-shared [`crate::session::AcceleratorRegistry`] (the seed
//! version re-instantiated every accelerator model per worker and
//! hardcoded the input variable to `"x"`). The free functions below keep
//! the old signatures compiling; new code should build a
//! [`crate::session::Session`].

use crate::accel::Accelerator;
use crate::ir::RecExpr;
use crate::session::{SessionBuilder, SweepSpec};
use crate::tensor::Tensor;
use std::collections::HashMap;

pub use crate::session::{DesignRev, SweepReport};

/// Build the accelerator set for a design revision.
#[deprecated(
    note = "use session::AcceleratorRegistry::for_rev, which adds O(1) \
            target-indexed dispatch"
)]
pub fn accelerators(rev: DesignRev) -> Vec<Box<dyn Accelerator>> {
    crate::session::registry::models(rev)
}

/// Run a classification co-simulation sweep over `images` with `workers`
/// threads, assuming the per-image input variable is named `"x"`.
#[deprecated(
    note = "use Session::compile + CompiledProgram::classify_sweep with an \
            explicit SweepSpec::input_var"
)]
pub fn classify_sweep(
    expr: &RecExpr,
    weights: &HashMap<String, Tensor>,
    images: &[Tensor],
    labels: &[usize],
    rev: DesignRev,
    workers: usize,
) -> SweepReport {
    let session = SessionBuilder::new().design_rev(rev).workers(workers).build();
    let program = session.attach(expr.clone());
    program.classify_sweep(&SweepSpec {
        input_var: "x",
        weights,
        inputs: images,
        labels,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::util::Rng;

    fn toy_classifier() -> (RecExpr, HashMap<String, Tensor>, Vec<Tensor>, Vec<usize>)
    {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        let b = g.weight("b");
        g.linear(x, w, b);
        let expr = g.finish();
        let mut rng = Rng::new(5);
        let weights: HashMap<String, Tensor> = [
            ("w".to_string(), Tensor::randn(&[4, 8], &mut rng, 0.5)),
            ("b".to_string(), Tensor::randn(&[4], &mut rng, 0.1)),
        ]
        .into_iter()
        .collect();
        let images: Vec<Tensor> =
            (0..23).map(|_| Tensor::randn(&[1, 8], &mut rng, 1.0)).collect();
        let labels: Vec<usize> = (0..23).map(|_| rng.below(4)).collect();
        (expr, weights, images, labels)
    }

    /// The deprecated shim must agree with the session path it wraps.
    #[test]
    #[allow(deprecated)]
    fn shim_matches_session_sweep() {
        let (expr, weights, images, labels) = toy_classifier();
        let old = classify_sweep(&expr, &weights, &images, &labels, DesignRev::Updated, 4);
        let session = SessionBuilder::new()
            .design_rev(DesignRev::Updated)
            .workers(4)
            .build();
        let new = session.attach(expr).classify_sweep(&SweepSpec {
            input_var: "x",
            weights: &weights,
            inputs: &images,
            labels: &labels,
        });
        assert_eq!(old.n, 23);
        assert_eq!(old.n, new.n);
        assert_eq!(old.ref_correct, new.ref_correct);
        assert_eq!(old.acc_correct, new.acc_correct);
    }

    #[test]
    #[allow(deprecated)]
    fn design_revisions_differ() {
        let orig = accelerators(DesignRev::Original);
        let upd = accelerators(DesignRev::Updated);
        assert_eq!(orig.len(), 3);
        assert_eq!(upd.len(), 3);
    }
}
