//! The Instruction-Level Abstraction (ILA) framework — the formal
//! software/hardware interface at the heart of D2A (Huang et al., TODAES
//! 2018; the ILAng platform, TACAS 2019).
//!
//! An ILA models an accelerator as a set of **instructions**, each
//! corresponding to one command at the accelerator's MMIO interface. Every
//! instruction has a *decode* condition (which interface command triggers
//! it) and *update* functions over the **architectural state** (config
//! registers + software-visible buffers). This is exactly the structure of
//! the ILAng snippet in Fig. 6 of the paper, transliterated to Rust:
//! `SetDecode` becomes [`Instr::decode`], `SetUpdate` becomes
//! [`Instr::update`].
//!
//! The simulator in [`sim`] executes programs of interface commands
//! against a model — the Rust analogue of ILAng's generated C++/SystemC
//! simulators used for Tables 2 and 4.

pub mod asm;
pub mod sim;

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

/// One command at the accelerator interface: an MMIO read or write of a
/// 128-bit word (the FlexASR interface width; narrower devices ignore the
/// upper bytes).
///
/// Writes carry a **byte-enable count** `len` (the AXI write-strobe
/// analogue): only the first `len` payload bytes are written by the
/// device. The seed streamer zero-padded the final beat of every burst
/// to 16 bytes, silently clobbering up to 15 bytes past an unaligned
/// slice's destination — dangerous for adjacent staged regions (e.g. the
/// FlexASR `PE_WGT_BASE + bias_base` / `wgt2_base` layouts). Partial
/// writes via [`Cmd::write_bytes`] make the short final beat explicit,
/// and every device's data-port instruction masks its store to
/// [`Cmd::payload`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Cmd {
    /// Write (true) or read (false).
    pub is_write: bool,
    /// Interface address.
    pub addr: u64,
    /// Payload (writes); ignored for reads.
    pub data: [u8; 16],
    /// Enabled payload bytes (1..=16 for writes; 16 for reads). Bytes
    /// beyond `len` are don't-care and must not be stored by devices.
    pub len: u8,
}

impl Cmd {
    /// An MMIO write of a full 128-bit beat.
    pub fn write(addr: u64, data: [u8; 16]) -> Self {
        Cmd { is_write: true, addr, data, len: 16 }
    }

    /// An MMIO write of `1..=16` payload bytes (a short final beat with
    /// byte enables); panics on an empty or oversized payload.
    pub fn write_bytes(addr: u64, bytes: &[u8]) -> Self {
        assert!(
            !bytes.is_empty() && bytes.len() <= 16,
            "partial write must carry 1..=16 bytes, got {}",
            bytes.len()
        );
        let mut data = [0u8; 16];
        data[..bytes.len()].copy_from_slice(bytes);
        Cmd { is_write: true, addr, data, len: bytes.len() as u8 }
    }

    /// An MMIO write of a u64 value (upper bytes zero).
    pub fn write_u64(addr: u64, v: u64) -> Self {
        let mut data = [0u8; 16];
        data[..8].copy_from_slice(&v.to_le_bytes());
        Cmd { is_write: true, addr, data, len: 16 }
    }

    /// An MMIO read.
    pub fn read(addr: u64) -> Self {
        Cmd { is_write: false, addr, data: [0u8; 16], len: 16 }
    }

    /// Low 8 bytes as u64.
    pub fn data_u64(&self) -> u64 {
        u64::from_le_bytes(self.data[..8].try_into().unwrap())
    }

    /// The byte-enabled payload (what a data-port store may write).
    pub fn payload(&self) -> &[u8] {
        &self.data[..self.len as usize]
    }
}

impl fmt::Display for Cmd {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let hi = u64::from_le_bytes(self.data[8..].try_into().unwrap());
        let lo = u64::from_le_bytes(self.data[..8].try_into().unwrap());
        if self.is_write {
            write!(f, "WR 0x{:08X}, 0x{:016X}{:016X}", self.addr, hi, lo)
        } else {
            write!(f, "RD 0x{:08X}", self.addr)
        }
    }
}

/// Architectural state of an ILA model: named registers (bit-vectors up
/// to 64 bits) and named byte-addressable memories.
///
/// Memory writes are **dirty-tracked**: every mutation path records the
/// byte range it touched (conservatively, the whole memory for the legacy
/// [`Self::mem_mut`] accessor), so a simulator reset between invocations
/// only has to restore the bytes a program actually wrote instead of
/// cloning the full multi-hundred-KiB initial state (see
/// [`sim::IlaSim::reset_dirty`]).
#[derive(Debug, Clone, Default)]
pub struct IlaState {
    regs: BTreeMap<String, (u64, u32)>,
    mems: BTreeMap<String, Vec<u8>>,
    /// Per-memory dirty watermark `[lo, hi)`; absent = clean.
    dirty: BTreeMap<String, (usize, usize)>,
}

impl IlaState {
    /// Empty state (no registers, no memories).
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare a register of `width` bits (like `NewBvState` in ILAng).
    pub fn new_bv(&mut self, name: &str, width: u32) {
        assert!(width <= 64, "registers are modeled up to 64 bits");
        self.regs.insert(name.to_string(), (0, width));
    }

    /// Declare a byte-addressable memory of `size` bytes (`NewMemState`).
    pub fn new_mem(&mut self, name: &str, size: usize) {
        self.mems.insert(name.to_string(), vec![0u8; size]);
    }

    /// Read a register.
    pub fn reg(&self, name: &str) -> u64 {
        self.regs
            .get(name)
            .unwrap_or_else(|| panic!("unknown ILA register `{name}`"))
            .0
    }

    /// Write a register (masked to its declared width).
    pub fn set_reg(&mut self, name: &str, value: u64) {
        let entry = self
            .regs
            .get_mut(name)
            .unwrap_or_else(|| panic!("unknown ILA register `{name}`"));
        let mask = if entry.1 == 64 { u64::MAX } else { (1u64 << entry.1) - 1 };
        entry.0 = value & mask;
    }

    /// Borrow a memory.
    pub fn mem(&self, name: &str) -> &[u8] {
        self.mems
            .get(name)
            .unwrap_or_else(|| panic!("unknown ILA memory `{name}`"))
    }

    /// Widen a memory's dirty watermark to cover `[lo, hi)`.
    fn mark_dirty(&mut self, name: &str, lo: usize, hi: usize) {
        if lo >= hi {
            return;
        }
        self.dirty
            .entry(name.to_string())
            .and_modify(|(dl, dh)| {
                *dl = (*dl).min(lo);
                *dh = (*dh).max(hi);
            })
            .or_insert((lo, hi));
    }

    /// Borrow a memory mutably. The legacy catch-all accessor: because
    /// the caller may write anywhere, the **whole** memory is marked
    /// dirty; prefer [`Self::mem_write`] / [`Self::mem_range_mut`] so
    /// dirty-region resets stay cheap.
    pub fn mem_mut(&mut self, name: &str) -> &mut Vec<u8> {
        let len = self.mem(name).len();
        self.mark_dirty(name, 0, len);
        self.mems
            .get_mut(name)
            .unwrap_or_else(|| panic!("unknown ILA memory `{name}`"))
    }

    /// Write `bytes` into a memory at `off`, dirty-tracking exactly that
    /// range.
    pub fn mem_write(&mut self, name: &str, off: usize, bytes: &[u8]) {
        self.mark_dirty(name, off, off + bytes.len());
        let mem = self
            .mems
            .get_mut(name)
            .unwrap_or_else(|| panic!("unknown ILA memory `{name}`"));
        mem[off..off + bytes.len()].copy_from_slice(bytes);
    }

    /// Mutably borrow the byte range `[lo, hi)` of a memory,
    /// dirty-tracking exactly that range.
    pub fn mem_range_mut(&mut self, name: &str, lo: usize, hi: usize) -> &mut [u8] {
        self.mark_dirty(name, lo, hi);
        let mem = self
            .mems
            .get_mut(name)
            .unwrap_or_else(|| panic!("unknown ILA memory `{name}`"));
        &mut mem[lo..hi]
    }

    /// Restore this state to `init` by rewinding only what was touched:
    /// every register value is copied back (registers are few and cheap)
    /// and each memory's dirty range is copied from `init`'s bytes.
    /// Returns the number of memory bytes restored — the work a
    /// dirty-region reset actually did, vs. [`Self::total_mem_bytes`] for
    /// a full clone.
    pub fn restore_from(&mut self, init: &IlaState) -> u64 {
        self.restore_from_keeping(init, &[])
    }

    /// [`Self::restore_from`] that **keeps** the listed `(mem, lo, hi)`
    /// byte ranges as-is instead of rewinding them — the residency hook:
    /// an execution engine that knows an operand burst is still staged in
    /// a region passes that region here, so the staged bytes survive the
    /// between-program reset and the burst need not be re-streamed. Kept
    /// ranges remain marked dirty (they still diverge from `init`), so a
    /// later reset without the keep list rewinds them normally.
    pub fn restore_from_keeping(
        &mut self,
        init: &IlaState,
        keep: &[(String, usize, usize)],
    ) -> u64 {
        for (name, val) in &init.regs {
            if let Some(entry) = self.regs.get_mut(name) {
                *entry = *val;
            }
        }
        let mut restored = 0u64;
        for (name, (lo, hi)) in std::mem::take(&mut self.dirty) {
            // kept sub-ranges of this memory's dirty watermark, merged
            let mut kept: Vec<(usize, usize)> = keep
                .iter()
                .filter(|(m, klo, khi)| *m == name && *khi > lo && *klo < hi)
                .map(|&(_, klo, khi)| (klo.max(lo), khi.min(hi)))
                .collect();
            kept.sort_unstable();
            let src = &init.mems[&name];
            let dst = self.mems.get_mut(&name).expect("dirty unknown mem");
            let mut cursor = lo;
            for &(klo, khi) in &kept {
                if cursor < klo {
                    dst[cursor..klo].copy_from_slice(&src[cursor..klo]);
                    restored += (klo - cursor) as u64;
                }
                cursor = cursor.max(khi);
            }
            if cursor < hi {
                dst[cursor..hi].copy_from_slice(&src[cursor..hi]);
                restored += (hi - cursor) as u64;
            }
            if let Some(&(first, _)) = kept.first() {
                // the kept bytes still diverge from init: the watermark
                // must keep covering them (conservatively, their span)
                let span_hi = kept.iter().map(|&(_, khi)| khi).max().unwrap();
                self.dirty.insert(name, (first, span_hi));
            }
        }
        restored
    }

    /// Total bytes across all memories (the cost of a full-state clone).
    pub fn total_mem_bytes(&self) -> u64 {
        self.mems.values().map(|m| m.len() as u64).sum()
    }

    /// Register names (for state dumps / debugging).
    pub fn reg_names(&self) -> impl Iterator<Item = &str> {
        self.regs.keys().map(|s| s.as_str())
    }
}

/// Errors from stepping an ILA model.
#[derive(Debug, thiserror::Error)]
pub enum IlaError {
    #[error("no instruction of `{model}` decodes command {cmd}")]
    NoDecode { model: String, cmd: String },
    #[error("instructions `{a}` and `{b}` of `{model}` both decode {cmd} — ILA determinism violated")]
    Ambiguous { model: String, a: String, b: String, cmd: String },
    #[error("instruction `{instr}` failed: {msg}")]
    Update { instr: String, msg: String },
}

/// Decode predicate: does this interface command trigger this instruction?
pub type DecodeFn = Arc<dyn Fn(&Cmd, &IlaState) -> bool + Send + Sync>;
/// State update function; may return read-back data (for RD commands).
pub type UpdateFn =
    Arc<dyn Fn(&Cmd, &mut IlaState) -> Result<Option<[u8; 16]>, String> + Send + Sync>;

/// One ILA instruction.
#[derive(Clone)]
pub struct Instr {
    /// Instruction name (as in the ILAng model).
    pub name: String,
    /// Which interface commands trigger this instruction.
    pub decode: DecodeFn,
    /// State update (may produce read-back data).
    pub update: UpdateFn,
}

impl fmt::Debug for Instr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Instr({})", self.name)
    }
}

/// A declared operand-staging window: an MMIO address range that maps
/// 1:1 onto a byte range of one architectural memory which only the
/// **host** ever writes (the device reads it but never mutates it
/// internally — that invariant is what makes engine-level residency
/// tracking sound). See [`Ila::stage_region`].
#[derive(Debug, Clone)]
pub struct StagingRegion {
    /// Backing memory name.
    pub mem: String,
    /// First MMIO address of the window.
    pub mmio_base: u64,
    /// Window size in bytes (memory offset = addr − `mmio_base`).
    pub size: usize,
}

/// An ILA model: a named set of instructions plus initial state.
#[derive(Clone)]
pub struct Ila {
    /// Model name.
    pub name: String,
    /// The instruction set.
    pub instrs: Vec<Instr>,
    /// Architectural reset state.
    pub init_state: IlaState,
    /// Declared operand-staging windows (see [`Self::stage_region`]).
    pub staging: Vec<StagingRegion>,
    /// Residency hazards: `(mmio_addr, mem)` pairs declaring that a write
    /// to `mmio_addr` may mutate `mem` internally (e.g. a DMA doorbell
    /// copying into a scratchpad), so any residency assumption about
    /// `mem` must be dropped when such a command executes.
    pub hazards: Vec<(u64, String)>,
}

impl Ila {
    /// A model with no instructions yet.
    pub fn new(name: &str, init_state: IlaState) -> Self {
        Ila {
            name: name.to_string(),
            instrs: Vec::new(),
            init_state,
            staging: Vec::new(),
            hazards: Vec::new(),
        }
    }

    /// Declare an operand-staging window: MMIO range
    /// `[mmio_base, mmio_base + size)` backs memory `mem` byte-for-byte,
    /// and `mem` is **host-exclusive** (no instruction of this model
    /// writes it internally, except via doorbells declared with
    /// [`Self::hazard`]). Execution engines use these declarations to
    /// keep fingerprinted operand bursts device-resident across
    /// invocations and skip re-streaming them.
    pub fn stage_region(&mut self, mem: &str, mmio_base: u64, size: usize) {
        assert!(
            self.init_state.mems.get(mem).is_some_and(|m| m.len() >= size),
            "staging region over unknown/short memory `{mem}`"
        );
        self.staging.push(StagingRegion { mem: mem.to_string(), mmio_base, size });
    }

    /// Declare that a write to `addr` (a DMA/copy doorbell) may mutate
    /// `mem` internally — engines must invalidate residency for `mem`
    /// when streaming such a command.
    pub fn hazard(&mut self, addr: u64, mem: &str) {
        self.hazards.push((addr, mem.to_string()));
    }

    /// Map an MMIO byte range onto its staging memory: `Some((mem, lo,
    /// hi))` when `[base, base + len)` lies entirely inside one declared
    /// window, else `None` (the range is not residency-trackable).
    pub fn staging_for(&self, base: u64, len: usize) -> Option<(&str, usize, usize)> {
        self.staging.iter().find_map(|r| {
            let end = r.mmio_base + r.size as u64;
            (base >= r.mmio_base && base + len as u64 <= end).then(|| {
                let lo = (base - r.mmio_base) as usize;
                (r.mem.as_str(), lo, lo + len)
            })
        })
    }

    /// Add an instruction (builder style, mirroring ILAng's `NewInstr`).
    pub fn instr(
        &mut self,
        name: &str,
        decode: impl Fn(&Cmd, &IlaState) -> bool + Send + Sync + 'static,
        update: impl Fn(&Cmd, &mut IlaState) -> Result<Option<[u8; 16]>, String>
            + Send
            + Sync
            + 'static,
    ) {
        self.instrs.push(Instr {
            name: name.to_string(),
            decode: Arc::new(decode),
            update: Arc::new(update),
        });
    }

    /// Which instruction (if any) decodes `cmd` in `state`; errors when
    /// more than one does (ILA instructions must be deterministic).
    pub fn decode(&self, cmd: &Cmd, state: &IlaState) -> Result<&Instr, IlaError> {
        let mut hit: Option<&Instr> = None;
        for ins in &self.instrs {
            if (ins.decode)(cmd, state) {
                if let Some(prev) = hit {
                    return Err(IlaError::Ambiguous {
                        model: self.name.clone(),
                        a: prev.name.clone(),
                        b: ins.name.clone(),
                        cmd: cmd.to_string(),
                    });
                }
                hit = Some(ins);
            }
        }
        hit.ok_or_else(|| IlaError::NoDecode {
            model: self.name.clone(),
            cmd: cmd.to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_ila() -> Ila {
        // two registers and one memory; three instructions
        let mut st = IlaState::new();
        st.new_bv("cfg", 16);
        st.new_bv("busy", 1);
        st.new_mem("buf", 64);
        let mut ila = Ila::new("toy", st);
        ila.instr(
            "set_cfg",
            |c, _| c.is_write && c.addr == 0x10,
            |c, s| {
                s.set_reg("cfg", c.data_u64());
                Ok(None)
            },
        );
        ila.instr(
            "write_buf",
            |c, _| c.is_write && (0x100..0x140).contains(&c.addr),
            |c, s| {
                let off = (c.addr - 0x100) as usize;
                s.mem_mut("buf")[off..off + 16].copy_from_slice(&c.data);
                Ok(None)
            },
        );
        ila.instr(
            "read_buf",
            |c, _| !c.is_write && (0x100..0x140).contains(&c.addr),
            |c, s| {
                let off = (c.addr - 0x100) as usize;
                let mut out = [0u8; 16];
                out.copy_from_slice(&s.mem("buf")[off..off + 16]);
                Ok(Some(out))
            },
        );
        ila
    }

    #[test]
    fn decode_selects_unique_instruction() {
        let ila = toy_ila();
        let st = ila.init_state.clone();
        let i = ila.decode(&Cmd::write_u64(0x10, 7), &st).unwrap();
        assert_eq!(i.name, "set_cfg");
        let i = ila.decode(&Cmd::read(0x100), &st).unwrap();
        assert_eq!(i.name, "read_buf");
    }

    #[test]
    fn decode_rejects_unknown_address() {
        let ila = toy_ila();
        let st = ila.init_state.clone();
        assert!(matches!(
            ila.decode(&Cmd::write_u64(0xDEAD, 0), &st),
            Err(IlaError::NoDecode { .. })
        ));
    }

    #[test]
    fn register_width_masking() {
        let mut st = IlaState::new();
        st.new_bv("r4", 4);
        st.set_reg("r4", 0xFF);
        assert_eq!(st.reg("r4"), 0xF);
    }

    #[test]
    fn cmd_display_matches_paper_trace_format() {
        let c = Cmd::write_u64(0xA0400010, 0x0010101000001);
        let s = c.to_string();
        assert!(s.starts_with("WR 0xA0400010, 0x"), "{s}");
    }
}
