//! The ILA simulator — Rust analogue of ILAng's generated C++ simulators.
//!
//! Executes interface-command programs against an [`Ila`] model,
//! maintaining architectural state across commands and collecting
//! read-back data. Also tracks per-instruction execution counts (the
//! "handy debugging information" of §4.4.2 that the paper's authors fed
//! back to the accelerator developers).

use super::{Cmd, Ila, IlaError, IlaState};
use std::collections::HashMap;

/// A running simulation of one ILA model.
pub struct IlaSim {
    pub model: Ila,
    pub state: IlaState,
    /// per-instruction execution counts
    pub instr_counts: HashMap<String, u64>,
    /// total commands executed
    pub steps: u64,
}

impl IlaSim {
    /// Instantiate a simulator with the model's initial state.
    pub fn new(model: Ila) -> Self {
        let state = model.init_state.clone();
        IlaSim { model, state, instr_counts: HashMap::new(), steps: 0 }
    }

    /// Reset to the initial state.
    pub fn reset(&mut self) {
        self.state = self.model.init_state.clone();
        self.instr_counts.clear();
        self.steps = 0;
    }

    /// Execute one interface command; returns read-back data when the
    /// instruction produces it.
    pub fn step(&mut self, cmd: &Cmd) -> Result<Option<[u8; 16]>, IlaError> {
        let instr = self.model.decode(cmd, &self.state)?.clone();
        self.steps += 1;
        *self.instr_counts.entry(instr.name.clone()).or_insert(0) += 1;
        (instr.update)(cmd, &mut self.state)
            .map_err(|msg| IlaError::Update { instr: instr.name.clone(), msg })
    }

    /// Execute a command program; returns all read-back words in order.
    pub fn run(&mut self, prog: &[Cmd]) -> Result<Vec<[u8; 16]>, IlaError> {
        let mut out = Vec::new();
        for cmd in prog {
            if let Some(d) = self.step(cmd)? {
                out.push(d);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counter_ila() -> Ila {
        let mut st = IlaState::new();
        st.new_bv("count", 32);
        let mut ila = Ila::new("counter", st);
        ila.instr(
            "increment",
            |c, _| c.is_write && c.addr == 0x0,
            |c, s| {
                let cur = s.reg("count");
                s.set_reg("count", cur + c.data_u64());
                Ok(None)
            },
        );
        ila.instr(
            "read_count",
            |c, _| !c.is_write && c.addr == 0x0,
            |_, s| {
                let mut out = [0u8; 16];
                out[..8].copy_from_slice(&s.reg("count").to_le_bytes());
                Ok(Some(out))
            },
        );
        ila
    }

    #[test]
    fn state_persists_across_commands() {
        let mut sim = IlaSim::new(counter_ila());
        let prog = vec![
            Cmd::write_u64(0, 5),
            Cmd::write_u64(0, 7),
            Cmd::read(0),
        ];
        let out = sim.run(&prog).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(u64::from_le_bytes(out[0][..8].try_into().unwrap()), 12);
        assert_eq!(sim.steps, 3);
        assert_eq!(sim.instr_counts["increment"], 2);
    }

    #[test]
    fn reset_restores_initial_state() {
        let mut sim = IlaSim::new(counter_ila());
        sim.step(&Cmd::write_u64(0, 9)).unwrap();
        sim.reset();
        let out = sim.step(&Cmd::read(0)).unwrap().unwrap();
        assert_eq!(u64::from_le_bytes(out[..8].try_into().unwrap()), 0);
    }
}
