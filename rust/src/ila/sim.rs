//! The ILA simulator — Rust analogue of ILAng's generated C++ simulators.
//!
//! Executes interface-command programs against an [`Ila`] model,
//! maintaining architectural state across commands and collecting
//! read-back data. Also tracks per-instruction execution counts (the
//! "handy debugging information" of §4.4.2 that the paper's authors fed
//! back to the accelerator developers).

use super::{Cmd, Ila, IlaError, IlaState};
use std::collections::HashMap;

/// A running simulation of one ILA model.
pub struct IlaSim {
    /// The ILA model being executed.
    pub model: Ila,
    /// Current architectural state.
    pub state: IlaState,
    /// per-instruction execution counts
    pub instr_counts: HashMap<String, u64>,
    /// total commands executed
    pub steps: u64,
    /// resets performed ([`Self::reset`] + [`Self::reset_dirty`])
    pub resets: u64,
    /// total bytes of memory state restored by resets; a full
    /// [`Self::reset`] counts the whole state, a [`Self::reset_dirty`]
    /// only what the previous program touched
    pub bytes_cleared: u64,
}

impl IlaSim {
    /// Instantiate a simulator with the model's initial state.
    pub fn new(model: Ila) -> Self {
        let state = model.init_state.clone();
        IlaSim {
            model,
            state,
            instr_counts: HashMap::new(),
            steps: 0,
            resets: 0,
            bytes_cleared: 0,
        }
    }

    /// Reset to the initial state by cloning it wholesale (the
    /// heavyweight baseline; ~0.3 MB for FlexASR). Prefer
    /// [`Self::reset_dirty`] between invocations.
    pub fn reset(&mut self) {
        self.bytes_cleared += self.state.total_mem_bytes();
        self.resets += 1;
        self.state = self.model.init_state.clone();
        self.instr_counts.clear();
        self.steps = 0;
    }

    /// Reset only the state the previous program(s) dirtied: registers
    /// are restored wholesale (they are few) and each memory rewinds just
    /// its dirty byte range. Equivalent to [`Self::reset`] for execution
    /// purposes — every subsequent decode sees the initial state — at a
    /// fraction of the memory traffic. The debug counters
    /// (`instr_counts`, `steps`) deliberately keep accumulating so a
    /// persistent engine reports per-session totals.
    pub fn reset_dirty(&mut self) {
        self.reset_dirty_keeping(&[]);
    }

    /// [`Self::reset_dirty`] that keeps the listed `(mem, lo, hi)` byte
    /// ranges device-resident instead of rewinding them — the execution
    /// engine passes the regions whose staged operand bursts it intends
    /// to reuse, so a persistent engine can skip re-streaming them (see
    /// [`crate::ila::IlaState::restore_from_keeping`]).
    pub fn reset_dirty_keeping(&mut self, keep: &[(String, usize, usize)]) {
        self.bytes_cleared +=
            self.state.restore_from_keeping(&self.model.init_state, keep);
        self.resets += 1;
    }

    /// Total bytes of this simulator's memories (what a full reset
    /// clones).
    pub fn state_bytes(&self) -> u64 {
        self.state.total_mem_bytes()
    }

    /// Execute one interface command; returns read-back data when the
    /// instruction produces it.
    pub fn step(&mut self, cmd: &Cmd) -> Result<Option<[u8; 16]>, IlaError> {
        let instr = self.model.decode(cmd, &self.state)?.clone();
        self.steps += 1;
        *self.instr_counts.entry(instr.name.clone()).or_insert(0) += 1;
        (instr.update)(cmd, &mut self.state)
            .map_err(|msg| IlaError::Update { instr: instr.name.clone(), msg })
    }

    /// Execute a command program; returns all read-back words in order.
    pub fn run(&mut self, prog: &[Cmd]) -> Result<Vec<[u8; 16]>, IlaError> {
        let mut out = Vec::new();
        for cmd in prog {
            if let Some(d) = self.step(cmd)? {
                out.push(d);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counter_ila() -> Ila {
        let mut st = IlaState::new();
        st.new_bv("count", 32);
        let mut ila = Ila::new("counter", st);
        ila.instr(
            "increment",
            |c, _| c.is_write && c.addr == 0x0,
            |c, s| {
                let cur = s.reg("count");
                s.set_reg("count", cur + c.data_u64());
                Ok(None)
            },
        );
        ila.instr(
            "read_count",
            |c, _| !c.is_write && c.addr == 0x0,
            |_, s| {
                let mut out = [0u8; 16];
                out[..8].copy_from_slice(&s.reg("count").to_le_bytes());
                Ok(Some(out))
            },
        );
        ila
    }

    #[test]
    fn state_persists_across_commands() {
        let mut sim = IlaSim::new(counter_ila());
        let prog = vec![
            Cmd::write_u64(0, 5),
            Cmd::write_u64(0, 7),
            Cmd::read(0),
        ];
        let out = sim.run(&prog).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(u64::from_le_bytes(out[0][..8].try_into().unwrap()), 12);
        assert_eq!(sim.steps, 3);
        assert_eq!(sim.instr_counts["increment"], 2);
    }

    #[test]
    fn reset_restores_initial_state() {
        let mut sim = IlaSim::new(counter_ila());
        sim.step(&Cmd::write_u64(0, 9)).unwrap();
        sim.reset();
        let out = sim.step(&Cmd::read(0)).unwrap().unwrap();
        assert_eq!(u64::from_le_bytes(out[..8].try_into().unwrap()), 0);
    }

    fn mem_ila() -> Ila {
        let mut st = IlaState::new();
        st.new_mem("buf", 1024);
        st.new_bv("cfg", 32);
        let mut ila = Ila::new("mem", st);
        ila.instr(
            "write_buf",
            |c, _| c.is_write && c.addr < 1024,
            |c, s| {
                s.mem_write("buf", c.addr as usize, &c.data);
                Ok(None)
            },
        );
        ila.instr(
            "read_buf",
            |c, _| !c.is_write && c.addr < 1024,
            |c, s| {
                let off = c.addr as usize;
                let mut out = [0u8; 16];
                out.copy_from_slice(&s.mem("buf")[off..off + 16]);
                Ok(Some(out))
            },
        );
        ila.instr(
            "set_cfg",
            |c, _| c.is_write && c.addr == 0x8000,
            |c, s| {
                s.set_reg("cfg", c.data_u64());
                Ok(None)
            },
        );
        ila
    }

    #[test]
    fn dirty_reset_restores_only_touched_bytes() {
        let mut sim = IlaSim::new(mem_ila());
        sim.step(&Cmd::write(64, [7u8; 16])).unwrap();
        sim.step(&Cmd::write(96, [9u8; 16])).unwrap();
        sim.step(&Cmd::write_u64(0x8000, 0xAB)).unwrap();
        sim.reset_dirty();
        // the whole architectural state is back to init...
        assert_eq!(sim.state.reg("cfg"), 0);
        let d = sim.step(&Cmd::read(96)).unwrap().unwrap();
        assert_eq!(d, [0u8; 16]);
        // ...but only the dirty watermark [64, 112) was rewound
        assert_eq!(sim.resets, 1);
        assert_eq!(sim.bytes_cleared, 48);
        assert!(sim.bytes_cleared < sim.state_bytes());
    }

    #[test]
    fn dirty_reset_keeping_preserves_resident_ranges() {
        let mut sim = IlaSim::new(mem_ila());
        sim.step(&Cmd::write(64, [7u8; 16])).unwrap();
        sim.step(&Cmd::write(96, [9u8; 16])).unwrap();
        sim.step(&Cmd::write_u64(0x8000, 0xAB)).unwrap();
        // keep [64, 80) staged; everything else rewinds (incl. registers)
        sim.reset_dirty_keeping(&[("buf".to_string(), 64, 80)]);
        assert_eq!(sim.state.mem("buf")[64], 7, "kept range must survive");
        assert_eq!(sim.state.mem("buf")[96], 0, "unkept range rewound");
        assert_eq!(sim.state.reg("cfg"), 0);
        // the kept bytes restored fewer bytes than a plain dirty reset
        assert_eq!(sim.bytes_cleared, 48 - 16);
        // the kept range is still dirty: a later plain reset rewinds it
        sim.reset_dirty();
        assert_eq!(sim.state.mem("buf")[64], 0);
    }

    #[test]
    fn dirty_reset_on_clean_sim_clears_nothing() {
        let mut sim = IlaSim::new(mem_ila());
        sim.reset_dirty();
        assert_eq!(sim.bytes_cleared, 0);
        // reads do not dirty state
        let _ = sim.step(&Cmd::read(0)).unwrap();
        sim.reset_dirty();
        assert_eq!(sim.bytes_cleared, 0);
        assert_eq!(sim.resets, 2);
    }

    #[test]
    fn legacy_mem_mut_is_conservatively_full_dirty() {
        let mut st = IlaState::new();
        st.new_mem("m", 256);
        let init = st.clone();
        let mut state = st;
        state.mem_mut("m")[3] = 5;
        assert_eq!(state.restore_from(&init), 256);
        assert_eq!(state.mem("m")[3], 0);
    }
}
