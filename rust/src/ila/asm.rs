//! ILA program fragments in assembly form — the Fig. 3(c)/Fig. 5(c)
//! representation sitting between compiler-IR fragments and raw MMIO
//! command streams.
//!
//! Each [`AsmInstr`] names an ILA instruction with symbolic operands; an
//! [`Fragment`] is the sequence for one accelerator operation. Fragments
//! are what VT2 (fragment-to-fragment equivalence) ranges over, and what
//! the code generator lowers 1:1 into MMIO commands (Fig. 5(c) → 5(d)).

use std::fmt;

/// One assembly-level ILA instruction with symbolic operand fields.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AsmInstr {
    /// e.g. "FlexASR_ILA.pe_cfg_rnn_layer_sizing"
    pub name: String,
    /// symbolic operands, e.g. ["%dim1", "%dim2"]
    pub operands: Vec<String>,
}

impl AsmInstr {
    /// Build an instruction from a name and symbolic operands.
    pub fn new(name: &str, operands: &[&str]) -> Self {
        AsmInstr {
            name: name.to_string(),
            operands: operands.iter().map(|s| s.to_string()).collect(),
        }
    }
}

impl fmt::Display for AsmInstr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name)?;
        for op in &self.operands {
            write!(f, " {op}")?;
        }
        Ok(())
    }
}

/// An ILA program fragment: the accelerator side of one IR-accelerator
/// mapping.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Fragment {
    /// The instructions, in program order.
    pub instrs: Vec<AsmInstr>,
}

impl Fragment {
    /// An empty fragment.
    pub fn new() -> Self {
        Fragment { instrs: Vec::new() }
    }

    /// Append an instruction (builder style).
    pub fn push(&mut self, name: &str, operands: &[&str]) -> &mut Self {
        self.instrs.push(AsmInstr::new(name, operands));
        self
    }

    /// Number of instructions.
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// True when the fragment has no instructions.
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }
}

impl fmt::Display for Fragment {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for i in &self.instrs {
            writeln!(f, "{i}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fragment_renders_like_fig5() {
        let mut frag = Fragment::new();
        frag.push("FlexASR_ILA.write_v", &["%addr", "%data"])
            .push("FlexASR_ILA.pe_cfg_rnn_layer_sizing", &["%dim1", "%dim2"])
            .push("FlexASR_ILA.fn_start", &[]);
        let s = frag.to_string();
        assert!(s.contains("FlexASR_ILA.write_v %addr %data"));
        assert!(s.lines().count() == 3);
    }
}
