//! Equality-saturation runner: applies a rule set to fixpoint under
//! node/iteration/time budgets (egg's `Runner`).

use super::rewrite::Rewrite;
use super::EGraph;
use std::time::{Duration, Instant};

/// Saturation budgets.
#[derive(Debug, Clone)]
pub struct RunnerLimits {
    pub max_iters: usize,
    pub max_nodes: usize,
    pub time_limit: Duration,
}

impl Default for RunnerLimits {
    fn default() -> Self {
        RunnerLimits {
            max_iters: 30,
            max_nodes: 200_000,
            time_limit: Duration::from_secs(30),
        }
    }
}

/// Why saturation stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// No rule produced a new union — a true fixed point.
    Saturated,
    IterLimit,
    NodeLimit,
    TimeLimit,
}

/// Per-iteration statistics (for the metrics module and EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct IterStats {
    pub unions: usize,
    pub classes: usize,
    pub nodes: usize,
}

/// Saturation driver.
pub struct Runner {
    pub limits: RunnerLimits,
    pub iterations: Vec<IterStats>,
    pub stop_reason: Option<StopReason>,
}

impl Default for Runner {
    fn default() -> Self {
        Self::new(RunnerLimits::default())
    }
}

impl Runner {
    pub fn new(limits: RunnerLimits) -> Self {
        Runner { limits, iterations: Vec::new(), stop_reason: None }
    }

    /// Run `rules` on `eg` until fixpoint or a budget trips.
    pub fn run(&mut self, eg: &mut EGraph, rules: &[Rewrite]) -> StopReason {
        let start = Instant::now();
        let reason = loop {
            if self.iterations.len() >= self.limits.max_iters {
                break StopReason::IterLimit;
            }
            if start.elapsed() > self.limits.time_limit {
                break StopReason::TimeLimit;
            }
            let mut unions = 0;
            for rule in rules {
                unions += rule.run(eg);
                if eg.nodes_added > self.limits.max_nodes {
                    break;
                }
            }
            eg.rebuild();
            self.iterations.push(IterStats {
                unions,
                classes: eg.num_classes(),
                nodes: eg.num_nodes(),
            });
            if eg.nodes_added > self.limits.max_nodes {
                break StopReason::NodeLimit;
            }
            if unions == 0 {
                break StopReason::Saturated;
            }
        };
        self.stop_reason = Some(reason);
        reason
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::pattern::dsl::*;
    use crate::ir::Op;
    use std::collections::HashMap;

    #[test]
    fn saturates_on_commutativity() {
        // add is commutative: (add ?a ?b) -> (add ?b ?a); a tiny graph
        // saturates quickly instead of looping forever.
        let mut eg = EGraph::new(HashMap::new());
        let a = eg.add(Op::Var("a".into()), vec![]);
        let b = eg.add(Op::Var("b".into()), vec![]);
        let ab = eg.add(Op::Add, vec![a, b]);
        let rules = vec![crate::egraph::Rewrite::pure(
            "add-comm",
            n(Op::Add, vec![v("x"), v("y")]),
            n(Op::Add, vec![v("y"), v("x")]),
        )];
        let mut runner = Runner::default();
        let reason = runner.run(&mut eg, &rules);
        assert_eq!(reason, StopReason::Saturated);
        // (add b a) is now in the same class
        let ba = eg.add(Op::Add, vec![b, a]);
        assert_eq!(eg.find(ba), eg.find(ab));
    }

    #[test]
    fn self_referential_rule_still_saturates() {
        // relu(x) -> relu(relu(x)) folds into a cyclic class: the e-graph
        // represents the infinite unrolling finitely and saturates.
        let mut eg = EGraph::new(HashMap::new());
        let a = eg.add(Op::Var("a".into()), vec![]);
        let _r = eg.add(Op::Relu, vec![a]);
        let rules = vec![crate::egraph::Rewrite::pure(
            "relu-grow",
            n(Op::Relu, vec![v("x")]),
            n(Op::Relu, vec![n(Op::Relu, vec![v("x")])]),
        )];
        let mut runner = Runner::default();
        let reason = runner.run(&mut eg, &rules);
        assert_eq!(reason, StopReason::Saturated);
    }

    #[test]
    fn node_limit_trips() {
        // a genuinely exploding dynamic rule: every application introduces
        // a fresh leaf, so the graph grows without bound.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        let mut eg = EGraph::new(HashMap::new());
        let a = eg.add(Op::Var("a".into()), vec![]);
        let _r = eg.add(Op::Relu, vec![a]);
        let rules = vec![crate::egraph::Rewrite::dynamic(
            "fresh-leaf-grow",
            n(Op::Relu, vec![v("x")]),
            move |eg, m| {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                let fresh = eg.add(Op::Var(format!("fresh{i}")), vec![]);
                let x = m.subst.class("x");
                let sum = eg.add(Op::Add, vec![x, fresh]);
                Some(eg.add(Op::Relu, vec![sum]))
            },
        )];
        let mut runner = Runner::new(RunnerLimits {
            max_iters: 1000,
            max_nodes: 50,
            time_limit: Duration::from_secs(5),
        });
        let reason = runner.run(&mut eg, &rules);
        assert_eq!(reason, StopReason::NodeLimit);
    }
}
