//! Equality-saturation runner: applies a rule set to fixpoint under
//! node/iteration/time budgets (egg's `Runner`).
//!
//! Two hot-path mechanisms ride on top of the plain loop:
//!
//! * searches are *op-indexed* ([`SearchStrategy::Indexed`]): each rule
//!   probes only the classes containing its pattern root's op family,
//!   and the probed-candidate counts are recorded per iteration in
//!   [`IterStats::candidates`];
//! * an egg-style [`BackoffScheduler`] bans rules whose match count
//!   explodes (e.g. commutativity-shaped rules) for a few iterations
//!   with exponentially growing thresholds, instead of re-matching and
//!   re-applying them every round. A fixpoint is only reported as
//!   [`StopReason::Saturated`] when no rule was banned that iteration;
//!   otherwise bans are cleared and saturation is re-checked.
//!
//! Budgets are enforced *between rules*, not just between iterations, so
//! one slow iteration cannot overshoot the time limit arbitrarily.
//! [`Runner::reference`] disables both mechanisms (full scan, no
//! scheduler) — the behavioural baseline the parity tests compare
//! against.

use super::pattern::SearchStrategy;
use super::rewrite::Rewrite;
use super::EGraph;
use std::time::{Duration, Instant};

/// Saturation budgets.
#[derive(Debug, Clone)]
pub struct RunnerLimits {
    /// Maximum saturation iterations.
    pub max_iters: usize,
    /// Node-count budget for the e-graph.
    pub max_nodes: usize,
    /// Wall-clock budget.
    pub time_limit: Duration,
}

impl Default for RunnerLimits {
    fn default() -> Self {
        RunnerLimits {
            max_iters: 30,
            max_nodes: 200_000,
            time_limit: Duration::from_secs(30),
        }
    }
}

/// Why saturation stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// No rule produced a new union (and none was banned) — a true
    /// fixed point.
    Saturated,
    IterLimit,
    NodeLimit,
    TimeLimit,
}

/// Per-iteration statistics (for the metrics module and EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct IterStats {
    /// New unions made this iteration.
    pub unions: usize,
    /// Canonical e-classes after this iteration's rebuild.
    pub classes: usize,
    /// Total e-nodes after this iteration's rebuild.
    pub nodes: usize,
    /// Root-candidate classes probed by all rule searches this iteration
    /// — the op-index effectiveness metric: under
    /// [`SearchStrategy::FullScan`] this is rules × classes; under
    /// [`SearchStrategy::Indexed`] only classes holding each rule's root
    /// op family are counted.
    pub candidates: usize,
    /// Matches found across all rules this iteration.
    pub matches: usize,
    /// Rules skipped this iteration because the backoff scheduler had
    /// banned them (or banned them on sight of an exploding match set).
    pub skipped_rules: usize,
}

/// Egg-style backoff rule scheduler: when a rule produces more than
/// `match_limit << times_banned` matches in one iteration, its matches
/// are *not* applied and the rule is banned for
/// `ban_length << times_banned` iterations. Exploding rules thus get
/// exponentially rarer (and exponentially larger quotas) instead of
/// dominating every round.
#[derive(Debug, Clone)]
pub struct BackoffScheduler {
    /// Base match budget per rule per iteration.
    pub match_limit: usize,
    /// Base ban duration, in iterations.
    pub ban_length: usize,
    stats: Vec<RuleBackoff>,
}

#[derive(Debug, Clone, Copy, Default)]
struct RuleBackoff {
    times_banned: u32,
    banned_until: usize,
}

impl Default for BackoffScheduler {
    /// Defaults are deliberately generous (10k matches) so well-behaved
    /// rule sets — including every seed app — never trip the scheduler
    /// and saturation results stay bit-identical to an unscheduled run.
    fn default() -> Self {
        BackoffScheduler::new(10_000, 4)
    }
}

impl BackoffScheduler {
    /// Scheduler with an initial match limit and base ban length.
    pub fn new(match_limit: usize, ban_length: usize) -> Self {
        BackoffScheduler { match_limit, ban_length, stats: Vec::new() }
    }

    /// Size the per-rule state for a rule set (clears previous bans).
    fn reset(&mut self, n_rules: usize) {
        self.stats = vec![RuleBackoff::default(); n_rules];
    }

    /// Is `rule` banned during `iter`?
    fn banned(&self, rule: usize, iter: usize) -> bool {
        self.stats.get(rule).is_some_and(|s| iter < s.banned_until)
    }

    /// Record a search outcome; returns true when the rule just got
    /// banned (its matches must then be discarded, not applied).
    fn observe(&mut self, rule: usize, iter: usize, n_matches: usize) -> bool {
        let Some(s) = self.stats.get_mut(rule) else {
            return false;
        };
        let shift = s.times_banned.min(20);
        let threshold = self.match_limit.saturating_mul(1 << shift);
        if n_matches > threshold {
            s.banned_until = iter + self.ban_length.saturating_mul(1 << shift).max(1);
            s.times_banned += 1;
            true
        } else {
            false
        }
    }

    /// Lift all bans (used when the unbanned rules reach a fixpoint, so
    /// saturation can be re-checked with the full rule set).
    fn clear_bans(&mut self) {
        for s in &mut self.stats {
            s.banned_until = 0;
        }
    }
}

/// Saturation driver.
pub struct Runner {
    /// Saturation budgets.
    pub limits: RunnerLimits,
    /// Per-iteration statistics, filled as saturation runs.
    pub iterations: Vec<IterStats>,
    /// Why the run stopped (set by `run`).
    pub stop_reason: Option<StopReason>,
    /// Backoff scheduler; `None` applies every rule every iteration.
    pub scheduler: Option<BackoffScheduler>,
    /// Root-candidate seeding strategy for every rule search.
    pub strategy: SearchStrategy,
}

impl Default for Runner {
    fn default() -> Self {
        Self::new(RunnerLimits::default())
    }
}

impl Runner {
    /// The production configuration: op-indexed search + backoff
    /// scheduling.
    pub fn new(limits: RunnerLimits) -> Self {
        Runner {
            limits,
            iterations: Vec::new(),
            stop_reason: None,
            scheduler: Some(BackoffScheduler::default()),
            strategy: SearchStrategy::Indexed,
        }
    }

    /// The reference configuration: full-scan search, no scheduler — the
    /// pre-index behaviour, kept for parity tests and benchmarks.
    pub fn reference(limits: RunnerLimits) -> Self {
        Runner {
            limits,
            iterations: Vec::new(),
            stop_reason: None,
            scheduler: None,
            strategy: SearchStrategy::FullScan,
        }
    }

    /// Total root-candidate classes probed across all iterations.
    pub fn total_candidates(&self) -> usize {
        self.iterations.iter().map(|i| i.candidates).sum()
    }

    /// Total matches found across all iterations.
    pub fn total_matches(&self) -> usize {
        self.iterations.iter().map(|i| i.matches).sum()
    }

    fn push_iter(
        &mut self,
        eg: &EGraph,
        unions: usize,
        candidates: usize,
        matches: usize,
        skipped_rules: usize,
    ) {
        self.iterations.push(IterStats {
            unions,
            classes: eg.num_classes(),
            nodes: eg.num_nodes(),
            candidates,
            matches,
            skipped_rules,
        });
    }

    /// Run `rules` on `eg` until fixpoint or a budget trips.
    pub fn run(&mut self, eg: &mut EGraph, rules: &[Rewrite]) -> StopReason {
        let start = Instant::now();
        if let Some(s) = &mut self.scheduler {
            s.reset(rules.len());
        }
        let reason = 'run: loop {
            if self.iterations.len() >= self.limits.max_iters {
                break StopReason::IterLimit;
            }
            if start.elapsed() > self.limits.time_limit {
                break StopReason::TimeLimit;
            }
            let iter = self.iterations.len();
            let mut unions = 0usize;
            let mut candidates = 0usize;
            let mut matches = 0usize;
            let mut skipped = 0usize;
            let mut ran = 0usize;
            let mut node_limit_hit = false;
            for (ri, rule) in rules.iter().enumerate() {
                // between-rules budget check: one slow iteration must not
                // blow the time limit arbitrarily
                if start.elapsed() > self.limits.time_limit {
                    eg.rebuild();
                    self.push_iter(eg, unions, candidates, matches, skipped);
                    break 'run StopReason::TimeLimit;
                }
                if self.scheduler.as_ref().is_some_and(|s| s.banned(ri, iter)) {
                    skipped += 1;
                    continue;
                }
                let (ms, probed) = rule.searcher.search_with(eg, self.strategy);
                candidates += probed;
                matches += ms.len();
                if self.scheduler.as_mut().is_some_and(|s| s.observe(ri, iter, ms.len())) {
                    // banned on sight: the match explosion is discarded
                    skipped += 1;
                    continue;
                }
                unions += rule.apply_matches(eg, &ms);
                ran += 1;
                if eg.nodes_added > self.limits.max_nodes {
                    node_limit_hit = true;
                    break;
                }
            }
            eg.rebuild();
            self.push_iter(eg, unions, candidates, matches, skipped);
            // a fixpoint with no banned rules is genuine saturation — even
            // when the node budget is also exhausted, the graph stopped
            // changing, so don't mislabel the stop reason. It only counts
            // when *every* rule actually ran: a node-limit break that
            // skipped the tail of the rule list proves nothing.
            if unions == 0 && skipped == 0 && ran == rules.len() {
                break StopReason::Saturated;
            }
            if node_limit_hit || eg.nodes_added > self.limits.max_nodes {
                break StopReason::NodeLimit;
            }
            if unions == 0 {
                // only banned rules remain; lift bans and re-check
                if let Some(s) = &mut self.scheduler {
                    s.clear_bans();
                }
            }
        };
        self.stop_reason = Some(reason);
        reason
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::pattern::dsl::*;
    use crate::ir::Op;
    use std::collections::HashMap;

    #[test]
    fn saturates_on_commutativity() {
        // add is commutative: (add ?a ?b) -> (add ?b ?a); a tiny graph
        // saturates quickly instead of looping forever.
        let mut eg = EGraph::new(HashMap::new());
        let a = eg.add(Op::Var("a".into()), vec![]);
        let b = eg.add(Op::Var("b".into()), vec![]);
        let ab = eg.add(Op::Add, vec![a, b]);
        let rules = vec![crate::egraph::Rewrite::pure(
            "add-comm",
            n(Op::Add, vec![v("x"), v("y")]),
            n(Op::Add, vec![v("y"), v("x")]),
        )];
        let mut runner = Runner::default();
        let reason = runner.run(&mut eg, &rules);
        assert_eq!(reason, StopReason::Saturated);
        // (add b a) is now in the same class
        let ba = eg.add(Op::Add, vec![b, a]);
        assert_eq!(eg.find(ba), eg.find(ab));
    }

    #[test]
    fn self_referential_rule_still_saturates() {
        // relu(x) -> relu(relu(x)) folds into a cyclic class: the e-graph
        // represents the infinite unrolling finitely and saturates.
        let mut eg = EGraph::new(HashMap::new());
        let a = eg.add(Op::Var("a".into()), vec![]);
        let _r = eg.add(Op::Relu, vec![a]);
        let rules = vec![crate::egraph::Rewrite::pure(
            "relu-grow",
            n(Op::Relu, vec![v("x")]),
            n(Op::Relu, vec![n(Op::Relu, vec![v("x")])]),
        )];
        let mut runner = Runner::default();
        let reason = runner.run(&mut eg, &rules);
        assert_eq!(reason, StopReason::Saturated);
    }

    #[test]
    fn node_limit_trips() {
        // a genuinely exploding dynamic rule: every application introduces
        // a fresh leaf, so the graph grows without bound.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        let mut eg = EGraph::new(HashMap::new());
        let a = eg.add(Op::Var("a".into()), vec![]);
        let _r = eg.add(Op::Relu, vec![a]);
        let rules = vec![crate::egraph::Rewrite::dynamic(
            "fresh-leaf-grow",
            n(Op::Relu, vec![v("x")]),
            move |eg, m| {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                let fresh = eg.add(Op::Var(format!("fresh{i}")), vec![]);
                let x = m.subst.class("x");
                let sum = eg.add(Op::Add, vec![x, fresh]);
                Some(eg.add(Op::Relu, vec![sum]))
            },
        )];
        let mut runner = Runner::new(RunnerLimits {
            max_iters: 1000,
            max_nodes: 50,
            time_limit: Duration::from_secs(5),
        });
        let reason = runner.run(&mut eg, &rules);
        assert_eq!(reason, StopReason::NodeLimit);
    }

    #[test]
    fn exhausted_node_budget_with_no_unions_is_saturation() {
        // the e-graph starts over the node budget, but no rule fires:
        // that is a fixpoint, not a node-limit stop (the seed mislabelled
        // this case as NodeLimit).
        let mut eg = EGraph::new(HashMap::new());
        let a = eg.add(Op::Var("a".into()), vec![]);
        let _r = eg.add(Op::Relu, vec![a]);
        let rules = vec![crate::egraph::Rewrite::pure(
            "never-matches",
            n(Op::Add, vec![v("x"), v("y")]),
            n(Op::Add, vec![v("y"), v("x")]),
        )];
        let mut runner = Runner::new(RunnerLimits {
            max_iters: 10,
            max_nodes: 0, // already exhausted by the two seed adds
            time_limit: Duration::from_secs(5),
        });
        let reason = runner.run(&mut eg, &rules);
        assert_eq!(reason, StopReason::Saturated);
    }

    #[test]
    fn node_limit_mid_rules_is_not_saturation() {
        // over budget after the first of two rules: the second rule never
        // ran, so the runner must not claim a fixpoint
        let mut eg = EGraph::new(HashMap::new());
        let a = eg.add(Op::Var("a".into()), vec![]);
        let _r = eg.add(Op::Relu, vec![a]);
        let comm = |x: &str, y: &str| {
            crate::egraph::Rewrite::pure(
                "swap",
                n(Op::Add, vec![v(x), v(y)]),
                n(Op::Add, vec![v(y), v(x)]),
            )
        };
        let rules = vec![comm("x", "y"), comm("p", "q")];
        let mut runner = Runner::new(RunnerLimits {
            max_iters: 10,
            max_nodes: 0, // already exhausted by the two seed adds
            time_limit: Duration::from_secs(5),
        });
        let reason = runner.run(&mut eg, &rules);
        assert_eq!(reason, StopReason::NodeLimit);
    }

    #[test]
    fn time_limit_checked_between_rules() {
        // the second rule sleeps past the budget: the runner must stop
        // mid-iteration instead of finishing every remaining rule.
        let mut eg = EGraph::new(HashMap::new());
        let a = eg.add(Op::Var("a".into()), vec![]);
        let _r = eg.add(Op::Relu, vec![a]);
        let slow = |_: &mut EGraph, _: &crate::egraph::pattern::Match| {
            std::thread::sleep(Duration::from_millis(30));
            None
        };
        let rules = vec![
            crate::egraph::Rewrite::dynamic("slow-1", n(Op::Relu, vec![v("x")]), slow),
            crate::egraph::Rewrite::dynamic("slow-2", n(Op::Relu, vec![v("x")]), slow),
        ];
        let mut runner = Runner::new(RunnerLimits {
            max_iters: 100,
            max_nodes: 1_000,
            time_limit: Duration::from_millis(10),
        });
        let reason = runner.run(&mut eg, &rules);
        assert_eq!(reason, StopReason::TimeLimit);
        assert_eq!(runner.iterations.len(), 1, "stopped inside the first iteration");
    }

    #[test]
    fn backoff_bans_exploding_rule_then_converges() {
        // with a match budget of 1, add-comm (2 matches on this graph)
        // gets banned on sight; the exponential threshold then admits it
        // and saturation is still reached.
        let mut eg = EGraph::new(HashMap::new());
        let a = eg.add(Op::Var("a".into()), vec![]);
        let b = eg.add(Op::Var("b".into()), vec![]);
        let c = eg.add(Op::Var("c".into()), vec![]);
        let _ab = eg.add(Op::Add, vec![a, b]);
        let _bc = eg.add(Op::Add, vec![b, c]);
        let rules = vec![crate::egraph::Rewrite::pure(
            "add-comm",
            n(Op::Add, vec![v("x"), v("y")]),
            n(Op::Add, vec![v("y"), v("x")]),
        )];
        let mut runner = Runner::default();
        runner.scheduler = Some(BackoffScheduler::new(1, 1));
        let reason = runner.run(&mut eg, &rules);
        assert_eq!(reason, StopReason::Saturated);
        assert!(
            runner.iterations.iter().any(|i| i.skipped_rules > 0),
            "the rule must have been banned at least once"
        );
        // the commuted nodes did get built eventually
        let ba = eg.add(Op::Add, vec![b, a]);
        let ab2 = eg.add(Op::Add, vec![a, b]);
        assert_eq!(eg.find(ba), eg.find(ab2));
    }

    #[test]
    fn iter_stats_expose_candidate_counts() {
        let build = || {
            let mut eg = EGraph::new(HashMap::new());
            let a = eg.add(Op::Var("a".into()), vec![]);
            let b = eg.add(Op::Var("b".into()), vec![]);
            let _ab = eg.add(Op::Add, vec![a, b]);
            eg
        };
        let rules = vec![crate::egraph::Rewrite::pure(
            "add-comm",
            n(Op::Add, vec![v("x"), v("y")]),
            n(Op::Add, vec![v("y"), v("x")]),
        )];
        let mut eg = build();
        let mut eg2 = build();
        let mut indexed = Runner::default();
        indexed.run(&mut eg, &rules);
        let mut reference = Runner::reference(RunnerLimits::default());
        reference.run(&mut eg2, &rules);
        assert!(indexed.total_candidates() > 0);
        assert!(
            indexed.total_candidates() < reference.total_candidates(),
            "indexed search must probe strictly fewer classes: {} vs {}",
            indexed.total_candidates(),
            reference.total_candidates()
        );
    }
}
