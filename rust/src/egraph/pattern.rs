//! Pattern language and e-matching.
//!
//! Patterns are trees of operator nodes and pattern variables (`?x`).
//! Operator positions may be exact ([`PatternNode::Node`]) or predicated
//! ([`PatternNode::AnyOp`]) — the latter matches a family of operators
//! (e.g. `Conv2d` with any stride/pad) and records the concrete operator
//! in the substitution so dynamic appliers can transfer its parameters to
//! the right-hand side.
//!
//! Searches are *op-indexed* by default: the e-graph's op-head index
//! (see [`super::EGraph::classes_in_family`]) seeds matching with only
//! the classes that contain the pattern root's operator family, instead
//! of probing every class. `AnyOp` roots declare the families their
//! predicate can accept via [`dsl::any_of`]; an un-hinted `AnyOp` or a
//! bare variable root falls back to the full scan. The unindexed scan
//! survives as [`SearchStrategy::FullScan`] — the reference the parity
//! tests compare against.

use super::{op_family, EGraph, OpFamily};
use crate::ir::{Id, Op};
use std::collections::HashMap;
use std::sync::Arc;

/// Shared pattern handle. Patterns are DAGs: repeated subtrees (e.g. the
/// 4 gate references to the same `gates` subterm in the unrolled-LSTM
/// pattern) are shared, so cloning is O(1) and deep recurrent patterns
/// stay linear in size.
pub type Pat = Arc<PatternNode>;

/// Operator predicate for `AnyOp` pattern positions.
pub type OpPred = fn(&Op) -> bool;

/// A pattern node (always handled through [`Pat`]).
#[derive(Clone)]
pub enum PatternNode {
    /// Pattern variable `?name`: matches any e-class, binds it.
    Var(String),
    /// Exact operator with sub-patterns.
    Node(Op, Vec<Pat>),
    /// Predicated operator: matches any op satisfying `pred`; the concrete
    /// op is bound under `bind` in the substitution. `family_hints` lists
    /// sample ops of every family the predicate can accept so a root-level
    /// `AnyOp` can seed from the op-head index; an empty list means
    /// "unknown — scan every class". A hint list that omits a family the
    /// predicate accepts would silently drop root matches, so hints are
    /// declared next to the predicate (see [`dsl::any_of`]).
    AnyOp { bind: String, pred: OpPred, family_hints: Vec<Op>, children: Vec<Pat> },
}

impl std::fmt::Debug for PatternNode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PatternNode::Var(v) => write!(f, "?{v}"),
            PatternNode::Node(op, ch) => write!(f, "({} {ch:?})", op.head()),
            PatternNode::AnyOp { bind, children, .. } => {
                write!(f, "(<{bind}> {children:?})")
            }
        }
    }
}

/// A top-level pattern.
#[derive(Debug, Clone)]
pub struct Pattern {
    /// The root pattern node.
    pub root: Pat,
}

/// One substitution: pattern-var -> e-class, op-binder -> concrete op.
#[derive(Debug, Clone, Default)]
pub struct Subst {
    /// Pattern-variable bindings (`?x` -> e-class).
    pub vars: HashMap<String, Id>,
    /// Op-binder bindings (`AnyOp` -> concrete op).
    pub ops: HashMap<String, Op>,
}

impl Subst {
    /// Bound e-class for `?name` (panics when the rewrite promised it).
    pub fn class(&self, name: &str) -> Id {
        *self.vars.get(name).unwrap_or_else(|| panic!("unbound pattern var ?{name}"))
    }

    /// Bound operator for an `AnyOp` binder.
    pub fn op(&self, name: &str) -> &Op {
        self.ops.get(name).unwrap_or_else(|| panic!("unbound op binder <{name}>"))
    }
}

/// A match: the e-class the pattern root matched, plus the substitution.
#[derive(Debug, Clone)]
pub struct Match {
    /// The e-class the pattern root matched.
    pub class: Id,
    /// The substitution that made it match.
    pub subst: Subst,
}

/// How a search seeds its root candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SearchStrategy {
    /// Seed from the op-head index: probe only classes containing the
    /// pattern root's operator family.
    #[default]
    Indexed,
    /// Probe every e-class (the pre-index behaviour) — kept as the
    /// reference implementation for parity tests and benchmarks.
    FullScan,
}

impl Pattern {
    /// Build from a pattern node.
    pub fn new(root: Pat) -> Self {
        Pattern { root }
    }

    /// Op families that can root a match, or `None` when any class can
    /// (variable roots and un-hinted `AnyOp` roots).
    pub fn root_families(&self) -> Option<Vec<OpFamily>> {
        match self.root.as_ref() {
            PatternNode::Var(_) => None,
            PatternNode::Node(op, _) => Some(vec![op_family(op)]),
            PatternNode::AnyOp { family_hints, .. } => {
                if family_hints.is_empty() {
                    None
                } else {
                    Some(family_hints.iter().map(op_family).collect())
                }
            }
        }
    }

    /// Search the whole e-graph; returns every (class, substitution) pair.
    pub fn search(&self, eg: &EGraph) -> Vec<Match> {
        self.search_with(eg, SearchStrategy::Indexed).0
    }

    /// Search under an explicit strategy; returns the matches plus the
    /// number of root-candidate classes probed (the `IterStats` counter).
    pub fn search_with(&self, eg: &EGraph, strategy: SearchStrategy) -> (Vec<Match>, usize) {
        let candidates: Vec<Id> = match (strategy, self.root_families()) {
            (SearchStrategy::Indexed, Some(fams)) => {
                let mut ids: Vec<Id> = fams
                    .iter()
                    .filter_map(|&f| eg.classes_in_family(f))
                    .flat_map(|s| s.iter().copied())
                    .collect();
                ids.sort_unstable();
                ids.dedup();
                ids
            }
            _ => {
                let mut ids: Vec<Id> = eg.iter_classes().map(|(id, _)| id).collect();
                ids.sort_unstable();
                ids
            }
        };
        let mut out = Vec::new();
        let mut memo = MatchMemo::default();
        for &id in &candidates {
            self.search_class_memo(eg, id, &mut out, &mut memo);
        }
        (out, candidates.len())
    }

    /// Search one e-class.
    pub fn search_class(&self, eg: &EGraph, class: Id, out: &mut Vec<Match>) {
        let mut memo = MatchMemo::default();
        self.search_class_memo(eg, class, out, &mut memo);
    }

    fn search_class_memo(
        &self,
        eg: &EGraph,
        class: Id,
        out: &mut Vec<Match>,
        memo: &mut MatchMemo,
    ) {
        let mut subst = Subst::default();
        let mut results = Vec::new();
        match_node(&self.root, eg, eg.find_imm(class), &mut subst, &mut results, memo);
        for s in results {
            out.push(Match { class: eg.find_imm(class), subst: s });
        }
    }
}

/// Memo table for DAG-shaped patterns: (pattern node identity, e-class,
/// incoming bindings) -> completed substitutions. Without this, matching
/// a shared recurrent subpattern (the unrolled LSTM) re-expands the DAG
/// as a tree — exponential time.
type MemoKey = (usize, Id, Vec<(String, Id)>);

/// Per-search memo of subpattern matches (keyed by subpattern identity,
/// e-class, and the bindings in scope) — keeps DAG-shaped patterns from
/// re-expanding as trees.
#[derive(Default)]
pub struct MatchMemo {
    table: HashMap<MemoKey, Vec<Subst>>,
}

fn subst_fingerprint(s: &Subst) -> Vec<(String, Id)> {
    let mut v: Vec<(String, Id)> = s.vars.iter().map(|(k, &i)| (k.clone(), i)).collect();
    v.sort();
    v
}

/// Recursive backtracking e-matching: try to match `pat` against e-class
/// `class`, extending `subst`; push every completed substitution.
fn match_node(
    pat: &Pat,
    eg: &EGraph,
    class: Id,
    subst: &mut Subst,
    out: &mut Vec<Subst>,
    memo: &mut MatchMemo,
) {
    match pat.as_ref() {
        PatternNode::Var(name) => {
            if let Some(&bound) = subst.vars.get(name) {
                if eg.find_imm(bound) == class {
                    out.push(subst.clone());
                }
            } else {
                subst.vars.insert(name.clone(), class);
                out.push(subst.clone());
                subst.vars.remove(name);
            }
        }
        PatternNode::Node(op, children) => {
            // memoize only interior nodes with children (leaf ops are
            // cheap; sharing only pays off for subtrees)
            let key: MemoKey =
                (Arc::as_ptr(pat) as usize, class, subst_fingerprint(subst));
            if let Some(cached) = memo.table.get(&key) {
                out.extend(cached.iter().cloned());
                return;
            }
            let mut results = Vec::new();
            match_op_position(eg, class, subst, &mut results, children, &|n| n == op, None, memo);
            memo.table.insert(key, results.clone());
            out.extend(results);
        }
        PatternNode::AnyOp { bind, pred, children, .. } => match_op_position(
            eg,
            class,
            subst,
            out,
            children,
            &|n| pred(n),
            Some(bind.as_str()),
            memo,
        ),
    }
}

#[allow(clippy::too_many_arguments)]
fn match_op_position(
    eg: &EGraph,
    class: Id,
    subst: &mut Subst,
    out: &mut Vec<Subst>,
    children: &[Pat],
    op_ok: &dyn Fn(&Op) -> bool,
    bind: Option<&str>,
    memo: &mut MatchMemo,
) {
    let Some(eclass) = eg.classes.get(&eg.find_imm(class)) else {
        return;
    };
    for enode in &eclass.nodes {
        if !op_ok(&enode.op) || enode.children.len() != children.len() {
            continue;
        }
        // match children left-to-right, threading substitutions
        let mut partials = vec![subst.clone()];
        for (cp, &cc) in children.iter().zip(&enode.children) {
            let mut next = Vec::new();
            for mut p in partials {
                match_node(cp, eg, eg.find_imm(cc), &mut p, &mut next, memo);
            }
            partials = next;
            if partials.is_empty() {
                break;
            }
        }
        for mut p in partials {
            if let Some(b) = bind {
                p.ops.insert(b.to_string(), enode.op.clone());
            }
            out.push(p);
        }
    }
}

/// Instantiate a pattern tree into the e-graph under a substitution
/// (`AnyOp` positions are not allowed on right-hand sides).
pub fn instantiate(pat: &Pat, eg: &mut EGraph, subst: &Subst) -> Id {
    match pat.as_ref() {
        PatternNode::Var(name) => subst.class(name),
        PatternNode::Node(op, children) => {
            let ch: Vec<Id> = children.iter().map(|c| instantiate(c, eg, subst)).collect();
            eg.add(op.clone(), ch)
        }
        PatternNode::AnyOp { .. } => {
            panic!("AnyOp is a searcher-only construct; use a dynamic applier")
        }
    }
}

/// Terse constructors for building patterns in rewrite definitions.
pub mod dsl {
    use super::*;

    /// Pattern variable `?name`.
    pub fn v(name: &str) -> Pat {
        Arc::new(PatternNode::Var(name.to_string()))
    }

    /// Exact operator node.
    pub fn n(op: Op, children: Vec<Pat>) -> Pat {
        Arc::new(PatternNode::Node(op, children))
    }

    /// Predicated operator node with no family hints: sound anywhere, but
    /// as a pattern *root* it forces a full e-graph scan. Prefer
    /// [`any_of`] when the accepted families are known.
    pub fn any(bind: &str, pred: OpPred, children: Vec<Pat>) -> Pat {
        Arc::new(PatternNode::AnyOp {
            bind: bind.to_string(),
            pred,
            family_hints: Vec::new(),
            children,
        })
    }

    /// Predicated operator node with explicit family hints: `families`
    /// must contain a sample op of *every* family `pred` can accept
    /// (parameters are ignored — only the enum discriminant matters), so
    /// root-level searches can seed from the op-head index.
    pub fn any_of(bind: &str, pred: OpPred, families: Vec<Op>, children: Vec<Pat>) -> Pat {
        Arc::new(PatternNode::AnyOp {
            bind: bind.to_string(),
            pred,
            family_hints: families,
            children,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::dsl::*;
    use super::*;
    use crate::ir::shape::Shape;
    use std::collections::HashMap;

    fn env() -> HashMap<String, Shape> {
        [
            ("x".to_string(), vec![2usize, 4]),
            ("w".to_string(), vec![3, 4]),
            ("b".to_string(), vec![3]),
        ]
        .into_iter()
        .collect()
    }

    #[test]
    fn matches_linear_pattern() {
        let mut eg = EGraph::new(env());
        let x = eg.add(Op::Var("x".into()), vec![]);
        let w = eg.add(Op::Weight("w".into()), vec![]);
        let b = eg.add(Op::Weight("b".into()), vec![]);
        let d = eg.add(Op::Dense, vec![x, w]);
        let lin = eg.add(Op::BiasAdd, vec![d, b]);
        let pat = Pattern::new(n(
            Op::BiasAdd,
            vec![n(Op::Dense, vec![v("x"), v("w")]), v("b")],
        ));
        let ms = pat.search(&eg);
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].class, eg.find_imm(lin));
        assert_eq!(ms[0].subst.class("x"), x);
        assert_eq!(ms[0].subst.class("w"), w);
        assert_eq!(ms[0].subst.class("b"), b);
    }

    #[test]
    fn nonlinear_var_must_agree() {
        // pattern (add ?a ?a) matches add(x, x) but not add(x, w)
        let mut eg = EGraph::new(env());
        let x = eg.add(Op::Var("x".into()), vec![]);
        let w = eg.add(Op::Var("w2".into()), vec![]);
        let _xx = eg.add(Op::Add, vec![x, x]);
        let _xw = eg.add(Op::Add, vec![x, w]);
        let pat = Pattern::new(n(Op::Add, vec![v("a"), v("a")]));
        let ms = pat.search(&eg);
        assert_eq!(ms.len(), 1);
    }

    #[test]
    fn anyop_captures_parameters() {
        let mut eg = EGraph::new(HashMap::new());
        let x = eg.add(Op::Var("img".into()), vec![]);
        let w = eg.add(Op::Weight("k".into()), vec![]);
        let _c = eg.add(
            Op::Conv2d { stride: (2, 2), pad: (1, 1), groups: 1 },
            vec![x, w],
        );
        let pat = Pattern::new(any(
            "conv",
            |op| matches!(op, Op::Conv2d { groups: 1, .. }),
            vec![v("x"), v("w")],
        ));
        let ms = pat.search(&eg);
        assert_eq!(ms.len(), 1);
        assert!(matches!(
            ms[0].subst.op("conv"),
            Op::Conv2d { stride: (2, 2), pad: (1, 1), groups: 1 }
        ));
    }

    #[test]
    fn indexed_search_agrees_with_full_scan_and_probes_less() {
        let mut eg = EGraph::new(env());
        let x = eg.add(Op::Var("x".into()), vec![]);
        let w = eg.add(Op::Weight("w".into()), vec![]);
        let b = eg.add(Op::Weight("b".into()), vec![]);
        let d = eg.add(Op::Dense, vec![x, w]);
        let _lin = eg.add(Op::BiasAdd, vec![d, b]);
        let _r = eg.add(Op::Relu, vec![d]);
        let pat = Pattern::new(n(Op::Dense, vec![v("x"), v("w")]));
        let (indexed, probed_indexed) = pat.search_with(&eg, SearchStrategy::Indexed);
        let (full, probed_full) = pat.search_with(&eg, SearchStrategy::FullScan);
        assert_eq!(indexed.len(), 1);
        assert_eq!(full.len(), 1);
        assert_eq!(indexed[0].class, full[0].class);
        assert_eq!(probed_indexed, 1, "only the Dense class is probed");
        assert_eq!(probed_full, eg.num_classes());
    }

    #[test]
    fn any_of_hints_seed_from_index() {
        let mut eg = EGraph::new(HashMap::new());
        let x = eg.add(Op::Var("img".into()), vec![]);
        let w = eg.add(Op::Weight("k".into()), vec![]);
        let _c = eg.add(
            Op::Conv2d { stride: (2, 2), pad: (1, 1), groups: 1 },
            vec![x, w],
        );
        let pat = Pattern::new(any_of(
            "conv",
            |op| matches!(op, Op::Conv2d { groups: 1, .. }),
            vec![Op::Conv2d { stride: (1, 1), pad: (0, 0), groups: 1 }],
            vec![v("x"), v("w")],
        ));
        let (ms, probed) = pat.search_with(&eg, SearchStrategy::Indexed);
        assert_eq!(ms.len(), 1);
        assert_eq!(probed, 1, "family hint narrows the seed to the conv class");
        assert!(matches!(
            ms[0].subst.op("conv"),
            Op::Conv2d { stride: (2, 2), pad: (1, 1), groups: 1 }
        ));
    }

    #[test]
    fn instantiate_builds_rhs() {
        let mut eg = EGraph::new(env());
        let x = eg.add(Op::Var("x".into()), vec![]);
        let w = eg.add(Op::Weight("w".into()), vec![]);
        let b = eg.add(Op::Weight("b".into()), vec![]);
        let d = eg.add(Op::Dense, vec![x, w]);
        let _lin = eg.add(Op::BiasAdd, vec![d, b]);
        let pat = Pattern::new(n(
            Op::BiasAdd,
            vec![n(Op::Dense, vec![v("x"), v("w")]), v("b")],
        ));
        let ms = pat.search(&eg);
        let rhs = n(Op::FlexLinear, vec![v("x"), v("w"), v("b")]);
        let new_id = instantiate(&rhs, &mut eg, &ms[0].subst);
        assert!(eg.shape_of(new_id).is_some());
        assert_eq!(eg.shape_of(new_id), Some(&vec![2, 3]));
    }
}
