//! Union-find over e-class ids (path-halving find, union-by-size).

use crate::ir::Id;

/// Disjoint-set forest.
#[derive(Debug, Clone, Default)]
pub struct UnionFind {
    parent: Vec<Id>,
    size: Vec<u32>,
}

impl UnionFind {
    /// An empty forest.
    pub fn new() -> Self {
        UnionFind { parent: Vec::new(), size: Vec::new() }
    }

    /// Create a fresh singleton set; returns its id.
    pub fn make_set(&mut self) -> Id {
        let id = self.parent.len();
        self.parent.push(id);
        self.size.push(1);
        id
    }

    /// Number of ids ever created.
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    /// True when no sets exist.
    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Canonical representative (with path halving).
    pub fn find(&mut self, mut x: Id) -> Id {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]];
            x = self.parent[x];
        }
        x
    }

    /// Non-mutating find (no compression) — for read-only contexts.
    pub fn find_imm(&self, mut x: Id) -> Id {
        while self.parent[x] != x {
            x = self.parent[x];
        }
        x
    }

    /// Union two sets; returns the surviving root (larger set wins so
    /// e-class data migration is minimized).
    pub fn union(&mut self, a: Id, b: Id) -> (Id, Id) {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra == rb {
            return (ra, rb);
        }
        let (winner, loser) = if self.size[ra] >= self.size[rb] { (ra, rb) } else { (rb, ra) };
        self.parent[loser] = winner;
        self.size[winner] += self.size[loser];
        (winner, loser)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_find_basics() {
        let mut uf = UnionFind::new();
        let ids: Vec<_> = (0..8).map(|_| uf.make_set()).collect();
        assert_eq!(uf.find(ids[3]), ids[3]);
        uf.union(ids[0], ids[1]);
        uf.union(ids[1], ids[2]);
        assert_eq!(uf.find(ids[0]), uf.find(ids[2]));
        assert_ne!(uf.find(ids[0]), uf.find(ids[3]));
    }

    #[test]
    fn union_returns_winner_loser() {
        let mut uf = UnionFind::new();
        let a = uf.make_set();
        let b = uf.make_set();
        let c = uf.make_set();
        uf.union(a, b); // a's set has size 2
        let (w, l) = uf.union(a, c);
        assert_eq!(w, uf.find(a));
        assert_eq!(l, c);
    }
}
