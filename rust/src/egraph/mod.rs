//! An egg-style e-graph with equality saturation (Willsey et al., POPL'21),
//! built from scratch for the D2A flexible-matching pass (§2.2).
//!
//! The e-graph compactly represents the exponential space of rewritten
//! programs; saturation applies compiler-IR rewrites and IR-accelerator
//! rewrites to a fixed point (or a node/iteration budget); extraction then
//! picks the representative that maximizes accelerator offloads.
//!
//! Each e-class carries a *shape analysis* value (egg's "analysis"
//! mechanism): the inferred tensor shape, which shape-dependent dynamic
//! rewrites (dense+zero-add, im2col) consult.
//!
//! # The op-head index
//!
//! E-matching cost is dominated by the root probe: naively, every rule
//! scans every e-class on every iteration. The e-graph therefore keeps an
//! *op-head index* — operator family ([`OpFamily`], the enum discriminant
//! of [`Op`], so all `Conv2d` parameterizations share one family) →
//! the set of canonical classes containing at least one e-node of that
//! family. [`Pattern::search`](pattern::Pattern::search) seeds matching
//! from the index entry of the pattern root's family, turning the
//! per-iteration search from O(rules × classes) into
//! O(rules × candidate classes). The index is maintained through
//! [`EGraph::add`], [`EGraph::union`], and [`EGraph::rebuild`]; families
//! only ever accumulate per class (class node sets never shrink), so the
//! index is always exact: a class is indexed under a family iff one of
//! its nodes belongs to it.

pub mod extract;
pub mod pattern;
pub mod rewrite;
pub mod runner;
pub mod unionfind;

pub use extract::{AccelCost, CostFn, Extractor};
pub use pattern::{Pattern, SearchStrategy, Subst};
pub use rewrite::{Applier, Rewrite};
pub use runner::{BackoffScheduler, IterStats, Runner, RunnerLimits, StopReason};

use crate::ir::shape::{infer_op, Shape};
use crate::ir::{Id, Node, Op, RecExpr};
use std::collections::{HashMap, HashSet};
use unionfind::UnionFind;

/// Operator family key: the enum discriminant of [`Op`], so every
/// parameterization of an operator (`Conv2d` with any stride/pad/groups,
/// `Reshape` to any shape, …) maps to one family. This is the key of the
/// e-graph's op-head index.
pub type OpFamily = std::mem::Discriminant<Op>;

/// The op family of an operator (see [`OpFamily`]).
pub fn op_family(op: &Op) -> OpFamily {
    std::mem::discriminant(op)
}

/// One equivalence class of e-nodes.
#[derive(Debug, Clone, Default)]
pub struct EClass {
    /// E-nodes in this class (children canonical as of the last rebuild).
    pub nodes: Vec<Node>,
    /// Parent e-nodes (and the class they live in) — used for congruence
    /// repair during rebuild.
    pub parents: Vec<(Node, Id)>,
    /// Shape analysis value (None when inference failed / leaves unknown).
    pub shape: Option<Shape>,
}

/// The e-graph.
pub struct EGraph {
    uf: UnionFind,
    /// canonical id -> class (non-canonical keys are stale and absent).
    pub classes: HashMap<Id, EClass>,
    /// canonicalized node -> class id (the hashcons).
    memo: HashMap<Node, Id>,
    /// op family -> canonical classes containing a node of that family
    /// (the op-head index seeding e-matching).
    op_index: HashMap<OpFamily, HashSet<Id>>,
    /// classes touched by unions since the last rebuild (deduped when the
    /// worklist is drained).
    dirty: Vec<Id>,
    /// shapes of `Var`/`Weight` leaves for the shape analysis.
    pub shape_env: HashMap<String, Shape>,
    /// total e-nodes added (monotonic; the saturation budget metric).
    pub nodes_added: usize,
}

impl EGraph {
    /// Create an empty e-graph with the given leaf-shape environment.
    pub fn new(shape_env: HashMap<String, Shape>) -> Self {
        EGraph {
            uf: UnionFind::new(),
            classes: HashMap::new(),
            memo: HashMap::new(),
            op_index: HashMap::new(),
            dirty: Vec::new(),
            shape_env,
            nodes_added: 0,
        }
    }

    /// Canonical id.
    pub fn find(&mut self, id: Id) -> Id {
        self.uf.find(id)
    }

    /// Canonical id without path compression (immutable contexts).
    pub fn find_imm(&self, id: Id) -> Id {
        self.uf.find_imm(id)
    }

    /// Canonicalize a node's children.
    fn canonicalize(&mut self, node: &Node) -> Node {
        Node {
            op: node.op.clone(),
            children: node.children.iter().map(|&c| self.uf.find(c)).collect(),
        }
    }

    fn compute_shape(&self, node: &Node) -> Option<Shape> {
        let child_shapes: Option<Vec<&Shape>> = node
            .children
            .iter()
            .map(|&c| self.classes.get(&self.find_imm(c)).and_then(|cl| cl.shape.as_ref()))
            .collect();
        infer_op(&node.op, &child_shapes?, &self.shape_env).ok()
    }

    /// Add an e-node; returns its class id (existing class when the node
    /// is already present — hash-consing).
    pub fn add(&mut self, op: Op, children: Vec<Id>) -> Id {
        let node = self.canonicalize(&Node::new(op, children));
        if let Some(&id) = self.memo.get(&node) {
            return self.uf.find(id);
        }
        let id = self.uf.make_set();
        let shape = self.compute_shape(&node);
        let class = EClass { nodes: vec![node.clone()], parents: Vec::new(), shape };
        self.classes.insert(id, class);
        self.memo.insert(node.clone(), id);
        self.op_index.entry(op_family(&node.op)).or_default().insert(id);
        for &c in &node.children {
            let cc = self.uf.find(c);
            self.classes.get_mut(&cc).unwrap().parents.push((node.clone(), id));
        }
        self.nodes_added += 1;
        id
    }

    /// Add a whole RecExpr; returns the class of its root.
    pub fn add_expr(&mut self, expr: &RecExpr) -> Id {
        let mut map: Vec<Id> = Vec::with_capacity(expr.len());
        for node in &expr.nodes {
            let children = node.children.iter().map(|&c| map[c]).collect();
            map.push(self.add(node.op.clone(), children));
        }
        *map.last().expect("empty expr")
    }

    /// Assert two classes equal. Returns the canonical id; `changed` is
    /// false when they were already equal.
    pub fn union(&mut self, a: Id, b: Id) -> (Id, bool) {
        let ra = self.uf.find(a);
        let rb = self.uf.find(b);
        if ra == rb {
            return (ra, false);
        }
        let (winner, loser) = self.uf.union(ra, rb);
        let lost = self.classes.remove(&loser).expect("loser class must exist");
        // migrate the loser's op-index memberships to the winner so the
        // index stays canonical without a rebuild
        for node in &lost.nodes {
            if let Some(set) = self.op_index.get_mut(&op_family(&node.op)) {
                set.remove(&loser);
                set.insert(winner);
            }
        }
        let win = self.classes.get_mut(&winner).expect("winner class must exist");
        win.nodes.extend(lost.nodes);
        win.parents.extend(lost.parents);
        // merge analysis: shapes must agree when both known (they describe
        // the same value); keep whichever is known.
        win.shape = match (win.shape.take(), lost.shape) {
            (Some(a), Some(b)) => {
                debug_assert_eq!(a, b, "shape analysis disagrees on merged class");
                Some(a)
            }
            (a, b) => a.or(b),
        };
        self.dirty.push(winner);
        (winner, true)
    }

    /// Restore the congruence invariant after unions (egg's `rebuild`).
    ///
    /// The dirty worklist is drained in deduplicated batches: a class
    /// unioned many times between rebuilds is repaired once per batch
    /// instead of once per union.
    pub fn rebuild(&mut self) {
        while !self.dirty.is_empty() {
            let mut todo = std::mem::take(&mut self.dirty);
            for id in &mut todo {
                *id = self.uf.find(*id);
            }
            todo.sort_unstable();
            todo.dedup();
            for id in todo {
                self.repair(id);
            }
        }
        // refresh shapes where newly computable
        self.propagate_shapes();
    }

    /// Repair congruence around one dirty class (a step of `rebuild`).
    fn repair(&mut self, id: Id) {
        let id = self.uf.find(id);
        let parents = match self.classes.get_mut(&id) {
            Some(c) => std::mem::take(&mut c.parents),
            None => return,
        };
        let mut new_parents: Vec<(Node, Id)> = Vec::with_capacity(parents.len());
        for (pnode, pclass) in parents {
            let canon = self.canonicalize(&pnode);
            self.memo.remove(&pnode);
            let pclass = self.uf.find(pclass);
            if let Some(&existing) = self.memo.get(&canon) {
                // congruence: two parents became identical -> union
                let (_, changed) = self.union(existing, pclass);
                if changed {
                    // the union pushed onto dirty; continue
                }
            } else {
                self.memo.insert(canon.clone(), pclass);
            }
            new_parents.push((canon, self.uf.find(pclass)));
        }
        let id = self.uf.find(id);
        if let Some(c) = self.classes.get_mut(&id) {
            c.parents.extend(new_parents);
            // canonicalize and dedup the class's own nodes
            let mut nodes = std::mem::take(&mut c.nodes);
            for n in &mut nodes {
                for ch in &mut n.children {
                    *ch = self.uf.find_imm(*ch);
                }
            }
            nodes.sort_unstable();
            nodes.dedup();
            self.classes.get_mut(&id).unwrap().nodes = nodes;
        }
    }

    /// Propagate shape analysis to classes that gained computable shapes.
    fn propagate_shapes(&mut self) {
        loop {
            let mut updates: Vec<(Id, Shape)> = Vec::new();
            for (&id, class) in &self.classes {
                if class.shape.is_some() {
                    continue;
                }
                for node in &class.nodes {
                    if let Some(s) = self.compute_shape(node) {
                        updates.push((id, s));
                        break;
                    }
                }
            }
            if updates.is_empty() {
                break;
            }
            for (id, s) in updates {
                self.classes.get_mut(&id).unwrap().shape = Some(s);
            }
        }
    }

    /// Shape of a class, if known.
    pub fn shape_of(&self, id: Id) -> Option<&Shape> {
        self.classes.get(&self.find_imm(id)).and_then(|c| c.shape.as_ref())
    }

    /// Number of canonical e-classes.
    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    /// Total e-nodes across all classes.
    pub fn num_nodes(&self) -> usize {
        self.classes.values().map(|c| c.nodes.len()).sum()
    }

    /// Iterate canonical (id, class) pairs.
    pub fn iter_classes(&self) -> impl Iterator<Item = (Id, &EClass)> {
        self.classes.iter().map(|(&id, c)| (id, c))
    }

    /// Canonical classes containing at least one node of `fam` (None when
    /// no class ever held one). The returned ids are canonical as of the
    /// last union — no rebuild is needed before querying.
    pub fn classes_in_family(&self, fam: OpFamily) -> Option<&HashSet<Id>> {
        self.op_index.get(&fam)
    }

    /// Check the op-head index invariant (used by the property tests):
    /// a canonical class is indexed under a family iff one of its nodes
    /// belongs to that family, and no stale (non-canonical) ids linger.
    pub fn validate_op_index(&self) -> Result<(), String> {
        for (fam, ids) in &self.op_index {
            for &id in ids {
                if self.find_imm(id) != id {
                    return Err(format!("op index holds non-canonical id {id}"));
                }
                let class = self
                    .classes
                    .get(&id)
                    .ok_or_else(|| format!("op index holds dead class {id}"))?;
                if !class.nodes.iter().any(|n| op_family(&n.op) == *fam) {
                    return Err(format!(
                        "class {id} indexed under a family it lacks"
                    ));
                }
            }
        }
        for (id, class) in self.iter_classes() {
            for node in &class.nodes {
                let indexed = self
                    .op_index
                    .get(&op_family(&node.op))
                    .is_some_and(|s| s.contains(&id));
                if !indexed {
                    return Err(format!(
                        "class {id} has op {} but is not indexed under it",
                        node.op.head()
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> HashMap<String, Shape> {
        [("x".to_string(), vec![2usize, 4]), ("w".to_string(), vec![3, 4])]
            .into_iter()
            .collect()
    }

    #[test]
    fn hashcons_dedups() {
        let mut eg = EGraph::new(env());
        let x1 = eg.add(Op::Var("x".into()), vec![]);
        let x2 = eg.add(Op::Var("x".into()), vec![]);
        assert_eq!(x1, x2);
        assert_eq!(eg.num_classes(), 1);
    }

    #[test]
    fn shape_analysis_computed_on_add() {
        let mut eg = EGraph::new(env());
        let x = eg.add(Op::Var("x".into()), vec![]);
        let w = eg.add(Op::Weight("w".into()), vec![]);
        let d = eg.add(Op::Dense, vec![x, w]);
        assert_eq!(eg.shape_of(d), Some(&vec![2, 3]));
    }

    #[test]
    fn union_merges_and_rebuild_restores_congruence() {
        let mut eg = EGraph::new(env());
        let x = eg.add(Op::Var("x".into()), vec![]);
        let w = eg.add(Op::Weight("w".into()), vec![]);
        // two distinct leaves a, b
        let a = eg.add(Op::Var("a".into()), vec![]);
        let b = eg.add(Op::Var("b".into()), vec![]);
        let fa = eg.add(Op::Relu, vec![a]);
        let fb = eg.add(Op::Relu, vec![b]);
        assert_ne!(eg.find(fa), eg.find(fb));
        eg.union(a, b);
        eg.rebuild();
        // congruence: relu(a) == relu(b) after a == b
        assert_eq!(eg.find(fa), eg.find(fb));
        let _ = (x, w);
    }

    #[test]
    fn add_expr_roundtrip() {
        let mut g = crate::ir::GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        let d = g.dense(x, w);
        g.relu(d);
        let expr = g.finish();
        let mut eg = EGraph::new(env());
        let root = eg.add_expr(&expr);
        assert!(eg.classes.contains_key(&eg.find_imm(root)));
        assert_eq!(eg.num_classes(), 4);
    }
}
