//! Cost-based extraction from a saturated e-graph.
//!
//! The paper's proof-of-concept cost function "maximizes the number of
//! accelerator operations" (§3). We realize that as min-cost extraction
//! where accelerator invocations are near-free and host compute is
//! expensive in proportion to its arithmetic volume, so any available
//! offload is always selected and, among host implementations, cheaper
//! structure wins.

use super::EGraph;
use crate::ir::{Id, Node, Op, RecExpr, Target};
use std::collections::HashMap;

/// Operator cost model.
pub trait CostFn {
    fn op_cost(&self, op: &Op) -> f64;
}

/// The accelerator-maximizing cost model used for Table 1.
///
/// `enabled` restricts which accelerators are considered available: an op
/// for a *disabled* accelerator costs infinity so extraction can never
/// pick it (the paper compiles per-target).
pub struct AccelCost {
    /// Accelerator targets extraction may offload to.
    pub enabled: Vec<Target>,
}

impl AccelCost {
    /// Cost function with one enabled target.
    pub fn for_target(t: Target) -> Self {
        AccelCost { enabled: vec![t] }
    }

    /// Cost function with several enabled targets.
    pub fn for_targets(ts: &[Target]) -> Self {
        AccelCost { enabled: ts.to_vec() }
    }
}

impl CostFn for AccelCost {
    fn op_cost(&self, op: &Op) -> f64 {
        use Op::*;
        let target = op.target();
        if target != Target::Host && !self.enabled.contains(&target) {
            return f64::INFINITY;
        }
        match op {
            // leaves are free
            Var(_) | Weight(_) | ConstScalar(_) | ZeroTensor(_) => 0.0,
            // accelerator invocations: near-free so offloads always win
            FlexLinear | FlexLstm { .. } | FlexLstmFused { .. } | FlexLayerNorm | FlexMaxpool
            | FlexMeanpool | FlexAttention | HlscnnConv2d { .. } | VtaGemm
            | VtaAdd => 1.0,
            // accelerator data movement: cheap but non-zero, so the §5.1
            // store/load-cancellation rewrite strictly improves cost
            FlexMaxpStore | FlexMaxpLoad => 0.5,
            // host compute, scaled by rough arithmetic volume
            Lstm { steps } => 50_000.0 * *steps as f64,
            Conv2d { .. } => 100_000.0,
            Dense => 10_000.0,
            Attention => 20_000.0,
            LayerNorm => 2_000.0,
            MatMaxPool { .. } | MatMeanPool { .. } | MaxPool2d { .. }
            | AvgPool2d { .. } => 1_500.0,
            TempMaxPool | TempMeanPool => 1_000.0,
            Softmax | Gelu | Tanh | Sigmoid | Relu | Mul | Add | BiasAdd => 100.0,
            GlobalAvgPool => 100.0,
            // structural ops are cheap
            Reshape(_) | Transpose | Concat | ConcatRows | SliceStep { .. }
            | SliceCols { .. } | WindowsFlatten { .. } | Im2col { .. }
            | FromIm2col { .. } => 10.0,
        }
    }
}

/// Extracts the min-cost representative of each e-class.
pub struct Extractor<'a, C: CostFn> {
    eg: &'a EGraph,
    cost_fn: C,
    /// best (cost, node) per canonical class
    best: HashMap<Id, (f64, Node)>,
}

impl<'a, C: CostFn> Extractor<'a, C> {
    /// Compute best costs for every class (fixpoint over the possibly
    /// cyclic e-graph; classes with no finite-cost term stay absent).
    pub fn new(eg: &'a EGraph, cost_fn: C) -> Self {
        let mut ex = Extractor { eg, cost_fn, best: HashMap::new() };
        ex.compute();
        ex
    }

    fn node_cost(&self, node: &Node) -> Option<f64> {
        let mut total = self.cost_fn.op_cost(&node.op);
        if !total.is_finite() {
            return None;
        }
        for &c in &node.children {
            let cc = self.eg.find_imm(c);
            total += self.best.get(&cc)?.0;
        }
        total.is_finite().then_some(total)
    }

    fn compute(&mut self) {
        loop {
            let mut changed = false;
            for (id, class) in self.eg.iter_classes() {
                for node in &class.nodes {
                    if let Some(cost) = self.node_cost(node) {
                        // Tree costs of deeply shared graphs (the unrolled
                        // LSTM) grow past f64 resolution, where a cheaper
                        // op no longer registers as strictly better; break
                        // ties by local op cost so accelerator ops still
                        // win (relative epsilon, then op-cost tiebreak).
                        // a self-referential node (e.g. `bias_add(D, 0)`
                        // living inside class D after dense-zero-add) must
                        // never win a tie: extracting it would loop.
                        let self_ref = node
                            .children
                            .iter()
                            .any(|&c| self.eg.find_imm(c) == id);
                        let better = match self.best.get(&id) {
                            Some((old, old_node)) => {
                                let eps = 1e-9 * old.abs().max(1.0);
                                cost < *old - eps
                                    || (!self_ref
                                        && cost <= *old + eps
                                        && self.cost_fn.op_cost(&node.op) + 1e-9
                                            < self.cost_fn.op_cost(&old_node.op))
                            }
                            None => true,
                        };
                        if better {
                            self.best.insert(id, (cost, node.clone()));
                            changed = true;
                        }
                    }
                }
            }
            if !changed {
                break;
            }
        }
    }

    /// Best cost of a class, if any term is extractable.
    pub fn cost_of(&self, id: Id) -> Option<f64> {
        self.best.get(&self.eg.find_imm(id)).map(|(c, _)| *c)
    }

    /// Extract the min-cost program rooted at `root` as a RecExpr
    /// (hash-consed, topologically ordered).
    pub fn extract(&self, root: Id) -> RecExpr {
        let mut expr = RecExpr::new();
        let mut memo: HashMap<Id, usize> = HashMap::new();
        let root = self.eg.find_imm(root);
        self.extract_rec(root, &mut expr, &mut memo);
        expr
    }

    fn extract_rec(
        &self,
        id: Id,
        expr: &mut RecExpr,
        memo: &mut HashMap<Id, usize>,
    ) -> usize {
        if let Some(&i) = memo.get(&id) {
            return i;
        }
        let (_, node) = self
            .best
            .get(&id)
            .unwrap_or_else(|| panic!("class {id} has no extractable term"));
        let children: Vec<usize> = node
            .children
            .iter()
            .map(|&c| self.extract_rec(self.eg.find_imm(c), expr, memo))
            .collect();
        let i = expr.add(node.op.clone(), children);
        memo.insert(id, i);
        i
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::pattern::dsl::*;
    use crate::egraph::Rewrite;
    use crate::ir::shape::Shape;
    use std::collections::HashMap as Map;

    fn env() -> Map<String, Shape> {
        [
            ("x".to_string(), vec![2usize, 4]),
            ("w".to_string(), vec![3, 4]),
            ("b".to_string(), vec![3]),
        ]
        .into_iter()
        .collect()
    }

    #[test]
    fn extraction_prefers_accelerator() {
        let mut eg = EGraph::new(env());
        let x = eg.add(Op::Var("x".into()), vec![]);
        let w = eg.add(Op::Weight("w".into()), vec![]);
        let b = eg.add(Op::Weight("b".into()), vec![]);
        let d = eg.add(Op::Dense, vec![x, w]);
        let root = eg.add(Op::BiasAdd, vec![d, b]);
        let rw = Rewrite::pure(
            "linear-to-flexasr",
            n(Op::BiasAdd, vec![n(Op::Dense, vec![v("x"), v("w")]), v("b")]),
            n(Op::FlexLinear, vec![v("x"), v("w"), v("b")]),
        );
        rw.run(&mut eg);
        eg.rebuild();
        let ex = Extractor::new(&eg, AccelCost::for_target(Target::FlexAsr));
        let best = ex.extract(root);
        assert_eq!(best.invocations(Target::FlexAsr), 1);
        assert_eq!(best.count(|o| matches!(o, Op::Dense)), 0);
    }

    #[test]
    fn disabled_target_never_extracted() {
        let mut eg = EGraph::new(env());
        let x = eg.add(Op::Var("x".into()), vec![]);
        let w = eg.add(Op::Weight("w".into()), vec![]);
        let d = eg.add(Op::Dense, vec![x, w]);
        let g = eg.add(Op::VtaGemm, vec![x, w]);
        eg.union(d, g);
        eg.rebuild();
        // FlexASR-only compilation: VTA op must not be chosen
        let ex = Extractor::new(&eg, AccelCost::for_target(Target::FlexAsr));
        let best = ex.extract(d);
        assert_eq!(best.invocations(Target::Vta), 0);
        assert_eq!(best.count(|o| matches!(o, Op::Dense)), 1);
    }

    #[test]
    fn cyclic_class_extracts_finite_term() {
        // dense -> bias_add(dense, 0) creates a cycle; extraction must
        // still terminate with the finite representative.
        let mut eg = EGraph::new(env());
        let x = eg.add(Op::Var("x".into()), vec![]);
        let w = eg.add(Op::Weight("w".into()), vec![]);
        let d = eg.add(Op::Dense, vec![x, w]);
        let z = eg.add(Op::ZeroTensor(vec![3]), vec![]);
        let ba = eg.add(Op::BiasAdd, vec![d, z]);
        eg.union(d, ba);
        eg.rebuild();
        let ex = Extractor::new(&eg, AccelCost::for_target(Target::FlexAsr));
        let best = ex.extract(d);
        assert!(best.len() >= 3);
        assert!(ex.cost_of(d).unwrap().is_finite());
    }
}
