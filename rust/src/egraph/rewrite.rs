//! Rewrite rules: searcher pattern + applier.
//!
//! Most IR-accelerator rewrites are *pure* (LHS pattern → RHS pattern);
//! the shape-dependent compiler-IR rewrites (dense+zero-add, im2col,
//! maxpool decomposition) use *dynamic* appliers that consult the e-class
//! shape analysis to synthesize parameterized RHS operators.

use super::pattern::{instantiate, Match, Pat, Pattern, SearchStrategy};
use super::EGraph;
use crate::ir::Id;

/// Applies the right-hand side of a rule for one match; returns the id of
/// the constructed RHS class (or `None` to decline, e.g. when a shape
/// precondition fails).
pub trait Applier: Send + Sync {
    fn apply(&self, eg: &mut EGraph, m: &Match) -> Option<Id>;
}

/// Pure pattern applier.
pub struct PatternApplier(pub Pat);

impl Applier for PatternApplier {
    fn apply(&self, eg: &mut EGraph, m: &Match) -> Option<Id> {
        Some(instantiate(&self.0, eg, &m.subst))
    }
}

/// Closure-based dynamic applier.
pub struct DynApplier<F>(pub F);

impl<F> Applier for DynApplier<F>
where
    F: Fn(&mut EGraph, &Match) -> Option<Id> + Send + Sync,
{
    fn apply(&self, eg: &mut EGraph, m: &Match) -> Option<Id> {
        (self.0)(eg, m)
    }
}

/// A named rewrite rule.
pub struct Rewrite {
    /// Rule name (shows up in scheduler/bench reports).
    pub name: String,
    /// Left-hand-side pattern.
    pub searcher: Pattern,
    /// Right-hand-side constructor.
    pub applier: Box<dyn Applier>,
}

impl Rewrite {
    /// Pure rule: LHS pattern → RHS pattern.
    pub fn pure(name: &str, lhs: Pat, rhs: Pat) -> Self {
        Rewrite {
            name: name.to_string(),
            searcher: Pattern::new(lhs),
            applier: Box::new(PatternApplier(rhs)),
        }
    }

    /// Dynamic rule with a closure applier.
    pub fn dynamic<F>(name: &str, lhs: Pat, f: F) -> Self
    where
        F: Fn(&mut EGraph, &Match) -> Option<Id> + Send + Sync + 'static,
    {
        Rewrite {
            name: name.to_string(),
            searcher: Pattern::new(lhs),
            applier: Box::new(DynApplier(f)),
        }
    }

    /// Search + apply everywhere (op-indexed); returns the number of
    /// *new* unions made. The [`super::Runner`] splits the two phases so
    /// its backoff scheduler can ban a rule *before* applying an
    /// explosion of matches; this convenience form applies unconditionally.
    pub fn run(&self, eg: &mut EGraph) -> usize {
        let (matches, _) = self.searcher.search_with(eg, SearchStrategy::Indexed);
        self.apply_matches(eg, &matches)
    }

    /// Apply the right-hand side for each match; returns the number of
    /// *new* unions made.
    pub fn apply_matches(&self, eg: &mut EGraph, matches: &[Match]) -> usize {
        let mut changed = 0;
        for m in matches {
            if let Some(rhs) = self.applier.apply(eg, m) {
                let (_, did) = eg.union(m.class, rhs);
                if did {
                    changed += 1;
                }
            }
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::pattern::dsl::*;
    use crate::ir::shape::Shape;
    use crate::ir::Op;
    use std::collections::HashMap;

    fn env() -> HashMap<String, Shape> {
        [
            ("x".to_string(), vec![2usize, 4]),
            ("w".to_string(), vec![3, 4]),
            ("b".to_string(), vec![3]),
        ]
        .into_iter()
        .collect()
    }

    #[test]
    fn pure_rewrite_unions_lhs_and_rhs() {
        let mut eg = EGraph::new(env());
        let x = eg.add(Op::Var("x".into()), vec![]);
        let w = eg.add(Op::Weight("w".into()), vec![]);
        let b = eg.add(Op::Weight("b".into()), vec![]);
        let d = eg.add(Op::Dense, vec![x, w]);
        let lin = eg.add(Op::BiasAdd, vec![d, b]);

        let rw = Rewrite::pure(
            "linear-to-flexasr",
            n(Op::BiasAdd, vec![n(Op::Dense, vec![v("x"), v("w")]), v("b")]),
            n(Op::FlexLinear, vec![v("x"), v("w"), v("b")]),
        );
        let changed = rw.run(&mut eg);
        eg.rebuild();
        assert_eq!(changed, 1);
        // the FlexLinear node must now be in the same class as bias_add
        let flex = eg.add(Op::FlexLinear, vec![x, w, b]);
        assert_eq!(eg.find(flex), eg.find(lin));
        // idempotent: second run makes no new unions
        assert_eq!(rw.run(&mut eg), 0);
    }

    #[test]
    fn dynamic_rewrite_can_decline() {
        let mut eg = EGraph::new(env());
        let x = eg.add(Op::Var("x".into()), vec![]);
        let _r = eg.add(Op::Relu, vec![x]);
        let rw = Rewrite::dynamic("never", n(Op::Relu, vec![v("a")]), |_, _| None);
        assert_eq!(rw.run(&mut eg), 0);
    }
}
