//! The D2A rewrite-rule library.
//!
//! Two families (§2.2):
//!
//! * **IR-accelerator rewrites** ([`accel`]) — derived from the
//!   IR-accelerator mappings; LHS is a compiler-IR pattern, RHS the
//!   corresponding accelerator operator. Applying only these is *exact
//!   matching*.
//! * **Compiler-IR rewrites** ([`compiler_ir`]) — accelerator-independent
//!   IR-to-IR rules (linear-layer exposure, dense+zero-add, im2col,
//!   maxpool decomposition, store/load cancellation) that expose more
//!   matches. Adding these on top is *flexible matching*.

pub mod accel;
pub mod compiler_ir;

use crate::egraph::Rewrite;
use crate::ir::Target;

/// Matching mode for a compilation run (the two columns of Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Matching {
    Exact,
    Flexible,
}

impl std::fmt::Display for Matching {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Matching::Exact => write!(f, "exact"),
            Matching::Flexible => write!(f, "flexible"),
        }
    }
}

/// Assemble the rule set for compiling to `targets` under `mode`.
/// Like [`rules_for`] but with the extended (validated-but-not-compiled)
/// FlexASR mappings included — used by the §5.1 data-movement study.
pub fn rules_for_extended(targets: &[Target], mode: Matching) -> Vec<Rewrite> {
    let mut rules = rules_for(targets, mode);
    if targets.contains(&Target::FlexAsr) {
        rules.extend(accel::flexasr_extended_rules());
    }
    rules
}

/// The rewrite-rule set for a target list under a matching mode
/// (Table 1's per-target compilation).
pub fn rules_for(targets: &[Target], mode: Matching) -> Vec<Rewrite> {
    let mut rules = Vec::new();
    for &t in targets {
        match t {
            Target::FlexAsr => rules.extend(accel::flexasr_rules()),
            Target::Hlscnn => rules.extend(accel::hlscnn_rules()),
            Target::Vta => rules.extend(accel::vta_rules()),
            Target::Host => {}
        }
    }
    if mode == Matching::Flexible {
        rules.extend(compiler_ir::rules());
        // the store/load cancellation (§5.1) is only meaningful when
        // FlexASR data-movement ops can appear
        if targets.contains(&Target::FlexAsr) {
            rules.extend(compiler_ir::data_movement_rules());
        }
    }
    rules
}
