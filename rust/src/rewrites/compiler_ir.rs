//! Compiler-IR rewrites — accelerator-independent, input-program-
//! independent rules that expose more IR-accelerator matches (§2.2.2).
//!
//! These are the source of the paper's *emergent effects*: im2col turns
//! 2-D convolutions into `nn.dense`, which the VTA GEMM rule then offloads
//! even though no conv-on-VTA rule exists; `dense -> dense + 0` exposes
//! FlexASR's linear layer for bare matmuls (the MobileNet-V2 observation
//! in §4.3.1).

use crate::egraph::pattern::dsl::*;
use crate::egraph::Rewrite;
use crate::ir::Op;

/// All general-purpose compiler-IR rewrites.
pub fn rules() -> Vec<Rewrite> {
    let mut rs = vec![
        linear_exposure_reshape(),
        linear_exposure_add(),
        dense_zero_add(),
        conv2d_im2col(),
        maxpool_decompose(),
        meanpool_decompose(),
    ];
    rs.extend(std::iter::empty::<Rewrite>());
    rs
}

/// §5.1 data-movement optimization: loading data out of the accelerator
/// only to store it back is a no-op.
pub fn data_movement_rules() -> Vec<Rewrite> {
    vec![Rewrite::dynamic(
        "fasr-store-load-cancel",
        n(Op::FlexMaxpStore, vec![n(Op::FlexMaxpLoad, vec![v("t")])]),
        |_, m| Some(m.subst.class("t")),
    )]
}

/// `(add (reshape (nn_dense x w) s) c)` → `(bias_add (nn_dense x w) c)`
/// when the reshape is shape-preserving in 2-D and `c` is a vector — the
/// §2.2.2 linear-layer example.
fn linear_exposure_reshape() -> Rewrite {
    Rewrite::dynamic(
        "linear-exposure-reshape",
        n(
            Op::Add,
            vec![
                any(
                    "rs",
                    |op| matches!(op, Op::Reshape(_)),
                    vec![n(Op::Dense, vec![v("x"), v("w")])],
                ),
                v("c"),
            ],
        ),
        |eg, m| {
            // precondition: c is rank-1 and reshape target is 2-D with the
            // same trailing dim
            let c = m.subst.class("c");
            let c_shape = eg.shape_of(c)?.clone();
            if c_shape.len() != 1 {
                return None;
            }
            let Op::Reshape(target) = m.subst.op("rs") else { return None };
            if target.len() != 2 || target[1] != c_shape[0] {
                return None;
            }
            let d = eg.add(Op::Dense, vec![m.subst.class("x"), m.subst.class("w")]);
            if eg.shape_of(d) != Some(&target.clone()) {
                return None;
            }
            Some(eg.add(Op::BiasAdd, vec![d, c]))
        },
    )
}

/// `(add (nn_dense x w) c)` → `(bias_add (nn_dense x w) c)` when `c` is a
/// vector (plain `add` with broadcast is semantically bias_add here).
fn linear_exposure_add() -> Rewrite {
    Rewrite::dynamic(
        "linear-exposure-add",
        n(Op::Add, vec![n(Op::Dense, vec![v("x"), v("w")]), v("c")]),
        |eg, m| {
            let c = m.subst.class("c");
            if eg.shape_of(c)?.len() != 1 {
                return None;
            }
            let d = eg.add(Op::Dense, vec![m.subst.class("x"), m.subst.class("w")]);
            Some(eg.add(Op::BiasAdd, vec![d, c]))
        },
    )
}

/// `(nn_dense x w)` → `(bias_add (nn_dense x w) 0)` — exposes FlexASR's
/// linear layer for bias-free matmuls ("rewriting nn.dense to nn.dense
/// followed by an add of a zero tensor", §4.3.1).
fn dense_zero_add() -> Rewrite {
    Rewrite::dynamic(
        "dense-zero-add",
        n(Op::Dense, vec![v("x"), v("w")]),
        |eg, m| {
            let out_shape = eg.shape_of(m.class)?.clone();
            if out_shape.len() != 2 {
                return None;
            }
            let zero = eg.add(Op::ZeroTensor(vec![out_shape[1]]), vec![]);
            let d = eg.add(Op::Dense, vec![m.subst.class("x"), m.subst.class("w")]);
            Some(eg.add(Op::BiasAdd, vec![d, zero]))
        },
    )
}

/// `(conv2d<s,p,1> x w)` → `(from_im2col (nn_dense (im2col x) (reshape w)))`
/// — the Glenside im2col rewrite [13] behind Table 1's conv-on-VTA counts.
fn conv2d_im2col() -> Rewrite {
    Rewrite::dynamic(
        "conv2d-im2col",
        any_of(
            "conv",
            |op| matches!(op, Op::Conv2d { groups: 1, .. }),
            vec![Op::Conv2d { stride: (1, 1), pad: (0, 0), groups: 1 }],
            vec![v("x"), v("w")],
        ),
        |eg, m| {
            let Op::Conv2d { stride, pad, .. } = *m.subst.op("conv") else {
                return None;
            };
            let x = m.subst.class("x");
            let w = m.subst.class("w");
            let xs = eg.shape_of(x)?.clone();
            let ws = eg.shape_of(w)?.clone();
            if xs.len() != 4 || ws.len() != 4 {
                return None;
            }
            let (n, _c, h, wd) = (xs[0], xs[1], xs[2], xs[3]);
            let (o, ci, kh, kw) = (ws[0], ws[1], ws[2], ws[3]);
            let oh = (h + 2 * pad.0).checked_sub(kh)? / stride.0 + 1;
            let ow = (wd + 2 * pad.1).checked_sub(kw)? / stride.1 + 1;
            let patches =
                eg.add(Op::Im2col { kernel: (kh, kw), stride, pad }, vec![x]);
            let wflat = eg.add(Op::Reshape(vec![o, ci * kh * kw]), vec![w]);
            let gemm = eg.add(Op::Dense, vec![patches, wflat]);
            Some(eg.add(Op::FromIm2col { n, oh, ow }, vec![gemm]))
        },
    )
}

/// Decompose matrix max pooling with a power-of-two window into
/// `reshape . temp_maxpool^k . windows_flatten` — the Fig. 7(c) rewrite
/// that exposes FlexASR's fixed (2,1)/(2,1) temporal max pool.
fn maxpool_decompose() -> Rewrite {
    pool_decompose(
        "maxpool-decompose",
        |op| matches!(op, Op::MatMaxPool { .. }),
        Op::MatMaxPool { window: (2, 2), stride: (2, 2) },
        |op| {
            let Op::MatMaxPool { window, stride } = *op else { unreachable!() };
            (window, stride)
        },
        Op::TempMaxPool,
    )
}

/// The mean-pool analogue (valid because the window size is a power of
/// two, so the mean of pairwise means equals the overall mean).
fn meanpool_decompose() -> Rewrite {
    pool_decompose(
        "meanpool-decompose",
        |op| matches!(op, Op::MatMeanPool { .. }),
        Op::MatMeanPool { window: (2, 2), stride: (2, 2) },
        |op| {
            let Op::MatMeanPool { window, stride } = *op else { unreachable!() };
            (window, stride)
        },
        Op::TempMeanPool,
    )
}

fn pool_decompose(
    name: &str,
    pred: fn(&Op) -> bool,
    family: Op,
    params: fn(&Op) -> ((usize, usize), (usize, usize)),
    stage_op: Op,
) -> Rewrite {
    Rewrite::dynamic(name, any_of("pool", pred, vec![family], vec![v("t")]), move |eg, m| {
        let (window, stride) = params(m.subst.op("pool"));
        let wsize = window.0 * window.1;
        if wsize < 2 || !wsize.is_power_of_two() {
            return None;
        }
        let out_shape = eg.shape_of(m.class)?.clone();
        if out_shape.len() != 2 {
            return None;
        }
        let t = m.subst.class("t");
        let mut cur = eg.add(Op::WindowsFlatten { window, stride }, vec![t]);
        for _ in 0..wsize.trailing_zeros() {
            cur = eg.add(stage_op.clone(), vec![cur]);
        }
        Some(eg.add(Op::Reshape(out_shape), vec![cur]))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{AccelCost, EGraph, Extractor, Runner};
    use crate::ir::shape::Shape;
    use crate::ir::{interp, GraphBuilder, Op, RecExpr, Target};
    use crate::rewrites::{rules_for, Matching};
    use crate::tensor::Tensor;
    use crate::util::Rng;
    use std::collections::HashMap;

    fn shapes(pairs: &[(&str, &[usize])]) -> HashMap<String, Shape> {
        pairs.iter().map(|(n, s)| (n.to_string(), s.to_vec())).collect()
    }

    #[test]
    fn bare_dense_reaches_flexasr_via_zero_add() {
        // the §4.3.1 MobileNet observation
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        g.dense(x, w);
        let expr = g.finish();
        let mut eg = EGraph::new(shapes(&[("x", &[2, 4]), ("w", &[3, 4])]));
        let root = eg.add_expr(&expr);

        // exact matching: no offload
        let mut eg2 = EGraph::new(shapes(&[("x", &[2, 4]), ("w", &[3, 4])]));
        let root2 = eg2.add_expr(&expr);
        Runner::default()
            .run(&mut eg2, &rules_for(&[Target::FlexAsr], Matching::Exact));
        let exact = Extractor::new(&eg2, AccelCost::for_target(Target::FlexAsr))
            .extract(root2);
        assert_eq!(exact.invocations(Target::FlexAsr), 0);

        // flexible matching: dense + 0 -> fasr_linear
        Runner::default()
            .run(&mut eg, &rules_for(&[Target::FlexAsr], Matching::Flexible));
        let flex =
            Extractor::new(&eg, AccelCost::for_target(Target::FlexAsr)).extract(root);
        assert_eq!(flex.invocations(Target::FlexAsr), 1);
    }

    #[test]
    fn conv_reaches_vta_via_im2col_emergence() {
        // emergent effect: no conv-on-VTA rule exists, yet conv offloads
        let mut g = GraphBuilder::new();
        let x = g.var("img");
        let w = g.weight("k");
        g.conv2d(x, w, (1, 1), (1, 1), 1);
        let expr = g.finish();
        let env = shapes(&[("img", &[1, 3, 8, 8]), ("k", &[4, 3, 3, 3])]);
        let mut eg = EGraph::new(env);
        let root = eg.add_expr(&expr);
        Runner::default().run(&mut eg, &rules_for(&[Target::Vta], Matching::Flexible));
        let flex =
            Extractor::new(&eg, AccelCost::for_target(Target::Vta)).extract(root);
        assert_eq!(flex.invocations(Target::Vta), 1);
        assert_eq!(flex.count(|o| matches!(o, Op::Conv2d { .. })), 0);
    }

    #[test]
    fn rewritten_conv_is_semantics_preserving() {
        // evaluate original vs extracted program — must agree in f32
        let mut g = GraphBuilder::new();
        let x = g.var("img");
        let w = g.weight("k");
        g.conv2d(x, w, (2, 2), (1, 1), 1);
        let expr = g.finish();
        let env = shapes(&[("img", &[1, 3, 8, 8]), ("k", &[4, 3, 3, 3])]);
        let mut eg = EGraph::new(env);
        let root = eg.add_expr(&expr);
        Runner::default().run(&mut eg, &rules_for(&[Target::Vta], Matching::Flexible));
        let flex: RecExpr =
            Extractor::new(&eg, AccelCost::for_target(Target::Vta)).extract(root);

        let mut rng = Rng::new(31);
        let tenv: HashMap<String, Tensor> = [
            ("img".to_string(), Tensor::randn(&[1, 3, 8, 8], &mut rng, 1.0)),
            ("k".to_string(), Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.5)),
        ]
        .into_iter()
        .collect();
        let a = interp::eval(&expr, &tenv).unwrap();
        let b = interp::eval(&flex, &tenv).unwrap();
        assert_eq!(a.shape, b.shape);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn fig7_maxpool_pipeline_with_cancellation() {
        // (mat_maxpool<(4,4),(2,2)> T) must become a single
        // store -> 4x fasr_maxpool -> load chain after flexible matching
        // with the store/load-cancellation rule.
        let mut e = RecExpr::new();
        let t = e.add(Op::Var("t".into()), vec![]);
        e.add(Op::MatMaxPool { window: (4, 4), stride: (2, 2) }, vec![t]);
        let env = shapes(&[("t", &[128, 128])]);
        let mut eg = EGraph::new(env);
        let root = eg.add_expr(&e);
        let rules = crate::rewrites::rules_for_extended(&[Target::FlexAsr], Matching::Flexible);
        Runner::default().run(&mut eg, &rules);
        let best =
            Extractor::new(&eg, AccelCost::for_target(Target::FlexAsr)).extract(root);
        let stores = best.count(|o| matches!(o, Op::FlexMaxpStore));
        let loads = best.count(|o| matches!(o, Op::FlexMaxpLoad));
        let pools = best.count(|o| matches!(o, Op::FlexMaxpool));
        assert_eq!(pools, 4, "four temporal maxpool stages: {}", crate::ir::parse::to_sexpr(&best));
        assert_eq!(stores, 1, "intermediate stores cancelled");
        assert_eq!(loads, 1, "intermediate loads cancelled");

        // and the result still computes the right thing
        let mut rng = Rng::new(7);
        let tenv: HashMap<String, Tensor> =
            [("t".to_string(), Tensor::randn(&[128, 128], &mut rng, 1.0))]
                .into_iter()
                .collect();
        let a = interp::eval(&e, &tenv).unwrap();
        let b = interp::eval(&best, &tenv).unwrap();
        assert_eq!(a.shape, vec![63, 63]);
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn reshape_linear_exposure() {
        // (add (reshape (dense x w)) c) with vector c becomes fasr_linear
        let mut e = RecExpr::new();
        let x = e.add(Op::Var("x".into()), vec![]);
        let w = e.add(Op::Weight("w".into()), vec![]);
        let c = e.add(Op::Weight("c".into()), vec![]);
        let d = e.add(Op::Dense, vec![x, w]);
        let r = e.add(Op::Reshape(vec![2, 3]), vec![d]);
        e.add(Op::Add, vec![r, c]);
        let env = shapes(&[("x", &[2, 4]), ("w", &[3, 4]), ("c", &[3])]);
        let mut eg = EGraph::new(env);
        let root = eg.add_expr(&e);
        Runner::default()
            .run(&mut eg, &rules_for(&[Target::FlexAsr], Matching::Flexible));
        let best =
            Extractor::new(&eg, AccelCost::for_target(Target::FlexAsr)).extract(root);
        assert_eq!(best.invocations(Target::FlexAsr), 1);
    }
}
