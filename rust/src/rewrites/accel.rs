//! IR-accelerator rewrites — one per supported accelerator operation
//! (Appendix A), derived from the verified IR-accelerator mappings.

use crate::egraph::pattern::dsl::*;
use crate::egraph::pattern::Pat;
use crate::egraph::Rewrite;
use crate::ir::Op;

/// FlexASR buffer capacity in elements per matrix dimension (the mapping
/// precondition for linear layers).
pub const FLEXASR_MAX_DIM: usize = 4096;

/// Build the unrolled-LSTM IR-accelerator rewrite for a fixed step count
/// and hidden size — "the pattern we match for the LSTM layer in exact
/// matching is precisely the formulation of an LSTM produced by TVM's
/// PyTorch importer, unrolled to the correct number of timesteps"
/// (Appendix A). The LHS is the full `steps`-deep gate recurrence
/// (16 ops per step); the RHS is ONE `fasr_lstm_fused` instruction —
/// Table 1's 566-Relay-ops-to-1 granularity collapse.
pub fn flexasr_unrolled_lstm(steps: usize, hidden: usize) -> Rewrite {
    let h = hidden;
    let h0: Pat = n(Op::ZeroTensor(vec![1, h]), vec![]);
    let c0: Pat = n(Op::ZeroTensor(vec![1, h]), vec![]);
    let mut hprev = h0;
    let mut cprev = c0;
    let mut chain: Option<Pat> = None;
    for t in 0..steps {
        let xt = n(Op::SliceStep { t }, vec![v("x")]);
        let cat = n(Op::Concat, vec![xt, hprev.clone()]);
        let gates = n(
            Op::Add,
            vec![n(Op::Dense, vec![cat, v("w")]), v("b")],
        );
        let gi = n(Op::Sigmoid, vec![n(Op::SliceCols { lo: 0, hi: h }, vec![gates.clone()])]);
        let gf = n(
            Op::Sigmoid,
            vec![n(Op::SliceCols { lo: h, hi: 2 * h }, vec![gates.clone()])],
        );
        let gg = n(
            Op::Tanh,
            vec![n(Op::SliceCols { lo: 2 * h, hi: 3 * h }, vec![gates.clone()])],
        );
        let go = n(
            Op::Sigmoid,
            vec![n(Op::SliceCols { lo: 3 * h, hi: 4 * h }, vec![gates])],
        );
        let ct = n(
            Op::Add,
            vec![n(Op::Mul, vec![gf, cprev.clone()]), n(Op::Mul, vec![gi, gg])],
        );
        let ht = n(Op::Mul, vec![go, n(Op::Tanh, vec![ct.clone()])]);
        chain = Some(match chain {
            None => ht.clone(),
            Some(acc) => n(Op::ConcatRows, vec![acc, ht.clone()]),
        });
        hprev = ht;
        cprev = ct;
    }
    let lhs = chain.expect("steps >= 1");
    Rewrite::dynamic(
        &format!("flexasr-unrolled-lstm-{steps}"),
        lhs,
        move |eg, m| {
            let fused = eg.add(
                Op::FlexLstmFused { steps },
                vec![m.subst.class("x"), m.subst.class("w"), m.subst.class("b")],
            );
            Some(eg.add(Op::Reshape(vec![steps, h]), vec![fused]))
        },
    )
}

/// FlexASR (Appendix A: linear layer, LSTM layer; plus the §4.4 mappings
/// for layer norm, temporal max/mean pool, and attention).
pub fn flexasr_rules() -> Vec<Rewrite> {
    vec![
        // Fig. 3 / Fig. 5: (bias_add (nn_dense x w) b) -> fasr_linear.
        // Capacity precondition: the operands must fit FlexASR's global
        // buffer / PE weight store (this is why e.g. the LSTM-WLM
        // vocabulary-sized decoder stays off FlexASR in Table 1).
        Rewrite::dynamic(
            "flexasr-linear",
            n(Op::BiasAdd, vec![n(Op::Dense, vec![v("x"), v("w")]), v("b")]),
            |eg, m| {
                let w = m.subst.class("w");
                let ws = eg.shape_of(w)?.clone();
                if ws.len() != 2 || ws[0] > FLEXASR_MAX_DIM || ws[1] > FLEXASR_MAX_DIM
                {
                    return None;
                }
                let d = eg.add(Op::Dense, vec![m.subst.class("x"), w]);
                let b = m.subst.class("b");
                let _ = d;
                Some(eg.add(
                    Op::FlexLinear,
                    vec![m.subst.class("x"), w, b],
                ))
            },
        ),
        // the whole unrolled LSTM maps to ONE FlexASR instruction —
        // Table 1's dramatic granularity mismatch (566 Relay ops -> 1).
        Rewrite::dynamic(
            "flexasr-lstm",
            any_of(
                "lstm",
                |op| matches!(op, Op::Lstm { .. }),
                vec![Op::Lstm { steps: 1 }],
                vec![v("x"), v("wi"), v("wh"), v("b")],
            ),
            |eg, m| {
                let Op::Lstm { steps } = *m.subst.op("lstm") else { return None };
                let ch = vec![
                    m.subst.class("x"),
                    m.subst.class("wi"),
                    m.subst.class("wh"),
                    m.subst.class("b"),
                ];
                Some(eg.add(Op::FlexLstm { steps }, ch))
            },
        ),
    ]
}

/// FlexASR mappings that are *validated* (Table 2) but not wired into the
/// end-to-end compiler — mirroring Appendix A: "The compiler supports two
/// of FlexASR's operations: linear layers and LSTM layers." These extra
/// rules power the §5.1 maxpool study and the fig7 bench.
pub fn flexasr_extended_rules() -> Vec<Rewrite> {
    vec![
        Rewrite::pure(
            "flexasr-layernorm",
            n(Op::LayerNorm, vec![v("x")]),
            n(Op::FlexLayerNorm, vec![v("x")]),
        ),
        // §5.1: temporal max pooling with explicit store/compute/load
        Rewrite::pure(
            "flexasr-temp-maxpool",
            n(Op::TempMaxPool, vec![v("t")]),
            n(
                Op::FlexMaxpLoad,
                vec![n(Op::FlexMaxpool, vec![n(Op::FlexMaxpStore, vec![v("t")])])],
            ),
        ),
        Rewrite::pure(
            "flexasr-temp-meanpool",
            n(Op::TempMeanPool, vec![v("t")]),
            n(
                Op::FlexMaxpLoad,
                vec![n(Op::FlexMeanpool, vec![n(Op::FlexMaxpStore, vec![v("t")])])],
            ),
        ),
        Rewrite::pure(
            "flexasr-attention",
            n(Op::Attention, vec![v("q"), v("k"), v("v")]),
            n(Op::FlexAttention, vec![v("q"), v("k"), v("v")]),
        ),
    ]
}

/// HLSCNN (Appendix A: one operation — non-grouped 2-D convolution).
pub fn hlscnn_rules() -> Vec<Rewrite> {
    vec![Rewrite::dynamic(
        "hlscnn-conv2d",
        any_of(
            "conv",
            |op| matches!(op, Op::Conv2d { groups: 1, .. }),
            vec![Op::Conv2d { stride: (1, 1), pad: (0, 0), groups: 1 }],
            vec![v("x"), v("w")],
        ),
        |eg, m| {
            let Op::Conv2d { stride, pad, .. } = *m.subst.op("conv") else {
                return None;
            };
            Some(eg.add(
                Op::HlscnnConv2d { stride, pad },
                vec![m.subst.class("x"), m.subst.class("w")],
            ))
        },
    )]
}

/// VTA (Appendix A: matrix multiplication and addition as fixed VTA
/// instruction sequences; `nn.dense` is the invocation-counted GEMM).
pub fn vta_rules() -> Vec<Rewrite> {
    vec![Rewrite::pure(
        "vta-gemm",
        n(Op::Dense, vec![v("x"), v("w")]),
        n(Op::VtaGemm, vec![v("x"), v("w")]),
    )]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{AccelCost, EGraph, Extractor, Runner};
    use crate::ir::shape::Shape;
    use crate::ir::{GraphBuilder, Op, Target};
    use std::collections::HashMap;

    fn env() -> HashMap<String, Shape> {
        [
            ("x".to_string(), vec![2usize, 4]),
            ("w".to_string(), vec![3, 4]),
            ("b".to_string(), vec![3]),
        ]
        .into_iter()
        .collect()
    }

    #[test]
    fn exact_matching_offloads_linear_to_flexasr() {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        let b = g.weight("b");
        g.linear(x, w, b);
        let expr = g.finish();
        let mut eg = EGraph::new(env());
        let root = eg.add_expr(&expr);
        Runner::default().run(&mut eg, &flexasr_rules());
        let best =
            Extractor::new(&eg, AccelCost::for_target(Target::FlexAsr)).extract(root);
        assert_eq!(best.invocations(Target::FlexAsr), 1);
    }

    #[test]
    fn lstm_collapses_to_one_invocation() {
        let mut g = GraphBuilder::new();
        let x = g.var("seq");
        let wi = g.weight("wi");
        let wh = g.weight("wh");
        let b = g.weight("b");
        g.lstm(x, wi, wh, b, 35);
        let expr = g.finish();
        let shapes: HashMap<String, Shape> = [
            ("seq".to_string(), vec![35usize, 1, 8]),
            ("wi".to_string(), vec![32, 8]),
            ("wh".to_string(), vec![32, 8]),
            ("b".to_string(), vec![32]),
        ]
        .into_iter()
        .collect();
        let mut eg = EGraph::new(shapes);
        let root = eg.add_expr(&expr);
        Runner::default().run(&mut eg, &flexasr_rules());
        let best =
            Extractor::new(&eg, AccelCost::for_target(Target::FlexAsr)).extract(root);
        assert_eq!(best.invocations(Target::FlexAsr), 1);
        assert_eq!(best.count(|o| matches!(o, Op::FlexLstm { steps: 35 })), 1);
    }

    #[test]
    fn conv_param_transfer_to_hlscnn() {
        let mut g = GraphBuilder::new();
        let x = g.var("img");
        let w = g.weight("k");
        g.conv2d(x, w, (2, 2), (1, 1), 1);
        let expr = g.finish();
        let shapes: HashMap<String, Shape> = [
            ("img".to_string(), vec![1usize, 3, 8, 8]),
            ("k".to_string(), vec![4, 3, 3, 3]),
        ]
        .into_iter()
        .collect();
        let mut eg = EGraph::new(shapes);
        let root = eg.add_expr(&expr);
        Runner::default().run(&mut eg, &hlscnn_rules());
        let best =
            Extractor::new(&eg, AccelCost::for_target(Target::Hlscnn)).extract(root);
        assert_eq!(best.invocations(Target::Hlscnn), 1);
        assert_eq!(
            best.count(|o| matches!(
                o,
                Op::HlscnnConv2d { stride: (2, 2), pad: (1, 1) }
            )),
            1
        );
    }

    #[test]
    fn grouped_conv_not_offloaded() {
        // HLSCNN supports only non-grouped convolution (Appendix A)
        let mut g = GraphBuilder::new();
        let x = g.var("img");
        let w = g.weight("k");
        g.conv2d(x, w, (1, 1), (1, 1), 4);
        let expr = g.finish();
        let mut eg = EGraph::new(HashMap::new());
        let root = eg.add_expr(&expr);
        Runner::default().run(&mut eg, &hlscnn_rules());
        let best =
            Extractor::new(&eg, AccelCost::for_target(Target::Hlscnn)).extract(root);
        assert_eq!(best.invocations(Target::Hlscnn), 0);
    }
}
