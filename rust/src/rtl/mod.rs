//! Cycle-level FlexASR datapath model — the RTL-simulation stand-in for
//! the paper's "30× speedup of ILA simulation over RTL simulation with a
//! commercial Verilog simulator" claim (§4.4.2) and for VT3-style
//! checking (ILA vs implementation).
//!
//! The model simulates the PE array the way an RTL simulator would: cycle
//! by cycle, evaluating every lane's decode/multiply/accumulate datapath
//! at the bit level and clocking a register file each cycle. The ILA
//! model computes the same result per *instruction* (whole-operation
//! update), which is exactly why ILA simulation is fast.

pub mod flexasr_rtl;

pub use flexasr_rtl::RtlFlexAsr;
