//! Cycle-accurate FlexASR linear-layer pipeline.
//!
//! Micro-architecture modeled (after Tambe et al., ISSCC'21):
//! * 16 PE lanes, each with an AdaptivFloat-8 decode unit, a multiplier,
//!   and a 32-bit accumulator;
//! * weights stream from the PE weight SRAM one 16-lane beat per cycle;
//! * a 3-stage pipeline (decode → multiply → accumulate) with explicit
//!   pipeline registers clocked every cycle;
//! * an output stage that re-encodes accumulators through the 8-bit port.
//!
//! Every cycle evaluates every lane at the **bit level** (codes, not
//! floats, cross the pipeline registers), which is what makes RTL-style
//! simulation slow and the ILA's per-instruction semantics fast.

use crate::accel::flexasr::model::{decode_byte, encode_byte};
use crate::numerics::adaptivfloat::AdaptivFloatFormat;
use crate::tensor::Tensor;

/// Number of PE lanes.
pub const LANES: usize = 16;

/// One lane's pipeline registers (bit-level).
#[derive(Debug, Clone, Copy, Default)]
struct LaneRegs {
    /// stage 1: fetched operand codes
    x_code: u8,
    w_code: u8,
    /// stage 2: decoded values (the RTL keeps these as fixed-point
    /// mantissa/exponent pairs; f32 here carries the same information)
    x_val: f32,
    w_val: f32,
    /// stage 3: product
    prod: f32,
    /// accumulator
    acc: f32,
}

/// The cycle-level device.
pub struct RtlFlexAsr {
    /// Storage format the lanes decode/encode.
    pub fmt: AdaptivFloatFormat,
    lanes: [LaneRegs; LANES],
    /// total cycles simulated (for the speedup report)
    pub cycles: u64,
}

impl Default for RtlFlexAsr {
    fn default() -> Self {
        Self::new()
    }
}

impl RtlFlexAsr {
    /// Device in the default (updated) AF8 format.
    pub fn new() -> Self {
        RtlFlexAsr {
            fmt: AdaptivFloatFormat::new(8, 3),
            lanes: [LaneRegs::default(); LANES],
            cycles: 0,
        }
    }

    /// Clock one cycle: shift the three pipeline stages in every lane.
    /// `fetch` supplies the stage-1 operand codes for each lane (None when
    /// the lane is idle this cycle).
    fn clock(
        &mut self,
        fetch: impl Fn(usize) -> Option<(u8, u8)>,
        x_bias: i32,
        w_bias: i32,
    ) {
        self.cycles += 1;
        for (lane, regs) in self.lanes.iter_mut().enumerate() {
            // stage 3: accumulate last cycle's product
            regs.acc += regs.prod;
            // stage 2 -> 3: multiply decoded operands
            regs.prod = regs.x_val * regs.w_val;
            // stage 1 -> 2: decode the fetched codes (bit-level work every
            // cycle, like the RTL's decode unit)
            regs.x_val = decode_byte(&self.fmt, regs.x_code, x_bias);
            regs.w_val = decode_byte(&self.fmt, regs.w_code, w_bias);
            // fetch -> stage 1
            match fetch(lane) {
                Some((xc, wc)) => {
                    regs.x_code = xc;
                    regs.w_code = wc;
                }
                None => {
                    regs.x_code = 0x80; // zero code
                    regs.w_code = 0x80;
                }
            }
        }
    }

    fn reset_accs(&mut self) {
        for r in self.lanes.iter_mut() {
            *r = LaneRegs::default();
        }
    }

    /// Cycle-accurate linear layer `x @ w^T + b` with AF8 storage,
    /// matching `FlexAsr::linear`'s numerics.
    pub fn linear(&mut self, x: &Tensor, w: &Tensor, b: &Tensor) -> Tensor {
        let (n, k) = (x.shape[0], x.shape[1]);
        let m = w.shape[0];
        let x_bias = self.fmt.select_bias(x.max_abs());
        let w_bias = self.fmt.select_bias(w.max_abs());
        let b_bias = self.fmt.select_bias(b.max_abs());
        // operand SRAM contents (codes)
        let xc: Vec<u8> =
            x.data.iter().map(|&v| encode_byte(&self.fmt, v, x_bias)).collect();
        let wc: Vec<u8> =
            w.data.iter().map(|&v| encode_byte(&self.fmt, v, w_bias)).collect();
        let bc: Vec<u8> =
            b.data.iter().map(|&v| encode_byte(&self.fmt, v, b_bias)).collect();

        let mut acc_out = vec![0.0f32; n * m];
        // each output row block: lanes sweep over k in 16-wide beats for
        // each (row, out) pair group of 16 outputs
        for i in 0..n {
            for j0 in (0..m).step_by(LANES) {
                self.reset_accs();
                let jn = (m - j0).min(LANES);
                // k beats + 3 drain cycles for the pipeline
                for t in 0..k + 3 {
                    self.clock(
                        |lane| {
                            if lane >= jn || t >= k {
                                return None;
                            }
                            let j = j0 + lane;
                            Some((xc[i * k + t], wc[j * k + t]))
                        },
                        x_bias,
                        w_bias,
                    );
                }
                for lane in 0..jn {
                    let j = j0 + lane;
                    let bias_v = decode_byte(&self.fmt, bc[j], b_bias);
                    acc_out[i * m + j] = self.lanes[lane].acc + bias_v;
                }
            }
        }
        // output port re-encodes through AF8
        let raw = Tensor::new(vec![n, m], acc_out);
        let out_bias = self.fmt.select_bias(raw.max_abs());
        raw.map(|v| decode_byte(&self.fmt, encode_byte(&self.fmt, v, out_bias), out_bias))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::FlexAsr;
    use crate::util::Rng;

    /// VT3: the RTL-level implementation must match the ILA specification
    /// on the linear layer (bit-level agreement on lattice operands).
    #[test]
    fn rtl_matches_ila_linear() {
        let dev = FlexAsr::new();
        let mut rtl = RtlFlexAsr::new();
        let mut rng = Rng::new(91);
        let x = dev.quant(&Tensor::randn(&[4, 32], &mut rng, 1.0));
        let w = dev.quant(&Tensor::randn(&[24, 32], &mut rng, 0.3));
        let b = dev.quant(&Tensor::randn(&[24], &mut rng, 0.1));
        let spec = dev.linear(&x, &w, &b);
        let impl_ = rtl.linear(&x, &w, &b);
        assert!(
            impl_.rel_error(&spec) < 0.01,
            "RTL diverges from ILA: {}",
            impl_.rel_error(&spec)
        );
    }

    #[test]
    fn cycle_count_tracks_workload() {
        let mut rtl = RtlFlexAsr::new();
        let mut rng = Rng::new(92);
        let x = Tensor::randn(&[2, 64], &mut rng, 1.0);
        let w = Tensor::randn(&[16, 64], &mut rng, 0.3);
        let b = Tensor::randn(&[16], &mut rng, 0.1);
        rtl.linear(&x, &w, &b);
        // 2 rows x 1 lane-group x (64 + 3) cycles
        assert_eq!(rtl.cycles, 2 * (64 + 3));
    }
}
