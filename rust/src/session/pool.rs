//! The arbitrated accelerator device pool: K shared per-target devices
//! behind an asynchronous work queue — the multi-tenant serving story.
//!
//! The per-worker engine model (one private [`IlaSim`] set per
//! [`super::ExecEngine`]) is the opposite of a real SoC, where many
//! requests contend for few devices behind an arbiter. A [`DevicePool`]
//! owns up to `K` devices per target (`K` typically < worker threads)
//! and brokers access through **decoupled request/response channels**,
//! the N:K arbitration structure of hardware accelerator interfaces:
//!
//! ```text
//! worker 0 ──checkout──▶ ┌──────────────┐ ──Grant(Device)──▶ worker 0
//! worker 1 ──checkout──▶ │ arbiter      │ ──Build──────────▶ worker 1
//!    ...                 │ (own thread) │        ...
//! worker N ──return────▶ └──────────────┘
//! ```
//!
//! Every checkout sends a request (target + affinity fingerprints) over
//! the pool's MPSC work queue and blocks on its own private response
//! channel; the arbiter thread answers with either a granted [`Device`]
//! or a `Build` ticket (capacity reserved, the caller constructs the
//! simulator itself so model construction never blocks the arbiter).
//! For template-bound programs the affinity fingerprints are the
//! template's **weight set**
//! ([`crate::codegen::ProgramTemplate::weight_fingerprints`]) — stable
//! across binds, so every call of an input-varying sweep scores against
//! the same resident weights; per-call slot bursts never pollute the
//! score. Direct `LoweredProgram` replays send every staged-burst
//! fingerprint. Returned devices keep their **residency set** — the
//! `(region, fingerprint)` pairs of operand bursts still staged in
//! device memory — which is exactly what the scheduler routes on:
//!
//! * [`SchedPolicy::Affinity`] (default): a freed device goes to the
//!   waiting request whose burst fingerprints best overlap the device's
//!   resident set (a cache-aware load balancer: re-streaming a weight
//!   set that is already on *some* device is the dominant serving cost);
//!   zero-overlap requests fall back to FIFO order, and any request
//!   passed over [`DevicePool::STARVATION_BOUND`] times is served next
//!   regardless of affinity, bounding starvation. A zero-overlap request
//!   also prefers *building* a fresh device while the pool is below
//!   capacity, rather than evicting residency another request built up.
//! * [`SchedPolicy::Fifo`]: strict arrival order, residency-blind — the
//!   baseline the serving benchmark compares against.
//!
//! Correctness does not depend on placement: the engine dirty-resets a
//! checked-out device before playing a program (keeping only resident
//! ranges, which are re-verified by fingerprint before every skip), so
//! results are bit-identical whichever device serves a request —
//! scheduling affects *traffic*, never *values*.

use crate::accel::flexasr::{model as fx, paging::PageTable};
use crate::ila::sim::IlaSim;
use crate::ir::Target;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One device-resident staged operand range: memory byte range plus the
/// fingerprint of the burst that staged it.
pub(crate) struct Resident {
    pub(crate) mem: String,
    pub(crate) lo: usize,
    pub(crate) hi: usize,
    pub(crate) fp: u64,
}

/// One pooled device: an ILA simulator plus the residency set and the
/// staging-DRAM page table that travel with it across checkouts (the
/// whole point of affinity scheduling — a returned device remembers
/// what is staged on it, and *where* the engine paged it).
pub(crate) struct Device {
    pub(crate) sim: IlaSim,
    pub(crate) resident: Vec<Resident>,
    /// Fingerprint-keyed LRU page table over the weight-staging DRAM;
    /// evicted pages drop out of [`overlap`]'s affinity score with it.
    pub(crate) pages: PageTable,
}

impl Device {
    pub(crate) fn new(sim: IlaSim) -> Self {
        Self::with_dram_capacity(sim, fx::WGT_DRAM_SIZE)
    }

    /// A device whose page table manages only `capacity` bytes of the
    /// staging DRAM — the eviction-pressure injection point for tests
    /// and capacity sweeps ([`crate::session::SessionBuilder`]'s
    /// `dram_capacity`).
    pub(crate) fn with_dram_capacity(sim: IlaSim, capacity: usize) -> Self {
        Device {
            sim,
            resident: Vec::new(),
            pages: PageTable::new(capacity.min(fx::WGT_DRAM_SIZE)),
        }
    }
}

/// How the pool assigns freed/idle devices to requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Route each request to the device whose resident burst set best
    /// covers the request's staged-burst fingerprints; FIFO fallback on
    /// zero overlap, with a starvation bound
    /// ([`DevicePool::STARVATION_BOUND`]).
    #[default]
    Affinity,
    /// Strict arrival order, residency-blind (the serving baseline).
    Fifo,
}

impl fmt::Display for SchedPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SchedPolicy::Affinity => write!(f, "affinity"),
            SchedPolicy::Fifo => write!(f, "fifo"),
        }
    }
}

/// Errors surfaced by pool checkouts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PoolError {
    /// The pool's arbiter has shut down (the pool was dropped while a
    /// checkout was in flight).
    Closed,
}

impl fmt::Display for PoolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PoolError::Closed => write!(f, "device pool is shut down"),
        }
    }
}

impl std::error::Error for PoolError {}

/// Cumulative scheduling counters, snapshotted by [`DevicePool::stats`].
///
/// Grants are classified exclusively:
/// `affinity_grants + fifo_grants + build_grants + starvation_promotions
/// == checkouts`.
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    /// Devices constructed so far (≤ capacity × targets in use).
    pub devices_built: u64,
    /// Total granted checkouts.
    pub checkouts: u64,
    /// Grants routed by residency overlap (affinity policy only).
    pub affinity_grants: u64,
    /// Grants routed by arrival order (FIFO policy, or the affinity
    /// policy's zero-overlap fallback).
    pub fifo_grants: u64,
    /// Grants satisfied by constructing a new device (pool below
    /// capacity for that target).
    pub build_grants: u64,
    /// Grants forced by the starvation bound — a request passed over
    /// [`DevicePool::STARVATION_BOUND`] times was served regardless of
    /// affinity.
    pub starvation_promotions: u64,
    /// Checkouts that found no idle device and no spare capacity and had
    /// to queue.
    pub queued: u64,
    /// Total time queued requests spent waiting for a device.
    pub wait: Duration,
    /// Integral of (checked-out devices × time): divide by
    /// `capacity × wall-clock` for pool occupancy.
    pub busy: Duration,
    /// Modeled device cycles spent by leased devices (summed from the
    /// per-lease [`crate::cost::Timeline`] deltas reported at return) —
    /// host-speed-independent pool occupancy.
    pub busy_cycles: u64,
    /// Modeled cycles of same-target queue exposure: each returned
    /// lease's cycles weighted by how many requests were still waiting
    /// for that target when it came back — host-speed-independent queue
    /// pressure (the cycle analogue of [`PoolStats::wait`]).
    pub wait_cycles: u64,
}

#[derive(Default)]
struct Counters {
    devices_built: AtomicU64,
    checkouts: AtomicU64,
    affinity_grants: AtomicU64,
    fifo_grants: AtomicU64,
    build_grants: AtomicU64,
    starvation_promotions: AtomicU64,
    queued: AtomicU64,
    wait_nanos: AtomicU64,
    busy_nanos: AtomicU64,
    busy_cycles: AtomicU64,
    wait_cycles: AtomicU64,
}

enum Response {
    /// A device, granted. Its residency set is intact.
    Grant(Device),
    /// Capacity reserved: the requester constructs the device itself
    /// (keeps ~0.3 MB simulator construction off the arbiter thread).
    Build,
}

enum Request {
    Checkout { target: usize, fps: Vec<u64>, resp: mpsc::Sender<Response> },
    Return { target: usize, device: Device, cycles: u64 },
    Shutdown,
}

struct Waiter {
    seq: u64,
    target: usize,
    fps: Vec<u64>,
    resp: mpsc::Sender<Response>,
    passed_over: u32,
    since: Instant,
}

enum GrantKind {
    Affinity,
    Fifo,
    Starved,
}

/// How many staged-burst fingerprints of `fps` are currently resident on
/// `device` — the affinity score. DRAM-staged bursts are scored against
/// the device's **page table** (the authority for what survives LRU
/// eviction); everything else against the residency set. An evicted page
/// leaves both, so it stops attracting requests immediately.
fn overlap(device: &Device, fps: &[u64]) -> usize {
    fps.iter()
        .filter(|fp| {
            device.pages.contains(**fp) || device.resident.iter().any(|r| r.fp == **fp)
        })
        .count()
}

/// Pick the idle device for an arriving request: under affinity, the one
/// with the best residency overlap; otherwise (and on zero overlap) the
/// front of the idle queue — devices return to the back, so the fallback
/// spreads load round-robin instead of hammering one device.
fn best_idle(idle: &[Device], fps: &[u64], policy: SchedPolicy) -> (usize, usize) {
    if matches!(policy, SchedPolicy::Fifo) {
        return (0, 0);
    }
    let mut best = (0usize, 0usize);
    for (i, d) in idle.iter().enumerate() {
        let ov = overlap(d, fps);
        if ov > best.1 {
            best = (i, ov);
        }
    }
    best
}

/// Pick the waiting request a freed device should serve. Starved
/// requests (passed over ≥ [`DevicePool::STARVATION_BOUND`] times) win
/// unconditionally, oldest first; then affinity by overlap (ties to the
/// older request); then FIFO.
fn choose_waiter(
    waiting: &[Waiter],
    target: usize,
    device: &Device,
    policy: SchedPolicy,
) -> Option<(usize, GrantKind)> {
    let mut oldest: Option<usize> = None;
    let mut starved: Option<usize> = None;
    let mut best: Option<(usize, usize)> = None; // (index, overlap > 0)
    for (i, w) in waiting.iter().enumerate() {
        if w.target != target {
            continue;
        }
        if oldest.map_or(true, |o| waiting[o].seq > w.seq) {
            oldest = Some(i);
        }
        if w.passed_over >= DevicePool::STARVATION_BOUND
            && starved.map_or(true, |s| waiting[s].seq > w.seq)
        {
            starved = Some(i);
        }
        let ov = overlap(device, &w.fps);
        if ov > 0
            && best.map_or(true, |(bi, bov)| {
                ov > bov || (ov == bov && waiting[bi].seq > w.seq)
            })
        {
            best = Some((i, ov));
        }
    }
    if let Some(s) = starved {
        return Some((s, GrantKind::Starved));
    }
    match policy {
        SchedPolicy::Fifo => oldest.map(|i| (i, GrantKind::Fifo)),
        SchedPolicy::Affinity => match best {
            Some((i, _)) => Some((i, GrantKind::Affinity)),
            None => oldest.map(|i| (i, GrantKind::Fifo)),
        },
    }
}

fn arbiter_loop(
    rx: mpsc::Receiver<Request>,
    capacity: usize,
    policy: SchedPolicy,
    counters: Arc<Counters>,
) {
    let mut idle: [Vec<Device>; Target::COUNT] = std::array::from_fn(|_| Vec::new());
    let mut built = [0usize; Target::COUNT];
    let mut waiting: Vec<Waiter> = Vec::new();
    let mut next_seq = 0u64;
    let mut busy = 0usize; // devices currently checked out, all targets
    let mut last_event = Instant::now();
    let mut tick = |busy: usize, last_event: &mut Instant| {
        let now = Instant::now();
        let dt = now.duration_since(*last_event).as_nanos() as u64;
        counters.busy_nanos.fetch_add(busy as u64 * dt, Relaxed);
        *last_event = now;
    };
    for req in rx {
        match req {
            Request::Checkout { target, fps, resp } => {
                tick(busy, &mut last_event);
                // under affinity, a zero-overlap request prefers warming
                // a fresh device (while capacity remains) over evicting
                // another request's residency on an idle one
                let pick = if idle[target].is_empty() {
                    None
                } else {
                    let (i, ov) = best_idle(&idle[target], &fps, policy);
                    let prefer_build = ov == 0
                        && built[target] < capacity
                        && matches!(policy, SchedPolicy::Affinity);
                    if prefer_build {
                        None
                    } else {
                        Some((i, ov))
                    }
                };
                if let Some((i, ov)) = pick {
                    let dev = idle[target].remove(i);
                    if ov > 0 {
                        counters.affinity_grants.fetch_add(1, Relaxed);
                    } else {
                        counters.fifo_grants.fetch_add(1, Relaxed);
                    }
                    counters.checkouts.fetch_add(1, Relaxed);
                    busy += 1;
                    if let Err(mpsc::SendError(Response::Grant(dev))) =
                        resp.send(Response::Grant(dev))
                    {
                        // requester vanished (panicked thread): reclaim
                        idle[target].push(dev);
                        busy -= 1;
                    }
                } else if built[target] < capacity {
                    built[target] += 1;
                    busy += 1;
                    counters.devices_built.fetch_add(1, Relaxed);
                    counters.build_grants.fetch_add(1, Relaxed);
                    counters.checkouts.fetch_add(1, Relaxed);
                    if resp.send(Response::Build).is_err() {
                        built[target] -= 1;
                        busy -= 1;
                    }
                } else {
                    counters.queued.fetch_add(1, Relaxed);
                    waiting.push(Waiter {
                        seq: next_seq,
                        target,
                        fps,
                        resp,
                        passed_over: 0,
                        since: Instant::now(),
                    });
                    next_seq += 1;
                }
            }
            Request::Return { target, mut device, cycles } => {
                tick(busy, &mut last_event);
                busy -= 1;
                counters.busy_cycles.fetch_add(cycles, Relaxed);
                // every request still queued for this target sat behind
                // those modeled cycles — charge each of them
                let stalled =
                    waiting.iter().filter(|w| w.target == target).count() as u64;
                counters.wait_cycles.fetch_add(cycles * stalled, Relaxed);
                loop {
                    let Some((idx, kind)) =
                        choose_waiter(&waiting, target, &device, policy)
                    else {
                        // no waiter for this target: park at the back of
                        // the idle queue (round-robin fallback order)
                        idle[target].push(device);
                        break;
                    };
                    let w = waiting.remove(idx);
                    for o in waiting
                        .iter_mut()
                        .filter(|o| o.target == target && o.seq < w.seq)
                    {
                        o.passed_over += 1;
                    }
                    match kind {
                        GrantKind::Affinity => {
                            counters.affinity_grants.fetch_add(1, Relaxed)
                        }
                        GrantKind::Fifo => counters.fifo_grants.fetch_add(1, Relaxed),
                        GrantKind::Starved => {
                            counters.starvation_promotions.fetch_add(1, Relaxed)
                        }
                    };
                    counters
                        .wait_nanos
                        .fetch_add(w.since.elapsed().as_nanos() as u64, Relaxed);
                    counters.checkouts.fetch_add(1, Relaxed);
                    busy += 1;
                    match w.resp.send(Response::Grant(device)) {
                        Ok(()) => break,
                        // waiter died while queued: take the device back
                        // and try the next candidate
                        Err(mpsc::SendError(Response::Grant(d))) => {
                            device = d;
                            busy -= 1;
                        }
                        Err(_) => unreachable!("return path only sends grants"),
                    }
                }
            }
            Request::Shutdown => break,
        }
    }
    // dropping `waiting` closes every queued response channel, so any
    // thread still blocked in checkout() observes PoolError::Closed
}

/// An arbitrated pool of up to K [`IlaSim`] devices per target, shared
/// by every [`super::ExecEngine`] the owning session hands out. See the
/// module docs for the scheduling model.
pub struct DevicePool {
    req_tx: mpsc::Sender<Request>,
    arbiter: Mutex<Option<JoinHandle<()>>>,
    counters: Arc<Counters>,
    capacity: usize,
    policy: SchedPolicy,
}

impl DevicePool {
    /// A queued request passed over this many times by affinity routing
    /// is served next regardless of overlap — the starvation bound.
    pub const STARVATION_BOUND: u32 = 4;

    /// Create a pool of up to `devices_per_target` devices per target
    /// (clamped to ≥ 1), scheduled by `policy`. Devices are built lazily
    /// on first demand, so unused targets cost nothing.
    pub fn new(devices_per_target: usize, policy: SchedPolicy) -> Self {
        let capacity = devices_per_target.max(1);
        let (req_tx, req_rx) = mpsc::channel();
        let counters = Arc::new(Counters::default());
        let worker_counters = Arc::clone(&counters);
        let handle = std::thread::Builder::new()
            .name("d2a-device-pool".into())
            .spawn(move || arbiter_loop(req_rx, capacity, policy, worker_counters))
            .expect("spawn device-pool arbiter thread");
        DevicePool {
            req_tx,
            arbiter: Mutex::new(Some(handle)),
            counters,
            capacity,
            policy,
        }
    }

    /// Maximum devices per target.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The pool's scheduling policy.
    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Snapshot the scheduling counters.
    pub fn stats(&self) -> PoolStats {
        let c = &self.counters;
        PoolStats {
            devices_built: c.devices_built.load(Relaxed),
            checkouts: c.checkouts.load(Relaxed),
            affinity_grants: c.affinity_grants.load(Relaxed),
            fifo_grants: c.fifo_grants.load(Relaxed),
            build_grants: c.build_grants.load(Relaxed),
            starvation_promotions: c.starvation_promotions.load(Relaxed),
            queued: c.queued.load(Relaxed),
            wait: Duration::from_nanos(c.wait_nanos.load(Relaxed)),
            busy: Duration::from_nanos(c.busy_nanos.load(Relaxed)),
            busy_cycles: c.busy_cycles.load(Relaxed),
            wait_cycles: c.wait_cycles.load(Relaxed),
        }
    }

    /// Check a device out for `target`, blocking until one is granted.
    /// `fps` are the requesting program's staged-burst fingerprints (the
    /// affinity score inputs); `build` constructs the device (simulator
    /// plus page table, so the caller picks the paged-DRAM capacity)
    /// when the pool reserves new capacity for this request.
    pub(crate) fn checkout(
        &self,
        target: Target,
        fps: &[u64],
        build: impl FnOnce() -> Device,
    ) -> Result<DeviceLease, PoolError> {
        let (resp_tx, resp_rx) = mpsc::channel();
        self.req_tx
            .send(Request::Checkout {
                target: target.index(),
                fps: fps.to_vec(),
                resp: resp_tx,
            })
            .map_err(|_| PoolError::Closed)?;
        let device = match resp_rx.recv().map_err(|_| PoolError::Closed)? {
            Response::Grant(d) => d,
            Response::Build => build(),
        };
        Ok(DeviceLease {
            device: Some(device),
            target: target.index(),
            cycles: 0,
            ret: self.req_tx.clone(),
        })
    }
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        let _ = self.req_tx.send(Request::Shutdown);
        if let Ok(mut guard) = self.arbiter.lock() {
            if let Some(handle) = guard.take() {
                let _ = handle.join();
            }
        }
    }
}

impl fmt::Debug for DevicePool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("DevicePool")
            .field("capacity", &self.capacity)
            .field("policy", &self.policy)
            .finish()
    }
}

/// A checked-out device. Dropping the lease returns the device — with
/// its residency set intact — to the pool for the next request.
pub struct DeviceLease {
    device: Option<Device>,
    target: usize,
    cycles: u64,
    ret: mpsc::Sender<Request>,
}

impl DeviceLease {
    pub(crate) fn device_mut(&mut self) -> &mut Device {
        self.device.as_mut().expect("lease already returned")
    }

    /// Attribute `c` modeled device cycles to this lease; reported to
    /// the arbiter at return for occupancy/wait accounting.
    pub(crate) fn note_cycles(&mut self, c: u64) {
        self.cycles += c;
    }
}

impl Drop for DeviceLease {
    fn drop(&mut self) {
        if let Some(device) = self.device.take() {
            // if the pool shut down first, the device is simply dropped
            let _ = self.ret.send(Request::Return {
                target: self.target,
                device,
                cycles: self.cycles,
            });
        }
    }
}

impl fmt::Debug for DeviceLease {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("DeviceLease").field("target", &self.target).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ila::{Ila, IlaState};

    fn toy_sim() -> IlaSim {
        let mut st = IlaState::new();
        st.new_mem("buf", 64);
        IlaSim::new(Ila::new("toy", st))
    }

    fn toy_dev() -> Device {
        Device::new(toy_sim())
    }

    fn device_with_fps(fps: &[u64]) -> Device {
        let mut d = Device::new(toy_sim());
        for &fp in fps {
            d.resident.push(Resident { mem: "buf".into(), lo: 0, hi: 1, fp });
        }
        d
    }

    fn waiter(seq: u64, target: usize, fps: &[u64], passed_over: u32) -> Waiter {
        Waiter {
            seq,
            target,
            fps: fps.to_vec(),
            resp: mpsc::channel().0,
            passed_over,
            since: Instant::now(),
        }
    }

    #[test]
    fn choose_waiter_prefers_best_overlap_under_affinity() {
        let dev = device_with_fps(&[1, 2, 3]);
        let waiting = vec![
            waiter(0, 0, &[9], 0),       // oldest, no overlap
            waiter(1, 0, &[1], 0),       // overlap 1
            waiter(2, 0, &[1, 2], 0),    // overlap 2 (best)
            waiter(3, 1, &[1, 2, 3], 0), // wrong target
        ];
        let (i, kind) = choose_waiter(&waiting, 0, &dev, SchedPolicy::Affinity).unwrap();
        assert_eq!(i, 2);
        assert!(matches!(kind, GrantKind::Affinity));
    }

    #[test]
    fn overlap_scores_paged_fingerprints_until_eviction() {
        let mut d = toy_dev();
        d.pages.alloc(42, 64).unwrap();
        assert_eq!(overlap(&d, &[42, 7]), 1, "paged fp counts toward affinity");
        let evicted = d.pages.flush();
        assert_eq!(evicted, vec![42]);
        assert_eq!(overlap(&d, &[42, 7]), 0, "evicted pages stop scoring");
    }

    #[test]
    fn choose_waiter_falls_back_to_fifo_on_zero_overlap() {
        let dev = device_with_fps(&[1]);
        let waiting = vec![waiter(5, 0, &[9], 0), waiter(6, 0, &[8], 0)];
        let (i, kind) = choose_waiter(&waiting, 0, &dev, SchedPolicy::Affinity).unwrap();
        assert_eq!(i, 0, "oldest request wins the fallback");
        assert!(matches!(kind, GrantKind::Fifo));
    }

    #[test]
    fn choose_waiter_fifo_policy_ignores_overlap() {
        let dev = device_with_fps(&[7]);
        let waiting = vec![waiter(0, 0, &[9], 0), waiter(1, 0, &[7], 0)];
        let (i, kind) = choose_waiter(&waiting, 0, &dev, SchedPolicy::Fifo).unwrap();
        assert_eq!(i, 0);
        assert!(matches!(kind, GrantKind::Fifo));
    }

    #[test]
    fn choose_waiter_starvation_bound_overrides_affinity() {
        let dev = device_with_fps(&[7]);
        let waiting = vec![
            waiter(0, 0, &[9], DevicePool::STARVATION_BOUND), // starved
            waiter(1, 0, &[7], 0),                            // perfect overlap
        ];
        let (i, kind) = choose_waiter(&waiting, 0, &dev, SchedPolicy::Affinity).unwrap();
        assert_eq!(i, 0, "the starved request must be served first");
        assert!(matches!(kind, GrantKind::Starved));
    }

    #[test]
    fn choose_waiter_none_for_other_targets() {
        let dev = device_with_fps(&[]);
        let waiting = vec![waiter(0, 1, &[1], 0)];
        assert!(choose_waiter(&waiting, 0, &dev, SchedPolicy::Affinity).is_none());
    }

    #[test]
    fn checkout_builds_up_to_capacity_then_queues() {
        let pool = DevicePool::new(1, SchedPolicy::Affinity);
        let lease = pool.checkout(Target::FlexAsr, &[], toy_dev).unwrap();
        let stats = pool.stats();
        assert_eq!(stats.devices_built, 1);
        assert_eq!(stats.build_grants, 1);
        assert_eq!(stats.checkouts, 1);
        drop(lease);
        // the returned device is granted, not rebuilt
        let lease2 = pool.checkout(Target::FlexAsr, &[], toy_dev).unwrap();
        let stats = pool.stats();
        assert_eq!(stats.devices_built, 1, "capacity 1 pool must reuse the device");
        assert_eq!(stats.checkouts, 2);
        drop(lease2);
    }

    #[test]
    fn contended_checkout_blocks_until_return() {
        let pool = Arc::new(DevicePool::new(1, SchedPolicy::Fifo));
        let lease = pool.checkout(Target::FlexAsr, &[], toy_dev).unwrap();
        let p2 = Arc::clone(&pool);
        let waiter = std::thread::spawn(move || {
            // blocks until the main thread drops its lease
            let l = p2.checkout(Target::FlexAsr, &[], toy_dev).unwrap();
            drop(l);
        });
        std::thread::sleep(Duration::from_millis(20));
        drop(lease);
        waiter.join().unwrap();
        let stats = pool.stats();
        assert_eq!(stats.devices_built, 1);
        assert_eq!(stats.checkouts, 2);
        assert_eq!(stats.queued, 1);
        assert!(stats.wait > Duration::ZERO);
    }

    #[test]
    fn modeled_cycle_accounting_reaches_pool_stats() {
        let pool = Arc::new(DevicePool::new(1, SchedPolicy::Fifo));
        let mut lease = pool.checkout(Target::FlexAsr, &[], toy_dev).unwrap();
        lease.note_cycles(100);
        lease.note_cycles(23);
        let p2 = Arc::clone(&pool);
        let waiter = std::thread::spawn(move || {
            let l = p2.checkout(Target::FlexAsr, &[], toy_dev).unwrap();
            drop(l);
        });
        std::thread::sleep(Duration::from_millis(20));
        drop(lease); // returns 123 modeled cycles while one request waits
        waiter.join().unwrap();
        // a further checkout serializes behind the waiter's return on the
        // arbiter's FIFO channel, so the counters below are settled
        let l = pool.checkout(Target::FlexAsr, &[], toy_dev).unwrap();
        drop(l);
        let s = pool.stats();
        assert_eq!(s.busy_cycles, 123, "only the first lease reported cycles");
        assert_eq!(s.wait_cycles, 123, "one request was queued behind the lease");
    }

    #[test]
    fn per_target_capacity_is_independent() {
        let pool = DevicePool::new(1, SchedPolicy::Affinity);
        let a = pool.checkout(Target::FlexAsr, &[], toy_dev).unwrap();
        // a different target gets its own device without waiting
        let b = pool.checkout(Target::Vta, &[], toy_dev).unwrap();
        assert_eq!(pool.stats().devices_built, 2);
        drop(a);
        drop(b);
    }

    #[test]
    fn stats_classify_grants_exclusively() {
        let pool = DevicePool::new(2, SchedPolicy::Affinity);
        let a = pool.checkout(Target::FlexAsr, &[1], toy_dev).unwrap();
        drop(a);
        let b = pool.checkout(Target::FlexAsr, &[2], toy_dev).unwrap();
        drop(b);
        let s = pool.stats();
        assert_eq!(
            s.affinity_grants + s.fifo_grants + s.build_grants + s.starvation_promotions,
            s.checkouts
        );
    }
}
