//! The accelerator registry: an owned, `Target`-indexed dispatch table.
//!
//! The registry replaces two seed-era patterns (whose deprecated shims,
//! `accel::accel_for` and `coordinator::accelerators`, are now deleted):
//!
//! * the O(n) `accel_for` linear scan on every intercepted node of the
//!   co-simulation hot loop, and
//! * the per-worker `coordinator::accelerators(rev)` re-instantiation,
//!   which rebuilt every accelerator model for each sweep thread.
//!
//! A registry is built once per [`super::Session`], wrapped in an `Arc`,
//! and shared by every [`super::CompiledProgram`] handle and worker
//! thread. Lookups index a fixed `[Option<usize>; Target::COUNT]` table,
//! so per-node dispatch is a single array read.

use super::DesignRev;
use crate::accel::{Accelerator, FlexAsr, Hlscnn, HlscnnConfig, Vta};
use crate::ir::{Op, Target};

/// Instantiate the accelerator models for a design revision. This is the
/// single place in the codebase that constructs the boxed model set;
/// everything else goes through an [`AcceleratorRegistry`].
pub fn models(rev: DesignRev) -> Vec<Box<dyn Accelerator>> {
    let (fa, hl) = match rev {
        DesignRev::Original => {
            (FlexAsr::original(), Hlscnn::new(HlscnnConfig::original()))
        }
        DesignRev::Updated => {
            (FlexAsr::updated(), Hlscnn::new(HlscnnConfig::updated()))
        }
    };
    vec![Box::new(fa), Box::new(hl), Box::new(Vta::new())]
}

/// An owned set of accelerator models with an O(1) target-indexed
/// dispatch table.
pub struct AcceleratorRegistry {
    accels: Vec<Box<dyn Accelerator>>,
    by_target: [Option<usize>; Target::COUNT],
    rev: Option<DesignRev>,
}

impl AcceleratorRegistry {
    /// Build a registry from an explicit model set. When two models claim
    /// the same target, the first registration wins (matching the old
    /// linear-scan semantics).
    pub fn new(accels: Vec<Box<dyn Accelerator>>) -> Self {
        let mut by_target = [None; Target::COUNT];
        for (i, a) in accels.iter().enumerate() {
            let slot = &mut by_target[a.target().index()];
            if slot.is_none() {
                *slot = Some(i);
            }
        }
        AcceleratorRegistry { accels, by_target, rev: None }
    }

    /// The standard three-accelerator set for a design revision (the
    /// Table 4 "Original" vs "Updated" columns).
    pub fn for_rev(rev: DesignRev) -> Self {
        let mut reg = Self::new(models(rev));
        reg.rev = Some(rev);
        reg
    }

    /// The design revision this registry was built for (`None` for
    /// custom model sets assembled via [`Self::new`]). Part of the
    /// engine's lowering-cache key, so programs lowered against one
    /// revision are never replayed under another.
    pub fn design_rev(&self) -> Option<DesignRev> {
        self.rev
    }

    /// O(1) lookup of the accelerator registered for a target.
    pub fn lookup(&self, target: Target) -> Option<&dyn Accelerator> {
        self.by_target[target.index()].map(|i| self.accels[i].as_ref())
    }

    /// O(1) lookup of the accelerator that owns `op` (None for host ops
    /// and for targets with no registered model).
    pub fn for_op(&self, op: &Op) -> Option<&dyn Accelerator> {
        self.lookup(op.target())
    }

    /// Registry slot index for a target — used by precomputed dispatch
    /// plans so the hot loop skips even the target match.
    pub fn slot_for(&self, target: Target) -> Option<usize> {
        self.by_target[target.index()]
    }

    /// Resolve a slot index obtained from [`Self::slot_for`].
    pub fn by_slot(&self, slot: usize) -> &dyn Accelerator {
        self.accels[slot].as_ref()
    }

    /// The registered models, in registration order.
    pub fn accels(&self) -> &[Box<dyn Accelerator>] {
        &self.accels
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.accels.len()
    }

    /// True when no model is registered.
    pub fn is_empty(&self) -> bool {
        self.accels.is_empty()
    }

    /// Targets with a registered model, in registration order.
    pub fn targets(&self) -> Vec<Target> {
        self.accels.iter().map(|a| a.target()).collect()
    }
}

impl std::fmt::Debug for AcceleratorRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AcceleratorRegistry")
            .field("targets", &self.targets())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_indexed_lookup() {
        let reg = AcceleratorRegistry::for_rev(DesignRev::Updated);
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.lookup(Target::FlexAsr).unwrap().name(), "FlexASR");
        assert_eq!(reg.lookup(Target::Hlscnn).unwrap().name(), "HLSCNN");
        assert_eq!(reg.lookup(Target::Vta).unwrap().name(), "VTA");
        assert!(reg.lookup(Target::Host).is_none());
    }

    #[test]
    fn for_op_dispatches_by_op_target() {
        let reg = AcceleratorRegistry::for_rev(DesignRev::Original);
        assert_eq!(reg.for_op(&Op::FlexLinear).unwrap().name(), "FlexASR");
        assert_eq!(reg.for_op(&Op::VtaGemm).unwrap().name(), "VTA");
        assert!(reg.for_op(&Op::Dense).is_none());
    }

    #[test]
    fn first_registration_wins() {
        let reg = AcceleratorRegistry::new(vec![
            Box::new(FlexAsr::original()),
            Box::new(FlexAsr::updated()),
        ]);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.slot_for(Target::FlexAsr), Some(0));
    }

    #[test]
    fn partial_registry_has_gaps() {
        let reg = AcceleratorRegistry::new(vec![Box::new(Vta::new())]);
        assert!(reg.lookup(Target::FlexAsr).is_none());
        assert_eq!(reg.lookup(Target::Vta).unwrap().name(), "VTA");
        assert_eq!(reg.targets(), vec![Target::Vta]);
    }
}
