//! Named input bindings for program execution.
//!
//! A [`Bindings`] maps IR leaf names (`Var`/`Weight`) to tensors. It
//! replaces the raw `HashMap<String, Tensor>` environments of the seed
//! API — and, crucially, makes the *input* variable of a sweep an
//! explicit parameter instead of the hardcoded `"x"` the old
//! `coordinator::classify_sweep` assumed.

use crate::tensor::Tensor;
use std::collections::HashMap;

/// Named tensor bindings for one evaluation of a compiled program.
#[derive(Debug, Clone, Default)]
pub struct Bindings {
    env: HashMap<String, Tensor>,
}

impl Bindings {
    /// Empty bindings.
    pub fn new() -> Self {
        Self::default()
    }

    /// Wrap an existing environment map (e.g. an artifact-store weight
    /// bundle) without copying.
    pub fn from_env(env: HashMap<String, Tensor>) -> Self {
        Bindings { env }
    }

    /// Builder-style insert.
    pub fn with(mut self, name: &str, value: Tensor) -> Self {
        self.set(name, value);
        self
    }

    /// Bind `name` to `value`, replacing any previous binding.
    pub fn set(&mut self, name: &str, value: Tensor) {
        self.env.insert(name.to_string(), value);
    }

    /// Look up a binding.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.env.get(name)
    }

    /// The underlying environment map (what the interpreter consumes).
    pub fn env(&self) -> &HashMap<String, Tensor> {
        &self.env
    }

    /// Number of bound names.
    pub fn len(&self) -> usize {
        self.env.len()
    }

    /// True when nothing is bound.
    pub fn is_empty(&self) -> bool {
        self.env.is_empty()
    }
}

impl From<HashMap<String, Tensor>> for Bindings {
    fn from(env: HashMap<String, Tensor>) -> Self {
        Bindings { env }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_style_binding() {
        let b = Bindings::new()
            .with("x", Tensor::ones(&[2, 2]))
            .with("w", Tensor::zeros(&[2]));
        assert_eq!(b.len(), 2);
        assert_eq!(b.get("x").unwrap().shape, vec![2, 2]);
        assert!(b.get("y").is_none());
    }

    #[test]
    fn set_replaces() {
        let mut b = Bindings::new();
        b.set("x", Tensor::zeros(&[1]));
        b.set("x", Tensor::ones(&[3]));
        assert_eq!(b.len(), 1);
        assert_eq!(b.get("x").unwrap().shape, vec![3]);
    }
}
