//! Named input bindings for program execution.
//!
//! A [`Bindings`] maps IR leaf names (`Var`/`Weight`) to tensors. It
//! replaces the raw `HashMap<String, Tensor>` environments of the seed
//! API — and, crucially, makes the *input* variable of a sweep an
//! explicit parameter instead of the hardcoded `"x"` the old (deleted)
//! `coordinator::classify_sweep` shim assumed.

use crate::ir::interp::EnvLookup;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// A borrowed environment layering one per-datapoint binding over a
/// shared base map — the allocation-free worker environment of
/// [`crate::session::CompiledProgram::classify_sweep`].
///
/// The seed sweep cloned the whole weight map once per worker and then
/// re-inserted the input tensor per point; a `LayeredEnv` is two
/// references, so worker spin-up allocates nothing and the shared
/// weights are read in place by every thread.
#[derive(Debug, Clone, Copy)]
pub struct LayeredEnv<'a> {
    base: &'a HashMap<String, Tensor>,
    name: &'a str,
    value: &'a Tensor,
}

impl<'a> LayeredEnv<'a> {
    /// Layer `name → value` over `base` (the override wins on collision,
    /// matching the seed's insert-over-clone semantics).
    pub fn new(base: &'a HashMap<String, Tensor>, name: &'a str, value: &'a Tensor) -> Self {
        LayeredEnv { base, name, value }
    }
}

impl EnvLookup for LayeredEnv<'_> {
    fn lookup(&self, name: &str) -> Option<&Tensor> {
        if name == self.name {
            Some(self.value)
        } else {
            self.base.get(name)
        }
    }
}

/// Named tensor bindings for one evaluation of a compiled program.
#[derive(Debug, Clone, Default)]
pub struct Bindings {
    env: HashMap<String, Tensor>,
}

impl Bindings {
    /// Empty bindings.
    pub fn new() -> Self {
        Self::default()
    }

    /// Wrap an existing environment map (e.g. an artifact-store weight
    /// bundle) without copying.
    pub fn from_env(env: HashMap<String, Tensor>) -> Self {
        Bindings { env }
    }

    /// Builder-style insert.
    pub fn with(mut self, name: &str, value: Tensor) -> Self {
        self.set(name, value);
        self
    }

    /// Bind `name` to `value`, replacing any previous binding.
    pub fn set(&mut self, name: &str, value: Tensor) {
        self.env.insert(name.to_string(), value);
    }

    /// Look up a binding.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.env.get(name)
    }

    /// The underlying environment map (what the interpreter consumes).
    pub fn env(&self) -> &HashMap<String, Tensor> {
        &self.env
    }

    /// Number of bound names.
    pub fn len(&self) -> usize {
        self.env.len()
    }

    /// True when nothing is bound.
    pub fn is_empty(&self) -> bool {
        self.env.is_empty()
    }
}

impl From<HashMap<String, Tensor>> for Bindings {
    fn from(env: HashMap<String, Tensor>) -> Self {
        Bindings { env }
    }
}

impl EnvLookup for Bindings {
    fn lookup(&self, name: &str) -> Option<&Tensor> {
        self.env.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_style_binding() {
        let b = Bindings::new()
            .with("x", Tensor::ones(&[2, 2]))
            .with("w", Tensor::zeros(&[2]));
        assert_eq!(b.len(), 2);
        assert_eq!(b.get("x").unwrap().shape, vec![2, 2]);
        assert!(b.get("y").is_none());
    }

    #[test]
    fn set_replaces() {
        let mut b = Bindings::new();
        b.set("x", Tensor::zeros(&[1]));
        b.set("x", Tensor::ones(&[3]));
        assert_eq!(b.len(), 1);
        assert_eq!(b.get("x").unwrap().shape, vec![3]);
    }

    #[test]
    fn layered_env_overrides_without_touching_base() {
        let base: HashMap<String, Tensor> = [
            ("w".to_string(), Tensor::ones(&[2])),
            ("x".to_string(), Tensor::zeros(&[2])),
        ]
        .into_iter()
        .collect();
        let point = Tensor::ones(&[4]);
        let env = LayeredEnv::new(&base, "x", &point);
        assert_eq!(env.lookup("x").unwrap().shape, vec![4], "override wins");
        assert_eq!(env.lookup("w").unwrap().shape, vec![2], "base visible");
        assert!(env.lookup("missing").is_none());
        assert_eq!(base.get("x").unwrap().shape, vec![2], "base untouched");
    }
}
