//! Backend-selectable accelerator execution: one engine driving either
//! the tensor fast path, the MMIO-level ILA simulators, or both.
//!
//! The paper's central object is the ILA — the formal software/hardware
//! interface from which simulators are derived — yet the seed hot path
//! only ever ran the hand-written tensor semantics
//! ([`Accelerator::exec_op`]), with MMIO-level execution stranded in
//! per-accelerator test helpers. [`ExecEngine`] makes the choice a
//! first-class, per-[`super::Session`] knob:
//!
//! * [`ExecBackend::Functional`] — the tensor fast path (default; what
//!   2000-image sweeps want);
//! * [`ExecBackend::IlaMmio`] — lower every accelerator op to an MMIO
//!   command program ([`Accelerator::lower`]) and run it on a per-worker
//!   [`IlaSim`] (deployment fidelity: every byte crosses the modeled
//!   interface);
//! * [`ExecBackend::CrossCheck`] — run **both**, bit-compare, and
//!   accumulate per-op mismatch statistics in a [`FidelityReport`]
//!   instead of aborting — the always-on VT3-style consistency check.
//!   On `DesignRev::Original` this visibly flags HLSCNN, whose silicon
//!   truncates wire-precision weights into the 8-bit store while the
//!   software model rounds (see `accel::hlscnn::model::wire_to_store`) —
//!   the repo-native version of the paper's "uncovered an unknown flaw"
//!   case study.
//!
//! Ops whose operands exceed the device buffers are **tiled** by the
//! driver into multi-trigger [`LoweredProgram`]s (weight-row tiles,
//! per-step LSTM gate tiles, output-channel tiles, flat ALU chunks), so
//! even the full Table 1 LSTM-WLM gate matrix executes as real MMIO.
//! Ops an accelerator genuinely cannot lower (pure data movement,
//! inputs larger than the staging buffers) fall back to the tensor path
//! under every backend, so whole applications always run end to end —
//! and [`FidelityReport::total_unlowered`] discloses every fallback.

use super::pool::{Device, DevicePool, Resident};
use super::{AcceleratorRegistry, DesignRev};
use crate::accel::flexasr::model as fx;
use crate::accel::flexasr::paging::PageTable;
use crate::accel::Accelerator;
use crate::codegen::{self, Burst, LoweredInvocation, LoweredProgram, ProgramTemplate};
use crate::cost::{self, CostTable, CycleBreakdown, Event, OpFamily, Timeline};
use crate::ila::sim::IlaSim;
use crate::ila::{Cmd, Ila};
use crate::ir::interp::EvalError;
use crate::ir::{Op, Target};
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::fmt;
use std::sync::Arc;

/// Which execution path a session's accelerator invocations take.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// Tensor-level bit-accurate fast path (`Accelerator::exec_op`).
    #[default]
    Functional,
    /// Driver-level MMIO programs on the ILA simulators
    /// (`Accelerator::lower` + `IlaSim`).
    IlaMmio,
    /// Run both paths, bit-compare every invocation, and accumulate a
    /// [`FidelityReport`]; the functional result flows onward.
    CrossCheck,
}

impl fmt::Display for ExecBackend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecBackend::Functional => write!(f, "functional"),
            ExecBackend::IlaMmio => write!(f, "ila-mmio"),
            ExecBackend::CrossCheck => write!(f, "cross-check"),
        }
    }
}

/// Per-op fidelity statistics accumulated by `ExecBackend::CrossCheck`.
#[derive(Debug, Clone)]
pub struct FidelityRecord {
    /// S-expression head of the op (e.g. `hlscnn_conv2d<s(1, 1),p(1, 1)>`).
    pub op: String,
    /// Owning accelerator target.
    pub target: Target,
    /// Invocations cross-checked (functional and MMIO both ran).
    pub checked: usize,
    /// Invocations whose two results were **not** bit-identical.
    pub mismatches: usize,
    /// Largest element-wise |functional − mmio| seen.
    pub max_abs_diff: f32,
}

/// Aggregate cross-check outcome of a run (empty unless the backend was
/// [`ExecBackend::CrossCheck`]). Mismatches are *reported*, never
/// panicked: the run completes on the functional results and the report
/// says where the two views of the hardware disagreed.
#[derive(Debug, Clone, Default)]
pub struct FidelityReport {
    records: Vec<FidelityRecord>,
    unlowered: usize,
}

impl FidelityReport {
    /// Per-op records, in first-seen order.
    pub fn records(&self) -> &[FidelityRecord] {
        &self.records
    }

    /// Total invocations cross-checked.
    pub fn total_checked(&self) -> usize {
        self.records.iter().map(|r| r.checked).sum()
    }

    /// Invocations that could NOT be cross-checked because the op has no
    /// MMIO lowering (data movement, device-capacity declines) and ran
    /// functional-only. A clean report with a non-zero count here means
    /// "everything *checked* agreed", not "everything was checked".
    pub fn total_unlowered(&self) -> usize {
        self.unlowered
    }

    /// Total bit-mismatched invocations.
    pub fn total_mismatches(&self) -> usize {
        self.records.iter().map(|r| r.mismatches).sum()
    }

    /// True when every checked invocation was bit-identical (vacuously
    /// true when nothing was checked).
    pub fn is_clean(&self) -> bool {
        self.total_mismatches() == 0
    }

    /// Records that saw at least one mismatch.
    pub fn mismatched(&self) -> impl Iterator<Item = &FidelityRecord> {
        self.records.iter().filter(|r| r.mismatches > 0)
    }

    /// Index of the record for `(op, target)`, creating it on first use.
    fn entry(&mut self, op: String, target: Target) -> usize {
        match self.records.iter().position(|r| r.target == target && r.op == op) {
            Some(i) => i,
            None => {
                self.records.push(FidelityRecord {
                    op,
                    target,
                    checked: 0,
                    mismatches: 0,
                    max_abs_diff: 0.0,
                });
                self.records.len() - 1
            }
        }
    }

    /// Record one cross-checked invocation.
    pub fn record(&mut self, op: &Op, target: Target, functional: &Tensor, mmio: &Tensor) {
        let idx = self.entry(op.head(), target);
        let rec = &mut self.records[idx];
        rec.checked += 1;
        if functional.shape != mmio.shape {
            rec.mismatches += 1;
            rec.max_abs_diff = f32::INFINITY;
        } else if functional != mmio {
            rec.mismatches += 1;
            rec.max_abs_diff = rec.max_abs_diff.max(functional.max_abs_diff(mmio));
        }
    }

    /// Fold another report (e.g. from a sweep worker) into this one.
    pub fn merge(&mut self, other: FidelityReport) {
        self.unlowered += other.unlowered;
        for rec in other.records {
            let idx = self.entry(rec.op.clone(), rec.target);
            let into = &mut self.records[idx];
            into.checked += rec.checked;
            into.mismatches += rec.mismatches;
            into.max_abs_diff = into.max_abs_diff.max(rec.max_abs_diff);
        }
    }

    /// Merge a batch of per-worker reports into one — the single merge
    /// point at a sweep/pool boundary. The result is **worker-order
    /// independent**: counts are commutative sums and the records are
    /// put in canonical `(target, op)` order, so the same sweep run
    /// with different worker counts (or interleavings) produces an
    /// identical report. Prefer this over folding [`Self::merge`] in a
    /// join loop, whose record order follows first-seen worker order.
    pub fn merge_all(reports: impl IntoIterator<Item = FidelityReport>) -> FidelityReport {
        let mut out = FidelityReport::default();
        for rep in reports {
            out.merge(rep);
        }
        out.records
            .sort_by(|a, b| (a.target.index(), &a.op).cmp(&(b.target.index(), &b.op)));
        out
    }
}

impl fmt::Display for FidelityReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.records.is_empty() && self.unlowered == 0 {
            return write!(f, "fidelity: nothing cross-checked");
        }
        writeln!(
            f,
            "fidelity: {}/{} invocations bit-identical",
            self.total_checked() - self.total_mismatches(),
            self.total_checked()
        )?;
        for r in &self.records {
            writeln!(
                f,
                "  {:<8} {:<28} checked {:>6}  mismatched {:>6}  max|Δ| {:.6}",
                r.target.to_string(),
                r.op,
                r.checked,
                r.mismatches,
                r.max_abs_diff
            )?;
        }
        if self.unlowered > 0 {
            writeln!(
                f,
                "  NOTE: {} invocation(s) had no MMIO lowering (capacity/data \
                 movement) and ran functional-only — NOT cross-checked",
                self.unlowered
            )?;
        }
        Ok(())
    }
}

/// Cache key of one lowering: the accelerator, the design revision it
/// was instantiated for, the op head, every operand's **shape**, and a
/// content fingerprint of the **weight** operands only (per
/// [`Accelerator::weight_operands`]). Input operand *values* are
/// deliberately absent — [`Accelerator::lower`] produces a weight-keyed
/// [`ProgramTemplate`] that is valid for every input of the keyed
/// shapes, so an input-varying sweep over a fixed layer hits one entry
/// per op instead of missing per data point.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct LowerKey {
    target: Target,
    rev: Option<DesignRev>,
    op: String,
    shapes: Vec<Vec<usize>>,
    weights: Vec<u64>,
}

/// Default bound on cached program templates per engine (distinct layers
/// in big models would otherwise grow the memo without bound, and a
/// tiled template can hold megabytes of encoded weight bursts). When
/// full, the **least-recently-used single entry** is evicted, so hot
/// repeated-layer templates survive churn that a wholesale clear would
/// flush. Override per engine with
/// [`ExecEngine::with_lowering_cache_capacity`].
const LOWER_CACHE_CAP: usize = 16;

/// One cached template plus its LRU stamp.
struct CacheSlot {
    tmpl: Option<Arc<ProgramTemplate>>,
    last_use: u64,
}

/// A per-engine memo of weight-keyed program templates, `Arc`-shared
/// with every caller. A hit skips re-encoding the weight bursts **and**
/// skips the driver-side calibration mirrors a monolithic lowering must
/// otherwise recompute per call (the FlexASR forced-bias bound factors
/// and the tiled-LSTM bias schedule) — the dominant host-side cost of
/// the MMIO path; only the cheap per-call [`ProgramTemplate::bind`]
/// remains. Declines (`lower` → `None`) are cached too, so unlowerable
/// ops pay the probe once per (shape, weight) set. Eviction is per-entry
/// LRU up to `cap`, counted in `evictions`.
struct LoweringCache {
    entries: HashMap<LowerKey, CacheSlot>,
    cap: usize,
    clock: u64,
    hits: u64,
    misses: u64,
    mirror_hits: u64,
    evictions: u64,
}

impl Default for LoweringCache {
    fn default() -> Self {
        LoweringCache {
            entries: HashMap::new(),
            cap: LOWER_CACHE_CAP,
            clock: 0,
            hits: 0,
            misses: 0,
            mirror_hits: 0,
            evictions: 0,
        }
    }
}

/// Drop residency entries that `cmds` may invalidate: writes to a
/// declared DMA/copy hazard doorbell clear the hazard's whole memory,
/// and a loose write landing inside a staging window clears overlapping
/// entries. (Operand bursts themselves are reconciled separately by the
/// streaming loop.)
fn invalidate_hazards(resident: &mut Vec<Resident>, model: &Ila, cmds: &[Cmd]) {
    for c in cmds.iter().filter(|c| c.is_write) {
        if resident.is_empty() {
            return;
        }
        for (addr, mem) in &model.hazards {
            if c.addr == *addr {
                resident.retain(|r| &r.mem != mem);
            }
        }
        if let Some((mem, lo, hi)) = model.staging_for(c.addr, c.len as usize) {
            resident.retain(|r| r.mem != mem || r.hi <= lo || r.lo >= hi);
        }
    }
}

/// Where each of a program's weight-staging-DRAM bursts lands, decided
/// by the **stage-planning pass** before any command runs (see
/// [`crate::accel::flexasr::paging`]). Keyed by `(invocation index,
/// burst index)`; the value is `(physical page offset, already
/// resident)` — `None` for the physical offset means the whole program
/// fell back to unpaged direct streaming at logical offsets.
struct PagingPlan {
    places: HashMap<(usize, usize), (Option<usize>, bool)>,
    /// `(logical_lo, logical_hi, phys_lo)` triples for rewriting
    /// `DMA_CTRL` source offsets from the lowering's logical cursor to
    /// the allocated page.
    remap: Vec<(usize, usize, usize)>,
}

impl PagingPlan {
    fn empty() -> Self {
        PagingPlan { places: HashMap::new(), remap: Vec::new() }
    }

    /// Physical source offset for a `DMA_CTRL` copy of `[src, src+len)`,
    /// when some page covers that logical range.
    fn remap_src(&self, src: usize, len: usize) -> Option<usize> {
        self.remap
            .iter()
            .find_map(|&(llo, lhi, plo)| {
                (src >= llo && src + len <= lhi).then(|| plo + (src - llo))
            })
    }

    /// The memory range burst `key` actually occupies: its page when
    /// paged, else its logical `[lo, hi)`.
    fn phys_range(&self, key: &(usize, usize), lo: usize, hi: usize) -> (usize, usize) {
        match self.places.get(key) {
            Some(&(Some(phys), _)) => (phys, phys + (hi - lo)),
            _ => (lo, hi),
        }
    }
}

/// The stage-planning pass: walk every DRAM-window stage burst of the
/// program and decide its placement in the device's [`PageTable`] before
/// a single command runs. Recurring fingerprints keep their pages
/// (LRU-touched and pinned); new ones allocate, evicting LRU unpinned
/// pages — whose residency entries are purged here, so the affinity
/// scores in [`super::pool`] stop counting them. If placement fails
/// (fragmentation against this program's own pins), the table is
/// flushed once and planning restarts from empty; if even an empty
/// table cannot hold the working set, the whole program streams unpaged
/// at the lowering's logical offsets (mutually disjoint by
/// construction) with no residency claims.
fn plan_paging(
    model: &Ila,
    resident: &mut Vec<Resident>,
    pages: &mut PageTable,
    prog: &LoweredProgram,
) -> PagingPlan {
    // the program's DRAM-window stage bursts, in streaming order:
    // (key, fingerprint, mem, logical_lo, len)
    let mut dram: Vec<((usize, usize), u64, String, usize, usize)> = Vec::new();
    for (i, inv) in prog.invocations.iter().enumerate() {
        for (bi, b) in inv.bursts.iter().enumerate() {
            let Some(r) = &b.region else { continue };
            if !fx::in_wgt_dram(r.base, r.len) {
                continue;
            }
            if let Some((mem, lo, hi)) = model.staging_for(r.base, r.len) {
                dram.push(((i, bi), b.fingerprint, mem.to_string(), lo, hi - lo));
            }
        }
    }
    if dram.is_empty() {
        return PagingPlan::empty();
    }
    let dram_mem = dram[0].2.clone();
    pages.unpin_all();
    for _attempt in 0..2 {
        if let Some(plan) = try_place(resident, pages, &dram) {
            return plan;
        }
        pages.flush();
        resident.retain(|r| r.mem != dram_mem);
    }
    // working set beyond even an empty table: stream everything unpaged
    let mut plan = PagingPlan::empty();
    for (key, ..) in &dram {
        plan.places.insert(*key, (None, false));
    }
    plan
}

/// One placement attempt over the current table state; `None` when some
/// burst cannot be placed even after evicting every unpinned page.
fn try_place(
    resident: &mut Vec<Resident>,
    pages: &mut PageTable,
    dram: &[((usize, usize), u64, String, usize, usize)],
) -> Option<PagingPlan> {
    let mut plan = PagingPlan::empty();
    for (key, fp, mem, lo, len) in dram {
        let (off, hit) = match pages.lookup(*fp) {
            Some(off) => {
                // page hit: resident only if the bytes also survived
                // (hazard invalidation may have dropped the claim)
                let hit = resident.iter().any(|r| {
                    &r.mem == mem && r.lo == off && r.hi == off + len && r.fp == *fp
                });
                (off, hit)
            }
            None => {
                let (off, evicted) = pages.alloc(*fp, *len)?;
                if !evicted.is_empty() {
                    resident.retain(|r| &r.mem != mem || !evicted.contains(&r.fp));
                }
                (off, false)
            }
        };
        plan.places.insert(*key, (Some(off), hit));
        plan.remap.push((*lo, lo + len, off));
    }
    Some(plan)
}

/// The memory ranges an invocation's staged bursts occupy (page-mapped),
/// i.e. what its in-flight trigger may still be reading.
fn staged_ranges(
    model: &Ila,
    plan: &PagingPlan,
    inv_idx: usize,
    inv: &LoweredInvocation,
) -> Vec<(String, usize, usize)> {
    inv.bursts
        .iter()
        .enumerate()
        .filter_map(|(bi, b)| {
            let r = b.region.as_ref()?;
            let (mem, lo, hi) = model.staging_for(r.base, r.len)?;
            let (plo, phi) = plan.phys_range(&(inv_idx, bi), lo, hi);
            Some((mem.to_string(), plo, phi))
        })
        .collect()
}

/// Is it safe to stream a staged burst into `mem[lo..hi)` while the
/// current invocation's trigger is still in flight? Refused when `mem`
/// is the target of any declared hazard doorbell — the in-flight
/// invocation's `DMA_CTRL` replay writes that memory, the
/// write-after-read the [`Ila::hazard`] declaration makes explicit (this
/// serializes the direct pe-weight path) — or when the in-flight
/// invocation itself staged an overlapping range of the same memory.
fn prefetch_safe(
    model: &Ila,
    mem: &str,
    lo: usize,
    hi: usize,
    inflight: &[(String, usize, usize)],
) -> bool {
    if model.hazards.iter().any(|(_, hmem)| hmem == mem) {
        return false;
    }
    !inflight.iter().any(|(m, ilo, ihi)| m == mem && *ilo < hi && lo < *ihi)
}

/// The per-worker execution engine: routes accelerator invocations to
/// the backend's path(s), owns lazily-built per-target [`IlaSim`]
/// instances, and accumulates the cross-check [`FidelityReport`].
///
/// An engine is cheap to create under `Functional` (no simulator state);
/// MMIO simulators are instantiated on first use per target and
/// **dirty-region reset** before every lowered program (only the state
/// the previous program touched is restored — see
/// [`IlaSim::reset_dirty`]), so results are independent of invocation
/// order and worker count without paying a full state clone per op.
///
/// Engines are built to be **held across calls**: obtain one from
/// [`super::CompiledProgram::engine`] and pass it to the `*_with` run
/// APIs ([`super::CompiledProgram::run_with`] and friends) to amortize
/// simulator construction over a whole session instead of rebuilding the
/// per-target simulators on every single-point evaluation.
///
/// A held engine additionally learns **operand residency**: every
/// staged burst whose MMIO range maps onto a declared host-exclusive
/// staging window ([`Ila::stage_region`]) is fingerprinted, the
/// between-program dirty reset keeps those ranges staged
/// ([`IlaSim::reset_dirty_keeping`]), and a later program presenting a
/// bit-identical burst for the same range skips streaming it entirely —
/// counted by [`Self::bursts_deduped`], with total interface traffic in
/// [`Self::bytes_streamed`]. Combined with the per-engine lowering
/// cache (program + calibration-mirror memo, [`Self::mirror_hits`]),
/// repeated MMIO evaluations of one layer re-stream only the operands
/// that actually changed.
///
/// Engines come in two flavors, chosen at construction:
///
/// * **private** ([`Self::new`]) — the engine owns one lazily-built
///   device per target, the classic one-simulator-set-per-worker model;
/// * **pooled** ([`Self::new_pooled`]) — the engine checks a device out
///   of a shared [`DevicePool`] per lowered program and returns it with
///   its residency set intact, so residency built up by one worker is
///   visible to the next request the pool routes to that device. The
///   residency reconciliation in [`Self::bytes_streamed`] accounting is
///   identical either way: a staged burst is skipped only when the
///   device's resident fingerprint matches bit-for-bit, so results do
///   not depend on which device the pool picked.
pub struct ExecEngine<'r> {
    registry: &'r AcceleratorRegistry,
    backend: ExecBackend,
    devices: DeviceSource,
    cache: LoweringCache,
    fidelity: FidelityReport,
    lowered: usize,
    triggers: usize,
    sims_built: usize,
    bytes_streamed: u64,
    bursts_deduped: u64,
    staged_streamed: u64,
    prefetched: u64,
    prefetch: bool,
    dram_capacity: usize,
    timeline: Timeline,
}

/// Where an engine's MMIO devices come from: a private lazily-built
/// per-target set, or a shared arbitrated pool.
enum DeviceSource {
    Private(Box<[Option<Device>; Target::COUNT]>),
    Pooled(Arc<DevicePool>),
}

impl<'r> ExecEngine<'r> {
    /// Build an engine over a registry for the given backend, with
    /// private per-target devices (built lazily on first MMIO use).
    pub fn new(registry: &'r AcceleratorRegistry, backend: ExecBackend) -> Self {
        let slots = Box::new(std::array::from_fn(|_| None));
        Self::with_devices(registry, backend, DeviceSource::Private(slots))
    }

    /// Build an engine that draws devices from a shared [`DevicePool`]
    /// instead of owning private simulators: each lowered program checks
    /// a device out (blocking under contention) and returns it — with
    /// its residency set intact — when the program finishes.
    pub fn new_pooled(
        registry: &'r AcceleratorRegistry,
        backend: ExecBackend,
        pool: Arc<DevicePool>,
    ) -> Self {
        Self::with_devices(registry, backend, DeviceSource::Pooled(pool))
    }

    fn with_devices(
        registry: &'r AcceleratorRegistry,
        backend: ExecBackend,
        devices: DeviceSource,
    ) -> Self {
        ExecEngine {
            registry,
            backend,
            devices,
            cache: LoweringCache::default(),
            fidelity: FidelityReport::default(),
            lowered: 0,
            triggers: 0,
            sims_built: 0,
            bytes_streamed: 0,
            bursts_deduped: 0,
            staged_streamed: 0,
            prefetched: 0,
            prefetch: true,
            dram_capacity: fx::WGT_DRAM_SIZE,
            timeline: Timeline::new(),
        }
    }

    /// Toggle ahead-of-trigger prefetch (on by default): when enabled,
    /// the engine stages invocation N+1's safe operand bursts while
    /// invocation N's trigger is still in flight, crediting the overlap
    /// against the trigger's modeled latency (see
    /// [`Event::PrefetchedStage`]). Results are bit-identical either
    /// way — the hazard rule refuses any burst the in-flight invocation
    /// could still observe — so this is the A/B knob for quantifying
    /// the overlap win.
    pub fn with_prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Cap the paged weight-staging DRAM managed per device (clamped to
    /// the architectural [`fx::WGT_DRAM_SIZE`]; that full size is the
    /// default). Affects devices built *after* this call — eviction
    /// tests inject small capacities here to force LRU churn on
    /// otherwise-comfortable tile sets.
    pub fn with_dram_capacity(mut self, bytes: usize) -> Self {
        self.dram_capacity = bytes.min(fx::WGT_DRAM_SIZE);
        self
    }

    /// Cap the per-engine template cache at `entries` (clamped to ≥ 1;
    /// default [`LOWER_CACHE_CAP`]). Sessions serving many distinct
    /// layers raise it to keep every template hot; capacity tests shrink
    /// it to force LRU churn.
    pub fn with_lowering_cache_capacity(mut self, entries: usize) -> Self {
        self.cache.cap = entries.max(1);
        self
    }

    /// The template-cache capacity in effect.
    pub fn lowering_cache_capacity(&self) -> usize {
        self.cache.cap
    }

    /// True when this engine draws devices from a shared [`DevicePool`].
    pub fn pooled(&self) -> bool {
        matches!(self.devices, DeviceSource::Pooled(_))
    }

    /// The engine's backend.
    pub fn backend(&self) -> ExecBackend {
        self.backend
    }

    /// True when this engine dispatches into `registry`'s model set (the
    /// compatibility check behind the `*_with` run APIs: a simulator
    /// cache is only valid for the registry that built it).
    pub fn serves(&self, registry: &AcceleratorRegistry) -> bool {
        std::ptr::eq(self.registry, registry)
    }

    /// Accelerator *ops* that actually executed as MMIO programs
    /// (lowered and run on an `IlaSim`) so far — useful to assert MMIO
    /// fidelity really engaged rather than silently falling back.
    pub fn lowered_invocations(&self) -> usize {
        self.lowered
    }

    /// Architecture-level trigger invocations executed across all
    /// lowered programs — greater than [`Self::lowered_invocations`]
    /// exactly when the driver tiled ops into multi-trigger programs.
    pub fn lowered_triggers(&self) -> usize {
        self.triggers
    }

    /// Private per-target simulators constructed so far (at most one per
    /// target for the engine's lifetime — the counter a caller-held
    /// engine keeps flat where per-call engines rebuild). Pooled engines
    /// build devices through the pool, so this stays 0 there; see
    /// [`DevicePool::stats`] for the pooled equivalent.
    pub fn sims_built(&self) -> usize {
        self.sims_built
    }

    /// Simulator resets performed (one dirty-region reset per lowered
    /// program). Covers private devices only; pooled devices travel with
    /// their own counters.
    pub fn resets(&self) -> u64 {
        self.sims().map(|s| s.resets).sum()
    }

    /// Memory bytes restored by those resets. Compare against
    /// [`Self::resets`] × [`Self::state_bytes`] — what the same run
    /// would have cloned under full per-invocation resets — to quantify
    /// the dirty-tracking savings. Private devices only.
    pub fn bytes_cleared(&self) -> u64 {
        self.sims().map(|s| s.bytes_cleared).sum()
    }

    /// Total architectural memory bytes of the built simulators (the
    /// per-reset cost of the full-clone baseline). Private devices only.
    pub fn state_bytes(&self) -> u64 {
        self.sims().map(|s| s.state_bytes()).sum()
    }

    /// MMIO write-payload bytes actually streamed to the simulators so
    /// far (skipped resident bursts contribute nothing). The headline
    /// residency metric: for the tiled LSTM-WLM it drops >10× between a
    /// fresh engine's first call and a persistent engine's repeat call.
    pub fn bytes_streamed(&self) -> u64 {
        self.bytes_streamed
    }

    /// Staged operand bursts skipped because a bit-identical burst was
    /// already device-resident in the same staging range.
    pub fn bursts_deduped(&self) -> u64 {
        self.bursts_deduped
    }

    /// Staged (region-mapped) operand bursts that actually had to be
    /// streamed — the residency misses. Together with
    /// [`Self::bursts_deduped`] this gives the residency hit rate.
    pub fn staged_streamed(&self) -> u64 {
        self.staged_streamed
    }

    /// Staged bursts streamed **ahead of trigger** — prefetched while a
    /// previous invocation's trigger was still in flight (a subset of
    /// [`Self::staged_streamed`]). Zero when prefetch is disabled via
    /// [`Self::with_prefetch`] or when the hazard rule serialized every
    /// candidate (e.g. the direct pe-weight staging path).
    pub fn prefetched_stages(&self) -> u64 {
        self.prefetched
    }

    /// Fraction of staged operand bursts served from device residency:
    /// `deduped / (deduped + streamed)`. `0.0` when nothing was staged.
    pub fn residency_hit_rate(&self) -> f64 {
        let total = self.bursts_deduped + self.staged_streamed;
        if total == 0 {
            0.0
        } else {
            self.bursts_deduped as f64 / total as f64
        }
    }

    /// The modeled-cycle [`Timeline`] this engine has accumulated: every
    /// lowered-program execution feeds stage/dedup/DMA-replay/trigger/
    /// read/reset events, costed under the per-target [`CostTable`] (see
    /// [`crate::cost`]). The timeline lives on the engine — never on a
    /// (possibly pooled, shared) device — so snapshots/deltas are
    /// engine-local and independent of device placement.
    pub fn timeline(&self) -> &Timeline {
        &self.timeline
    }

    /// Total modeled device cycles executed by this engine's lowered
    /// programs, split transfer/compute/overhead.
    pub fn modeled_cycles(&self) -> CycleBreakdown {
        self.timeline.totals()
    }

    /// Replace the per-target cost models (codesign sweeps over
    /// hypothetical devices). Tallies already accumulated are kept —
    /// they were costed under the models active when recorded.
    pub fn set_cost_models(&mut self, models: CostTable) {
        self.timeline.set_models(models);
    }

    /// Driver-side calibration mirrors avoided by template-cache hits:
    /// the weight encodes and weight-side bias-bound factors (the
    /// FlexASR forced `CFG_OUT_BIAS` and LSTM bias-schedule mirrors) a
    /// monolithic lowering would recompute per call. Because templates
    /// are weight-keyed, these accrue even when every call's *inputs*
    /// differ.
    pub fn mirror_hits(&self) -> u64 {
        self.cache.mirror_hits
    }

    /// Template-cache hits (weight-keyed templates reused; only the
    /// per-call bind ran).
    pub fn lower_cache_hits(&self) -> u64 {
        self.cache.hits
    }

    /// Template-cache misses (templates lowered from scratch).
    pub fn lower_cache_misses(&self) -> u64 {
        self.cache.misses
    }

    /// Lowering-cache entries evicted (LRU, one at a time, when the
    /// cache is at capacity).
    pub fn lower_cache_evictions(&self) -> u64 {
        self.cache.evictions
    }

    fn sims(&self) -> impl Iterator<Item = &IlaSim> {
        let slots: &[Option<Device>] = match &self.devices {
            DeviceSource::Private(slots) => &slots[..],
            DeviceSource::Pooled(_) => &[],
        };
        slots.iter().flatten().map(|d| &d.sim)
    }

    /// Take the accumulated fidelity report, leaving an empty one.
    pub fn take_fidelity(&mut self) -> FidelityReport {
        std::mem::take(&mut self.fidelity)
    }

    /// Execute one op on the accelerator that owns it, per the backend.
    /// `Ok(None)` means no registered accelerator claims the op (host
    /// ops, unregistered targets) — the caller evaluates f32 semantics.
    pub fn execute(&mut self, op: &Op, inputs: &[&Tensor]) -> Result<Option<Tensor>, EvalError> {
        let registry = self.registry;
        match registry.for_op(op) {
            Some(accel) => self.execute_on(accel, op, inputs),
            None => Ok(None),
        }
    }

    /// Execute one op via the registry slot a dispatch plan resolved.
    pub fn execute_slot(
        &mut self,
        slot: usize,
        op: &Op,
        inputs: &[&Tensor],
    ) -> Result<Option<Tensor>, EvalError> {
        let registry = self.registry;
        self.execute_on(registry.by_slot(slot), op, inputs)
    }

    /// Execute one op on an accelerator resolved from this engine's
    /// registry. Private on purpose: the per-target simulator cache is
    /// only valid for the registry's own model instances, so external
    /// callers must go through [`Self::execute`] / [`Self::execute_slot`]
    /// (mixing in a foreign accelerator of the same target would replay
    /// its program on a simulator built from a different design rev).
    fn execute_on(
        &mut self,
        accel: &'r dyn Accelerator,
        op: &Op,
        inputs: &[&Tensor],
    ) -> Result<Option<Tensor>, EvalError> {
        match self.backend {
            ExecBackend::Functional => Ok(accel.exec_op(op, inputs)),
            ExecBackend::IlaMmio => match self.lower_cached(accel, op, inputs) {
                Some(tmpl) => self.run_template(accel, op, &tmpl, inputs).map(Some),
                // not lowerable (data movement, shapes that cannot be
                // staged even tile-wise): the tensor path keeps the
                // application running end to end
                None => Ok(accel.exec_op(op, inputs)),
            },
            ExecBackend::CrossCheck => {
                let functional = match accel.exec_op(op, inputs) {
                    Some(t) => t,
                    None => return Ok(None),
                };
                match self.lower_cached(accel, op, inputs) {
                    Some(tmpl) => {
                        let mmio = self.run_template(accel, op, &tmpl, inputs)?;
                        self.fidelity.record(op, accel.target(), &functional, &mmio);
                    }
                    // not lowerable: count it so a "clean" report cannot
                    // silently mean "nothing was actually compared"
                    None => self.fidelity.unlowered += 1,
                }
                Ok(Some(functional))
            }
        }
    }

    /// Lower an op through the per-engine [`LoweringCache`]: any call
    /// whose shapes match and whose *weight* operands are bit-identical
    /// reuses the `Arc`-shared template (weight bursts pre-encoded,
    /// weight-side calibration factors pre-computed) — input values do
    /// not participate in the key. Declines are memoized too.
    fn lower_cached(
        &mut self,
        accel: &dyn Accelerator,
        op: &Op,
        inputs: &[&Tensor],
    ) -> Option<Arc<ProgramTemplate>> {
        let key = LowerKey {
            target: accel.target(),
            rev: self.registry.design_rev(),
            op: op.head(),
            shapes: inputs.iter().map(|t| t.shape.clone()).collect(),
            weights: accel
                .weight_operands(op)
                .iter()
                .filter_map(|&i| inputs.get(i).map(|t| t.fingerprint()))
                .collect(),
        };
        self.cache.clock += 1;
        let now = self.cache.clock;
        if let Some(slot) = self.cache.entries.get_mut(&key) {
            slot.last_use = now;
            self.cache.hits += 1;
            return match &slot.tmpl {
                Some(t) => {
                    let t = Arc::clone(t);
                    self.cache.mirror_hits += t.mirrors as u64;
                    Some(t)
                }
                None => None,
            };
        }
        self.cache.misses += 1;
        let lowered = accel.lower(op, inputs);
        if self.cache.entries.len() >= self.cache.cap {
            // evict the least-recently-used single entry: cold one-off
            // layers churn through while hot repeated-layer templates
            // keep refreshing their stamp
            let victim = self
                .cache
                .entries
                .iter()
                .min_by_key(|(_, slot)| slot.last_use)
                .map(|(k, _)| k.clone());
            if let Some(victim) = victim {
                self.cache.entries.remove(&victim);
                self.cache.evictions += 1;
            }
        }
        self.cache.entries.insert(key, CacheSlot { tmpl: lowered.clone(), last_use: now });
        lowered
    }

    /// Bind a cached template to this call's operands and play the
    /// resulting concrete program. The bind is the whole per-call
    /// host-side cost of a template hit — one codec pass over the input
    /// operands plus a few command-lane patches — recorded as
    /// [`Event::Bind`]. Pooled checkouts route on the template's
    /// *weight* fingerprints (stable across binds), not the per-call
    /// slot bursts, so affinity keeps steering repeat calls of one layer
    /// to the device already holding its weights.
    fn run_template(
        &mut self,
        accel: &dyn Accelerator,
        op: &Op,
        tmpl: &ProgramTemplate,
        inputs: &[&Tensor],
    ) -> Result<Tensor, EvalError> {
        let bound = tmpl
            .bind(inputs)
            .map_err(|e| EvalError::Op(op.head(), format!("template bind: {e}")))?;
        let fps = tmpl.weight_fingerprints();
        self.run_program(accel, op, &bound.program, &fps, Some(bound.slot_bytes))
    }

    /// Run an already-concrete lowered program (no template bind): the
    /// entry point for verification replays and prefetch tests that hold
    /// a `LoweredProgram` directly. Pooled checkouts route on every
    /// staged-burst fingerprint.
    fn run_lowered(
        &mut self,
        accel: &dyn Accelerator,
        op: &Op,
        prog: &LoweredProgram,
    ) -> Result<Tensor, EvalError> {
        let fps = staged_fingerprints(prog);
        self.run_program(accel, op, prog, &fps, None)
    }

    /// Run a lowered program on a device — private or checked out of the
    /// shared pool, per this engine's [`DeviceSource`]. `affinity`
    /// carries the burst fingerprints a pooled checkout scores devices
    /// by; `bind_bytes` is `Some` when the program came from a template
    /// bind (recorded as [`Event::Bind`] overhead inside the op).
    fn run_program(
        &mut self,
        accel: &dyn Accelerator,
        op: &Op,
        prog: &LoweredProgram,
        affinity: &[u64],
        bind_bytes: Option<u64>,
    ) -> Result<Tensor, EvalError> {
        self.lowered += 1;
        self.triggers += prog.invocations.len();
        let pool = match &self.devices {
            DeviceSource::Pooled(pool) => Some(Arc::clone(pool)),
            DeviceSource::Private(_) => None,
        };
        if let Some(pool) = pool {
            // checkout carries the affinity fingerprints so the arbiter
            // can route to the device with the best residency
            let cap = self.dram_capacity;
            let mut lease = pool
                .checkout(accel.target(), affinity, || {
                    Device::with_dram_capacity(IlaSim::new(accel.build_ila()), cap)
                })
                .map_err(|e| EvalError::Op(op.head(), format!("MMIO backend: {e}")))?;
            // the lease's Drop returns the device — residency intact —
            // whether the program succeeds or errors; the modeled cycles
            // this program executed ride back with it so the pool can
            // report occupancy/wait in device cycles, not just wall time
            let before = self.timeline.totals();
            let out = self.play_program(lease.device_mut(), op, prog, bind_bytes);
            let delta = self.timeline.totals().saturating_sub(&before);
            lease.note_cycles(delta.total());
            return out;
        }
        let idx = accel.target().index();
        let taken = match &mut self.devices {
            DeviceSource::Private(slots) => slots[idx].take(),
            DeviceSource::Pooled(_) => unreachable!("pooled path returned above"),
        };
        let mut dev = match taken {
            Some(dev) => dev,
            None => {
                self.sims_built += 1;
                Device::with_dram_capacity(IlaSim::new(accel.build_ila()), self.dram_capacity)
            }
        };
        let out = self.play_program(&mut dev, op, prog, bind_bytes);
        if let DeviceSource::Private(slots) = &mut self.devices {
            slots[idx] = Some(dev);
        }
        out
    }

    /// Play a lowered program on a device, in two phases per the
    /// software/hardware interface contract:
    ///
    /// 1. **Stage planning** — [`plan_paging`] walks every DRAM-window
    ///    stage burst and binds it to a page of the device's
    ///    [`PageTable`] (recurring fingerprints keep their pages; new
    ///    ones allocate, evicting LRU); then one residency-keeping dirty
    ///    reset rewinds everything else the last program touched.
    /// 2. **Execution** — invocations run in order on shared device
    ///    state. Staged bursts stream to their planned pages (`DMA_CTRL`
    ///    sources rewritten from logical to physical offsets), and
    ///    bursts whose page still holds bit-identical resident bytes are
    ///    skipped entirely. After each invocation's trigger fires, the
    ///    engine **prefetches** the next invocation's hazard-free staged
    ///    bursts while the trigger is modeled in flight (double-buffered
    ///    staging: the next tile's page is disjoint from every page the
    ///    in-flight trigger can read), crediting the overlap in the
    ///    timeline via [`Event::PrefetchedStage`].
    ///
    /// The fingerprint checks make residency safe no matter which engine
    /// last used a pooled device, and the hazard rule ([`prefetch_safe`])
    /// keeps prefetched execution bit-identical to serialized execution.
    fn play_program(
        &mut self,
        dev: &mut Device,
        op: &Op,
        prog: &LoweredProgram,
        bind_bytes: Option<u64>,
    ) -> Result<Tensor, EvalError> {
        let head = op.head();
        let family = OpFamily::of_head(&head);
        let target = prog.target();
        self.timeline.begin_op(target, &head);
        if let Some(bytes) = bind_bytes {
            // the template bind that produced this program: flat host
            // overhead, attributed to the op it served
            self.timeline.record(Event::Bind { bytes });
        }
        let Device { sim, resident, pages } = dev;
        // phase 1: bind every DRAM stage burst to a page (this purges
        // residency for evicted pages, so the reset below rewinds them)
        let plan = plan_paging(&sim.model, resident, pages, prog);
        // between-program reset: everything the last program dirtied is
        // rewound EXCEPT ranges whose staged bursts we may reuse
        let keep: Vec<(String, usize, usize)> =
            resident.iter().map(|r| (r.mem.clone(), r.lo, r.hi)).collect();
        let cleared_before = sim.bytes_cleared;
        sim.reset_dirty_keeping(&keep);
        self.timeline.record(Event::Reset {
            bytes: sim.bytes_cleared.saturating_sub(cleared_before),
        });

        // phase 2: execute, staging one invocation ahead of the trigger
        let n = prog.invocations.len();
        let mut consumed: Vec<Vec<bool>> =
            prog.invocations.iter().map(|inv| vec![false; inv.bursts.len()]).collect();
        let mut parts = Vec::new();
        for (i, inv) in prog.invocations.iter().enumerate() {
            let mut had_control = false;
            for (bi, burst) in inv.bursts.iter().enumerate() {
                if consumed[i][bi] {
                    // already streamed by the previous invocation's
                    // prefetch window
                    continue;
                }
                let staged = burst
                    .region
                    .as_ref()
                    .and_then(|r| sim.model.staging_for(r.base, r.len))
                    .is_some();
                if staged {
                    self.stage_burst(sim, resident, &plan, op, target, (i, bi), burst, None)?;
                } else {
                    had_control |= burst.region.is_none();
                    // control or unstaged burst: rewrite DMA_CTRL source
                    // offsets onto the planned pages, and honor residency
                    // hazards (DMA doorbells, loose writes into staging
                    // windows)
                    let remapped;
                    let cmds: &[Cmd] = if plan.remap.is_empty() {
                        &burst.cmds
                    } else {
                        remapped = remap_dma_sources(&plan, &burst.cmds);
                        &remapped
                    };
                    invalidate_hazards(resident, &sim.model, cmds);
                    sim.run(cmds).map_err(|e| {
                        EvalError::Op(op.head(), format!("MMIO backend: {e}"))
                    })?;
                    self.bytes_streamed += burst.payload_bytes();
                    let (beats, dma) = cost::control_profile(cmds);
                    self.timeline.record(Event::Control { beats });
                    if dma > 0 {
                        self.timeline.record(Event::DmaReplay { bytes: dma });
                    }
                }
            }
            self.timeline.record(Event::Trigger { family });
            if self.prefetch && had_control && i + 1 < n {
                // the trigger is modeled in flight: stage the next
                // invocation's hazard-free operand bursts now, crediting
                // up to one trigger latency of overlap
                let mut budget =
                    self.timeline.models().get(target).trigger_cycles[family.index()];
                let inflight = staged_ranges(&sim.model, &plan, i, inv);
                let next = &prog.invocations[i + 1];
                for (bi, burst) in next.bursts.iter().enumerate() {
                    if consumed[i + 1][bi] {
                        continue;
                    }
                    let Some(r) = &burst.region else { continue };
                    let Some((mem, lo, hi)) = sim
                        .model
                        .staging_for(r.base, r.len)
                        .map(|(m, lo, hi)| (m.to_string(), lo, hi))
                    else {
                        continue;
                    };
                    let (plo, phi) = plan.phys_range(&(i + 1, bi), lo, hi);
                    if !prefetch_safe(&sim.model, &mem, plo, phi, &inflight) {
                        continue;
                    }
                    self.stage_burst(
                        sim,
                        resident,
                        &plan,
                        op,
                        target,
                        (i + 1, bi),
                        burst,
                        Some(&mut budget),
                    )?;
                    consumed[i + 1][bi] = true;
                }
            }
            if let Some(rplan) = &inv.read {
                parts.push(codegen::read_result(inv, sim).map_err(|e| {
                    EvalError::Op(op.head(), format!("MMIO backend: {e}"))
                })?);
                self.timeline.record(Event::Read { bytes: rplan.read_bytes() });
            }
        }
        codegen::stitch_parts(parts, &prog.stitch)
            .map_err(|e| EvalError::Op(op.head(), format!("MMIO backend: {e}")))
    }

    /// Stream (or dedup-skip) one staged operand burst per the paging
    /// plan. `budget` is `Some` when this is an ahead-of-trigger
    /// prefetch: the stream is recorded as [`Event::PrefetchedStage`]
    /// with overlap credit drawn from (and decremented against) the
    /// in-flight trigger's remaining latency; dedup skips consume no
    /// budget.
    #[allow(clippy::too_many_arguments)]
    fn stage_burst(
        &mut self,
        sim: &mut IlaSim,
        resident: &mut Vec<Resident>,
        plan: &PagingPlan,
        op: &Op,
        target: Target,
        key: (usize, usize),
        burst: &Burst,
        budget: Option<&mut u64>,
    ) -> Result<(), EvalError> {
        let (mem, lo, hi) = {
            let r = burst.region.as_ref().expect("staged burst carries a region");
            let (m, lo, hi) = sim
                .model
                .staging_for(r.base, r.len)
                .expect("staged burst maps onto a staging window");
            (m.to_string(), lo, hi)
        };
        // where the burst lands, whether its bytes are already there,
        // and whether the landing spot is residency-claimable
        let (plo, phi, hit, claim) = match plan.places.get(&key).copied() {
            // paged DRAM burst: land on its page
            Some((Some(phys), hit)) => (phys, phys + (hi - lo), hit, true),
            // unpaged overflow: stream at the logical offset, claim no
            // residency (the page table is not tracking these bytes)
            Some((None, _)) => (lo, hi, false, false),
            // non-DRAM staging window (pe_weight, direct path): the
            // pre-paging exact-range fingerprint dedup
            None => {
                let hit = resident.iter().any(|r| {
                    r.mem == mem && r.lo == lo && r.hi == hi && r.fp == burst.fingerprint
                });
                (lo, hi, hit, true)
            }
        };
        let bytes = burst.payload_bytes();
        if hit {
            self.bursts_deduped += 1;
            self.timeline.record(Event::DedupSkip { bytes });
            return Ok(());
        }
        // rebase the MMIO addresses when the page landed away from the
        // lowering's logical cursor
        let rebased;
        let cmds: &[Cmd] = if plo == lo {
            &burst.cmds
        } else {
            rebased = burst
                .cmds
                .iter()
                .map(|c| Cmd {
                    addr: c.addr.wrapping_add(plo as u64).wrapping_sub(lo as u64),
                    ..c.clone()
                })
                .collect::<Vec<_>>();
            &rebased
        };
        sim.run(cmds)
            .map_err(|e| EvalError::Op(op.head(), format!("MMIO backend: {e}")))?;
        self.bytes_streamed += bytes;
        self.staged_streamed += 1;
        let beats = burst.cmds.len() as u64;
        match budget {
            Some(b) => {
                let cost = beats * self.timeline.models().get(target).mmio_beat_cycles;
                let overlap = cost.min(*b);
                *b -= overlap;
                self.prefetched += 1;
                self.timeline.record(Event::PrefetchedStage {
                    bytes,
                    beats,
                    overlap_cycles: overlap,
                });
            }
            None => self.timeline.record(Event::Stage { bytes, beats }),
        }
        resident.retain(|r| r.mem != mem || r.hi <= plo || r.lo >= phi);
        if claim {
            resident.push(Resident { mem, lo: plo, hi: phi, fp: burst.fingerprint });
        }
        Ok(())
    }
}

/// Rewrite every `DMA_CTRL` descriptor in `cmds` whose logical source
/// range is covered by a planned page, pointing it at the physical page
/// offset instead (destination and length are untouched).
fn remap_dma_sources(plan: &PagingPlan, cmds: &[Cmd]) -> Vec<Cmd> {
    cmds.iter()
        .map(|c| {
            if c.is_write && c.addr == fx::DMA_CTRL {
                let (src, dst, len) = fx::dma_fields(c.data_u64());
                if let Some(p) = plan.remap_src(src, len) {
                    return Cmd::write_u64(fx::DMA_CTRL, fx::dma_word(p, dst, len));
                }
            }
            c.clone()
        })
        .collect()
}

/// Fingerprints of a program's region-mapped (staged) bursts — the
/// affinity-score inputs a pooled checkout sends to the arbiter.
fn staged_fingerprints(prog: &LoweredProgram) -> Vec<u64> {
    prog.invocations
        .iter()
        .flat_map(|inv| inv.bursts.iter())
        .filter(|b| b.region.is_some())
        .map(|b| b.fingerprint)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::DesignRev;
    use crate::util::Rng;

    fn registry(rev: DesignRev) -> AcceleratorRegistry {
        AcceleratorRegistry::for_rev(rev)
    }

    #[test]
    fn functional_and_mmio_agree_on_flex_linear() {
        let reg = registry(DesignRev::Updated);
        let mut rng = Rng::new(7);
        let x = Tensor::randn(&[4, 16], &mut rng, 1.0);
        let w = Tensor::randn(&[8, 16], &mut rng, 0.3);
        let b = Tensor::randn(&[8], &mut rng, 0.1);
        let inputs = [&x, &w, &b];
        let mut func = ExecEngine::new(&reg, ExecBackend::Functional);
        let mut mmio = ExecEngine::new(&reg, ExecBackend::IlaMmio);
        let f = func.execute(&Op::FlexLinear, &inputs).unwrap().unwrap();
        let m = mmio.execute(&Op::FlexLinear, &inputs).unwrap().unwrap();
        assert_eq!(f, m, "backends must be bit-identical");
        assert_eq!(mmio.lowered_invocations(), 1);
        assert_eq!(func.lowered_invocations(), 0);
    }

    #[test]
    fn host_ops_are_not_claimed() {
        let reg = registry(DesignRev::Updated);
        let mut engine = ExecEngine::new(&reg, ExecBackend::IlaMmio);
        let t = Tensor::ones(&[2, 2]);
        assert!(engine.execute(&Op::Relu, &[&t]).unwrap().is_none());
    }

    #[test]
    fn crosscheck_is_clean_on_the_updated_designs() {
        let reg = registry(DesignRev::Updated);
        let mut engine = ExecEngine::new(&reg, ExecBackend::CrossCheck);
        let mut rng = Rng::new(8);
        let x = Tensor::randn(&[4, 16], &mut rng, 1.0);
        let w = Tensor::randn(&[8, 16], &mut rng, 0.3);
        let b = Tensor::randn(&[8], &mut rng, 0.1);
        engine.execute(&Op::FlexLinear, &[&x, &w, &b]).unwrap().unwrap();
        let xc = Tensor::randn(&[1, 3, 6, 6], &mut rng, 1.0);
        let wc = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.2);
        engine
            .execute(&Op::HlscnnConv2d { stride: (1, 1), pad: (1, 1) }, &[&xc, &wc])
            .unwrap()
            .unwrap();
        let rep = engine.take_fidelity();
        assert_eq!(rep.total_checked(), 2);
        assert!(rep.is_clean(), "updated designs must cross-check clean:\n{rep}");
        // taking the report resets the accumulator
        assert_eq!(engine.take_fidelity().total_checked(), 0);
    }

    #[test]
    fn crosscheck_flags_the_original_hlscnn_weight_store() {
        let reg = registry(DesignRev::Original);
        let mut engine = ExecEngine::new(&reg, ExecBackend::CrossCheck);
        let mut rng = Rng::new(9);
        let x = Tensor::randn(&[1, 3, 6, 6], &mut rng, 1.0);
        // typical trained-conv weight scale: codes land between the
        // coarse 8-bit store steps, where floor != round
        let w = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.2);
        let op = Op::HlscnnConv2d { stride: (1, 1), pad: (1, 1) };
        let out = engine.execute(&op, &[&x, &w]).unwrap();
        assert!(out.is_some(), "cross-check must not abort the run");
        let rep = engine.take_fidelity();
        assert_eq!(rep.total_checked(), 1);
        assert!(
            rep.total_mismatches() > 0,
            "original HLSCNN weight-store truncation must be flagged:\n{rep}"
        );
        let rec = rep.mismatched().next().unwrap();
        assert_eq!(rec.target, Target::Hlscnn);
        assert!(rec.max_abs_diff > 0.0 && rec.max_abs_diff.is_finite());
    }

    #[test]
    fn crosscheck_counts_unlowerable_invocations() {
        let reg = registry(DesignRev::Updated);
        let mut engine = ExecEngine::new(&reg, ExecBackend::CrossCheck);
        let t = Tensor::ones(&[2, 4]);
        // data movement executes functionally but has no MMIO program
        engine.execute(&Op::FlexMaxpStore, &[&t]).unwrap().unwrap();
        let rep = engine.take_fidelity();
        assert_eq!(rep.total_checked(), 0);
        assert_eq!(rep.total_unlowered(), 1);
        assert!(rep.is_clean(), "unlowered is not a mismatch");
        assert!(
            format!("{rep}").contains("NOT cross-checked"),
            "the report must disclose unchecked invocations:\n{rep}"
        );
    }

    #[test]
    fn fidelity_reports_merge() {
        let mut a = FidelityReport::default();
        let mut b = FidelityReport::default();
        let t1 = Tensor::ones(&[2]);
        let t2 = Tensor::zeros(&[2]);
        a.record(&Op::VtaGemm, Target::Vta, &t1, &t1);
        b.record(&Op::VtaGemm, Target::Vta, &t1, &t2);
        b.record(&Op::FlexLinear, Target::FlexAsr, &t1, &t1);
        a.merge(b);
        assert_eq!(a.total_checked(), 3);
        assert_eq!(a.total_mismatches(), 1);
        assert_eq!(a.records().len(), 2);
        assert!(!a.is_clean());
    }

    #[test]
    fn merge_all_is_worker_order_independent() {
        let t1 = Tensor::ones(&[2]);
        let t2 = Tensor::zeros(&[2]);
        let make = |seed: usize| {
            // three "workers" that saw different op mixes
            let mut r = FidelityReport::default();
            if seed % 2 == 0 {
                r.record(&Op::VtaGemm, Target::Vta, &t1, &t2);
            }
            r.record(&Op::FlexLinear, Target::FlexAsr, &t1, &t1);
            if seed == 2 {
                r.record(
                    &Op::HlscnnConv2d { stride: (1, 1), pad: (0, 0) },
                    Target::Hlscnn,
                    &t1,
                    &t1,
                );
            }
            r
        };
        let forward = FidelityReport::merge_all([make(0), make(1), make(2)]);
        let shuffled = FidelityReport::merge_all([make(2), make(0), make(1)]);
        assert_eq!(forward.total_checked(), shuffled.total_checked());
        assert_eq!(forward.total_mismatches(), shuffled.total_mismatches());
        let sig = |r: &FidelityReport| {
            r.records()
                .iter()
                .map(|rec| (rec.target, rec.op.clone(), rec.checked, rec.mismatches))
                .collect::<Vec<_>>()
        };
        assert_eq!(sig(&forward), sig(&shuffled), "record order must be canonical");
    }

    #[test]
    fn lowering_cache_evicts_single_lru_entries() {
        let reg = registry(DesignRev::Updated);
        let mut engine = ExecEngine::new(&reg, ExecBackend::IlaMmio);
        let mut rng = Rng::new(11);
        let x = Tensor::randn(&[1, 16], &mut rng, 1.0);
        let b = Tensor::randn(&[4], &mut rng, 0.1);
        let weights: Vec<Tensor> =
            (0..LOWER_CACHE_CAP + 1).map(|_| Tensor::randn(&[4, 16], &mut rng, 0.3)).collect();
        // fill the cache exactly to capacity
        for w in weights.iter().take(LOWER_CACHE_CAP) {
            engine.execute(&Op::FlexLinear, &[&x, w, &b]).unwrap().unwrap();
        }
        assert_eq!(engine.lower_cache_evictions(), 0);
        // refresh entry 0 so it is NOT the LRU victim...
        engine.execute(&Op::FlexLinear, &[&x, &weights[0], &b]).unwrap().unwrap();
        let hits_before = engine.lower_cache_hits();
        assert_eq!(hits_before, 1);
        // ...then overflow: exactly one (cold) entry is evicted
        engine.execute(&Op::FlexLinear, &[&x, &weights[LOWER_CACHE_CAP], &b]).unwrap().unwrap();
        assert_eq!(engine.lower_cache_evictions(), 1);
        // the refreshed hot entry survived the eviction
        engine.execute(&Op::FlexLinear, &[&x, &weights[0], &b]).unwrap().unwrap();
        assert_eq!(engine.lower_cache_hits(), hits_before + 1);
        // the LRU victim (entry 1) is gone: touching it is a miss
        let misses_before = engine.lower_cache_misses();
        engine.execute(&Op::FlexLinear, &[&x, &weights[1], &b]).unwrap().unwrap();
        assert_eq!(engine.lower_cache_misses(), misses_before + 1);
    }

    #[test]
    fn lowering_cache_capacity_knob_bounds_evictions() {
        let reg = registry(DesignRev::Updated);
        let mut engine =
            ExecEngine::new(&reg, ExecBackend::IlaMmio).with_lowering_cache_capacity(2);
        assert_eq!(engine.lowering_cache_capacity(), 2);
        let mut rng = Rng::new(12);
        let x = Tensor::randn(&[1, 16], &mut rng, 1.0);
        let b = Tensor::randn(&[4], &mut rng, 0.1);
        let weights: Vec<Tensor> = (0..4).map(|_| Tensor::randn(&[4, 16], &mut rng, 0.3)).collect();
        for w in weights.iter().take(2) {
            engine.execute(&Op::FlexLinear, &[&x, w, &b]).unwrap().unwrap();
        }
        assert_eq!(engine.lower_cache_evictions(), 0, "at capacity, no eviction yet");
        for w in weights.iter().skip(2) {
            engine.execute(&Op::FlexLinear, &[&x, w, &b]).unwrap().unwrap();
        }
        // each overflow evicts exactly one LRU entry
        assert_eq!(engine.lower_cache_evictions(), 2);
        // the zero request clamps to one live entry, not an unusable cache
        let clamped = ExecEngine::new(&reg, ExecBackend::IlaMmio).with_lowering_cache_capacity(0);
        assert_eq!(clamped.lowering_cache_capacity(), 1);
    }

    #[test]
    fn input_varying_calls_hit_the_weight_keyed_template_cache() {
        let reg = registry(DesignRev::Updated);
        let mut engine = ExecEngine::new(&reg, ExecBackend::CrossCheck);
        let mut rng = Rng::new(13);
        let w = Tensor::randn(&[8, 16], &mut rng, 0.3);
        let b = Tensor::randn(&[8], &mut rng, 0.1);
        for i in 0..4 {
            let x = Tensor::randn(&[4, 16], &mut rng, 1.0 + i as f32 * 0.1);
            engine.execute(&Op::FlexLinear, &[&x, &w, &b]).unwrap().unwrap();
        }
        // one template miss, then every fresh-input call hits and binds
        assert_eq!(engine.lower_cache_misses(), 1);
        assert_eq!(engine.lower_cache_hits(), 3);
        assert!(engine.mirror_hits() > 0, "weight-side mirrors must be reused");
        let row = &engine.timeline().per_op()[0];
        assert_eq!(row.binds, 4, "every call binds the template");
        let rep = engine.take_fidelity();
        assert_eq!(rep.total_checked(), 4);
        assert!(rep.is_clean(), "bound programs must stay bit-exact:\n{rep}");
    }

    #[test]
    fn prefetch_is_refused_on_the_direct_path_war_hazard() {
        use crate::accel::flexasr::FlexAsr;
        let reg = registry(DesignRev::Updated);
        let mut rng = Rng::new(31);
        let x = Tensor::randn(&[2, 64], &mut rng, 1.0);
        let w = Tensor::randn(&[96, 64], &mut rng, 0.3);
        let b = Tensor::randn(&[96], &mut rng, 0.1);
        for (accel, label) in [
            (FlexAsr { dram_budget: 0, ..FlexAsr::original() }, "original"),
            (FlexAsr { dram_budget: 0, ..FlexAsr::new() }, "updated"),
        ] {
            // zero DRAM budget forces the direct path: weight tiles
            // stage straight into pe_weight, the DMA_CTRL hazard target
            let prog =
                accel.lower_linear_for_verify(&x, &w, &b, 32).expect("tiled lowering");
            assert!(prog.invocations.len() > 1, "{label}: tiling expected");
            let mut engine = ExecEngine::new(&reg, ExecBackend::IlaMmio);
            let out = engine.run_lowered(&accel, &Op::FlexLinear, &prog).unwrap();
            // the WAR rule must refuse every candidate: stage and
            // trigger stay strictly serialized
            assert_eq!(engine.prefetched_stages(), 0, "{label}");
            assert!(engine.staged_streamed() > 0, "{label}");
            let func = accel.exec_op(&Op::FlexLinear, &[&x, &w, &b]).unwrap();
            assert_eq!(out, func, "{label}: serialized path must stay bit-exact");
        }
    }

    #[test]
    fn dram_path_prefetch_overlaps_and_stays_bit_exact() {
        use crate::accel::flexasr::FlexAsr;
        let reg = registry(DesignRev::Updated);
        let mut rng = Rng::new(32);
        let x = Tensor::randn(&[2, 64], &mut rng, 1.0);
        let w = Tensor::randn(&[96, 64], &mut rng, 0.3);
        let b = Tensor::randn(&[96], &mut rng, 0.1);
        for (accel, label) in
            [(FlexAsr::original(), "original"), (FlexAsr::new(), "updated")]
        {
            let prog =
                accel.lower_linear_for_verify(&x, &w, &b, 32).expect("tiled lowering");
            let tiles = prog.invocations.len() - 1; // minus the input-only invocation
            assert!(tiles > 1, "{label}: several weight tiles expected");
            let mut on = ExecEngine::new(&reg, ExecBackend::IlaMmio);
            let mut off = ExecEngine::new(&reg, ExecBackend::IlaMmio).with_prefetch(false);
            let a = on.run_lowered(&accel, &Op::FlexLinear, &prog).unwrap();
            let b2 = off.run_lowered(&accel, &Op::FlexLinear, &prog).unwrap();
            assert_eq!(a, b2, "{label}: prefetched and serialized runs must agree");
            // tile N+1's DRAM page is disjoint from everything tile N's
            // trigger reads, so every tile after the first prefetches
            assert_eq!(on.prefetched_stages(), tiles as u64 - 1, "{label}");
            assert_eq!(off.prefetched_stages(), 0, "{label}");
            assert_eq!(
                on.bytes_streamed(),
                off.bytes_streamed(),
                "{label}: prefetch reorders traffic, never adds any"
            );
            assert!(
                on.modeled_cycles().total() < off.modeled_cycles().total(),
                "{label}: overlap credit must cut modeled cycles ({} vs {})",
                on.modeled_cycles().total(),
                off.modeled_cycles().total()
            );
        }
    }

    #[test]
    fn timeline_accumulates_modeled_cycles_per_op() {
        let reg = registry(DesignRev::Updated);
        let mut engine = ExecEngine::new(&reg, ExecBackend::IlaMmio);
        let mut rng = Rng::new(21);
        let x = Tensor::randn(&[4, 16], &mut rng, 1.0);
        let w = Tensor::randn(&[8, 16], &mut rng, 0.3);
        let b = Tensor::randn(&[8], &mut rng, 0.1);
        engine.execute(&Op::FlexLinear, &[&x, &w, &b]).unwrap().unwrap();
        let total = engine.modeled_cycles();
        assert!(total.transfer > 0, "staging + read-back must cost transfer");
        assert!(total.compute > 0, "the trigger must cost compute");
        assert!(total.overhead > 0, "config beats + reset must cost overhead");
        let ops = engine.timeline().per_op();
        assert_eq!(ops.len(), 1);
        assert_eq!(ops[0].op, "fasr_linear");
        assert_eq!((ops[0].executions, ops[0].triggers), (1, 1));
        assert!(ops[0].staged_bytes > 0 && ops[0].read_bytes > 0);
        // one trigger: compute equals the family's modeled latency
        let model = crate::cost::CostModel::for_target(Target::FlexAsr);
        assert_eq!(
            ops[0].cycles.compute,
            model.trigger_cycles[crate::cost::OpFamily::Linear.index()]
        );

        // a bit-identical repeat dedups the staged weight burst: the
        // per-call transfer delta drops below the cold-start cost
        let snap = engine.timeline().snapshot();
        engine.execute(&Op::FlexLinear, &[&x, &w, &b]).unwrap().unwrap();
        let (delta, dops) = engine.timeline().since(&snap);
        assert!(
            delta.transfer < total.transfer,
            "repeat transfer {} must undercut cold-start {}",
            delta.transfer,
            total.transfer
        );
        assert_eq!(dops.len(), 1);
        assert!(dops[0].dedup_bytes > 0, "the weight stage must dedup");
        assert_eq!(dops[0].executions, 1, "the delta covers one execution");
    }

    #[test]
    fn cost_model_overrides_rescale_new_work_only() {
        let reg = registry(DesignRev::Updated);
        let mut engine = ExecEngine::new(&reg, ExecBackend::IlaMmio);
        let mut rng = Rng::new(22);
        let x = Tensor::randn(&[4, 16], &mut rng, 1.0);
        let w = Tensor::randn(&[8, 16], &mut rng, 0.3);
        let b = Tensor::randn(&[8], &mut rng, 0.1);
        engine.execute(&Op::FlexLinear, &[&x, &w, &b]).unwrap().unwrap();
        let before = engine.modeled_cycles();
        // a hypothetical device with a 10x slower interconnect
        let mut models = CostTable::default();
        let slow = models
            .get(Target::FlexAsr)
            .builder()
            .mmio_beat_cycles(40)
            .build();
        models.set(Target::FlexAsr, slow);
        engine.set_cost_models(models);
        assert_eq!(
            engine.modeled_cycles(),
            before,
            "swapping models must not rewrite history"
        );
        let mut fresh_rng = Rng::new(23);
        let x2 = Tensor::randn(&[4, 16], &mut fresh_rng, 1.0);
        engine.execute(&Op::FlexLinear, &[&x2, &w, &b]).unwrap().unwrap();
        let delta = engine.modeled_cycles().saturating_sub(&before);
        assert!(
            delta.transfer > before.transfer,
            "one re-costed call ({}) must out-bill the whole cheap history \
             ({}) under a 10x interconnect",
            delta.transfer,
            before.transfer
        );
    }
}
