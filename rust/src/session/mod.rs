//! The unified session API: one handle-based entry point for
//! compile → co-simulate → sweep.
//!
//! The seed API scattered the D2A flow across free functions
//! (`compiler::compile`, `cosim::run_accelerated`,
//! `coordinator::classify_sweep`) that each took 5–6 positional
//! arguments, re-instantiated accelerator models per worker thread, and
//! hardcoded the sweep input variable to `"x"`. Following the ISA-like
//! interface discipline of the ILA papers, this module concentrates the
//! whole flow behind three types:
//!
//! * [`AcceleratorRegistry`] — an `Arc`-shared, `Target`-indexed dispatch
//!   table over the bit-accurate accelerator models;
//! * [`Session`] (built by [`SessionBuilder`]) — owns the registry plus
//!   the compilation policy (targets, matching mode, saturation limits,
//!   design revision, worker count) and exposes [`Session::compile`];
//! * [`CompiledProgram`] — a reusable handle caching the extracted
//!   [`RecExpr`] *and* a precomputed per-node [`DispatchPlan`] (dispatch
//!   slots plus a tensor-liveness plan), with [`CompiledProgram::run`],
//!   [`CompiledProgram::run_batch`], [`CompiledProgram::cosim`] and
//!   [`CompiledProgram::classify_sweep`]. The execution loop is
//!   zero-clone: leaves are borrowed from the [`Bindings`] and
//!   intermediates are freed at their last use.
//!
//! ```text
//! SessionBuilder ──build()──▶ Session ──compile(&App)──▶ CompiledProgram
//!                              │  Arc<AcceleratorRegistry>     │ plan: per-node slot
//!                              │  ExecBackend                  │
//!                              └────────────┬──────────────────┘
//!                                           ▼
//!                                 ExecEngine (per worker)
//!                              Functional │ IlaMmio │ CrossCheck
//!                               exec_op   │ lower + IlaSim │ both
//! ```
//!
//! Execution is **backend-selectable** per session
//! ([`SessionBuilder::backend`]): the same compiled program can run on
//! the tensor fast path, at MMIO fidelity on the ILA simulators, or in
//! [`ExecBackend::CrossCheck`] mode where every invocation runs both
//! ways and bit-level disagreements accumulate in a [`FidelityReport`]
//! — the fidelity ladder (`docs/ARCHITECTURE.md`). Under the MMIO
//! backends, oversized layers execute as driver-tiled multi-trigger
//! programs, and callers can hold one [`ExecEngine`] across calls
//! ([`CompiledProgram::engine`] + the `*_with` APIs) so repeated
//! single-point evaluations skip per-call simulator construction.
//!
//! Sessions can additionally be built with a shared **device pool**
//! ([`SessionBuilder::device_pool`]): instead of one private simulator
//! set per worker, all engines check devices out of one arbitrated
//! [`DevicePool`] (K devices per target, K typically < workers) whose
//! scheduler routes each request to the device with the best operand
//! residency ([`SchedPolicy`]) — the multi-tenant serving model. See
//! the [`pool`] module docs.

pub mod backend;
pub mod bindings;
pub mod pool;
pub mod registry;

pub use backend::{ExecBackend, ExecEngine, FidelityRecord, FidelityReport};
pub use bindings::{Bindings, LayeredEnv};
pub use pool::{DevicePool, PoolError, PoolStats, SchedPolicy};
pub use registry::AcceleratorRegistry;

use crate::apps::App;
use crate::compiler;
use crate::cost::{CycleBreakdown, OpCycles};
use crate::egraph::{RunnerLimits, StopReason};
use crate::ir::interp::{self, EnvLookup, EvalError};
use crate::ir::shape::Shape;
use crate::ir::{Op, RecExpr, Target};
use crate::rewrites::Matching;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Which accelerator configuration a session runs under (the Table 4
/// "Original" vs "Updated" columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DesignRev {
    /// As-published designs: HLSCNN 8-bit fixed-point weight store.
    Original,
    /// Post-co-design fix: HLSCNN 16-bit weights.
    Updated,
}

/// Configuration builder for a [`Session`].
///
/// ```
/// use d2a::ir::Target;
/// use d2a::session::{DesignRev, ExecBackend, Session};
///
/// let session = Session::builder()
///     .targets(&[Target::FlexAsr, Target::Hlscnn])
///     .design_rev(DesignRev::Updated)
///     .backend(ExecBackend::Functional)
///     .workers(4)
///     .build();
/// assert_eq!(session.workers(), 4);
/// assert_eq!(session.backend(), ExecBackend::Functional);
/// ```
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    targets: Vec<Target>,
    mode: Matching,
    limits: RunnerLimits,
    rev: DesignRev,
    workers: usize,
    track_errors: bool,
    backend: ExecBackend,
    extended: bool,
    pool_devices: Option<usize>,
    sched: SchedPolicy,
    prefetch: bool,
    dram_capacity: usize,
    lower_cache_cap: Option<usize>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionBuilder {
    /// Defaults: all three accelerators, flexible matching, default
    /// saturation limits, updated designs, one worker, no per-invocation
    /// error tracking, functional execution backend.
    pub fn new() -> Self {
        SessionBuilder {
            targets: vec![Target::FlexAsr, Target::Hlscnn, Target::Vta],
            mode: Matching::Flexible,
            limits: RunnerLimits::default(),
            rev: DesignRev::Updated,
            workers: 1,
            track_errors: false,
            backend: ExecBackend::Functional,
            extended: false,
            pool_devices: None,
            sched: SchedPolicy::Affinity,
            prefetch: true,
            dram_capacity: crate::accel::flexasr::model::WGT_DRAM_SIZE,
            lower_cache_cap: None,
        }
    }

    /// Restrict compilation to the given targets.
    pub fn targets(mut self, targets: &[Target]) -> Self {
        self.targets = targets.to_vec();
        self
    }

    /// Exact or flexible matching (the two columns of Table 1).
    pub fn matching(mut self, mode: Matching) -> Self {
        self.mode = mode;
        self
    }

    /// Equality-saturation budgets.
    pub fn limits(mut self, limits: RunnerLimits) -> Self {
        self.limits = limits;
        self
    }

    /// Accelerator design revision (original vs updated numerics).
    pub fn design_rev(mut self, rev: DesignRev) -> Self {
        self.rev = rev;
        self
    }

    /// Worker threads for batched execution and sweeps.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Record per-invocation relative errors during co-simulation (the
    /// §4.4.2 debugging statistics; costs an extra f32 evaluation per
    /// accelerator invocation).
    pub fn track_errors(mut self, on: bool) -> Self {
        self.track_errors = on;
        self
    }

    /// Also saturate with the extended FlexASR rule set (2-D pool
    /// decomposition + store/load cancellation — the §5.1 / Fig. 7
    /// data-movement rules) on top of the per-target mapping rules.
    pub fn extended_rules(mut self, on: bool) -> Self {
        self.extended = on;
        self
    }

    /// Select the execution backend for accelerator invocations.
    ///
    /// * [`ExecBackend::Functional`] (default) — tensor fast path; use
    ///   for big sweeps where throughput matters.
    /// * [`ExecBackend::IlaMmio`] — full MMIO programs on the ILA
    ///   simulators; use when deployment fidelity matters (every byte
    ///   crosses the modeled hardware interface).
    /// * [`ExecBackend::CrossCheck`] — both, bit-compared per
    ///   invocation into a [`FidelityReport`]; use as the always-on
    ///   consistency check between the two views of the hardware.
    pub fn backend(mut self, backend: ExecBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Share one arbitrated [`DevicePool`] of `devices_per_target`
    /// simulators (clamped to ≥ 1) among all of the session's engines,
    /// instead of one private simulator set per worker. Only the MMIO
    /// backends touch devices, so this is a no-op under
    /// [`ExecBackend::Functional`]. Pick `devices_per_target` smaller
    /// than the worker count to model multi-tenant contention.
    pub fn device_pool(mut self, devices_per_target: usize) -> Self {
        self.pool_devices = Some(devices_per_target.max(1));
        self
    }

    /// Scheduling policy for the shared device pool (default
    /// [`SchedPolicy::Affinity`]). Meaningless without
    /// [`Self::device_pool`].
    pub fn sched_policy(mut self, policy: SchedPolicy) -> Self {
        self.sched = policy;
        self
    }

    /// Toggle ahead-of-trigger operand prefetch in the MMIO engines (on
    /// by default): stage the next invocation's hazard-free bursts while
    /// the current trigger is modeled in flight, crediting the overlap
    /// in the modeled-cycle timeline. Results are bit-identical either
    /// way — turn it off for an A/B cost comparison.
    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Cap the paged weight-staging DRAM each MMIO device manages
    /// (clamped to the architectural size, which is also the default).
    /// Small caps force LRU page eviction on otherwise-comfortable tile
    /// sets — the concurrency/eviction test harness knob.
    pub fn dram_capacity(mut self, bytes: usize) -> Self {
        self.dram_capacity = bytes.min(crate::accel::flexasr::model::WGT_DRAM_SIZE);
        self
    }

    /// Cap each engine's weight-keyed template cache at `entries`
    /// (clamped to ≥ 1; engine default when unset). Templates are keyed
    /// by (target, revision, op head, operand shapes, weight
    /// fingerprints), so a serving session with more distinct hot layers
    /// than the default capacity raises this to keep every layer's
    /// template resident.
    pub fn lowering_cache_capacity(mut self, entries: usize) -> Self {
        self.lower_cache_cap = Some(entries.max(1));
        self
    }

    /// Instantiate the accelerator models once and freeze the session.
    pub fn build(self) -> Session {
        Session {
            registry: Arc::new(AcceleratorRegistry::for_rev(self.rev)),
            targets: self.targets,
            mode: self.mode,
            limits: self.limits,
            rev: self.rev,
            workers: self.workers,
            track_errors: self.track_errors,
            backend: self.backend,
            extended: self.extended,
            pool: self.pool_devices.map(|k| Arc::new(DevicePool::new(k, self.sched))),
            prefetch: self.prefetch,
            dram_capacity: self.dram_capacity,
            lower_cache_cap: self.lower_cache_cap,
        }
    }
}

/// A configured compile/validate session: owns the accelerator registry
/// and the compilation policy.
pub struct Session {
    registry: Arc<AcceleratorRegistry>,
    targets: Vec<Target>,
    mode: Matching,
    limits: RunnerLimits,
    rev: DesignRev,
    workers: usize,
    track_errors: bool,
    backend: ExecBackend,
    extended: bool,
    pool: Option<Arc<DevicePool>>,
    prefetch: bool,
    dram_capacity: usize,
    lower_cache_cap: Option<usize>,
}

impl Session {
    /// Start building a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// The shared accelerator registry.
    pub fn registry(&self) -> &Arc<AcceleratorRegistry> {
        &self.registry
    }

    /// The session's design revision.
    pub fn design_rev(&self) -> DesignRev {
        self.rev
    }

    /// The session's matching mode.
    pub fn matching(&self) -> Matching {
        self.mode
    }

    /// The session's compilation targets.
    pub fn targets(&self) -> &[Target] {
        &self.targets
    }

    /// The session's worker-thread count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The session's execution backend.
    pub fn backend(&self) -> ExecBackend {
        self.backend
    }

    /// The session's shared device pool, when one was configured via
    /// [`SessionBuilder::device_pool`] (e.g. to read
    /// [`DevicePool::stats`] after a serving run).
    pub fn device_pool(&self) -> Option<&Arc<DevicePool>> {
        self.pool.as_ref()
    }

    /// Compile an application (including app-specific rewrite rules) into
    /// a reusable handle.
    pub fn compile(&self, app: &App) -> CompiledProgram {
        let res = compiler::compile_app(app, &self.targets, self.mode, self.limits.clone());
        self.finish(res)
    }

    /// Compile a bare IR expression under the session policy (including
    /// the extended FlexASR data-movement rules when the session enabled
    /// [`SessionBuilder::extended_rules`]).
    pub fn compile_expr(
        &self,
        expr: &RecExpr,
        shapes: &HashMap<String, Shape>,
    ) -> CompiledProgram {
        let extra = if self.extended && self.targets.contains(&Target::FlexAsr) {
            crate::rewrites::accel::flexasr_extended_rules()
        } else {
            Vec::new()
        };
        let res = compiler::compile_with_extra(
            expr,
            shapes,
            &self.targets,
            self.mode,
            self.limits.clone(),
            extra,
        );
        self.finish(res)
    }

    /// Wrap an already-compiled expression in a handle (precomputing its
    /// dispatch plan) without running saturation again.
    pub fn attach(&self, expr: RecExpr) -> CompiledProgram {
        self.handle(expr, None)
    }

    fn finish(&self, res: compiler::CompileResult) -> CompiledProgram {
        let stats = CompileStats {
            stop: res.stop,
            classes: res.classes,
            nodes: res.nodes,
            elapsed: res.elapsed,
            candidates: res.candidate_classes(),
            matches: res.total_matches(),
        };
        self.handle(res.expr, Some(stats))
    }

    fn handle(&self, expr: RecExpr, stats: Option<CompileStats>) -> CompiledProgram {
        let plan = DispatchPlan::new(&expr, &self.registry);
        CompiledProgram {
            expr,
            stats,
            plan,
            registry: Arc::clone(&self.registry),
            workers: self.workers,
            track_errors: self.track_errors,
            backend: self.backend,
            pool: self.pool.clone(),
            prefetch: self.prefetch,
            dram_capacity: self.dram_capacity,
            lower_cache_cap: self.lower_cache_cap,
        }
    }
}

/// Compilation statistics carried by a [`CompiledProgram`] (absent for
/// handles created via [`Session::attach`]).
#[derive(Debug, Clone)]
pub struct CompileStats {
    /// Why saturation stopped.
    pub stop: StopReason,
    /// e-graph classes at extraction time.
    pub classes: usize,
    /// e-graph nodes at extraction time.
    pub nodes: usize,
    /// Wall-clock of saturation + extraction.
    pub elapsed: Duration,
    /// Root-candidate classes probed during saturation (op-index metric).
    pub candidates: usize,
    /// E-matches found during saturation.
    pub matches: usize,
}

/// One per-node dispatch decision, precomputed at compile time.
#[derive(Debug, Clone, Copy)]
enum Step {
    /// Host-evaluated op (or a leaf bound from the environment).
    Host,
    /// Route to the registry model in `slot`; `invocation` marks
    /// accelerator *compute* (data-movement ops are not invocations).
    Accel { slot: usize, invocation: bool },
}

/// Precomputed per-node dispatch decisions for one compiled expression —
/// the hot loop reads an array instead of matching op targets and
/// scanning accelerator lists per node per input — plus a liveness plan:
/// for each step, which value slots die there and can be freed, so big
/// sweep batches stop retaining every intermediate tensor until the end
/// of the evaluation.
#[derive(Debug, Clone)]
pub struct DispatchPlan {
    steps: Vec<Step>,
    /// frees[i] = value slots whose last use is step i (the root is
    /// never listed; unused non-root nodes are freed at their own step).
    frees: Vec<Vec<usize>>,
    offloaded: usize,
}

impl DispatchPlan {
    fn new(expr: &RecExpr, registry: &AcceleratorRegistry) -> Self {
        let n = expr.len();
        let mut steps = Vec::with_capacity(n);
        let mut offloaded = 0usize;
        // liveness: the last step consuming each node's value
        let mut last_use: Vec<Option<usize>> = vec![None; n];
        for (i, node) in expr.nodes.iter().enumerate() {
            for &c in &node.children {
                last_use[c] = Some(i);
            }
            let t = node.op.target();
            let step = if t == Target::Host {
                Step::Host
            } else {
                match registry.slot_for(t) {
                    Some(slot) => {
                        let invocation = node.op.is_accel_invocation();
                        if invocation {
                            offloaded += 1;
                        }
                        Step::Accel { slot, invocation }
                    }
                    // target compiled for but no model registered: fall
                    // back to the op's f32 semantics
                    None => Step::Host,
                }
            };
            steps.push(step);
        }
        let mut frees: Vec<Vec<usize>> = vec![Vec::new(); n];
        for i in 0..n.saturating_sub(1) {
            // the root (last node) stays live; everything else dies at
            // its last consumer, or immediately when never consumed
            frees[last_use[i].unwrap_or(i)].push(i);
        }
        DispatchPlan { steps, frees, offloaded }
    }

    /// Number of accelerator invocations the plan routes per evaluation.
    pub fn offloaded(&self) -> usize {
        self.offloaded
    }

    /// Value slots freed after each step (exposed for the liveness tests).
    pub fn frees(&self) -> &[Vec<usize>] {
        &self.frees
    }
}

/// Result of one traced accelerated evaluation
/// ([`CompiledProgram::run_traced`]): the output plus the invocation
/// statistics, without the reference pass [`CompiledProgram::cosim`]
/// adds.
#[derive(Debug, Clone)]
pub struct RunTrace {
    /// Output with accelerator numerics on the offloaded regions.
    pub output: Tensor,
    /// Accelerator invocations executed.
    pub invocations: usize,
    /// Invocations that executed as MMIO programs on an ILA simulator
    /// (0 under [`ExecBackend::Functional`]).
    pub mmio_invocations: usize,
    /// MMIO write-payload bytes streamed to the simulators by **this
    /// call** (a per-call delta of [`ExecEngine::bytes_streamed`]); on a
    /// persistent engine, operand residency makes repeat calls strictly
    /// cheaper here.
    pub bytes_streamed: u64,
    /// Staged operand bursts this call skipped because a bit-identical
    /// burst was already device-resident (delta of
    /// [`ExecEngine::bursts_deduped`]).
    pub bursts_deduped: u64,
    /// Driver-side calibration mirrors this call avoided via the
    /// engine's lowering cache (delta of [`ExecEngine::mirror_hits`]).
    pub mirror_hits: u64,
    /// Modeled device cycles spent by **this call** (a delta of the
    /// engine's [`crate::cost::Timeline`]), split transfer vs compute vs
    /// overhead. Zero under [`ExecBackend::Functional`] (nothing crosses
    /// the modeled interface). Engine-local and placement-independent:
    /// on a pooled engine the delta covers only this call's programs,
    /// whichever devices served them.
    pub cycles: CycleBreakdown,
    /// Per-(target, op-head) modeled-cycle breakdowns for this call, in
    /// canonical (target, op) order (delta of the engine timeline's
    /// per-op rows).
    pub op_cycles: Vec<OpCycles>,
    /// Per-invocation relative errors (§4.4.2 debugging statistics);
    /// empty unless the session enabled
    /// [`SessionBuilder::track_errors`].
    pub inv_errors: Vec<f32>,
    /// Cross-check outcome (empty unless the session backend is
    /// [`ExecBackend::CrossCheck`]).
    pub fidelity: FidelityReport,
}

/// Result of one co-simulated evaluation ([`CompiledProgram::cosim`]).
#[derive(Debug, Clone)]
pub struct CosimReport {
    /// Pure f32 reference output (IR interpreter).
    pub reference: Tensor,
    /// Output with accelerator numerics on the offloaded regions.
    pub accelerated: Tensor,
    /// Accelerator invocations executed.
    pub invocations: usize,
    /// Relative (Frobenius) error of `accelerated` vs `reference`.
    pub rel_error: f32,
    /// Per-invocation relative errors (§4.4.2 debugging statistics);
    /// empty unless the session enabled
    /// [`SessionBuilder::track_errors`].
    pub inv_errors: Vec<f32>,
    /// Cross-check outcome (empty unless the session backend is
    /// [`ExecBackend::CrossCheck`]).
    pub fidelity: FidelityReport,
}

/// A classification sweep over a dataset: which bindings are shared
/// (weights), which variable carries the per-datapoint input — explicit,
/// where the seed API hardcoded `"x"` — and the labelled data.
#[derive(Debug, Clone, Copy)]
pub struct SweepSpec<'a> {
    /// Name of the per-datapoint input variable.
    pub input_var: &'a str,
    /// Bindings shared by every datapoint (weights, constants).
    pub weights: &'a HashMap<String, Tensor>,
    /// One tensor per datapoint, bound to `input_var`.
    pub inputs: &'a [Tensor],
    /// Ground-truth class per datapoint.
    pub labels: &'a [usize],
}

/// Merged result of a (possibly multi-worker) classification sweep.
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// Data points evaluated.
    pub n: usize,
    /// Correct classifications of the f32 reference.
    pub ref_correct: usize,
    /// Correct classifications under accelerator numerics.
    pub acc_correct: usize,
    /// Wall-clock duration of the whole sweep.
    pub elapsed: Duration,
    /// Aggregate simulation (worker busy) time, summed across threads.
    /// With `w` workers this is ≈ `w × elapsed`; dividing *wall* time by
    /// `n` (the seed behaviour) under-reported the Table 4 per-point sim
    /// time by about that factor.
    pub sim_time: Duration,
    /// Worker threads used.
    pub workers: usize,
    /// Accelerated evaluations that *failed* (e.g. an MMIO engine fault
    /// under [`ExecBackend::IlaMmio`]); these points count as
    /// misclassifications, so a non-zero value means the accuracy gap is
    /// (partly) execution failure, not numerics.
    pub exec_errors: usize,
    /// Cross-check outcome merged across workers (empty unless the
    /// session backend is [`ExecBackend::CrossCheck`]).
    pub fidelity: FidelityReport,
    /// Modeled device cycles summed across workers (transfer vs compute
    /// vs overhead); zero under [`ExecBackend::Functional`].
    pub cycles: CycleBreakdown,
    /// Per-(target, op-head) modeled-cycle breakdowns merged across
    /// workers, in canonical (target, op) order.
    pub op_cycles: Vec<OpCycles>,
}

impl SweepReport {
    /// Reference classification accuracy.
    pub fn ref_accuracy(&self) -> f32 {
        self.ref_correct as f32 / self.n as f32
    }

    /// Accelerated classification accuracy.
    pub fn acc_accuracy(&self) -> f32 {
        self.acc_correct as f32 / self.n as f32
    }

    /// Wall-clock time per data point (throughput view: shrinks as
    /// workers are added).
    pub fn wall_time_per_point(&self) -> Duration {
        self.elapsed / self.n.max(1) as u32
    }

    /// Aggregate simulation time per data point (the Table 4 "per-point
    /// sim time" column: the cost of simulating one point, independent of
    /// how many workers ran the sweep).
    pub fn sim_time_per_point(&self) -> Duration {
        self.sim_time / self.n.max(1) as u32
    }

    /// Average simulation time per data point (the Table 4 column).
    /// Alias for [`Self::sim_time_per_point`]; the seed version divided
    /// wall time by `n`, silently shrinking with the worker count.
    pub fn time_per_point(&self) -> Duration {
        self.sim_time_per_point()
    }

    /// Modeled device cycles per data point — the host-speed-independent
    /// latency figure (zero under [`ExecBackend::Functional`]).
    pub fn cycles_per_point(&self) -> u64 {
        self.cycles.total() / self.n.max(1) as u64
    }
}

/// A compiled program handle: the extracted expression, its compilation
/// statistics, and a precomputed dispatch plan bound to the session's
/// shared registry. Handles are cheap to reuse across batches and are
/// `Sync` — one handle can serve many worker threads.
pub struct CompiledProgram {
    expr: RecExpr,
    stats: Option<CompileStats>,
    plan: DispatchPlan,
    registry: Arc<AcceleratorRegistry>,
    workers: usize,
    track_errors: bool,
    backend: ExecBackend,
    pool: Option<Arc<DevicePool>>,
    prefetch: bool,
    dram_capacity: usize,
    lower_cache_cap: Option<usize>,
}

impl CompiledProgram {
    /// The extracted (rewritten) program.
    pub fn expr(&self) -> &RecExpr {
        &self.expr
    }

    /// The execution backend this handle runs under.
    pub fn backend(&self) -> ExecBackend {
        self.backend
    }

    /// A fresh execution engine for this handle's backend, to be **held
    /// by the caller** across [`Self::run_with`] /
    /// [`Self::run_traced_with`] / [`Self::cosim_with`] calls.
    ///
    /// The per-call convenience APIs ([`Self::run`] and friends) build a
    /// throwaway engine each time — which, under the MMIO backends,
    /// re-instantiates the per-target ILA simulators (a ~0.3 MB
    /// initial-state clone for FlexASR) on every single-point
    /// evaluation. A persistent engine pays that once: simulators are
    /// built on first use, dirty-region reset between invocations, and
    /// reused for the engine's lifetime.
    ///
    /// ```
    /// use d2a::ir::{GraphBuilder, Op, Target};
    /// use d2a::session::{Bindings, ExecBackend, Session};
    /// use d2a::tensor::Tensor;
    ///
    /// // an already-mapped accelerator op (attach() skips saturation)
    /// let mut g = GraphBuilder::new();
    /// let (x, w, b) = (g.var("x"), g.weight("w"), g.weight("b"));
    /// g.expr.add(Op::FlexLinear, vec![x, w, b]);
    /// let session = Session::builder()
    ///     .targets(&[Target::FlexAsr])
    ///     .backend(ExecBackend::IlaMmio)
    ///     .build();
    /// let program = session.attach(g.finish());
    /// let bindings = Bindings::new()
    ///     .with("x", Tensor::ones(&[1, 8]))
    ///     .with("w", Tensor::ones(&[4, 8]))
    ///     .with("b", Tensor::ones(&[4]));
    ///
    /// let mut engine = program.engine();
    /// let first = program.run_with(&mut engine, &bindings).unwrap();
    /// let second = program.run_with(&mut engine, &bindings).unwrap();
    /// assert_eq!(first, second);
    /// assert_eq!(engine.sims_built(), 1); // one simulator, two MMIO runs
    /// assert_eq!(engine.lowered_invocations(), 2);
    /// ```
    ///
    /// When the session was built with [`SessionBuilder::device_pool`],
    /// the returned engine draws devices from the shared pool instead of
    /// owning private simulators.
    pub fn engine(&self) -> ExecEngine<'_> {
        let engine = match &self.pool {
            Some(pool) => {
                ExecEngine::new_pooled(&self.registry, self.backend, Arc::clone(pool))
            }
            None => ExecEngine::new(&self.registry, self.backend),
        };
        let engine = engine.with_prefetch(self.prefetch).with_dram_capacity(self.dram_capacity);
        match self.lower_cache_cap {
            Some(cap) => engine.with_lowering_cache_capacity(cap),
            None => engine,
        }
    }

    /// The shared device pool this handle's engines draw from (None for
    /// sessions without [`SessionBuilder::device_pool`]).
    pub fn device_pool(&self) -> Option<&Arc<DevicePool>> {
        self.pool.as_ref()
    }

    /// Guard for the `*_with` APIs: the engine must dispatch into this
    /// handle's registry (its simulator cache is only valid for the
    /// model instances that built it).
    fn check_engine(&self, engine: &ExecEngine<'_>) -> Result<(), EvalError> {
        if engine.serves(&self.registry) {
            Ok(())
        } else {
            Err(EvalError::Input(
                "execution engine belongs to a different session/registry; \
                 obtain it from this program's `engine()`"
                    .into(),
            ))
        }
    }

    /// Compilation statistics (None for [`Session::attach`] handles).
    pub fn stats(&self) -> Option<&CompileStats> {
        self.stats.as_ref()
    }

    /// The registry this handle dispatches to.
    pub fn registry(&self) -> &Arc<AcceleratorRegistry> {
        &self.registry
    }

    /// The precomputed dispatch plan.
    pub fn plan(&self) -> &DispatchPlan {
        &self.plan
    }

    /// Static accelerator invocations per target — the Table 1 metric.
    pub fn invocations(&self, target: Target) -> usize {
        self.expr.invocations(target)
    }

    /// Pure f32 reference evaluation (no accelerator numerics).
    pub fn run_ref(&self, bindings: &Bindings) -> Result<Tensor, EvalError> {
        interp::eval(&self.expr, bindings.env())
    }

    /// Evaluate with accelerator numerics on the offloaded regions,
    /// through the session's execution backend.
    ///
    /// This tensor-only API does not surface the
    /// [`ExecBackend::CrossCheck`] fidelity report; use
    /// [`Self::run_traced`] when the cross-check outcome matters, and a
    /// caller-held [`Self::engine`] + [`Self::run_with`] for repeated
    /// single-point MMIO evaluations.
    ///
    /// ```
    /// use d2a::ir::{GraphBuilder, Target};
    /// use d2a::session::{Bindings, Session};
    /// use d2a::tensor::Tensor;
    ///
    /// let mut g = GraphBuilder::new();
    /// let (x, w, b) = (g.var("x"), g.weight("w"), g.weight("b"));
    /// g.linear(x, w, b);
    /// let shapes = [
    ///     ("x".to_string(), vec![1usize, 8]),
    ///     ("w".to_string(), vec![4, 8]),
    ///     ("b".to_string(), vec![4]),
    /// ]
    /// .into_iter()
    /// .collect();
    /// let session = Session::builder().targets(&[Target::FlexAsr]).build();
    /// let program = session.compile_expr(&g.finish(), &shapes);
    /// assert_eq!(program.invocations(Target::FlexAsr), 1);
    ///
    /// let out = program
    ///     .run(&Bindings::new()
    ///         .with("x", Tensor::ones(&[1, 8]))
    ///         .with("w", Tensor::ones(&[4, 8]))
    ///         .with("b", Tensor::ones(&[4])))
    ///     .unwrap();
    /// assert_eq!(out.shape, vec![1, 4]);
    /// ```
    pub fn run(&self, bindings: &Bindings) -> Result<Tensor, EvalError> {
        let mut engine = self.engine();
        self.run_with(&mut engine, bindings)
    }

    /// [`Self::run`] on a caller-held engine (see [`Self::engine`]):
    /// repeated single-point evaluations skip per-call simulator
    /// construction, and under [`ExecBackend::CrossCheck`] the fidelity
    /// report keeps accumulating in the engine across calls.
    pub fn run_with(
        &self,
        engine: &mut ExecEngine<'_>,
        bindings: &Bindings,
    ) -> Result<Tensor, EvalError> {
        self.check_engine(engine)?;
        self.exec(bindings.env(), engine, None).map(|(t, _)| t)
    }

    /// Evaluate with accelerator numerics, returning the invocation
    /// count, (when the session opted in) per-invocation errors, and the
    /// backend's fidelity report — half the cost of [`Self::cosim`] when
    /// the f32 reference output is not needed.
    pub fn run_traced(&self, bindings: &Bindings) -> Result<RunTrace, EvalError> {
        let mut engine = self.engine();
        self.run_traced_with(&mut engine, bindings)
    }

    /// [`Self::run_traced`] on a caller-held engine. The trace reports
    /// **this call's** MMIO invocation count and drains the fidelity
    /// accumulated in the engine since it was last taken.
    pub fn run_traced_with(
        &self,
        engine: &mut ExecEngine<'_>,
        bindings: &Bindings,
    ) -> Result<RunTrace, EvalError> {
        self.check_engine(engine)?;
        let mmio_before = engine.lowered_invocations();
        let bytes_before = engine.bytes_streamed();
        let dedup_before = engine.bursts_deduped();
        let mirrors_before = engine.mirror_hits();
        let timeline_before = engine.timeline().snapshot();
        let mut inv_errors = Vec::new();
        let errors = if self.track_errors { Some(&mut inv_errors) } else { None };
        let (output, invocations) = self.exec(bindings.env(), engine, errors)?;
        let (cycles, op_cycles) = engine.timeline().since(&timeline_before);
        Ok(RunTrace {
            output,
            invocations,
            mmio_invocations: engine.lowered_invocations() - mmio_before,
            bytes_streamed: engine.bytes_streamed() - bytes_before,
            bursts_deduped: engine.bursts_deduped() - dedup_before,
            mirror_hits: engine.mirror_hits() - mirrors_before,
            cycles,
            op_cycles,
            inv_errors,
            fidelity: engine.take_fidelity(),
        })
    }

    /// Evaluate a batch, sharded over the session's worker threads.
    /// Output order matches input order and results are independent of
    /// the worker count. Each worker owns one [`ExecEngine`] (and thus
    /// its own ILA simulators under the MMIO backends).
    ///
    /// Note: this tensor-only API does not surface the
    /// [`ExecBackend::CrossCheck`] fidelity report (the per-worker
    /// engines are dropped with it); use [`Self::run_traced`] per point
    /// or [`Self::classify_sweep`] when the cross-check outcome matters.
    pub fn run_batch(&self, batch: &[Bindings]) -> Vec<Result<Tensor, EvalError>> {
        let workers = self.workers.max(1).min(batch.len().max(1));
        if workers <= 1 {
            let mut engine = self.engine();
            return batch
                .iter()
                .map(|b| self.exec(b.env(), &mut engine, None).map(|(t, _)| t))
                .collect();
        }
        let chunk = batch.len().div_ceil(workers);
        let mut out = Vec::with_capacity(batch.len());
        thread::scope(|s| {
            let handles: Vec<_> = batch
                .chunks(chunk)
                .map(|shard| {
                    s.spawn(move || {
                        let mut engine = self.engine();
                        shard
                            .iter()
                            .map(|b| {
                                self.exec(b.env(), &mut engine, None).map(|(t, _)| t)
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            for h in handles {
                out.extend(h.join().expect("batch worker panicked"));
            }
        });
        out
    }

    /// Co-simulate one evaluation: reference f32 vs accelerator
    /// numerics, with per-invocation error tracking when the session
    /// opted in.
    pub fn cosim(&self, bindings: &Bindings) -> Result<CosimReport, EvalError> {
        let mut engine = self.engine();
        self.cosim_with(&mut engine, bindings)
    }

    /// [`Self::cosim`] on a caller-held engine (see [`Self::engine`]).
    pub fn cosim_with(
        &self,
        engine: &mut ExecEngine<'_>,
        bindings: &Bindings,
    ) -> Result<CosimReport, EvalError> {
        self.check_engine(engine)?;
        let reference = interp::eval(&self.expr, bindings.env())?;
        let mut inv_errors = Vec::new();
        let errors = if self.track_errors { Some(&mut inv_errors) } else { None };
        let (accelerated, invocations) = self.exec(bindings.env(), engine, errors)?;
        let rel_error = accelerated.rel_error(&reference);
        Ok(CosimReport {
            reference,
            accelerated,
            invocations,
            rel_error,
            inv_errors,
            fidelity: engine.take_fidelity(),
        })
    }

    /// Application-level classification sweep (Table 4): reference and
    /// accelerated accuracy over a labelled dataset, sharded over the
    /// session's worker threads. The input variable is explicit in the
    /// [`SweepSpec`].
    ///
    /// Worker spin-up is allocation-free: each point evaluates under a
    /// [`LayeredEnv`] (shared weight map + one borrowed input slot)
    /// instead of the seed's per-worker clone of the whole weight map.
    pub fn classify_sweep(&self, spec: &SweepSpec<'_>) -> SweepReport {
        assert_eq!(
            spec.inputs.len(),
            spec.labels.len(),
            "sweep inputs/labels length mismatch"
        );
        let start = Instant::now();
        let workers = self.workers.max(1);
        let mut totals = (0usize, 0usize, 0usize); // (ref, acc, n)
        let mut sim_time = Duration::ZERO;
        let mut exec_errors = 0usize;
        // workers return their raw reports; ONE merge at the boundary
        // (below) keeps the result worker-order-independent
        let mut worker_fidelity = Vec::with_capacity(workers);
        let mut cycles = CycleBreakdown::default();
        let mut worker_ops = Vec::with_capacity(workers);
        thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|wid| {
                    s.spawn(move || {
                        let mut engine = self.engine();
                        let busy = Instant::now();
                        let (mut ref_c, mut acc_c, mut n) = (0usize, 0usize, 0usize);
                        let mut errs = 0usize;
                        let mut idx = wid;
                        while idx < spec.inputs.len() {
                            let env = LayeredEnv::new(
                                spec.weights,
                                spec.input_var,
                                &spec.inputs[idx],
                            );
                            if let Ok(r) = interp::eval(&self.expr, &env) {
                                if r.argmax() == spec.labels[idx] {
                                    ref_c += 1;
                                }
                            }
                            // an execution failure counts as a miss AND is
                            // surfaced in the report — the MMIO backends
                            // make this path genuinely fallible
                            match self.exec(&env, &mut engine, None) {
                                Ok((a, _)) => {
                                    if a.argmax() == spec.labels[idx] {
                                        acc_c += 1;
                                    }
                                }
                                Err(_) => errs += 1,
                            }
                            n += 1;
                            idx += workers;
                        }
                        let wc = engine.modeled_cycles();
                        let wops = engine.timeline().per_op().to_vec();
                        let fid = engine.take_fidelity();
                        (ref_c, acc_c, n, errs, busy.elapsed(), fid, wc, wops)
                    })
                })
                .collect();
            for h in handles {
                let (r, a, n, errs, busy, fid, wc, wops) =
                    h.join().expect("sweep worker panicked");
                totals.0 += r;
                totals.1 += a;
                totals.2 += n;
                exec_errors += errs;
                sim_time += busy;
                worker_fidelity.push(fid);
                cycles += wc;
                worker_ops.push(wops);
            }
        });
        SweepReport {
            n: totals.2,
            ref_correct: totals.0,
            acc_correct: totals.1,
            elapsed: start.elapsed(),
            sim_time,
            workers,
            exec_errors,
            fidelity: FidelityReport::merge_all(worker_fidelity),
            cycles,
            op_cycles: OpCycles::merge_all(worker_ops),
        }
    }

    /// Language-model co-simulation sweep (the Table 4 LSTM-WLM row):
    /// per-token perplexity, reference vs accelerated. Uses the default
    /// [`crate::cosim::LmSpec`] (input `"x_seq"`, 16-token windows) with
    /// the session's error-tracking setting and execution backend; see
    /// [`Self::lm_sweep_spec`] for explicit control.
    pub fn lm_sweep(
        &self,
        weights: &HashMap<String, Tensor>,
        embed: &Tensor,
        tokens: &[usize],
        n_sentences: usize,
    ) -> Result<crate::cosim::LmReport, EvalError> {
        let spec = crate::cosim::LmSpec {
            track_errors: self.track_errors,
            ..crate::cosim::LmSpec::default()
        };
        self.lm_sweep_spec(&spec, weights, embed, tokens, n_sentences)
    }

    /// Language-model co-simulation sweep with an explicit [`LmSpec`]
    /// (input variable name, window length, error tracking) — no
    /// hardcoded `"x_seq"`/16 assumptions. Runs under the session's
    /// execution backend.
    ///
    /// [`LmSpec`]: crate::cosim::LmSpec
    pub fn lm_sweep_spec(
        &self,
        spec: &crate::cosim::LmSpec<'_>,
        weights: &HashMap<String, Tensor>,
        embed: &Tensor,
        tokens: &[usize],
        n_sentences: usize,
    ) -> Result<crate::cosim::LmReport, EvalError> {
        // a pooled session's LM sweep draws its devices from the shared
        // pool like every other engine of the session
        let mut engine = self.engine();
        crate::cosim::cosim_lm_engine(
            &self.expr,
            spec,
            weights,
            embed,
            tokens,
            n_sentences,
            &mut engine,
        )
    }

    /// The plan-driven interpreter loop: host ops run f32 semantics,
    /// accelerator ops dispatch through the precomputed slot table into
    /// the worker's [`ExecEngine`] (which routes them to the tensor fast
    /// path, the ILA MMIO simulators, or both, per the session backend).
    ///
    /// The loop is *zero-clone*: `Var`/`Weight` leaves are borrowed from
    /// the environment instead of cloned (the seed cloned every leaf —
    /// including full weight matrices — on every evaluation), and
    /// intermediate tensors are dropped at their precomputed last use
    /// (`DispatchPlan::frees`), so peak memory is the live set, not the
    /// whole program.
    fn exec<E: EnvLookup + ?Sized>(
        &self,
        env: &E,
        engine: &mut ExecEngine<'_>,
        mut errors: Option<&mut Vec<f32>>,
    ) -> Result<(Tensor, usize), EvalError> {
        enum Slot<'a> {
            Borrowed(&'a Tensor),
            Owned(Tensor),
            Freed,
        }
        impl Slot<'_> {
            fn get(&self) -> &Tensor {
                match self {
                    Slot::Borrowed(t) => t,
                    Slot::Owned(t) => t,
                    Slot::Freed => unreachable!("liveness plan freed a live value"),
                }
            }
        }
        let mut values: Vec<Slot<'_>> = Vec::with_capacity(self.expr.len());
        let mut invocations = 0usize;
        for (i, (node, step)) in self.expr.nodes.iter().zip(&self.plan.steps).enumerate() {
            let v = match &node.op {
                Op::Var(n) | Op::Weight(n) => Slot::Borrowed(
                    env.lookup(n).ok_or_else(|| EvalError::Unbound(n.clone()))?,
                ),
                op => {
                    let ch: Vec<&Tensor> =
                        node.children.iter().map(|&c| values[c].get()).collect();
                    let out = match *step {
                        Step::Accel { slot, invocation } => {
                            match engine.execute_slot(slot, op, &ch)? {
                                Some(out) => {
                                    if invocation {
                                        invocations += 1;
                                        if let Some(errs) = errors.as_mut() {
                                            if let Ok(r) = interp::eval_op(op, &ch) {
                                                errs.push(out.rel_error(&r));
                                            }
                                        }
                                    }
                                    out
                                }
                                None => interp::eval_op(op, &ch)?,
                            }
                        }
                        Step::Host => interp::eval_op(op, &ch)?,
                    };
                    Slot::Owned(out)
                }
            };
            values.push(v);
            for &dead in &self.plan.frees[i] {
                values[dead] = Slot::Freed;
            }
        }
        let out = match values.pop().expect("empty program") {
            Slot::Owned(t) => t,
            // a bare-leaf program: the root is the environment tensor
            Slot::Borrowed(t) => t.clone(),
            Slot::Freed => unreachable!("the root is never freed"),
        };
        Ok((out, invocations))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::util::Rng;

    fn linear_app() -> (RecExpr, HashMap<String, Shape>) {
        let mut g = GraphBuilder::new();
        let x = g.var("input");
        let w = g.weight("w");
        let b = g.weight("b");
        g.linear(x, w, b);
        let shapes: HashMap<String, Shape> = [
            ("input".to_string(), vec![1usize, 8]),
            ("w".to_string(), vec![4, 8]),
            ("b".to_string(), vec![4]),
        ]
        .into_iter()
        .collect();
        (g.finish(), shapes)
    }

    fn linear_bindings(rng: &mut Rng) -> Bindings {
        Bindings::new()
            .with("input", Tensor::randn(&[1, 8], rng, 1.0))
            .with("w", Tensor::randn(&[4, 8], rng, 0.3))
            .with("b", Tensor::randn(&[4], rng, 0.1))
    }

    #[test]
    fn compile_produces_offloading_plan() {
        let (expr, shapes) = linear_app();
        let session = Session::builder().targets(&[Target::FlexAsr]).build();
        let program = session.compile_expr(&expr, &shapes);
        assert_eq!(program.invocations(Target::FlexAsr), 1);
        assert_eq!(program.plan().offloaded(), 1);
        assert!(program.stats().is_some());
    }

    #[test]
    fn run_applies_accelerator_numerics() {
        let (expr, shapes) = linear_app();
        let session = Session::builder().targets(&[Target::FlexAsr]).build();
        let program = session.compile_expr(&expr, &shapes);
        let mut rng = Rng::new(3);
        let b = linear_bindings(&mut rng);
        let acc = program.run(&b).unwrap();
        let reference = program.run_ref(&b).unwrap();
        let e = acc.rel_error(&reference);
        assert!(e > 0.0 && e < 0.1, "AdaptivFloat gap out of range: {e}");
    }

    #[test]
    fn cosim_reports_invocations_and_errors_when_tracking() {
        let (expr, shapes) = linear_app();
        let session = Session::builder()
            .targets(&[Target::FlexAsr])
            .track_errors(true)
            .build();
        let program = session.compile_expr(&expr, &shapes);
        let mut rng = Rng::new(4);
        let rep = program.cosim(&linear_bindings(&mut rng)).unwrap();
        assert_eq!(rep.invocations, 1);
        assert_eq!(rep.inv_errors.len(), 1);
        assert!(rep.rel_error < 0.1);
    }

    #[test]
    fn cosim_errors_empty_without_opt_in() {
        let (expr, shapes) = linear_app();
        let session = Session::builder().targets(&[Target::FlexAsr]).build();
        let program = session.compile_expr(&expr, &shapes);
        let mut rng = Rng::new(5);
        let rep = program.cosim(&linear_bindings(&mut rng)).unwrap();
        assert_eq!(rep.invocations, 1);
        assert!(rep.inv_errors.is_empty());
    }

    #[test]
    fn attach_skips_compilation_but_plans_dispatch() {
        let (expr, shapes) = linear_app();
        let session = Session::builder().targets(&[Target::FlexAsr]).build();
        let compiled = session.compile_expr(&expr, &shapes);
        let attached = session.attach(compiled.expr().clone());
        assert!(attached.stats().is_none());
        assert_eq!(attached.plan().offloaded(), compiled.plan().offloaded());
        let mut rng = Rng::new(6);
        let b = linear_bindings(&mut rng);
        assert_eq!(attached.run(&b).unwrap(), compiled.run(&b).unwrap());
    }

    #[test]
    fn handles_share_one_registry() {
        let session = Session::builder().build();
        let (expr, shapes) = linear_app();
        let p1 = session.compile_expr(&expr, &shapes);
        let p2 = session.attach(p1.expr().clone());
        assert!(Arc::ptr_eq(p1.registry(), p2.registry()));
        assert!(Arc::ptr_eq(p1.registry(), session.registry()));
    }

    #[test]
    fn liveness_plan_frees_at_last_use_and_keeps_root() {
        // x ── relu ── add ── (root)
        //  └──────────┘        diamond: x used by relu (1) and add (2)
        let mut g = GraphBuilder::new();
        let x = g.var("x"); // 0
        let r = g.relu(x); // 1
        g.add(x, r); // 2 (root)
        let session = Session::builder().build();
        let program = session.attach(g.finish());
        let frees = program.plan().frees();
        assert_eq!(frees.len(), 3);
        assert!(frees[1].is_empty(), "x is still live after relu");
        let mut at_root = frees[2].clone();
        at_root.sort_unstable();
        assert_eq!(at_root, vec![0, 1], "x and relu die at the root step");
        // and the root itself is never freed
        assert!(!frees.iter().any(|f| f.contains(&2)));
    }

    #[test]
    fn unused_node_freed_immediately() {
        // an attach()ed expression with dead code: the dead node must be
        // freed at its own step, not retained for the whole evaluation
        let mut g = GraphBuilder::new();
        let x = g.var("x"); // 0
        let _dead = g.relu(x); // 1 (unused)
        g.relu(x); // 2 (root)
        let session = Session::builder().build();
        let program = session.attach(g.finish());
        assert!(program.plan().frees()[1].contains(&1));
        let b = Bindings::new().with("x", Tensor::ones(&[2, 2]));
        assert_eq!(program.run(&b).unwrap(), program.run_ref(&b).unwrap());
    }

    #[test]
    fn bare_leaf_program_returns_the_binding() {
        let mut g = GraphBuilder::new();
        g.var("x");
        let session = Session::builder().build();
        let program = session.attach(g.finish());
        let t = Tensor::ones(&[3]);
        let b = Bindings::new().with("x", t.clone());
        assert_eq!(program.run(&b).unwrap(), t);
    }

    #[test]
    fn sweep_report_separates_wall_and_sim_time() {
        // the seed bug: time_per_point() divided *wall* time by n, so a
        // 4-worker sweep under-reported per-point sim time ~4x
        let rep = SweepReport {
            n: 10,
            ref_correct: 9,
            acc_correct: 8,
            elapsed: Duration::from_secs(10),
            sim_time: Duration::from_secs(40),
            workers: 4,
            exec_errors: 0,
            fidelity: FidelityReport::default(),
            cycles: CycleBreakdown::default(),
            op_cycles: Vec::new(),
        };
        assert_eq!(rep.wall_time_per_point(), Duration::from_secs(1));
        assert_eq!(rep.sim_time_per_point(), Duration::from_secs(4));
        assert_eq!(rep.time_per_point(), rep.sim_time_per_point());
    }

    #[test]
    fn classify_sweep_sim_time_bounded_by_workers() {
        let (expr, shapes) = linear_app();
        let mut rng = Rng::new(9);
        let weights: HashMap<String, Tensor> = [
            ("w".to_string(), Tensor::randn(&[4, 8], &mut rng, 0.3)),
            ("b".to_string(), Tensor::randn(&[4], &mut rng, 0.1)),
        ]
        .into_iter()
        .collect();
        let inputs: Vec<Tensor> =
            (0..16).map(|_| Tensor::randn(&[1, 8], &mut rng, 1.0)).collect();
        let labels: Vec<usize> = (0..16).map(|_| rng.below(4)).collect();
        for workers in [1usize, 4] {
            let session = Session::builder()
                .targets(&[Target::FlexAsr])
                .workers(workers)
                .build();
            let program = session.compile_expr(&expr, &shapes);
            let rep = program.classify_sweep(&SweepSpec {
                input_var: "input",
                weights: &weights,
                inputs: &inputs,
                labels: &labels,
            });
            assert_eq!(rep.n, 16);
            assert_eq!(rep.workers, workers);
            // each worker's busy time is bounded by the sweep wall time
            assert!(
                rep.sim_time <= rep.elapsed * workers as u32,
                "aggregate sim time {:?} exceeds {} x wall {:?}",
                rep.sim_time,
                workers,
                rep.elapsed
            );
        }
    }

    #[test]
    fn run_batch_empty_and_single() {
        let (expr, shapes) = linear_app();
        let session = Session::builder().targets(&[Target::FlexAsr]).workers(4).build();
        let program = session.compile_expr(&expr, &shapes);
        assert!(program.run_batch(&[]).is_empty());
        let mut rng = Rng::new(7);
        let b = linear_bindings(&mut rng);
        let out = program.run_batch(std::slice::from_ref(&b));
        assert_eq!(out.len(), 1);
        assert_eq!(*out[0].as_ref().unwrap(), program.run(&b).unwrap());
    }

    #[test]
    fn backend_threads_from_builder_to_program() {
        let (expr, shapes) = linear_app();
        for backend in
            [ExecBackend::Functional, ExecBackend::IlaMmio, ExecBackend::CrossCheck]
        {
            let session =
                Session::builder().targets(&[Target::FlexAsr]).backend(backend).build();
            assert_eq!(session.backend(), backend);
            let program = session.compile_expr(&expr, &shapes);
            assert_eq!(program.backend(), backend);
        }
    }

    #[test]
    fn mmio_backend_runs_bit_identical_to_functional() {
        let (expr, shapes) = linear_app();
        let functional = Session::builder().targets(&[Target::FlexAsr]).build();
        let program = functional.compile_expr(&expr, &shapes);
        let mmio = Session::builder()
            .targets(&[Target::FlexAsr])
            .backend(ExecBackend::IlaMmio)
            .build()
            .attach(program.expr().clone());
        let mut rng = Rng::new(21);
        let b = linear_bindings(&mut rng);
        assert_eq!(program.run(&b).unwrap(), mmio.run(&b).unwrap());
        // and the MMIO run really lowered (no silent fallback)
        let trace = mmio.run_traced(&b).unwrap();
        assert_eq!(trace.invocations, 1);
        assert_eq!(trace.mmio_invocations, 1);
        assert!(trace.cycles.total() > 0, "MMIO run must accrue modeled cycles");
        assert_eq!(trace.op_cycles.len(), 1, "one op head ran: {:?}", trace.op_cycles);
    }

    #[test]
    fn crosscheck_backend_populates_fidelity() {
        let (expr, shapes) = linear_app();
        let session = Session::builder()
            .targets(&[Target::FlexAsr])
            .backend(ExecBackend::CrossCheck)
            .build();
        let program = session.compile_expr(&expr, &shapes);
        let mut rng = Rng::new(22);
        let trace = program.run_traced(&linear_bindings(&mut rng)).unwrap();
        assert_eq!(trace.fidelity.total_checked(), 1);
        assert!(trace.fidelity.is_clean(), "{}", trace.fidelity);
        // functional runs leave the report empty
        let plain = Session::builder().targets(&[Target::FlexAsr]).build();
        let t2 = plain
            .attach(program.expr().clone())
            .run_traced(&linear_bindings(&mut rng))
            .unwrap();
        assert_eq!(t2.fidelity.total_checked(), 0);
        assert_eq!(t2.mmio_invocations, 0);
        assert_eq!(t2.cycles.total(), 0, "functional runs model no device cycles");
    }

    #[test]
    fn crosscheck_sweep_merges_worker_fidelity() {
        let (expr, shapes) = linear_app();
        let mut rng = Rng::new(23);
        let weights: HashMap<String, Tensor> = [
            ("w".to_string(), Tensor::randn(&[4, 8], &mut rng, 0.3)),
            ("b".to_string(), Tensor::randn(&[4], &mut rng, 0.1)),
        ]
        .into_iter()
        .collect();
        let inputs: Vec<Tensor> =
            (0..12).map(|_| Tensor::randn(&[1, 8], &mut rng, 1.0)).collect();
        let labels: Vec<usize> = (0..12).map(|_| rng.below(4)).collect();
        let session = Session::builder()
            .targets(&[Target::FlexAsr])
            .backend(ExecBackend::CrossCheck)
            .workers(3)
            .build();
        let program = session.compile_expr(&expr, &shapes);
        let rep = program.classify_sweep(&SweepSpec {
            input_var: "input",
            weights: &weights,
            inputs: &inputs,
            labels: &labels,
        });
        assert_eq!(rep.n, 12);
        // one FlexLinear invocation per point, merged across 3 workers
        assert_eq!(rep.fidelity.total_checked(), 12);
        assert!(rep.fidelity.is_clean(), "{}", rep.fidelity);
    }
}
