//! The unified session API: one handle-based entry point for
//! compile → co-simulate → sweep.
//!
//! The seed API scattered the D2A flow across free functions
//! (`compiler::compile`, `cosim::run_accelerated`,
//! `coordinator::classify_sweep`) that each took 5–6 positional
//! arguments, re-instantiated accelerator models per worker thread, and
//! hardcoded the sweep input variable to `"x"`. Following the ISA-like
//! interface discipline of the ILA papers, this module concentrates the
//! whole flow behind three types:
//!
//! * [`AcceleratorRegistry`] — an `Arc`-shared, `Target`-indexed dispatch
//!   table over the bit-accurate accelerator models;
//! * [`Session`] (built by [`SessionBuilder`]) — owns the registry plus
//!   the compilation policy (targets, matching mode, saturation limits,
//!   design revision, worker count) and exposes [`Session::compile`];
//! * [`CompiledProgram`] — a reusable handle caching the extracted
//!   [`RecExpr`] *and* a precomputed per-node [`DispatchPlan`], with
//!   [`CompiledProgram::run`], [`CompiledProgram::run_batch`],
//!   [`CompiledProgram::cosim`] and [`CompiledProgram::classify_sweep`].
//!
//! ```text
//! SessionBuilder ──build()──▶ Session ──compile(&App)──▶ CompiledProgram
//!                              │  Arc<AcceleratorRegistry>     │ plan: per-node slot
//!                              └────────────┬──────────────────┘
//!                                           ▼
//!                          ILA tensor fast path (exec_op)
//! ```

pub mod bindings;
pub mod registry;

pub use bindings::Bindings;
pub use registry::AcceleratorRegistry;

use crate::apps::App;
use crate::compiler;
use crate::egraph::{RunnerLimits, StopReason};
use crate::ir::interp::{self, EvalError};
use crate::ir::shape::Shape;
use crate::ir::{Op, RecExpr, Target};
use crate::rewrites::Matching;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Which accelerator configuration a session runs under (the Table 4
/// "Original" vs "Updated" columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DesignRev {
    /// As-published designs: HLSCNN 8-bit fixed-point weight store.
    Original,
    /// Post-co-design fix: HLSCNN 16-bit weights.
    Updated,
}

/// Configuration builder for a [`Session`].
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    targets: Vec<Target>,
    mode: Matching,
    limits: RunnerLimits,
    rev: DesignRev,
    workers: usize,
    track_errors: bool,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionBuilder {
    /// Defaults: all three accelerators, flexible matching, default
    /// saturation limits, updated designs, one worker, no per-invocation
    /// error tracking.
    pub fn new() -> Self {
        SessionBuilder {
            targets: vec![Target::FlexAsr, Target::Hlscnn, Target::Vta],
            mode: Matching::Flexible,
            limits: RunnerLimits::default(),
            rev: DesignRev::Updated,
            workers: 1,
            track_errors: false,
        }
    }

    /// Restrict compilation to the given targets.
    pub fn targets(mut self, targets: &[Target]) -> Self {
        self.targets = targets.to_vec();
        self
    }

    /// Exact or flexible matching (the two columns of Table 1).
    pub fn matching(mut self, mode: Matching) -> Self {
        self.mode = mode;
        self
    }

    /// Equality-saturation budgets.
    pub fn limits(mut self, limits: RunnerLimits) -> Self {
        self.limits = limits;
        self
    }

    /// Accelerator design revision (original vs updated numerics).
    pub fn design_rev(mut self, rev: DesignRev) -> Self {
        self.rev = rev;
        self
    }

    /// Worker threads for batched execution and sweeps.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Record per-invocation relative errors during co-simulation (the
    /// §4.4.2 debugging statistics; costs an extra f32 evaluation per
    /// accelerator invocation).
    pub fn track_errors(mut self, on: bool) -> Self {
        self.track_errors = on;
        self
    }

    /// Instantiate the accelerator models once and freeze the session.
    pub fn build(self) -> Session {
        Session {
            registry: Arc::new(AcceleratorRegistry::for_rev(self.rev)),
            targets: self.targets,
            mode: self.mode,
            limits: self.limits,
            rev: self.rev,
            workers: self.workers,
            track_errors: self.track_errors,
        }
    }
}

/// A configured compile/validate session: owns the accelerator registry
/// and the compilation policy.
pub struct Session {
    registry: Arc<AcceleratorRegistry>,
    targets: Vec<Target>,
    mode: Matching,
    limits: RunnerLimits,
    rev: DesignRev,
    workers: usize,
    track_errors: bool,
}

impl Session {
    /// Start building a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// The shared accelerator registry.
    pub fn registry(&self) -> &Arc<AcceleratorRegistry> {
        &self.registry
    }

    /// The session's design revision.
    pub fn design_rev(&self) -> DesignRev {
        self.rev
    }

    /// The session's matching mode.
    pub fn matching(&self) -> Matching {
        self.mode
    }

    /// The session's compilation targets.
    pub fn targets(&self) -> &[Target] {
        &self.targets
    }

    /// The session's worker-thread count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Compile an application (including app-specific rewrite rules) into
    /// a reusable handle.
    pub fn compile(&self, app: &App) -> CompiledProgram {
        let res = compiler::compile_app(app, &self.targets, self.mode, self.limits.clone());
        self.finish(res)
    }

    /// Compile a bare IR expression under the session policy.
    pub fn compile_expr(
        &self,
        expr: &RecExpr,
        shapes: &HashMap<String, Shape>,
    ) -> CompiledProgram {
        let res = compiler::compile(expr, shapes, &self.targets, self.mode, self.limits.clone());
        self.finish(res)
    }

    /// Wrap an already-compiled expression in a handle (precomputing its
    /// dispatch plan) without running saturation again.
    pub fn attach(&self, expr: RecExpr) -> CompiledProgram {
        self.handle(expr, None)
    }

    fn finish(&self, res: compiler::CompileResult) -> CompiledProgram {
        let stats = CompileStats {
            stop: res.stop,
            classes: res.classes,
            nodes: res.nodes,
            elapsed: res.elapsed,
        };
        self.handle(res.expr, Some(stats))
    }

    fn handle(&self, expr: RecExpr, stats: Option<CompileStats>) -> CompiledProgram {
        let plan = DispatchPlan::new(&expr, &self.registry);
        CompiledProgram {
            expr,
            stats,
            plan,
            registry: Arc::clone(&self.registry),
            workers: self.workers,
            track_errors: self.track_errors,
        }
    }
}

/// Compilation statistics carried by a [`CompiledProgram`] (absent for
/// handles created via [`Session::attach`]).
#[derive(Debug, Clone)]
pub struct CompileStats {
    /// Why saturation stopped.
    pub stop: StopReason,
    /// e-graph classes at extraction time.
    pub classes: usize,
    /// e-graph nodes at extraction time.
    pub nodes: usize,
    /// Wall-clock of saturation + extraction.
    pub elapsed: Duration,
}

/// One per-node dispatch decision, precomputed at compile time.
#[derive(Debug, Clone, Copy)]
enum Step {
    /// Host-evaluated op (or a leaf bound from the environment).
    Host,
    /// Route to the registry model in `slot`; `invocation` marks
    /// accelerator *compute* (data-movement ops are not invocations).
    Accel { slot: usize, invocation: bool },
}

/// Precomputed per-node dispatch decisions for one compiled expression —
/// the hot loop reads an array instead of matching op targets and
/// scanning accelerator lists per node per input.
#[derive(Debug, Clone)]
pub struct DispatchPlan {
    steps: Vec<Step>,
    offloaded: usize,
}

impl DispatchPlan {
    fn new(expr: &RecExpr, registry: &AcceleratorRegistry) -> Self {
        let mut steps = Vec::with_capacity(expr.len());
        let mut offloaded = 0usize;
        for node in &expr.nodes {
            let t = node.op.target();
            let step = if t == Target::Host {
                Step::Host
            } else {
                match registry.slot_for(t) {
                    Some(slot) => {
                        let invocation = node.op.is_accel_invocation();
                        if invocation {
                            offloaded += 1;
                        }
                        Step::Accel { slot, invocation }
                    }
                    // target compiled for but no model registered: fall
                    // back to the op's f32 semantics
                    None => Step::Host,
                }
            };
            steps.push(step);
        }
        DispatchPlan { steps, offloaded }
    }

    /// Number of accelerator invocations the plan routes per evaluation.
    pub fn offloaded(&self) -> usize {
        self.offloaded
    }
}

/// Result of one traced accelerated evaluation
/// ([`CompiledProgram::run_traced`]): the output plus the invocation
/// statistics, without the reference pass [`CompiledProgram::cosim`]
/// adds.
#[derive(Debug, Clone)]
pub struct RunTrace {
    /// Output with accelerator numerics on the offloaded regions.
    pub output: Tensor,
    /// Accelerator invocations executed.
    pub invocations: usize,
    /// Per-invocation relative errors (§4.4.2 debugging statistics);
    /// empty unless the session enabled
    /// [`SessionBuilder::track_errors`].
    pub inv_errors: Vec<f32>,
}

/// Result of one co-simulated evaluation ([`CompiledProgram::cosim`]).
#[derive(Debug, Clone)]
pub struct CosimReport {
    /// Pure f32 reference output (IR interpreter).
    pub reference: Tensor,
    /// Output with accelerator numerics on the offloaded regions.
    pub accelerated: Tensor,
    /// Accelerator invocations executed.
    pub invocations: usize,
    /// Relative (Frobenius) error of `accelerated` vs `reference`.
    pub rel_error: f32,
    /// Per-invocation relative errors (§4.4.2 debugging statistics);
    /// empty unless the session enabled
    /// [`SessionBuilder::track_errors`].
    pub inv_errors: Vec<f32>,
}

/// A classification sweep over a dataset: which bindings are shared
/// (weights), which variable carries the per-datapoint input — explicit,
/// where the seed API hardcoded `"x"` — and the labelled data.
#[derive(Debug, Clone, Copy)]
pub struct SweepSpec<'a> {
    /// Name of the per-datapoint input variable.
    pub input_var: &'a str,
    /// Bindings shared by every datapoint (weights, constants).
    pub weights: &'a HashMap<String, Tensor>,
    /// One tensor per datapoint, bound to `input_var`.
    pub inputs: &'a [Tensor],
    /// Ground-truth class per datapoint.
    pub labels: &'a [usize],
}

/// Merged result of a (possibly multi-worker) classification sweep.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub n: usize,
    pub ref_correct: usize,
    pub acc_correct: usize,
    pub elapsed: Duration,
    pub workers: usize,
}

impl SweepReport {
    pub fn ref_accuracy(&self) -> f32 {
        self.ref_correct as f32 / self.n as f32
    }

    pub fn acc_accuracy(&self) -> f32 {
        self.acc_correct as f32 / self.n as f32
    }

    /// Average simulation time per data point (the Table 4 column).
    pub fn time_per_point(&self) -> Duration {
        self.elapsed / self.n.max(1) as u32
    }
}

/// A compiled program handle: the extracted expression, its compilation
/// statistics, and a precomputed dispatch plan bound to the session's
/// shared registry. Handles are cheap to reuse across batches and are
/// `Sync` — one handle can serve many worker threads.
pub struct CompiledProgram {
    expr: RecExpr,
    stats: Option<CompileStats>,
    plan: DispatchPlan,
    registry: Arc<AcceleratorRegistry>,
    workers: usize,
    track_errors: bool,
}

impl CompiledProgram {
    /// The extracted (rewritten) program.
    pub fn expr(&self) -> &RecExpr {
        &self.expr
    }

    /// Compilation statistics (None for [`Session::attach`] handles).
    pub fn stats(&self) -> Option<&CompileStats> {
        self.stats.as_ref()
    }

    /// The registry this handle dispatches to.
    pub fn registry(&self) -> &Arc<AcceleratorRegistry> {
        &self.registry
    }

    /// The precomputed dispatch plan.
    pub fn plan(&self) -> &DispatchPlan {
        &self.plan
    }

    /// Static accelerator invocations per target — the Table 1 metric.
    pub fn invocations(&self, target: Target) -> usize {
        self.expr.invocations(target)
    }

    /// Pure f32 reference evaluation (no accelerator numerics).
    pub fn run_ref(&self, bindings: &Bindings) -> Result<Tensor, EvalError> {
        interp::eval(&self.expr, bindings.env())
    }

    /// Evaluate with accelerator numerics on the offloaded regions.
    pub fn run(&self, bindings: &Bindings) -> Result<Tensor, EvalError> {
        self.exec(bindings.env(), None).map(|(t, _)| t)
    }

    /// Evaluate with accelerator numerics, returning the invocation
    /// count and (when the session opted in) per-invocation errors —
    /// half the cost of [`Self::cosim`] when the f32 reference output
    /// is not needed.
    pub fn run_traced(&self, bindings: &Bindings) -> Result<RunTrace, EvalError> {
        let mut inv_errors = Vec::new();
        let errors = if self.track_errors { Some(&mut inv_errors) } else { None };
        let (output, invocations) = self.exec(bindings.env(), errors)?;
        Ok(RunTrace { output, invocations, inv_errors })
    }

    /// Evaluate a batch, sharded over the session's worker threads.
    /// Output order matches input order and results are independent of
    /// the worker count.
    pub fn run_batch(&self, batch: &[Bindings]) -> Vec<Result<Tensor, EvalError>> {
        let workers = self.workers.max(1).min(batch.len().max(1));
        if workers <= 1 {
            return batch.iter().map(|b| self.run(b)).collect();
        }
        let chunk = batch.len().div_ceil(workers);
        let mut out = Vec::with_capacity(batch.len());
        thread::scope(|s| {
            let handles: Vec<_> = batch
                .chunks(chunk)
                .map(|shard| {
                    s.spawn(move || {
                        shard.iter().map(|b| self.run(b)).collect::<Vec<_>>()
                    })
                })
                .collect();
            for h in handles {
                out.extend(h.join().expect("batch worker panicked"));
            }
        });
        out
    }

    /// Co-simulate one evaluation: reference f32 vs accelerator
    /// numerics, with per-invocation error tracking when the session
    /// opted in.
    pub fn cosim(&self, bindings: &Bindings) -> Result<CosimReport, EvalError> {
        let reference = interp::eval(&self.expr, bindings.env())?;
        let mut inv_errors = Vec::new();
        let errors = if self.track_errors { Some(&mut inv_errors) } else { None };
        let (accelerated, invocations) = self.exec(bindings.env(), errors)?;
        let rel_error = accelerated.rel_error(&reference);
        Ok(CosimReport { reference, accelerated, invocations, rel_error, inv_errors })
    }

    /// Application-level classification sweep (Table 4): reference and
    /// accelerated accuracy over a labelled dataset, sharded over the
    /// session's worker threads. Replaces `coordinator::classify_sweep`;
    /// the input variable is explicit in the [`SweepSpec`].
    pub fn classify_sweep(&self, spec: &SweepSpec<'_>) -> SweepReport {
        assert_eq!(
            spec.inputs.len(),
            spec.labels.len(),
            "sweep inputs/labels length mismatch"
        );
        let start = Instant::now();
        let workers = self.workers.max(1);
        let mut totals = (0usize, 0usize, 0usize); // (ref, acc, n)
        thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|wid| {
                    s.spawn(move || {
                        let mut env = spec.weights.clone();
                        let (mut ref_c, mut acc_c, mut n) = (0usize, 0usize, 0usize);
                        let mut idx = wid;
                        while idx < spec.inputs.len() {
                            env.insert(
                                spec.input_var.to_string(),
                                spec.inputs[idx].clone(),
                            );
                            if let Ok(r) = interp::eval(&self.expr, &env) {
                                if r.argmax() == spec.labels[idx] {
                                    ref_c += 1;
                                }
                            }
                            if let Ok((a, _)) = self.exec(&env, None) {
                                if a.argmax() == spec.labels[idx] {
                                    acc_c += 1;
                                }
                            }
                            n += 1;
                            idx += workers;
                        }
                        (ref_c, acc_c, n)
                    })
                })
                .collect();
            for h in handles {
                let (r, a, n) = h.join().expect("sweep worker panicked");
                totals.0 += r;
                totals.1 += a;
                totals.2 += n;
            }
        });
        SweepReport {
            n: totals.2,
            ref_correct: totals.0,
            acc_correct: totals.1,
            elapsed: start.elapsed(),
            workers,
        }
    }

    /// Language-model co-simulation sweep (the Table 4 LSTM-WLM row):
    /// per-token perplexity, reference vs accelerated.
    pub fn lm_sweep(
        &self,
        weights: &HashMap<String, Tensor>,
        embed: &Tensor,
        tokens: &[usize],
        n_sentences: usize,
    ) -> Result<crate::cosim::LmReport, EvalError> {
        crate::cosim::cosim_lm(
            &self.expr,
            weights,
            embed,
            tokens,
            n_sentences,
            &self.registry,
        )
    }

    /// The plan-driven interpreter loop: host ops run f32 semantics,
    /// accelerator ops dispatch through the precomputed slot table
    /// (no per-node target match, no accelerator scan).
    fn exec(
        &self,
        env: &HashMap<String, Tensor>,
        mut errors: Option<&mut Vec<f32>>,
    ) -> Result<(Tensor, usize), EvalError> {
        let mut values: Vec<Tensor> = Vec::with_capacity(self.expr.len());
        let mut invocations = 0usize;
        for (node, step) in self.expr.nodes.iter().zip(&self.plan.steps) {
            let ch: Vec<&Tensor> = node.children.iter().map(|&c| &values[c]).collect();
            let v = match &node.op {
                Op::Var(n) | Op::Weight(n) => {
                    env.get(n).cloned().ok_or_else(|| EvalError::Unbound(n.clone()))?
                }
                op => match *step {
                    Step::Accel { slot, invocation } => {
                        match self.registry.by_slot(slot).exec_op(op, &ch) {
                            Some(out) => {
                                if invocation {
                                    invocations += 1;
                                    if let Some(errs) = errors.as_mut() {
                                        if let Ok(r) = interp::eval_op(op, &ch) {
                                            errs.push(out.rel_error(&r));
                                        }
                                    }
                                }
                                out
                            }
                            None => interp::eval_op(op, &ch)?,
                        }
                    }
                    Step::Host => interp::eval_op(op, &ch)?,
                },
            };
            values.push(v);
        }
        Ok((values.pop().expect("empty program"), invocations))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::util::Rng;

    fn linear_app() -> (RecExpr, HashMap<String, Shape>) {
        let mut g = GraphBuilder::new();
        let x = g.var("input");
        let w = g.weight("w");
        let b = g.weight("b");
        g.linear(x, w, b);
        let shapes: HashMap<String, Shape> = [
            ("input".to_string(), vec![1usize, 8]),
            ("w".to_string(), vec![4, 8]),
            ("b".to_string(), vec![4]),
        ]
        .into_iter()
        .collect();
        (g.finish(), shapes)
    }

    fn linear_bindings(rng: &mut Rng) -> Bindings {
        Bindings::new()
            .with("input", Tensor::randn(&[1, 8], rng, 1.0))
            .with("w", Tensor::randn(&[4, 8], rng, 0.3))
            .with("b", Tensor::randn(&[4], rng, 0.1))
    }

    #[test]
    fn compile_produces_offloading_plan() {
        let (expr, shapes) = linear_app();
        let session = Session::builder().targets(&[Target::FlexAsr]).build();
        let program = session.compile_expr(&expr, &shapes);
        assert_eq!(program.invocations(Target::FlexAsr), 1);
        assert_eq!(program.plan().offloaded(), 1);
        assert!(program.stats().is_some());
    }

    #[test]
    fn run_applies_accelerator_numerics() {
        let (expr, shapes) = linear_app();
        let session = Session::builder().targets(&[Target::FlexAsr]).build();
        let program = session.compile_expr(&expr, &shapes);
        let mut rng = Rng::new(3);
        let b = linear_bindings(&mut rng);
        let acc = program.run(&b).unwrap();
        let reference = program.run_ref(&b).unwrap();
        let e = acc.rel_error(&reference);
        assert!(e > 0.0 && e < 0.1, "AdaptivFloat gap out of range: {e}");
    }

    #[test]
    fn cosim_reports_invocations_and_errors_when_tracking() {
        let (expr, shapes) = linear_app();
        let session = Session::builder()
            .targets(&[Target::FlexAsr])
            .track_errors(true)
            .build();
        let program = session.compile_expr(&expr, &shapes);
        let mut rng = Rng::new(4);
        let rep = program.cosim(&linear_bindings(&mut rng)).unwrap();
        assert_eq!(rep.invocations, 1);
        assert_eq!(rep.inv_errors.len(), 1);
        assert!(rep.rel_error < 0.1);
    }

    #[test]
    fn cosim_errors_empty_without_opt_in() {
        let (expr, shapes) = linear_app();
        let session = Session::builder().targets(&[Target::FlexAsr]).build();
        let program = session.compile_expr(&expr, &shapes);
        let mut rng = Rng::new(5);
        let rep = program.cosim(&linear_bindings(&mut rng)).unwrap();
        assert_eq!(rep.invocations, 1);
        assert!(rep.inv_errors.is_empty());
    }

    #[test]
    fn attach_skips_compilation_but_plans_dispatch() {
        let (expr, shapes) = linear_app();
        let session = Session::builder().targets(&[Target::FlexAsr]).build();
        let compiled = session.compile_expr(&expr, &shapes);
        let attached = session.attach(compiled.expr().clone());
        assert!(attached.stats().is_none());
        assert_eq!(attached.plan().offloaded(), compiled.plan().offloaded());
        let mut rng = Rng::new(6);
        let b = linear_bindings(&mut rng);
        assert_eq!(attached.run(&b).unwrap(), compiled.run(&b).unwrap());
    }

    #[test]
    fn handles_share_one_registry() {
        let session = Session::builder().build();
        let (expr, shapes) = linear_app();
        let p1 = session.compile_expr(&expr, &shapes);
        let p2 = session.attach(p1.expr().clone());
        assert!(Arc::ptr_eq(p1.registry(), p2.registry()));
        assert!(Arc::ptr_eq(p1.registry(), session.registry()));
    }

    #[test]
    fn run_batch_empty_and_single() {
        let (expr, shapes) = linear_app();
        let session = Session::builder().targets(&[Target::FlexAsr]).workers(4).build();
        let program = session.compile_expr(&expr, &shapes);
        assert!(program.run_batch(&[]).is_empty());
        let mut rng = Rng::new(7);
        let b = linear_bindings(&mut rng);
        let out = program.run_batch(std::slice::from_ref(&b));
        assert_eq!(out.len(), 1);
        assert_eq!(*out[0].as_ref().unwrap(), program.run(&b).unwrap());
    }
}
