//! Hand-rolled CLI (clap is not in the offline vendored set).

use std::collections::HashMap;

/// Parsed command line: subcommand + `--key value` flags.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    /// The subcommand (first positional argument).
    pub command: String,
    /// `--key value` flags (bare `--key` maps to "true").
    pub flags: HashMap<String, String>,
}

impl Cli {
    /// Parse `std::env::args()`-style input.
    pub fn parse(args: impl Iterator<Item = String>) -> Cli {
        let mut args = args.skip(1);
        let command = args.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        let mut key: Option<String> = None;
        for a in args {
            if let Some(k) = a.strip_prefix("--") {
                if let Some(prev) = key.take() {
                    flags.insert(prev, "true".to_string());
                }
                key = Some(k.to_string());
            } else if let Some(k) = key.take() {
                flags.insert(k, a);
            }
        }
        if let Some(prev) = key.take() {
            flags.insert(prev, "true".to_string());
        }
        Cli { command, flags }
    }

    /// A flag's value, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// A flag parsed as `usize`, with a default.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let c = parse("d2a cosim --app resnet20 --limit 100 --verbose");
        assert_eq!(c.command, "cosim");
        assert_eq!(c.get("app"), Some("resnet20"));
        assert_eq!(c.get_usize("limit", 0), 100);
        assert_eq!(c.get("verbose"), Some("true"));
    }

    #[test]
    fn default_command_is_help() {
        let c = parse("d2a");
        assert_eq!(c.command, "help");
    }
}
