//! The FlexASR MaxPool mapping verification (Table 3).

use super::obligations::{discharge_pairs, VerifyOutcome};
use crate::smt::bv::{BvTerm, EquivResult};
use std::collections::HashMap;
use std::rc::Rc;
use std::time::{Duration, Instant};

/// FlexASR global-buffer bank count (the tiling width).
pub const BANKS: usize = 16;

/// Symbolic input element `x[i][j]`.
fn xin(i: usize, j: usize) -> Rc<BvTerm> {
    BvTerm::var(format!("x_{i}_{j}"))
}

/// Compiler-IR fragment, fully symbolic: `out[i][j] = max(x[2i][j],
/// x[2i+1][j])` — the unrolled `map reduceMax (windows (2,1)(2,1))`.
pub fn spec_grid(r: usize, c: usize) -> Vec<Vec<Rc<BvTerm>>> {
    assert!(r % 2 == 0);
    (0..r / 2)
        .map(|i| (0..c).map(|j| BvTerm::max(xin(2 * i, j), xin(2 * i + 1, j))).collect())
        .collect()
}

/// FlexASR fragment: symbolic execution of the tiled implementation.
///
/// The driver stores column `j` of the matrix into bank `j % 16` at line
/// `i * ceil(c/16) + j / 16`; each bank's reduction lane computes row-pair
/// maxima **with the hardware operand order (odd row first)** into a tile
/// buffer, and readout re-interleaves banks into the output layout. The
/// net data flow reaches the same input elements through a different loop
/// nest and operand order — which is precisely what the prover must see
/// through.
pub fn flexasr_grid(r: usize, c: usize) -> Vec<Vec<Rc<BvTerm>>> {
    assert!(r % 2 == 0);
    let lines = c.div_ceil(BANKS);
    // store phase: bank[b][line] = x[i][j] for j%16==b, line = i*lines + j/16
    let mut bank: Vec<HashMap<usize, Rc<BvTerm>>> =
        (0..BANKS).map(|_| HashMap::new()).collect();
    for i in 0..r {
        for j in 0..c {
            bank[j % BANKS].insert(i * lines + j / BANKS, xin(i, j));
        }
    }
    // compute phase: per bank, per line-column, reduce row pairs
    // (hardware operand order: odd row enters the comparator first)
    let mut tile: Vec<HashMap<usize, Rc<BvTerm>>> =
        (0..BANKS).map(|_| HashMap::new()).collect();
    for (b, bank_mem) in bank.iter().enumerate() {
        for i in 0..r / 2 {
            for l in 0..lines {
                if let (Some(a0), Some(a1)) = (
                    bank_mem.get(&((2 * i + 1) * lines + l)),
                    bank_mem.get(&(2 * i * lines + l)),
                ) {
                    tile[b].insert(i * lines + l, BvTerm::max(a0.clone(), a1.clone()));
                }
            }
        }
    }
    // readout phase: re-interleave
    (0..r / 2)
        .map(|i| {
            (0..c)
                .map(|j| tile[j % BANKS][&(i * lines + j / BANKS)].clone())
                .collect()
        })
        .collect()
}

fn pairs_for_columns(
    spec: &[Vec<Rc<BvTerm>>],
    impl_: &[Vec<Rc<BvTerm>>],
    cols: std::ops::Range<usize>,
) -> Vec<(Rc<BvTerm>, Rc<BvTerm>)> {
    let mut pairs = Vec::new();
    for (srow, irow) in spec.iter().zip(impl_) {
        for j in cols.clone() {
            pairs.push((srow[j].clone(), irow[j].clone()));
        }
    }
    pairs
}

/// Bounded model checking: unroll everything, one monolithic miter,
/// discharged through the shared obligation runner.
pub fn verify_bmc(r: usize, c: usize, timeout: Duration) -> VerifyOutcome {
    let start = Instant::now();
    let spec = spec_grid(r, c);
    let impl_ = flexasr_grid(r, c);
    let pairs = pairs_for_columns(&spec, &impl_, 0..c);
    let mut out = discharge_pairs(8, &pairs, timeout);
    out.elapsed = start.elapsed(); // include grid construction
    out
}

/// CHC-style verification with the supplied relational invariant: the
/// inductive step for tile `t` proves columns `[16t, 16(t+1))` equal,
/// assuming nothing about other tiles (the fragments are tile-local, so
/// the invariant is inductive by construction — the paper's "relational
/// invariants that capture the customized tiling of FlexASR").
pub fn verify_chc(r: usize, c: usize, timeout: Duration) -> VerifyOutcome {
    let start = Instant::now();
    let spec = spec_grid(r, c);
    let impl_ = flexasr_grid(r, c);
    let tiles = c.div_ceil(BANKS);
    let mut conflicts = 0u64;
    let mut vars = 0usize;
    for t in 0..tiles {
        if start.elapsed() > timeout {
            return VerifyOutcome {
                result: EquivResult::Timeout,
                elapsed: start.elapsed(),
                queries: t,
                conflicts,
                vars,
            };
        }
        let lo = t * BANKS;
        let hi = ((t + 1) * BANKS).min(c);
        let pairs = pairs_for_columns(&spec, &impl_, lo..hi);
        let remaining = timeout.saturating_sub(start.elapsed());
        let step = discharge_pairs(8, &pairs, remaining);
        conflicts += step.conflicts;
        vars += step.vars;
        if step.result != EquivResult::Equivalent {
            return VerifyOutcome {
                result: step.result,
                elapsed: start.elapsed(),
                queries: t + 1,
                conflicts,
                vars,
            };
        }
    }
    VerifyOutcome {
        result: EquivResult::Equivalent,
        elapsed: start.elapsed(),
        queries: tiles,
        conflicts,
        vars,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smt::bv::BitBlaster;
    use crate::util::Rng;

    const T: Duration = Duration::from_secs(60);

    /// Concrete differential check: both grids compute matrix maxpool.
    #[test]
    fn grids_agree_concretely() {
        let (r, c) = (4usize, 32usize);
        let spec = spec_grid(r, c);
        let impl_ = flexasr_grid(r, c);
        let mut rng = Rng::new(101);
        let mut env = HashMap::new();
        for i in 0..r {
            for j in 0..c {
                env.insert(format!("x_{i}_{j}"), rng.below(256) as u64);
            }
        }
        for i in 0..r / 2 {
            for j in 0..c {
                assert_eq!(spec[i][j].eval(&env, 8), impl_[i][j].eval(&env, 8));
            }
        }
    }

    #[test]
    fn bmc_proves_small_instance() {
        let out = verify_bmc(2, 16, T);
        assert_eq!(out.result, EquivResult::Equivalent);
        assert_eq!(out.queries, 1);
    }

    #[test]
    fn chc_proves_small_instance_with_tile_queries() {
        let out = verify_chc(4, 32, T);
        assert_eq!(out.result, EquivResult::Equivalent);
        assert_eq!(out.queries, 2, "one inductive step per 16-column tile");
    }

    #[test]
    fn chc_scales_better_than_bmc() {
        // the Table 3 shape on a size where both finish quickly
        let bmc = verify_bmc(4, 32, T);
        let chc = verify_chc(4, 32, T);
        assert_eq!(bmc.result, EquivResult::Equivalent);
        assert_eq!(chc.result, EquivResult::Equivalent);
        assert!(
            bmc.vars > chc.vars / chc.queries * (chc.queries + 1) / 2,
            "BMC formula must be larger than a single CHC step: {} vs {}",
            bmc.vars,
            chc.vars / chc.queries
        );
    }

    #[test]
    fn buggy_implementation_is_refuted() {
        // swap max for min in one cone: the prover must find it
        let (r, c) = (2usize, 16usize);
        let spec = spec_grid(r, c);
        let mut impl_ = flexasr_grid(r, c);
        impl_[0][3] = BvTerm::min(xin(0, 3), xin(1, 3));
        let pairs = pairs_for_columns(&spec, &impl_, 0..c);
        let mut bb = BitBlaster::new(8);
        match bb.prove_all_equal(&pairs, T) {
            EquivResult::Counterexample(m) => {
                let a = m.get("x_0_3").copied().unwrap_or(0);
                let b = m.get("x_1_3").copied().unwrap_or(0);
                assert_ne!(a.max(b), a.min(b), "witness must distinguish");
            }
            other => panic!("expected refutation, got {other:?}"),
        }
    }
}
