//! Proof-based formal verification of IR-accelerator mappings (§4.4.1).
//!
//! The case study mirrors the paper's: the **FlexASR MaxPool mapping**,
//! verified as equivalence of two program fragments over fixed-size
//! tensors with *symbolic 8-bit data*:
//!
//! * the compiler-IR fragment — `map reduceMax (windows (2,1) (2,1) T)`;
//! * the FlexASR fragment — the same reduction expressed through the
//!   accelerator's customized tiling: the matrix is striped across the
//!   16 banks of the global buffer, each bank's lane reduces its own
//!   row-pairs (with the hardware's operand order), and the results are
//!   re-interleaved on readout.
//!
//! Two methods, as in Table 3:
//!
//! * **BMC** ([`maxpool::verify_bmc`]): unroll *all* loops on both sides
//!   and discharge one monolithic miter. Simple, but the formula grows
//!   with the full tensor and the solver's effort grows superlinearly.
//! * **CHC-style** ([`maxpool::verify_chc`]): a product program of the two
//!   fragments with a supplied **relational loop invariant** — "after `t`
//!   tile iterations, the first `16t` output columns of the two sides
//!   agree" — whose inductive step only quantifies over one tile. Each
//!   step is a small miter; the number of steps is linear in the tile
//!   count. (The paper likewise supplies the relational invariants by
//!   hand and leaves inference to future work.)
//!
//! Beyond the Table 3 case study, [`lowering`] + [`obligations`] apply
//! the same machinery to the repo's own compiler: a symbolic executor
//! walks every tiled [`crate::codegen::LoweredProgram`] over
//! [`crate::smt::BvTerm`]s and an obligation generator enumerates
//! bounded shapes covering every tiling edge for both design revisions
//! — **translation validation** of the codegen layer, which rediscovers
//! the Original-rev HLSCNN `wire_to_store` truncation as a concrete
//! counterexample.

pub mod lowering;
pub mod maxpool;
pub mod obligations;

pub use maxpool::{verify_bmc, verify_chc};
pub use obligations::{
    all_obligations, all_obligations_both_revs, check, conv_witness_tensors, discharge_pairs,
    expected_label, LoweringCex, ObKind, Obligation, ObligationReport, ObligationStatus,
    VerifyOutcome,
};
