//! Symbolic execution of [`LoweredProgram`]s for translation validation.
//!
//! This is the static-analysis half of the `d2a verify` obligation
//! pipeline (see [`super::obligations`]): a *shadow device* walks the
//! exact MMIO command stream a driver lowering produced — operand
//! bursts, DMA replays, per-tile triggers, bias schedules, `ReadPlan`
//! decode, stitching — but carries [`BvTerm`]s instead of concrete
//! bytes wherever a *marker* input element or a trigger result flows.
//! The walk yields a symbolic term grid for the program's final result,
//! which the obligation runner miters against an independently built
//! reference grid for the op's semantics and discharges with the
//! in-repo bit-blaster + CDCL solver (`smt::{bv,sat}`).
//!
//! Two fidelity levels coexist:
//!
//! * **Exact integer datapaths** (HLSCNN conv2d, the VTA vector ALU)
//!   are modelled bit-precisely: the shared symbolic kernels here
//!   ([`sym_conv2d_codes`], [`sym_wire_to_store_hw`], [`sym_vta_add`])
//!   mirror the integer reference kernels in `accel/*/model.rs`
//!   operation for operation, so a counterexample from the solver is a
//!   *concrete witness* that replays on the real simulator.
//! * **Float datapaths** (FlexASR's AdaptivFloat MACs) are abstracted
//!   by hash-consed **uninterpreted functions** ([`UfTable`]): two
//!   applications are the same term iff the opcode, every scheduled
//!   bias, and every operand term agree. This cannot prove numeric
//!   properties of the float math, but it proves exactly what tiling
//!   can break — that each tile feeds the *right operand bytes* under
//!   the *right bias schedule* to the *right trigger* and stores the
//!   result where the stitcher expects it.
//!
//! Inputs are introduced as **marker codes**: each operand element is
//! staged as a distinct concrete code whose byte pattern is registered
//! in a [`MarkerMap`]; when the shadow device reads a registered code
//! it substitutes the mapped symbolic variable. The obligation builders
//! construct marker tensors whose canonical encoding provably
//! round-trips (asserted, not assumed), so the correspondence
//! `staged byte ↔ symbolic variable` is exact.
//!
//! Since drivers lower to weight-keyed
//! [`crate::codegen::ProgramTemplate`]s, the obligations bind each
//! template with the marker tensors and structurally require that every
//! byte a late-bound [`crate::codegen::OperandSlot`] stages resolves to
//! a registered marker (`super::obligations::bind_slot_symbolic`). Slot
//! payloads therefore enter the walk as *free symbolic operand bytes*:
//! the discharged verdict covers every input the template can be bound
//! with, not just the one concrete lowering that was executed.

use crate::accel::flexasr::model as fx;
use crate::accel::hlscnn::model as hx;
use crate::accel::hlscnn::HlscnnConfig;
use crate::accel::vta::model as vx;
use crate::codegen::{LoweredProgram, ReadPlan, Stitch};
use crate::ila::Cmd;
use crate::ir::Target;
use crate::numerics::adaptivfloat::AdaptivFloatFormat;
use crate::numerics::fixed_point::FixedPointFormat;
use crate::smt::BvTerm;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::rc::Rc;

/// Marker registry: `(element width in bytes, raw little-endian code
/// bits)` → the symbolic variable standing for that staged element.
///
/// Codes must be globally distinct across every operand of one
/// obligation (the builders below enforce this on insert), because the
/// shadow device resolves markers by *value*, not by address.
pub type MarkerMap = HashMap<(usize, u64), Rc<BvTerm>>;

/// A symbolic result grid: the term computed for every element of a
/// tensor, in row-major order of `shape`.
#[derive(Debug, Clone)]
pub struct SymGrid {
    /// Tensor shape the terms are laid out in.
    pub shape: Vec<usize>,
    /// One term per element, row-major.
    pub terms: Vec<Rc<BvTerm>>,
}

/// Decode metadata attached to a symbolic read-back: everything the
/// host-side [`ReadPlan`] decode consumes *besides* the raw codes. Two
/// sides of a miter must agree on this exactly — a lowering that stores
/// the right codes under the wrong exponent bias is still wrong, and
/// that mismatch is caught structurally here rather than by the solver.
#[derive(Debug, Clone, PartialEq)]
pub enum ReadMeta {
    /// AdaptivFloat-8 read-back: the decode bias (from
    /// `STATUS_OUT_BIAS`) and the format parameters.
    Flex {
        /// Output exponent bias the device reported.
        bias: i32,
        /// Format total bits.
        bits: u32,
        /// Format exponent bits.
        exp_bits: u32,
    },
    /// HLSCNN fixed-point i16 read-back.
    Hlscnn {
        /// Format total bits.
        bits: u32,
        /// Format fractional bits.
        frac: u32,
    },
    /// VTA int32 read-back with a per-tensor power-of-two scale.
    Vta {
        /// Dequantization scale.
        scale: f32,
    },
}

/// A symbolic read-back: the term grid plus its decode metadata.
#[derive(Debug, Clone)]
pub struct SymPart {
    /// Terms for every element of the read block.
    pub grid: SymGrid,
    /// Decode parameters the host would apply to those codes.
    pub meta: ReadMeta,
}

/// Which device semantics drive the shadow triggers.
#[derive(Debug, Clone, Copy)]
pub enum DeviceModel {
    /// FlexASR: float datapath abstracted by uninterpreted functions.
    FlexAsr,
    /// HLSCNN with the given (rev-dependent) fixed-point formats —
    /// modelled bit-exactly, including the wire→store weight cast.
    Hlscnn(HlscnnConfig),
    /// VTA's saturating int32 vector ALU — modelled bit-exactly.
    Vta,
}

impl DeviceModel {
    fn target(&self) -> Target {
        match self {
            DeviceModel::FlexAsr => Target::FlexAsr,
            DeviceModel::Hlscnn(_) => Target::Hlscnn,
            DeviceModel::Vta => Target::Vta,
        }
    }
}

// ---------------------------------------------------------------------
// Uninterpreted functions
// ---------------------------------------------------------------------

/// Hash-consed uninterpreted-function table for abstracting float
/// datapaths: `apply` returns the *same* fresh variable for the same
/// `(name, params, args)` triple and a distinct one otherwise, which is
/// precisely the congruence the equivalence obligations need. One table
/// must be shared by the shadow execution and the reference builder of
/// an obligation so their applications alias.
#[derive(Debug, Default)]
pub struct UfTable {
    map: HashMap<(String, Vec<i64>, Vec<Rc<BvTerm>>), Rc<BvTerm>>,
    counter: usize,
}

impl UfTable {
    /// Fresh empty table.
    pub fn new() -> Self {
        UfTable::default()
    }

    /// Apply `name(params; args)`, hash-consing the result.
    pub fn apply(&mut self, name: &str, params: &[i64], args: &[Rc<BvTerm>]) -> Rc<BvTerm> {
        let key = (name.to_string(), params.to_vec(), args.to_vec());
        if let Some(t) = self.map.get(&key) {
            return t.clone();
        }
        let t = BvTerm::var(format!("uf{}_{}", self.counter, name));
        self.counter += 1;
        self.map.insert(key, t.clone());
        t
    }
}

/// One FlexASR linear output element `out[i][j]` as an uninterpreted
/// function of the operand codes and the full bias/activation schedule.
/// Shared by the shadow `fn_start` handler and [`ref_linear`].
pub fn uf_linear_elem(
    uf: &mut UfTable,
    k: usize,
    b_in: i32,
    b_wgt: i32,
    b_bias: i32,
    act: i64,
    out_bias: i32,
    x_row: &[Rc<BvTerm>],
    w_row: &[Rc<BvTerm>],
    b_j: &Rc<BvTerm>,
) -> Rc<BvTerm> {
    let mut args: Vec<Rc<BvTerm>> = x_row.to_vec();
    args.extend_from_slice(w_row);
    args.push(b_j.clone());
    uf.apply(
        "flex_linear",
        &[k as i64, b_in as i64, b_wgt as i64, b_bias as i64, act, out_bias as i64],
        &args,
    )
}

/// One FlexASR LSTM pre-activation gate element (the `OP_LSTM_GATES`
/// wide-float output) as an uninterpreted function.
#[allow(clippy::too_many_arguments)]
pub fn uf_lstm_gate_elem(
    uf: &mut UfTable,
    e: usize,
    hidden: usize,
    b_in: i32,
    b_wgt: i32,
    b_bias: i32,
    b_wgt2: i32,
    h_bias_in: i32,
    wide_bias: i32,
    x_row: &[Rc<BvTerm>],
    h_row: &[Rc<BvTerm>],
    wi_row: &[Rc<BvTerm>],
    wh_row: &[Rc<BvTerm>],
    b_j: &Rc<BvTerm>,
) -> Rc<BvTerm> {
    let mut args: Vec<Rc<BvTerm>> = x_row.to_vec();
    args.extend_from_slice(h_row);
    args.extend_from_slice(wi_row);
    args.extend_from_slice(wh_row);
    args.push(b_j.clone());
    uf.apply(
        "flex_lstm_gate",
        &[
            e as i64,
            hidden as i64,
            b_in as i64,
            b_wgt as i64,
            b_bias as i64,
            b_wgt2 as i64,
            h_bias_in as i64,
            wide_bias as i64,
        ],
        &args,
    )
}

/// The three `OP_LSTM_ACT` per-element outputs (next hidden code,
/// output-port code, next cell code) as uninterpreted functions of the
/// four gate terms and the previous cell code.
pub fn uf_lstm_act_elem(
    uf: &mut UfTable,
    which: &str,
    biases: &[i32],
    gate_i: &Rc<BvTerm>,
    gate_f: &Rc<BvTerm>,
    gate_g: &Rc<BvTerm>,
    gate_o: &Rc<BvTerm>,
    c_prev: &Rc<BvTerm>,
) -> Rc<BvTerm> {
    let params: Vec<i64> = biases.iter().map(|&b| b as i64).collect();
    let args = vec![
        gate_i.clone(),
        gate_f.clone(),
        gate_g.clone(),
        gate_o.clone(),
        c_prev.clone(),
    ];
    uf.apply(&format!("flex_lstm_act_{which}"), &params, &args)
}

// ---------------------------------------------------------------------
// Shared symbolic integer kernels (exact datapaths)
// ---------------------------------------------------------------------

/// Symbolic mirror of the **hardware** weight cast
/// [`hx::wire_to_store`]: arithmetic-shift the Q16.12 wire code down to
/// the store format, then saturate. On the Original rev this truncates
/// toward negative infinity — the flaw the Table 3 story rediscovers.
pub fn sym_wire_to_store_hw(store: FixedPointFormat, wire: &Rc<BvTerm>) -> Rc<BvTerm> {
    let shift = hx::wire_wgt_fmt().frac_bits.saturating_sub(store.frac_bits);
    let hi = (1i64 << (store.bits - 1)) - 1;
    let lo = -(1i64 << (store.bits - 1));
    BvTerm::sclamp(BvTerm::ashr(wire.clone(), shift), lo, hi)
}

/// Symbolic mirror of the **software** weight quantization
/// (`FixedPointFormat::encode` applied to the wire value): shift with
/// round-to-nearest-even, then saturate to the same rails.
pub fn sym_wire_to_store_sw(store: FixedPointFormat, wire: &Rc<BvTerm>) -> Rc<BvTerm> {
    let shift = hx::wire_wgt_fmt().frac_bits.saturating_sub(store.frac_bits);
    let hi = (1i64 << (store.bits - 1)) - 1;
    let lo = -(1i64 << (store.bits - 1));
    BvTerm::sclamp(BvTerm::rte(wire.clone(), shift), lo, hi)
}

/// Symbolic mirror of [`hx::conv2d_codes`]: NHWC activation codes ×
/// O-major-HWC store-format weight codes → NHWC output codes, with the
/// identical loop order, padding skip, and round-to-nearest-even
/// requantization saturating to the activation format.
#[allow(clippy::too_many_arguments)]
pub fn sym_conv2d_codes(
    acts: &[Rc<BvTerm>],
    wgts_store: &[Rc<BvTerm>],
    (c, h, w): (usize, usize, usize),
    o: usize,
    (kh, kw): (usize, usize),
    (sh, sw): (usize, usize),
    (ph, pw): (usize, usize),
    act_fmt: FixedPointFormat,
    wgt_fmt: FixedPointFormat,
) -> Vec<Rc<BvTerm>> {
    let oh = (h + 2 * ph - kh) / sh + 1;
    let ow = (w + 2 * pw - kw) / sw + 1;
    let hi = (1i64 << (act_fmt.bits - 1)) - 1;
    let lo = -(1i64 << (act_fmt.bits - 1));
    let mut out = Vec::with_capacity(oh * ow * o);
    for y in 0..oh {
        for xw in 0..ow {
            for oc in 0..o {
                let mut acc: Option<Rc<BvTerm>> = None;
                for dy in 0..kh {
                    let iy = (y * sh + dy) as isize - ph as isize;
                    if iy < 0 || iy as usize >= h {
                        continue;
                    }
                    for dx in 0..kw {
                        let ix = (xw * sw + dx) as isize - pw as isize;
                        if ix < 0 || ix as usize >= w {
                            continue;
                        }
                        for ch in 0..c {
                            let a = &acts[(iy as usize * w + ix as usize) * c + ch];
                            let wv = &wgts_store[((oc * kh + dy) * kw + dx) * c + ch];
                            let prod = BvTerm::mul(a.clone(), wv.clone());
                            acc = Some(match acc {
                                None => prod,
                                Some(s) => BvTerm::add(s, prod),
                            });
                        }
                    }
                }
                let acc = acc.unwrap_or_else(|| BvTerm::cnst(0));
                // accumulator carries `act_frac + wgt_frac` fractional
                // bits; requantize back to the activation lattice
                out.push(BvTerm::sclamp(BvTerm::rte(acc, wgt_fmt.frac_bits), lo, hi));
            }
        }
    }
    out
}

/// Symbolic mirror of the VTA saturating vector-ALU add (`alu_add`
/// with `saturate` set): per-lane `clamp(a + b, -127, 127)`.
pub fn sym_vta_add(a: &Rc<BvTerm>, b: &Rc<BvTerm>) -> Rc<BvTerm> {
    BvTerm::sclamp(BvTerm::add(a.clone(), b.clone()), -127, 127)
}

// ---------------------------------------------------------------------
// The shadow device
// ---------------------------------------------------------------------

struct Shadow<'m> {
    /// Concrete byte image per device memory region, zero-initialized
    /// like `IlaState::new_mem`.
    regions: Vec<(u64, Vec<u8>)>,
    /// Symbolic overlays: absolute address → (term, element width).
    overlays: HashMap<u64, (Rc<BvTerm>, usize)>,
    /// Concrete config registers (addr → last written u64).
    regs: HashMap<u64, u64>,
    /// The `STATUS_OUT_BIAS` value the last FlexASR trigger reported.
    status_out_bias: i32,
    markers: &'m MarkerMap,
}

impl<'m> Shadow<'m> {
    fn new(target: Target, markers: &'m MarkerMap) -> Self {
        let regions: Vec<(u64, usize)> = match target {
            Target::FlexAsr => vec![
                (fx::GB_BASE, fx::GB_SIZE),
                (fx::PE_WGT_BASE, fx::PE_WGT_SIZE),
                (fx::WGT_DRAM_BASE, fx::WGT_DRAM_SIZE),
            ],
            Target::Hlscnn => vec![
                (hx::ACT_BASE, hx::ACT_SIZE),
                (hx::WGT_BASE, hx::WGT_SIZE),
                (hx::OUT_BASE, hx::OUT_SIZE),
            ],
            Target::Vta => vec![
                (vx::INP_BASE, vx::INP_SIZE),
                (vx::WGT_BASE, vx::WGT_SIZE),
                (vx::ACC_BASE, vx::ACC_SIZE),
            ],
        };
        Shadow {
            regions: regions.into_iter().map(|(b, s)| (b, vec![0u8; s])).collect(),
            overlays: HashMap::new(),
            regs: HashMap::new(),
            status_out_bias: 0,
            markers,
        }
    }

    fn reg(&self, addr: u64) -> u64 {
        self.regs.get(&addr).copied().unwrap_or(0)
    }

    fn in_region(&self, addr: u64) -> bool {
        self.regions
            .iter()
            .any(|(b, m)| addr >= *b && addr < *b + m.len() as u64)
    }

    fn clear_overlays(&mut self, addr: u64, len: usize) {
        let end = addr + len as u64;
        self.overlays
            .retain(|&oa, &mut (_, ow)| oa + ow as u64 <= addr || oa >= end);
    }

    fn write_overlay(&mut self, addr: u64, width: usize, t: Rc<BvTerm>) {
        self.clear_overlays(addr, width);
        self.overlays.insert(addr, (t, width));
    }

    fn write_concrete(&mut self, addr: u64, payload: &[u8]) -> Result<(), String> {
        let end = addr + payload.len() as u64;
        for (base, mem) in &mut self.regions {
            if addr >= *base && end <= *base + mem.len() as u64 {
                let off = (addr - *base) as usize;
                mem[off..off + payload.len()].copy_from_slice(payload);
                self.clear_overlays(addr, payload.len());
                return Ok(());
            }
        }
        Err(format!("burst write outside device memory at {addr:#x}"))
    }

    fn read_concrete(&self, addr: u64, width: usize) -> Result<&[u8], String> {
        for (base, mem) in &self.regions {
            if addr >= *base && addr + width as u64 <= *base + mem.len() as u64 {
                let off = (addr - *base) as usize;
                return Ok(&mem[off..off + width]);
            }
        }
        Err(format!("read outside device memory at {addr:#x}"))
    }

    /// Read one element: exact overlay hit → its term; partial overlay
    /// overlap → error (a lowering must never slice a symbolic result);
    /// otherwise the concrete bytes, resolved through the marker map.
    fn read_elem(&self, addr: u64, width: usize) -> Result<Rc<BvTerm>, String> {
        if let Some((t, w)) = self.overlays.get(&addr) {
            if *w == width {
                return Ok(t.clone());
            }
            return Err(format!(
                "misaligned symbolic read at {addr:#x}: overlay width {w}, read width {width}"
            ));
        }
        for (&oa, &(_, ow)) in &self.overlays {
            if oa < addr + width as u64 && oa + ow as u64 > addr {
                return Err(format!(
                    "read at {addr:#x} partially overlaps symbolic overlay at {oa:#x}"
                ));
            }
        }
        let bytes = self.read_concrete(addr, width)?;
        let raw: u64 = match width {
            1 => bytes[0] as u64,
            2 => u16::from_le_bytes([bytes[0], bytes[1]]) as u64,
            4 => u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as u64,
            _ => return Err(format!("unsupported element width {width}")),
        };
        if let Some(t) = self.markers.get(&(width, raw)) {
            return Ok(t.clone());
        }
        Ok(match width {
            1 => BvTerm::cnst(raw),
            2 => BvTerm::cnst_i(raw as u16 as i16 as i64),
            _ => BvTerm::cnst_i(raw as u32 as i32 as i64),
        })
    }

    fn apply(
        &mut self,
        model: &DeviceModel,
        cmd: &Cmd,
        uf: &mut UfTable,
    ) -> Result<(), String> {
        if !cmd.is_write {
            return Ok(());
        }
        if self.in_region(cmd.addr) {
            return self.write_concrete(cmd.addr, cmd.payload());
        }
        match model {
            DeviceModel::FlexAsr => match cmd.addr {
                fx::DMA_CTRL => self.flex_dma(cmd.data_u64()),
                fx::FN_START => {
                    if cmd.data_u64() != 0 {
                        self.flex_trigger(uf)
                    } else {
                        Ok(())
                    }
                }
                _ => {
                    self.regs.insert(cmd.addr, cmd.data_u64());
                    Ok(())
                }
            },
            DeviceModel::Hlscnn(cfg) => match cmd.addr {
                hx::CFG_START => {
                    if cmd.data_u64() != 0 {
                        self.hlscnn_trigger(*cfg)
                    } else {
                        Ok(())
                    }
                }
                _ => {
                    self.regs.insert(cmd.addr, cmd.data_u64());
                    Ok(())
                }
            },
            DeviceModel::Vta => match cmd.addr {
                vx::INSN_ADDR => self.vta_insn(cmd),
                _ => {
                    self.regs.insert(cmd.addr, cmd.data_u64());
                    Ok(())
                }
            },
        }
    }

    /// Replay a `DMA_CTRL` word: weight-DRAM → PE buffer copy, same
    /// field layout as [`fx::dma_word`].
    fn flex_dma(&mut self, w: u64) -> Result<(), String> {
        let (src, dst, len) = fx::dma_fields(w);
        if src + len > fx::WGT_DRAM_SIZE || dst + len > fx::PE_WGT_SIZE {
            return Err(format!("DMA out of range: src {src:#x} dst {dst:#x} len {len:#x}"));
        }
        let src_base = fx::WGT_DRAM_BASE + src as u64;
        for (&oa, &(_, ow)) in &self.overlays {
            if oa < src_base + len as u64 && oa + ow as u64 > src_base {
                return Err("DMA from a symbolic source region is unsupported".to_string());
            }
        }
        let bytes: Vec<u8> = {
            let dram = self
                .regions
                .iter()
                .find(|(b, _)| *b == fx::WGT_DRAM_BASE)
                .expect("flexasr shadow has a DRAM region");
            dram.1[src..src + len].to_vec()
        };
        self.write_concrete(fx::PE_WGT_BASE + dst as u64, &bytes)
    }

    /// Dispatch a FlexASR `fn_start`, mirroring the register decode of
    /// `accel::flexasr::model::build_ila`.
    fn flex_trigger(&mut self, uf: &mut UfTable) -> Result<(), String> {
        let sizing = self.reg(fx::CFG_LAYER_SIZING);
        let (k, m) = ((sizing & 0xFFFF) as usize, ((sizing >> 16) & 0xFFFF) as usize);
        let control = self.reg(fx::CFG_GB_CONTROL);
        let (opcode, n) = (control & 0xFF, ((control >> 8) & 0xFF_FFFF) as usize);
        let mmngr = self.reg(fx::CFG_GB_MMNGR);
        let (in_base, out_base) = (mmngr & 0xFFFF_FFFF, mmngr >> 32);
        let mmngr2 = self.reg(fx::CFG_GB_MMNGR2);
        let (m2_lo, m2_hi) = (mmngr2 & 0xFFFF_FFFF, mmngr2 >> 32);
        let mngr = self.reg(fx::CFG_MNGR);
        let (bias_base, wgt2_base) = (mngr & 0xFFFF_FFFF, mngr >> 32);
        let eb = self.reg(fx::CFG_EXP_BIAS);
        let bias = |idx: u32| ((eb >> (8 * idx)) & 0xFF) as u8 as i8 as i32;
        let eb2 = self.reg(fx::CFG_EXP_BIAS2);
        let bias2 = |idx: u32| ((eb2 >> (8 * idx)) & 0xFF) as u8 as i8 as i32;
        let ob_reg = self.reg(fx::CFG_OUT_BIAS);
        let forced = (ob_reg & 0x100 != 0).then(|| (ob_reg & 0xFF) as u8 as i8 as i32);
        let gb = fx::GB_BASE;
        let pe = fx::PE_WGT_BASE;

        match opcode {
            fx::OP_LINEAR => {
                let ob = forced.ok_or_else(|| {
                    "symbolic linear requires a driver-forced CFG_OUT_BIAS \
                     (the output bias is data-dependent otherwise)"
                        .to_string()
                })?;
                let act = (self.reg(fx::CFG_ACT) & 0xFF) as i64;
                let mut writes = Vec::with_capacity(n * m);
                for i in 0..n {
                    let x_row: Vec<Rc<BvTerm>> = (0..k)
                        .map(|j| self.read_elem(gb + in_base + (i * k + j) as u64, 1))
                        .collect::<Result<_, _>>()?;
                    for j in 0..m {
                        let w_row: Vec<Rc<BvTerm>> = (0..k)
                            .map(|t| self.read_elem(pe + (j * k + t) as u64, 1))
                            .collect::<Result<_, _>>()?;
                        let b_j = self.read_elem(pe + bias_base + j as u64, 1)?;
                        let term = uf_linear_elem(
                            uf,
                            k,
                            bias(0),
                            bias(1),
                            bias(2),
                            act,
                            ob,
                            &x_row,
                            &w_row,
                            &b_j,
                        );
                        writes.push((gb + out_base + (i * m + j) as u64, term));
                    }
                }
                for (addr, t) in writes {
                    self.write_overlay(addr, 1, t);
                }
                self.status_out_bias = ob;
                Ok(())
            }
            fx::OP_LSTM_GATES => {
                let hidden = n;
                let (e, r) = (k, m);
                let h_base = m2_lo;
                let (h_bias_in, wide_bias) = (bias2(0), bias2(1));
                let x_row: Vec<Rc<BvTerm>> = (0..e)
                    .map(|j| self.read_elem(gb + in_base + j as u64, 1))
                    .collect::<Result<_, _>>()?;
                let h_row: Vec<Rc<BvTerm>> = (0..hidden)
                    .map(|j| self.read_elem(gb + h_base + j as u64, 1))
                    .collect::<Result<_, _>>()?;
                let mut writes = Vec::with_capacity(r);
                for j in 0..r {
                    let wi_row: Vec<Rc<BvTerm>> = (0..e)
                        .map(|t| self.read_elem(pe + (j * e + t) as u64, 1))
                        .collect::<Result<_, _>>()?;
                    let wh_row: Vec<Rc<BvTerm>> = (0..hidden)
                        .map(|t| self.read_elem(pe + wgt2_base + (j * hidden + t) as u64, 1))
                        .collect::<Result<_, _>>()?;
                    let b_j = self.read_elem(pe + bias_base + j as u64, 1)?;
                    let g = uf_lstm_gate_elem(
                        uf,
                        e,
                        hidden,
                        bias(0),
                        bias(1),
                        bias(2),
                        bias(3),
                        h_bias_in,
                        wide_bias,
                        &x_row,
                        &h_row,
                        &wi_row,
                        &wh_row,
                        &b_j,
                    );
                    writes.push((gb + out_base + 4 * j as u64, g));
                }
                for (addr, g) in writes {
                    self.write_overlay(addr, 4, g);
                }
                self.status_out_bias = wide_bias;
                Ok(())
            }
            fx::OP_LSTM_ACT => {
                let hidden = n;
                let (h_base, c_base) = (m2_lo, m2_hi);
                let (c_bias_in, h_bias_out, c_bias_out) = (bias(0), bias(1), bias(2));
                let ob = forced
                    .ok_or_else(|| "lstm_act requires a forced output bias".to_string())?;
                let gates: Vec<Rc<BvTerm>> = (0..4 * hidden)
                    .map(|i| self.read_elem(gb + in_base + 4 * i as u64, 4))
                    .collect::<Result<_, _>>()?;
                let c_prev: Vec<Rc<BvTerm>> = (0..hidden)
                    .map(|j| self.read_elem(gb + c_base + j as u64, 1))
                    .collect::<Result<_, _>>()?;
                for j in 0..hidden {
                    let (gi, gf, gg, go) = (
                        &gates[j],
                        &gates[hidden + j],
                        &gates[2 * hidden + j],
                        &gates[3 * hidden + j],
                    );
                    let h_t = uf_lstm_act_elem(
                        uf,
                        "h",
                        &[c_bias_in, h_bias_out],
                        gi,
                        gf,
                        gg,
                        go,
                        &c_prev[j],
                    );
                    let o_t = uf_lstm_act_elem(
                        uf,
                        "out",
                        &[c_bias_in, h_bias_out, ob],
                        gi,
                        gf,
                        gg,
                        go,
                        &c_prev[j],
                    );
                    let c_t = uf_lstm_act_elem(
                        uf,
                        "c",
                        &[c_bias_in, c_bias_out],
                        gi,
                        gf,
                        gg,
                        go,
                        &c_prev[j],
                    );
                    self.write_overlay(gb + h_base + j as u64, 1, h_t);
                    self.write_overlay(gb + out_base + j as u64, 1, o_t);
                    self.write_overlay(gb + c_base + j as u64, 1, c_t);
                }
                self.status_out_bias = ob;
                Ok(())
            }
            _ => Err(format!("symbolic FlexASR trigger: unsupported opcode {opcode}")),
        }
    }

    /// Replay an HLSCNN `conv_start`, bit-exactly, via the shared
    /// symbolic kernels.
    fn hlscnn_trigger(&mut self, cfg: HlscnnConfig) -> Result<(), String> {
        let shp = self.reg(hx::CFG_SHAPE);
        let c = (shp & 0xFFF) as usize;
        let h = ((shp >> 12) & 0xFFF) as usize;
        let w = ((shp >> 24) & 0xFFF) as usize;
        let o = ((shp >> 36) & 0xFFF) as usize;
        let krn = self.reg(hx::CFG_KERNEL);
        let kh = (krn & 0xFF) as usize;
        let kw = ((krn >> 8) & 0xFF) as usize;
        let sh = ((krn >> 16) & 0xFF) as usize;
        let sw = ((krn >> 24) & 0xFF) as usize;
        let ph = ((krn >> 32) & 0xFF) as usize;
        let pw = ((krn >> 40) & 0xFF) as usize;
        if kh == 0 || kw == 0 || sh == 0 || sw == 0 {
            return Err("conv_start with zero kernel/stride field".to_string());
        }
        if h + 2 * ph < kh || w + 2 * pw < kw {
            return Err("conv_start kernel larger than padded input".to_string());
        }
        let acts: Vec<Rc<BvTerm>> = (0..h * w * c)
            .map(|i| self.read_elem(hx::ACT_BASE + 2 * i as u64, 2))
            .collect::<Result<_, _>>()?;
        let store: Vec<Rc<BvTerm>> = (0..o * kh * kw * c)
            .map(|i| {
                self.read_elem(hx::WGT_BASE + 2 * i as u64, 2)
                    .map(|wire| sym_wire_to_store_hw(cfg.weight_fmt, &wire))
            })
            .collect::<Result<_, _>>()?;
        let out = sym_conv2d_codes(
            &acts,
            &store,
            (c, h, w),
            o,
            (kh, kw),
            (sh, sw),
            (ph, pw),
            cfg.act_fmt,
            cfg.weight_fmt,
        );
        for (i, t) in out.into_iter().enumerate() {
            self.write_overlay(hx::OUT_BASE + 2 * i as u64, 2, t);
        }
        Ok(())
    }

    /// Replay a VTA instruction-doorbell write.
    fn vta_insn(&mut self, cmd: &Cmd) -> Result<(), String> {
        let d = &cmd.data;
        if d[0] == vx::VTA_ALU_ADD {
            let saturate = d[1] != 0;
            let len = u32::from_le_bytes([d[2], d[3], d[4], d[5]]) as usize;
            if len * 4 > vx::ACC_SIZE || len * 4 > vx::WGT_SIZE {
                return Err("alu_add length exceeds scratchpads".to_string());
            }
            let mut lanes = Vec::with_capacity(len);
            for i in 0..len {
                let a = self.read_elem(vx::ACC_BASE + 4 * i as u64, 4)?;
                let b = self.read_elem(vx::WGT_BASE + 4 * i as u64, 4)?;
                let sum = BvTerm::add(a, b);
                lanes.push(if saturate {
                    BvTerm::sclamp(sum, -127, 127)
                } else {
                    sum
                });
            }
            for (i, t) in lanes.into_iter().enumerate() {
                self.write_overlay(vx::ACC_BASE + 4 * i as u64, 4, t);
            }
            Ok(())
        } else {
            Err(format!("symbolic VTA: unsupported instruction opcode {}", d[0]))
        }
    }

    /// Capture one invocation's read-back as a symbolic part.
    fn sym_read(&self, plan: &ReadPlan) -> Result<SymPart, String> {
        match plan {
            ReadPlan::FlexAf8 { base, shape, fmt } => {
                let count: usize = shape.iter().product();
                let terms: Vec<Rc<BvTerm>> = (0..count)
                    .map(|i| self.read_elem(base + i as u64, 1))
                    .collect::<Result<_, _>>()?;
                Ok(SymPart {
                    grid: SymGrid { shape: shape.clone(), terms },
                    meta: ReadMeta::Flex {
                        bias: self.status_out_bias,
                        bits: fmt.bits,
                        exp_bits: fmt.exp_bits,
                    },
                })
            }
            ReadPlan::HlscnnI16 { base, shape, fmt } => {
                if shape.len() != 4 {
                    return Err("HlscnnI16 read plan must be rank 4".to_string());
                }
                let (n, o, oh, ow) = (shape[0], shape[1], shape[2], shape[3]);
                let mut terms = vec![BvTerm::cnst(0); n * o * oh * ow];
                let mut idx = 0usize;
                // the device stores NHWC; the host decode permutes to
                // NCHW (`hx::decode_out_nchw_fmt`) — mirror that here
                for b in 0..n {
                    for y in 0..oh {
                        for xw in 0..ow {
                            for ch in 0..o {
                                terms[((b * o + ch) * oh + y) * ow + xw] =
                                    self.read_elem(base + 2 * idx as u64, 2)?;
                                idx += 1;
                            }
                        }
                    }
                }
                Ok(SymPart {
                    grid: SymGrid { shape: shape.clone(), terms },
                    meta: ReadMeta::Hlscnn { bits: fmt.bits, frac: fmt.frac_bits },
                })
            }
            ReadPlan::VtaI32 { base, shape, scale } => {
                let count: usize = shape.iter().product();
                let terms: Vec<Rc<BvTerm>> = (0..count)
                    .map(|i| self.read_elem(base + 4 * i as u64, 4))
                    .collect::<Result<_, _>>()?;
                Ok(SymPart {
                    grid: SymGrid { shape: shape.clone(), terms },
                    meta: ReadMeta::Vta { scale: *scale },
                })
            }
        }
    }
}

/// Concatenate per-invocation parts along `axis` into `shape`,
/// mirroring the concrete stitcher. All parts must share decode
/// metadata — tiles decoded under different biases/scales are a
/// lowering bug surfaced here as an error.
fn concat_parts(parts: Vec<SymPart>, axis: usize, shape: &[usize]) -> Result<SymPart, String> {
    let first_meta = parts
        .first()
        .map(|p| p.meta.clone())
        .ok_or_else(|| "stitch of an empty part list".to_string())?;
    for p in &parts {
        if p.meta != first_meta {
            return Err(format!(
                "tiles disagree on decode metadata: {:?} vs {:?}",
                p.meta, first_meta
            ));
        }
        if p.grid.shape.len() != shape.len() {
            return Err("tile rank mismatch in stitch".to_string());
        }
        for (d, (&pd, &sd)) in p.grid.shape.iter().zip(shape.iter()).enumerate() {
            if d != axis && pd != sd {
                return Err(format!("tile dim {d} mismatch: {pd} vs {sd}"));
            }
        }
    }
    let axis_total: usize = parts.iter().map(|p| p.grid.shape[axis]).sum();
    if axis_total != shape[axis] {
        return Err(format!(
            "stitched axis {axis} covers {axis_total} of {} elements",
            shape[axis]
        ));
    }
    let outer: usize = shape[..axis].iter().product();
    let inner: usize = shape[axis + 1..].iter().product();
    let mut terms = vec![BvTerm::cnst(0); outer * shape[axis] * inner];
    let mut off = 0usize;
    for p in parts {
        let pa = p.grid.shape[axis];
        for oi in 0..outer {
            for a in 0..pa {
                for ii in 0..inner {
                    terms[(oi * shape[axis] + off + a) * inner + ii] =
                        p.grid.terms[(oi * pa + a) * inner + ii].clone();
                }
            }
        }
        off += pa;
    }
    Ok(SymPart {
        grid: SymGrid { shape: shape.to_vec(), terms },
        meta: first_meta,
    })
}

/// Symbolically execute a lowered program against the shadow device:
/// replay every burst in order, dispatch triggers through `model`'s
/// symbolic semantics, capture each invocation's read-back *in program
/// order* (a later tile may overwrite the block an earlier tile was
/// read from — exactly as the concrete executor interleaves), and
/// stitch the parts. Returns the final symbolic result grid + decode
/// metadata, or a structural error when the program strays outside the
/// validated fragment.
pub fn sym_execute_program(
    prog: &LoweredProgram,
    model: &DeviceModel,
    markers: &MarkerMap,
    uf: &mut UfTable,
) -> Result<SymPart, String> {
    if prog.target() != model.target() {
        return Err(format!(
            "program targets {:?} but the shadow device models {:?}",
            prog.target(),
            model.target()
        ));
    }
    let mut shadow = Shadow::new(model.target(), markers);
    let mut parts = Vec::new();
    for inv in &prog.invocations {
        for burst in &inv.bursts {
            for cmd in burst.cmds.iter() {
                shadow.apply(model, cmd, uf)?;
            }
        }
        if let Some(plan) = &inv.read {
            parts.push(shadow.sym_read(plan)?);
        }
    }
    match &prog.stitch {
        Stitch::Last => parts.pop().ok_or_else(|| "no read-back invocation".to_string()),
        Stitch::Concat { axis, shape } => concat_parts(parts, *axis, shape),
    }
}

// ---------------------------------------------------------------------
// Marker tensor builders
// ---------------------------------------------------------------------

/// Build a row-major grid of symbolic input variables `{prefix}{i}`.
pub fn svar_grid(prefix: &str, count: usize, bits: u32) -> Vec<Rc<BvTerm>> {
    (0..count)
        .map(|i| BvTerm::svar(format!("{prefix}{i}"), bits))
        .collect()
}

/// AdaptivFloat-8 marker allocator: hands out concrete byte codes that
/// (a) are globally distinct within one obligation, (b) decode to
/// finite nonzero values at bias 0, (c) re-encode to themselves, and
/// (d) keep each tensor's first element in the format's top binade so
/// `select_bias` provably picks bias 0 for every marker tensor.
pub struct Af8MarkerPool {
    fmt: AdaptivFloatFormat,
    anchors: Vec<u8>,
    smalls: Vec<u8>,
    next_anchor: usize,
    next_small: usize,
}

impl Af8MarkerPool {
    /// Enumerate the usable code pool for `fmt`.
    pub fn new(fmt: AdaptivFloatFormat) -> Self {
        let e_max = ((1i32 << fmt.exp_bits) - 1) as f32;
        let binade_lo = e_max.exp2();
        let binade_hi = binade_lo * 2.0;
        let mut anchors = Vec::new();
        let mut smalls = Vec::new();
        for code in 0u16..=255 {
            let code = code as u8;
            if code == 0x80 || code == 0x81 {
                continue; // canonical zero and its nudge target
            }
            let v = fx::decode_byte(&fmt, code, 0);
            if !v.is_finite() || v == 0.0 {
                continue;
            }
            if fx::encode_byte(&fmt, v, 0) != code {
                continue; // non-canonical encoding
            }
            let mag = v.abs();
            if mag >= binade_lo && mag < binade_hi {
                anchors.push(code);
            } else if mag < binade_lo {
                smalls.push(code);
            }
        }
        // deterministic hand-out order: anchors and smalls by ascending
        // magnitude (codes are already enumerated in byte order; sort by
        // decoded magnitude so ties in layout never matter)
        let sort_key = |fmt: &AdaptivFloatFormat, c: u8| fx::decode_byte(fmt, c, 0).abs();
        anchors.sort_by(|a, b| sort_key(&fmt, *a).total_cmp(&sort_key(&fmt, *b)));
        smalls.sort_by(|a, b| sort_key(&fmt, *a).total_cmp(&sort_key(&fmt, *b)));
        Af8MarkerPool { fmt, anchors, smalls, next_anchor: 0, next_small: 0 }
    }

    /// Build one marker tensor: element 0 gets a fresh top-binade
    /// anchor, the rest fresh sub-binade codes. Registers every code in
    /// `markers` as an 8-bit symbolic variable `{prefix}{i}` and
    /// asserts that the canonical tensor encode reproduces exactly the
    /// planned codes at bias 0.
    pub fn tensor(
        &mut self,
        shape: &[usize],
        prefix: &str,
        markers: &mut MarkerMap,
    ) -> Result<Tensor, String> {
        let count: usize = shape.iter().product();
        if count == 0 {
            return Err("marker tensor must be non-empty".to_string());
        }
        let mut codes = Vec::with_capacity(count);
        codes.push(
            *self
                .anchors
                .get(self.next_anchor)
                .ok_or_else(|| "AF8 marker pool out of anchor codes".to_string())?,
        );
        self.next_anchor += 1;
        for _ in 1..count {
            codes.push(
                *self
                    .smalls
                    .get(self.next_small)
                    .ok_or_else(|| "AF8 marker pool out of small codes".to_string())?,
            );
            self.next_small += 1;
        }
        let vals: Vec<f32> = codes.iter().map(|&c| fx::decode_byte(&self.fmt, c, 0)).collect();
        let t = Tensor::new(shape.to_vec(), vals);
        let (enc, bias) = fx::encode_tensor(&self.fmt, &t);
        if bias != 0 {
            return Err(format!("marker tensor {prefix} selected bias {bias}, expected 0"));
        }
        if enc != codes {
            return Err(format!("marker tensor {prefix} does not round-trip its codes"));
        }
        for (i, &c) in codes.iter().enumerate() {
            let prev = markers.insert((1, c as u64), BvTerm::svar(format!("{prefix}{i}"), 8));
            if prev.is_some() {
                return Err(format!("marker code collision on {c:#04x} ({prefix}{i})"));
            }
        }
        Ok(t)
    }
}

/// HLSCNN activation markers: NCHW element `i` carries fixed-point code
/// `i + 1` (value `(i+1) · 2^-frac`), registered as a 2-byte marker
/// bound to the 6-bit symbolic variable `a{i}`.
pub fn hlscnn_act_markers(
    fmt: FixedPointFormat,
    shape: &[usize],
    markers: &mut MarkerMap,
) -> Result<Tensor, String> {
    let count: usize = shape.iter().product();
    let mut vals = Vec::with_capacity(count);
    for i in 0..count {
        let code = (i + 1) as i64;
        let v = fmt.decode(code);
        if fmt.encode(v) != code {
            return Err(format!("activation marker code {code} does not round-trip"));
        }
        let prev = markers.insert(
            (2, code as u16 as u64),
            BvTerm::svar(format!("a{i}"), 6),
        );
        if prev.is_some() {
            return Err(format!("activation marker code collision on {code}"));
        }
        vals.push(v);
    }
    Ok(Tensor::new(shape.to_vec(), vals))
}

/// HLSCNN weight markers: OIHW element `i` carries **wire** (Q16.12)
/// code `code_offset + i`, registered as a 2-byte marker bound to the
/// 12-bit symbolic variable `w{i}`. `code_offset` must clear the
/// activation code range so the two marker families never collide.
pub fn hlscnn_wgt_markers(
    shape: &[usize],
    code_offset: usize,
    markers: &mut MarkerMap,
) -> Result<Tensor, String> {
    let wire = hx::wire_wgt_fmt();
    let count: usize = shape.iter().product();
    let mut vals = Vec::with_capacity(count);
    for i in 0..count {
        let code = (code_offset + i) as i64;
        let v = wire.decode(code);
        if wire.encode(v) != code {
            return Err(format!("weight marker wire code {code} does not round-trip"));
        }
        let prev = markers.insert(
            (2, code as u16 as u64),
            BvTerm::svar(format!("w{i}"), 12),
        );
        if prev.is_some() {
            return Err(format!("weight marker code collision on {code}"));
        }
        vals.push(v);
    }
    Ok(Tensor::new(shape.to_vec(), vals))
}

/// VTA int8 marker operands for a length-`len` add: `a[i] = i + 1`,
/// `b[i] = -(i + 1)`, each registered as a 4-byte int32 marker bound to
/// a 7-bit symbolic variable (`a{i}` / `b{i}`). Returns the operand
/// tensors plus the shared power-of-two scale the driver will select.
pub fn vta_add_markers(
    len: usize,
    markers: &mut MarkerMap,
) -> Result<(Tensor, Tensor, f32), String> {
    use crate::numerics::int8::Int8Format;
    if len == 0 || len > 127 {
        return Err("vta marker length must be in 1..=127".to_string());
    }
    let int8 = Int8Format::new();
    let a_vals: Vec<f32> = (0..len).map(|i| (i + 1) as f32).collect();
    let b_vals: Vec<f32> = (0..len).map(|i| -((i + 1) as f32)).collect();
    let scale = int8.select_scale(len as f32);
    for (i, &v) in a_vals.iter().enumerate() {
        let code = int8.encode(v, scale) as i32;
        let prev = markers.insert(
            (4, code as u32 as u64),
            BvTerm::svar(format!("a{i}"), 7),
        );
        if prev.is_some() {
            return Err(format!("VTA marker code collision on a[{i}] = {code}"));
        }
    }
    for (i, &v) in b_vals.iter().enumerate() {
        let code = int8.encode(v, scale) as i32;
        let prev = markers.insert(
            (4, code as u32 as u64),
            BvTerm::svar(format!("b{i}"), 7),
        );
        if prev.is_some() {
            return Err(format!("VTA marker code collision on b[{i}] = {code}"));
        }
    }
    Ok((
        Tensor::new(vec![len], a_vals),
        Tensor::new(vec![len], b_vals),
        scale,
    ))
}

// ---------------------------------------------------------------------
// Reference grids (op semantics over the same symbolic inputs)
// ---------------------------------------------------------------------

/// Reference semantics for the FlexASR linear layer over marker terms:
/// every output element is the shared linear UF applied to the full
/// operand rows under the expected bias schedule.
#[allow(clippy::too_many_arguments)]
pub fn ref_linear(
    uf: &mut UfTable,
    x: &[Rc<BvTerm>],
    w: &[Rc<BvTerm>],
    b: &[Rc<BvTerm>],
    (n, k, m): (usize, usize, usize),
    (xb, wb, bb): (i32, i32, i32),
    out_bias: i32,
) -> SymGrid {
    let mut terms = Vec::with_capacity(n * m);
    for i in 0..n {
        let x_row = &x[i * k..(i + 1) * k];
        for j in 0..m {
            let w_row = &w[j * k..(j + 1) * k];
            terms.push(uf_linear_elem(
                uf, k, xb, wb, bb, 0, out_bias, x_row, w_row, &b[j],
            ));
        }
    }
    SymGrid { shape: vec![n, m], terms }
}

/// The per-step bias schedule the reference LSTM threads through its
/// UF applications — the validator recomputes it independently via
/// `FlexAsr::lstm_traced` and the lowering must agree.
#[derive(Debug, Clone)]
pub struct RefLstmSchedule {
    /// Wide (gate) bias per step.
    pub wide: Vec<i32>,
    /// Hidden-state bias per step.
    pub h: Vec<i32>,
    /// Cell-state bias per step.
    pub c: Vec<i32>,
    /// Forced output-port bias (whole sequence).
    pub out: i32,
}

/// Reference semantics for the FlexASR LSTM over marker terms: per
/// step, gate UFs over `x_t`, the previous hidden-code terms, and the
/// full weight rows; then per-element activation UFs producing the next
/// hidden/cell code terms and the output codes. Initial hidden/cell
/// codes are the canonical zero byte `0x80`, exactly as the driver
/// stages them.
#[allow(clippy::too_many_arguments)]
pub fn ref_lstm(
    uf: &mut UfTable,
    x: &[Rc<BvTerm>],
    wi: &[Rc<BvTerm>],
    wh: &[Rc<BvTerm>],
    b: &[Rc<BvTerm>],
    (t, e, h): (usize, usize, usize),
    (xb, wib, bb, whb): (i32, i32, i32, i32),
    sched: &RefLstmSchedule,
) -> SymGrid {
    let four_h = 4 * h;
    let mut h_prev: Vec<Rc<BvTerm>> = (0..h).map(|_| BvTerm::cnst(0x80)).collect();
    let mut c_prev: Vec<Rc<BvTerm>> = (0..h).map(|_| BvTerm::cnst(0x80)).collect();
    let mut out = Vec::with_capacity(t * h);
    for step in 0..t {
        let h_bias_in = if step == 0 { 0 } else { sched.h[step - 1] };
        let c_bias_in = if step == 0 { 0 } else { sched.c[step - 1] };
        let x_row = &x[step * e..(step + 1) * e];
        let gates: Vec<Rc<BvTerm>> = (0..four_h)
            .map(|j| {
                uf_lstm_gate_elem(
                    uf,
                    e,
                    h,
                    xb,
                    wib,
                    bb,
                    whb,
                    h_bias_in,
                    sched.wide[step],
                    x_row,
                    &h_prev,
                    &wi[j * e..(j + 1) * e],
                    &wh[j * h..(j + 1) * h],
                    &b[j],
                )
            })
            .collect();
        let mut h_next = Vec::with_capacity(h);
        let mut c_next = Vec::with_capacity(h);
        for j in 0..h {
            let (gi, gf, gg, go) =
                (&gates[j], &gates[h + j], &gates[2 * h + j], &gates[3 * h + j]);
            h_next.push(uf_lstm_act_elem(
                uf,
                "h",
                &[c_bias_in, sched.h[step]],
                gi,
                gf,
                gg,
                go,
                &c_prev[j],
            ));
            out.push(uf_lstm_act_elem(
                uf,
                "out",
                &[c_bias_in, sched.h[step], sched.out],
                gi,
                gf,
                gg,
                go,
                &c_prev[j],
            ));
            c_next.push(uf_lstm_act_elem(
                uf,
                "c",
                &[c_bias_in, sched.c[step]],
                gi,
                gf,
                gg,
                go,
                &c_prev[j],
            ));
        }
        h_prev = h_next;
        c_prev = c_next;
    }
    SymGrid { shape: vec![t, 1, h], terms: out }
}

/// Reference semantics for HLSCNN conv2d over marker terms: activation
/// variables in NCHW order (`a{i}`), **wire** weight variables in OIHW
/// order (`w{i}`), software round-to-nearest weight quantization
/// ([`sym_wire_to_store_sw`]), then the shared integer convolution
/// kernel — finally permuted NHWC → NCHW as the host decode does.
pub fn ref_conv2d(
    acts_nchw: &[Rc<BvTerm>],
    wgts_oihw: &[Rc<BvTerm>],
    (c, h, w): (usize, usize, usize),
    o: usize,
    (kh, kw): (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    cfg: HlscnnConfig,
) -> SymGrid {
    // NCHW markers → the device's NHWC activation order
    let mut acts_nhwc = Vec::with_capacity(h * w * c);
    for y in 0..h {
        for xw in 0..w {
            for ch in 0..c {
                acts_nhwc.push(acts_nchw[(ch * h + y) * w + xw].clone());
            }
        }
    }
    // OIHW wire markers → O-major HWC store codes via the software cast
    let mut store = Vec::with_capacity(o * kh * kw * c);
    for oc in 0..o {
        for dy in 0..kh {
            for dx in 0..kw {
                for ch in 0..c {
                    let wire = &wgts_oihw[((oc * c + ch) * kh + dy) * kw + dx];
                    store.push(sym_wire_to_store_sw(cfg.weight_fmt, wire));
                }
            }
        }
    }
    let codes = sym_conv2d_codes(
        &acts_nhwc,
        &store,
        (c, h, w),
        o,
        (kh, kw),
        stride,
        pad,
        cfg.act_fmt,
        cfg.weight_fmt,
    );
    let oh = (h + 2 * pad.0 - kh) / stride.0 + 1;
    let ow = (w + 2 * pad.1 - kw) / stride.1 + 1;
    let mut terms = vec![BvTerm::cnst(0); o * oh * ow];
    for y in 0..oh {
        for xw in 0..ow {
            for ch in 0..o {
                terms[(ch * oh + y) * ow + xw] = codes[(y * ow + xw) * o + ch].clone();
            }
        }
    }
    SymGrid { shape: vec![1, o, oh, ow], terms }
}

/// Reference semantics for the chunked VTA add over marker terms.
pub fn ref_vta_add(a: &[Rc<BvTerm>], b: &[Rc<BvTerm>], shape: &[usize]) -> SymGrid {
    let terms = a.iter().zip(b.iter()).map(|(x, y)| sym_vta_add(x, y)).collect();
    SymGrid { shape: shape.to_vec(), terms }
}
