//! Auto-generated translation-validation obligations for every tiled
//! driver lowering.
//!
//! Each [`Obligation`] names one op head, one [`DesignRev`], and one
//! bounded shape chosen to exercise a specific **tiling edge** (single
//! tile, exact tile split, uneven tail, capacity-bound tiles, padded
//! borders, multi-step LSTM schedules, chunk tails). Checking an
//! obligation runs the *real* driver template lowering on marker
//! tensors (via the `*_template*` cap-override entry points, so small
//! shapes still produce multi-tile programs), binds the resulting
//! slot-symbolic [`crate::codegen::ProgramTemplate`] with the markers
//! under a structural side condition — every byte a late-bound
//! [`crate::codegen::OperandSlot`] stages must resolve to a registered
//! marker variable, so slot payloads enter the proof as *free symbolic
//! operand bytes* — then symbolically executes the bound
//! [`crate::codegen::LoweredProgram`] with
//! [`super::lowering::sym_execute_program`], builds an independent
//! symbolic reference grid for the op's semantics, and discharges the
//! element-wise miter with the in-repo bit-blaster + CDCL solver.
//!
//! The expected verdict is part of the obligation lattice:
//! `DesignRev::Updated` lowerings must all verify **equivalent**, while
//! the Original-rev HLSCNN conv obligations are expected to come back
//! **inequivalent** — the solver rediscovers the truncating
//! `wire_to_store` weight cast as a concrete counterexample (the
//! paper's Table 4 headline bug), and the witness replays on the real
//! simulator (`tests/lowering_obligations.rs`).

use super::lowering::{
    hlscnn_act_markers, hlscnn_wgt_markers, ref_conv2d, ref_linear, ref_lstm, ref_vta_add,
    svar_grid, sym_execute_program, vta_add_markers, Af8MarkerPool, DeviceModel, MarkerMap,
    ReadMeta, RefLstmSchedule, SymGrid, SymPart, UfTable,
};
use crate::accel::flexasr::model as fx;
use crate::accel::flexasr::FlexAsr;
use crate::accel::hlscnn::model as hx;
use crate::accel::hlscnn::{Hlscnn, HlscnnConfig};
use crate::accel::vta::Vta;
use crate::codegen::{LoweredProgram, ProgramTemplate};
use crate::ir::Target;
use crate::session::DesignRev;
use crate::smt::{BitBlaster, BvTerm, EquivResult};
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::{Duration, Instant};

/// Verification outcome with timing and query statistics, shared by
/// the maxpool Table 3 checks and the lowering obligations.
#[derive(Debug, Clone)]
pub struct VerifyOutcome {
    /// Equivalence verdict.
    pub result: EquivResult,
    /// Wall-clock time the check took.
    pub elapsed: Duration,
    /// number of SAT queries discharged (1 for BMC; tiles for CHC)
    pub queries: usize,
    /// total SAT conflicts across queries (proof effort)
    pub conflicts: u64,
    /// total CNF variables created
    pub vars: usize,
}

/// Discharge one miter — prove every pair equal at `width` bits — and
/// report uniform solver statistics. This is the single entry point
/// every verification surface (Table 3 maxpool, lowering obligations)
/// routes through.
pub fn discharge_pairs(
    width: u32,
    pairs: &[(Rc<BvTerm>, Rc<BvTerm>)],
    timeout: Duration,
) -> VerifyOutcome {
    let start = Instant::now();
    let mut bb = BitBlaster::new(width);
    let result = bb.prove_all_equal(pairs, timeout);
    VerifyOutcome {
        result,
        elapsed: start.elapsed(),
        queries: 1,
        conflicts: bb.solver.stats_conflicts,
        vars: bb.solver.num_vars(),
    }
}

/// The op-specific shape parameters of one obligation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObKind {
    /// FlexASR forced-bias linear: `x[n,k] @ w[m,k]^T + b[m]` with a
    /// row-tile cap.
    Linear {
        /// Batch rows.
        n: usize,
        /// Input features.
        k: usize,
        /// Output features.
        m: usize,
        /// Row-tile cap forced onto the lowering.
        cap: usize,
    },
    /// FlexASR scheduled LSTM: `t` steps, input width `e`, hidden `h`,
    /// with a gate-row tile cap.
    Lstm {
        /// Time steps.
        t: usize,
        /// Input features per step.
        e: usize,
        /// Hidden size.
        h: usize,
        /// Gate-row tile cap forced onto the lowering.
        cap: usize,
    },
    /// HLSCNN channel-tiled conv2d on a `[1,c,h,w]` image.
    Conv {
        /// Input channels.
        c: usize,
        /// Image height.
        h: usize,
        /// Image width.
        w: usize,
        /// Output channels.
        o: usize,
        /// Kernel height.
        kh: usize,
        /// Kernel width.
        kw: usize,
        /// Stride (h, w).
        stride: (usize, usize),
        /// Padding (h, w).
        pad: (usize, usize),
        /// Output-channel tile cap forced onto the lowering.
        cap: usize,
    },
    /// Chunked VTA saturating vector add over `len` lanes.
    VtaAdd {
        /// Total lanes.
        len: usize,
        /// Chunk cap forced onto the lowering.
        cap: usize,
    },
}

/// One translation-validation obligation: a (target, rev, op, shape)
/// tuple exercising a named tiling edge.
#[derive(Debug, Clone)]
pub struct Obligation {
    /// Stable identifier, `op/rev/edge`.
    pub id: String,
    /// Accelerator the lowering targets.
    pub target: Target,
    /// Design revision under check.
    pub rev: DesignRev,
    /// Op head name (`linear`, `lstm`, `conv2d`, `vta_add`).
    pub op: &'static str,
    /// Tiling edge this shape exercises.
    pub edge: &'static str,
    /// Bit-width the miter is discharged at.
    pub width: u32,
    /// Shape parameters.
    pub kind: ObKind,
}

/// Concrete counterexample extracted from a SAT model: the first
/// differing output element, both codes, the full input assignment,
/// and (where the analysis can localize it) a note pinpointing the
/// diverging datapath.
#[derive(Debug, Clone)]
pub struct LoweringCex {
    /// Flat index of the first differing output element.
    pub index: usize,
    /// Hardware-side output code at that element.
    pub hw_code: i64,
    /// Reference-side output code at that element.
    pub ref_code: i64,
    /// Input variable assignment (name → signed value), sorted by name.
    pub inputs: Vec<(String, i64)>,
    /// Human-readable localization of the divergence, when available.
    pub note: String,
}

/// Verdict of one obligation check.
#[derive(Debug, Clone)]
pub enum ObligationStatus {
    /// The lowered program provably computes the op's semantics.
    Equivalent,
    /// The solver found a concrete diverging input.
    Inequivalent(Box<LoweringCex>),
    /// A structural side condition failed before any solving (shape or
    /// decode-metadata disagreement, lowering bail-out, executor error).
    Mismatch(String),
    /// The solver exhausted its time budget.
    Timeout,
}

impl ObligationStatus {
    /// Short lowercase label (`equivalent`, `inequivalent`, ...).
    pub fn label(&self) -> &'static str {
        match self {
            ObligationStatus::Equivalent => "equivalent",
            ObligationStatus::Inequivalent(_) => "inequivalent",
            ObligationStatus::Mismatch(_) => "mismatch",
            ObligationStatus::Timeout => "timeout",
        }
    }
}

/// Result of checking one obligation.
#[derive(Debug, Clone)]
pub struct ObligationReport {
    /// The obligation checked.
    pub ob: Obligation,
    /// Verdict.
    pub status: ObligationStatus,
    /// Solver statistics, when a miter was actually discharged.
    pub stats: Option<VerifyOutcome>,
}

impl ObligationReport {
    /// Whether the verdict matches the obligation lattice's expectation
    /// ([`expected_label`]).
    pub fn as_expected(&self) -> bool {
        self.status.label() == expected_label(&self.ob)
    }
}

/// The expected verdict for an obligation: Original-rev HLSCNN conv
/// lowerings carry the known truncating `wire_to_store` weight cast
/// and must be refuted; everything else must verify.
pub fn expected_label(ob: &Obligation) -> &'static str {
    if ob.op == "conv2d" && ob.rev == DesignRev::Original {
        "inequivalent"
    } else {
        "equivalent"
    }
}

fn rev_name(rev: DesignRev) -> &'static str {
    match rev {
        DesignRev::Original => "original",
        DesignRev::Updated => "updated",
    }
}

fn flex_dev(rev: DesignRev) -> FlexAsr {
    match rev {
        DesignRev::Original => FlexAsr::original(),
        DesignRev::Updated => FlexAsr::updated(),
    }
}

fn hlscnn_cfg(rev: DesignRev) -> HlscnnConfig {
    match rev {
        DesignRev::Original => HlscnnConfig::original(),
        DesignRev::Updated => HlscnnConfig::updated(),
    }
}

fn obligation(
    target: Target,
    rev: DesignRev,
    op: &'static str,
    edge: &'static str,
    width: u32,
    kind: ObKind,
) -> Obligation {
    Obligation {
        id: format!("{op}/{}/{edge}", rev_name(rev)),
        target,
        rev,
        op,
        edge,
        width,
        kind,
    }
}

/// Enumerate the bounded-shape obligation set for one design revision:
/// every tiled lowering × every tiling edge it can hit. Shapes are the
/// *smoke set* — deliberately tiny so the whole suite (including the
/// SAT search that refutes the Original-rev conv) stays CI-fast, while
/// the cap overrides still force genuine multi-tile programs.
pub fn all_obligations(rev: DesignRev) -> Vec<Obligation> {
    let fl = Target::FlexAsr;
    let hl = Target::Hlscnn;
    let vt = Target::Vta;
    let unit = (1usize, 1usize);
    let nopad = (0usize, 0usize);
    vec![
        // FlexASR forced-bias linear: row-tile edges
        obligation(fl, rev, "linear", "single-tile", 8,
            ObKind::Linear { n: 2, k: 3, m: 4, cap: usize::MAX }),
        obligation(fl, rev, "linear", "exact-tiles", 8,
            ObKind::Linear { n: 2, k: 3, m: 6, cap: 3 }),
        obligation(fl, rev, "linear", "uneven-tail", 8,
            ObKind::Linear { n: 2, k: 3, m: 5, cap: 2 }),
        obligation(fl, rev, "linear", "capacity-bound", 8,
            ObKind::Linear { n: 2, k: 3, m: 7, cap: 3 }),
        // FlexASR LSTM: per-step gate-tile schedule edges
        obligation(fl, rev, "lstm", "two-tile-steps", 8,
            ObKind::Lstm { t: 2, e: 3, h: 2, cap: 2 }),
        obligation(fl, rev, "lstm", "single-tile-step", 8,
            ObKind::Lstm { t: 2, e: 3, h: 2, cap: usize::MAX }),
        // HLSCNN conv2d: output-channel split edges (+ padding skip)
        obligation(hl, rev, "conv2d", "single-tile", 24,
            ObKind::Conv { c: 1, h: 2, w: 2, o: 2, kh: 1, kw: 1,
                stride: unit, pad: nopad, cap: usize::MAX }),
        obligation(hl, rev, "conv2d", "exact-channel-split", 24,
            ObKind::Conv { c: 1, h: 2, w: 2, o: 4, kh: 1, kw: 1,
                stride: unit, pad: nopad, cap: 2 }),
        obligation(hl, rev, "conv2d", "uneven-channel-split", 24,
            ObKind::Conv { c: 2, h: 1, w: 1, o: 3, kh: 1, kw: 1,
                stride: unit, pad: nopad, cap: 2 }),
        obligation(hl, rev, "conv2d", "padded-tail", 24,
            ObKind::Conv { c: 1, h: 1, w: 2, o: 1, kh: 1, kw: 2,
                stride: unit, pad: (0, 1), cap: usize::MAX }),
        // VTA chunked saturating add
        obligation(vt, rev, "vta_add", "single-chunk", 16,
            ObKind::VtaAdd { len: 4, cap: usize::MAX }),
        obligation(vt, rev, "vta_add", "exact-chunks", 16,
            ObKind::VtaAdd { len: 6, cap: 3 }),
        obligation(vt, rev, "vta_add", "chunk-tail", 16,
            ObKind::VtaAdd { len: 7, cap: 3 }),
    ]
}

/// Obligations for both design revisions.
pub fn all_obligations_both_revs() -> Vec<Obligation> {
    let mut v = all_obligations(DesignRev::Original);
    v.extend(all_obligations(DesignRev::Updated));
    v
}

/// Check one obligation within `timeout`. Structural failures (the
/// lowering bailing out, the symbolic executor rejecting the program,
/// shape or decode-metadata disagreement) surface as
/// [`ObligationStatus::Mismatch`]; everything that reaches the solver
/// reports its statistics.
pub fn check(ob: &Obligation, timeout: Duration) -> ObligationReport {
    match run(ob, timeout) {
        Ok(report) => report,
        Err(msg) => ObligationReport {
            ob: ob.clone(),
            status: ObligationStatus::Mismatch(msg),
            stats: None,
        },
    }
}

fn run(ob: &Obligation, timeout: Duration) -> Result<ObligationReport, String> {
    match ob.kind {
        ObKind::Linear { n, k, m, cap } => run_linear(ob, n, k, m, cap, timeout),
        ObKind::Lstm { t, e, h, cap } => run_lstm(ob, t, e, h, cap, timeout),
        ObKind::Conv { c, h, w, o, kh, kw, stride, pad, cap } => {
            run_conv(ob, (c, h, w), o, (kh, kw), stride, pad, cap, timeout)
        }
        ObKind::VtaAdd { len, cap } => run_vta_add(ob, len, cap, timeout),
    }
}

fn finish(
    ob: &Obligation,
    hw: SymPart,
    reference: SymGrid,
    ref_meta: ReadMeta,
    timeout: Duration,
) -> Result<ObligationReport, String> {
    if hw.grid.shape != reference.shape {
        return Err(format!(
            "result shape mismatch: hardware {:?} vs reference {:?}",
            hw.grid.shape, reference.shape
        ));
    }
    if hw.meta != ref_meta {
        return Err(format!(
            "decode metadata mismatch: hardware {:?} vs reference {:?}",
            hw.meta, ref_meta
        ));
    }
    let pairs: Vec<(Rc<BvTerm>, Rc<BvTerm>)> = hw
        .grid
        .terms
        .iter()
        .cloned()
        .zip(reference.terms.iter().cloned())
        .collect();
    let outcome = discharge_pairs(ob.width, &pairs, timeout);
    let status = match &outcome.result {
        EquivResult::Equivalent => ObligationStatus::Equivalent,
        EquivResult::Timeout => ObligationStatus::Timeout,
        EquivResult::Counterexample(model) => ObligationStatus::Inequivalent(Box::new(
            build_cex(ob, &hw.grid, &reference, model),
        )),
    };
    Ok(ObligationReport { ob: ob.clone(), status, stats: Some(outcome) })
}

/// Bind a slot-symbolic template with marker operands under the slot
/// discipline the obligations rely on: each late-bound burst must stage
/// exactly its [`crate::codegen::OperandSlot`]'s payload slice, and
/// every element code in it must resolve to a registered marker
/// variable. That is what makes the check a proof *over the template*
/// rather than over one concrete lowering — slot payloads reach the
/// shadow device as free symbolic operand bytes, so the verdict covers
/// every input the template can ever be bound with, while a concrete
/// operand byte leaking into a late-bound payload (a template that
/// secretly specialized on the marker inputs) fails structurally before
/// any solving.
fn bind_slot_symbolic(
    tmpl: &ProgramTemplate,
    operands: &[&Tensor],
    markers: &MarkerMap,
) -> Result<LoweredProgram, String> {
    let bound = tmpl
        .bind(operands)
        .map_err(|e| format!("template bind rejected marker operands: {e}"))?;
    let prog = bound.program;
    for (ii, bi, slot) in tmpl.slots() {
        let burst = prog
            .invocations
            .get(ii)
            .and_then(|inv| inv.bursts.get(bi))
            .ok_or_else(|| format!("slot ({ii},{bi}) missing from the bound program"))?;
        let payload: Vec<u8> = burst
            .cmds
            .iter()
            .filter(|c| c.is_write)
            .flat_map(|c| c.payload().iter().copied())
            .collect();
        if payload.len() != slot.bytes.len() {
            return Err(format!(
                "slot ({ii},{bi}) staged {} bytes, expected {}",
                payload.len(),
                slot.bytes.len()
            ));
        }
        let width = slot.codec.elem_bytes();
        for (ei, chunk) in payload.chunks(width).enumerate() {
            let mut code = 0u64;
            for (j, &byte) in chunk.iter().enumerate() {
                code |= (byte as u64) << (8 * j);
            }
            if !markers.contains_key(&(width, code)) {
                return Err(format!(
                    "slot ({ii},{bi}) element {ei} staged code {code:#x} that is \
                     not a registered marker — a concrete operand byte leaked \
                     into a late-bound payload"
                ));
            }
        }
    }
    Ok(prog)
}

fn run_linear(
    ob: &Obligation,
    n: usize,
    k: usize,
    m: usize,
    cap: usize,
    timeout: Duration,
) -> Result<ObligationReport, String> {
    let dev = flex_dev(ob.rev);
    let mut markers = MarkerMap::new();
    let mut pool = Af8MarkerPool::new(dev.af);
    let x = pool.tensor(&[n, k], "x", &mut markers)?;
    let w = pool.tensor(&[m, k], "w", &mut markers)?;
    let b = pool.tensor(&[m], "b", &mut markers)?;
    let tmpl = dev
        .lower_linear_template_for_verify(&x, &w, &b, cap)
        .ok_or_else(|| "tiled linear lowering declined the shape".to_string())?;
    let prog = bind_slot_symbolic(&tmpl, &[&x, &w, &b], &markers)?;
    let mut uf = UfTable::new();
    let hw = sym_execute_program(&prog, &DeviceModel::FlexAsr, &markers, &mut uf)?;
    let (_, xb) = fx::encode_tensor(&dev.af, &x);
    let (_, wb) = fx::encode_tensor(&dev.af, &w);
    let (_, bb) = fx::encode_tensor(&dev.af, &b);
    let out_bias = dev.linear_forced_bias(&x, &w, &b);
    let reference = ref_linear(
        &mut uf,
        &svar_grid("x", n * k, 8),
        &svar_grid("w", m * k, 8),
        &svar_grid("b", m, 8),
        (n, k, m),
        (xb, wb, bb),
        out_bias,
    );
    let ref_meta = ReadMeta::Flex {
        bias: out_bias,
        bits: dev.af.bits,
        exp_bits: dev.af.exp_bits,
    };
    finish(ob, hw, reference, ref_meta, timeout)
}

fn run_lstm(
    ob: &Obligation,
    t: usize,
    e: usize,
    h: usize,
    cap: usize,
    timeout: Duration,
) -> Result<ObligationReport, String> {
    let dev = flex_dev(ob.rev);
    let four_h = 4 * h;
    let mut markers = MarkerMap::new();
    let mut pool = Af8MarkerPool::new(dev.af);
    let x = pool.tensor(&[t, 1, e], "x", &mut markers)?;
    let wi = pool.tensor(&[four_h, e], "wi", &mut markers)?;
    let wh = pool.tensor(&[four_h, h], "wh", &mut markers)?;
    let b = pool.tensor(&[four_h], "b", &mut markers)?;
    let tmpl = dev
        .lower_lstm_template_for_verify(&x, &wi, &wh, &b, cap)
        .ok_or_else(|| "tiled LSTM lowering declined the shape".to_string())?;
    let prog = bind_slot_symbolic(&tmpl, &[&x, &wi, &wh, &b], &markers)?;
    let mut uf = UfTable::new();
    let hw = sym_execute_program(&prog, &DeviceModel::FlexAsr, &markers, &mut uf)?;
    let (_, xb) = fx::encode_tensor(&dev.af, &x);
    let (_, wib) = fx::encode_tensor(&dev.af, &wi);
    let (_, whb) = fx::encode_tensor(&dev.af, &wh);
    let (_, bb) = fx::encode_tensor(&dev.af, &b);
    // independent recomputation of the per-step bias schedule the
    // driver must have programmed
    let (_, traced) = dev.lstm_traced(&x, &wi, &wh, &b);
    let sched = RefLstmSchedule {
        wide: traced.wide.clone(),
        h: traced.h.clone(),
        c: traced.c.clone(),
        out: traced.out,
    };
    let reference = ref_lstm(
        &mut uf,
        &svar_grid("x", t * e, 8),
        &svar_grid("wi", four_h * e, 8),
        &svar_grid("wh", four_h * h, 8),
        &svar_grid("b", four_h, 8),
        (t, e, h),
        (xb, wib, bb, whb),
        &sched,
    );
    let ref_meta = ReadMeta::Flex {
        bias: sched.out,
        bits: dev.af.bits,
        exp_bits: dev.af.exp_bits,
    };
    finish(ob, hw, reference, ref_meta, timeout)
}

#[allow(clippy::too_many_arguments)]
fn run_conv(
    ob: &Obligation,
    (c, h, w): (usize, usize, usize),
    o: usize,
    (kh, kw): (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    cap: usize,
    timeout: Duration,
) -> Result<ObligationReport, String> {
    let cfg = hlscnn_cfg(ob.rev);
    let dev = Hlscnn::new(cfg);
    let mut markers = MarkerMap::new();
    let x = hlscnn_act_markers(cfg.act_fmt, &[1, c, h, w], &mut markers)?;
    let wt = hlscnn_wgt_markers(&[o, c, kh, kw], c * h * w + 1, &mut markers)?;
    let tmpl = dev
        .lower_conv2d_template(&x, &wt, stride, pad, cap)
        .ok_or_else(|| "tiled conv2d lowering declined the shape".to_string())?;
    let prog = bind_slot_symbolic(&tmpl, &[&x, &wt], &markers)?;
    let mut uf = UfTable::new();
    let hw = sym_execute_program(&prog, &DeviceModel::Hlscnn(cfg), &markers, &mut uf)?;
    let reference = ref_conv2d(
        &svar_grid("a", c * h * w, 6),
        &svar_grid("w", o * c * kh * kw, 12),
        (c, h, w),
        o,
        (kh, kw),
        stride,
        pad,
        cfg,
    );
    let ref_meta = ReadMeta::Hlscnn {
        bits: cfg.act_fmt.bits,
        frac: cfg.act_fmt.frac_bits,
    };
    finish(ob, hw, reference, ref_meta, timeout)
}

fn run_vta_add(
    ob: &Obligation,
    len: usize,
    cap: usize,
    timeout: Duration,
) -> Result<ObligationReport, String> {
    let dev = Vta::new();
    let mut markers = MarkerMap::new();
    let (a, b, scale) = vta_add_markers(len, &mut markers)?;
    let tmpl = dev
        .lower_add_template(&a, &b, cap)
        .ok_or_else(|| "chunked vta_add lowering declined the shape".to_string())?;
    let prog = bind_slot_symbolic(&tmpl, &[&a, &b], &markers)?;
    let mut uf = UfTable::new();
    let hw = sym_execute_program(&prog, &DeviceModel::Vta, &markers, &mut uf)?;
    let reference = ref_vta_add(&svar_grid("a", len, 7), &svar_grid("b", len, 7), &[len]);
    let ref_meta = ReadMeta::Vta { scale };
    finish(ob, hw, reference, ref_meta, timeout)
}

// ---------------------------------------------------------------------
// Counterexample extraction
// ---------------------------------------------------------------------

fn sext(v: u64, width: u32) -> i64 {
    if width >= 64 {
        return v as i64;
    }
    let m = 1u64 << (width - 1);
    ((v & ((1u64 << width) - 1)) ^ m).wrapping_sub(m) as i64
}

/// Round-to-nearest-even shift-down on a two's-complement value — the
/// software weight-quantization arithmetic, used to localize which
/// weight cast diverges in a counterexample.
fn rte_i64(v: i64, s: u32) -> i64 {
    if s == 0 {
        return v;
    }
    let q = v >> s;
    let r = v & ((1i64 << s) - 1);
    let half = 1i64 << (s - 1);
    q + ((r > half || (r == half && (q & 1) == 1)) as i64)
}

fn build_cex(
    ob: &Obligation,
    hw: &SymGrid,
    reference: &SymGrid,
    model: &HashMap<String, u64>,
) -> LoweringCex {
    let (mut index, mut hw_code, mut ref_code) = (0usize, 0i64, 0i64);
    for i in 0..hw.terms.len() {
        let a = hw.terms[i].eval(model, ob.width);
        let r = reference.terms[i].eval(model, ob.width);
        if a != r {
            index = i;
            hw_code = sext(a, ob.width);
            ref_code = sext(r, ob.width);
            break;
        }
    }
    let mut inputs: Vec<(String, i64)> = model
        .iter()
        .filter(|(name, _)| !name.starts_with("uf"))
        .map(|(name, v)| (name.clone(), *v as i64))
        .collect();
    inputs.sort();
    LoweringCex {
        index,
        hw_code,
        ref_code,
        inputs,
        note: cex_note(ob, model),
    }
}

/// Localize the divergence for conv counterexamples: find the weight
/// whose hardware wire→store cast (arithmetic shift) disagrees with the
/// software round-to-nearest-even quantization under the model values.
fn cex_note(ob: &Obligation, model: &HashMap<String, u64>) -> String {
    let ObKind::Conv { .. } = ob.kind else {
        return String::new();
    };
    let store = hlscnn_cfg(ob.rev).weight_fmt;
    let shift = hx::wire_wgt_fmt().frac_bits.saturating_sub(store.frac_bits);
    let hi = (1i64 << (store.bits - 1)) - 1;
    let lo = -(1i64 << (store.bits - 1));
    let mut weights: Vec<(usize, i64)> = model
        .iter()
        .filter_map(|(name, v)| {
            name.strip_prefix('w')
                .and_then(|idx| idx.parse::<usize>().ok())
                .map(|idx| (idx, *v as i64))
        })
        .collect();
    weights.sort();
    for (idx, wire) in weights {
        let truncated = (wire >> shift).clamp(lo, hi);
        let rounded = rte_i64(wire, shift).clamp(lo, hi);
        if truncated != rounded {
            return format!(
                "weight w{idx}: wire code {wire} stores as {truncated} through the \
                 hardware wire_to_store arithmetic shift (>> {shift}), but as \
                 {rounded} under the software round-to-nearest-even quantization \
                 — the truncating weight-cast flaw"
            );
        }
    }
    "no single weight cast differs under this model; divergence arises downstream".to_string()
}

/// Reconstruct the concrete input tensors of a conv counterexample so
/// it can be replayed through the real lowering + simulator: NCHW
/// activations from the `a{i}` assignment (fixed-point codes) and OIHW
/// weights from the `w{i}` assignment (Q16.12 wire codes). Both
/// reconstructions are exact — every code is representable, so the
/// encode on replay reproduces the model's codes bit-for-bit.
pub fn conv_witness_tensors(
    ob: &Obligation,
    cex: &LoweringCex,
) -> Option<(Tensor, Tensor)> {
    let ObKind::Conv { c, h, w, o, kh, kw, .. } = ob.kind else {
        return None;
    };
    let cfg = hlscnn_cfg(ob.rev);
    let wire = hx::wire_wgt_fmt();
    let lookup = |name: String| -> i64 {
        cex.inputs
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    };
    let act = Tensor::from_fn(&[1, c, h, w], |i| cfg.act_fmt.decode(lookup(format!("a{i}"))));
    let wgt = Tensor::from_fn(&[o, c, kh, kw], |i| wire.decode(lookup(format!("w{i}"))));
    Some((act, wgt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Accelerator;
    use crate::codegen::execute_program;
    use crate::ila::sim::IlaSim;

    const T: Duration = Duration::from_secs(120);

    /// Obligation ids are unique and the sweep exercises every lowerable
    /// op head on both revisions.
    #[test]
    fn obligation_sweep_covers_all_op_heads() {
        let obs = all_obligations_both_revs();
        let ids: std::collections::HashSet<_> = obs.iter().map(|o| o.id.clone()).collect();
        assert_eq!(ids.len(), obs.len(), "duplicate obligation ids");
        for op in ["linear", "lstm", "conv2d", "vta_add"] {
            for rev in [DesignRev::Original, DesignRev::Updated] {
                assert!(
                    obs.iter().any(|o| o.op == op && o.rev == rev),
                    "no {op} obligation for {rev:?}"
                );
            }
        }
    }

    /// A *tiled* Original-rev conv counterexample replays through the
    /// capped lowering (a genuine multi-invocation program) on the
    /// concrete simulator and diverges from the functional path at the
    /// reported element — the crate-internal complement to the
    /// single-tile replay in `tests/lowering_obligations.rs`.
    #[test]
    fn tiled_conv_counterexample_replays_through_capped_lowering() {
        let ob = all_obligations(DesignRev::Original)
            .into_iter()
            .find(|ob| {
                ob.op == "conv2d"
                    && matches!(ob.kind, ObKind::Conv { cap, o, .. } if cap < o)
            })
            .expect("a channel-split conv obligation exists");
        let rep = check(&ob, T);
        let ObligationStatus::Inequivalent(cex) = &rep.status else {
            panic!("expected a counterexample, got {}", rep.status.label());
        };
        let (act, wgt) =
            conv_witness_tensors(&ob, cex).expect("conv witness tensors");
        let ObKind::Conv { stride, pad, cap, .. } = ob.kind else { unreachable!() };

        let dev = Hlscnn::new(hlscnn_cfg(ob.rev));
        let prog = dev
            .lower_conv2d_capped(&act, &wgt, stride, pad, cap)
            .expect("witness shape lowers");
        assert!(
            prog.invocations.len() > 1,
            "the capped obligation must produce a multi-tile program"
        );
        let mut sim = IlaSim::new(dev.build_ila());
        let device = execute_program(&prog, &mut sim).expect("witness replays");
        let functional = dev.conv2d(&act, &wgt, stride, pad);
        assert_eq!(device.shape, functional.shape);
        assert_ne!(
            device.data[cex.index], functional.data[cex.index],
            "witness must diverge at element {}",
            cex.index
        );
    }

    /// The VTA chunk-tail obligation goes through the miter fast (both
    /// sides reduce to structurally identical terms) and is equivalent.
    #[test]
    fn vta_chunk_tail_equivalent() {
        let ob = all_obligations(DesignRev::Updated)
            .into_iter()
            .find(|ob| ob.op == "vta_add" && ob.edge == "chunk-tail")
            .expect("vta chunk-tail obligation exists");
        let rep = check(&ob, T);
        assert!(matches!(rep.status, ObligationStatus::Equivalent), "{:?}", rep.status);
    }
}
