//! # d2a — Application-Level Validation of Accelerator Designs Using a
//! # Formal Software/Hardware Interface
//!
//! Rust + JAX + Pallas reproduction of the D2A/3LA system: an ILA-based
//! compiler flow (equality-saturation instruction selection over a pure
//! tensor IR), bit-accurate accelerator models with custom numerics, and
//! compilation-results validation at the operation level (simulation +
//! formal) and at the application level (co-simulation).
//!
//! The public entry point is the [`session`] module: build a [`Session`]
//! with [`SessionBuilder`], compile applications into [`CompiledProgram`]
//! handles, and run/co-simulate/sweep through them on a per-session
//! [`session::ExecBackend`] (tensor fast path, MMIO-level ILA
//! simulation, or bit-exact cross-check of both — the fidelity ladder).
//! The free functions in [`compiler`] and [`cosim`] remain as the
//! low-level core.
//!
//! See `docs/ARCHITECTURE.md` for the layer map, the fidelity ladder,
//! and where driver-side tiling and persistent execution engines sit.

#![warn(missing_docs)]

pub mod accel;
pub mod apps;
pub mod cli;
pub mod codegen;
pub mod compiler;
pub mod cosim;
pub mod cost;
pub mod egraph;
pub mod ila;
pub mod ir;
pub mod numerics;
pub mod rewrites;
pub mod rtl;
pub mod runtime;
pub mod session;
pub mod smt;
pub mod soc;
pub mod tensor;
pub mod util;
pub mod verify;

pub use session::{Bindings, CompiledProgram, Session, SessionBuilder};
