//! Compilation-results validation by simulation (§4.4): operation-level
//! checking of IR-accelerator mappings (Table 2) and application-level
//! co-simulation (Table 4).
//!
//! The co-sim driver evaluates a *compiled* program (accelerator ops
//! present after flexible matching) through the f32 interpreter with an
//! [`AccelHook`] that reroutes every accelerator node to the bit-accurate
//! ILA fast path — so host regions run IR semantics and offloaded regions
//! run the accelerator's exact custom numerics, just like the ILAng-based
//! co-simulation in the paper.
//!
//! Dispatch goes through the session-layer
//! [`AcceleratorRegistry`](crate::session::AcceleratorRegistry): each
//! intercepted node costs one O(1) table read instead of the seed-era
//! linear scan over all accelerator models. Prefer driving co-simulation
//! through [`crate::session::CompiledProgram::cosim`], which adds a
//! precomputed per-node dispatch plan on top.

pub mod stats;
pub mod table2;

use crate::ir::interp::{eval_with_hook, EvalError, EvalHook};
use crate::ir::{Node, RecExpr};
use crate::session::AcceleratorRegistry;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// Evaluation hook that dispatches accelerator ops to ILA models through
/// a target-indexed registry and records per-invocation error statistics
/// against the f32 semantics.
pub struct AccelHook<'a> {
    registry: &'a AcceleratorRegistry,
    /// number of accelerator invocations executed
    pub invocations: usize,
    /// per-invocation relative error vs the f32 op semantics (the
    /// debugging statistics of §4.4.2)
    pub inv_errors: Vec<f32>,
    /// record per-invocation errors (costs an extra f32 evaluation)
    pub track_errors: bool,
}

impl<'a> AccelHook<'a> {
    pub fn new(registry: &'a AcceleratorRegistry) -> Self {
        AccelHook {
            registry,
            invocations: 0,
            inv_errors: Vec::new(),
            track_errors: false,
        }
    }
}

impl EvalHook for AccelHook<'_> {
    fn intercept(&mut self, node: &Node, ch: &[&Tensor]) -> Option<Tensor> {
        let accel = self.registry.for_op(&node.op)?;
        let out = accel.exec_op(&node.op, ch)?;
        if node.op.is_accel_invocation() {
            self.invocations += 1;
            if self.track_errors {
                if let Ok(reference) = crate::ir::interp::eval_op(&node.op, ch) {
                    self.inv_errors.push(out.rel_error(&reference));
                }
            }
        }
        Some(out)
    }
}

/// Evaluate a compiled program with accelerator numerics.
pub fn run_accelerated(
    expr: &RecExpr,
    env: &HashMap<String, Tensor>,
    registry: &AcceleratorRegistry,
) -> Result<(Tensor, usize), EvalError> {
    let mut hook = AccelHook::new(registry);
    let out = eval_with_hook(expr, env, &mut hook)?;
    Ok((out, hook.invocations))
}

/// Language-model co-simulation: per-token perplexity over `n_sentences`
/// consecutive (SEQ_LEN+1)-token windows, reference vs accelerated.
pub fn cosim_lm(
    expr: &RecExpr,
    weights: &HashMap<String, Tensor>,
    embed: &Tensor,
    tokens: &[usize],
    n_sentences: usize,
    registry: &AcceleratorRegistry,
) -> Result<LmReport, EvalError> {
    let seq_len = 16usize;
    let e = embed.shape[1];
    let mut env = weights.clone();
    let mut nll_ref = 0.0f64;
    let mut nll_acc = 0.0f64;
    let mut count = 0usize;
    for s in 0..n_sentences {
        let w = &tokens[s * (seq_len + 1)..(s + 1) * (seq_len + 1)];
        // embedding lookup on the host (as in the paper's runtime)
        let mut x = vec![0.0f32; seq_len * e];
        for (t, &tok) in w[..seq_len].iter().enumerate() {
            x[t * e..(t + 1) * e]
                .copy_from_slice(&embed.data[tok * e..(tok + 1) * e]);
        }
        env.insert("x_seq".to_string(), Tensor::new(vec![seq_len, 1, e], x));
        let logits_ref = crate::ir::interp::eval(expr, &env)?;
        let (logits_acc, _) = run_accelerated(expr, &env, registry)?;
        for t in 0..seq_len {
            let target = w[t + 1];
            nll_ref += -log_softmax_at(&logits_ref, t, target) as f64;
            nll_acc += -log_softmax_at(&logits_acc, t, target) as f64;
            count += 1;
        }
    }
    Ok(LmReport {
        sentences: n_sentences,
        ref_perplexity: (nll_ref / count as f64).exp() as f32,
        acc_perplexity: (nll_acc / count as f64).exp() as f32,
    })
}

/// Result of a language-model co-simulation.
#[derive(Debug, Clone)]
pub struct LmReport {
    pub sentences: usize,
    pub ref_perplexity: f32,
    pub acc_perplexity: f32,
}

fn log_softmax_at(logits: &Tensor, row: usize, idx: usize) -> f32 {
    let c = *logits.shape.last().unwrap();
    let r = &logits.data[row * c..(row + 1) * c];
    let m = r.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = m + r.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
    r[idx] - lse
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{GraphBuilder, Op};
    use crate::session::DesignRev;
    use crate::util::Rng;

    fn registry() -> AcceleratorRegistry {
        AcceleratorRegistry::for_rev(DesignRev::Updated)
    }

    #[test]
    fn hook_reroutes_accel_ops_and_counts() {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        let b = g.weight("b");
        let lin = g.expr.add(Op::FlexLinear, vec![x, w, b]);
        let _ = g.expr.add(Op::Relu, vec![lin]);
        let expr = g.finish();
        let mut rng = Rng::new(1);
        let env: HashMap<String, Tensor> = [
            ("x".to_string(), Tensor::randn(&[2, 8], &mut rng, 1.0)),
            ("w".to_string(), Tensor::randn(&[4, 8], &mut rng, 0.3)),
            ("b".to_string(), Tensor::randn(&[4], &mut rng, 0.1)),
        ]
        .into_iter()
        .collect();
        let reg = registry();
        let (out, inv) = run_accelerated(&expr, &env, &reg).unwrap();
        assert_eq!(inv, 1);
        // accelerated result differs from f32 (AdaptivFloat) but not by much
        let reference = crate::ir::interp::eval(&expr, &env).unwrap();
        let e = out.rel_error(&reference);
        assert!(e > 0.0 && e < 0.1, "e={e}");
    }

    #[test]
    fn hook_and_plan_paths_agree() {
        // the AccelHook path and the session's plan-driven path must
        // produce identical tensors (same models, same dispatch)
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        let b = g.weight("b");
        let lin = g.expr.add(Op::FlexLinear, vec![x, w, b]);
        let _ = g.expr.add(Op::Relu, vec![lin]);
        let expr = g.finish();
        let mut rng = Rng::new(2);
        let env: HashMap<String, Tensor> = [
            ("x".to_string(), Tensor::randn(&[2, 8], &mut rng, 1.0)),
            ("w".to_string(), Tensor::randn(&[4, 8], &mut rng, 0.3)),
            ("b".to_string(), Tensor::randn(&[4], &mut rng, 0.1)),
        ]
        .into_iter()
        .collect();
        let (hook_out, _) = run_accelerated(&expr, &env, &registry()).unwrap();
        let session = crate::session::Session::builder().build();
        let program = session.attach(expr);
        let plan_out =
            program.run(&crate::session::Bindings::from_env(env)).unwrap();
        assert_eq!(hook_out, plan_out);
    }

    #[test]
    fn lm_log_softmax_sane() {
        let t = Tensor::new(vec![1, 3], vec![0.0, 0.0, 0.0]);
        let l = log_softmax_at(&t, 0, 1);
        assert!((l - (1.0f32 / 3.0).ln()).abs() < 1e-5);
    }
}
