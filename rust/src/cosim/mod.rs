//! Compilation-results validation by simulation (§4.4): operation-level
//! checking of IR-accelerator mappings (Table 2) and application-level
//! co-simulation (Table 4).
//!
//! The co-sim driver evaluates a *compiled* program (accelerator ops
//! present after flexible matching) through the f32 interpreter with an
//! [`AccelHook`] that reroutes every accelerator node to the bit-accurate
//! ILA fast path — so host regions run IR semantics and offloaded regions
//! run the accelerator's exact custom numerics, just like the ILAng-based
//! co-simulation in the paper.
//!
//! Dispatch goes through the session-layer execution engine
//! ([`crate::session::ExecEngine`]): each intercepted node costs one
//! O(1) registry read, and the engine routes it to the tensor fast path,
//! the MMIO/ILA simulators, or both, per the selected
//! [`ExecBackend`](crate::session::ExecBackend). Prefer driving
//! co-simulation through [`crate::session::CompiledProgram::cosim`],
//! which adds a precomputed per-node dispatch plan on top.

pub mod stats;
pub mod table2;

use crate::cost::{CycleBreakdown, OpCycles};
use crate::ir::interp::{eval_with_hook, EvalError, EvalHook};
use crate::ir::{Node, RecExpr};
use crate::session::{AcceleratorRegistry, ExecBackend, ExecEngine, FidelityReport};
use crate::tensor::Tensor;
use std::collections::HashMap;

/// Evaluation hook that dispatches accelerator ops through a
/// backend-selectable [`ExecEngine`] and records per-invocation error
/// statistics against the f32 semantics.
pub struct AccelHook<'a> {
    engine: ExecEngine<'a>,
    /// number of accelerator invocations executed
    pub invocations: usize,
    /// per-invocation relative error vs the f32 op semantics (the
    /// debugging statistics of §4.4.2)
    pub inv_errors: Vec<f32>,
    /// record per-invocation errors (costs an extra f32 evaluation)
    pub track_errors: bool,
}

impl<'a> AccelHook<'a> {
    /// Hook over the tensor fast path (the sweep default).
    pub fn new(registry: &'a AcceleratorRegistry) -> Self {
        Self::with_backend(registry, ExecBackend::Functional)
    }

    /// Hook over an explicit execution backend.
    pub fn with_backend(registry: &'a AcceleratorRegistry, backend: ExecBackend) -> Self {
        AccelHook {
            engine: ExecEngine::new(registry, backend),
            invocations: 0,
            inv_errors: Vec::new(),
            track_errors: false,
        }
    }

    /// Take the engine's accumulated cross-check report.
    pub fn take_fidelity(&mut self) -> FidelityReport {
        self.engine.take_fidelity()
    }
}

impl EvalHook for AccelHook<'_> {
    fn intercept(&mut self, node: &Node, ch: &[&Tensor]) -> Result<Option<Tensor>, EvalError> {
        let out = match self.engine.execute(&node.op, ch)? {
            Some(t) => t,
            None => return Ok(None),
        };
        if node.op.is_accel_invocation() {
            self.invocations += 1;
            if self.track_errors {
                if let Ok(reference) = crate::ir::interp::eval_op(&node.op, ch) {
                    self.inv_errors.push(out.rel_error(&reference));
                }
            }
        }
        Ok(Some(out))
    }
}

/// Evaluate a compiled program with accelerator numerics (tensor fast
/// path; build an [`AccelHook::with_backend`] for MMIO fidelity).
pub fn run_accelerated(
    expr: &RecExpr,
    env: &HashMap<String, Tensor>,
    registry: &AcceleratorRegistry,
) -> Result<(Tensor, usize), EvalError> {
    let mut hook = AccelHook::new(registry);
    let out = eval_with_hook(expr, env, &mut hook)?;
    Ok((out, hook.invocations))
}

/// Parameters of a language-model co-simulation sweep. The seed
/// hardcoded the input variable (`"x_seq"`) and the window length (16) —
/// the same hardcoding PR 1 removed from classification sweeps via
/// [`crate::session::SweepSpec`].
#[derive(Debug, Clone)]
pub struct LmSpec<'a> {
    /// Name of the per-window input variable the program reads.
    pub input_var: &'a str,
    /// Tokens per evaluation window (each window consumes `seq_len + 1`
    /// tokens: `seq_len` inputs plus the shifted targets).
    pub seq_len: usize,
    /// Record per-invocation relative errors (the §4.4.2 debugging
    /// statistics; costs an extra f32 evaluation per invocation).
    pub track_errors: bool,
}

impl Default for LmSpec<'_> {
    fn default() -> Self {
        LmSpec { input_var: "x_seq", seq_len: 16, track_errors: false }
    }
}

/// Language-model co-simulation: per-token perplexity over `n_sentences`
/// consecutive (seq_len+1)-token windows, reference vs accelerated, with
/// the default [`LmSpec`] (input `"x_seq"`, 16-token windows, no error
/// tracking). Kept for the seed callers; prefer [`cosim_lm_spec`].
pub fn cosim_lm(
    expr: &RecExpr,
    weights: &HashMap<String, Tensor>,
    embed: &Tensor,
    tokens: &[usize],
    n_sentences: usize,
    registry: &AcceleratorRegistry,
) -> Result<LmReport, EvalError> {
    cosim_lm_spec(expr, &LmSpec::default(), weights, embed, tokens, n_sentences, registry)
}

/// Language-model co-simulation under an explicit [`LmSpec`], on the
/// tensor fast path. See [`cosim_lm_backend`] for backend selection.
pub fn cosim_lm_spec(
    expr: &RecExpr,
    spec: &LmSpec<'_>,
    weights: &HashMap<String, Tensor>,
    embed: &Tensor,
    tokens: &[usize],
    n_sentences: usize,
    registry: &AcceleratorRegistry,
) -> Result<LmReport, EvalError> {
    cosim_lm_backend(
        expr,
        spec,
        weights,
        embed,
        tokens,
        n_sentences,
        registry,
        ExecBackend::Functional,
    )
}

/// Language-model co-simulation under an explicit [`LmSpec`] and
/// execution backend.
///
/// Malformed inputs (short token streams, out-of-vocabulary token ids,
/// non-matrix embedding tables) return [`EvalError::Input`] instead of
/// slice-panicking, and per-invocation error statistics are collected
/// when `spec.track_errors` is set instead of being silently dropped.
#[allow(clippy::too_many_arguments)]
pub fn cosim_lm_backend(
    expr: &RecExpr,
    spec: &LmSpec<'_>,
    weights: &HashMap<String, Tensor>,
    embed: &Tensor,
    tokens: &[usize],
    n_sentences: usize,
    registry: &AcceleratorRegistry,
    backend: ExecBackend,
) -> Result<LmReport, EvalError> {
    let mut engine = ExecEngine::new(registry, backend);
    cosim_lm_engine(expr, spec, weights, embed, tokens, n_sentences, &mut engine)
}

/// Hook that dispatches through a **borrowed** engine — the LM sweep
/// path for engines whose devices come from a shared
/// [`DevicePool`](crate::session::DevicePool) (the caller builds the
/// pooled engine; the sweep only borrows it).
struct EngineHook<'e, 'a> {
    engine: &'e mut ExecEngine<'a>,
    invocations: usize,
    inv_errors: Vec<f32>,
    track_errors: bool,
}

impl EvalHook for EngineHook<'_, '_> {
    fn intercept(&mut self, node: &Node, ch: &[&Tensor]) -> Result<Option<Tensor>, EvalError> {
        let out = match self.engine.execute(&node.op, ch)? {
            Some(t) => t,
            None => return Ok(None),
        };
        if node.op.is_accel_invocation() {
            self.invocations += 1;
            if self.track_errors {
                if let Ok(reference) = crate::ir::interp::eval_op(&node.op, ch) {
                    self.inv_errors.push(out.rel_error(&reference));
                }
            }
        }
        Ok(Some(out))
    }
}

/// Language-model co-simulation on a **caller-held engine** — the
/// engine's backend (and device source: private simulators or a shared
/// [`DevicePool`](crate::session::DevicePool)) decides how accelerator
/// ops execute. [`cosim_lm_backend`] wraps this with a throwaway
/// private-device engine. The report drains the fidelity accumulated in
/// the engine since it was last taken.
pub fn cosim_lm_engine(
    expr: &RecExpr,
    spec: &LmSpec<'_>,
    weights: &HashMap<String, Tensor>,
    embed: &Tensor,
    tokens: &[usize],
    n_sentences: usize,
    engine: &mut ExecEngine<'_>,
) -> Result<LmReport, EvalError> {
    let seq_len = spec.seq_len;
    if seq_len == 0 {
        return Err(EvalError::Input("LmSpec::seq_len must be >= 1".into()));
    }
    if embed.shape.len() != 2 {
        return Err(EvalError::Input(format!(
            "embedding table must be [vocab, dim], got {:?}",
            embed.shape
        )));
    }
    let needed = n_sentences * (seq_len + 1);
    if tokens.len() < needed {
        return Err(EvalError::Input(format!(
            "LM sweep needs {needed} tokens ({n_sentences} windows x {} tokens), got {}",
            seq_len + 1,
            tokens.len()
        )));
    }
    let (vocab, e) = (embed.shape[0], embed.shape[1]);
    let mut env = weights.clone();
    let timeline_before = engine.timeline().snapshot();
    let mut hook = EngineHook {
        engine,
        invocations: 0,
        inv_errors: Vec::new(),
        track_errors: spec.track_errors,
    };
    let mut nll_ref = 0.0f64;
    let mut nll_acc = 0.0f64;
    let mut count = 0usize;
    for s in 0..n_sentences {
        let w = &tokens[s * (seq_len + 1)..(s + 1) * (seq_len + 1)];
        if let Some(&bad) = w.iter().find(|&&tok| tok >= vocab) {
            return Err(EvalError::Input(format!(
                "token id {bad} out of vocabulary (size {vocab})"
            )));
        }
        // embedding lookup on the host (as in the paper's runtime)
        let mut x = vec![0.0f32; seq_len * e];
        for (t, &tok) in w[..seq_len].iter().enumerate() {
            x[t * e..(t + 1) * e]
                .copy_from_slice(&embed.data[tok * e..(tok + 1) * e]);
        }
        env.insert(
            spec.input_var.to_string(),
            Tensor::new(vec![seq_len, 1, e], x),
        );
        let logits_ref = crate::ir::interp::eval(expr, &env)?;
        let logits_acc = eval_with_hook(expr, &env, &mut hook)?;
        // targets index the *logits* rows/columns, whose geometry need
        // not match the embedding table — validate before indexing
        let width = *logits_ref.shape.last().unwrap_or(&0);
        if logits_ref.data.len() < seq_len * width.max(1) {
            return Err(EvalError::Input(format!(
                "program produced logits {:?}, need {seq_len} rows",
                logits_ref.shape
            )));
        }
        if let Some(&bad) = w[1..].iter().find(|&&tok| tok >= width) {
            return Err(EvalError::Input(format!(
                "target token {bad} out of logits width {width}"
            )));
        }
        for t in 0..seq_len {
            let target = w[t + 1];
            nll_ref += -log_softmax_at(&logits_ref, t, target) as f64;
            nll_acc += -log_softmax_at(&logits_acc, t, target) as f64;
            count += 1;
        }
    }
    let fidelity = hook.engine.take_fidelity();
    let (cycles, op_cycles) = hook.engine.timeline().since(&timeline_before);
    Ok(LmReport {
        sentences: n_sentences,
        ref_perplexity: (nll_ref / count.max(1) as f64).exp() as f32,
        acc_perplexity: (nll_acc / count.max(1) as f64).exp() as f32,
        invocations: hook.invocations,
        inv_errors: hook.inv_errors,
        fidelity,
        cycles,
        op_cycles,
    })
}

/// Result of a language-model co-simulation.
#[derive(Debug, Clone)]
pub struct LmReport {
    /// Evaluation windows processed.
    pub sentences: usize,
    /// Per-token perplexity of the f32 reference.
    pub ref_perplexity: f32,
    /// Per-token perplexity under accelerator numerics.
    pub acc_perplexity: f32,
    /// Accelerator invocations executed across the whole sweep.
    pub invocations: usize,
    /// Per-invocation relative errors (empty unless
    /// [`LmSpec::track_errors`] was set).
    pub inv_errors: Vec<f32>,
    /// Cross-check outcome (empty unless the sweep ran under
    /// [`ExecBackend::CrossCheck`]).
    pub fidelity: FidelityReport,
    /// Modeled device cycles spent across the sweep (transfer vs compute
    /// vs overhead); zero on the functional fast path.
    pub cycles: CycleBreakdown,
    /// Per-(target, op-head) modeled-cycle breakdowns for the sweep, in
    /// canonical (target, op) order.
    pub op_cycles: Vec<OpCycles>,
}

fn log_softmax_at(logits: &Tensor, row: usize, idx: usize) -> f32 {
    let c = *logits.shape.last().unwrap();
    let r = &logits.data[row * c..(row + 1) * c];
    let m = r.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = m + r.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
    r[idx] - lse
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{GraphBuilder, Op};
    use crate::session::DesignRev;
    use crate::util::Rng;

    fn registry() -> AcceleratorRegistry {
        AcceleratorRegistry::for_rev(DesignRev::Updated)
    }

    #[test]
    fn hook_reroutes_accel_ops_and_counts() {
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        let b = g.weight("b");
        let lin = g.expr.add(Op::FlexLinear, vec![x, w, b]);
        let _ = g.expr.add(Op::Relu, vec![lin]);
        let expr = g.finish();
        let mut rng = Rng::new(1);
        let env: HashMap<String, Tensor> = [
            ("x".to_string(), Tensor::randn(&[2, 8], &mut rng, 1.0)),
            ("w".to_string(), Tensor::randn(&[4, 8], &mut rng, 0.3)),
            ("b".to_string(), Tensor::randn(&[4], &mut rng, 0.1)),
        ]
        .into_iter()
        .collect();
        let reg = registry();
        let (out, inv) = run_accelerated(&expr, &env, &reg).unwrap();
        assert_eq!(inv, 1);
        // accelerated result differs from f32 (AdaptivFloat) but not by much
        let reference = crate::ir::interp::eval(&expr, &env).unwrap();
        let e = out.rel_error(&reference);
        assert!(e > 0.0 && e < 0.1, "e={e}");
    }

    #[test]
    fn hook_and_plan_paths_agree() {
        // the AccelHook path and the session's plan-driven path must
        // produce identical tensors (same models, same dispatch)
        let mut g = GraphBuilder::new();
        let x = g.var("x");
        let w = g.weight("w");
        let b = g.weight("b");
        let lin = g.expr.add(Op::FlexLinear, vec![x, w, b]);
        let _ = g.expr.add(Op::Relu, vec![lin]);
        let expr = g.finish();
        let mut rng = Rng::new(2);
        let env: HashMap<String, Tensor> = [
            ("x".to_string(), Tensor::randn(&[2, 8], &mut rng, 1.0)),
            ("w".to_string(), Tensor::randn(&[4, 8], &mut rng, 0.3)),
            ("b".to_string(), Tensor::randn(&[4], &mut rng, 0.1)),
        ]
        .into_iter()
        .collect();
        let (hook_out, _) = run_accelerated(&expr, &env, &registry()).unwrap();
        let session = crate::session::Session::builder().build();
        let program = session.attach(expr);
        let plan_out =
            program.run(&crate::session::Bindings::from_env(env)).unwrap();
        assert_eq!(hook_out, plan_out);
    }

    #[test]
    fn lm_log_softmax_sane() {
        let t = Tensor::new(vec![1, 3], vec![0.0, 0.0, 0.0]);
        let l = log_softmax_at(&t, 0, 1);
        assert!((l - (1.0f32 / 3.0).ln()).abs() < 1e-5);
    }

    /// A tiny LM program: x_seq-style input through one FlexLinear layer.
    fn tiny_lm(
        input_var: &str,
        seq_len: usize,
        e: usize,
        v: usize,
    ) -> (crate::ir::RecExpr, HashMap<String, Tensor>, Tensor) {
        let mut g = GraphBuilder::new();
        let x = g.var(input_var);
        let flat = g.reshape(x, &[seq_len, e]);
        let w = g.weight("w");
        let b = g.weight("b");
        g.expr.add(Op::FlexLinear, vec![flat, w, b]);
        let mut rng = Rng::new(12);
        let weights: HashMap<String, Tensor> = [
            ("w".to_string(), Tensor::randn(&[v, e], &mut rng, 0.3)),
            ("b".to_string(), Tensor::randn(&[v], &mut rng, 0.1)),
        ]
        .into_iter()
        .collect();
        let embed = Tensor::randn(&[v, e], &mut rng, 1.0);
        (g.finish(), weights, embed)
    }

    #[test]
    fn lm_spec_short_token_stream_errors_instead_of_panicking() {
        let (expr, weights, embed) = tiny_lm("x_seq", 4, 8, 16);
        let spec = LmSpec { input_var: "x_seq", seq_len: 4, track_errors: false };
        let tokens: Vec<usize> = (0..7).map(|i| i % 16).collect(); // needs 2*5=10
        let err = cosim_lm_spec(&expr, &spec, &weights, &embed, &tokens, 2, &registry())
            .unwrap_err();
        assert!(matches!(err, EvalError::Input(_)), "got {err:?}");
    }

    #[test]
    fn lm_spec_out_of_vocab_token_errors() {
        let (expr, weights, embed) = tiny_lm("x_seq", 4, 8, 16);
        let spec = LmSpec { input_var: "x_seq", seq_len: 4, track_errors: false };
        let tokens = vec![0, 1, 99, 2, 3]; // 99 >= vocab 16
        let err = cosim_lm_spec(&expr, &spec, &weights, &embed, &tokens, 1, &registry())
            .unwrap_err();
        assert!(matches!(err, EvalError::Input(_)), "got {err:?}");
    }

    #[test]
    fn lm_spec_custom_input_var_and_error_tracking() {
        let (seq_len, e, v) = (4usize, 8usize, 16usize);
        let (expr, weights, embed) = tiny_lm("tokens_embedded", seq_len, e, v);
        let spec = LmSpec {
            input_var: "tokens_embedded",
            seq_len,
            track_errors: true,
        };
        let tokens: Vec<usize> = (0..2 * (seq_len + 1)).map(|i| i % v).collect();
        let rep =
            cosim_lm_spec(&expr, &spec, &weights, &embed, &tokens, 2, &registry())
                .unwrap();
        assert_eq!(rep.sentences, 2);
        assert_eq!(rep.invocations, 2, "one FlexLinear per window");
        assert_eq!(rep.inv_errors.len(), 2, "track_errors threads through");
        assert!(rep.ref_perplexity.is_finite() && rep.acc_perplexity.is_finite());
        // without tracking, the stats stay empty but perplexities agree
        let plain = LmSpec { track_errors: false, ..spec };
        let rep2 =
            cosim_lm_spec(&expr, &plain, &weights, &embed, &tokens, 2, &registry())
                .unwrap();
        assert!(rep2.inv_errors.is_empty());
        assert_eq!(rep.acc_perplexity, rep2.acc_perplexity);
    }
}
