//! Error statistics for simulation-based validation (Table 2's metric).

/// Mean and standard deviation of a set of relative errors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorStats {
    /// Mean relative error.
    pub mean: f32,
    /// Standard deviation of the relative error.
    pub std_dev: f32,
    /// Sample count.
    pub n: usize,
}

impl ErrorStats {
    /// Compute from a sample of relative errors.
    pub fn from_samples(samples: &[f32]) -> ErrorStats {
        let n = samples.len();
        if n == 0 {
            return ErrorStats { mean: 0.0, std_dev: 0.0, n: 0 };
        }
        let mean = samples.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = samples
            .iter()
            .map(|&x| (x as f64 - mean).powi(2))
            .sum::<f64>()
            / n as f64;
        ErrorStats { mean: mean as f32, std_dev: var.sqrt() as f32, n }
    }

    /// Render as the paper's "x.xx%" format.
    pub fn pct(&self) -> (String, String) {
        (
            format!("{:.2}%", self.mean * 100.0),
            format!("{:.2}%", self.std_dev * 100.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = ErrorStats::from_samples(&[0.01, 0.03]);
        assert!((s.mean - 0.02).abs() < 1e-6);
        assert!((s.std_dev - 0.01).abs() < 1e-6);
        assert_eq!(s.pct().0, "2.00%");
    }

    #[test]
    fn empty_is_zero() {
        let s = ErrorStats::from_samples(&[]);
        assert_eq!(s.mean, 0.0);
        assert_eq!(s.n, 0);
    }
}
