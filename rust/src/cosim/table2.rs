//! Operation-level simulation-based validation of the IR-accelerator
//! mappings (Table 2): 100 random test inputs per mapping, accelerator
//! ILA simulation vs the IR interpreter on the closest standard datatype,
//! relative error by Frobenius norm.
//!
//! Protocol, as in §4.4.1: test inputs are generated **on the
//! accelerator's operand lattice** (the reference interpreter "uses 8-bit
//! integer ... when checking operations of VTA", i.e. both sides see the
//! same quantized operands); errors then isolate the *internal* custom
//! numerics — which is why VTA GEMM and FlexASR MaxPool validate at
//! exactly 0.00%.

use super::stats::ErrorStats;
use crate::accel::{Accelerator, FlexAsr, Hlscnn, HlscnnConfig, Vta};
use crate::ir::{interp, Op};
use crate::tensor::Tensor;
use crate::util::Rng;

/// One Table 2 row.
#[derive(Debug, Clone)]
pub struct MappingValidation {
    /// Accelerator name (Table 2 column 1).
    pub accelerator: &'static str,
    /// Operation name (Table 2 column 2).
    pub operation: &'static str,
    /// Relative-error statistics over the random test inputs.
    pub stats: ErrorStats,
}

/// Validate all eight mappings of Table 2 with `n` random inputs each.
pub fn validate_all(n: usize, seed: u64) -> Vec<MappingValidation> {
    let fa = FlexAsr::new();
    let hl = Hlscnn::new(HlscnnConfig::updated());
    let vta = Vta::new();
    let mut rng = Rng::new(seed);
    let mut rows = Vec::new();

    // Row 1: VTA GEMM — int8 lattice operands, exact
    rows.push(run_mapping("VTA", "GEMM", n, &mut rng, |rng| {
        let x = vta.quant(&Tensor::randn(&[8, 64], rng, 1.0));
        let w = vta.quant(&Tensor::randn(&[16, 64], rng, 1.0));
        let acc = vta.exec_op(&Op::VtaGemm, &[&x, &w]).unwrap();
        let reference = interp::eval_op(&Op::VtaGemm, &[&x, &w]).unwrap();
        acc.rel_error(&reference)
    }));

    // Row 2: HLSCNN Conv2D — fixed-point lattice operands
    rows.push(run_mapping("HLSCNN", "Conv2D", n, &mut rng, |rng| {
        let x = Tensor::randn(&[1, 8, 8, 8], rng, 1.0);
        let w = Tensor::randn(&[8, 8, 3, 3], rng, 0.2);
        let op = Op::HlscnnConv2d { stride: (1, 1), pad: (1, 1) };
        let acc = hl.exec_op(&op, &[&x, &w]).unwrap();
        let reference = interp::eval_op(&op, &[&x, &w]).unwrap();
        acc.rel_error(&reference)
    }));

    // Row 3: FlexASR LinearLayer
    rows.push(run_mapping("FlexASR", "LinearLayer", n, &mut rng, |rng| {
        let x = fa.quant(&Tensor::randn(&[16, 64], rng, 1.0));
        let w = fa.quant(&Tensor::randn(&[32, 64], rng, 0.2));
        let b = fa.quant(&Tensor::randn(&[32], rng, 0.1));
        let acc = fa.exec_op(&Op::FlexLinear, &[&x, &w, &b]).unwrap();
        let reference = interp::eval_op(&Op::FlexLinear, &[&x, &w, &b]).unwrap();
        acc.rel_error(&reference)
    }));

    // Row 4: FlexASR LSTM
    rows.push(run_mapping("FlexASR", "LSTM", n, &mut rng, |rng| {
        let op = Op::FlexLstm { steps: 8 };
        let x = fa.quant(&Tensor::randn(&[8, 1, 32], rng, 1.0));
        let wi = fa.quant(&Tensor::randn(&[128, 32], rng, 0.2));
        let wh = fa.quant(&Tensor::randn(&[128, 32], rng, 0.2));
        let b = fa.quant(&Tensor::randn(&[128], rng, 0.1));
        let acc = fa.exec_op(&op, &[&x, &wi, &wh, &b]).unwrap();
        let reference = interp::eval_op(&op, &[&x, &wi, &wh, &b]).unwrap();
        acc.rel_error(&reference)
    }));

    // Row 5: FlexASR LayerNorm
    rows.push(run_mapping("FlexASR", "LayerNorm", n, &mut rng, |rng| {
        let x = fa.quant(&Tensor::randn(&[16, 64], rng, 1.0));
        let acc = fa.exec_op(&Op::FlexLayerNorm, &[&x]).unwrap();
        let reference = interp::eval_op(&Op::FlexLayerNorm, &[&x]).unwrap();
        acc.rel_error(&reference)
    }));

    // Row 6: FlexASR MaxPool — exact on the lattice
    rows.push(run_mapping("FlexASR", "MaxPool", n, &mut rng, |rng| {
        let x = fa.quant(&Tensor::randn(&[16, 64], rng, 1.0));
        let acc = fa.exec_op(&Op::FlexMaxpool, &[&x]).unwrap();
        let reference = interp::eval_op(&Op::TempMaxPool, &[&x]).unwrap();
        acc.rel_error(&reference)
    }));

    // Row 7: FlexASR MeanPool
    rows.push(run_mapping("FlexASR", "MeanPool", n, &mut rng, |rng| {
        let x = fa.quant(&Tensor::randn(&[16, 64], rng, 1.0));
        let acc = fa.exec_op(&Op::FlexMeanpool, &[&x]).unwrap();
        let reference = interp::eval_op(&Op::TempMeanPool, &[&x]).unwrap();
        acc.rel_error(&reference)
    }));

    // Row 8: FlexASR Attention — the lossiest mapping
    rows.push(run_mapping("FlexASR", "Attention", n, &mut rng, |rng| {
        let q = fa.quant(&Tensor::randn(&[16, 32], rng, 1.0));
        let k = fa.quant(&Tensor::randn(&[16, 32], rng, 1.0));
        let v = fa.quant(&Tensor::randn(&[16, 32], rng, 1.0));
        let acc = fa.exec_op(&Op::FlexAttention, &[&q, &k, &v]).unwrap();
        let reference = interp::eval_op(&Op::FlexAttention, &[&q, &k, &v]).unwrap();
        acc.rel_error(&reference)
    }));

    rows
}

fn run_mapping(
    accelerator: &'static str,
    operation: &'static str,
    n: usize,
    rng: &mut Rng,
    mut f: impl FnMut(&mut Rng) -> f32,
) -> MappingValidation {
    let samples: Vec<f32> = (0..n).map(|_| f(rng)).collect();
    MappingValidation { accelerator, operation, stats: ErrorStats::from_samples(&samples) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shape_holds() {
        let rows = validate_all(20, 7);
        let get = |op: &str| {
            rows.iter().find(|r| r.operation == op).unwrap().stats.mean
        };
        // exact rows
        assert_eq!(get("GEMM"), 0.0, "VTA GEMM must be exact");
        assert_eq!(get("MaxPool"), 0.0, "FlexASR MaxPool must be exact");
        // lossy rows are nonzero
        for op in ["Conv2D", "LinearLayer", "LSTM", "LayerNorm", "MeanPool", "Attention"]
        {
            assert!(get(op) > 0.0, "{op} should show quantization error");
        }
        // attention is the worst FlexASR mapping (Table 2 ordering)
        assert!(get("Attention") > get("LinearLayer"));
        assert!(get("Attention") > get("MeanPool") * 0.5);
        // everything is small in absolute terms
        for r in &rows {
            assert!(r.stats.mean < 0.15, "{}: {}", r.operation, r.stats.mean);
        }
    }
}
