//! `d2a` — the leader binary: compile applications to accelerators,
//! validate mappings, run application-level co-simulation, verify the
//! maxpool mapping formally, and demo the SoC deployment.

use d2a::apps::table1::all_apps;
use d2a::cli::Cli;
use d2a::cost::{CycleBreakdown, OpCycles};
use d2a::egraph::RunnerLimits;
use d2a::ir::Target;
use d2a::rewrites::Matching;
use d2a::runtime::ArtifactStore;
use d2a::session::{DesignRev, ExecBackend, SessionBuilder, SweepSpec};
use std::time::Duration;

const HELP: &str = "\
d2a — Application-Level Validation of Accelerator Designs Using a Formal
Software/Hardware Interface (D2A/3LA reproduction)

USAGE: d2a <command> [flags]

COMMANDS:
  table1                 compilation statistics (exact vs flexible), 6 apps
  table2 [--inputs N]    simulation-based mapping validation (default 100)
  verify [--rows R --cols C --timeout SECS] [--all | --rev original|updated]
         [--target flexasr|hlscnn|vta] [--op linear|lstm|conv2d|vta_add]
                         BMC + CHC verification of the FlexASR MaxPool mapping,
                         then translation validation of every tiled lowering:
                         symbolic execution of the real MMIO programs mitered
                         against the op semantics (--all covers both design
                         revisions; the Original-rev HLSCNN conv obligation
                         prints a concrete wire_to_store counterexample)
  cosim  --app NAME [--rev original|updated] [--limit N] [--workers W]
         [--input-var NAME] [--backend functional|mmio|crosscheck]
                         application-level co-simulation (resmlp | resnet20 |
                         mobilenet | lstm); `mmio` runs every accelerator op
                         as MMIO programs on the ILA simulators, `crosscheck`
                         runs both paths and reports bit-level mismatches
                         (try --rev original --app resnet20 --backend
                         crosscheck to see the HLSCNN weight-store flaw);
                         mmio/crosscheck sweeps also report modeled device
                         cycles (transfer/compute/overhead per op family)
  soc-demo               run a D2A-lowered program on the emulated SoC
  help                   this text
";

fn main() -> anyhow::Result<()> {
    let cli = Cli::parse(std::env::args());
    match cli.command.as_str() {
        "table1" => cmd_table1(),
        "table2" => cmd_table2(cli.get_usize("inputs", 100)),
        "verify" => cmd_verify(&cli),
        "cosim" => cmd_cosim(&cli),
        "soc-demo" => cmd_soc_demo(),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

fn cmd_table1() -> anyhow::Result<()> {
    println!("Table 1 — static accelerator invocations (exact/flexible)");
    println!(
        "{:<14} {:>9} {:>12} {:>12} {:>12}",
        "app", "#ops", "FlexASR", "HLSCNN", "VTA"
    );
    for app in all_apps() {
        let mut cells = Vec::new();
        for target in [Target::FlexAsr, Target::Hlscnn, Target::Vta] {
            let mut counts = Vec::new();
            for mode in [Matching::Exact, Matching::Flexible] {
                let res = d2a::compiler::compile_app(&app, &[target], mode, limits());
                counts.push(res.invocations(target).to_string());
            }
            cells.push(counts.join("/"));
        }
        println!(
            "{:<14} {:>9} {:>12} {:>12} {:>12}",
            app.name,
            app.num_ops(),
            cells[0],
            cells[1],
            cells[2]
        );
    }
    Ok(())
}

fn limits() -> RunnerLimits {
    RunnerLimits { max_iters: 8, max_nodes: 150_000, time_limit: Duration::from_secs(30) }
}

fn cmd_table2(n: usize) -> anyhow::Result<()> {
    println!("Table 2 — simulation-based mapping validation ({n} inputs)");
    println!("{:<10} {:<12} {:>10} {:>10}", "accel", "operation", "avg err", "std dev");
    for row in d2a::cosim::table2::validate_all(n, 2022) {
        let (m, s) = row.stats.pct();
        println!("{:<10} {:<12} {:>10} {:>10}", row.accelerator, row.operation, m, s);
    }
    Ok(())
}

fn cmd_verify(cli: &Cli) -> anyhow::Result<()> {
    use d2a::verify as vf;
    let rows = cli.get_usize("rows", 4);
    let cols = cli.get_usize("cols", 32);
    let t = Duration::from_secs(cli.get_usize("timeout", 120) as u64);
    println!("FlexASR MaxPool mapping, {rows}x{cols}, timeout {}s", t.as_secs());
    let bmc = vf::verify_bmc(rows, cols, t);
    println!(
        "  BMC: {:?} in {:.2}s ({} vars, {} conflicts)",
        bmc.result,
        bmc.elapsed.as_secs_f64(),
        bmc.vars,
        bmc.conflicts
    );
    let chc = vf::verify_chc(rows, cols, t);
    println!(
        "  CHC: {:?} in {:.2}s ({} queries, {} conflicts)",
        chc.result,
        chc.elapsed.as_secs_f64(),
        chc.queries,
        chc.conflicts
    );

    let revs: Vec<DesignRev> = if cli.get("all").is_some() {
        vec![DesignRev::Original, DesignRev::Updated]
    } else {
        match cli.get("rev") {
            Some("original") => vec![DesignRev::Original],
            Some("updated") | None => vec![DesignRev::Updated],
            Some(other) => anyhow::bail!("unknown --rev `{other}`"),
        }
    };
    let target = cli.get("target");
    let op = cli.get("op");
    println!();
    println!("Lowering translation validation (tiled MMIO programs vs op semantics)");
    println!(
        "{:<36} {:>13} {:>13} {:>7} {:>10} {:>8}",
        "obligation", "status", "expected", "vars", "conflicts", "time"
    );
    let mut checked = 0usize;
    let mut failures = 0usize;
    for rev in revs {
        for ob in vf::all_obligations(rev) {
            if let Some(tf) = target {
                if !format!("{:?}", ob.target).eq_ignore_ascii_case(tf) {
                    continue;
                }
            }
            if let Some(of) = op {
                if ob.op != of {
                    continue;
                }
            }
            checked += 1;
            let rep = vf::check(&ob, t);
            let (vars, conflicts, secs) = rep
                .stats
                .as_ref()
                .map(|s| (s.vars, s.conflicts, s.elapsed.as_secs_f64()))
                .unwrap_or((0, 0, 0.0));
            let ok = rep.as_expected();
            println!(
                "{:<36} {:>13} {:>13} {:>7} {:>10} {:>7.2}s{}",
                ob.id,
                rep.status.label(),
                vf::expected_label(&ob),
                vars,
                conflicts,
                secs,
                if ok { "" } else { "  <-- UNEXPECTED" }
            );
            match &rep.status {
                vf::ObligationStatus::Inequivalent(cex) => print_cex(&ob, cex),
                vf::ObligationStatus::Mismatch(msg) => println!("      mismatch: {msg}"),
                _ => {}
            }
            if !ok {
                failures += 1;
            }
        }
    }
    if checked == 0 {
        anyhow::bail!("no obligation matches the given --target/--op/--rev filters");
    }
    if failures > 0 {
        anyhow::bail!("{failures} obligation(s) deviated from the expected verdict");
    }
    println!("all {checked} obligations matched their expected verdicts");
    Ok(())
}

fn print_cex(ob: &d2a::verify::Obligation, cex: &d2a::verify::LoweringCex) {
    println!(
        "      counterexample at flat output index {}: device code {} vs reference code {}",
        cex.index, cex.hw_code, cex.ref_code
    );
    if !cex.note.is_empty() {
        println!("      {}", cex.note);
    }
    let inputs: Vec<String> =
        cex.inputs.iter().map(|(n, v)| format!("{n}={v}")).collect();
    println!("      symbolic inputs: {}", inputs.join(" "));
    if let Some((act, wgt)) = d2a::verify::conv_witness_tensors(ob, cex) {
        println!("      witness activations {:?}: {:?}", act.shape, act.data);
        println!("      witness weights {:?}: {:?}", wgt.shape, wgt.data);
    }
}

fn cmd_cosim(cli: &Cli) -> anyhow::Result<()> {
    let store = ArtifactStore::open(None)?;
    let app_name = cli.get("app").unwrap_or("resmlp");
    let rev = match cli.get("rev") {
        Some("original") => DesignRev::Original,
        _ => DesignRev::Updated,
    };
    let backend = match cli.get("backend") {
        Some("mmio") | Some("ila-mmio") => ExecBackend::IlaMmio,
        Some("crosscheck") | Some("cross-check") => ExecBackend::CrossCheck,
        Some("functional") | None => ExecBackend::Functional,
        // a typo silently downgrading to Functional would make the
        // cross-check demo "pass" for the wrong reason — refuse instead
        Some(other) => anyhow::bail!(
            "unknown --backend `{other}` (expected functional | mmio | crosscheck)"
        ),
    };
    let limit = cli.get_usize("limit", 400);
    let workers = cli.get_usize("workers", 1);

    if app_name == "lstm" {
        let app = d2a::apps::cosim_models::lstm_wlm_lite();
        let session = SessionBuilder::new()
            .targets(&[Target::FlexAsr])
            .matching(Matching::Flexible)
            .limits(limits())
            .design_rev(rev)
            .backend(backend)
            .build();
        let program = session.compile(&app);
        let mut weights = store.weights("lstm")?;
        let embed = weights.remove("embed").expect("embed table");
        let tokens = store.test_tokens()?;
        let n_sent = limit.min(100);
        let rep = program.lm_sweep(&weights, &embed, &tokens, n_sent)?;
        println!(
            "LSTM-WLM ({n_sent} sentences, {backend} backend): \
             reference ppl {:.2}, accelerated ppl {:.2}",
            rep.ref_perplexity, rep.acc_perplexity
        );
        print_cycles(&rep.cycles, &rep.op_cycles, n_sent);
        if backend == ExecBackend::CrossCheck {
            print!("{}", rep.fidelity);
        }
        return Ok(());
    }

    let (app, model) = match app_name {
        "resmlp" => (d2a::apps::cosim_models::resmlp_lite(), "resmlp"),
        "resnet20" => (d2a::apps::cosim_models::resnet20_lite(), "resnet20"),
        "mobilenet" => (d2a::apps::cosim_models::mobilenet_lite(), "mobilenet"),
        other => anyhow::bail!("unknown app `{other}`"),
    };
    let targets: &[Target] = if model == "resmlp" {
        &[Target::FlexAsr]
    } else {
        &[Target::FlexAsr, Target::Hlscnn]
    };
    let session = SessionBuilder::new()
        .targets(targets)
        .matching(Matching::Flexible)
        .limits(limits())
        .design_rev(rev)
        .workers(workers)
        .backend(backend)
        .build();
    let program = session.compile(&app);
    println!(
        "{}: compiled with {} FlexASR + {} HLSCNN invocations",
        app.name,
        program.invocations(Target::FlexAsr),
        program.invocations(Target::Hlscnn)
    );
    let weights = store.weights(model)?;
    let (images, labels) = store.test_images()?;
    let n = limit.min(images.len());
    let rep = program.classify_sweep(&SweepSpec {
        input_var: cli.get("input-var").unwrap_or("x"),
        weights: &weights,
        inputs: &images[..n],
        labels: &labels[..n],
    });
    println!(
        "{} [{:?}, {} backend] over {} images: reference {:.2}%, \
         accelerated {:.2}%  (sim {:.1?}/image, wall {:.1?}/image, {} workers)",
        app.name,
        rev,
        backend,
        rep.n,
        rep.ref_accuracy() * 100.0,
        rep.acc_accuracy() * 100.0,
        rep.sim_time_per_point(),
        rep.wall_time_per_point(),
        rep.workers
    );
    if rep.exec_errors > 0 {
        println!(
            "WARNING: {} accelerated evaluation(s) failed outright \
             (execution faults, counted as misses)",
            rep.exec_errors
        );
    }
    print_cycles(&rep.cycles, &rep.op_cycles, rep.n);
    if backend == ExecBackend::CrossCheck {
        print!("{}", rep.fidelity);
    }
    Ok(())
}

/// Modeled-cycle summary for a sweep: the cost-model totals plus the
/// per-op breakdown the timeline folded them into. Silent under the
/// Functional backend (no device work, all counters zero).
fn print_cycles(cycles: &CycleBreakdown, op_cycles: &[OpCycles], n: usize) {
    if cycles.total() == 0 {
        return;
    }
    println!(
        "modeled device cycles: {}/point ({} total: {} transfer / {} compute / \
         {} overhead)",
        cycles.total() / n.max(1) as u64,
        cycles.total(),
        cycles.transfer,
        cycles.compute,
        cycles.overhead,
    );
    println!(
        "  {:<8} {:<22} {:>6} {:>12} {:>12} {:>12} {:>13}",
        "target", "op", "execs", "transfer", "compute", "overhead", "total"
    );
    for oc in op_cycles {
        println!(
            "  {:<8} {:<22} {:>6} {:>12} {:>12} {:>12} {:>13}",
            oc.target.to_string(),
            oc.op,
            oc.executions,
            oc.cycles.transfer,
            oc.cycles.compute,
            oc.cycles.overhead,
            oc.cycles.total(),
        );
    }
}

fn cmd_soc_demo() -> anyhow::Result<()> {
    use d2a::accel::{Accelerator, FlexAsr, Vta};
    use d2a::ir::Op;
    use d2a::soc::driver::Driver;
    use d2a::tensor::Tensor;
    use d2a::util::Rng;
    let mut drv = Driver::new(d2a::soc::reference_soc());
    let fa = FlexAsr::new();
    let vta = Vta::new();
    let mut rng = Rng::new(1);
    let x = fa.quant(&Tensor::randn(&[4, 16], &mut rng, 1.0));
    let w = fa.quant(&Tensor::randn(&[8, 16], &mut rng, 0.3));
    let b = fa.quant(&Tensor::randn(&[8], &mut rng, 0.1));
    let prog = fa
        .lower_concrete(&Op::FlexLinear, &[&x, &w, &b])
        .expect("linear fits the device");
    println!("FlexASR linear fragment (Fig. 5c):\n{}", prog.invocations[0].asm);
    println!("final MMIO commands (Fig. 5d):");
    let cmds: Vec<_> = prog.invocations[0].cmds().collect();
    for c in cmds.iter().rev().take(7).rev() {
        println!("  {c}");
    }
    let y = drv.invoke_program(&prog)?;
    println!("result shape {:?}; now chaining into VTA GEMM...", y.shape);
    let w2 = vta.quant(&Tensor::randn(&[4, 8], &mut rng, 1.0));
    let yq = vta.quant(&y);
    let gemm = vta
        .lower_concrete(&Op::VtaGemm, &[&yq, &w2])
        .expect("gemm fits the device");
    let y2 = drv.invoke_program(&gemm)?;
    println!(
        "VTA GEMM result shape {:?}; bus handled {} MMIO commands total",
        y2.shape,
        drv.bus.total_steps()
    );
    Ok(())
}
