//! Data-transfer accounting and post-extraction program analysis for the
//! §5.1 optimization study (Fig. 7 / the fig7 bench).
//!
//! The *rewrite-level* store/load cancellation lives in
//! `rewrites::compiler_ir::data_movement_rules`; this module measures its
//! effect on an extracted program and derives the fused lowering plan.

use crate::ir::{Op, RecExpr};

/// Data-movement statistics of an extracted program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferStats {
    /// `fasr_maxp_store` ops (host -> GB transfers).
    pub stores: usize,
    /// `fasr_maxp_load` ops (GB -> host transfers).
    pub loads: usize,
    /// Pool compute triggers.
    pub compute: usize,
}

/// Count FlexASR data-movement ops and compute invocations.
pub fn transfer_stats(expr: &RecExpr) -> TransferStats {
    TransferStats {
        stores: expr.count(|o| matches!(o, Op::FlexMaxpStore)),
        loads: expr.count(|o| matches!(o, Op::FlexMaxpLoad)),
        compute: expr.count(|o| matches!(o, Op::FlexMaxpool | Op::FlexMeanpool)),
    }
}

/// Find maximal chains `load(pool^k(store(t)))` in a program; returns the
/// chain lengths. A fully §5.1-optimized program has one chain of length
/// k; the naive program has k chains of length 1.
pub fn pool_chains(expr: &RecExpr) -> Vec<usize> {
    let mut chains = Vec::new();
    for node in &expr.nodes {
        if !matches!(node.op, Op::FlexMaxpLoad) {
            continue;
        }
        // walk down through consecutive pools
        let mut len = 0usize;
        let mut cur = node.children[0];
        loop {
            match &expr.nodes[cur].op {
                Op::FlexMaxpool | Op::FlexMeanpool => {
                    len += 1;
                    cur = expr.nodes[cur].children[0];
                }
                Op::FlexMaxpStore => break,
                _ => {
                    len = 0;
                    break;
                }
            }
        }
        if len > 0 {
            chains.push(len);
        }
    }
    chains
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parse::parse_sexpr;

    #[test]
    fn optimized_fig7_program_is_one_chain() {
        let e = parse_sexpr(
            "(reshape[63, 63] (fasr_maxp_load (fasr_maxpool (fasr_maxpool \
             (fasr_maxpool (fasr_maxpool (fasr_maxp_store \
             (windows_flatten<(4, 4),(2, 2)> %t))))))))",
        )
        .unwrap();
        let st = transfer_stats(&e);
        assert_eq!(st, TransferStats { stores: 1, loads: 1, compute: 4 });
        assert_eq!(pool_chains(&e), vec![4]);
    }

    #[test]
    fn naive_fig7_program_is_four_chains() {
        let e = parse_sexpr(
            "(reshape[63, 63] (fasr_maxp_load (fasr_maxpool (fasr_maxp_store \
             (fasr_maxp_load (fasr_maxpool (fasr_maxp_store \
             (fasr_maxp_load (fasr_maxpool (fasr_maxp_store \
             (fasr_maxp_load (fasr_maxpool (fasr_maxp_store \
             (windows_flatten<(4, 4),(2, 2)> %t))))))))))))))",
        )
        .unwrap();
        let st = transfer_stats(&e);
        assert_eq!(st.stores, 4);
        assert_eq!(st.loads, 4);
        assert_eq!(pool_chains(&e), vec![1, 1, 1, 1]);
    }
}
