//! The shared code-generation machinery behind `Accelerator::lower` (the
//! Fig. 3(b)→(d) / Fig. 5 pipeline): the [`LoweredProgram`] /
//! [`LoweredInvocation`] / [`ReadPlan`] vocabulary every per-accelerator
//! lowering produces, the MMIO byte streamer, and the executors that play
//! a lowered program against an [`crate::ila::sim::IlaSim`] and decode /
//! stitch its result.
//!
//! A lowered *program* is a sequence of *invocations* — each one MMIO
//! write burst + trigger (+ optional read-back) — because one tensor op
//! frequently needs **multiple architecture-level instructions**: a layer
//! whose operands exceed the device buffers is tiled by the driver
//! (weight-row tiles for FlexASR linear, per-step gate tiles for LSTM,
//! output-channel tiles for HLSCNN conv2d, flat chunks for the VTA ALU),
//! exactly as the ILA papers model real driver behaviour. Single-trigger
//! ops are the degenerate one-invocation program
//! ([`LoweredProgram::single`]). Invocations of one program execute on
//! one simulator session **without intervening resets**, so operands
//! staged by an earlier invocation (the activation tensor, the input
//! matrix) stay resident for later tiles.
//!
//! The per-op lowerings themselves live with their accelerators
//! (`accel::{flexasr,hlscnn,vta}`), reached through the
//! [`crate::accel::Accelerator::lower`] trait method — there are no
//! free-function lowerings here any more. The §5.1 fused maxpool-chain
//! lowering is `FlexAsr::lower_maxpool_chain`; its program-level
//! accounting stays in [`optimize`].

pub mod optimize;

use crate::accel::flexasr::model as fx;
use crate::accel::hlscnn::model as hx;
use crate::accel::vta::model as vx;
use crate::ila::asm::Fragment;
use crate::ila::Cmd;
use crate::ir::Target;
use crate::numerics::adaptivfloat::AdaptivFloatFormat;
use crate::numerics::fixed_point::FixedPointFormat;
use crate::tensor::Tensor;
use crate::util::fnv1a;
use std::sync::Arc;

/// The MMIO address range an operand burst stages into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MmioRegion {
    /// First byte address written.
    pub base: u64,
    /// Payload length in bytes.
    pub len: usize,
}

/// One fingerprinted MMIO command burst.
///
/// Commands are `Arc`-shared so identical bursts (the same weight tile
/// staged by many timesteps or sweep points) are encoded **once**
/// host-side and shared by every program that replays them, and the
/// content fingerprint + target region let an execution engine recognize
/// a burst that is already device-resident and skip re-streaming it
/// (operand residency — see `session::ExecEngine`).
#[derive(Debug, Clone)]
pub struct Burst {
    /// The MMIO commands, in order.
    pub cmds: Arc<[Cmd]>,
    /// Content fingerprint (address + enabled payload bytes of every
    /// command, in order).
    pub fingerprint: u64,
    /// The contiguous staging region this burst fills, for operand
    /// bursts; `None` for config/trigger tails (always streamed).
    pub region: Option<MmioRegion>,
}

impl Burst {
    /// An operand-staging burst: stream `payload` as 16-byte beats (with
    /// a byte-enabled short final beat) into `[base, base+len)`.
    pub fn stage(base: u64, payload: &[u8]) -> Self {
        let mut cmds = Vec::new();
        stream_bytes(&mut cmds, base, payload);
        let mut fp = fnv1a(0, &base.to_le_bytes());
        fp = fnv1a(fp, payload);
        Burst {
            cmds: cmds.into(),
            fingerprint: fp,
            region: Some(MmioRegion { base, len: payload.len() }),
        }
    }

    /// A control burst (configuration writes, triggers, status reads):
    /// no staging region, always streamed.
    pub fn control(cmds: Vec<Cmd>) -> Self {
        let mut fp = 0u64;
        for c in &cmds {
            fp = fnv1a(fp, &c.addr.to_le_bytes());
            fp = fnv1a(fp, if c.is_write { c.payload() } else { &[] });
        }
        Burst { cmds: cmds.into(), fingerprint: fp, region: None }
    }

    /// Bytes of write payload this burst moves over MMIO when streamed.
    pub fn payload_bytes(&self) -> u64 {
        self.cmds
            .iter()
            .filter(|c| c.is_write)
            .map(|c| c.len as u64)
            .sum()
    }
}

/// How to retrieve and decode an accelerator result after the command
/// stream has executed. Each plan carries the device's *configured*
/// storage format (design revisions differ), so decoding never assumes a
/// default-configured device.
#[derive(Debug, Clone)]
pub enum ReadPlan {
    /// FlexASR: read `status_out_bias`, then AF8 codes at `base`.
    FlexAf8 {
        /// MMIO address of the first code.
        base: u64,
        /// Decoded tensor shape.
        shape: Vec<usize>,
        /// The device's configured storage format.
        fmt: AdaptivFloatFormat,
    },
    /// HLSCNN: read i16 codes at `base`, NHWC layout, in the device's
    /// activation format.
    HlscnnI16 {
        /// MMIO address of the first code.
        base: u64,
        /// Decoded tensor shape (NCHW).
        shape: Vec<usize>,
        /// The device's configured activation format.
        fmt: FixedPointFormat,
    },
    /// VTA: read i32 accumulators at `base`, dequantized by `scale`.
    VtaI32 {
        /// MMIO address of the first accumulator word.
        base: u64,
        /// Decoded tensor shape.
        shape: Vec<usize>,
        /// f32 dequantization factor (product of operand scales).
        scale: f32,
    },
}

/// One lowered accelerator invocation: a sequence of command bursts and,
/// when this invocation produces (part of) the op's result, a read plan
/// for it.
#[derive(Debug, Clone)]
pub struct LoweredInvocation {
    /// Owning accelerator.
    pub target: Target,
    /// The Fig. 5(c) assembly-level fragment.
    pub asm: Fragment,
    /// The Fig. 5(d) MMIO command stream, as fingerprinted [`Burst`]s:
    /// operand-staging bursts (region-tagged, residency-trackable)
    /// followed by config/trigger control bursts.
    pub bursts: Vec<Burst>,
    /// How to retrieve this invocation's result; `None` for invocations
    /// whose effect stays in device state (operand staging, intermediate
    /// tiles of a multi-trigger program).
    pub read: Option<ReadPlan>,
}

/// How a multi-invocation program's read-backs combine into the op's
/// final tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Stitch {
    /// The last read-back *is* the result (single-trigger ops; programs
    /// whose tiles accumulate in device memory and read once at the end).
    Last,
    /// Concatenate the read-backs along `axis` (tile outputs are
    /// contiguous blocks of the result along that axis), then reshape to
    /// `shape` — bit-exact data assembly, no arithmetic.
    Concat {
        /// Axis the tiles partition.
        axis: usize,
        /// Final result shape.
        shape: Vec<usize>,
    },
}

/// One lowered accelerator *op*: a sequence of invocations plus the
/// stitch step combining their read-backs. See the module docs for why
/// this is a sequence (driver-side tiling).
///
/// **Invariant:** a program whose stitch is [`Stitch::Last`] must carry
/// its read plan on exactly one invocation — the one producing the op
/// result. `Last` used to silently discard earlier read-backs; since the
/// stream-path hardening pass, [`stitch_parts`] rejects multi-read
/// `Last` programs with a structured error so a future lowering cannot
/// mask a lost tile.
#[derive(Debug, Clone)]
pub struct LoweredProgram {
    /// The invocations, in execution order.
    pub invocations: Vec<LoweredInvocation>,
    /// How read-backs assemble into the op result.
    pub stitch: Stitch,
    /// Driver-side calibration mirrors this lowering had to compute (the
    /// tiled-linear forced-bias replay, the tiled-LSTM `lstm_traced`
    /// bias-schedule replay). The engine's lowering cache reports a
    /// `mirror_hits` counter from this: a cache hit on a program with
    /// `mirrors > 0` is a full mirror recomputation avoided.
    pub mirrors: usize,
}

impl LoweredProgram {
    /// The degenerate single-trigger program.
    pub fn single(inv: LoweredInvocation) -> Self {
        LoweredProgram { invocations: vec![inv], stitch: Stitch::Last, mirrors: 0 }
    }

    /// Owning accelerator (programs never mix targets).
    pub fn target(&self) -> Target {
        self.invocations[0].target
    }

    /// Total MMIO beats moving tensor data across all invocations.
    pub fn data_beats(&self) -> usize {
        self.invocations.iter().map(|i| i.data_beats()).sum()
    }

    /// Total enabled payload bytes crossing MMIO into data windows.
    pub fn data_bytes(&self) -> u64 {
        self.invocations.iter().map(|i| i.data_bytes()).sum()
    }

    /// Total bytes moved by on-device `DMA_CTRL` replays.
    pub fn dma_replay_bytes(&self) -> u64 {
        self.invocations.iter().map(|i| i.dma_replay_bytes()).sum()
    }

    /// True when the driver tiled the op into multiple triggers.
    pub fn is_tiled(&self) -> bool {
        self.invocations.len() > 1
    }
}

/// True when `addr` lies in an operand/result data window of any device:
/// FlexASR global buffer / PE weight buffer / weight-staging DRAM,
/// HLSCNN activation / weight / output SRAM, VTA input / weight /
/// accumulator buffer. (VTA accumulators and HLSCNN outputs count —
/// VtaAdd stages its first operand directly into the accumulator window,
/// and only host *writes* are tallied, so device-produced results never
/// double-count.)
fn in_data_window(addr: u64) -> bool {
    (fx::GB_BASE..fx::GB_BASE + fx::GB_SIZE as u64).contains(&addr)
        || (fx::PE_WGT_BASE..fx::PE_WGT_BASE + fx::PE_WGT_SIZE as u64).contains(&addr)
        || (fx::WGT_DRAM_BASE..fx::WGT_DRAM_BASE + fx::WGT_DRAM_SIZE as u64)
            .contains(&addr)
        || (hx::ACT_BASE..hx::ACT_BASE + hx::ACT_SIZE as u64).contains(&addr)
        || (hx::WGT_BASE..hx::WGT_BASE + hx::WGT_SIZE as u64).contains(&addr)
        || (hx::OUT_BASE..hx::OUT_BASE + hx::OUT_SIZE as u64).contains(&addr)
        || (vx::INP_BASE..vx::INP_BASE + vx::INP_SIZE as u64).contains(&addr)
        || (vx::WGT_BASE..vx::WGT_BASE + vx::WGT_SIZE as u64).contains(&addr)
        || (vx::ACC_BASE..vx::ACC_BASE + vx::ACC_SIZE as u64).contains(&addr)
}

impl LoweredInvocation {
    /// All MMIO commands of this invocation, in stream order.
    pub fn cmds(&self) -> impl Iterator<Item = &Cmd> {
        self.bursts.iter().flat_map(|b| b.cmds.iter())
    }

    /// Number of MMIO beats moving tensor data (the §5.1 metric): write
    /// beats into a data window, exactly as [`stream_bytes`] put them on
    /// the bus — a byte-enabled short final beat is one beat. Read
    /// commands touching a data window are result fetches, not data
    /// pushed by the host, and are excluded; on-device `DMA_CTRL` replay
    /// traffic never crosses MMIO and is reported separately by
    /// [`Self::dma_replay_bytes`].
    pub fn data_beats(&self) -> usize {
        self.cmds().filter(|c| c.is_write && in_data_window(c.addr)).count()
    }

    /// Enabled payload bytes crossing MMIO into data windows. Unlike the
    /// beat count this gives a short final beat its true size: a 22-byte
    /// stage is 2 beats but 22 bytes, not 32.
    pub fn data_bytes(&self) -> u64 {
        self.cmds()
            .filter(|c| c.is_write && in_data_window(c.addr))
            .map(|c| c.len as u64)
            .sum()
    }

    /// Bytes moved by on-device `DMA_CTRL` replays (staging DRAM → PE
    /// weight buffer), decoded from each descriptor's length field — the
    /// same count the simulator copies when the descriptor executes.
    pub fn dma_replay_bytes(&self) -> u64 {
        self.cmds()
            .filter(|c| c.is_write && c.addr == fx::DMA_CTRL)
            .map(|c| c.data_u64() >> 44)
            .sum()
    }
}

impl ReadPlan {
    /// Bytes this plan fetches from device memory (stored codes/words,
    /// before decode): AF8 is one byte per element, HLSCNN two, VTA
    /// four. The FlexASR status-bias beat is control, not data, and is
    /// excluded.
    pub fn read_bytes(&self) -> u64 {
        match self {
            ReadPlan::FlexAf8 { shape, .. } => {
                shape.iter().product::<usize>() as u64
            }
            ReadPlan::HlscnnI16 { shape, .. } => {
                2 * shape.iter().product::<usize>() as u64
            }
            ReadPlan::VtaI32 { shape, .. } => {
                4 * shape.iter().product::<usize>() as u64
            }
        }
    }
}

/// Stream a byte buffer as 16-byte MMIO writes starting at `base` (used
/// by every per-accelerator lowering). An unaligned final slice becomes a
/// **byte-enabled short beat** ([`Cmd::write_bytes`]); the seed zero-
/// padded it to 16 bytes, clobbering up to 15 bytes past the slice's end
/// — fatal for adjacent staged regions packed closer than a beat.
pub fn stream_bytes(cmds: &mut Vec<Cmd>, base: u64, bytes: &[u8]) {
    for (i, chunk) in bytes.chunks(16).enumerate() {
        cmds.push(Cmd::write_bytes(base + 16 * i as u64, chunk));
    }
}

// ----------------------------------------------------------------------
// Result retrieval
// ----------------------------------------------------------------------

/// Execute a whole lowered program on one simulator session — invocations
/// run in order with **no resets in between** (staged operands stay
/// resident) — collecting each invocation's read-back and stitching them
/// into the op result. The caller is responsible for resetting the
/// simulator *before* the program (the execution engine does a
/// dirty-region reset).
pub fn execute_program(
    prog: &LoweredProgram,
    sim: &mut crate::ila::sim::IlaSim,
) -> anyhow::Result<Tensor> {
    let mut parts = Vec::new();
    for inv in &prog.invocations {
        for burst in &inv.bursts {
            sim.run(&burst.cmds).map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        if inv.read.is_some() {
            parts.push(read_result(inv, sim)?);
        }
    }
    stitch_parts(parts, &prog.stitch)
}

/// Assemble invocation read-backs per the program's stitch step.
///
/// A [`Stitch::Last`] program with more than one read-back is rejected
/// (see the invariant on [`LoweredProgram`]): the extra read plans mean a
/// lowering produced tiles it then threw away, which `Last` used to mask
/// silently.
pub fn stitch_parts(mut parts: Vec<Tensor>, stitch: &Stitch) -> anyhow::Result<Tensor> {
    match stitch {
        Stitch::Last => {
            anyhow::ensure!(
                parts.len() <= 1,
                "Stitch::Last over {} read-backs would discard {} tile(s); \
                 a Last program must carry exactly one read plan",
                parts.len(),
                parts.len() - 1
            );
            parts.pop().ok_or_else(|| anyhow::anyhow!("program produced no read-back"))
        }
        Stitch::Concat { axis, shape } => {
            if parts.is_empty() {
                anyhow::bail!("concat stitch over zero tiles");
            }
            let t = concat_axis(&parts, *axis)?;
            anyhow::ensure!(
                t.len() == shape.iter().product::<usize>(),
                "stitched {} elements, expected shape {shape:?}",
                t.len()
            );
            Ok(t.reshape(shape))
        }
    }
}

/// Concatenate tensors along `axis` (all other dims must agree).
///
/// Shape validation is structured (`anyhow`), not `debug_assert!`: a
/// malformed [`LoweredProgram`] must fail loudly in release builds too,
/// instead of silently corrupting the stitched tensor.
fn concat_axis(parts: &[Tensor], axis: usize) -> anyhow::Result<Tensor> {
    let first = &parts[0];
    let rank = first.shape.len();
    anyhow::ensure!(axis < rank, "concat axis {axis} out of rank {rank}");
    for (i, p) in parts.iter().enumerate() {
        anyhow::ensure!(
            p.shape.len() == rank
                && p.shape[..axis] == first.shape[..axis]
                && p.shape[axis + 1..] == first.shape[axis + 1..],
            "tile {i} shape {:?} disagrees with tile 0 shape {:?} off axis {axis}",
            p.shape,
            first.shape
        );
    }
    let outer: usize = first.shape[..axis].iter().product();
    let inner: usize = first.shape[axis + 1..].iter().product();
    let axis_total: usize = parts.iter().map(|p| p.shape[axis]).sum();
    let mut shape = first.shape.clone();
    shape[axis] = axis_total;
    let mut data = vec![0.0f32; outer * axis_total * inner];
    let mut axis_off = 0usize;
    for p in parts {
        let block = p.shape[axis] * inner;
        for o in 0..outer {
            let dst = (o * axis_total + axis_off) * inner;
            data[dst..dst + block].copy_from_slice(&p.data[o * block..(o + 1) * block]);
        }
        axis_off += p.shape[axis];
    }
    Ok(Tensor::new(shape, data))
}

/// Execute a single lowered invocation and decode its result (requires a
/// read plan; use [`execute_program`] for whole ops).
pub fn execute_lowered(
    inv: &LoweredInvocation,
    sim: &mut crate::ila::sim::IlaSim,
) -> anyhow::Result<Tensor> {
    for burst in &inv.bursts {
        sim.run(&burst.cmds).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    read_result(inv, sim)
}

/// Decode a completed invocation's result from device state. Reads that
/// return no data surface as structured errors instead of being masked
/// with zeros. Errors when the invocation has no read plan.
pub fn read_result(
    inv: &LoweredInvocation,
    sim: &mut crate::ila::sim::IlaSim,
) -> anyhow::Result<Tensor> {
    let plan = inv
        .read
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("invocation has no read plan"))?;
    let fetch = |sim: &mut crate::ila::sim::IlaSim,
                 base: u64,
                 nbytes: usize|
     -> anyhow::Result<Vec<u8>> {
        let mut out = Vec::with_capacity(nbytes);
        let mut addr = base;
        while out.len() < nbytes {
            let d = sim
                .step(&Cmd::read(addr))
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .ok_or_else(|| {
                    anyhow::anyhow!("read at 0x{addr:08X} returned no data")
                })?;
            out.extend_from_slice(&d);
            addr += 16;
        }
        out.truncate(nbytes);
        Ok(out)
    };
    match plan {
        ReadPlan::FlexAf8 { base, shape, fmt } => {
            let ob = sim
                .step(&Cmd::read(fx::STATUS_OUT_BIAS))
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "status read at 0x{:08X} returned no data",
                        fx::STATUS_OUT_BIAS
                    )
                })?[0] as i8 as i32;
            let n: usize = shape.iter().product();
            let codes = fetch(sim, *base, n)?;
            Ok(fx::decode_tensor(fmt, &codes, ob, shape))
        }
        ReadPlan::HlscnnI16 { base, shape, fmt } => {
            let n: usize = shape.iter().product();
            let bytes = fetch(sim, *base, 2 * n)?;
            let codes: Vec<i16> = bytes
                .chunks(2)
                .map(|p| i16::from_le_bytes(p.try_into().unwrap()))
                .collect();
            Ok(hx::decode_out_nchw_fmt(*fmt, &codes, shape))
        }
        ReadPlan::VtaI32 { base, shape, scale } => {
            let n: usize = shape.iter().product();
            let bytes = fetch(sim, *base, 4 * n)?;
            let vals: Vec<f32> = bytes
                .chunks(4)
                .map(|q| i32::from_le_bytes(q.try_into().unwrap()) as f32 * scale)
                .collect();
            Ok(Tensor::new(shape.clone(), vals))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{Accelerator, FlexAsr, Hlscnn, Vta};
    use crate::ila::sim::IlaSim;
    use crate::ir::Op;
    use crate::util::Rng;

    #[test]
    fn lowered_linear_runs_end_to_end() {
        let dev = FlexAsr::new();
        let mut rng = Rng::new(71);
        let x = dev.quant(&Tensor::randn(&[4, 16], &mut rng, 1.0));
        let w = dev.quant(&Tensor::randn(&[8, 16], &mut rng, 0.3));
        let b = dev.quant(&Tensor::randn(&[8], &mut rng, 0.1));
        let prog = dev.lower(&Op::FlexLinear, &[&x, &w, &b]).unwrap();
        assert!(!prog.is_tiled(), "small linear is a single trigger");
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_program(&prog, &mut sim).unwrap();
        // the MMIO result equals the tensor-level fast path bit-exactly:
        // both sides quantize through the same storage codec
        let expect = dev.linear(&x, &w, &b);
        assert_eq!(got, expect, "MMIO path diverges from tensor path");
        assert!(
            prog.invocations[0].asm.len() >= 8,
            "Fig. 5(c)-style fragment emitted"
        );
    }

    #[test]
    fn oversized_linear_tiles_instead_of_declining() {
        // weights beyond the 256 KiB PE buffer: the driver now emits a
        // multi-trigger row-tiled program instead of falling back
        let dev = FlexAsr::new();
        let mut rng = Rng::new(76);
        let x = Tensor::randn(&[2, 600], &mut rng, 1.0);
        let w = Tensor::randn(&[600, 600], &mut rng, 0.3);
        let b = Tensor::randn(&[600], &mut rng, 0.1);
        let prog = dev.lower(&Op::FlexLinear, &[&x, &w, &b]).unwrap();
        assert!(prog.is_tiled(), "600x600 weights exceed one tile");
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_program(&prog, &mut sim).unwrap();
        assert_eq!(got, dev.linear(&x, &w, &b), "tiled MMIO diverges");
    }

    #[test]
    fn stitch_concat_reassembles_column_tiles() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 5.0, 6.0]);
        let b = Tensor::new(vec![2, 1], vec![3.0, 7.0]);
        let out = stitch_parts(
            vec![a, b],
            &Stitch::Concat { axis: 1, shape: vec![2, 3] },
        )
        .unwrap();
        assert_eq!(out.data, vec![1.0, 2.0, 3.0, 5.0, 6.0, 7.0]);
        // Last with exactly one read-back is the result
        let last = stitch_parts(vec![Tensor::zeros(&[2])], &Stitch::Last).unwrap();
        assert_eq!(last.shape, vec![2]);
        assert!(stitch_parts(vec![], &Stitch::Last).is_err());
    }

    #[test]
    fn stitch_last_rejects_multiple_readbacks() {
        // Last used to silently discard every read-back but the final
        // one; a multi-read Last program is now a structured error so a
        // lowering cannot mask a lost tile
        let err = stitch_parts(
            vec![Tensor::ones(&[1]), Tensor::zeros(&[2])],
            &Stitch::Last,
        )
        .unwrap_err();
        assert!(err.to_string().contains("discard"), "{err}");
    }

    #[test]
    fn concat_shape_mismatch_is_a_structured_error() {
        // release builds used to skip the debug_assert and corrupt the
        // stitched tensor; malformed tiles must fail loudly
        let a = Tensor::new(vec![2, 2], vec![1.0; 4]);
        let bad = Tensor::new(vec![3, 1], vec![2.0; 3]);
        let err = stitch_parts(
            vec![a.clone(), bad],
            &Stitch::Concat { axis: 1, shape: vec![2, 3] },
        )
        .unwrap_err();
        assert!(err.to_string().contains("disagrees"), "{err}");
        // out-of-rank axis is rejected, not a panic
        let err = stitch_parts(
            vec![a.clone(), a],
            &Stitch::Concat { axis: 5, shape: vec![2, 4] },
        )
        .unwrap_err();
        assert!(err.to_string().contains("axis"), "{err}");
    }

    #[test]
    fn unaligned_burst_does_not_clobber_adjacent_region() {
        // regression for the stream_bytes zero-pad bug: a deliberately
        // unaligned tile boundary — 22 payload bytes, then a second
        // region starting 22 bytes in (packed tighter than a beat)
        let dev = FlexAsr::new();
        let mut sim = IlaSim::new(dev.build_ila());
        use crate::accel::flexasr::model as fxm;
        // pre-stage a sentinel where the adjacent region lives
        let sentinel = Burst::stage(fxm::PE_WGT_BASE + 16, &[0xAAu8; 16]);
        for c in sentinel.cmds.iter() {
            sim.step(c).unwrap();
        }
        // an unaligned 22-byte burst [0, 22) — its final beat covers
        // [16, 32) but only 6 bytes are enabled
        let tile = Burst::stage(fxm::PE_WGT_BASE, &[0x11u8; 22]);
        assert_eq!(tile.cmds.last().unwrap().len, 6, "short final beat");
        for c in tile.cmds.iter() {
            sim.step(c).unwrap();
        }
        let mem = sim.state.mem("pe_weight");
        assert_eq!(&mem[..22], &[0x11u8; 22][..]);
        assert_eq!(
            &mem[22..32],
            &[0xAAu8; 10][..],
            "the zero-pad clobbered the adjacent staged region"
        );
    }

    #[test]
    fn maxpool_chain_optimized_moves_less_data() {
        let dev = FlexAsr::new();
        let mut rng = Rng::new(72);
        let t = dev.quant(&Tensor::randn(&[64, 64], &mut rng, 1.0));
        let fused = dev.lower_maxpool_chain(&t, 4);
        let naive = dev.lower_maxpool_chain_naive(&t, 4);
        let naive_beats: usize = naive.iter().map(|i| i.data_beats()).sum();
        // naive: 256+128+64+32 = 480 store beats (plus ~240 read-back
        // beats not counted here since reads happen in read_result);
        // fused: one 256-beat store. Require a clear win on stores alone.
        assert!(
            fused.data_beats() * 5 < naive_beats * 3,
            "fused {} vs naive {naive_beats}",
            fused.data_beats()
        );

        // and the fused program computes the right maxpool
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_lowered(&fused, &mut sim).unwrap();
        let mut expect = t.clone();
        for _ in 0..4 {
            expect =
                crate::ir::interp::eval_op(&crate::ir::Op::TempMaxPool, &[&expect])
                    .unwrap();
        }
        assert!(got.rel_error(&expect) < 1e-5);
    }

    #[test]
    fn tiled_lstm_stages_each_weight_tile_once_not_once_per_step() {
        // the PR-4 lowering re-streamed every gate tile on every
        // timestep (~t x redundant traffic); the DRAM-staged lowering
        // moves each tile across MMIO exactly once, then DMA-replays it
        let dev = FlexAsr::new();
        let mut rng = Rng::new(78);
        let (t, e, h) = (4usize, 200usize, 200usize);
        let x = Tensor::randn(&[t, 1, e], &mut rng, 1.0);
        let wi = Tensor::randn(&[4 * h, e], &mut rng, 0.3);
        let wh = Tensor::randn(&[4 * h, h], &mut rng, 0.3);
        let b = Tensor::randn(&[4 * h], &mut rng, 0.1);
        let prog = dev.lower(&Op::FlexLstm { steps: t }, &[&x, &wi, &wh, &b]).unwrap();
        assert!(prog.is_tiled());
        assert_eq!(prog.mirrors, 1, "the bias-schedule mirror is declared");
        use crate::accel::flexasr::model as fxm;
        let dram_range =
            fxm::WGT_DRAM_BASE..fxm::WGT_DRAM_BASE + fxm::WGT_DRAM_SIZE as u64;
        let pe_range =
            fxm::PE_WGT_BASE..fxm::PE_WGT_BASE + fxm::PE_WGT_SIZE as u64;
        let dram_bytes: u64 = prog
            .invocations
            .iter()
            .flat_map(|i| i.bursts.iter())
            .filter(|bu| {
                bu.region.is_some_and(|r| dram_range.contains(&r.base))
            })
            .map(|bu| bu.payload_bytes())
            .sum();
        let weight_bytes = (4 * h * e + 4 * h * h + 4 * h) as u64;
        assert!(
            dram_bytes >= weight_bytes && dram_bytes < weight_bytes + weight_bytes / 2,
            "weights must cross MMIO about once ({dram_bytes} B staged for \
             {weight_bytes} B of weights), not once per timestep"
        );
        // no direct PE-window data writes remain: tiles ride the DMA
        assert!(
            prog.invocations.iter().flat_map(|i| i.cmds()).all(|c| {
                !c.is_write || !pe_range.contains(&c.addr)
            }),
            "per-step invocations must not re-stream weight tiles"
        );
        // and the program still computes the exact fast-path result
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_program(&prog, &mut sim).unwrap();
        assert_eq!(got, dev.lstm(&x, &wi, &wh, &b));
    }

    #[test]
    fn lowered_hlscnn_conv_end_to_end() {
        let dev = Hlscnn::default();
        let mut rng = Rng::new(73);
        let x = Tensor::randn(&[1, 3, 6, 6], &mut rng, 1.0);
        let w = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.2);
        let op = Op::HlscnnConv2d { stride: (1, 1), pad: (1, 1) };
        let prog = dev.lower(&op, &[&x, &w]).unwrap();
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_program(&prog, &mut sim).unwrap();
        // updated design: the integer kernel is shared, so the MMIO and
        // tensor views agree bit-exactly
        let expect = dev.conv2d(&x, &w, (1, 1), (1, 1));
        assert_eq!(got, expect);
    }

    #[test]
    fn lowered_vta_gemm_end_to_end() {
        let dev = Vta::new();
        let mut rng = Rng::new(74);
        let x = dev.quant(&Tensor::randn(&[4, 16], &mut rng, 1.0));
        let w = dev.quant(&Tensor::randn(&[8, 16], &mut rng, 1.0));
        let prog = dev.lower(&Op::VtaGemm, &[&x, &w]).unwrap();
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_program(&prog, &mut sim).unwrap();
        let expect = dev.gemm(&x, &w);
        assert_eq!(got.rel_error(&expect), 0.0, "VTA GEMM is exact");
    }

    #[test]
    fn lower_declines_foreign_and_untileable_ops() {
        let fa = FlexAsr::new();
        let mut rng = Rng::new(75);
        let x = Tensor::randn(&[1, 600], &mut rng, 1.0);
        let w = Tensor::randn(&[600, 600], &mut rng, 0.3);
        // foreign op: not this accelerator's
        assert!(fa.lower(&Op::VtaGemm, &[&x, &w]).is_none());
        // data movement has no single-op program
        assert!(fa.lower(&Op::FlexMaxpStore, &[&x]).is_none());
        // an input matrix that alone overflows the global buffer cannot
        // be staged even one row-tile at a time: decline, don't corrupt
        let xb = Tensor::randn(&[3, 30_000], &mut rng, 1.0);
        let wb = Tensor::randn(&[4, 30_000], &mut rng, 0.3);
        let bb = Tensor::randn(&[4], &mut rng, 0.1);
        assert!(fa.lower(&Op::FlexLinear, &[&xb, &wb, &bb]).is_none());
        // batched conv: HLSCNN is a batch-1 device
        let hl = Hlscnn::default();
        let xc = Tensor::randn(&[2, 3, 6, 6], &mut rng, 1.0);
        let k = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.2);
        let op = Op::HlscnnConv2d { stride: (1, 1), pad: (1, 1) };
        assert!(hl.lower(&op, &[&xc, &k]).is_none());
    }

    #[test]
    fn data_beat_accounting_matches_the_bus_on_unaligned_tails() {
        // a 22-byte stage: stream_bytes emits 2 beats (one full, one
        // byte-enabled short), so the beat count is 2 — but the payload
        // crossing the bus is 22 bytes, not 2 * 16
        let stage = Burst::stage(fx::GB_BASE, &[0x5Au8; 22]);
        let inv = LoweredInvocation {
            target: Target::FlexAsr,
            asm: Fragment::new(),
            bursts: vec![stage],
            read: None,
        };
        assert_eq!(inv.data_beats(), 2, "short final beat is one beat");
        assert_eq!(inv.data_bytes(), 22, "tail counts its true size");

        // a read command inside a data window (a result fetch) is not
        // data the host pushed: it must not inflate the beat count
        let mut with_read = inv.clone();
        with_read.bursts.push(Burst::control(vec![Cmd::read(fx::GB_BASE)]));
        assert_eq!(with_read.data_beats(), 2, "reads are not data beats");
        assert_eq!(with_read.data_bytes(), 22);

        // a DMA_CTRL descriptor is control, not a data beat; its replay
        // length is decoded from the descriptor word instead
        let mut with_dma = inv.clone();
        with_dma.bursts.push(Burst::control(vec![Cmd::write_u64(
            fx::DMA_CTRL,
            fx::dma_word(0, 0, 4096),
        )]));
        assert_eq!(with_dma.data_beats(), 2);
        assert_eq!(with_dma.dma_replay_bytes(), 4096);
    }

    #[test]
    fn dma_replay_bytes_cover_the_staged_lstm_weights() {
        // the DRAM-staged LSTM replays every weight tile per timestep:
        // the decoded replay traffic must be at least t times the weight
        // footprint, while data_beats (MMIO writes) stays near one pass
        let dev = FlexAsr::new();
        let mut rng = Rng::new(79);
        let (t, e, h) = (4usize, 200usize, 200usize);
        let x = Tensor::randn(&[t, 1, e], &mut rng, 1.0);
        let wi = Tensor::randn(&[4 * h, e], &mut rng, 0.3);
        let wh = Tensor::randn(&[4 * h, h], &mut rng, 0.3);
        let b = Tensor::randn(&[4 * h], &mut rng, 0.1);
        let prog =
            dev.lower(&Op::FlexLstm { steps: t }, &[&x, &wi, &wh, &b]).unwrap();
        let weight_bytes = (4 * h * e + 4 * h * h) as u64;
        assert!(
            prog.dma_replay_bytes() >= weight_bytes * t as u64,
            "replays {} must cover {} weight bytes x {t} steps",
            prog.dma_replay_bytes(),
            weight_bytes
        );
        // MMIO data traffic stays a single staging pass (plus
        // activations/biases), far below the replayed total
        assert!(prog.data_bytes() < prog.dma_replay_bytes());
    }

    #[test]
    fn read_plan_bytes_follow_the_storage_width() {
        let af = ReadPlan::FlexAf8 {
            base: fx::GB_BASE,
            shape: vec![3, 5],
            fmt: AdaptivFloatFormat::new(8, 3),
        };
        assert_eq!(af.read_bytes(), 15);
        let hl = ReadPlan::HlscnnI16 {
            base: hx::OUT_BASE,
            shape: vec![1, 2, 2, 2],
            fmt: FixedPointFormat::new(16, 8),
        };
        assert_eq!(hl.read_bytes(), 16);
        let vt = ReadPlan::VtaI32 { base: vx::ACC_BASE, shape: vec![4], scale: 1.0 };
        assert_eq!(vt.read_bytes(), 16);
    }
}
