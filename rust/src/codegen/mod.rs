//! Code generation: matched accelerator operators → ILA program fragments
//! → MMIO command streams (the Fig. 3(b)→(d) / Fig. 5 pipeline).
//!
//! Each lowering produces a [`LoweredInvocation`]: the raw command stream
//! that drives the accelerator over its bus interface, plus a
//! [`ReadPlan`] describing how the driver fetches and decodes the result.
//! The assembly-level [`Fragment`] view (Fig. 5(c)) is emitted alongside
//! for inspection and for the VT2 verification path.
//!
//! §5.1's data-transfer optimization appears here too:
//! [`lower_flex_maxpool_chain`] fuses a chain of temporal max pools into
//! one store → k×trigger → load program, eliminating the intermediate
//! loads/stores that naive per-op lowering would emit.

pub mod optimize;

use crate::accel::flexasr::{model as fx, FlexAsr};
use crate::accel::hlscnn::{model as hx, Hlscnn};
use crate::accel::vta::{model as vx, Vta};
use crate::ila::asm::Fragment;
use crate::ila::Cmd;
use crate::ir::Target;
use crate::tensor::Tensor;

/// How to retrieve and decode an accelerator result after the command
/// stream has executed.
#[derive(Debug, Clone)]
pub enum ReadPlan {
    /// FlexASR: read `status_out_bias`, then `len` AF8 codes at `base`.
    FlexAf8 { base: u64, shape: Vec<usize> },
    /// HLSCNN: read `len` i16 codes at `base`, NHWC layout.
    HlscnnI16 { base: u64, shape: Vec<usize> },
    /// VTA: read `n*m` i32 accumulators at `base`, dequant by `scale`.
    VtaI32 { base: u64, shape: Vec<usize>, scale: f32 },
}

/// One lowered accelerator invocation.
#[derive(Debug, Clone)]
pub struct LoweredInvocation {
    pub target: Target,
    pub asm: Fragment,
    pub cmds: Vec<Cmd>,
    pub read: ReadPlan,
}

impl LoweredInvocation {
    /// Number of MMIO beats moving tensor data (the §5.1 metric).
    pub fn data_beats(&self) -> usize {
        self.cmds
            .iter()
            .filter(|c| {
                let a = c.addr;
                (fx::GB_BASE..fx::GB_BASE + fx::GB_SIZE as u64).contains(&a)
                    || (fx::PE_WGT_BASE..fx::PE_WGT_BASE + fx::PE_WGT_SIZE as u64)
                        .contains(&a)
                    || (hx::ACT_BASE..hx::ACT_BASE + hx::ACT_SIZE as u64).contains(&a)
                    || (hx::WGT_BASE..hx::WGT_BASE + hx::WGT_SIZE as u64).contains(&a)
                    || (vx::INP_BASE..vx::INP_BASE + vx::INP_SIZE as u64).contains(&a)
                    || (vx::WGT_BASE..vx::WGT_BASE + vx::WGT_SIZE as u64).contains(&a)
            })
            .count()
    }
}

/// Stream a byte buffer as 16-byte MMIO writes starting at `base`.
fn stream_bytes(cmds: &mut Vec<Cmd>, base: u64, bytes: &[u8]) {
    for (i, chunk) in bytes.chunks(16).enumerate() {
        let mut data = [0u8; 16];
        data[..chunk.len()].copy_from_slice(chunk);
        cmds.push(Cmd::write(base + 16 * i as u64, data));
    }
}

// ----------------------------------------------------------------------
// FlexASR lowerings
// ----------------------------------------------------------------------

/// Lower a FlexASR linear layer (`fasr_linear x w b`) — the Fig. 5
/// mapping end to end.
pub fn lower_flex_linear(
    dev: &FlexAsr,
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
) -> LoweredInvocation {
    let fmt = dev.af;
    let (n, k) = (x.shape[0], x.shape[1]);
    let m = w.shape[0];
    let (xc, xb) = fx::encode_tensor(&fmt, x);
    let (wc, wb) = fx::encode_tensor(&fmt, w);
    let (bc, bb) = fx::encode_tensor(&fmt, b);
    let bias_base = ((m * k + 15) / 16 * 16) as u64;
    let out_base = ((n * k + 15) / 16 * 16) as u64;

    let mut cmds = Vec::new();
    stream_bytes(&mut cmds, fx::GB_BASE, &xc);
    stream_bytes(&mut cmds, fx::PE_WGT_BASE, &wc);
    stream_bytes(&mut cmds, fx::PE_WGT_BASE + bias_base, &bc);
    cmds.push(Cmd::write_u64(
        fx::CFG_LAYER_SIZING,
        (k as u64) | ((m as u64) << 16),
    ));
    cmds.push(Cmd::write_u64(fx::CFG_MNGR, bias_base));
    cmds.push(Cmd::write_u64(fx::CFG_ACT, 0));
    cmds.push(Cmd::write_u64(
        fx::CFG_GB_CONTROL,
        fx::OP_LINEAR | ((n as u64) << 8),
    ));
    cmds.push(Cmd::write_u64(fx::CFG_GB_MMNGR, out_base << 32));
    cmds.push(Cmd::write_u64(
        fx::CFG_EXP_BIAS,
        (xb as u8 as u64) | ((wb as u8 as u64) << 8) | ((bb as u8 as u64) << 16),
    ));
    cmds.push(Cmd::write_u64(fx::FN_START, 1));

    let mut asm = Fragment::new();
    asm.push("FlexASR_ILA.write_v", &["%input"])
        .push("FlexASR_ILA.write_wgt", &["%weight", "%bias"])
        .push("FlexASR_ILA.pe_cfg_rnn_layer_sizing", &["%k", "%m"])
        .push("FlexASR_ILA.pe_cfg_mngr", &["%bias_base"])
        .push("FlexASR_ILA.pe_cfg_act_mngr", &["%act"])
        .push("FlexASR_ILA.gb_cfg_gb_control", &["%opcode", "%n"])
        .push("FlexASR_ILA.gb_cfg_mmngr_gb_large", &["%in", "%out"])
        .push("FlexASR_ILA.cfg_exp_bias", &["%biases"])
        .push("FlexASR_ILA.fn_start", &[])
        .push("FlexASR_ILA.read_v", &["%output"]);

    LoweredInvocation {
        target: Target::FlexAsr,
        asm,
        cmds,
        read: ReadPlan::FlexAf8 { base: fx::GB_BASE + out_base, shape: vec![n, m] },
    }
}

/// Lower a chain of `stages` FlexASR temporal max pools over `t` with the
/// §5.1 optimization: ONE store in, `stages` triggers ping-ponging between
/// two GB regions, ONE load out.
pub fn lower_flex_maxpool_chain(
    dev: &FlexAsr,
    t: &Tensor,
    stages: usize,
) -> LoweredInvocation {
    assert!(stages >= 1);
    let fmt = dev.af;
    let (r, c) = (t.shape[0], t.shape[1]);
    assert!(r % (1 << stages) == 0, "rows must divide by 2^stages");
    let (tc, tb) = fx::encode_tensor(&fmt, t);
    let half = (fx::GB_SIZE / 2) as u64;

    let mut cmds = Vec::new();
    stream_bytes(&mut cmds, fx::GB_BASE, &tc);
    let mut rows = r;
    let mut in_base = 0u64;
    let mut exp_bias = tb;
    for s in 0..stages {
        let out_base = if in_base == 0 { half } else { 0 };
        cmds.push(Cmd::write_u64(fx::CFG_LAYER_SIZING, c as u64));
        cmds.push(Cmd::write_u64(
            fx::CFG_GB_CONTROL,
            fx::OP_MAXPOOL | ((rows as u64) << 8),
        ));
        cmds.push(Cmd::write_u64(fx::CFG_GB_MMNGR, in_base | (out_base << 32)));
        cmds.push(Cmd::write_u64(fx::CFG_EXP_BIAS, exp_bias as u8 as u64));
        cmds.push(Cmd::write_u64(fx::FN_START, 1));
        // maxpool preserves the exponent bias (max of lattice values);
        // subsequent stages read the device-chosen output bias, which for
        // maxpool equals or shrinks the input bias. The driver conservatively
        // re-reads the status register between stages — modeled by reading
        // it in the command stream (a status read, not a data beat).
        cmds.push(Cmd::read(fx::STATUS_OUT_BIAS));
        rows /= 2;
        in_base = out_base;
        exp_bias = tb; // same-lattice: device bias query is advisory here
        let _ = s;
    }

    let mut asm = Fragment::new();
    asm.push("FlexASR_ILA.fasrMaxpStore", &["%t"]);
    for _ in 0..stages {
        asm.push("FlexASR_ILA.fasrMaxpool", &[]);
    }
    asm.push("FlexASR_ILA.fasrMaxpLoad", &["%out"]);

    LoweredInvocation {
        target: Target::FlexAsr,
        asm,
        cmds,
        read: ReadPlan::FlexAf8 {
            base: fx::GB_BASE + in_base,
            shape: vec![r >> stages, c],
        },
    }
}

/// Naive per-op lowering of the same chain (each stage stores and loads)
/// — the baseline that Fig. 7 / the fig7 bench compares against.
pub fn lower_flex_maxpool_chain_naive(
    dev: &FlexAsr,
    t: &Tensor,
    stages: usize,
) -> Vec<LoweredInvocation> {
    let mut out = Vec::new();
    let mut cur = t.clone();
    for _ in 0..stages {
        let inv = lower_flex_maxpool_chain(dev, &cur, 1);
        cur = crate::ir::interp::eval_op(&crate::ir::Op::TempMaxPool, &[&cur]).unwrap();
        // naive lowering also reads the result back after every stage
        out.push(inv);
    }
    out
}

// ----------------------------------------------------------------------
// HLSCNN lowering
// ----------------------------------------------------------------------

/// Lower `hlscnn_conv2d` (batch 1).
pub fn lower_hlscnn_conv2d(
    dev: &Hlscnn,
    x: &Tensor,
    w: &Tensor,
    stride: (usize, usize),
    pad: (usize, usize),
) -> LoweredInvocation {
    assert_eq!(x.shape[0], 1, "HLSCNN device is batch-1; driver loops batch");
    let (c, h, wd) = (x.shape[1], x.shape[2], x.shape[3]);
    let (o, kh, kw) = (w.shape[0], w.shape[2], w.shape[3]);
    let oh = (h + 2 * pad.0 - kh) / stride.0 + 1;
    let ow = (wd + 2 * pad.1 - kw) / stride.1 + 1;

    let mut cmds = Vec::new();
    stream_bytes(&mut cmds, hx::ACT_BASE, &hx::encode_act_nhwc(dev, x));
    stream_bytes(&mut cmds, hx::WGT_BASE, &hx::encode_wgt(dev, w));
    cmds.push(Cmd::write_u64(
        hx::CFG_SHAPE,
        (c as u64) | ((h as u64) << 12) | ((wd as u64) << 24) | ((o as u64) << 36),
    ));
    cmds.push(Cmd::write_u64(
        hx::CFG_KERNEL,
        (kh as u64)
            | ((kw as u64) << 8)
            | ((stride.0 as u64) << 16)
            | ((stride.1 as u64) << 24)
            | ((pad.0 as u64) << 32)
            | ((pad.1 as u64) << 40),
    ));
    cmds.push(Cmd::write_u64(hx::CFG_START, 1));

    let mut asm = Fragment::new();
    asm.push("HLSCNN_ILA.wr_act", &["%fmap"])
        .push("HLSCNN_ILA.wr_wgt", &["%filters"])
        .push("HLSCNN_ILA.cfg_conv_shape", &["%c", "%h", "%w", "%o"])
        .push("HLSCNN_ILA.cfg_conv_kernel", &["%k", "%s", "%p"])
        .push("HLSCNN_ILA.conv_start", &[])
        .push("HLSCNN_ILA.rd_out", &["%out"]);

    LoweredInvocation {
        target: Target::Hlscnn,
        asm,
        cmds,
        read: ReadPlan::HlscnnI16 { base: hx::OUT_BASE, shape: vec![1, o, oh, ow] },
    }
}

// ----------------------------------------------------------------------
// VTA lowering
// ----------------------------------------------------------------------

/// Lower `vta_gemm` (dense semantics).
pub fn lower_vta_gemm(dev: &Vta, x: &Tensor, w: &Tensor) -> LoweredInvocation {
    let (n, k) = (x.shape[0], x.shape[1]);
    let m = w.shape[0];
    let sx = dev.int8.select_scale(x.max_abs());
    let sw = dev.int8.select_scale(w.max_abs());
    let xc: Vec<u8> = x.data.iter().map(|&v| dev.int8.encode(v, sx) as u8).collect();
    let wc: Vec<u8> = w.data.iter().map(|&v| dev.int8.encode(v, sw) as u8).collect();

    let mut cmds = Vec::new();
    stream_bytes(&mut cmds, vx::INP_BASE, &xc);
    stream_bytes(&mut cmds, vx::WGT_BASE, &wc);
    cmds.push(Cmd::write(vx::INSN_ADDR, vx::insn_reset((n * m) as u32)));
    cmds.push(Cmd::write(vx::INSN_ADDR, vx::insn_gemm(n as u16, k as u16, m as u16)));

    let mut asm = Fragment::new();
    asm.push("VTA_ILA.load_inp", &["%x"])
        .push("VTA_ILA.load_wgt", &["%w"])
        .push("VTA_ILA.reset_acc", &[])
        .push("VTA_ILA.gemm", &["%n", "%k", "%m"])
        .push("VTA_ILA.store_out", &["%out"]);

    LoweredInvocation {
        target: Target::Vta,
        asm,
        cmds,
        read: ReadPlan::VtaI32 { base: vx::ACC_BASE, shape: vec![n, m], scale: sx * sw },
    }
}

// ----------------------------------------------------------------------
// Result retrieval
// ----------------------------------------------------------------------

/// Execute a lowered invocation on a fresh ILA simulator of the right
/// device and decode the result per its read plan.
pub fn execute_lowered(
    inv: &LoweredInvocation,
    sim: &mut crate::ila::sim::IlaSim,
) -> anyhow::Result<Tensor> {
    sim.run(&inv.cmds).map_err(|e| anyhow::anyhow!("{e}"))?;
    read_result(inv, sim)
}

/// Decode a completed invocation's result from device state.
pub fn read_result(
    inv: &LoweredInvocation,
    sim: &mut crate::ila::sim::IlaSim,
) -> anyhow::Result<Tensor> {
    let fetch = |sim: &mut crate::ila::sim::IlaSim,
                 base: u64,
                 nbytes: usize|
     -> anyhow::Result<Vec<u8>> {
        let mut out = Vec::with_capacity(nbytes);
        let mut addr = base;
        while out.len() < nbytes {
            let d = sim
                .step(&Cmd::read(addr))
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .ok_or_else(|| anyhow::anyhow!("read returned no data"))?;
            out.extend_from_slice(&d);
            addr += 16;
        }
        out.truncate(nbytes);
        Ok(out)
    };
    match &inv.read {
        ReadPlan::FlexAf8 { base, shape } => {
            let fmt = crate::numerics::adaptivfloat::AdaptivFloatFormat::new(8, 3);
            let ob = sim
                .step(&Cmd::read(fx::STATUS_OUT_BIAS))
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .unwrap()[0] as i8 as i32;
            let n: usize = shape.iter().product();
            let codes = fetch(sim, *base, n)?;
            Ok(fx::decode_tensor(&fmt, &codes, ob, shape))
        }
        ReadPlan::HlscnnI16 { base, shape } => {
            let n: usize = shape.iter().product();
            let bytes = fetch(sim, *base, 2 * n)?;
            let codes: Vec<i16> = bytes
                .chunks(2)
                .map(|p| i16::from_le_bytes(p.try_into().unwrap()))
                .collect();
            let dev = Hlscnn::default();
            Ok(hx::decode_out_nchw(&dev, &codes, shape))
        }
        ReadPlan::VtaI32 { base, shape, scale } => {
            let n: usize = shape.iter().product();
            let bytes = fetch(sim, *base, 4 * n)?;
            let vals: Vec<f32> = bytes
                .chunks(4)
                .map(|q| i32::from_le_bytes(q.try_into().unwrap()) as f32 * scale)
                .collect();
            Ok(Tensor::new(shape.clone(), vals))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Accelerator;
    use crate::ila::sim::IlaSim;
    use crate::util::Rng;

    #[test]
    fn lowered_linear_runs_end_to_end() {
        let dev = FlexAsr::new();
        let mut rng = Rng::new(71);
        let x = dev.quant(&Tensor::randn(&[4, 16], &mut rng, 1.0));
        let w = dev.quant(&Tensor::randn(&[8, 16], &mut rng, 0.3));
        let b = dev.quant(&Tensor::randn(&[8], &mut rng, 0.1));
        let inv = lower_flex_linear(&dev, &x, &w, &b);
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_lowered(&inv, &mut sim).unwrap();
        // the MMIO result equals the tensor-level fast path modulo the
        // codec roundtrip of operands
        let expect = dev.linear(&x, &w, &b);
        assert!(got.rel_error(&expect) < 0.02, "err {}", got.rel_error(&expect));
        assert!(inv.asm.len() >= 8, "Fig. 5(c)-style fragment emitted");
    }

    #[test]
    fn maxpool_chain_optimized_moves_less_data() {
        let dev = FlexAsr::new();
        let mut rng = Rng::new(72);
        let t = dev.quant(&Tensor::randn(&[64, 64], &mut rng, 1.0));
        let fused = lower_flex_maxpool_chain(&dev, &t, 4);
        let naive = lower_flex_maxpool_chain_naive(&dev, &t, 4);
        let naive_beats: usize = naive.iter().map(|i| i.data_beats()).sum();
        // naive: 256+128+64+32 = 480 store beats (plus ~240 read-back
        // beats not counted here since reads happen in read_result);
        // fused: one 256-beat store. Require a clear win on stores alone.
        assert!(
            fused.data_beats() * 5 < naive_beats * 3,
            "fused {} vs naive {naive_beats}",
            fused.data_beats()
        );

        // and the fused program computes the right maxpool
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_lowered(&fused, &mut sim).unwrap();
        let mut expect = t.clone();
        for _ in 0..4 {
            expect =
                crate::ir::interp::eval_op(&crate::ir::Op::TempMaxPool, &[&expect])
                    .unwrap();
        }
        assert!(got.rel_error(&expect) < 1e-5);
    }

    #[test]
    fn lowered_hlscnn_conv_end_to_end() {
        let dev = Hlscnn::default();
        let mut rng = Rng::new(73);
        let x = Tensor::randn(&[1, 3, 6, 6], &mut rng, 1.0);
        let w = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.2);
        let inv = lower_hlscnn_conv2d(&dev, &x, &w, (1, 1), (1, 1));
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_lowered(&inv, &mut sim).unwrap();
        let expect = dev.conv2d(&x, &w, (1, 1), (1, 1));
        assert!(got.max_abs_diff(&expect) <= dev.cfg.act_fmt.step() + 1e-6);
    }

    #[test]
    fn lowered_vta_gemm_end_to_end() {
        let dev = Vta::new();
        let mut rng = Rng::new(74);
        let x = dev.quant(&Tensor::randn(&[4, 16], &mut rng, 1.0));
        let w = dev.quant(&Tensor::randn(&[8, 16], &mut rng, 1.0));
        let inv = lower_vta_gemm(&dev, &x, &w);
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_lowered(&inv, &mut sim).unwrap();
        let expect = dev.gemm(&x, &w);
        assert_eq!(got.rel_error(&expect), 0.0, "VTA GEMM is exact");
    }
}
