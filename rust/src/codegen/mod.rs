//! The shared code-generation machinery behind `Accelerator::lower` (the
//! Fig. 3(b)→(d) / Fig. 5 pipeline): the [`LoweredProgram`] /
//! [`LoweredInvocation`] / [`ReadPlan`] vocabulary every per-accelerator
//! lowering produces, the MMIO byte streamer, and the executors that play
//! a lowered program against an [`crate::ila::sim::IlaSim`] and decode /
//! stitch its result.
//!
//! A lowered *program* is a sequence of *invocations* — each one MMIO
//! write burst + trigger (+ optional read-back) — because one tensor op
//! frequently needs **multiple architecture-level instructions**: a layer
//! whose operands exceed the device buffers is tiled by the driver
//! (weight-row tiles for FlexASR linear, per-step gate tiles for LSTM,
//! output-channel tiles for HLSCNN conv2d, flat chunks for the VTA ALU),
//! exactly as the ILA papers model real driver behaviour. Single-trigger
//! ops are the degenerate one-invocation program
//! ([`LoweredProgram::single`]). Invocations of one program execute on
//! one simulator session **without intervening resets**, so operands
//! staged by an earlier invocation (the activation tensor, the input
//! matrix) stay resident for later tiles.
//!
//! The per-op lowerings themselves live with their accelerators
//! (`accel::{flexasr,hlscnn,vta}`), reached through the
//! [`crate::accel::Accelerator::lower`] trait method — there are no
//! free-function lowerings here any more. The §5.1 fused maxpool-chain
//! lowering is `FlexAsr::lower_maxpool_chain`; its program-level
//! accounting stays in [`optimize`].
//!
//! Lowering is **two-phase**: `Accelerator::lower` produces a
//! weight-keyed [`ProgramTemplate`] whose bursts are either concrete
//! payloads (weights, config, `DMA_CTRL` descriptors) or symbolic
//! [`OperandSlot`]s for the late-bound input operands, and a cheap
//! [`ProgramTemplate::bind`] fills the slots per call, yielding the
//! concrete [`LoweredProgram`] the executors play. The template is a
//! function of (op head, operand shapes, weight contents) only, so an
//! engine may cache it across input-varying calls — see
//! `session::ExecEngine`.

pub mod optimize;

use crate::accel::flexasr::model as fx;
use crate::accel::hlscnn::model as hx;
use crate::accel::vta::model as vx;
use crate::ila::asm::Fragment;
use crate::ila::Cmd;
use crate::ir::Target;
use crate::numerics::adaptivfloat::AdaptivFloatFormat;
use crate::numerics::fixed_point::FixedPointFormat;
use crate::numerics::int8::Int8Format;
use crate::tensor::Tensor;
use crate::util::fnv1a;
use std::collections::HashMap;
use std::fmt;
use std::ops::Range;
use std::sync::Arc;

/// The MMIO address range an operand burst stages into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MmioRegion {
    /// First byte address written.
    pub base: u64,
    /// Payload length in bytes.
    pub len: usize,
}

/// One fingerprinted MMIO command burst.
///
/// Commands are `Arc`-shared so identical bursts (the same weight tile
/// staged by many timesteps or sweep points) are encoded **once**
/// host-side and shared by every program that replays them, and the
/// content fingerprint + target region let an execution engine recognize
/// a burst that is already device-resident and skip re-streaming it
/// (operand residency — see `session::ExecEngine`).
#[derive(Debug, Clone)]
pub struct Burst {
    /// The MMIO commands, in order.
    pub cmds: Arc<[Cmd]>,
    /// Content fingerprint (address + enabled payload bytes of every
    /// command, in order).
    pub fingerprint: u64,
    /// The contiguous staging region this burst fills, for operand
    /// bursts; `None` for config/trigger tails (always streamed).
    pub region: Option<MmioRegion>,
}

impl Burst {
    /// An operand-staging burst: stream `payload` as 16-byte beats (with
    /// a byte-enabled short final beat) into `[base, base+len)`.
    pub fn stage(base: u64, payload: &[u8]) -> Self {
        let mut cmds = Vec::new();
        stream_bytes(&mut cmds, base, payload);
        let mut fp = fnv1a(0, &base.to_le_bytes());
        fp = fnv1a(fp, payload);
        Burst {
            cmds: cmds.into(),
            fingerprint: fp,
            region: Some(MmioRegion { base, len: payload.len() }),
        }
    }

    /// A control burst (configuration writes, triggers, status reads):
    /// no staging region, always streamed.
    pub fn control(cmds: Vec<Cmd>) -> Self {
        let mut fp = 0u64;
        for c in &cmds {
            fp = fnv1a(fp, &c.addr.to_le_bytes());
            fp = fnv1a(fp, if c.is_write { c.payload() } else { &[] });
        }
        Burst { cmds: cmds.into(), fingerprint: fp, region: None }
    }

    /// Bytes of write payload this burst moves over MMIO when streamed.
    pub fn payload_bytes(&self) -> u64 {
        self.cmds
            .iter()
            .filter(|c| c.is_write)
            .map(|c| c.len as u64)
            .sum()
    }
}

/// How to retrieve and decode an accelerator result after the command
/// stream has executed. Each plan carries the device's *configured*
/// storage format (design revisions differ), so decoding never assumes a
/// default-configured device.
#[derive(Debug, Clone)]
pub enum ReadPlan {
    /// FlexASR: read `status_out_bias`, then AF8 codes at `base`.
    FlexAf8 {
        /// MMIO address of the first code.
        base: u64,
        /// Decoded tensor shape.
        shape: Vec<usize>,
        /// The device's configured storage format.
        fmt: AdaptivFloatFormat,
    },
    /// HLSCNN: read i16 codes at `base`, NHWC layout, in the device's
    /// activation format.
    HlscnnI16 {
        /// MMIO address of the first code.
        base: u64,
        /// Decoded tensor shape (NCHW).
        shape: Vec<usize>,
        /// The device's configured activation format.
        fmt: FixedPointFormat,
    },
    /// VTA: read i32 accumulators at `base`, dequantized by `scale`.
    VtaI32 {
        /// MMIO address of the first accumulator word.
        base: u64,
        /// Decoded tensor shape.
        shape: Vec<usize>,
        /// f32 dequantization factor (product of operand scales).
        scale: f32,
    },
}

/// One lowered accelerator invocation: a sequence of command bursts and,
/// when this invocation produces (part of) the op's result, a read plan
/// for it.
#[derive(Debug, Clone)]
pub struct LoweredInvocation {
    /// Owning accelerator.
    pub target: Target,
    /// The Fig. 5(c) assembly-level fragment.
    pub asm: Fragment,
    /// The Fig. 5(d) MMIO command stream, as fingerprinted [`Burst`]s:
    /// operand-staging bursts (region-tagged, residency-trackable)
    /// followed by config/trigger control bursts.
    pub bursts: Vec<Burst>,
    /// How to retrieve this invocation's result; `None` for invocations
    /// whose effect stays in device state (operand staging, intermediate
    /// tiles of a multi-trigger program).
    pub read: Option<ReadPlan>,
}

/// How a multi-invocation program's read-backs combine into the op's
/// final tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Stitch {
    /// The last read-back *is* the result (single-trigger ops; programs
    /// whose tiles accumulate in device memory and read once at the end).
    Last,
    /// Concatenate the read-backs along `axis` (tile outputs are
    /// contiguous blocks of the result along that axis), then reshape to
    /// `shape` — bit-exact data assembly, no arithmetic.
    Concat {
        /// Axis the tiles partition.
        axis: usize,
        /// Final result shape.
        shape: Vec<usize>,
    },
}

/// One lowered accelerator *op*: a sequence of invocations plus the
/// stitch step combining their read-backs. See the module docs for why
/// this is a sequence (driver-side tiling).
///
/// **Invariant:** a program whose stitch is [`Stitch::Last`] must carry
/// its read plan on exactly one invocation — the one producing the op
/// result. `Last` used to silently discard earlier read-backs; since the
/// stream-path hardening pass, [`stitch_parts`] rejects multi-read
/// `Last` programs with a structured error so a future lowering cannot
/// mask a lost tile.
#[derive(Debug, Clone)]
pub struct LoweredProgram {
    /// The invocations, in execution order.
    pub invocations: Vec<LoweredInvocation>,
    /// How read-backs assemble into the op result.
    pub stitch: Stitch,
    /// Driver-side calibration mirrors this lowering had to compute (the
    /// tiled-linear forced-bias replay, the tiled-LSTM `lstm_traced`
    /// bias-schedule replay). The engine's lowering cache reports a
    /// `mirror_hits` counter from this: a cache hit on a program with
    /// `mirrors > 0` is a full mirror recomputation avoided.
    pub mirrors: usize,
}

impl LoweredProgram {
    /// The degenerate single-trigger program.
    pub fn single(inv: LoweredInvocation) -> Self {
        LoweredProgram { invocations: vec![inv], stitch: Stitch::Last, mirrors: 0 }
    }

    /// Owning accelerator (programs never mix targets).
    pub fn target(&self) -> Target {
        self.invocations[0].target
    }

    /// Total MMIO beats moving tensor data across all invocations.
    pub fn data_beats(&self) -> usize {
        self.invocations.iter().map(|i| i.data_beats()).sum()
    }

    /// Total enabled payload bytes crossing MMIO into data windows.
    pub fn data_bytes(&self) -> u64 {
        self.invocations.iter().map(|i| i.data_bytes()).sum()
    }

    /// Total bytes moved by on-device `DMA_CTRL` replays.
    pub fn dma_replay_bytes(&self) -> u64 {
        self.invocations.iter().map(|i| i.dma_replay_bytes()).sum()
    }

    /// True when the driver tiled the op into multiple triggers.
    pub fn is_tiled(&self) -> bool {
        self.invocations.len() > 1
    }
}

/// True when `addr` lies in an operand/result data window of any device:
/// FlexASR global buffer / PE weight buffer / weight-staging DRAM,
/// HLSCNN activation / weight / output SRAM, VTA input / weight /
/// accumulator buffer. (VTA accumulators and HLSCNN outputs count —
/// VtaAdd stages its first operand directly into the accumulator window,
/// and only host *writes* are tallied, so device-produced results never
/// double-count.)
fn in_data_window(addr: u64) -> bool {
    (fx::GB_BASE..fx::GB_BASE + fx::GB_SIZE as u64).contains(&addr)
        || (fx::PE_WGT_BASE..fx::PE_WGT_BASE + fx::PE_WGT_SIZE as u64).contains(&addr)
        || (fx::WGT_DRAM_BASE..fx::WGT_DRAM_BASE + fx::WGT_DRAM_SIZE as u64)
            .contains(&addr)
        || (hx::ACT_BASE..hx::ACT_BASE + hx::ACT_SIZE as u64).contains(&addr)
        || (hx::WGT_BASE..hx::WGT_BASE + hx::WGT_SIZE as u64).contains(&addr)
        || (hx::OUT_BASE..hx::OUT_BASE + hx::OUT_SIZE as u64).contains(&addr)
        || (vx::INP_BASE..vx::INP_BASE + vx::INP_SIZE as u64).contains(&addr)
        || (vx::WGT_BASE..vx::WGT_BASE + vx::WGT_SIZE as u64).contains(&addr)
        || (vx::ACC_BASE..vx::ACC_BASE + vx::ACC_SIZE as u64).contains(&addr)
}

impl LoweredInvocation {
    /// All MMIO commands of this invocation, in stream order.
    pub fn cmds(&self) -> impl Iterator<Item = &Cmd> {
        self.bursts.iter().flat_map(|b| b.cmds.iter())
    }

    /// Number of MMIO beats moving tensor data (the §5.1 metric): write
    /// beats into a data window, exactly as [`stream_bytes`] put them on
    /// the bus — a byte-enabled short final beat is one beat. Read
    /// commands touching a data window are result fetches, not data
    /// pushed by the host, and are excluded; on-device `DMA_CTRL` replay
    /// traffic never crosses MMIO and is reported separately by
    /// [`Self::dma_replay_bytes`].
    pub fn data_beats(&self) -> usize {
        self.cmds().filter(|c| c.is_write && in_data_window(c.addr)).count()
    }

    /// Enabled payload bytes crossing MMIO into data windows. Unlike the
    /// beat count this gives a short final beat its true size: a 22-byte
    /// stage is 2 beats but 22 bytes, not 32.
    pub fn data_bytes(&self) -> u64 {
        self.cmds()
            .filter(|c| c.is_write && in_data_window(c.addr))
            .map(|c| c.len as u64)
            .sum()
    }

    /// Bytes moved by on-device `DMA_CTRL` replays (staging DRAM → PE
    /// weight buffer), decoded from each descriptor's length field — the
    /// same count the simulator copies when the descriptor executes.
    pub fn dma_replay_bytes(&self) -> u64 {
        self.cmds()
            .filter(|c| c.is_write && c.addr == fx::DMA_CTRL)
            .map(|c| c.data_u64() >> 44)
            .sum()
    }
}

impl ReadPlan {
    /// Bytes this plan fetches from device memory (stored codes/words,
    /// before decode): AF8 is one byte per element, HLSCNN two, VTA
    /// four. The FlexASR status-bias beat is control, not data, and is
    /// excluded.
    pub fn read_bytes(&self) -> u64 {
        match self {
            ReadPlan::FlexAf8 { shape, .. } => {
                shape.iter().product::<usize>() as u64
            }
            ReadPlan::HlscnnI16 { shape, .. } => {
                2 * shape.iter().product::<usize>() as u64
            }
            ReadPlan::VtaI32 { shape, .. } => {
                4 * shape.iter().product::<usize>() as u64
            }
        }
    }
}

/// Stream a byte buffer as 16-byte MMIO writes starting at `base` (used
/// by every per-accelerator lowering). An unaligned final slice becomes a
/// **byte-enabled short beat** ([`Cmd::write_bytes`]); the seed zero-
/// padded it to 16 bytes, clobbering up to 15 bytes past the slice's end
/// — fatal for adjacent staged regions packed closer than a beat.
pub fn stream_bytes(cmds: &mut Vec<Cmd>, base: u64, bytes: &[u8]) {
    for (i, chunk) in bytes.chunks(16).enumerate() {
        cmds.push(Cmd::write_bytes(base + 16 * i as u64, chunk));
    }
}

// ----------------------------------------------------------------------
// Result retrieval
// ----------------------------------------------------------------------

/// Execute a whole lowered program on one simulator session — invocations
/// run in order with **no resets in between** (staged operands stay
/// resident) — collecting each invocation's read-back and stitching them
/// into the op result. The caller is responsible for resetting the
/// simulator *before* the program (the execution engine does a
/// dirty-region reset).
pub fn execute_program(
    prog: &LoweredProgram,
    sim: &mut crate::ila::sim::IlaSim,
) -> anyhow::Result<Tensor> {
    let mut parts = Vec::new();
    for inv in &prog.invocations {
        for burst in &inv.bursts {
            sim.run(&burst.cmds).map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        if inv.read.is_some() {
            parts.push(read_result(inv, sim)?);
        }
    }
    stitch_parts(parts, &prog.stitch)
}

/// Assemble invocation read-backs per the program's stitch step.
///
/// A [`Stitch::Last`] program with more than one read-back is rejected
/// (see the invariant on [`LoweredProgram`]): the extra read plans mean a
/// lowering produced tiles it then threw away, which `Last` used to mask
/// silently.
pub fn stitch_parts(mut parts: Vec<Tensor>, stitch: &Stitch) -> anyhow::Result<Tensor> {
    match stitch {
        Stitch::Last => {
            anyhow::ensure!(
                parts.len() <= 1,
                "Stitch::Last over {} read-backs would discard {} tile(s); \
                 a Last program must carry exactly one read plan",
                parts.len(),
                parts.len() - 1
            );
            parts.pop().ok_or_else(|| anyhow::anyhow!("program produced no read-back"))
        }
        Stitch::Concat { axis, shape } => {
            if parts.is_empty() {
                anyhow::bail!("concat stitch over zero tiles");
            }
            let t = concat_axis(&parts, *axis)?;
            anyhow::ensure!(
                t.len() == shape.iter().product::<usize>(),
                "stitched {} elements, expected shape {shape:?}",
                t.len()
            );
            Ok(t.reshape(shape))
        }
    }
}

/// Concatenate tensors along `axis` (all other dims must agree).
///
/// Shape validation is structured (`anyhow`), not `debug_assert!`: a
/// malformed [`LoweredProgram`] must fail loudly in release builds too,
/// instead of silently corrupting the stitched tensor.
fn concat_axis(parts: &[Tensor], axis: usize) -> anyhow::Result<Tensor> {
    let first = &parts[0];
    let rank = first.shape.len();
    anyhow::ensure!(axis < rank, "concat axis {axis} out of rank {rank}");
    for (i, p) in parts.iter().enumerate() {
        anyhow::ensure!(
            p.shape.len() == rank
                && p.shape[..axis] == first.shape[..axis]
                && p.shape[axis + 1..] == first.shape[axis + 1..],
            "tile {i} shape {:?} disagrees with tile 0 shape {:?} off axis {axis}",
            p.shape,
            first.shape
        );
    }
    let outer: usize = first.shape[..axis].iter().product();
    let inner: usize = first.shape[axis + 1..].iter().product();
    let axis_total: usize = parts.iter().map(|p| p.shape[axis]).sum();
    let mut shape = first.shape.clone();
    shape[axis] = axis_total;
    let mut data = vec![0.0f32; outer * axis_total * inner];
    let mut axis_off = 0usize;
    for p in parts {
        let block = p.shape[axis] * inner;
        for o in 0..outer {
            let dst = (o * axis_total + axis_off) * inner;
            data[dst..dst + block].copy_from_slice(&p.data[o * block..(o + 1) * block]);
        }
        axis_off += p.shape[axis];
    }
    Ok(Tensor::new(shape, data))
}

/// Execute a single lowered invocation and decode its result (requires a
/// read plan; use [`execute_program`] for whole ops).
pub fn execute_lowered(
    inv: &LoweredInvocation,
    sim: &mut crate::ila::sim::IlaSim,
) -> anyhow::Result<Tensor> {
    for burst in &inv.bursts {
        sim.run(&burst.cmds).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    read_result(inv, sim)
}

/// Decode a completed invocation's result from device state. Reads that
/// return no data surface as structured errors instead of being masked
/// with zeros. Errors when the invocation has no read plan.
pub fn read_result(
    inv: &LoweredInvocation,
    sim: &mut crate::ila::sim::IlaSim,
) -> anyhow::Result<Tensor> {
    let plan = inv
        .read
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("invocation has no read plan"))?;
    let fetch = |sim: &mut crate::ila::sim::IlaSim,
                 base: u64,
                 nbytes: usize|
     -> anyhow::Result<Vec<u8>> {
        let mut out = Vec::with_capacity(nbytes);
        let mut addr = base;
        while out.len() < nbytes {
            let d = sim
                .step(&Cmd::read(addr))
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .ok_or_else(|| {
                    anyhow::anyhow!("read at 0x{addr:08X} returned no data")
                })?;
            out.extend_from_slice(&d);
            addr += 16;
        }
        out.truncate(nbytes);
        Ok(out)
    };
    match plan {
        ReadPlan::FlexAf8 { base, shape, fmt } => {
            let ob = sim
                .step(&Cmd::read(fx::STATUS_OUT_BIAS))
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "status read at 0x{:08X} returned no data",
                        fx::STATUS_OUT_BIAS
                    )
                })?[0] as i8 as i32;
            let n: usize = shape.iter().product();
            let codes = fetch(sim, *base, n)?;
            Ok(fx::decode_tensor(fmt, &codes, ob, shape))
        }
        ReadPlan::HlscnnI16 { base, shape, fmt } => {
            let n: usize = shape.iter().product();
            let bytes = fetch(sim, *base, 2 * n)?;
            let codes: Vec<i16> = bytes
                .chunks(2)
                .map(|p| i16::from_le_bytes(p.try_into().unwrap()))
                .collect();
            Ok(hx::decode_out_nchw_fmt(*fmt, &codes, shape))
        }
        ReadPlan::VtaI32 { base, shape, scale } => {
            let n: usize = shape.iter().product();
            let bytes = fetch(sim, *base, 4 * n)?;
            let vals: Vec<f32> = bytes
                .chunks(4)
                .map(|q| i32::from_le_bytes(q.try_into().unwrap()) as f32 * scale)
                .collect();
            Ok(Tensor::new(shape.clone(), vals))
        }
    }
}

// ----------------------------------------------------------------------
// Program templates: weight-keyed programs with late-bound input slots
// ----------------------------------------------------------------------

/// The wire codec a late-bound operand is encoded with at bind time —
/// the same storage codec the driver-side lowering would have used on a
/// concrete tensor.
#[derive(Debug, Clone, Copy)]
pub enum SlotCodec {
    /// FlexASR AdaptivFloat-8 codes. The whole-tensor exponent bias is
    /// chosen at bind (`select_bias(max_abs)`) and patched into every
    /// command lane registered with [`BindValue::SlotBias`].
    FlexAf8 {
        /// Storage format of the owning design revision.
        fmt: AdaptivFloatFormat,
    },
    /// HLSCNN NHWC activation stream: little-endian i16 fixed-point
    /// codes in the device's configured activation format.
    HlscnnActNhwc {
        /// Activation format of the owning design revision.
        fmt: FixedPointFormat,
    },
    /// VTA int8 codes, one byte per element, quantized by the bind-time
    /// scale resolved from the template's [`ScaleRule`].
    VtaI8,
    /// VTA int8 codes widened to little-endian i32 accumulator words
    /// (the ALU path pre-loads both operands into the accumulator
    /// window), quantized by the [`ScaleRule`] scale.
    VtaI8Acc,
}

impl SlotCodec {
    /// Wire bytes per tensor element: AF8 and VTA int8 codes are one
    /// byte, HLSCNN activations are little-endian i16 words, and the
    /// VTA ALU path widens each int8 code to an i32 accumulator word.
    pub fn elem_bytes(&self) -> usize {
        match self {
            SlotCodec::FlexAf8 { .. } | SlotCodec::VtaI8 => 1,
            SlotCodec::HlscnnActNhwc { .. } => 2,
            SlotCodec::VtaI8Acc => 4,
        }
    }

    /// Encode a bound operand into its full wire byte stream. Returns
    /// the bytes plus the AdaptivFloat exponent bias chosen (0 for
    /// non-AF codecs). `scale` is the int8 scale for the VTA codecs and
    /// ignored elsewhere.
    fn encode(&self, t: &Tensor, scale: f32) -> (Vec<u8>, i32) {
        match self {
            SlotCodec::FlexAf8 { fmt } => fx::encode_tensor(fmt, t),
            SlotCodec::HlscnnActNhwc { fmt } => {
                (hx::encode_act_nhwc_fmt(*fmt, t), 0)
            }
            SlotCodec::VtaI8 => {
                let f = Int8Format;
                (t.data.iter().map(|&v| f.encode(v, scale) as u8).collect(), 0)
            }
            SlotCodec::VtaI8Acc => {
                let f = Int8Format;
                let mut out = Vec::with_capacity(t.data.len() * 4);
                for &v in &t.data {
                    out.extend_from_slice(
                        &(f.encode(v, scale) as i32).to_le_bytes(),
                    );
                }
                (out, 0)
            }
        }
    }
}

/// A symbolic operand burst inside a [`ProgramTemplate`]: the staging
/// region is fixed by the template, the payload arrives at bind time.
#[derive(Debug, Clone)]
pub struct OperandSlot {
    /// Index into the op's operand list this slot is filled from.
    pub operand: usize,
    /// First byte address the payload stages into.
    pub base: u64,
    /// The slice of the operand's encoded byte stream this slot stages
    /// — tiled/chunked lowerings split one operand across several slots.
    pub bytes: Range<usize>,
    /// Wire codec for the operand.
    pub codec: SlotCodec,
}

/// One burst position of a template invocation: a concrete fingerprinted
/// burst (weights, config, triggers, `DMA_CTRL` words) or a late-bound
/// operand slot.
#[derive(Debug, Clone)]
pub enum TemplateBurst {
    /// Input-independent payload, shared by every bind of the template.
    Concrete(Burst),
    /// Late-bound operand staging burst.
    Slot(OperandSlot),
}

/// One invocation of a [`ProgramTemplate`] (mirrors
/// [`LoweredInvocation`], with slot-or-concrete bursts).
#[derive(Debug, Clone)]
pub struct TemplateInvocation {
    /// Owning accelerator.
    pub target: Target,
    /// The Fig. 5(c) assembly-level fragment.
    pub asm: Fragment,
    /// The burst positions, in stream order.
    pub bursts: Vec<TemplateBurst>,
    /// Read plan (a `VtaI32` scale here is a placeholder the bind step
    /// rewrites per the template's [`ScaleRule`]).
    pub read: Option<ReadPlan>,
}

/// A bind-time value patched into an 8-bit lane of a control command.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BindValue {
    /// The AdaptivFloat exponent bias the bind chose for a slotted
    /// operand (FlexASR `CFG_EXP_BIAS` lanes).
    SlotBias {
        /// Operand index the bias belongs to.
        operand: usize,
    },
    /// The input-independent-formula output bias evaluated at bind
    /// ([`BindCalib`] — FlexASR forced `CFG_OUT_BIAS` low byte).
    OutBias,
    /// The LSTM wide accumulator bias evaluated at bind ([`BindCalib`] —
    /// FlexASR `CFG_EXP_BIAS2` lane).
    WideBias,
}

/// One 8-bit lane patch the bind step applies to a control command: the
/// template keeps every input-independent bit of the word (opcodes,
/// sizes, the `CFG_OUT_BIAS` force flag) and the bind overwrites only
/// the registered byte lane.
#[derive(Debug, Clone, Copy)]
pub struct CmdPatch {
    /// Invocation index the patched command lives in.
    pub invocation: usize,
    /// Burst index within the invocation (must be a control burst).
    pub burst: usize,
    /// Command index within the burst.
    pub cmd: usize,
    /// Bit offset of the 8-bit lane to overwrite.
    pub shift: u32,
    /// The value resolved at bind time.
    pub value: BindValue,
}

/// Input-independent calibration carried by a template: the weight-side
/// factors of the conservative whole-layer bias bound. The bind step
/// combines them with the (cheap) input-side factor — see
/// `accel::flexasr` for the shared bound helpers both this and the
/// functional fast path evaluate, guaranteeing bit-identical biases.
#[derive(Debug, Clone)]
pub enum BindCalib {
    /// No host-side calibration (HLSCNN, VTA, FlexASR row-wise ops whose
    /// output bias the device auto-selects).
    None,
    /// FlexASR linear: `out_bias = select_bias(w_row_norm · ‖xq row‖₂ +
    /// b_max)` (Cauchy–Schwarz row bound over codec-roundtripped
    /// values).
    FlexLinear {
        /// Storage format (bias selection + operand roundtrip).
        af: AdaptivFloatFormat,
        /// Max L2 norm over rows of the roundtripped weight matrix.
        w_row_norm: f32,
        /// Max |b| over the roundtripped bias vector.
        b_max: f32,
        /// Row length of the input operand (the contraction dim).
        k: usize,
    },
    /// FlexASR LSTM: `wide = select_bias(wi_norm · ‖xq row‖₂ + wh_norm ·
    /// √h + b_max)`, constant across timesteps (h is roundtripped at
    /// bias `select_bias(1.0)` so `‖h row‖₂ ≤ √h`).
    FlexLstm {
        /// Storage format (input operand roundtrip).
        af: AdaptivFloatFormat,
        /// Wide accumulator format (bias selection).
        af_wide: AdaptivFloatFormat,
        /// Max row L2 of the roundtripped input-gate weights.
        wi_row_norm: f32,
        /// Max row L2 of the roundtripped hidden-gate weights.
        wh_row_norm: f32,
        /// Max |b| over the roundtripped gate bias.
        b_max: f32,
        /// Input feature dimension (x row length).
        feat: usize,
        /// Hidden dimension.
        hidden: usize,
    },
}

impl BindCalib {
    /// The forced output bias for this bind, if the calibration defines
    /// one.
    fn out_bias(&self, inputs: &[&Tensor]) -> Option<i32> {
        match self {
            BindCalib::FlexLinear { af, w_row_norm, b_max, k } => {
                let xq = fx::codec_roundtrip(af, inputs[0]);
                let xn = fx::max_row_l2(&xq.data, *k);
                Some(crate::accel::flexasr::linear_bias_bound(
                    af, *w_row_norm, xn, *b_max,
                ))
            }
            _ => None,
        }
    }

    /// The LSTM wide accumulator bias for this bind, if defined.
    fn wide_bias(&self, inputs: &[&Tensor]) -> Option<i32> {
        match self {
            BindCalib::FlexLstm {
                af,
                af_wide,
                wi_row_norm,
                wh_row_norm,
                b_max,
                feat,
                hidden,
            } => {
                let xq = fx::codec_roundtrip(af, inputs[0]);
                let xn = fx::max_row_l2(&xq.data, *feat);
                Some(crate::accel::flexasr::lstm_wide_bias_bound(
                    af_wide,
                    *wi_row_norm,
                    xn,
                    *wh_row_norm,
                    *hidden,
                    *b_max,
                ))
            }
            _ => None,
        }
    }
}

/// How the bind step resolves int8 quantization scales (VTA) and
/// rewrites the `VtaI32` read-plan dequantization factor.
#[derive(Debug, Clone, Copy)]
pub enum ScaleRule {
    /// No bind-time scale (FlexASR/HLSCNN codecs carry their formats).
    None,
    /// VTA GEMM: operand 0 quantizes at `select_scale(max_abs)`; the
    /// read-back dequantizes by `sx · sw` (`sw` fixed when the template
    /// was lowered from the weight operand).
    VtaGemm {
        /// Weight scale chosen at lowering.
        sw: f32,
    },
    /// VTA ALU add: every slotted operand shares one bind-time scale
    /// (`select_scale` over their joint max), which also dequantizes the
    /// read-back.
    VtaAdd,
}

/// Why [`ProgramTemplate::bind`] rejected an operand set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BindError {
    /// Wrong number of operands for the templated op.
    OperandCount {
        /// Operands the template was lowered for.
        expected: usize,
        /// Operands supplied.
        got: usize,
    },
    /// An operand's shape differs from the shape the template was
    /// lowered for (templates are shape-keyed).
    ShapeMismatch {
        /// Offending operand index.
        operand: usize,
    },
    /// A *weight* operand's content fingerprint differs from the one
    /// baked into the template — the template's concrete weight bursts
    /// would silently stage stale weights, so the bind refuses
    /// (cache-key soundness).
    WeightMismatch {
        /// Offending operand index.
        operand: usize,
    },
    /// Internal template inconsistency: a patch or slot referenced a
    /// position that does not exist.
    Malformed {
        /// What was inconsistent.
        what: &'static str,
    },
}

impl fmt::Display for BindError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BindError::OperandCount { expected, got } => {
                write!(f, "template binds {expected} operands, got {got}")
            }
            BindError::ShapeMismatch { operand } => {
                write!(f, "operand {operand} shape differs from the template key")
            }
            BindError::WeightMismatch { operand } => write!(
                f,
                "weight operand {operand} content differs from the template \
                 fingerprint; re-lower instead of re-binding"
            ),
            BindError::Malformed { what } => {
                write!(f, "malformed template: {what}")
            }
        }
    }
}

impl std::error::Error for BindError {}

/// A bound, ready-to-play program plus what the bind resolved — handed
/// to the engine so binding cost and chosen calibration are observable.
#[derive(Debug, Clone)]
pub struct BoundProgram {
    /// The concrete program (plays exactly like a monolithic lowering).
    pub program: LoweredProgram,
    /// Payload bytes the bind encoded into slot bursts.
    pub slot_bytes: u64,
    /// AdaptivFloat biases chosen per slotted operand index.
    pub slot_biases: Vec<(usize, i32)>,
    /// Forced output bias this bind evaluated, if any.
    pub out_bias: Option<i32>,
    /// LSTM wide accumulator bias this bind evaluated, if any.
    pub wide_bias: Option<i32>,
    /// VTA read-back dequantization scale this bind resolved, if any.
    pub read_scale: Option<f32>,
}

/// A weight-keyed lowered-program template: phase one of the two-phase
/// lowering. Everything input-independent — weight bursts, `DMA_CTRL`
/// schedules, tile structure, config words, the weight-side bias-bound
/// factors — is concrete; input operands are [`OperandSlot`]s plus
/// [`CmdPatch`]es for the few command lanes that depend on them.
///
/// A template is valid for any operand set matching its shapes whose
/// weight operands match its fingerprints, which is exactly the
/// engine-side cache key (target, rev, op head, shapes, weight
/// fingerprints). [`Self::bind`] enforces the weight half at bind time.
#[derive(Debug, Clone)]
pub struct ProgramTemplate {
    /// Owning accelerator.
    pub target: Target,
    /// Template invocations, in execution order.
    pub invocations: Vec<TemplateInvocation>,
    /// How read-backs assemble into the op result.
    pub stitch: Stitch,
    /// Driver-side calibration mirrors a *monolithic* lowering would
    /// recompute per call and a template hit avoids (weight encodes +
    /// weight-side bound factors). Reported via the engine's
    /// `mirror_hits` counter.
    pub mirrors: usize,
    /// Shapes of every operand the template was lowered for.
    pub operand_shapes: Vec<Vec<usize>>,
    /// `(operand index, content fingerprint)` of each weight operand
    /// baked into concrete bursts.
    pub weight_ops: Vec<(usize, u64)>,
    /// Weight-side factors of the input-independent bias bound.
    pub calib: BindCalib,
    /// Bind-time int8 scale resolution (VTA).
    pub scale_rule: ScaleRule,
    /// Command-lane patches the bind applies.
    pub patches: Vec<CmdPatch>,
}

impl ProgramTemplate {
    /// Wrap a fully concrete program (no slots, no patches) as a
    /// template — the degenerate case for lowerings whose whole command
    /// stream is input-independent apart from the staged operands
    /// already being weights.
    pub fn concrete(
        target: Target,
        prog: LoweredProgram,
        operand_shapes: Vec<Vec<usize>>,
        weight_ops: Vec<(usize, u64)>,
    ) -> Self {
        ProgramTemplate {
            target,
            mirrors: prog.mirrors,
            stitch: prog.stitch.clone(),
            invocations: prog
                .invocations
                .into_iter()
                .map(|inv| TemplateInvocation {
                    target: inv.target,
                    asm: inv.asm,
                    bursts: inv
                        .bursts
                        .into_iter()
                        .map(TemplateBurst::Concrete)
                        .collect(),
                    read: inv.read,
                })
                .collect(),
            operand_shapes,
            weight_ops,
            calib: BindCalib::None,
            scale_rule: ScaleRule::None,
            patches: Vec::new(),
        }
    }

    /// Content fingerprints of the template's concrete region-staged
    /// bursts — the *weight set* of the template. This is what pooled
    /// checkout affinity routes on: two binds of one template share
    /// exactly these resident bursts, while slot bursts differ per call.
    pub fn weight_fingerprints(&self) -> Vec<u64> {
        self.invocations
            .iter()
            .flat_map(|i| i.bursts.iter())
            .filter_map(|b| match b {
                TemplateBurst::Concrete(b) if b.region.is_some() => {
                    Some(b.fingerprint)
                }
                _ => None,
            })
            .collect()
    }

    /// Every operand slot with its (invocation, burst) position.
    pub fn slots(&self) -> impl Iterator<Item = (usize, usize, &OperandSlot)> {
        self.invocations.iter().enumerate().flat_map(|(ii, inv)| {
            inv.bursts.iter().enumerate().filter_map(move |(bi, b)| match b {
                TemplateBurst::Slot(s) => Some((ii, bi, s)),
                TemplateBurst::Concrete(_) => None,
            })
        })
    }

    /// Number of multi-trigger invocations (templates mirror
    /// [`LoweredProgram::is_tiled`]).
    pub fn is_tiled(&self) -> bool {
        self.invocations.len() > 1
    }

    /// Bind input operands into the slots, producing a concrete program
    /// bit-identical to a monolithic lowering of the same operands.
    ///
    /// Validates shapes and weight fingerprints (a mutated weight tensor
    /// is rejected — the concrete weight bursts would be stale), encodes
    /// each slotted operand once through its codec, evaluates the
    /// input-side bias-bound factors, and applies the command-lane
    /// patches.
    pub fn bind(&self, inputs: &[&Tensor]) -> Result<BoundProgram, BindError> {
        if inputs.len() != self.operand_shapes.len() {
            return Err(BindError::OperandCount {
                expected: self.operand_shapes.len(),
                got: inputs.len(),
            });
        }
        for (i, sh) in self.operand_shapes.iter().enumerate() {
            if inputs[i].shape != *sh {
                return Err(BindError::ShapeMismatch { operand: i });
            }
        }
        for &(idx, fp) in &self.weight_ops {
            if inputs[idx].fingerprint() != fp {
                return Err(BindError::WeightMismatch { operand: idx });
            }
        }

        // Resolve the bind-time int8 scale (VTA) before encoding slots.
        let (slot_scale, read_scale) = match self.scale_rule {
            ScaleRule::None => (1.0, None),
            ScaleRule::VtaGemm { sw } => {
                let sx = Int8Format.select_scale(inputs[0].max_abs());
                (sx, Some(sx * sw))
            }
            ScaleRule::VtaAdd => {
                let mut m = 0.0f32;
                for (_, _, s) in self.slots() {
                    m = m.max(inputs[s.operand].max_abs());
                }
                let s = Int8Format.select_scale(m);
                (s, Some(s))
            }
        };

        // Encode each slotted operand exactly once (tiled lowerings
        // slice one stream across several slots).
        let mut streams: HashMap<usize, (Vec<u8>, i32)> = HashMap::new();
        for (_, _, s) in self.slots() {
            if !streams.contains_key(&s.operand) {
                streams.insert(
                    s.operand,
                    s.codec.encode(inputs[s.operand], slot_scale),
                );
            }
        }
        let out_bias = self.calib.out_bias(inputs);
        let wide_bias = self.calib.wide_bias(inputs);

        let mut slot_bytes = 0u64;
        let mut invocations = Vec::with_capacity(self.invocations.len());
        for (ii, tinv) in self.invocations.iter().enumerate() {
            let mut bursts = Vec::with_capacity(tinv.bursts.len());
            for (bi, tb) in tinv.bursts.iter().enumerate() {
                let mut burst = match tb {
                    TemplateBurst::Concrete(b) => b.clone(),
                    TemplateBurst::Slot(s) => {
                        let (stream, _) = &streams[&s.operand];
                        if s.bytes.end > stream.len() {
                            return Err(BindError::Malformed {
                                what: "slot range exceeds operand stream",
                            });
                        }
                        slot_bytes += s.bytes.len() as u64;
                        Burst::stage(s.base, &stream[s.bytes.clone()])
                    }
                };
                let pats = self
                    .patches
                    .iter()
                    .filter(|p| p.invocation == ii && p.burst == bi);
                let mut cmds: Option<Vec<Cmd>> = None;
                for p in pats {
                    let lane = match p.value {
                        BindValue::SlotBias { operand } => streams
                            .get(&operand)
                            .map(|&(_, b)| b)
                            .ok_or(BindError::Malformed {
                                what: "SlotBias patch on an unslotted operand",
                            })?,
                        BindValue::OutBias => out_bias.ok_or(
                            BindError::Malformed { what: "OutBias without calib" },
                        )?,
                        BindValue::WideBias => wide_bias.ok_or(
                            BindError::Malformed { what: "WideBias without calib" },
                        )?,
                    } as u8;
                    let cs = cmds.get_or_insert_with(|| burst.cmds.to_vec());
                    let c = cs.get_mut(p.cmd).ok_or(BindError::Malformed {
                        what: "patch command index out of range",
                    })?;
                    let v = (c.data_u64() & !(0xFFu64 << p.shift))
                        | ((lane as u64) << p.shift);
                    *c = Cmd::write_u64(c.addr, v);
                }
                if let Some(cs) = cmds {
                    // patched bursts are control bursts: rebuild so the
                    // fingerprint covers the patched payload
                    burst = Burst::control(cs);
                }
                bursts.push(burst);
            }
            let read = tinv.read.clone().map(|r| match (r, read_scale) {
                (ReadPlan::VtaI32 { base, shape, .. }, Some(scale)) => {
                    ReadPlan::VtaI32 { base, shape, scale }
                }
                (r, _) => r,
            });
            invocations.push(LoweredInvocation {
                target: tinv.target,
                asm: tinv.asm.clone(),
                bursts,
                read,
            });
        }
        Ok(BoundProgram {
            program: LoweredProgram {
                invocations,
                stitch: self.stitch.clone(),
                mirrors: self.mirrors,
            },
            slot_bytes,
            slot_biases: streams.iter().map(|(&i, &(_, b))| (i, b)).collect(),
            out_bias,
            wide_bias,
            read_scale,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{Accelerator, FlexAsr, Hlscnn, Vta};
    use crate::ila::sim::IlaSim;
    use crate::ir::Op;
    use crate::util::Rng;

    #[test]
    fn lowered_linear_runs_end_to_end() {
        let dev = FlexAsr::new();
        let mut rng = Rng::new(71);
        let x = dev.quant(&Tensor::randn(&[4, 16], &mut rng, 1.0));
        let w = dev.quant(&Tensor::randn(&[8, 16], &mut rng, 0.3));
        let b = dev.quant(&Tensor::randn(&[8], &mut rng, 0.1));
        let prog = dev.lower_concrete(&Op::FlexLinear, &[&x, &w, &b]).unwrap();
        assert!(!prog.is_tiled(), "small linear is a single trigger");
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_program(&prog, &mut sim).unwrap();
        // the MMIO result equals the tensor-level fast path bit-exactly:
        // both sides quantize through the same storage codec
        let expect = dev.linear(&x, &w, &b);
        assert_eq!(got, expect, "MMIO path diverges from tensor path");
        assert!(
            prog.invocations[0].asm.len() >= 8,
            "Fig. 5(c)-style fragment emitted"
        );
    }

    #[test]
    fn oversized_linear_tiles_instead_of_declining() {
        // weights beyond the 256 KiB PE buffer: the driver now emits a
        // multi-trigger row-tiled program instead of falling back
        let dev = FlexAsr::new();
        let mut rng = Rng::new(76);
        let x = Tensor::randn(&[2, 600], &mut rng, 1.0);
        let w = Tensor::randn(&[600, 600], &mut rng, 0.3);
        let b = Tensor::randn(&[600], &mut rng, 0.1);
        let prog = dev.lower_concrete(&Op::FlexLinear, &[&x, &w, &b]).unwrap();
        assert!(prog.is_tiled(), "600x600 weights exceed one tile");
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_program(&prog, &mut sim).unwrap();
        assert_eq!(got, dev.linear(&x, &w, &b), "tiled MMIO diverges");
    }

    #[test]
    fn stitch_concat_reassembles_column_tiles() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 5.0, 6.0]);
        let b = Tensor::new(vec![2, 1], vec![3.0, 7.0]);
        let out = stitch_parts(
            vec![a, b],
            &Stitch::Concat { axis: 1, shape: vec![2, 3] },
        )
        .unwrap();
        assert_eq!(out.data, vec![1.0, 2.0, 3.0, 5.0, 6.0, 7.0]);
        // Last with exactly one read-back is the result
        let last = stitch_parts(vec![Tensor::zeros(&[2])], &Stitch::Last).unwrap();
        assert_eq!(last.shape, vec![2]);
        assert!(stitch_parts(vec![], &Stitch::Last).is_err());
    }

    #[test]
    fn stitch_last_rejects_multiple_readbacks() {
        // Last used to silently discard every read-back but the final
        // one; a multi-read Last program is now a structured error so a
        // lowering cannot mask a lost tile
        let err = stitch_parts(
            vec![Tensor::ones(&[1]), Tensor::zeros(&[2])],
            &Stitch::Last,
        )
        .unwrap_err();
        assert!(err.to_string().contains("discard"), "{err}");
    }

    #[test]
    fn concat_shape_mismatch_is_a_structured_error() {
        // release builds used to skip the debug_assert and corrupt the
        // stitched tensor; malformed tiles must fail loudly
        let a = Tensor::new(vec![2, 2], vec![1.0; 4]);
        let bad = Tensor::new(vec![3, 1], vec![2.0; 3]);
        let err = stitch_parts(
            vec![a.clone(), bad],
            &Stitch::Concat { axis: 1, shape: vec![2, 3] },
        )
        .unwrap_err();
        assert!(err.to_string().contains("disagrees"), "{err}");
        // out-of-rank axis is rejected, not a panic
        let err = stitch_parts(
            vec![a.clone(), a],
            &Stitch::Concat { axis: 5, shape: vec![2, 4] },
        )
        .unwrap_err();
        assert!(err.to_string().contains("axis"), "{err}");
    }

    #[test]
    fn unaligned_burst_does_not_clobber_adjacent_region() {
        // regression for the stream_bytes zero-pad bug: a deliberately
        // unaligned tile boundary — 22 payload bytes, then a second
        // region starting 22 bytes in (packed tighter than a beat)
        let dev = FlexAsr::new();
        let mut sim = IlaSim::new(dev.build_ila());
        use crate::accel::flexasr::model as fxm;
        // pre-stage a sentinel where the adjacent region lives
        let sentinel = Burst::stage(fxm::PE_WGT_BASE + 16, &[0xAAu8; 16]);
        for c in sentinel.cmds.iter() {
            sim.step(c).unwrap();
        }
        // an unaligned 22-byte burst [0, 22) — its final beat covers
        // [16, 32) but only 6 bytes are enabled
        let tile = Burst::stage(fxm::PE_WGT_BASE, &[0x11u8; 22]);
        assert_eq!(tile.cmds.last().unwrap().len, 6, "short final beat");
        for c in tile.cmds.iter() {
            sim.step(c).unwrap();
        }
        let mem = sim.state.mem("pe_weight");
        assert_eq!(&mem[..22], &[0x11u8; 22][..]);
        assert_eq!(
            &mem[22..32],
            &[0xAAu8; 10][..],
            "the zero-pad clobbered the adjacent staged region"
        );
    }

    #[test]
    fn maxpool_chain_optimized_moves_less_data() {
        let dev = FlexAsr::new();
        let mut rng = Rng::new(72);
        let t = dev.quant(&Tensor::randn(&[64, 64], &mut rng, 1.0));
        let fused = dev.lower_maxpool_chain(&t, 4);
        let naive = dev.lower_maxpool_chain_naive(&t, 4);
        let naive_beats: usize = naive.iter().map(|i| i.data_beats()).sum();
        // naive: 256+128+64+32 = 480 store beats (plus ~240 read-back
        // beats not counted here since reads happen in read_result);
        // fused: one 256-beat store. Require a clear win on stores alone.
        assert!(
            fused.data_beats() * 5 < naive_beats * 3,
            "fused {} vs naive {naive_beats}",
            fused.data_beats()
        );

        // and the fused program computes the right maxpool
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_lowered(&fused, &mut sim).unwrap();
        let mut expect = t.clone();
        for _ in 0..4 {
            expect =
                crate::ir::interp::eval_op(&crate::ir::Op::TempMaxPool, &[&expect])
                    .unwrap();
        }
        assert!(got.rel_error(&expect) < 1e-5);
    }

    #[test]
    fn tiled_lstm_stages_each_weight_tile_once_not_once_per_step() {
        // the PR-4 lowering re-streamed every gate tile on every
        // timestep (~t x redundant traffic); the DRAM-staged lowering
        // moves each tile across MMIO exactly once, then DMA-replays it
        let dev = FlexAsr::new();
        let mut rng = Rng::new(78);
        let (t, e, h) = (4usize, 200usize, 200usize);
        let x = Tensor::randn(&[t, 1, e], &mut rng, 1.0);
        let wi = Tensor::randn(&[4 * h, e], &mut rng, 0.3);
        let wh = Tensor::randn(&[4 * h, h], &mut rng, 0.3);
        let b = Tensor::randn(&[4 * h], &mut rng, 0.1);
        let prog = dev.lower_concrete(&Op::FlexLstm { steps: t }, &[&x, &wi, &wh, &b]).unwrap();
        assert!(prog.is_tiled());
        assert_eq!(prog.mirrors, 1, "the bias-schedule mirror is declared");
        use crate::accel::flexasr::model as fxm;
        let dram_range =
            fxm::WGT_DRAM_BASE..fxm::WGT_DRAM_BASE + fxm::WGT_DRAM_SIZE as u64;
        let pe_range =
            fxm::PE_WGT_BASE..fxm::PE_WGT_BASE + fxm::PE_WGT_SIZE as u64;
        let dram_bytes: u64 = prog
            .invocations
            .iter()
            .flat_map(|i| i.bursts.iter())
            .filter(|bu| {
                bu.region.is_some_and(|r| dram_range.contains(&r.base))
            })
            .map(|bu| bu.payload_bytes())
            .sum();
        let weight_bytes = (4 * h * e + 4 * h * h + 4 * h) as u64;
        assert!(
            dram_bytes >= weight_bytes && dram_bytes < weight_bytes + weight_bytes / 2,
            "weights must cross MMIO about once ({dram_bytes} B staged for \
             {weight_bytes} B of weights), not once per timestep"
        );
        // no direct PE-window data writes remain: tiles ride the DMA
        assert!(
            prog.invocations.iter().flat_map(|i| i.cmds()).all(|c| {
                !c.is_write || !pe_range.contains(&c.addr)
            }),
            "per-step invocations must not re-stream weight tiles"
        );
        // and the program still computes the exact fast-path result
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_program(&prog, &mut sim).unwrap();
        assert_eq!(got, dev.lstm(&x, &wi, &wh, &b));
    }

    #[test]
    fn lowered_hlscnn_conv_end_to_end() {
        let dev = Hlscnn::default();
        let mut rng = Rng::new(73);
        let x = Tensor::randn(&[1, 3, 6, 6], &mut rng, 1.0);
        let w = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.2);
        let op = Op::HlscnnConv2d { stride: (1, 1), pad: (1, 1) };
        let prog = dev.lower_concrete(&op, &[&x, &w]).unwrap();
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_program(&prog, &mut sim).unwrap();
        // updated design: the integer kernel is shared, so the MMIO and
        // tensor views agree bit-exactly
        let expect = dev.conv2d(&x, &w, (1, 1), (1, 1));
        assert_eq!(got, expect);
    }

    #[test]
    fn lowered_vta_gemm_end_to_end() {
        let dev = Vta::new();
        let mut rng = Rng::new(74);
        let x = dev.quant(&Tensor::randn(&[4, 16], &mut rng, 1.0));
        let w = dev.quant(&Tensor::randn(&[8, 16], &mut rng, 1.0));
        let prog = dev.lower_concrete(&Op::VtaGemm, &[&x, &w]).unwrap();
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_program(&prog, &mut sim).unwrap();
        let expect = dev.gemm(&x, &w);
        assert_eq!(got.rel_error(&expect), 0.0, "VTA GEMM is exact");
    }

    #[test]
    fn lower_declines_foreign_and_untileable_ops() {
        let fa = FlexAsr::new();
        let mut rng = Rng::new(75);
        let x = Tensor::randn(&[1, 600], &mut rng, 1.0);
        let w = Tensor::randn(&[600, 600], &mut rng, 0.3);
        // foreign op: not this accelerator's
        assert!(fa.lower(&Op::VtaGemm, &[&x, &w]).is_none());
        // data movement has no single-op program
        assert!(fa.lower(&Op::FlexMaxpStore, &[&x]).is_none());
        // an input matrix that alone overflows the global buffer cannot
        // be staged even one row-tile at a time: decline, don't corrupt
        let xb = Tensor::randn(&[3, 30_000], &mut rng, 1.0);
        let wb = Tensor::randn(&[4, 30_000], &mut rng, 0.3);
        let bb = Tensor::randn(&[4], &mut rng, 0.1);
        assert!(fa.lower(&Op::FlexLinear, &[&xb, &wb, &bb]).is_none());
        // batched conv: HLSCNN is a batch-1 device
        let hl = Hlscnn::default();
        let xc = Tensor::randn(&[2, 3, 6, 6], &mut rng, 1.0);
        let k = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.2);
        let op = Op::HlscnnConv2d { stride: (1, 1), pad: (1, 1) };
        assert!(hl.lower(&op, &[&xc, &k]).is_none());
    }

    #[test]
    fn data_beat_accounting_matches_the_bus_on_unaligned_tails() {
        // a 22-byte stage: stream_bytes emits 2 beats (one full, one
        // byte-enabled short), so the beat count is 2 — but the payload
        // crossing the bus is 22 bytes, not 2 * 16
        let stage = Burst::stage(fx::GB_BASE, &[0x5Au8; 22]);
        let inv = LoweredInvocation {
            target: Target::FlexAsr,
            asm: Fragment::new(),
            bursts: vec![stage],
            read: None,
        };
        assert_eq!(inv.data_beats(), 2, "short final beat is one beat");
        assert_eq!(inv.data_bytes(), 22, "tail counts its true size");

        // a read command inside a data window (a result fetch) is not
        // data the host pushed: it must not inflate the beat count
        let mut with_read = inv.clone();
        with_read.bursts.push(Burst::control(vec![Cmd::read(fx::GB_BASE)]));
        assert_eq!(with_read.data_beats(), 2, "reads are not data beats");
        assert_eq!(with_read.data_bytes(), 22);

        // a DMA_CTRL descriptor is control, not a data beat; its replay
        // length is decoded from the descriptor word instead
        let mut with_dma = inv.clone();
        with_dma.bursts.push(Burst::control(vec![Cmd::write_u64(
            fx::DMA_CTRL,
            fx::dma_word(0, 0, 4096),
        )]));
        assert_eq!(with_dma.data_beats(), 2);
        assert_eq!(with_dma.dma_replay_bytes(), 4096);
    }

    #[test]
    fn dma_replay_bytes_cover_the_staged_lstm_weights() {
        // the DRAM-staged LSTM replays every weight tile per timestep:
        // the decoded replay traffic must be at least t times the weight
        // footprint, while data_beats (MMIO writes) stays near one pass
        let dev = FlexAsr::new();
        let mut rng = Rng::new(79);
        let (t, e, h) = (4usize, 200usize, 200usize);
        let x = Tensor::randn(&[t, 1, e], &mut rng, 1.0);
        let wi = Tensor::randn(&[4 * h, e], &mut rng, 0.3);
        let wh = Tensor::randn(&[4 * h, h], &mut rng, 0.3);
        let b = Tensor::randn(&[4 * h], &mut rng, 0.1);
        let prog =
            dev.lower_concrete(&Op::FlexLstm { steps: t }, &[&x, &wi, &wh, &b]).unwrap();
        let weight_bytes = (4 * h * e + 4 * h * h) as u64;
        assert!(
            prog.dma_replay_bytes() >= weight_bytes * t as u64,
            "replays {} must cover {} weight bytes x {t} steps",
            prog.dma_replay_bytes(),
            weight_bytes
        );
        // MMIO data traffic stays a single staging pass (plus
        // activations/biases), far below the replayed total
        assert!(prog.data_bytes() < prog.dma_replay_bytes());
    }

    #[test]
    fn read_plan_bytes_follow_the_storage_width() {
        let af = ReadPlan::FlexAf8 {
            base: fx::GB_BASE,
            shape: vec![3, 5],
            fmt: AdaptivFloatFormat::new(8, 3),
        };
        assert_eq!(af.read_bytes(), 15);
        let hl = ReadPlan::HlscnnI16 {
            base: hx::OUT_BASE,
            shape: vec![1, 2, 2, 2],
            fmt: FixedPointFormat::new(16, 8),
        };
        assert_eq!(hl.read_bytes(), 16);
        let vt = ReadPlan::VtaI32 { base: vx::ACC_BASE, shape: vec![4], scale: 1.0 };
        assert_eq!(vt.read_bytes(), 16);
    }
}
