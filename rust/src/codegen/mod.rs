//! The shared code-generation machinery behind `Accelerator::lower` (the
//! Fig. 3(b)→(d) / Fig. 5 pipeline): the [`LoweredInvocation`] /
//! [`ReadPlan`] vocabulary every per-accelerator lowering produces, the
//! MMIO byte streamer, and the executors that play a lowered invocation
//! against an [`crate::ila::sim::IlaSim`] and decode its result.
//!
//! The per-op lowerings themselves live with their accelerators
//! (`accel::{flexasr,hlscnn,vta}`), reached through the
//! [`crate::accel::Accelerator::lower`] trait method — there are no
//! free-function lowerings here any more. The §5.1 fused maxpool-chain
//! lowering is `FlexAsr::lower_maxpool_chain`; its program-level
//! accounting stays in [`optimize`].

pub mod optimize;

use crate::accel::flexasr::model as fx;
use crate::accel::hlscnn::model as hx;
use crate::accel::vta::model as vx;
use crate::ila::asm::Fragment;
use crate::ila::Cmd;
use crate::ir::Target;
use crate::numerics::adaptivfloat::AdaptivFloatFormat;
use crate::numerics::fixed_point::FixedPointFormat;
use crate::tensor::Tensor;

/// How to retrieve and decode an accelerator result after the command
/// stream has executed. Each plan carries the device's *configured*
/// storage format (design revisions differ), so decoding never assumes a
/// default-configured device.
#[derive(Debug, Clone)]
pub enum ReadPlan {
    /// FlexASR: read `status_out_bias`, then `len` AF8 codes at `base`.
    FlexAf8 { base: u64, shape: Vec<usize>, fmt: AdaptivFloatFormat },
    /// HLSCNN: read `len` i16 codes at `base`, NHWC layout, in the
    /// device's activation format.
    HlscnnI16 { base: u64, shape: Vec<usize>, fmt: FixedPointFormat },
    /// VTA: read `n*m` i32 accumulators at `base`, dequant by `scale`.
    VtaI32 { base: u64, shape: Vec<usize>, scale: f32 },
}

/// One lowered accelerator invocation.
#[derive(Debug, Clone)]
pub struct LoweredInvocation {
    pub target: Target,
    pub asm: Fragment,
    pub cmds: Vec<Cmd>,
    pub read: ReadPlan,
}

impl LoweredInvocation {
    /// Number of MMIO beats moving tensor data (the §5.1 metric).
    pub fn data_beats(&self) -> usize {
        self.cmds
            .iter()
            .filter(|c| {
                let a = c.addr;
                (fx::GB_BASE..fx::GB_BASE + fx::GB_SIZE as u64).contains(&a)
                    || (fx::PE_WGT_BASE..fx::PE_WGT_BASE + fx::PE_WGT_SIZE as u64)
                        .contains(&a)
                    || (hx::ACT_BASE..hx::ACT_BASE + hx::ACT_SIZE as u64).contains(&a)
                    || (hx::WGT_BASE..hx::WGT_BASE + hx::WGT_SIZE as u64).contains(&a)
                    || (vx::INP_BASE..vx::INP_BASE + vx::INP_SIZE as u64).contains(&a)
                    || (vx::WGT_BASE..vx::WGT_BASE + vx::WGT_SIZE as u64).contains(&a)
            })
            .count()
    }
}

/// Stream a byte buffer as 16-byte MMIO writes starting at `base` (used
/// by every per-accelerator lowering).
pub fn stream_bytes(cmds: &mut Vec<Cmd>, base: u64, bytes: &[u8]) {
    for (i, chunk) in bytes.chunks(16).enumerate() {
        let mut data = [0u8; 16];
        data[..chunk.len()].copy_from_slice(chunk);
        cmds.push(Cmd::write(base + 16 * i as u64, data));
    }
}

// ----------------------------------------------------------------------
// Result retrieval
// ----------------------------------------------------------------------

/// Execute a lowered invocation on a fresh ILA simulator of the right
/// device and decode the result per its read plan.
pub fn execute_lowered(
    inv: &LoweredInvocation,
    sim: &mut crate::ila::sim::IlaSim,
) -> anyhow::Result<Tensor> {
    sim.run(&inv.cmds).map_err(|e| anyhow::anyhow!("{e}"))?;
    read_result(inv, sim)
}

/// Decode a completed invocation's result from device state. Reads that
/// return no data surface as structured errors instead of being masked
/// with zeros.
pub fn read_result(
    inv: &LoweredInvocation,
    sim: &mut crate::ila::sim::IlaSim,
) -> anyhow::Result<Tensor> {
    let fetch = |sim: &mut crate::ila::sim::IlaSim,
                 base: u64,
                 nbytes: usize|
     -> anyhow::Result<Vec<u8>> {
        let mut out = Vec::with_capacity(nbytes);
        let mut addr = base;
        while out.len() < nbytes {
            let d = sim
                .step(&Cmd::read(addr))
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .ok_or_else(|| {
                    anyhow::anyhow!("read at 0x{addr:08X} returned no data")
                })?;
            out.extend_from_slice(&d);
            addr += 16;
        }
        out.truncate(nbytes);
        Ok(out)
    };
    match &inv.read {
        ReadPlan::FlexAf8 { base, shape, fmt } => {
            let ob = sim
                .step(&Cmd::read(fx::STATUS_OUT_BIAS))
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "status read at 0x{:08X} returned no data",
                        fx::STATUS_OUT_BIAS
                    )
                })?[0] as i8 as i32;
            let n: usize = shape.iter().product();
            let codes = fetch(sim, *base, n)?;
            Ok(fx::decode_tensor(fmt, &codes, ob, shape))
        }
        ReadPlan::HlscnnI16 { base, shape, fmt } => {
            let n: usize = shape.iter().product();
            let bytes = fetch(sim, *base, 2 * n)?;
            let codes: Vec<i16> = bytes
                .chunks(2)
                .map(|p| i16::from_le_bytes(p.try_into().unwrap()))
                .collect();
            Ok(hx::decode_out_nchw_fmt(*fmt, &codes, shape))
        }
        ReadPlan::VtaI32 { base, shape, scale } => {
            let n: usize = shape.iter().product();
            let bytes = fetch(sim, *base, 4 * n)?;
            let vals: Vec<f32> = bytes
                .chunks(4)
                .map(|q| i32::from_le_bytes(q.try_into().unwrap()) as f32 * scale)
                .collect();
            Ok(Tensor::new(shape.clone(), vals))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{Accelerator, FlexAsr, Hlscnn, Vta};
    use crate::ila::sim::IlaSim;
    use crate::ir::Op;
    use crate::util::Rng;

    #[test]
    fn lowered_linear_runs_end_to_end() {
        let dev = FlexAsr::new();
        let mut rng = Rng::new(71);
        let x = dev.quant(&Tensor::randn(&[4, 16], &mut rng, 1.0));
        let w = dev.quant(&Tensor::randn(&[8, 16], &mut rng, 0.3));
        let b = dev.quant(&Tensor::randn(&[8], &mut rng, 0.1));
        let inv = dev.lower(&Op::FlexLinear, &[&x, &w, &b]).unwrap();
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_lowered(&inv, &mut sim).unwrap();
        // the MMIO result equals the tensor-level fast path bit-exactly:
        // both sides quantize through the same storage codec
        let expect = dev.linear(&x, &w, &b);
        assert_eq!(got, expect, "MMIO path diverges from tensor path");
        assert!(inv.asm.len() >= 8, "Fig. 5(c)-style fragment emitted");
    }

    #[test]
    fn maxpool_chain_optimized_moves_less_data() {
        let dev = FlexAsr::new();
        let mut rng = Rng::new(72);
        let t = dev.quant(&Tensor::randn(&[64, 64], &mut rng, 1.0));
        let fused = dev.lower_maxpool_chain(&t, 4);
        let naive = dev.lower_maxpool_chain_naive(&t, 4);
        let naive_beats: usize = naive.iter().map(|i| i.data_beats()).sum();
        // naive: 256+128+64+32 = 480 store beats (plus ~240 read-back
        // beats not counted here since reads happen in read_result);
        // fused: one 256-beat store. Require a clear win on stores alone.
        assert!(
            fused.data_beats() * 5 < naive_beats * 3,
            "fused {} vs naive {naive_beats}",
            fused.data_beats()
        );

        // and the fused program computes the right maxpool
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_lowered(&fused, &mut sim).unwrap();
        let mut expect = t.clone();
        for _ in 0..4 {
            expect =
                crate::ir::interp::eval_op(&crate::ir::Op::TempMaxPool, &[&expect])
                    .unwrap();
        }
        assert!(got.rel_error(&expect) < 1e-5);
    }

    #[test]
    fn lowered_hlscnn_conv_end_to_end() {
        let dev = Hlscnn::default();
        let mut rng = Rng::new(73);
        let x = Tensor::randn(&[1, 3, 6, 6], &mut rng, 1.0);
        let w = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.2);
        let op = Op::HlscnnConv2d { stride: (1, 1), pad: (1, 1) };
        let inv = dev.lower(&op, &[&x, &w]).unwrap();
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_lowered(&inv, &mut sim).unwrap();
        // updated design: the integer kernel is shared, so the MMIO and
        // tensor views agree bit-exactly
        let expect = dev.conv2d(&x, &w, (1, 1), (1, 1));
        assert_eq!(got, expect);
    }

    #[test]
    fn lowered_vta_gemm_end_to_end() {
        let dev = Vta::new();
        let mut rng = Rng::new(74);
        let x = dev.quant(&Tensor::randn(&[4, 16], &mut rng, 1.0));
        let w = dev.quant(&Tensor::randn(&[8, 16], &mut rng, 1.0));
        let inv = dev.lower(&Op::VtaGemm, &[&x, &w]).unwrap();
        let mut sim = IlaSim::new(dev.build_ila());
        let got = execute_lowered(&inv, &mut sim).unwrap();
        let expect = dev.gemm(&x, &w);
        assert_eq!(got.rel_error(&expect), 0.0, "VTA GEMM is exact");
    }

    #[test]
    fn lower_declines_oversized_and_foreign_ops() {
        let fa = FlexAsr::new();
        let mut rng = Rng::new(75);
        // weights beyond the PE buffer: decline, don't corrupt
        let x = Tensor::randn(&[1, 600], &mut rng, 1.0);
        let w = Tensor::randn(&[600, 600], &mut rng, 0.3);
        let b = Tensor::randn(&[600], &mut rng, 0.1);
        assert!(fa.lower(&Op::FlexLinear, &[&x, &w, &b]).is_none());
        // foreign op: not this accelerator's
        assert!(fa.lower(&Op::VtaGemm, &[&x, &w]).is_none());
        // data movement has no single-op program
        assert!(fa.lower(&Op::FlexMaxpStore, &[&x]).is_none());
        // batched conv: HLSCNN is a batch-1 device
        let hl = Hlscnn::default();
        let xb = Tensor::randn(&[2, 3, 6, 6], &mut rng, 1.0);
        let k = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.2);
        let op = Op::HlscnnConv2d { stride: (1, 1), pad: (1, 1) };
        assert!(hl.lower(&op, &[&xb, &k]).is_none());
    }
}
