//! The HLSCNN ILA model over its MMIO interface.
//!
//! Feature maps cross the interface as 16-bit fixed-point codes (8 per
//! 128-bit beat, little-endian i16); weights as codes in the configured
//! weight format (i8 or i16 depending on [`HlscnnConfig`]). HLSCNN is
//! NHWC internally (§4.1); the driver performs the NCHW→NHWC conversion,
//! and the ILA stores the feature map in NHWC order.

use super::Hlscnn;
use crate::ila::{Cmd, Ila, IlaState};
use crate::tensor::Tensor;

// ----- address map ------------------------------------------------------
/// Activation (feature map) buffer: 128 KiB.
pub const ACT_BASE: u64 = 0xB010_0000;
pub const ACT_SIZE: usize = 0x2_0000;
/// Weight buffer: 128 KiB.
pub const WGT_BASE: u64 = 0xB020_0000;
pub const WGT_SIZE: usize = 0x2_0000;
/// Output buffer: 128 KiB.
pub const OUT_BASE: u64 = 0xB030_0000;
pub const OUT_SIZE: usize = 0x2_0000;
/// in channels C (0..12) | H (12..24) | W (24..36) | out channels O (36..48).
pub const CFG_SHAPE: u64 = 0xB000_0010;
/// KH (0..8) | KW (8..16) | SH (16..24) | SW (24..32) | PH (32..40) | PW (40..48).
pub const CFG_KERNEL: u64 = 0xB000_0020;
/// trigger.
pub const CFG_START: u64 = 0xB000_0030;

fn i16_store(mem: &mut [u8], base: usize, vals: impl Iterator<Item = i16>) {
    for (i, v) in vals.enumerate() {
        mem[base + 2 * i..base + 2 * i + 2].copy_from_slice(&v.to_le_bytes());
    }
}

fn i16_load(mem: &[u8], base: usize, n: usize) -> Vec<i16> {
    (0..n)
        .map(|i| i16::from_le_bytes(mem[base + 2 * i..base + 2 * i + 2].try_into().unwrap()))
        .collect()
}

/// Encode an NCHW activation tensor into the device's NHWC i16 layout.
pub fn encode_act_nhwc(dev: &Hlscnn, x: &Tensor) -> Vec<u8> {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let fmt = dev.cfg.act_fmt;
    let mut out = vec![0u8; n * c * h * w * 2];
    let mut idx = 0;
    for b in 0..n {
        for y in 0..h {
            for xw in 0..w {
                for ch in 0..c {
                    let v = x.data[((b * c + ch) * h + y) * w + xw];
                    let code = fmt.encode(v) as i16;
                    out[2 * idx..2 * idx + 2].copy_from_slice(&code.to_le_bytes());
                    idx += 1;
                }
            }
        }
    }
    out
}

/// Encode an OIHW weight tensor into the device's weight layout (O-major,
/// per-filter HWC order), in the configured weight width (always shipped
/// as i16 codes on the wire; the device re-truncates to its store width).
pub fn encode_wgt(dev: &Hlscnn, w: &Tensor) -> Vec<u8> {
    let (o, c, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let fmt = dev.cfg.weight_fmt;
    let mut out = vec![0u8; o * c * kh * kw * 2];
    let mut idx = 0;
    for oc in 0..o {
        for dy in 0..kh {
            for dx in 0..kw {
                for ch in 0..c {
                    let v = w.data[((oc * c + ch) * kh + dy) * kw + dx];
                    let code = fmt.encode(v) as i16;
                    out[2 * idx..2 * idx + 2].copy_from_slice(&code.to_le_bytes());
                    idx += 1;
                }
            }
        }
    }
    out
}

/// Decode the device's NHWC i16 output buffer back to an NCHW tensor.
pub fn decode_out_nchw(dev: &Hlscnn, codes: &[i16], shape: &[usize]) -> Tensor {
    let (n, o, oh, ow) = (shape[0], shape[1], shape[2], shape[3]);
    let fmt = dev.cfg.act_fmt;
    let mut out = vec![0.0f32; n * o * oh * ow];
    let mut idx = 0;
    for b in 0..n {
        for y in 0..oh {
            for xw in 0..ow {
                for ch in 0..o {
                    out[((b * o + ch) * oh + y) * ow + xw] = fmt.decode(codes[idx] as i64);
                    idx += 1;
                }
            }
        }
    }
    Tensor::new(shape.to_vec(), out)
}

/// Build the HLSCNN ILA (batch-1 device; the driver loops over batch).
pub fn build_ila(dev: Hlscnn) -> Ila {
    let mut st = IlaState::new();
    st.new_mem("act", ACT_SIZE);
    st.new_mem("wgt", WGT_SIZE);
    st.new_mem("out", OUT_SIZE);
    st.new_bv("cfg_shape", 48);
    st.new_bv("cfg_kernel", 48);
    let mut ila = Ila::new("HLSCNN_ILA", st);

    for (name, base, size, mem) in [
        ("wr_act", ACT_BASE, ACT_SIZE as u64, "act"),
        ("wr_wgt", WGT_BASE, WGT_SIZE as u64, "wgt"),
    ] {
        ila.instr(
            name,
            move |c, _| c.is_write && (base..base + size).contains(&c.addr),
            move |c, s| {
                let off = (c.addr - base) as usize;
                s.mem_mut(mem)[off..off + 16].copy_from_slice(&c.data);
                Ok(None)
            },
        );
    }
    ila.instr(
        "rd_out",
        |c, _| !c.is_write && (OUT_BASE..OUT_BASE + OUT_SIZE as u64).contains(&c.addr),
        |c, s| {
            let off = (c.addr - OUT_BASE) as usize;
            let mut out = [0u8; 16];
            out.copy_from_slice(&s.mem("out")[off..off + 16]);
            Ok(Some(out))
        },
    );
    for (name, addr, reg) in [
        ("cfg_conv_shape", CFG_SHAPE, "cfg_shape"),
        ("cfg_conv_kernel", CFG_KERNEL, "cfg_kernel"),
    ] {
        let reg = reg.to_string();
        ila.instr(
            name,
            move |c, _| c.is_write && c.addr == addr,
            move |c, s| {
                s.set_reg(&reg, c.data_u64());
                Ok(None)
            },
        );
    }

    ila.instr(
        "conv_start",
        |c, _| c.is_write && c.addr == CFG_START && c.data_u64() == 1,
        move |_, s| {
            let shp = s.reg("cfg_shape");
            let (c_in, h, w, o) = (
                (shp & 0xFFF) as usize,
                ((shp >> 12) & 0xFFF) as usize,
                ((shp >> 24) & 0xFFF) as usize,
                ((shp >> 36) & 0xFFF) as usize,
            );
            let krn = s.reg("cfg_kernel");
            let (kh, kw, sh, sw, ph, pw) = (
                (krn & 0xFF) as usize,
                ((krn >> 8) & 0xFF) as usize,
                ((krn >> 16) & 0xFF) as usize,
                ((krn >> 24) & 0xFF) as usize,
                ((krn >> 32) & 0xFF) as usize,
                ((krn >> 40) & 0xFF) as usize,
            );
            if kh == 0 || kw == 0 || sh == 0 || sw == 0 {
                return Err("kernel/stride not configured".into());
            }
            let oh = (h + 2 * ph).checked_sub(kh).ok_or("kernel too large")? / sh + 1;
            let ow = (w + 2 * pw).checked_sub(kw).ok_or("kernel too large")? / sw + 1;

            let act_fmt = dev.cfg.act_fmt;
            let wgt_fmt = dev.cfg.weight_fmt;
            let acts = i16_load(s.mem("act"), 0, h * w * c_in);
            let wgts = i16_load(s.mem("wgt"), 0, o * kh * kw * c_in);
            // integer conv with 64-bit accumulation over NHWC layout; the
            // device re-truncates weight codes to its store width
            let mut out_codes = vec![0i16; oh * ow * o];
            for y in 0..oh {
                for xw in 0..ow {
                    for oc in 0..o {
                        let mut acc: i64 = 0;
                        for dy in 0..kh {
                            let iy = (y * sh + dy) as isize - ph as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for dx in 0..kw {
                                let ix = (xw * sw + dx) as isize - pw as isize;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                for ch in 0..c_in {
                                    let a = acts
                                        [(iy as usize * w + ix as usize) * c_in + ch]
                                        as i64;
                                    let wv = wgt_fmt.encode(wgt_fmt.decode(
                                        wgts[((oc * kh + dy) * kw + dx) * c_in + ch]
                                            as i64,
                                    ));
                                    acc += a * wv;
                                }
                            }
                        }
                        // acc has act_frac + wgt_frac fractional bits;
                        // shift back to the activation format, saturating
                        let val = acc as f64
                            * 0.5f64.powi(
                                (act_fmt.frac_bits + wgt_fmt.frac_bits) as i32,
                            );
                        out_codes[(y * ow + xw) * o + oc] =
                            act_fmt.encode(val as f32) as i16;
                    }
                }
            }
            i16_store(s.mem_mut("out"), 0, out_codes.into_iter());
            Ok(None)
        },
    );
    ila
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::hlscnn::HlscnnConfig;
    use crate::ila::sim::IlaSim;
    use crate::util::Rng;

    fn stream(sim: &mut IlaSim, base: u64, bytes: &[u8]) {
        for (i, chunk) in bytes.chunks(16).enumerate() {
            let mut data = [0u8; 16];
            data[..chunk.len()].copy_from_slice(chunk);
            sim.step(&Cmd::write(base + 16 * i as u64, data)).unwrap();
        }
    }

    /// VT3-style consistency: MMIO model vs tensor-level fast path.
    #[test]
    fn mmio_matches_tensor_conv() {
        let dev = Hlscnn::new(HlscnnConfig::updated());
        let mut rng = Rng::new(41);
        let x = Tensor::randn(&[1, 3, 6, 6], &mut rng, 1.0);
        let w = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.2);
        let expect = dev.conv2d(&x, &w, (1, 1), (1, 1));

        let mut sim = IlaSim::new(build_ila(dev));
        stream(&mut sim, ACT_BASE, &encode_act_nhwc(&dev, &x));
        stream(&mut sim, WGT_BASE, &encode_wgt(&dev, &w));
        let shape_reg = 3u64 | (6 << 12) | (6 << 24) | (4 << 36);
        sim.step(&Cmd::write_u64(CFG_SHAPE, shape_reg)).unwrap();
        let kern_reg =
            3u64 | (3 << 8) | (1 << 16) | (1 << 24) | (1 << 32) | (1 << 40);
        sim.step(&Cmd::write_u64(CFG_KERNEL, kern_reg)).unwrap();
        sim.step(&Cmd::write_u64(CFG_START, 1)).unwrap();

        let n_out = 4 * 6 * 6;
        let mut codes = Vec::new();
        let mut addr = OUT_BASE;
        while codes.len() < n_out {
            let d = sim.step(&Cmd::read(addr)).unwrap().unwrap();
            for pair in d.chunks(2) {
                codes.push(i16::from_le_bytes(pair.try_into().unwrap()));
            }
            addr += 16;
        }
        codes.truncate(n_out);
        let got = decode_out_nchw(&dev, &codes, &[1, 4, 6, 6]);
        assert!(
            got.max_abs_diff(&expect) <= dev.cfg.act_fmt.step() + 1e-6,
            "max diff {}",
            got.max_abs_diff(&expect)
        );
    }

    #[test]
    fn trigger_without_config_errors() {
        let dev = Hlscnn::default();
        let mut sim = IlaSim::new(build_ila(dev));
        assert!(sim.step(&Cmd::write_u64(CFG_START, 1)).is_err());
    }
}
