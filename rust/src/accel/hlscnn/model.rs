//! The HLSCNN ILA model over its MMIO interface.
//!
//! Feature maps cross the interface as 16-bit fixed-point codes (8 per
//! 128-bit beat, little-endian i16); weights as codes in the configured
//! weight format (i8 or i16 depending on [`HlscnnConfig`]). HLSCNN is
//! NHWC internally (§4.1); the driver performs the NCHW→NHWC conversion,
//! and the ILA stores the feature map in NHWC order.

use super::Hlscnn;
use crate::ila::{Cmd, Ila, IlaState};
use crate::numerics::fixed_point::FixedPointFormat;
use crate::tensor::Tensor;

// ----- address map ------------------------------------------------------
/// Activation (feature map) buffer: 128 KiB.
pub const ACT_BASE: u64 = 0xB010_0000;
/// Activation buffer size in bytes.
pub const ACT_SIZE: usize = 0x2_0000;
/// Weight buffer: 128 KiB.
pub const WGT_BASE: u64 = 0xB020_0000;
/// Weight buffer size in bytes.
pub const WGT_SIZE: usize = 0x2_0000;
/// Output buffer: 128 KiB.
pub const OUT_BASE: u64 = 0xB030_0000;
/// Output buffer size in bytes.
pub const OUT_SIZE: usize = 0x2_0000;
/// in channels C (0..12) | H (12..24) | W (24..36) | out channels O (36..48).
pub const CFG_SHAPE: u64 = 0xB000_0010;
/// KH (0..8) | KW (8..16) | SH (16..24) | SW (24..32) | PH (32..40) | PW (40..48).
pub const CFG_KERNEL: u64 = 0xB000_0020;
/// trigger.
pub const CFG_START: u64 = 0xB000_0030;

/// The interface ("wire") format for weights: 16-bit fixed point with 12
/// fraction bits, matching the *updated* weight store. The driver always
/// ships weights at wire precision; the device adapts them to its store
/// width (see [`wire_to_store`]).
pub fn wire_wgt_fmt() -> FixedPointFormat {
    FixedPointFormat::new(16, 12)
}

/// Adapt a wire-format weight code to the device's weight-store format.
///
/// The updated 16-bit store matches the wire format, so codes pass
/// through unchanged. The **original** 8-bit store drops the extra
/// fraction bits with an arithmetic right shift (truncation toward
/// negative infinity — what dropping low-order bits of a two's-complement
/// register does in RTL) and saturates at the store rails. The software
/// stack's tensor-level model assumed round-to-nearest into the store
/// format, so roughly half of all trained weights land one store step
/// below what the compiler believes — invisible in operation-level
/// tolerance tests, surfaced by `ExecBackend::CrossCheck` (the
/// repo-native version of the paper's "unknown flaw" found by
/// application-level validation).
pub fn wire_to_store(store: FixedPointFormat, code: i64) -> i64 {
    let wire = wire_wgt_fmt();
    let shift = wire.frac_bits.saturating_sub(store.frac_bits);
    let shifted = code >> shift;
    // defensive rails for store widths narrower than `wire.bits - shift`;
    // with the two shipped configs (Q16.12 wire → Q8.2 or Q16.12 store)
    // the shifted i16 range already fits and this never engages
    let max = (1i64 << (store.bits - 1)) - 1;
    let min = -(1i64 << (store.bits - 1));
    shifted.clamp(min, max)
}

/// The shared integer convolution datapath: NHWC activation codes ×
/// store-format weight codes → NHWC output codes, 64-bit accumulation,
/// requantized to the activation format at writeback.
///
/// Both the ILA's `conv_start` update and the tensor fast path
/// ([`Hlscnn::conv2d`]) call this one function, so the two views are
/// bit-identical **by construction** whenever they agree on the store
/// codes (always true for the updated design; the original design's
/// wire→store truncation makes them diverge — see [`wire_to_store`]).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_codes(
    acts: &[i16],
    wgts_store: &[i64],
    (c_in, h, w): (usize, usize, usize),
    o: usize,
    (kh, kw): (usize, usize),
    (sh, sw): (usize, usize),
    (ph, pw): (usize, usize),
    act_fmt: FixedPointFormat,
    wgt_fmt: FixedPointFormat,
) -> Vec<i16> {
    let oh = (h + 2 * ph - kh) / sh + 1;
    let ow = (w + 2 * pw - kw) / sw + 1;
    let mut out_codes = vec![0i16; oh * ow * o];
    for y in 0..oh {
        for xw in 0..ow {
            for oc in 0..o {
                let mut acc: i64 = 0;
                for dy in 0..kh {
                    let iy = (y * sh + dy) as isize - ph as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for dx in 0..kw {
                        let ix = (xw * sw + dx) as isize - pw as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        for ch in 0..c_in {
                            let a = acts[(iy as usize * w + ix as usize) * c_in + ch]
                                as i64;
                            let wv =
                                wgts_store[((oc * kh + dy) * kw + dx) * c_in + ch];
                            acc += a * wv;
                        }
                    }
                }
                // acc has act_frac + wgt_frac fractional bits; shift back
                // to the activation format, saturating
                let val = acc as f64
                    * 0.5f64.powi((act_fmt.frac_bits + wgt_fmt.frac_bits) as i32);
                out_codes[(y * ow + xw) * o + oc] = act_fmt.encode(val as f32) as i16;
            }
        }
    }
    out_codes
}

fn i16_store(mem: &mut [u8], base: usize, vals: impl Iterator<Item = i16>) {
    for (i, v) in vals.enumerate() {
        mem[base + 2 * i..base + 2 * i + 2].copy_from_slice(&v.to_le_bytes());
    }
}

fn i16_load(mem: &[u8], base: usize, n: usize) -> Vec<i16> {
    (0..n)
        .map(|i| i16::from_le_bytes(mem[base + 2 * i..base + 2 * i + 2].try_into().unwrap()))
        .collect()
}

/// Encode an NCHW activation tensor into the device's NHWC i16 layout.
pub fn encode_act_nhwc(dev: &Hlscnn, x: &Tensor) -> Vec<u8> {
    encode_act_nhwc_fmt(dev.cfg.act_fmt, x)
}

/// [`encode_act_nhwc`] with an explicit activation format (what a
/// [`crate::codegen::SlotCodec`] carries).
pub fn encode_act_nhwc_fmt(fmt: FixedPointFormat, x: &Tensor) -> Vec<u8> {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = vec![0u8; n * c * h * w * 2];
    let mut idx = 0;
    for b in 0..n {
        for y in 0..h {
            for xw in 0..w {
                for ch in 0..c {
                    let v = x.data[((b * c + ch) * h + y) * w + xw];
                    let code = fmt.encode(v) as i16;
                    out[2 * idx..2 * idx + 2].copy_from_slice(&code.to_le_bytes());
                    idx += 1;
                }
            }
        }
    }
    out
}

/// Encode an OIHW weight tensor into the device's weight layout (O-major,
/// per-filter HWC order), always at **wire precision** ([`wire_wgt_fmt`],
/// i16 with 12 fraction bits); the device adapts the codes to its store
/// width on use ([`wire_to_store`]).
pub fn encode_wgt(_dev: &Hlscnn, w: &Tensor) -> Vec<u8> {
    let (o, c, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let fmt = wire_wgt_fmt();
    let mut out = vec![0u8; o * c * kh * kw * 2];
    let mut idx = 0;
    for oc in 0..o {
        for dy in 0..kh {
            for dx in 0..kw {
                for ch in 0..c {
                    let v = w.data[((oc * c + ch) * kh + dy) * kw + dx];
                    let code = fmt.encode(v) as i16;
                    out[2 * idx..2 * idx + 2].copy_from_slice(&code.to_le_bytes());
                    idx += 1;
                }
            }
        }
    }
    out
}

/// Decode the device's NHWC i16 output buffer back to an NCHW tensor.
pub fn decode_out_nchw(dev: &Hlscnn, codes: &[i16], shape: &[usize]) -> Tensor {
    decode_out_nchw_fmt(dev.cfg.act_fmt, codes, shape)
}

/// [`decode_out_nchw`] with an explicit activation format (what a
/// [`crate::codegen::ReadPlan`] carries).
pub fn decode_out_nchw_fmt(fmt: FixedPointFormat, codes: &[i16], shape: &[usize]) -> Tensor {
    let (n, o, oh, ow) = (shape[0], shape[1], shape[2], shape[3]);
    let mut out = vec![0.0f32; n * o * oh * ow];
    let mut idx = 0;
    for b in 0..n {
        for y in 0..oh {
            for xw in 0..ow {
                for ch in 0..o {
                    out[((b * o + ch) * oh + y) * ow + xw] = fmt.decode(codes[idx] as i64);
                    idx += 1;
                }
            }
        }
    }
    Tensor::new(shape.to_vec(), out)
}

/// Build the HLSCNN ILA (batch-1 device; the driver loops over batch).
pub fn build_ila(dev: Hlscnn) -> Ila {
    let mut st = IlaState::new();
    st.new_mem("act", ACT_SIZE);
    st.new_mem("wgt", WGT_SIZE);
    st.new_mem("out", OUT_SIZE);
    st.new_bv("cfg_shape", 48);
    st.new_bv("cfg_kernel", 48);
    let mut ila = Ila::new("HLSCNN_ILA", st);

    for (name, base, size, mem) in [
        ("wr_act", ACT_BASE, ACT_SIZE as u64, "act"),
        ("wr_wgt", WGT_BASE, WGT_SIZE as u64, "wgt"),
    ] {
        ila.instr(
            name,
            move |c, _| c.is_write && (base..base + size).contains(&c.addr),
            move |c, s| {
                let off = (c.addr - base) as usize;
                // byte-enabled store: a short final beat must not clobber
                // bytes past the streamed slice
                s.mem_write(mem, off, c.payload());
                Ok(None)
            },
        );
    }
    ila.instr(
        "rd_out",
        |c, _| !c.is_write && (OUT_BASE..OUT_BASE + OUT_SIZE as u64).contains(&c.addr),
        |c, s| {
            let off = (c.addr - OUT_BASE) as usize;
            let mut out = [0u8; 16];
            out.copy_from_slice(&s.mem("out")[off..off + 16]);
            Ok(Some(out))
        },
    );
    for (name, addr, reg) in [
        ("cfg_conv_shape", CFG_SHAPE, "cfg_shape"),
        ("cfg_conv_kernel", CFG_KERNEL, "cfg_kernel"),
    ] {
        let reg = reg.to_string();
        ila.instr(
            name,
            move |c, _| c.is_write && c.addr == addr,
            move |c, s| {
                s.set_reg(&reg, c.data_u64());
                Ok(None)
            },
        );
    }

    ila.instr(
        "conv_start",
        |c, _| c.is_write && c.addr == CFG_START && c.data_u64() == 1,
        move |_, s| {
            let shp = s.reg("cfg_shape");
            let (c_in, h, w, o) = (
                (shp & 0xFFF) as usize,
                ((shp >> 12) & 0xFFF) as usize,
                ((shp >> 24) & 0xFFF) as usize,
                ((shp >> 36) & 0xFFF) as usize,
            );
            let krn = s.reg("cfg_kernel");
            let (kh, kw, sh, sw, ph, pw) = (
                (krn & 0xFF) as usize,
                ((krn >> 8) & 0xFF) as usize,
                ((krn >> 16) & 0xFF) as usize,
                ((krn >> 24) & 0xFF) as usize,
                ((krn >> 32) & 0xFF) as usize,
                ((krn >> 40) & 0xFF) as usize,
            );
            if kh == 0 || kw == 0 || sh == 0 || sw == 0 {
                return Err("kernel/stride not configured".into());
            }
            // validate geometry before touching the scratchpads
            (h + 2 * ph).checked_sub(kh).ok_or("kernel too large")?;
            (w + 2 * pw).checked_sub(kw).ok_or("kernel too large")?;

            let act_fmt = dev.cfg.act_fmt;
            let wgt_fmt = dev.cfg.weight_fmt;
            let acts = i16_load(s.mem("act"), 0, h * w * c_in);
            // adapt wire-precision weight codes to the store width (the
            // original 8-bit store truncates — see `wire_to_store`)
            let wgts: Vec<i64> = i16_load(s.mem("wgt"), 0, o * kh * kw * c_in)
                .into_iter()
                .map(|code| wire_to_store(wgt_fmt, code as i64))
                .collect();
            let out_codes = conv2d_codes(
                &acts,
                &wgts,
                (c_in, h, w),
                o,
                (kh, kw),
                (sh, sw),
                (ph, pw),
                act_fmt,
                wgt_fmt,
            );
            let n_out = out_codes.len();
            i16_store(s.mem_range_mut("out", 0, 2 * n_out), 0, out_codes.into_iter());
            Ok(None)
        },
    );
    // residency contract: the act/wgt scratchpads are host-exclusive
    // (conv writes only `out`), so staged feature maps and filter banks
    // may stay device-resident across invocations.
    ila.stage_region("act", ACT_BASE, ACT_SIZE);
    ila.stage_region("wgt", WGT_BASE, WGT_SIZE);
    ila
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::hlscnn::HlscnnConfig;
    use crate::ila::sim::IlaSim;

    // NOTE: the seed-era `mmio_matches_tensor_conv` test was subsumed by
    // `tests/backend_parity.rs`, which asserts bit-exact Functional ≡
    // IlaMmio agreement for the updated design through the session
    // backend engine (and that CrossCheck flags the original design).

    #[test]
    fn trigger_without_config_errors() {
        let dev = Hlscnn::default();
        let mut sim = IlaSim::new(build_ila(dev));
        assert!(sim.step(&Cmd::write_u64(CFG_START, 1)).is_err());
    }

    #[test]
    fn wire_to_store_is_identity_for_the_updated_width() {
        let store = HlscnnConfig::updated().weight_fmt;
        for code in [-32768i64, -1024, -1, 0, 1, 513, 32767] {
            assert_eq!(wire_to_store(store, code), code);
        }
    }

    #[test]
    fn wire_to_store_truncates_on_the_original_width() {
        let store = HlscnnConfig::original().weight_fmt;
        // wire fixed<16,12> → store fixed<8,2>: 10 fraction bits dropped
        // by arithmetic shift (floor), not round-to-nearest
        assert_eq!(wire_to_store(store, 1024), 1); // exactly 0.25
        assert_eq!(wire_to_store(store, 1023), 0); // 0.2498 → floor 0
        assert_eq!(wire_to_store(store, 1535), 1); // 0.3748 → floor, round would give 0.25 too
        assert_eq!(wire_to_store(store, 1536), 1); // 0.375 → round-to-nearest(-even) gives 2; RTL floors to 1
        assert_eq!(wire_to_store(store, -1), -1); // -2^-12 → floor -0.25
        // extreme wire codes: the 10-bit shift alone keeps i16 codes
        // inside the 8-bit store range ([-32, 31] of 0.25 steps), so
        // these are shift results, not clamped rails
        assert_eq!(wire_to_store(store, 32767), 31);
        assert_eq!(wire_to_store(store, -32768), -32);
    }

    #[test]
    fn the_original_store_diverges_from_round_to_nearest() {
        // the flaw CrossCheck surfaces: the software model rounds 0.38 to
        // the nearest store step (0.5); the silicon's bit-drop floors the
        // wire code (1556 >> 10 = 1) to 0.25
        let store = HlscnnConfig::original().weight_fmt;
        let wire = wire_wgt_fmt();
        let value = 0.38f32;
        let rtl = store.decode(wire_to_store(store, wire.encode(value)));
        let sw = store.quantize_value(value);
        assert_eq!(sw, 0.5, "software rounds to nearest");
        assert_eq!(rtl, 0.25, "silicon floors");
    }
}
