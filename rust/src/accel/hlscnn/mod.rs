//! HLSCNN — a coarse-grained 2-D convolution accelerator (Whatmough et
//! al., VLSI'19) operating on **8/16-bit fixed point** data.
//!
//! One supported operation (Appendix A): non-grouped 2-D convolution.
//! Weights are stored in a narrow fixed-point format — **8-bit in the
//! original design** — while activations use 16-bit fixed point and MACs
//! accumulate in wide integers. Table 4's co-design case study: the 8-bit
//! weight store quantizes trained CIFAR conv weights so hard that
//! application accuracy collapses (91.55% → 29.15% for ResNet-20);
//! widening the weight store to 16 bits recovers it. Both configurations
//! are modeled here via [`HlscnnConfig`].

pub mod model;

use super::Accelerator;
use crate::codegen::{
    BindCalib, Burst, LoweredProgram, OperandSlot, ProgramTemplate, ReadPlan,
    ScaleRule, SlotCodec, Stitch, TemplateBurst, TemplateInvocation,
};
use crate::ila::asm::Fragment;
use crate::ila::{Cmd, Ila};
use crate::ir::{Op, Target};
use crate::numerics::fixed_point::FixedPointFormat;
use crate::tensor::Tensor;
use self::model as hx;
use std::sync::Arc;

/// HLSCNN numerics configuration — the co-design knob of Table 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HlscnnConfig {
    /// Weight storage format.
    pub weight_fmt: FixedPointFormat,
    /// Activation (feature map) format.
    pub act_fmt: FixedPointFormat,
}

impl HlscnnConfig {
    /// The original silicon: weights share the 8-bit fixed-point format
    /// that the accumulator path needs for its value range (few fraction
    /// bits) — which quantizes trained conv weights to a handful of
    /// coarse steps. This is the Table 4 root cause ("weight data values
    /// ... heavily quantized by its 8-bit fixed point data type due to a
    /// narrower value range").
    pub fn original() -> Self {
        HlscnnConfig {
            weight_fmt: FixedPointFormat::new(8, 2),
            act_fmt: FixedPointFormat::new(16, 8),
        }
    }

    /// The developer fix from the Table 4 case study: 16-bit weights.
    pub fn updated() -> Self {
        HlscnnConfig {
            weight_fmt: FixedPointFormat::new(16, 12),
            act_fmt: FixedPointFormat::new(16, 8),
        }
    }
}

/// The HLSCNN accelerator model.
#[derive(Debug, Clone, Copy)]
pub struct Hlscnn {
    /// Numerics configuration (original vs updated weight store).
    pub cfg: HlscnnConfig,
}

impl Default for Hlscnn {
    fn default() -> Self {
        Hlscnn { cfg: HlscnnConfig::updated() }
    }
}

impl Hlscnn {
    /// Model with an explicit numerics configuration.
    pub fn new(cfg: HlscnnConfig) -> Self {
        Hlscnn { cfg }
    }

    /// Bit-accurate 2-D convolution: weights and activations snapped to
    /// their fixed-point lattices, integer MAC accumulation (64-bit),
    /// output requantized to the activation format.
    ///
    /// This runs the **same integer kernel** as the ILA model
    /// ([`model::conv2d_codes`]), so the tensor view and the MMIO view
    /// are bit-identical by construction — with one deliberate exception:
    /// this path quantizes weights round-to-nearest into the store format
    /// (the software contract), while the original silicon truncates the
    /// wire code ([`model::wire_to_store`]); `ExecBackend::CrossCheck`
    /// exists to catch exactly that class of divergence.
    pub fn conv2d(
        &self,
        x: &Tensor,
        w: &Tensor,
        stride: (usize, usize),
        pad: (usize, usize),
    ) -> Tensor {
        assert_eq!(x.shape.len(), 4, "conv2d expects NCHW activations");
        assert_eq!(w.shape.len(), 4, "conv2d expects OIHW weights");
        let (n, c, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let (o, kh, kw) = (w.shape[0], w.shape[2], w.shape[3]);
        assert_eq!(w.shape[1], c, "conv2d channel mismatch");
        assert!(
            h + 2 * pad.0 >= kh && wd + 2 * pad.1 >= kw,
            "conv2d kernel larger than padded input"
        );
        let oh = (h + 2 * pad.0 - kh) / stride.0 + 1;
        let ow = (wd + 2 * pad.1 - kw) / stride.1 + 1;
        let act_fmt = self.cfg.act_fmt;
        let wgt_fmt = self.cfg.weight_fmt;
        // store-format weight codes in the device's O-major HWC layout
        let mut wgts = vec![0i64; o * kh * kw * c];
        for oc in 0..o {
            for dy in 0..kh {
                for dx in 0..kw {
                    for ch in 0..c {
                        wgts[((oc * kh + dy) * kw + dx) * c + ch] =
                            wgt_fmt.encode(w.data[((oc * c + ch) * kh + dy) * kw + dx]);
                    }
                }
            }
        }
        let mut out = vec![0.0f32; n * o * oh * ow];
        let mut acts = vec![0i16; h * wd * c];
        for b in 0..n {
            // NHWC activation codes for this image
            for y in 0..h {
                for xw in 0..wd {
                    for ch in 0..c {
                        acts[(y * wd + xw) * c + ch] =
                            act_fmt.encode(x.data[((b * c + ch) * h + y) * wd + xw])
                                as i16;
                    }
                }
            }
            let codes = hx::conv2d_codes(
                &acts,
                &wgts,
                (c, h, wd),
                o,
                (kh, kw),
                stride,
                pad,
                act_fmt,
                wgt_fmt,
            );
            for y in 0..oh {
                for xw in 0..ow {
                    for oc in 0..o {
                        out[((b * o + oc) * oh + y) * ow + xw] =
                            act_fmt.decode(codes[(y * ow + xw) * o + oc] as i64);
                    }
                }
            }
        }
        Tensor::new(vec![n, o, oh, ow], out)
    }

    /// Lower `hlscnn_conv2d` to a weight-keyed MMIO program template
    /// (batch-1 device; the engine falls back to the tensor path for
    /// batched inputs). The feature map is the template's one
    /// [`OperandSlot`] (NHWC i16 codes, staged once); every filter tile
    /// is a concrete fingerprinted burst. No command lane depends on
    /// input values — the fixed-point output requantization is
    /// per-element, with no whole-tensor parameter to calibrate — so the
    /// template has no patches. When the filter bank or the output
    /// exceed the scratchpads, the driver tiles over **output
    /// channels**: each tile streams its filter rows, reconfigures the
    /// shape register with its channel count, triggers, and reads its
    /// output block back.
    fn lower_conv2d(
        &self,
        x: &Tensor,
        w: &Tensor,
        stride: (usize, usize),
        pad: (usize, usize),
    ) -> Option<ProgramTemplate> {
        self.lower_conv2d_template(x, w, stride, pad, usize::MAX)
    }

    /// [`Self::lower_conv2d`] with a forced output-channel tile `cap`,
    /// the translation-validation entry point: small obligation shapes
    /// still exercise genuine channel-split programs. Concrete —
    /// template + bind over the same operands.
    pub(crate) fn lower_conv2d_capped(
        &self,
        x: &Tensor,
        w: &Tensor,
        stride: (usize, usize),
        pad: (usize, usize),
        cap: usize,
    ) -> Option<LoweredProgram> {
        let tmpl = self.lower_conv2d_template(x, w, stride, pad, cap)?;
        tmpl.bind(&[x, w]).ok().map(|bp| bp.program)
    }

    /// Template form of [`Self::lower_conv2d_capped`], for slot-aware
    /// obligations over symbolic feature-map bytes.
    pub(crate) fn lower_conv2d_template(
        &self,
        x: &Tensor,
        w: &Tensor,
        stride: (usize, usize),
        pad: (usize, usize),
        cap: usize,
    ) -> Option<ProgramTemplate> {
        if x.shape.len() != 4 || w.shape.len() != 4 || x.shape[0] != 1 {
            return None;
        }
        let (c, h, wd) = (x.shape[1], x.shape[2], x.shape[3]);
        let (o, kh, kw) = (w.shape[0], w.shape[2], w.shape[3]);
        if w.shape[1] != c || c == 0 || o == 0 {
            return None;
        }
        if kh == 0 || kw == 0 || stride.0 == 0 || stride.1 == 0 {
            return None;
        }
        if h + 2 * pad.0 < kh || wd + 2 * pad.1 < kw {
            return None;
        }
        // config-register field widths (per tile for the channel count)
        if c > 0xFFF || h > 0xFFF || wd > 0xFFF {
            return None;
        }
        if kh > 0xFF || kw > 0xFF || stride.0 > 0xFF || stride.1 > 0xFF
            || pad.0 > 0xFF || pad.1 > 0xFF
        {
            return None;
        }
        let oh = (h + 2 * pad.0 - kh) / stride.0 + 1;
        let ow = (wd + 2 * pad.1 - kw) / stride.1 + 1;
        // the feature map is not tiled: it must fit the act scratchpad
        if 2 * c * h * wd > hx::ACT_SIZE {
            return None;
        }
        // output-channel tile capacity from the weight and output
        // scratchpads and the 12-bit shape field
        let o_cap = (hx::WGT_SIZE / (2 * c * kh * kw))
            .min(hx::OUT_SIZE / (2 * oh * ow))
            .min(0xFFF)
            .min(o)
            .min(cap);
        if o_cap == 0 {
            return None;
        }

        let wgt_codes = hx::encode_wgt(self, w); // O-major filter rows
        let filter_bytes = 2 * c * kh * kw;
        let mut invocations = Vec::new();
        let mut lo = 0usize;
        while lo < o {
            let oc = o_cap.min(o - lo);
            let mut bursts = Vec::new();
            if lo == 0 {
                // the feature map stays resident across tiles: one slot,
                // encoded at bind (2 bytes per element, NHWC)
                bursts.push(TemplateBurst::Slot(OperandSlot {
                    operand: 0,
                    base: hx::ACT_BASE,
                    bytes: 0..2 * c * h * wd,
                    codec: SlotCodec::HlscnnActNhwc { fmt: self.cfg.act_fmt },
                }));
            }
            bursts.push(TemplateBurst::Concrete(Burst::stage(
                hx::WGT_BASE,
                &wgt_codes[lo * filter_bytes..(lo + oc) * filter_bytes],
            )));
            let mut cmds = Vec::new();
            cmds.push(Cmd::write_u64(
                hx::CFG_SHAPE,
                (c as u64) | ((h as u64) << 12) | ((wd as u64) << 24)
                    | ((oc as u64) << 36),
            ));
            cmds.push(Cmd::write_u64(
                hx::CFG_KERNEL,
                (kh as u64)
                    | ((kw as u64) << 8)
                    | ((stride.0 as u64) << 16)
                    | ((stride.1 as u64) << 24)
                    | ((pad.0 as u64) << 32)
                    | ((pad.1 as u64) << 40),
            ));
            cmds.push(Cmd::write_u64(hx::CFG_START, 1));
            bursts.push(TemplateBurst::Concrete(Burst::control(cmds)));

            let mut asm = Fragment::new();
            if lo == 0 {
                asm.push("HLSCNN_ILA.wr_act", &["%fmap"]);
            }
            asm.push("HLSCNN_ILA.wr_wgt", &["%filter_rows"])
                .push("HLSCNN_ILA.cfg_conv_shape", &["%c", "%h", "%w", "%o_tile"])
                .push("HLSCNN_ILA.cfg_conv_kernel", &["%k", "%s", "%p"])
                .push("HLSCNN_ILA.conv_start", &[])
                .push("HLSCNN_ILA.rd_out", &["%out_channels"]);

            invocations.push(TemplateInvocation {
                target: Target::Hlscnn,
                asm,
                bursts,
                read: Some(ReadPlan::HlscnnI16 {
                    base: hx::OUT_BASE,
                    shape: vec![1, oc, oh, ow],
                    fmt: self.cfg.act_fmt,
                }),
            });
            lo += oc;
        }
        Some(ProgramTemplate {
            target: Target::Hlscnn,
            invocations,
            stitch: Stitch::Concat { axis: 1, shape: vec![1, o, oh, ow] },
            mirrors: 0,
            operand_shapes: vec![x.shape.clone(), w.shape.clone()],
            weight_ops: vec![(1, w.fingerprint())],
            calib: BindCalib::None,
            scale_rule: ScaleRule::None,
            patches: Vec::new(),
        })
    }
}

impl Accelerator for Hlscnn {
    fn name(&self) -> &'static str {
        "HLSCNN"
    }

    fn target(&self) -> Target {
        Target::Hlscnn
    }

    fn build_ila(&self) -> Ila {
        model::build_ila(*self)
    }

    fn exec_op(&self, op: &Op, inputs: &[&Tensor]) -> Option<Tensor> {
        match op {
            Op::HlscnnConv2d { stride, pad } => {
                Some(self.conv2d(inputs[0], inputs[1], *stride, *pad))
            }
            _ => None,
        }
    }

    fn lower(&self, op: &Op, inputs: &[&Tensor]) -> Option<Arc<ProgramTemplate>> {
        match op {
            Op::HlscnnConv2d { stride, pad } => self
                .lower_conv2d(inputs[0], inputs[1], *stride, *pad)
                .map(Arc::new),
            _ => None,
        }
    }

    fn weight_operands(&self, op: &Op) -> &'static [usize] {
        match op {
            Op::HlscnnConv2d { .. } => &[1],
            _ => &[],
        }
    }

    fn supported_ops(&self) -> Vec<&'static str> {
        vec!["Conv2D"]
    }
}

/// Literature-calibrated timing constants for HLSCNN (see
/// [`crate::cost`]). HLS-generated FPGA-class control dominates: the
/// accelerator (Whatmough et al., VLSI'19 lineage) takes more cycles per
/// MMIO beat and per trigger than the hand-tuned FlexASR datapath:
///
/// * `mmio_beat_cycles = 8` — HLS AXI-lite style register/buffer writes
///   cost several fabric cycles of handshake per 16-byte beat.
/// * `dma_bytes_per_cycle = 16` — a 128-bit internal bus.
/// * A conv trigger walks the full filter window per output pixel
///   (256 cycles per channel-tile trigger); other families (never
///   mapped here today) default to 128.
/// * Resets re-arm the config registers (48) and restore dirty
///   activation/weight SRAM at 32 B/cycle.
/// * `bind_cycles = 8` — flat host-side template-bind overhead per call.
pub fn cost_model() -> crate::cost::CostModel {
    use crate::cost::{CostModel, OpFamily};
    let mut b = CostModel::zero()
        .builder()
        .mmio_beat_cycles(8)
        .dma_bytes_per_cycle(16)
        .reset_base_cycles(48)
        .restore_bytes_per_cycle(32)
        .bind_cycles(8);
    for f in OpFamily::ALL {
        b = b.trigger(f, 128);
    }
    b.trigger(OpFamily::Conv, 256).build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;
    use crate::util::Rng;

    #[test]
    fn conv_error_nonzero_under_quantization() {
        let dev = Hlscnn::new(HlscnnConfig::updated());
        let mut rng = Rng::new(31);
        let x = Tensor::randn(&[1, 3, 8, 8], &mut rng, 1.0);
        let w = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.3);
        let acc = dev.conv2d(&x, &w, (1, 1), (1, 1));
        let reference = ops::conv2d(&x, &w, (1, 1), (1, 1));
        let e = acc.rel_error(&reference);
        assert!(e > 0.0 && e < 0.05, "e={e}");
    }

    #[test]
    fn original_8bit_much_lossier_than_updated_16bit() {
        // the Table 4 root cause in miniature
        let mut rng = Rng::new(32);
        let x = Tensor::randn(&[1, 3, 8, 8], &mut rng, 1.0);
        // trained conv weights: small typical scale
        let w = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.08);
        let reference = ops::conv2d(&x, &w, (1, 1), (1, 1));
        let e8 = Hlscnn::new(HlscnnConfig::original())
            .conv2d(&x, &w, (1, 1), (1, 1))
            .rel_error(&reference);
        let e16 = Hlscnn::new(HlscnnConfig::updated())
            .conv2d(&x, &w, (1, 1), (1, 1))
            .rel_error(&reference);
        assert!(
            e8 > 5.0 * e16,
            "8-bit ({e8}) must be far lossier than 16-bit ({e16})"
        );
    }

    #[test]
    fn exec_op_rejects_foreign_ops() {
        let dev = Hlscnn::default();
        let t = Tensor::ones(&[2, 2]);
        assert!(dev.exec_op(&Op::FlexMaxpool, &[&t]).is_none());
    }
}
