//! HLSCNN — a coarse-grained 2-D convolution accelerator (Whatmough et
//! al., VLSI'19) operating on **8/16-bit fixed point** data.
//!
//! One supported operation (Appendix A): non-grouped 2-D convolution.
//! Weights are stored in a narrow fixed-point format — **8-bit in the
//! original design** — while activations use 16-bit fixed point and MACs
//! accumulate in wide integers. Table 4's co-design case study: the 8-bit
//! weight store quantizes trained CIFAR conv weights so hard that
//! application accuracy collapses (91.55% → 29.15% for ResNet-20);
//! widening the weight store to 16 bits recovers it. Both configurations
//! are modeled here via [`HlscnnConfig`].

pub mod model;

use super::Accelerator;
use crate::ila::Ila;
use crate::ir::{Op, Target};
use crate::numerics::fixed_point::FixedPointFormat;
use crate::numerics::NumericFormat;
use crate::tensor::{ops, Tensor};

/// HLSCNN numerics configuration — the co-design knob of Table 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HlscnnConfig {
    /// Weight storage format.
    pub weight_fmt: FixedPointFormat,
    /// Activation (feature map) format.
    pub act_fmt: FixedPointFormat,
}

impl HlscnnConfig {
    /// The original silicon: weights share the 8-bit fixed-point format
    /// that the accumulator path needs for its value range (few fraction
    /// bits) — which quantizes trained conv weights to a handful of
    /// coarse steps. This is the Table 4 root cause ("weight data values
    /// ... heavily quantized by its 8-bit fixed point data type due to a
    /// narrower value range").
    pub fn original() -> Self {
        HlscnnConfig {
            weight_fmt: FixedPointFormat::new(8, 2),
            act_fmt: FixedPointFormat::new(16, 8),
        }
    }

    /// The developer fix from the Table 4 case study: 16-bit weights.
    pub fn updated() -> Self {
        HlscnnConfig {
            weight_fmt: FixedPointFormat::new(16, 12),
            act_fmt: FixedPointFormat::new(16, 8),
        }
    }
}

/// The HLSCNN accelerator model.
#[derive(Debug, Clone, Copy)]
pub struct Hlscnn {
    pub cfg: HlscnnConfig,
}

impl Default for Hlscnn {
    fn default() -> Self {
        Hlscnn { cfg: HlscnnConfig::updated() }
    }
}

impl Hlscnn {
    pub fn new(cfg: HlscnnConfig) -> Self {
        Hlscnn { cfg }
    }

    /// Bit-accurate 2-D convolution: weights and activations snapped to
    /// their fixed-point lattices, wide MAC accumulation, output
    /// requantized to the activation format.
    pub fn conv2d(
        &self,
        x: &Tensor,
        w: &Tensor,
        stride: (usize, usize),
        pad: (usize, usize),
    ) -> Tensor {
        let xq = self.cfg.act_fmt.quantize(x);
        let wq = self.cfg.weight_fmt.quantize(w);
        // both operand lattices are dyadic, so f32 conv over lattice
        // values reproduces the integer MAC datapath exactly at these
        // magnitudes; the lossy step is the output requantization.
        let acc = ops::conv2d(&xq, &wq, stride, pad);
        self.cfg.act_fmt.quantize(&acc)
    }
}

impl Accelerator for Hlscnn {
    fn name(&self) -> &'static str {
        "HLSCNN"
    }

    fn target(&self) -> Target {
        Target::Hlscnn
    }

    fn build_ila(&self) -> Ila {
        model::build_ila(*self)
    }

    fn exec_op(&self, op: &Op, inputs: &[&Tensor]) -> Option<Tensor> {
        match op {
            Op::HlscnnConv2d { stride, pad } => {
                Some(self.conv2d(inputs[0], inputs[1], *stride, *pad))
            }
            _ => None,
        }
    }

    fn supported_ops(&self) -> Vec<&'static str> {
        vec!["Conv2D"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn conv_error_nonzero_under_quantization() {
        let dev = Hlscnn::new(HlscnnConfig::updated());
        let mut rng = Rng::new(31);
        let x = Tensor::randn(&[1, 3, 8, 8], &mut rng, 1.0);
        let w = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.3);
        let acc = dev.conv2d(&x, &w, (1, 1), (1, 1));
        let reference = ops::conv2d(&x, &w, (1, 1), (1, 1));
        let e = acc.rel_error(&reference);
        assert!(e > 0.0 && e < 0.05, "e={e}");
    }

    #[test]
    fn original_8bit_much_lossier_than_updated_16bit() {
        // the Table 4 root cause in miniature
        let mut rng = Rng::new(32);
        let x = Tensor::randn(&[1, 3, 8, 8], &mut rng, 1.0);
        // trained conv weights: small typical scale
        let w = Tensor::randn(&[4, 3, 3, 3], &mut rng, 0.08);
        let reference = ops::conv2d(&x, &w, (1, 1), (1, 1));
        let e8 = Hlscnn::new(HlscnnConfig::original())
            .conv2d(&x, &w, (1, 1), (1, 1))
            .rel_error(&reference);
        let e16 = Hlscnn::new(HlscnnConfig::updated())
            .conv2d(&x, &w, (1, 1), (1, 1))
            .rel_error(&reference);
        assert!(
            e8 > 5.0 * e16,
            "8-bit ({e8}) must be far lossier than 16-bit ({e16})"
        );
    }

    #[test]
    fn exec_op_rejects_foreign_ops() {
        let dev = Hlscnn::default();
        let t = Tensor::ones(&[2, 2]);
        assert!(dev.exec_op(&Op::FlexMaxpool, &[&t]).is_none());
    }
}
