//! VTA — the Versatile Tensor Accelerator (Moreau et al., IEEE Micro'19):
//! a fine-grained, processor-like tensor accelerator with an ISA, an int8
//! GEMM core with int32 accumulators, and a vector ALU.
//!
//! Appendix A: our prototype implements matrix multiplication and
//! addition as fixed sequences of VTA ILA instructions. Because VTA's
//! arithmetic is plain integer arithmetic and the Table 2 reference runs
//! on the same int8 operands, GEMM validates **exactly** (0.00% error —
//! Table 2 row 1).

pub mod model;

use super::Accelerator;
use crate::codegen::{
    BindCalib, Burst, OperandSlot, ProgramTemplate, ReadPlan,
    ScaleRule, SlotCodec, Stitch, TemplateBurst, TemplateInvocation,
};
use crate::ila::asm::Fragment;
use crate::ila::{Cmd, Ila};
use crate::ir::{Op, Target};
use crate::numerics::int8::{int8_gemm_acc, Int8Format};
use crate::tensor::Tensor;
use self::model as vx;
use std::sync::Arc;

/// The VTA accelerator model.
#[derive(Debug, Clone, Copy, Default)]
pub struct Vta {
    /// The int8 quantization format (per-tensor power-of-two scales).
    pub int8: Int8Format,
}

impl Vta {
    /// Default int8 configuration.
    pub fn new() -> Self {
        Vta { int8: Int8Format::new() }
    }

    /// Quantize to the int8 lattice (per-tensor power-of-two scale).
    pub fn quant(&self, t: &Tensor) -> Tensor {
        use crate::numerics::NumericFormat;
        self.int8.quantize(t)
    }

    /// GEMM (dense semantics x @ w^T): int8 operands, int32 accumulation,
    /// f32 dequantization with the product of the operand scales. Exact
    /// with respect to integer arithmetic.
    pub fn gemm(&self, x: &Tensor, w: &Tensor) -> Tensor {
        let (n, k) = (x.shape[0], x.shape[1]);
        let m = w.shape[0];
        let sx = self.int8.select_scale(x.max_abs());
        let sw = self.int8.select_scale(w.max_abs());
        let xc: Vec<i8> = x.data.iter().map(|&v| self.int8.encode(v, sx)).collect();
        let wc: Vec<i8> = w.data.iter().map(|&v| self.int8.encode(v, sw)).collect();
        let acc = int8_gemm_acc(&xc, &wc, n, k, m);
        Tensor::new(
            vec![n, m],
            acc.into_iter().map(|a| a as f32 * sx * sw).collect(),
        )
    }

    /// Lower `vta_gemm` (dense semantics) to the fixed
    /// load/load/reset/gemm/store instruction sequence (Appendix A).
    ///
    /// The weight operand is encoded into a concrete staged burst at
    /// lowering (its scale `sw` is baked into the template's
    /// [`ScaleRule::VtaGemm`]); the activation operand is a late-bound
    /// [`OperandSlot`] whose int8 scale `sx` and the `sx · sw` read-back
    /// dequantization are resolved per bind — exactly the values a
    /// monolithic lowering of the same operands would compute.
    fn lower_gemm(&self, x: &Tensor, w: &Tensor) -> Option<ProgramTemplate> {
        if x.shape.len() != 2 || w.shape.len() != 2 {
            return None;
        }
        let (n, k) = (x.shape[0], x.shape[1]);
        let m = w.shape[0];
        if w.shape[1] != k || n == 0 || k == 0 || m == 0 {
            return None;
        }
        // instruction-word field widths and scratchpad capacities
        if n > 0xFFFF || k > 0xFFFF || m > 0xFFFF || n * m > u32::MAX as usize {
            return None;
        }
        if n * k > vx::INP_SIZE || m * k > vx::WGT_SIZE || n * m * 4 > vx::ACC_SIZE {
            return None;
        }
        let sw = self.int8.select_scale(w.max_abs());
        let wc: Vec<u8> = w.data.iter().map(|&v| self.int8.encode(v, sw) as u8).collect();

        let bursts = vec![
            TemplateBurst::Slot(OperandSlot {
                operand: 0,
                base: vx::INP_BASE,
                bytes: 0..n * k,
                codec: SlotCodec::VtaI8,
            }),
            TemplateBurst::Concrete(Burst::stage(vx::WGT_BASE, &wc)),
            TemplateBurst::Concrete(Burst::control(vec![
                Cmd::write(vx::INSN_ADDR, vx::insn_reset((n * m) as u32)),
                Cmd::write(vx::INSN_ADDR, vx::insn_gemm(n as u16, k as u16, m as u16)),
            ])),
        ];

        let mut asm = Fragment::new();
        asm.push("VTA_ILA.load_inp", &["%x"])
            .push("VTA_ILA.load_wgt", &["%w"])
            .push("VTA_ILA.reset_acc", &[])
            .push("VTA_ILA.gemm", &["%n", "%k", "%m"])
            .push("VTA_ILA.store_out", &["%out"]);

        Some(ProgramTemplate {
            target: Target::Vta,
            invocations: vec![TemplateInvocation {
                target: Target::Vta,
                asm,
                bursts,
                // placeholder scale; the bind rewrites it to `sx · sw`
                read: Some(ReadPlan::VtaI32 {
                    base: vx::ACC_BASE,
                    shape: vec![n, m],
                    scale: sw,
                }),
            }],
            stitch: Stitch::Last,
            mirrors: 0,
            operand_shapes: vec![x.shape.clone(), w.shape.clone()],
            weight_ops: vec![(1, w.fingerprint())],
            calib: BindCalib::None,
            scale_rule: ScaleRule::VtaGemm { sw },
            patches: Vec::new(),
        })
    }

    /// Lower `vta_add` to driver-level int32 ALU operand staging: the
    /// left operand's pre-scaled int32 codes go straight into the
    /// accumulator scratchpad (`load_acc`), the right operand's into the
    /// weight scratchpad, then one saturating `alu_add` per chunk and an
    /// accumulator read-back. Tensors larger than the scratchpads are
    /// processed in flat chunks (the driver's loop) and stitched by
    /// concatenation — bit-exact because the shared power-of-two scale
    /// is per-*tensor* and computed once by the driver.
    fn lower_add(&self, a: &Tensor, b: &Tensor) -> Option<ProgramTemplate> {
        self.lower_add_template(a, b, usize::MAX)
    }

    /// Template form of the ALU-add lowering: both operands are
    /// late-bound slots (neither is a weight), sharing one bind-time
    /// scale ([`ScaleRule::VtaAdd`]) exactly as the driver's monolithic
    /// loop shares one per-tensor scale. Each chunk invocation slices
    /// `4·lo .. 4·(lo+len)` out of the operands' widened i32
    /// accumulator-word streams. A `cap` below the buffer capacity is
    /// the translation-validation override: small obligation shapes
    /// still exercise genuine multi-chunk programs.
    pub(crate) fn lower_add_template(
        &self,
        a: &Tensor,
        b: &Tensor,
        cap: usize,
    ) -> Option<ProgramTemplate> {
        // the staged form requires equal shapes; broadcast adds fall
        // back to the (integer-exact) tensor path
        if a.shape != b.shape || a.data.is_empty() {
            return None;
        }
        let chunk_cap = (vx::ACC_SIZE / 4)
            .min(vx::WGT_SIZE / 4)
            .min(u32::MAX as usize)
            .min(cap.max(1));
        let total = a.data.len();
        let mut invocations = Vec::new();
        let mut lo = 0usize;
        while lo < total {
            let len = chunk_cap.min(total - lo);
            let bursts = vec![
                TemplateBurst::Slot(OperandSlot {
                    operand: 0,
                    base: vx::ACC_BASE,
                    bytes: 4 * lo..4 * (lo + len),
                    codec: SlotCodec::VtaI8Acc,
                }),
                TemplateBurst::Slot(OperandSlot {
                    operand: 1,
                    base: vx::WGT_BASE,
                    bytes: 4 * lo..4 * (lo + len),
                    codec: SlotCodec::VtaI8Acc,
                }),
                TemplateBurst::Concrete(Burst::control(vec![Cmd::write(
                    vx::INSN_ADDR,
                    vx::insn_alu_add(len as u32, true),
                )])),
            ];

            let mut asm = Fragment::new();
            asm.push("VTA_ILA.load_acc", &["%a_chunk"])
                .push("VTA_ILA.load_wgt", &["%b_chunk"])
                .push("VTA_ILA.alu_add_sat", &["%len"])
                .push("VTA_ILA.store_out", &["%out_chunk"]);

            invocations.push(TemplateInvocation {
                target: Target::Vta,
                asm,
                bursts,
                // placeholder scale; the bind rewrites it to the shared
                // joint-max scale
                read: Some(ReadPlan::VtaI32 {
                    base: vx::ACC_BASE,
                    shape: vec![len],
                    scale: 1.0,
                }),
            });
            lo += len;
        }
        Some(ProgramTemplate {
            target: Target::Vta,
            invocations,
            stitch: Stitch::Concat { axis: 0, shape: a.shape.clone() },
            mirrors: 0,
            operand_shapes: vec![a.shape.clone(), b.shape.clone()],
            weight_ops: Vec::new(),
            calib: BindCalib::None,
            scale_rule: ScaleRule::VtaAdd,
            patches: Vec::new(),
        })
    }

    /// Elementwise add on the vector ALU: int8 operands at a shared
    /// scale, int32 add, saturating writeback to int8.
    pub fn alu_add(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let scale = self
            .int8
            .select_scale(a.max_abs().max(b.max_abs()));
        let out = a.zip(b, |x, y| {
            let xa = self.int8.encode(x, scale) as i32;
            let ya = self.int8.encode(y, scale) as i32;
            let sum = (xa + ya).clamp(-127, 127);
            sum as f32 * scale
        });
        out
    }
}

impl Accelerator for Vta {
    fn name(&self) -> &'static str {
        "VTA"
    }

    fn target(&self) -> Target {
        Target::Vta
    }

    fn build_ila(&self) -> Ila {
        model::build_ila(*self)
    }

    fn exec_op(&self, op: &Op, inputs: &[&Tensor]) -> Option<Tensor> {
        match op {
            Op::VtaGemm => Some(self.gemm(inputs[0], inputs[1])),
            Op::VtaAdd => Some(self.alu_add(inputs[0], inputs[1])),
            _ => None,
        }
    }

    fn lower(&self, op: &Op, inputs: &[&Tensor]) -> Option<Arc<ProgramTemplate>> {
        let tmpl = match op {
            Op::VtaGemm => self.lower_gemm(inputs[0], inputs[1])?,
            Op::VtaAdd => self.lower_add(inputs[0], inputs[1])?,
            _ => return None,
        };
        Some(Arc::new(tmpl))
    }

    fn weight_operands(&self, op: &Op) -> &'static [usize] {
        match op {
            // GEMM bakes the pre-encoded weight matrix into a concrete
            // staged burst; the ALU add has no weight operands (both
            // sides are late-bound).
            Op::VtaGemm => &[1],
            _ => &[],
        }
    }

    fn supported_ops(&self) -> Vec<&'static str> {
        vec!["GEMM", "ALU-Add"]
    }
}

/// Literature-calibrated timing constants for VTA (see [`crate::cost`]).
/// VTA (Moreau et al., IEEE Micro'19) is instruction-driven with a
/// decoupled access/execute pipeline, so per-trigger latency is low and
/// throughput comes from keeping the GEMM core fed:
///
/// * `mmio_beat_cycles = 6` — the FPGA shell's memory-mapped load path.
/// * `dma_bytes_per_cycle = 16` — 128-bit load/store units.
/// * A GEMM instruction retires a 16×16 int8 tile through the systolic
///   array in ~64 cycles; a vector ALU op is half that (32); 48 covers
///   unprofiled families.
/// * Resets are cheap (24) — the ISA has an explicit accumulator-reset
///   instruction — with restores at 32 B/cycle.
/// * `bind_cycles = 6` — filling a template's operand slots is one int8
///   encode pass on the host, cheaper than FlexASR's AdaptivFloat
///   bind (8) thanks to the trivial codec.
pub fn cost_model() -> crate::cost::CostModel {
    use crate::cost::{CostModel, OpFamily};
    let mut b = CostModel::zero()
        .builder()
        .mmio_beat_cycles(6)
        .dma_bytes_per_cycle(16)
        .reset_base_cycles(24)
        .restore_bytes_per_cycle(32)
        .bind_cycles(6);
    for f in OpFamily::ALL {
        b = b.trigger(f, 48);
    }
    b.trigger(OpFamily::Gemm, 64).trigger(OpFamily::Alu, 32).build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;
    use crate::util::Rng;

    #[test]
    fn gemm_exact_on_int8_lattice() {
        // Table 2 row 1: VTA GEMM error 0.00% — reference over the same
        // int8 operands is identical integer arithmetic.
        let vta = Vta::new();
        let mut rng = Rng::new(51);
        let x = vta.quant(&Tensor::randn(&[8, 32], &mut rng, 1.0));
        let w = vta.quant(&Tensor::randn(&[16, 32], &mut rng, 1.0));
        let acc = vta.gemm(&x, &w);
        let reference = ops::dense(&x, &w);
        assert_eq!(acc.rel_error(&reference), 0.0);
    }

    #[test]
    fn alu_add_saturates() {
        let vta = Vta::new();
        let a = Tensor::new(vec![2], vec![100.0, -100.0]);
        let b = Tensor::new(vec![2], vec![100.0, -100.0]);
        let y = vta.alu_add(&a, &b);
        // scale covers 100 -> 127*s >= 100; 200 > 127*s saturates
        let s = vta.int8.select_scale(100.0);
        assert_eq!(y.data[0], 127.0 * s);
        assert_eq!(y.data[1], -127.0 * s);
    }

    #[test]
    fn gemm_nonlattice_inputs_still_close() {
        let vta = Vta::new();
        let mut rng = Rng::new(52);
        let x = Tensor::randn(&[4, 16], &mut rng, 1.0);
        let w = Tensor::randn(&[4, 16], &mut rng, 1.0);
        let acc = vta.gemm(&x, &w);
        let reference = ops::dense(&x, &w);
        let e = acc.rel_error(&reference);
        assert!(e > 0.0 && e < 0.05, "e={e}");
    }
}
