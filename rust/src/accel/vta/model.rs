//! The VTA ILA model over its (memory-mapped) instruction interface.
//!
//! VTA is ISA-driven rather than config-register-driven: the host enqueues
//! LOAD/GEMM/ALU/STORE instructions. We model the queue head as a single
//! MMIO doorbell: each 128-bit write to `INSN_ADDR` is one VTA instruction
//! word, decoded by opcode — matching how the VTA ILA in the paper assigns
//! one ILA instruction per ISA instruction.

use super::Vta;
use crate::ila::{Cmd, Ila, IlaState};

// ----- address map ------------------------------------------------------
/// Instruction doorbell.
pub const INSN_ADDR: u64 = 0xC000_0010;
/// Input (activation) scratchpad: 64 KiB of int8 codes.
pub const INP_BASE: u64 = 0xC010_0000;
/// Input scratchpad size in bytes.
pub const INP_SIZE: usize = 0x1_0000;
/// Weight scratchpad: 64 KiB of int8 codes.
pub const WGT_BASE: u64 = 0xC020_0000;
/// Weight scratchpad size in bytes.
pub const WGT_SIZE: usize = 0x1_0000;
/// Accumulator/output scratchpad: 256 KiB of int32 codes.
pub const ACC_BASE: u64 = 0xC030_0000;
/// Accumulator scratchpad size in bytes.
pub const ACC_SIZE: usize = 0x4_0000;

// ----- instruction opcodes (byte 0 of the instruction word) -------------
/// GEMM instruction opcode.
pub const VTA_GEMM: u8 = 1;
/// Vector-ALU add instruction opcode.
pub const VTA_ALU_ADD: u8 = 2;
/// Accumulator-reset instruction opcode.
pub const VTA_RESET_ACC: u8 = 3;

/// Pack a GEMM instruction: gemm over x[n,k] (inp), w[m,k] (wgt) into
/// acc[n,m] (int32 accumulate on top of existing acc contents).
pub fn insn_gemm(n: u16, k: u16, m: u16) -> [u8; 16] {
    let mut w = [0u8; 16];
    w[0] = VTA_GEMM;
    w[2..4].copy_from_slice(&n.to_le_bytes());
    w[4..6].copy_from_slice(&k.to_le_bytes());
    w[6..8].copy_from_slice(&m.to_le_bytes());
    w
}

/// Pack an ALU-add instruction: acc[i] += inp2[i] over `len` int32 lanes
/// (operand streamed into the weight scratchpad as int32). With
/// `saturate`, the write-back clamps each lane to the int8 value range
/// [-127, 127] — the vector ALU's saturating int8 mode, which is what
/// the driver-level `vta_add` lowering uses so the MMIO result matches
/// the tensor fast path's saturating semantics bit-exactly.
pub fn insn_alu_add(len: u32, saturate: bool) -> [u8; 16] {
    let mut w = [0u8; 16];
    w[0] = VTA_ALU_ADD;
    w[1] = saturate as u8;
    w[2..6].copy_from_slice(&len.to_le_bytes());
    w
}

/// Pack an accumulator-reset instruction.
pub fn insn_reset(len: u32) -> [u8; 16] {
    let mut w = [0u8; 16];
    w[0] = VTA_RESET_ACC;
    w[2..6].copy_from_slice(&len.to_le_bytes());
    w
}

/// Build the VTA ILA.
pub fn build_ila(_dev: Vta) -> Ila {
    let mut st = IlaState::new();
    st.new_mem("inp", INP_SIZE);
    st.new_mem("wgt", WGT_SIZE);
    st.new_mem("acc", ACC_SIZE);
    let mut ila = Ila::new("VTA_ILA", st);

    for (name, base, size, mem) in [
        ("load_inp", INP_BASE, INP_SIZE as u64, "inp"),
        ("load_wgt", WGT_BASE, WGT_SIZE as u64, "wgt"),
        // int32 ALU operand staging: the driver writes pre-scaled
        // accumulator words directly (the `vta_add` lowering)
        ("load_acc", ACC_BASE, ACC_SIZE as u64, "acc"),
    ] {
        ila.instr(
            name,
            move |c, _| c.is_write && (base..base + size).contains(&c.addr),
            move |c, s| {
                let off = (c.addr - base) as usize;
                // byte-enabled store: a short final beat must not clobber
                // bytes past the streamed slice
                s.mem_write(mem, off, c.payload());
                Ok(None)
            },
        );
    }
    ila.instr(
        "store_out",
        |c, _| !c.is_write && (ACC_BASE..ACC_BASE + ACC_SIZE as u64).contains(&c.addr),
        |c, s| {
            let off = (c.addr - ACC_BASE) as usize;
            let mut out = [0u8; 16];
            out.copy_from_slice(&s.mem("acc")[off..off + 16]);
            Ok(Some(out))
        },
    );

    // one ILA instruction per ISA opcode, decoded from the doorbell word
    ila.instr(
        "gemm",
        |c, _| c.is_write && c.addr == INSN_ADDR && c.data[0] == VTA_GEMM,
        |c, s| {
            let n = u16::from_le_bytes(c.data[2..4].try_into().unwrap()) as usize;
            let k = u16::from_le_bytes(c.data[4..6].try_into().unwrap()) as usize;
            let m = u16::from_le_bytes(c.data[6..8].try_into().unwrap()) as usize;
            if n * k > INP_SIZE || m * k > WGT_SIZE || n * m * 4 > ACC_SIZE {
                return Err(format!("gemm {n}x{k}x{m} exceeds scratchpads"));
            }
            let inp = s.mem("inp")[..n * k].to_vec();
            let wgt = s.mem("wgt")[..m * k].to_vec();
            let acc = s.mem_range_mut("acc", 0, 4 * n * m);
            for i in 0..n {
                for j in 0..m {
                    let mut sum: i32 = 0;
                    for t in 0..k {
                        sum += (inp[i * k + t] as i8) as i32 * (wgt[j * k + t] as i8) as i32;
                    }
                    let off = 4 * (i * m + j);
                    let cur = i32::from_le_bytes(acc[off..off + 4].try_into().unwrap());
                    acc[off..off + 4].copy_from_slice(&(cur + sum).to_le_bytes());
                }
            }
            Ok(None)
        },
    );
    ila.instr(
        "alu_add",
        |c, _| c.is_write && c.addr == INSN_ADDR && c.data[0] == VTA_ALU_ADD,
        |c, s| {
            let saturate = c.data[1] != 0;
            let len = u32::from_le_bytes(c.data[2..6].try_into().unwrap()) as usize;
            if len * 4 > ACC_SIZE || len * 4 > WGT_SIZE {
                return Err("alu_add length exceeds scratchpads".into());
            }
            let operand = s.mem("wgt")[..len * 4].to_vec();
            let acc = s.mem_range_mut("acc", 0, 4 * len);
            for i in 0..len {
                let a =
                    i32::from_le_bytes(acc[4 * i..4 * i + 4].try_into().unwrap());
                let b = i32::from_le_bytes(
                    operand[4 * i..4 * i + 4].try_into().unwrap(),
                );
                let sum = if saturate { (a + b).clamp(-127, 127) } else { a + b };
                acc[4 * i..4 * i + 4].copy_from_slice(&sum.to_le_bytes());
            }
            Ok(None)
        },
    );
    ila.instr(
        "reset_acc",
        |c, _| c.is_write && c.addr == INSN_ADDR && c.data[0] == VTA_RESET_ACC,
        |c, s| {
            let len = u32::from_le_bytes(c.data[2..6].try_into().unwrap()) as usize;
            let acc = s.mem_range_mut("acc", 0, (len * 4).min(ACC_SIZE));
            for b in acc.iter_mut() {
                *b = 0;
            }
            Ok(None)
        },
    );
    // residency contract: the inp/wgt scratchpads are host-exclusive
    // (gemm/alu/reset write only `acc`), so staged operands may stay
    // device-resident across invocations. `acc` is NOT stageable — every
    // compute instruction mutates it.
    ila.stage_region("inp", INP_BASE, INP_SIZE);
    ila.stage_region("wgt", WGT_BASE, WGT_SIZE);
    ila
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ila::sim::IlaSim;
    use crate::numerics::int8::int8_gemm_acc;
    use crate::util::Rng;

    fn stream(sim: &mut IlaSim, base: u64, bytes: &[u8]) {
        for (i, chunk) in bytes.chunks(16).enumerate() {
            let mut data = [0u8; 16];
            data[..chunk.len()].copy_from_slice(chunk);
            sim.step(&Cmd::write(base + 16 * i as u64, data)).unwrap();
        }
    }

    /// VT3-style consistency: the MMIO GEMM must equal the int8 reference.
    #[test]
    fn mmio_gemm_matches_int8_reference() {
        let mut rng = Rng::new(61);
        let (n, k, m) = (4usize, 16usize, 8usize);
        let x: Vec<i8> =
            (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let w: Vec<i8> =
            (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let expect = int8_gemm_acc(&x, &w, n, k, m);

        let mut sim = IlaSim::new(build_ila(Vta::new()));
        let xb: Vec<u8> = x.iter().map(|&v| v as u8).collect();
        let wb: Vec<u8> = w.iter().map(|&v| v as u8).collect();
        stream(&mut sim, INP_BASE, &xb);
        stream(&mut sim, WGT_BASE, &wb);
        sim.step(&Cmd::write(INSN_ADDR, insn_reset((n * m) as u32))).unwrap();
        sim.step(&Cmd::write(INSN_ADDR, insn_gemm(n as u16, k as u16, m as u16)))
            .unwrap();

        let mut got = Vec::new();
        let mut addr = ACC_BASE;
        while got.len() < n * m {
            let d = sim.step(&Cmd::read(addr)).unwrap().unwrap();
            for q in d.chunks(4) {
                got.push(i32::from_le_bytes(q.try_into().unwrap()));
            }
            addr += 16;
        }
        got.truncate(n * m);
        assert_eq!(got, expect);
    }

    #[test]
    fn oversized_gemm_rejected() {
        let mut sim = IlaSim::new(build_ila(Vta::new()));
        assert!(sim
            .step(&Cmd::write(INSN_ADDR, insn_gemm(1000, 1000, 1000)))
            .is_err());
    }
}
